//! The proxy detector ("TinyBlobNet"): a YOLO-style single-scale CNN over
//! the synthetic scenes.
//!
//! Weights come from the build-time JAX training run (`python -m
//! compile.train`, exported to `artifacts/detector_weights.json`) or from
//! an analytic template construction good enough for unit tests. The same
//! architecture is defined in `python/compile/model.py` — the AOT HLO the
//! Rust runtime executes is lowered from there, and an integration test
//! cross-checks the two.

use std::collections::HashMap;

use crate::ir::interp::{Interpreter, Value};
use crate::ir::{ActivationKind, Graph, GraphBuilder, PaddingMode};
use crate::postproc::bbox::Detection;
use crate::postproc::map::{mean_average_precision, GroundTruth};
use crate::postproc::nms::{decode_and_nms, NmsConfig};
use crate::util::json::Json;
use crate::util::Rng;

use super::scenes::Scene;

/// Object classes in the synthetic benchmark.
pub const NUM_CLASSES: usize = 4;
/// Anchors per cell (sizes 2.5 and 5 grid cells — see `ir::interp`).
pub const NUM_ANCHORS: usize = 2;
/// Detector layer channel plan: (out_c, kernel, stride).
pub const LAYERS: [(usize, usize, usize); 3] = [(16, 5, 2), (32, 3, 2), (32, 3, 2)];

/// Head channels.
pub fn head_channels() -> usize {
    NUM_ANCHORS * (5 + NUM_CLASSES)
}

/// One conv layer's weights.
#[derive(Debug, Clone)]
pub struct ConvWeights {
    /// `[oc, kh, kw, ic]` row-major.
    pub shape: [usize; 4],
    pub w: Vec<f32>,
    pub b: Vec<f32>,
}

/// All detector weights (3 backbone convs + head).
#[derive(Debug, Clone)]
pub struct DetectorWeights {
    pub convs: Vec<ConvWeights>,
}

impl DetectorWeights {
    /// Parse from the JSON emitted by `python/compile/train.py`.
    pub fn from_json(text: &str) -> Result<Self, String> {
        let j = Json::parse(text)?;
        let layers = j.get("layers").and_then(|l| l.as_arr()).ok_or("missing layers")?;
        let mut convs = Vec::new();
        for l in layers {
            let shape_v = l.get("shape").and_then(|s| s.as_arr()).ok_or("missing shape")?;
            if shape_v.len() != 4 {
                return Err("shape must be rank 4".into());
            }
            let mut shape = [0usize; 4];
            for (i, s) in shape_v.iter().enumerate() {
                shape[i] = s.as_f64().ok_or("bad shape entry")? as usize;
            }
            let w: Vec<f32> = l
                .get("w")
                .and_then(|w| w.as_arr())
                .ok_or("missing w")?
                .iter()
                .map(|v| v.as_f64().unwrap_or(0.0) as f32)
                .collect();
            let b: Vec<f32> = l
                .get("b")
                .and_then(|b| b.as_arr())
                .ok_or("missing b")?
                .iter()
                .map(|v| v.as_f64().unwrap_or(0.0) as f32)
                .collect();
            if w.len() != shape.iter().product::<usize>() || b.len() != shape[0] {
                return Err(format!("weight sizes inconsistent with shape {shape:?}"));
            }
            convs.push(ConvWeights { shape, w, b });
        }
        if convs.len() != LAYERS.len() + 1 {
            return Err(format!("expected {} conv layers, got {}", LAYERS.len() + 1, convs.len()));
        }
        Ok(Self { convs })
    }

    /// Load from `artifacts/detector_weights.json` if present.
    pub fn load(path: &str) -> Option<Self> {
        let text = std::fs::read_to_string(path).ok()?;
        Self::from_json(&text).ok()
    }

    /// Analytic template weights: layer-1 centre-surround + edge filters,
    /// energy aggregation, and a hand-set head. Detects bright compact
    /// objects well enough for unit tests and as a training-free fallback.
    pub fn analytic() -> Self {
        let mut convs = Vec::new();
        // ---- conv1: 16 × 5×5×3 ----
        let (oc1, k1, ic1) = (16usize, 5usize, 3usize);
        let mut w1 = vec![0f32; oc1 * k1 * k1 * ic1];
        let mut set1 = |o: usize, y: usize, x: usize, v: f32| {
            for c in 0..ic1 {
                w1[((o * k1 + y) * k1 + x) * ic1 + c] = v / ic1 as f32;
            }
        };
        for o in 0..oc1 {
            for y in 0..k1 {
                for x in 0..k1 {
                    let dy = y as f32 - 2.0;
                    let dx = x as f32 - 2.0;
                    let r2 = dx * dx + dy * dy;
                    let v = match o % 8 {
                        // centre-surround (blob) at two scales
                        0 => (-r2 / 1.5).exp() - 0.45 * (-r2 / 6.0).exp(),
                        1 => (-r2 / 3.0).exp() - 0.55 * (-r2 / 10.0).exp(),
                        // oriented edges
                        2 => dx / 2.0 * (-r2 / 4.0).exp(),
                        3 => dy / 2.0 * (-r2 / 4.0).exp(),
                        4 => (dx + dy) / 2.8 * (-r2 / 4.0).exp(),
                        5 => (dx - dy) / 2.8 * (-r2 / 4.0).exp(),
                        // ring (inverted centre)
                        6 => (-(r2 - 4.0).abs() / 1.5).exp() - 0.5 * (-r2 / 1.0).exp(),
                        // brightness
                        _ => 0.15,
                    };
                    set1(o, y, x, v * 1.4);
                }
            }
        }
        convs.push(ConvWeights { shape: [oc1, k1, k1, ic1], w: w1, b: vec![-0.12; oc1] });

        // ---- conv2: 32 × 3×3×16: spatial max-ish aggregation ----
        let (oc2, k2, ic2) = (32usize, 3usize, 16usize);
        let mut w2 = vec![0f32; oc2 * k2 * k2 * ic2];
        for o in 0..oc2 {
            let src = o % ic2;
            for y in 0..k2 {
                for x in 0..k2 {
                    let centre = if y == 1 && x == 1 { 0.5 } else { 0.1 };
                    w2[((o * k2 + y) * k2 + x) * ic2 + src] = centre;
                }
            }
        }
        convs.push(ConvWeights { shape: [oc2, k2, k2, ic2], w: w2, b: vec![0.0; oc2] });

        // ---- conv3: 32 × 3×3×32: pass-through aggregation ----
        let (oc3, k3, ic3) = (32usize, 3usize, 32usize);
        let mut w3 = vec![0f32; oc3 * k3 * k3 * ic3];
        for o in 0..oc3 {
            for y in 0..k3 {
                for x in 0..k3 {
                    let v = if y == 1 && x == 1 { 0.6 } else { 0.05 };
                    w3[((o * k3 + y) * k3 + x) * ic3 + o] = v;
                }
            }
        }
        convs.push(ConvWeights { shape: [oc3, k3, k3, ic3], w: w3, b: vec![0.0; oc3] });

        // ---- head: A*(5+C) × 1×1×32 ----
        let hc = head_channels();
        let mut wh = vec![0f32; hc * oc3];
        let mut bh = vec![0f32; hc];
        let per = 5 + NUM_CLASSES;
        for a in 0..NUM_ANCHORS {
            let base = a * per;
            // tx, ty biases 0 (center of cell); tw/th 0 (anchor default).
            // objectness: blob channels (0,1 mod 8) positive, brightness
            // assists; strong negative bias so empty cells stay silent.
            for src in 0..oc3 {
                let f = src % 8;
                let v = match f {
                    0 | 1 => 2.2,
                    7 => 0.6,
                    _ => 0.0,
                };
                wh[(base + 4) * oc3 + src] = v;
            }
            bh[base + 4] = -3.0;
            // classes: disc ← blob & !edge; square ← H/V edges; diamond ←
            // diagonal edges; ring ← ring filter.
            let class_w: [(usize, &[(usize, f32)]); 4] = [
                (0, &[(0, 2.0), (1, 1.2), (2, -1.0), (3, -1.0), (6, -1.5)]),
                (1, &[(2, 1.8), (3, 1.8), (4, -1.2), (5, -1.2)]),
                (2, &[(4, 1.8), (5, 1.8), (2, -1.2), (3, -1.2)]),
                (3, &[(6, 2.5), (0, -1.5)]),
            ];
            for (cls, taps) in class_w {
                for &(f, v) in taps {
                    // taps apply to every source channel with that filter id
                    for src in 0..oc3 {
                        if src % 8 == f {
                            wh[(base + 5 + cls) * oc3 + src] += v / (oc3 / 8) as f32;
                        }
                    }
                }
                bh[base + 5 + cls] = -0.5;
            }
        }
        convs.push(ConvWeights { shape: [hc, 1, 1, oc3], w: wh, b: bh });
        Self { convs }
    }
}

/// Build the detector graph at a given input size (must be ÷8).
pub fn build_detector(input_size: usize, weights: &DetectorWeights) -> Graph {
    assert_eq!(input_size % 8, 0, "input size must be divisible by 8");
    assert_eq!(weights.convs.len(), LAYERS.len() + 1);
    let mut b = GraphBuilder::new(format!("tinyblobnet@{input_size}"));
    let mut x = b.input("image", vec![1, input_size, input_size, 3]);
    for (i, &(oc, k, s)) in LAYERS.iter().enumerate() {
        let cw = &weights.convs[i];
        assert_eq!(cw.shape[0], oc, "layer {i} channel mismatch");
        x = b.conv2d(
            x,
            oc,
            k,
            s,
            PaddingMode::Same,
            ActivationKind::Relu6,
            Some(cw.w.clone()),
            Some(cw.b.clone()),
        );
    }
    let hw = &weights.convs[LAYERS.len()];
    let head = b.conv2d(
        x,
        head_channels(),
        1,
        1,
        PaddingMode::Valid,
        ActivationKind::None,
        Some(hw.w.clone()),
        Some(hw.b.clone()),
    );
    let d = b.box_decode(head, NUM_ANCHORS, NUM_CLASSES);
    b.finish(&[d])
}

/// Run a detector graph over scenes and compute mAP@0.5.
/// Scenes are rescaled to the graph's input size if needed.
pub fn evaluate_detector(g: &Graph, scenes: &[Scene], nms_cfg: &NmsConfig) -> f64 {
    evaluate_detector_opts(g, scenes, nms_cfg, false)
}

/// As [`evaluate_detector`], optionally class-agnostic (localization-only
/// mAP — used with the analytic template weights, which localize well but
/// classify crudely; the trained weights use the full metric).
pub fn evaluate_detector_opts(
    g: &Graph,
    scenes: &[Scene],
    nms_cfg: &NmsConfig,
    class_agnostic: bool,
) -> f64 {
    evaluate_detector_iou(g, scenes, nms_cfg, class_agnostic, 0.5)
}

/// As [`evaluate_detector_opts`] with an explicit matching-IoU threshold.
pub fn evaluate_detector_iou(
    g: &Graph,
    scenes: &[Scene],
    nms_cfg: &NmsConfig,
    class_agnostic: bool,
    iou_thr: f32,
) -> f64 {
    let size = g.node(g.inputs[0]).output.shape[1];
    let interp = Interpreter::new(g);
    let mut dets = Vec::with_capacity(scenes.len());
    let mut gts = Vec::with_capacity(scenes.len());
    for sc in scenes {
        let input: Value = if sc.image.shape[1] == size {
            sc.image.clone()
        } else {
            super::scenes::rescale_scene(sc, sc.image.shape[1], size).image
        };
        let outs = interp.run(&[input]);
        let mut cands = Vec::new();
        for o in &outs {
            cands.extend(decode_and_nms(&o.f, NUM_CLASSES, nms_cfg));
        }
        let mut truths = sc.truths.clone();
        if class_agnostic {
            for c in cands.iter_mut() {
                c.class = 0;
            }
            for t in truths.iter_mut() {
                t.class = 0;
            }
            // Re-run class-aware NMS collapsed to one class.
            cands = crate::postproc::nms::nms(cands, nms_cfg);
        }
        dets.push(cands);
        gts.push(truths);
    }
    let classes = if class_agnostic { 1 } else { NUM_CLASSES };
    mean_average_precision(&dets, &gts, classes, iou_thr)
}

/// Calibration inputs for quantization, drawn from scenes.
pub fn calibration_batches(scenes: &[Scene], size: usize, n: usize) -> Vec<Vec<Value>> {
    scenes
        .iter()
        .take(n)
        .map(|sc| {
            let v = if sc.image.shape[1] == size {
                sc.image.clone()
            } else {
                super::scenes::rescale_scene(sc, sc.image.shape[1], size).image
            };
            vec![v]
        })
        .collect()
}

/// Convenience: weights from artifacts when trained, else analytic.
pub fn default_weights() -> DetectorWeights {
    DetectorWeights::load("artifacts/detector_weights.json")
        .unwrap_or_else(DetectorWeights::analytic)
}

/// Measurement model of the synthetic detector: miss/jitter/false-positive
/// rates applied to exact ground truth. The scenario subsystem uses this
/// in place of the (slow, interpreter-bound) CNN when sweeping thousands
/// of frames; `examples/traffic_scenario.rs` demonstrates the real CNN on
/// a rendered frame.
#[derive(Debug, Clone)]
pub struct SyntheticDetectorConfig {
    /// Probability that a ground-truth object produces no detection.
    pub miss_rate: f64,
    /// Geometric false-positive rate: each frame draws FPs while a
    /// `chance(fp_rate)` coin keeps landing (expected fp_rate/(1-fp_rate)).
    pub fp_rate: f64,
    /// σ of the Gaussian centre jitter (fraction-of-canvas units).
    pub center_jitter: f64,
    /// σ of the multiplicative box-size jitter.
    pub size_jitter: f64,
    /// σ of the Gaussian objectness-score noise around 0.85.
    pub score_sigma: f64,
    /// Probability a detection reports a wrong class.
    pub confusion: f64,
    pub nms: NmsConfig,
}

impl Default for SyntheticDetectorConfig {
    fn default() -> Self {
        Self {
            miss_rate: 0.08,
            fp_rate: 0.30,
            center_jitter: 0.010,
            size_jitter: 0.08,
            score_sigma: 0.08,
            confusion: 0.05,
            nms: NmsConfig::default(),
        }
    }
}

/// A synthetic detector whose noise is seeded through [`util::Rng`]
/// (`crate::util::Rng`) per `(seed, camera, frame)`, so every frame's
/// detections are a pure function of those three values — byte-identical
/// across reruns, replay order, and thread counts. Raw outputs are emitted
/// in the CNN head's row format (`[cx, cy, w, h, obj, c0..]`) and pass
/// through the same [`decode_and_nms`] path as real inference.
#[derive(Debug, Clone)]
pub struct SyntheticDetector {
    pub seed: u64,
    pub cfg: SyntheticDetectorConfig,
}

impl SyntheticDetector {
    pub fn new(seed: u64) -> Self {
        Self { seed, cfg: SyntheticDetectorConfig::default() }
    }

    /// The per-frame RNG stream id. Distinct multipliers keep camera and
    /// frame contributions from aliasing for small indices.
    fn frame_seed(&self, camera: usize, frame: usize) -> u64 {
        self.seed
            ^ (camera as u64).wrapping_mul(0x9E37_79B9_7F4A_7C15)
            ^ (frame as u64).wrapping_mul(0xBF58_476D_1CE4_E5B9)
    }

    /// Detect on a frame given its exact ground truth.
    pub fn detect(&self, camera: usize, frame: usize, truths: &[GroundTruth]) -> Vec<Detection> {
        let c = &self.cfg;
        let mut rng = Rng::new(self.frame_seed(camera, frame));
        let mut rows: Vec<f32> = Vec::new();
        for t in truths {
            if rng.chance(c.miss_rate) {
                continue; // missed detections draw nothing further
            }
            let cx = t.bbox.cx as f64 + rng.normal() * c.center_jitter;
            let cy = t.bbox.cy as f64 + rng.normal() * c.center_jitter;
            let w = (t.bbox.w as f64 * (1.0 + rng.normal() * c.size_jitter)).max(0.01);
            let h = (t.bbox.h as f64 * (1.0 + rng.normal() * c.size_jitter)).max(0.01);
            let obj = (0.85 + rng.normal() * c.score_sigma).clamp(0.30, 0.999);
            let class = if rng.chance(c.confusion) {
                (t.class + 1 + rng.below(NUM_CLASSES - 1)) % NUM_CLASSES
            } else {
                t.class
            };
            push_row(&mut rows, cx, cy, w, h, obj, class);
        }
        while rng.chance(c.fp_rate) {
            let cx = rng.range_f64(0.05, 0.95);
            let cy = rng.range_f64(0.05, 0.95);
            let w = rng.range_f64(0.03, 0.15);
            let h = rng.range_f64(0.03, 0.15);
            let obj = rng.range_f64(0.30, 0.60);
            let class = rng.below(NUM_CLASSES);
            push_row(&mut rows, cx, cy, w, h, obj, class);
        }
        decode_and_nms(&rows, NUM_CLASSES, &c.nms)
    }
}

/// Append one head-format row: box, objectness, one-hot-ish class scores.
fn push_row(rows: &mut Vec<f32>, cx: f64, cy: f64, w: f64, h: f64, obj: f64, class: usize) {
    rows.extend_from_slice(&[cx as f32, cy as f32, w as f32, h as f32, obj as f32]);
    for c in 0..NUM_CLASSES {
        rows.push(if c == class { 0.95 } else { 0.02 });
    }
}

#[allow(dead_code)]
fn _unused(_: &HashMap<(), ()>) {}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::dataset::scenes::{validation_set, SceneConfig};

    #[test]
    fn detector_builds_and_runs() {
        let w = DetectorWeights::analytic();
        let g = build_detector(96, &w);
        assert!(g.validate().is_ok());
        let scenes = validation_set(&SceneConfig { size: 96, ..Default::default() }, 2, 1);
        let out = Interpreter::new(&g).run(&[scenes[0].image.clone()]);
        let cells = (96 / 8) * (96 / 8);
        assert_eq!(out[0].shape, vec![1, cells * NUM_ANCHORS, 5 + NUM_CLASSES]);
    }

    #[test]
    fn analytic_detector_beats_chance() {
        let w = DetectorWeights::analytic();
        let g = build_detector(96, &w);
        let scenes = validation_set(
            &SceneConfig { size: 96, noise: 0.02, min_objects: 1, max_objects: 2, ..Default::default() },
            12,
            42,
        );
        let map = evaluate_detector_iou(
            &g,
            &scenes,
            &NmsConfig { score_threshold: 0.3, iou_threshold: 0.2, ..Default::default() },
            true,
            0.3,
        );
        // Template weights are no trained YOLO (the build-time JAX run
        // provides those); they must localize far better than random.
        assert!(map > 0.1, "analytic localization mAP@0.3 {map}");
    }

    #[test]
    fn weights_json_roundtrip() {
        let w = DetectorWeights::analytic();
        // serialize by hand
        let layers: Vec<Json> = w
            .convs
            .iter()
            .map(|c| {
                Json::obj(vec![
                    ("shape", Json::Arr(c.shape.iter().map(|&s| Json::Num(s as f64)).collect())),
                    ("w", Json::Arr(c.w.iter().map(|&v| Json::Num(v as f64)).collect())),
                    ("b", Json::Arr(c.b.iter().map(|&v| Json::Num(v as f64)).collect())),
                ])
            })
            .collect();
        let text = Json::obj(vec![("layers", Json::Arr(layers))]).dump();
        let back = DetectorWeights::from_json(&text).unwrap();
        assert_eq!(back.convs.len(), w.convs.len());
        assert_eq!(back.convs[0].w.len(), w.convs[0].w.len());
        assert!((back.convs[0].w[0] - w.convs[0].w[0]).abs() < 1e-5);
    }

    #[test]
    fn synthetic_detector_is_a_pure_function_of_seed_camera_frame() {
        use crate::postproc::bbox::BBox;
        let gts = vec![
            GroundTruth { bbox: BBox::new(0.3, 0.3, 0.12, 0.12), class: 0 },
            GroundTruth { bbox: BBox::new(0.7, 0.6, 0.10, 0.10), class: 2 },
        ];
        let det = SyntheticDetector::new(99);
        let a = det.detect(1, 7, &gts);
        let b = det.detect(1, 7, &gts);
        assert_eq!(format!("{a:?}"), format!("{b:?}"), "same (seed,cam,frame) must be byte-equal");
        let c = det.detect(2, 7, &gts);
        let d = det.detect(1, 8, &gts);
        assert!(
            format!("{a:?}") != format!("{c:?}") || format!("{a:?}") != format!("{d:?}"),
            "different streams should differ"
        );
    }

    #[test]
    fn synthetic_detector_recovers_truth_boxes() {
        use crate::postproc::bbox::BBox;
        // With noise disabled the detector returns the ground truth exactly.
        let gts = vec![GroundTruth { bbox: BBox::new(0.4, 0.5, 0.2, 0.2), class: 3 }];
        let det = SyntheticDetector {
            seed: 1,
            cfg: SyntheticDetectorConfig {
                miss_rate: 0.0,
                fp_rate: 0.0,
                center_jitter: 0.0,
                size_jitter: 0.0,
                score_sigma: 0.0,
                confusion: 0.0,
                ..Default::default()
            },
        };
        let dets = det.detect(0, 0, &gts);
        assert_eq!(dets.len(), 1);
        assert_eq!(dets[0].class, 3);
        assert!(dets[0].bbox.iou(&gts[0].bbox) > 0.99);
    }

    #[test]
    fn rejects_malformed_json() {
        assert!(DetectorWeights::from_json("{}").is_err());
        assert!(DetectorWeights::from_json(r#"{"layers":[{"shape":[1,1,1],"w":[],"b":[]}]}"#).is_err());
    }
}
