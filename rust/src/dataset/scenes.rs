//! Procedural scene generator with exact ground truth.
//!
//! Scenes are grayscale-ish (3 replicated channels) images of bright
//! geometric objects — disc, square, diamond, ring — over a noisy,
//! vignetted background. Object size, intensity, position and count are
//! randomized per scene; ground-truth boxes are exact by construction.

use crate::ir::interp::Value;
use crate::postproc::bbox::BBox;
use crate::postproc::map::GroundTruth;
use crate::util::Rng;

/// Object classes (indices are the detector's class ids).
pub const CLASS_NAMES: [&str; 4] = ["disc", "square", "diamond", "ring"];

/// Scene generation parameters.
#[derive(Debug, Clone)]
pub struct SceneConfig {
    /// Rendered canvas resolution (the "sensor"); experiments then feed
    /// the detector at various input sizes by re-rendering.
    pub size: usize,
    pub min_objects: usize,
    pub max_objects: usize,
    /// Object radius range in *fraction of canvas* (so ground truth is
    /// resolution-independent).
    pub min_r: f64,
    pub max_r: f64,
    /// Background noise σ.
    pub noise: f64,
}

impl Default for SceneConfig {
    fn default() -> Self {
        Self { size: 160, min_objects: 1, max_objects: 4, min_r: 0.04, max_r: 0.14, noise: 0.04 }
    }
}

/// A generated scene: image tensor + ground truth.
#[derive(Debug, Clone)]
pub struct Scene {
    pub image: Value,
    pub truths: Vec<GroundTruth>,
}

/// One explicitly-placed object (the scenario subsystem's world model
/// renders frames from these; [`render_scene`] draws its own at random).
/// Coordinates and radius are in fraction-of-canvas units, like the
/// ground truth.
#[derive(Debug, Clone, Copy)]
pub struct SceneObject {
    /// Class id (index into [`CLASS_NAMES`]).
    pub class: usize,
    pub cx: f64,
    pub cy: f64,
    /// Radius as a fraction of the canvas.
    pub r: f64,
    pub intensity: f64,
}

/// The shared background pass: soft gradient + per-pixel noise. The RNG
/// draw order (gx, gy, base, then one `normal` per pixel) is part of the
/// dataset's determinism contract — [`render_scene`] golden values
/// depend on it.
fn background(cfg: &SceneConfig, rng: &mut Rng) -> Vec<f32> {
    let s = cfg.size;
    let mut lum = vec![0f32; s * s];
    let gx = rng.range_f64(-0.1, 0.1) as f32;
    let gy = rng.range_f64(-0.1, 0.1) as f32;
    let base = rng.range_f64(0.08, 0.18) as f32;
    for y in 0..s {
        for x in 0..s {
            let n = (rng.normal() as f32) * cfg.noise as f32;
            lum[y * s + x] =
                (base + gx * x as f32 / s as f32 + gy * y as f32 / s as f32 + n).clamp(0.0, 1.0);
        }
    }
    lum
}

/// Replicate a luminance plane over 3 channels (detector input is
/// NHWC ×3).
fn to_image(lum: &[f32], s: usize) -> Value {
    let mut img = vec![0f32; s * s * 3];
    for (i, &v) in lum.iter().enumerate() {
        img[i * 3] = v;
        img[i * 3 + 1] = v;
        img[i * 3 + 2] = v;
    }
    Value::new(vec![1, s, s, 3], img)
}

/// Render a frame of *given* objects over a fresh random background —
/// the camera model of `scenario::` workloads, where object positions
/// come from a deterministic world simulation rather than the scene
/// RNG. Ground truth is exact by construction, as in [`render_scene`].
pub fn render_objects(cfg: &SceneConfig, objects: &[SceneObject], rng: &mut Rng) -> Scene {
    let s = cfg.size;
    let mut lum = background(cfg, rng);
    let mut truths = Vec::new();
    for o in objects {
        let r = (o.r * s as f64) as f32;
        let cx = o.cx as f32 * s as f32;
        let cy = o.cy as f32 * s as f32;
        draw(&mut lum, s, o.class, cx, cy, r, o.intensity as f32);
        truths.push(GroundTruth {
            bbox: BBox::new(cx / s as f32, cy / s as f32, 2.0 * r / s as f32, 2.0 * r / s as f32),
            class: o.class,
        });
    }
    Scene { image: to_image(&lum, s), truths }
}

/// Render one scene at the configured resolution.
pub fn render_scene(cfg: &SceneConfig, rng: &mut Rng) -> Scene {
    let s = cfg.size;
    let mut lum = background(cfg, rng);

    let count = rng.range(cfg.min_objects, cfg.max_objects + 1);
    let mut truths = Vec::new();
    for _ in 0..count {
        let class = rng.below(CLASS_NAMES.len());
        let r_frac = rng.range_f64(cfg.min_r, cfg.max_r);
        let r = (r_frac * s as f64) as f32;
        let cx = rng.range_f64(r_frac + 0.02, 1.0 - r_frac - 0.02) as f32 * s as f32;
        let cy = rng.range_f64(r_frac + 0.02, 1.0 - r_frac - 0.02) as f32 * s as f32;
        let intensity = rng.range_f64(0.55, 0.95) as f32;
        draw(&mut lum, s, class, cx, cy, r, intensity);
        truths.push(GroundTruth {
            bbox: BBox::new(cx / s as f32, cy / s as f32, 2.0 * r / s as f32, 2.0 * r / s as f32),
            class,
        });
    }

    Scene { image: to_image(&lum, s), truths }
}

fn draw(lum: &mut [f32], s: usize, class: usize, cx: f32, cy: f32, r: f32, v: f32) {
    let x0 = ((cx - r).floor().max(0.0)) as usize;
    let x1 = ((cx + r).ceil().min(s as f32 - 1.0)) as usize;
    let y0 = ((cy - r).floor().max(0.0)) as usize;
    let y1 = ((cy + r).ceil().min(s as f32 - 1.0)) as usize;
    for y in y0..=y1 {
        for x in x0..=x1 {
            let dx = x as f32 - cx;
            let dy = y as f32 - cy;
            let inside = match class {
                0 => dx * dx + dy * dy <= r * r,                      // disc
                1 => dx.abs() <= r * 0.9 && dy.abs() <= r * 0.9,      // square
                2 => dx.abs() + dy.abs() <= r * 1.1,                  // diamond
                _ => {
                    let d2 = dx * dx + dy * dy;
                    d2 <= r * r && d2 >= (r * 0.55) * (r * 0.55)      // ring
                }
            };
            if inside {
                lum[y * s + x] = v;
            }
        }
    }
}

/// Generate a deterministic validation set.
pub fn validation_set(cfg: &SceneConfig, n: usize, seed: u64) -> Vec<Scene> {
    let mut rng = Rng::new(seed);
    (0..n).map(|_| render_scene(cfg, &mut rng)).collect()
}

/// Re-render a scene's objects at a different input size (the Figure 3
/// input-size sweep: same world, fewer pixels).
pub fn rescale_scene(scene: &Scene, from: usize, to: usize) -> Scene {
    let src = &scene.image.f;
    let mut img = vec![0f32; to * to * 3];
    for y in 0..to {
        for x in 0..to {
            // Bilinear sample of the luminance (channel 0).
            let fy = (y as f32 + 0.5) * from as f32 / to as f32 - 0.5;
            let fx = (x as f32 + 0.5) * from as f32 / to as f32 - 0.5;
            let y0 = fy.floor().max(0.0) as usize;
            let x0 = fx.floor().max(0.0) as usize;
            let y1 = (y0 + 1).min(from - 1);
            let x1 = (x0 + 1).min(from - 1);
            let wy = (fy - y0 as f32).clamp(0.0, 1.0);
            let wx = (fx - x0 as f32).clamp(0.0, 1.0);
            let at = |yy: usize, xx: usize| src[(yy * from + xx) * 3];
            let v = at(y0, x0) * (1.0 - wy) * (1.0 - wx)
                + at(y0, x1) * (1.0 - wy) * wx
                + at(y1, x0) * wy * (1.0 - wx)
                + at(y1, x1) * wy * wx;
            for c in 0..3 {
                img[(y * to + x) * 3 + c] = v;
            }
        }
    }
    Scene {
        image: Value::new(vec![1, to, to, 3], img),
        truths: scene.truths.clone(), // normalized coords are size-free
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn scene_dimensions_and_range() {
        let mut rng = Rng::new(1);
        let s = render_scene(&SceneConfig::default(), &mut rng);
        assert_eq!(s.image.shape, vec![1, 160, 160, 3]);
        assert!(s.image.f.iter().all(|&v| (0.0..=1.0).contains(&v)));
        assert!(!s.truths.is_empty());
    }

    #[test]
    fn ground_truth_boxes_contain_bright_pixels() {
        let mut rng = Rng::new(2);
        let cfg = SceneConfig { noise: 0.0, ..Default::default() };
        let s = render_scene(&cfg, &mut rng);
        for t in &s.truths {
            let size = 160.0f32;
            let cx = (t.bbox.cx * size) as usize;
            let cy = (t.bbox.cy * size) as usize;
            // Center pixel of a disc/square/diamond is bright; a ring's
            // center is dark but its edge is bright.
            let probe = if t.class == 3 {
                let r = t.bbox.w / 2.0 * size;
                ((cy as f32 - r * 0.8) as usize * 160 + cx) * 3
            } else {
                (cy * 160 + cx) * 3
            };
            assert!(s.image.f[probe] > 0.4, "class {} at ({cx},{cy})", t.class);
        }
    }

    #[test]
    fn validation_set_deterministic() {
        let cfg = SceneConfig::default();
        let a = validation_set(&cfg, 3, 7);
        let b = validation_set(&cfg, 3, 7);
        assert_eq!(a[2].image.f, b[2].image.f);
        let c = validation_set(&cfg, 3, 8);
        assert_ne!(a[0].image.f, c[0].image.f);
    }

    #[test]
    fn render_objects_places_exact_truths() {
        let cfg = SceneConfig { noise: 0.0, ..Default::default() };
        let objs = [
            SceneObject { class: 0, cx: 0.25, cy: 0.25, r: 0.08, intensity: 0.9 },
            SceneObject { class: 1, cx: 0.7, cy: 0.6, r: 0.06, intensity: 0.8 },
        ];
        let mut rng = Rng::new(5);
        let sc = render_objects(&cfg, &objs, &mut rng);
        assert_eq!(sc.truths.len(), 2);
        assert_eq!(sc.truths[0].class, 0);
        assert!((sc.truths[1].bbox.cx - 0.7).abs() < 0.01);
        // The disc's center pixel is bright.
        let probe = ((0.25 * 160.0) as usize * 160 + (0.25 * 160.0) as usize) * 3;
        assert!(sc.image.f[probe] > 0.4);
        // Same objects, same seed: byte-identical frame.
        let sc2 = render_objects(&cfg, &objs, &mut Rng::new(5));
        assert_eq!(sc.image.f, sc2.image.f);
    }

    #[test]
    fn rescale_preserves_truths_and_shrinks_image() {
        let mut rng = Rng::new(3);
        let s = render_scene(&SceneConfig::default(), &mut rng);
        let small = rescale_scene(&s, 160, 96);
        assert_eq!(small.image.shape, vec![1, 96, 96, 3]);
        assert_eq!(small.truths.len(), s.truths.len());
        // Downscaled image keeps overall energy (roughly).
        let mean_a: f32 = s.image.f.iter().sum::<f32>() / s.image.f.len() as f32;
        let mean_b: f32 = small.image.f.iter().sum::<f32>() / small.image.f.len() as f32;
        assert!((mean_a - mean_b).abs() < 0.05);
    }
}
