//! Synthetic detection benchmark (the COCO stand-in — DESIGN.md §2).
//!
//! The paper's accuracy experiments (Table I, Figures 3/4) measure mAP of
//! YOLOv7-tiny on COCO; we have neither the trained weights nor the
//! dataset, so we build the closest controllable equivalent: procedurally
//! generated scenes of geometric objects with exact ground truth
//! ([`scenes`]), detected by a small YOLO-style CNN ([`detector`]) whose
//! weights come from the build-time JAX training run (`make artifacts`)
//! or an analytic template fallback. Quantization, pruning and input-size
//! reduction act on this detector through the same information-loss
//! mechanisms that degrade YOLOv7 — which is what the experiments measure.

pub mod detector;
pub mod scenes;

pub use detector::{build_detector, DetectorWeights, NUM_CLASSES};
pub use scenes::{render_scene, Scene, SceneConfig};
