//! Deterministic discrete-event fleet simulator + arrival traces.
//!
//! Open-loop: requests arrive on a pre-generated trace regardless of the
//! fleet's state (cameras don't wait), which is what exposes tail
//! latency and shedding. The driver advances time event-to-event —
//! arrivals, batch completions, batch-wait deadlines — so results are
//! exact for the service model and bit-reproducible for a seed
//! ([`crate::util::rng::Rng`] everywhere, no wall clock).

use crate::dataset::scenes::SceneConfig;
use crate::util::Rng;

use super::admission::{admit, Admission, ShedPolicy};
use super::batcher::{BatchPolicy, Decision};
use super::device::Backend;
use super::metrics::{FleetMetrics, FleetReport};
use super::shard::ShardPool;
use super::Request;

/// Fleet-wide serving configuration for one simulated run.
#[derive(Debug, Clone)]
pub struct SimConfig {
    pub batch: BatchPolicy,
    /// Per-device admission queue bound.
    pub queue_depth: usize,
    pub shed: ShedPolicy,
    /// Latency objective completed requests are judged against, s.
    pub slo_s: f64,
    /// Idle devices steal from backlogged siblings.
    pub work_stealing: bool,
}

impl Default for SimConfig {
    fn default() -> Self {
        Self {
            batch: BatchPolicy::default(),
            queue_depth: 64,
            shed: ShedPolicy::DropOldest,
            slo_s: 0.100,
            work_stealing: true,
        }
    }
}

/// Open-loop Poisson arrivals at `rate_hz` over `horizon_s`.
pub fn poisson_trace(rate_hz: f64, horizon_s: f64, seed: u64) -> Vec<Request> {
    assert!(rate_hz > 0.0 && horizon_s > 0.0);
    let mut rng = Rng::new(seed);
    let mut t = 0.0;
    let mut out = Vec::new();
    loop {
        // Exponential inter-arrival via inverse CDF.
        t += -(1.0 - rng.f64()).ln() / rate_hz;
        if t >= horizon_s {
            break;
        }
        out.push(Request { id: out.len() as u64, camera: 0, arrival_s: t, objects: 1 });
    }
    out
}

/// Bursty multi-camera arrivals: `cameras` streams at nominal `fps` with
/// per-camera phase offsets and frame jitter. Scene complexity is drawn
/// from `scene`'s object-count range ([`crate::dataset::scenes`]'s
/// distribution); busy frames (above the midpoint) trigger an immediate
/// follow-up frame — the event-driven re-capture that makes real camera
/// traffic bursty rather than Poisson.
pub fn multi_camera_trace(
    scene: &SceneConfig,
    cameras: usize,
    fps: f64,
    horizon_s: f64,
    seed: u64,
) -> Vec<Request> {
    assert!(cameras > 0 && fps > 0.0 && horizon_s > 0.0);
    let mut rng = Rng::new(seed);
    let period = 1.0 / fps;
    // Burst only on frames *strictly* above the midpoint, so a
    // degenerate range (min == max) never bursts instead of always.
    let midpoint = (scene.min_objects + scene.max_objects) as f64 / 2.0;
    let mut out = Vec::new();
    for cam in 0..cameras {
        let mut t = rng.f64() * period; // phase offset
        while t < horizon_s {
            let objects = rng.range(scene.min_objects, scene.max_objects + 1);
            out.push(Request { id: 0, camera: cam, arrival_s: t, objects });
            if objects as f64 > midpoint {
                let t2 = t + 0.1 * period;
                if t2 < horizon_s {
                    out.push(Request { id: 0, camera: cam, arrival_s: t2, objects });
                }
            }
            // ±10% frame jitter around the nominal period.
            t += period * rng.range_f64(0.9, 1.1);
        }
    }
    out.sort_by(|a, b| {
        a.arrival_s.partial_cmp(&b.arrival_s).unwrap().then(a.camera.cmp(&b.camera))
    });
    for (i, r) in out.iter_mut().enumerate() {
        r.id = i as u64;
    }
    out
}

/// Complete any batch finished by `now`, then let idle devices steal and
/// dispatch until nothing changes.
fn settle(pool: &mut ShardPool, now: f64, cfg: &SimConfig, metrics: &mut FleetMetrics) {
    loop {
        let mut progressed = false;
        for i in 0..pool.devices.len() {
            // 1. Completion.
            if pool.devices[i].busy && pool.devices[i].free_at <= now {
                let done_at = pool.devices[i].free_at;
                let batch = std::mem::take(&mut pool.devices[i].in_flight);
                for r in batch {
                    metrics.record_completion(i, done_at - r.arrival_s);
                }
                pool.devices[i].busy = false;
                progressed = true;
            }
            if pool.devices[i].busy {
                continue;
            }
            // 2. Work stealing into an idle, empty device.
            if cfg.work_stealing && pool.devices[i].queue.is_empty() {
                let n = pool.steal_into(i);
                if n > 0 {
                    metrics.record_steal(i, n);
                    progressed = true;
                }
            }
            // 3. Dynamic-batching dispatch.
            let d = &mut pool.devices[i];
            let cap = d.backend.max_batch();
            if let Decision::Dispatch(n) = cfg.batch.decide(&d.queue, now, cap) {
                let batch: Vec<Request> = d.queue.drain(..n).collect();
                let service = d.backend.batch_latency_s(batch.len());
                d.busy = true;
                d.free_at = now + service;
                d.in_flight = batch;
                metrics.record_batch(i, service);
                progressed = true;
            }
        }
        if !progressed {
            break;
        }
    }
}

/// The next event after `now`: the earliest of the next arrival, any
/// in-flight completion, or any idle device's batch-wait deadline.
fn next_event(pool: &ShardPool, next_arrival: Option<f64>, batch: &BatchPolicy, now: f64) -> f64 {
    let mut t = next_arrival.unwrap_or(f64::INFINITY);
    for d in &pool.devices {
        if d.busy {
            t = t.min(d.free_at);
        } else if let Decision::WaitUntil(w) = batch.decide(&d.queue, now, d.backend.max_batch()) {
            t = t.min(w);
        }
    }
    t
}

/// Run a trace through the pool. The pool's queues may be pre-loaded
/// (tests use this to create skew); devices are expected idle at start.
pub fn simulate(pool: &mut ShardPool, trace: &[Request], cfg: &SimConfig) -> FleetReport {
    assert!(!pool.is_empty(), "simulate needs at least one device");
    let mut metrics = FleetMetrics::new(pool.len(), cfg.slo_s);
    let mut next = 0usize; // next trace index
    let mut now = 0.0f64;
    let mut last_completion = 0.0f64;

    loop {
        // Admit every arrival due by `now`.
        while next < trace.len() && trace[next].arrival_s <= now {
            let idx = pool.route(now);
            let d = &mut pool.devices[idx];
            match admit(&mut d.queue, cfg.queue_depth, cfg.shed, trace[next].clone()) {
                Admission::Admitted => {}
                Admission::AdmittedEvicted(_) | Admission::Rejected => metrics.record_shed(),
            }
            next += 1;
        }

        settle(pool, now, cfg, &mut metrics);
        for d in &pool.devices {
            if d.busy {
                last_completion = last_completion.max(d.free_at);
            }
        }

        let arrivals_left = next < trace.len();
        let work_left = pool.devices.iter().any(|d| d.busy || !d.queue.is_empty());
        if !arrivals_left && !work_left {
            break;
        }

        let t = next_event(pool, trace.get(next).map(|r| r.arrival_s), &cfg.batch, now);
        if !t.is_finite() {
            // Only possible if every queue emptied and nothing is busy —
            // already handled above, but guard against a stall.
            break;
        }
        now = t.max(now);
    }

    let backends: Vec<&dyn Backend> = pool.devices.iter().map(|d| d.backend.as_ref()).collect();
    metrics.report(&backends, last_completion.max(now))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::baselines::Platform;
    use crate::serving::device::BaselineDevice;

    /// A deterministic synthetic device: 5 ms overhead + 5 ms/frame.
    fn test_device() -> BaselineDevice {
        let p = Platform { name: "test-dev", overhead_s: 5e-3, sustained_gops: 100.0, power_w: 10.0 };
        BaselineDevice::new(p, 0.5, 16)
    }

    fn one_device_pool() -> ShardPool {
        let mut pool = ShardPool::new();
        pool.register(Box::new(test_device()));
        pool
    }

    #[test]
    fn poisson_trace_hits_rate_and_is_deterministic() {
        let a = poisson_trace(200.0, 10.0, 7);
        let b = poisson_trace(200.0, 10.0, 7);
        assert_eq!(a.len(), b.len());
        assert!((a[5].arrival_s - b[5].arrival_s).abs() < 1e-15);
        // 2000 expected arrivals; 3σ ≈ 134.
        assert!((a.len() as f64 - 2000.0).abs() < 150.0, "{} arrivals", a.len());
        assert!(a.windows(2).all(|w| w[0].arrival_s <= w[1].arrival_s));
    }

    #[test]
    fn multi_camera_trace_is_sorted_bursty_and_seeded() {
        let scene = SceneConfig::default();
        let a = multi_camera_trace(&scene, 8, 30.0, 5.0, 11);
        let b = multi_camera_trace(&scene, 8, 30.0, 5.0, 11);
        assert_eq!(a.len(), b.len());
        assert!(a.windows(2).all(|w| w[0].arrival_s <= w[1].arrival_s));
        // Nominal 8×30×5 = 1200 frames, plus bursts.
        assert!(a.len() > 1200, "{} frames", a.len());
        assert!(a.iter().all(|r| r.arrival_s < 5.0));
        assert!(a.iter().any(|r| r.camera == 7));
        // Ids are the post-sort positions.
        assert!(a.iter().enumerate().all(|(i, r)| r.id == i as u64));
    }

    #[test]
    fn degenerate_object_range_never_bursts() {
        // min == max: every frame sits exactly on the midpoint, so no
        // frame is "busy" and the trace is the nominal rate, not 2×.
        let scene = SceneConfig { min_objects: 2, max_objects: 2, ..Default::default() };
        let a = multi_camera_trace(&scene, 4, 20.0, 5.0, 3);
        let nominal = 4.0 * 20.0 * 5.0;
        assert!(
            (a.len() as f64) <= nominal * 1.05,
            "{} frames for nominal {nominal}",
            a.len()
        );
    }

    /// The batcher's core trade-off, measured end to end: at saturating
    /// load, batching lifts throughput; at light load, waiting for a
    /// batch costs latency.
    #[test]
    fn batching_trades_latency_for_throughput() {
        // Saturating: 10 ms/request unbatched → capacity 100/s; offer 180/s.
        let trace = poisson_trace(180.0, 8.0, 42);
        let base = SimConfig {
            queue_depth: 16,
            shed: ShedPolicy::RejectNewest,
            work_stealing: false,
            slo_s: 0.25,
            ..Default::default()
        };
        let unbatched = SimConfig { batch: BatchPolicy::unbatched(), ..base.clone() };
        let batched =
            SimConfig { batch: BatchPolicy::new(8, 0.020), ..base.clone() };
        let r1 = simulate(&mut one_device_pool(), &trace, &unbatched);
        let r8 = simulate(&mut one_device_pool(), &trace, &batched);
        assert!(
            r8.throughput_fps() > 1.5 * r1.throughput_fps(),
            "batched {:.0} fps !> 1.5× unbatched {:.0} fps",
            r8.throughput_fps(),
            r1.throughput_fps()
        );
        assert!(r8.shed < r1.shed, "batching should shed less: {} vs {}", r8.shed, r1.shed);

        // Light load: 20/s on a 100/s device — batching only adds waiting.
        let light = poisson_trace(20.0, 8.0, 43);
        let r1l = simulate(&mut one_device_pool(), &light, &unbatched);
        let r8l = simulate(
            &mut one_device_pool(),
            &light,
            &SimConfig { batch: BatchPolicy::new(8, 0.050), ..base.clone() },
        );
        assert!(
            r8l.p50_s > r1l.p50_s,
            "waiting for batches must raise median latency: {} !> {}",
            r8l.p50_s,
            r1l.p50_s
        );
    }

    /// Work stealing rescues a skewed backlog: preload one device's
    /// queue, leave its sibling idle.
    #[test]
    fn work_stealing_balances_skewed_load() {
        let skewed_pool = || {
            let mut pool = ShardPool::new();
            pool.register(Box::new(test_device()));
            pool.register(Box::new(test_device()));
            for i in 0..40 {
                pool.devices[0]
                    .queue
                    .push_back(Request { id: i, camera: 0, arrival_s: 0.0, objects: 1 });
            }
            pool
        };
        let cfg = SimConfig {
            batch: BatchPolicy::new(4, 0.005),
            work_stealing: true,
            ..Default::default()
        };
        let no_steal = SimConfig { work_stealing: false, ..cfg.clone() };

        let mut p = skewed_pool();
        let stolen = simulate(&mut p, &[], &cfg);
        let mut p = skewed_pool();
        let idle = simulate(&mut p, &[], &no_steal);

        assert_eq!(stolen.completed, 40);
        assert_eq!(idle.completed, 40);
        let thief = &stolen.devices[1];
        assert!(thief.stolen > 0, "idle sibling must steal");
        assert!(thief.completed > 0, "and serve what it stole");
        assert!(
            stolen.makespan_s < 0.75 * idle.makespan_s,
            "stealing must cut the drain time: {} !< 0.75×{}",
            stolen.makespan_s,
            idle.makespan_s
        );
        assert!(stolen.max_s < idle.max_s, "tail latency improves too");
    }

    #[test]
    fn overload_sheds_and_violates_slo() {
        // 5× overload on a shallow queue.
        let trace = poisson_trace(500.0, 4.0, 9);
        let cfg = SimConfig {
            batch: BatchPolicy::unbatched(),
            queue_depth: 4,
            shed: ShedPolicy::DropOldest,
            slo_s: 0.015,
            work_stealing: false,
        };
        let r = simulate(&mut one_device_pool(), &trace, &cfg);
        assert!(r.shed > 0, "overload must shed");
        assert!(r.completed > 0);
        assert!(r.slo_violations > 0);
        assert!(r.slo_attainment() < 1.0);
        // Bounded queue + drop-oldest keeps the served tail bounded:
        // worst case ≈ (queue_depth+1) × service time, far below open-loop.
        assert!(r.max_s < 0.2, "drop-oldest must bound latency, got {}", r.max_s);
    }

    #[test]
    fn simulation_is_deterministic() {
        let scene = SceneConfig::default();
        let mk = || {
            let mut pool = ShardPool::new();
            pool.register(Box::new(test_device()));
            pool.register(Box::new(test_device()));
            pool
        };
        let trace = multi_camera_trace(&scene, 6, 25.0, 4.0, 5);
        let cfg = SimConfig::default();
        let a = simulate(&mut mk(), &trace, &cfg);
        let b = simulate(&mut mk(), &trace, &cfg);
        assert_eq!(a.completed, b.completed);
        assert_eq!(a.shed, b.shed);
        assert!((a.p99_s - b.p99_s).abs() < 1e-15);
        assert!((a.makespan_s - b.makespan_s).abs() < 1e-15);
    }

    #[test]
    fn all_requests_accounted_for() {
        let trace = poisson_trace(150.0, 3.0, 21);
        let cfg = SimConfig { queue_depth: 8, ..Default::default() };
        let r = simulate(&mut one_device_pool(), &trace, &cfg);
        assert_eq!(r.completed + r.shed, trace.len() as u64);
        let per_dev: u64 = r.devices.iter().map(|d| d.completed).sum();
        assert_eq!(per_dev, r.completed);
    }
}
