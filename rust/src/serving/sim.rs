//! Deterministic discrete-event fleet simulator + arrival models.
//!
//! Two client models feed the same driver:
//!
//! - **Open-loop** traces ([`poisson_trace`], [`multi_camera_trace`]):
//!   requests arrive on a pre-generated schedule regardless of fleet
//!   state (cameras don't wait), which is what exposes tail latency and
//!   shedding.
//! - **Closed-loop** clients ([`ClosedLoopConfig`]): each camera holds at
//!   most K frames in flight and emits its next frame a think-time after
//!   a completion hands the window token back — the arrival rate adapts
//!   to fleet capacity, which is what exposes end-to-end goodput.
//!
//! The driver advances time event-to-event — arrivals, batch
//! completions, batch-wait deadlines, provisioning warm-ups, autoscaler
//! epochs — so results are exact for the service model and
//! bit-reproducible for a seed ([`crate::util::rng::Rng`] everywhere, no
//! wall clock). With an [`Autoscaler`] attached ([`simulate_autoscaled`]),
//! the pool grows and shrinks between epochs through the device
//! [`Lifecycle`](super::shard::Lifecycle).

use std::collections::HashSet;

use crate::dataset::scenes::SceneConfig;
use crate::util::Rng;

use super::admission::{admit, Admission, AdmissionPolicy, ShedPolicy};
use super::faults::FaultPlan;
use super::autoscale::{
    Autoscaler, DrainOrder, EpochObservation, ScaleAction, ScaleEventKind, ScalingEvent,
};
use super::batcher::{BatchPolicy, Decision};
use super::device::{Backend, DeviceCatalog};
use super::metrics::{EnergyLedger, EpochStats, FleetMetrics, FleetReport};
use super::shard::{Lifecycle, ShardPool};
use super::{Request, RequestOutcome, SloClass};

/// Fleet-wide serving configuration for one simulated run.
#[derive(Debug, Clone)]
pub struct SimConfig {
    pub batch: BatchPolicy,
    /// Per-device admission queue bound.
    pub queue_depth: usize,
    pub shed: ShedPolicy,
    /// Front-door policy ahead of the queues (per-class token buckets
    /// or open). Shared verbatim by the DES and the live threaded
    /// runtime.
    pub admission: AdmissionPolicy,
    /// Latency objective completed requests are judged against, s
    /// (scaled per class by [`SloClass::slo_factor`]).
    pub slo_s: f64,
    /// Idle devices steal from backlogged siblings.
    pub work_stealing: bool,
    /// Bin width of the fleet [`EnergyLedger`], virtual s (at least
    /// [`EnergyLedger::MIN_EPOCH_S`] — bins are dense over the run).
    pub energy_epoch_s: f64,
    /// Seeded fault schedule + recovery machinery ([`super::faults`]).
    /// `None` (the default) leaves every fault branch inert — runs are
    /// bit-identical to the pre-fault driver.
    pub faults: Option<FaultPlan>,
}

impl Default for SimConfig {
    fn default() -> Self {
        Self {
            batch: BatchPolicy::default(),
            queue_depth: 64,
            shed: ShedPolicy::DropOldest,
            admission: AdmissionPolicy::Open,
            slo_s: 0.100,
            work_stealing: true,
            energy_epoch_s: 0.5,
            faults: None,
        }
    }
}

/// Closed-loop client model: `cameras` streams that each keep at most
/// `max_outstanding` frames in flight. While the window has room a camera
/// free-runs at its frame period (±10% jitter); at the limit it stalls
/// until a completion (or shed) returns the token, then waits `think_s`
/// (±10%) before the next frame. New frames stop at `horizon_s`; the
/// simulation then drains.
#[derive(Debug, Clone)]
pub struct ClosedLoopConfig {
    pub cameras: usize,
    /// The per-camera window K (≥ 1).
    pub max_outstanding: usize,
    /// Nominal inter-frame period while the window has room, s.
    pub period_s: f64,
    /// Pause between a completion and the next frame when the camera was
    /// stalled at the window limit, s.
    pub think_s: f64,
    /// Stop emitting new frames at this virtual time, s.
    pub horizon_s: f64,
    pub seed: u64,
    /// Stamp each camera's frames with [`SloClass::for_camera`] instead
    /// of [`SloClass::Standard`].
    pub classed: bool,
}

impl Default for ClosedLoopConfig {
    fn default() -> Self {
        Self {
            cameras: 8,
            max_outstanding: 2,
            period_s: 1.0 / 30.0,
            think_s: 0.005,
            horizon_s: 10.0,
            seed: 0,
            classed: false,
        }
    }
}

/// Open-loop Poisson arrivals at `rate_hz` over `horizon_s`.
pub fn poisson_trace(rate_hz: f64, horizon_s: f64, seed: u64) -> Vec<Request> {
    assert!(rate_hz > 0.0 && horizon_s > 0.0);
    let mut rng = Rng::new(seed);
    let mut t = 0.0;
    let mut out = Vec::new();
    loop {
        // Exponential inter-arrival via inverse CDF.
        t += -(1.0 - rng.f64()).ln() / rate_hz;
        if t >= horizon_s {
            break;
        }
        out.push(Request {
            id: out.len() as u64,
            camera: 0,
            arrival_s: t,
            objects: 1,
            class: SloClass::Standard,
            rung: 0,
            retries: 0,
        });
    }
    out
}

/// Bursty multi-camera arrivals: `cameras` streams at nominal `fps` with
/// per-camera phase offsets and frame jitter. Scene complexity is drawn
/// from `scene`'s object-count range ([`crate::dataset::scenes`]'s
/// distribution); busy frames (above the midpoint) trigger an immediate
/// follow-up frame — the event-driven re-capture that makes real camera
/// traffic bursty rather than Poisson.
pub fn multi_camera_trace(
    scene: &SceneConfig,
    cameras: usize,
    fps: f64,
    horizon_s: f64,
    seed: u64,
) -> Vec<Request> {
    assert!(cameras > 0 && fps > 0.0 && horizon_s > 0.0);
    let mut rng = Rng::new(seed);
    let period = 1.0 / fps;
    // Burst only on frames *strictly* above the midpoint, so a
    // degenerate range (min == max) never bursts instead of always.
    let midpoint = (scene.min_objects + scene.max_objects) as f64 / 2.0;
    let mut out = Vec::new();
    for cam in 0..cameras {
        let mut t = rng.f64() * period; // phase offset
        while t < horizon_s {
            let objects = rng.range(scene.min_objects, scene.max_objects + 1);
            out.push(Request {
                id: 0,
                camera: cam,
                arrival_s: t,
                objects,
                class: SloClass::Standard,
                rung: 0,
                retries: 0,
            });
            if objects as f64 > midpoint {
                let t2 = t + 0.1 * period;
                if t2 < horizon_s {
                    out.push(Request {
                        id: 0,
                        camera: cam,
                        arrival_s: t2,
                        objects,
                        class: SloClass::Standard,
                        rung: 0,
                        retries: 0,
                    });
                }
            }
            // ±10% frame jitter around the nominal period.
            t += period * rng.range_f64(0.9, 1.1);
        }
    }
    out.sort_by(|a, b| {
        a.arrival_s.partial_cmp(&b.arrival_s).unwrap().then(a.camera.cmp(&b.camera))
    });
    for (i, r) in out.iter_mut().enumerate() {
        r.id = i as u64;
    }
    out
}

/// One camera's closed-loop window state.
#[derive(Debug, Clone)]
struct CamState {
    outstanding: usize,
    /// Next emission time; `None` while stalled at the window limit or
    /// past the horizon.
    next_at: Option<f64>,
}

/// The driver's pluggable arrival source.
enum Arrivals<'a> {
    Open { trace: &'a [Request], next: usize },
    Closed { cl: ClosedLoopConfig, cams: Vec<CamState>, rng: Rng, next_id: u64 },
}

impl Arrivals<'_> {
    fn closed(cl: ClosedLoopConfig) -> Arrivals<'static> {
        assert!(cl.cameras > 0 && cl.max_outstanding > 0 && cl.period_s > 0.0);
        let mut rng = Rng::new(cl.seed);
        let cams = (0..cl.cameras)
            .map(|_| {
                // Phase offsets past a (very short) horizon emit nothing.
                let t0 = rng.f64() * cl.period_s;
                CamState { outstanding: 0, next_at: (t0 < cl.horizon_s).then_some(t0) }
            })
            .collect();
        Arrivals::Closed { cl, cams, rng, next_id: 0 }
    }

    /// Earliest pending emission time, if any.
    fn peek(&self) -> Option<f64> {
        match self {
            Arrivals::Open { trace, next } => trace.get(*next).map(|r| r.arrival_s),
            Arrivals::Closed { cams, .. } => cams
                .iter()
                .filter_map(|c| c.next_at)
                .min_by(|a, b| a.partial_cmp(b).unwrap()),
        }
    }

    /// The next request due at or before `now` (in emission order; closed
    /// loop breaks time ties to the lowest camera index).
    fn pop_due(&mut self, now: f64) -> Option<Request> {
        match self {
            Arrivals::Open { trace, next } => {
                if *next < trace.len() && trace[*next].arrival_s <= now {
                    let r = trace[*next];
                    *next += 1;
                    Some(r)
                } else {
                    None
                }
            }
            Arrivals::Closed { cl, cams, rng, next_id } => {
                let mut best: Option<(usize, f64)> = None;
                for (i, c) in cams.iter().enumerate() {
                    if let Some(t) = c.next_at {
                        let earlier = match best {
                            None => true,
                            Some((_, bt)) => t < bt,
                        };
                        if t <= now && earlier {
                            best = Some((i, t));
                        }
                    }
                }
                let (i, t) = best?;
                let cam = &mut cams[i];
                cam.outstanding += 1;
                cam.next_at = if cam.outstanding < cl.max_outstanding {
                    let tn = t + cl.period_s * rng.range_f64(0.9, 1.1);
                    if tn < cl.horizon_s {
                        Some(tn)
                    } else {
                        None
                    }
                } else {
                    None
                };
                let id = *next_id;
                *next_id += 1;
                let class =
                    if cl.classed { SloClass::for_camera(i) } else { SloClass::Standard };
                Some(Request { id, camera: i, arrival_s: t, objects: 1, class, rung: 0, retries: 0 })
            }
        }
    }

    /// A request left the system (completed or shed) at time `t`: return
    /// the window token to its closed-loop camera.
    fn on_done(&mut self, r: &Request, t: f64) {
        if let Arrivals::Closed { cl, cams, rng, .. } = self {
            let cam = &mut cams[r.camera];
            // Revive only cameras stalled *at the window limit* — a
            // camera whose next frame was dropped by the horizon stays
            // stopped (its window still had room, so a completion is not
            // what it was waiting for).
            let was_limited = cam.outstanding == cl.max_outstanding;
            cam.outstanding = cam.outstanding.saturating_sub(1);
            if was_limited && cam.next_at.is_none() && t < cl.horizon_s {
                // Floor the think time at 1 µs: a zero think-time would
                // let a shed frame re-arm its camera at the *same*
                // instant, and a full queue could then shed it again
                // without virtual time ever advancing (a DES livelock).
                let tn = t + cl.think_s.max(1e-6) * rng.range_f64(0.9, 1.1);
                if tn < cl.horizon_s {
                    cam.next_at = Some(tn);
                }
            }
        }
    }

    fn pending(&self) -> bool {
        match self {
            Arrivals::Open { trace, next } => *next < trace.len(),
            Arrivals::Closed { cams, .. } => cams.iter().any(|c| c.next_at.is_some()),
        }
    }
}

/// Which dispatch loop the DES driver runs. Both produce byte-identical
/// `FleetReport`s — `tests/fleet_scale.rs` pins that on every config
/// family — but they pay very different per-event costs.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum DriveMode {
    /// The flat hot path every `simulate*` entry point uses: memoized
    /// service estimates in routing, an O(1)-guarded steal scan, the
    /// batch-wait deadline inlined (no `Decision` round trip), recycled
    /// batch buffers, and batched metric recording.
    Optimized,
    /// The pre-optimization dispatch loop, frozen verbatim as the
    /// differential oracle for the scale-invariance suite (the
    /// `simulate*_reference` entry points).
    Reference,
}

/// Complete any batch finished by `now`, then let idle active devices
/// steal and serving devices dispatch until nothing changes. Requests
/// that completed are appended to `done` (with their completion time) so
/// closed-loop cameras get their window tokens back. `caps` is the
/// precomputed per-device effective batch cap (optimized mode only; the
/// reference mode re-derives it through the virtual call every decision,
/// as the pre-optimization loop did).
#[allow(clippy::too_many_arguments)]
fn settle(
    pool: &mut ShardPool,
    now: f64,
    cfg: &SimConfig,
    metrics: &mut FleetMetrics,
    done: &mut Vec<(Request, f64, bool)>,
    frt: &mut Option<FaultRt>,
    mode: DriveMode,
    caps: &[usize],
) {
    loop {
        let mut progressed = false;
        // Lazy per-pass steal guard: if no queue holds ≥ 2 requests when
        // first checked, no steal in this pass can move anything (queues
        // only shrink inside `settle`, and a steal needs a ≥ 2 victim to
        // create a new ≥ 2 queue) — so every skipped `steal_into` scan
        // would have returned 0. Turns the O(devices²) idle-fleet scan
        // into one O(devices) probe per pass.
        let mut steal_possible: Option<bool> = None;
        for i in 0..pool.devices.len() {
            // 1. Completion (any lifecycle: draining devices finish too).
            if pool.devices[i].busy && pool.devices[i].free_at <= now {
                let done_at = pool.devices[i].free_at;
                let mut batch = std::mem::take(&mut pool.devices[i].in_flight);
                for r in batch.drain(..) {
                    // Exactly-once: a completion whose id already
                    // resolved (its re-dispatched copy finished first)
                    // is suppressed — counted, never double-reported.
                    if let Some(f) = frt.as_mut() {
                        if !f.resolved.insert(r.id) {
                            metrics.faults.duplicates_suppressed += 1;
                            continue;
                        }
                    }
                    match mode {
                        DriveMode::Optimized => {
                            metrics.pend_completion(i, done_at - r.arrival_s, r.class, r.rung)
                        }
                        DriveMode::Reference => {
                            metrics.record_completion(i, done_at - r.arrival_s, r.class);
                            metrics.record_variant(r.rung);
                        }
                    }
                    done.push((r, done_at, false));
                }
                // Park the drained buffer for the next dispatch: steady
                // state allocates no batch vectors.
                pool.devices[i].spare = batch;
                pool.devices[i].busy = false;
                progressed = true;
            }
            if pool.devices[i].busy || !pool.devices[i].lifecycle.serves() {
                continue;
            }
            // A crashed-but-undetected device executes nothing; its
            // queue keeps receiving work until the watchdog notices.
            if frt.as_ref().map_or(false, |f| f.failed(i)) {
                continue;
            }
            // 2. Work stealing into an idle, empty, *accepting* device.
            if cfg.work_stealing
                && pool.devices[i].lifecycle.accepts_new()
                && pool.devices[i].queue.is_empty()
            {
                let scan = match mode {
                    DriveMode::Reference => true,
                    DriveMode::Optimized => *steal_possible
                        .get_or_insert_with(|| pool.devices.iter().any(|d| d.queue.len() > 1)),
                };
                if scan {
                    let n = pool.steal_into(i);
                    if n > 0 {
                        metrics.record_steal(i, n);
                        progressed = true;
                    }
                }
            }
            // 3. Dynamic-batching dispatch. The optimized arm inlines
            // `BatchPolicy::decide` against the precomputed cap, sharing
            // `earliest_deadline_s` so the two arms agree bit-for-bit.
            let d = &mut pool.devices[i];
            let n = match mode {
                DriveMode::Reference => {
                    match cfg.batch.decide(&d.queue, now, d.backend.max_batch()) {
                        Decision::Dispatch(n) => n,
                        _ => 0,
                    }
                }
                DriveMode::Optimized => {
                    let qlen = d.queue.len();
                    if qlen == 0 {
                        0
                    } else if qlen >= caps[i] {
                        caps[i]
                    } else if now >= cfg.batch.earliest_deadline_s(&d.queue) {
                        qlen
                    } else {
                        0
                    }
                }
            };
            if n > 0 {
                let mut batch = std::mem::take(&mut d.spare);
                batch.extend(d.queue.drain(..n));
                // Degraded frames shrink the batch's marginal cost; with
                // no ladder (or an all-rung-0 batch) this is bit-exactly
                // the backend's plain batch latency.
                let mut service = match cfg.admission.ladder() {
                    Some(l) => l.batch_service_s(d.backend.as_ref(), &batch),
                    None => match mode {
                        DriveMode::Optimized => d.service_for(batch.len()),
                        DriveMode::Reference => d.backend.batch_latency_s(batch.len()),
                    },
                };
                // Fault injection at dispatch: slowdown windows and
                // per-batch spikes inflate the modeled service time; a
                // batch slow enough to cross the heartbeat timeout gets
                // a straggler check scheduled against it.
                if let Some(f) = frt.as_mut() {
                    let ord = f.ordinal[i];
                    f.ordinal[i] += 1;
                    let spike = f.plan.spike(i, ord);
                    if spike > 1.0 {
                        metrics.faults.spikes += 1;
                    }
                    service *= f.plan.slowdown(i, now) * spike;
                    if let Some(rp) = f.plan.recovery.as_ref() {
                        if service > rp.heartbeat_timeout_s {
                            f.events.push(FaultEvent::Straggler {
                                device: i,
                                t: now + rp.heartbeat_timeout_s,
                            });
                        }
                    }
                }
                d.busy = true;
                d.free_at = now + service;
                d.in_flight = batch;
                metrics.record_batch(i, service);
                progressed = true;
            }
        }
        if !progressed {
            break;
        }
    }
}

/// The next event after `now`: the earliest of the next arrival, any
/// in-flight completion, any serving device's batch-wait deadline, any
/// provisioning device's warm-up end, or (under a fault plan) any
/// crash/detect/straggler event or staged re-dispatch.
#[allow(clippy::too_many_arguments)]
fn next_event(
    pool: &ShardPool,
    next_arrival: Option<f64>,
    batch: &BatchPolicy,
    now: f64,
    frt: Option<&FaultRt>,
    mode: DriveMode,
    caps: &[usize],
) -> f64 {
    let mut t = next_arrival.unwrap_or(f64::INFINITY);
    if let Some(f) = frt {
        t = t.min(f.next_t());
    }
    for (i, d) in pool.devices.iter().enumerate() {
        if let Lifecycle::Provisioning { ready_at } = d.lifecycle {
            t = t.min(ready_at);
            continue;
        }
        // A crashed shard produces no events of its own until its
        // watchdog fires (that event lives in the fault schedule).
        if frt.map_or(false, |f| f.failed(i)) {
            continue;
        }
        if d.busy {
            t = t.min(d.free_at);
        } else if d.lifecycle.serves() {
            match mode {
                DriveMode::Reference => {
                    if let Decision::WaitUntil(w) =
                        batch.decide(&d.queue, now, d.backend.max_batch())
                    {
                        t = t.min(w);
                    }
                }
                // `decide` inlined against the precomputed cap: only a
                // non-empty under-cap queue whose deadline is still ahead
                // yields a wait event (the same three-way split `decide`
                // makes, minus the virtual calls).
                DriveMode::Optimized => {
                    let qlen = d.queue.len();
                    if qlen > 0 && qlen < caps[i] {
                        let w = batch.earliest_deadline_s(&d.queue);
                        if now < w {
                            t = t.min(w);
                        }
                    }
                }
            }
        }
    }
    t
}

/// One scheduled event of the DES fault machinery.
#[derive(Debug, Clone, Copy, PartialEq)]
enum FaultEvent {
    /// The injected crash instant: the device silently stops executing.
    Crash { device: usize, t: f64 },
    /// The watchdog's heartbeat timeout expires: the crash becomes known.
    Detect { device: usize, t: f64 },
    /// Heartbeat check on a dispatched batch whose (fault-inflated)
    /// service time crossed the timeout.
    Straggler { device: usize, t: f64 },
}

impl FaultEvent {
    fn t(&self) -> f64 {
        match *self {
            FaultEvent::Crash { t, .. }
            | FaultEvent::Detect { t, .. }
            | FaultEvent::Straggler { t, .. } => t,
        }
    }

    /// Tie order within one instant: crashes land before detections
    /// before straggler checks, then device index.
    fn order(&self) -> (u8, usize) {
        match *self {
            FaultEvent::Crash { device, .. } => (0, device),
            FaultEvent::Detect { device, .. } => (1, device),
            FaultEvent::Straggler { device, .. } => (2, device),
        }
    }
}

/// Runtime state of one [`FaultPlan`] inside a DES run.
struct FaultRt {
    plan: FaultPlan,
    /// Scheduled crash/detect/straggler events not yet processed.
    events: Vec<FaultEvent>,
    /// Requests staged for re-dispatch: `(redispatch_at, copy)`.
    pending: Vec<(f64, Request)>,
    /// Ids with a terminal outcome (completed / shed / expired) — the
    /// exactly-once gate: later completions of stale copies are
    /// suppressed, later sheds dropped.
    resolved: HashSet<u64>,
    /// Simulator ground truth: the device crashed. *Knowledge* (the
    /// lifecycle the router consults) lags until the watchdog detects
    /// it — without recovery, forever.
    truth_failed: Vec<bool>,
    /// Crash instant per device (base of the MTTR measurement).
    crash_t: Vec<f64>,
    /// In-flight batches stranded by a crash, awaiting detection (or
    /// end-of-run expiry).
    stranded: Vec<Vec<Request>>,
    /// Per-device dispatched-batch ordinal (the spike draw's index).
    ordinal: Vec<u64>,
    /// Devices whose reboot re-provisioning is in flight (MTTR closes
    /// at activation).
    rebooting: Vec<bool>,
}

impl FaultRt {
    fn new(plan: &FaultPlan, n_devices: usize) -> Self {
        plan.validate();
        let mut events: Vec<FaultEvent> = plan
            .crashes
            .iter()
            .map(|c| FaultEvent::Crash { device: c.device, t: c.at_s })
            .collect();
        events.sort_by(|a, b| {
            a.t().partial_cmp(&b.t()).unwrap().then(a.order().cmp(&b.order()))
        });
        Self {
            plan: plan.clone(),
            events,
            pending: Vec::new(),
            resolved: HashSet::new(),
            truth_failed: vec![false; n_devices],
            crash_t: vec![0.0; n_devices],
            stranded: vec![Vec::new(); n_devices],
            ordinal: vec![0; n_devices],
            rebooting: vec![false; n_devices],
        }
    }

    /// Track one more device (autoscaler grow).
    fn add_device(&mut self) {
        self.truth_failed.push(false);
        self.crash_t.push(0.0);
        self.stranded.push(Vec::new());
        self.ordinal.push(0);
        self.rebooting.push(false);
    }

    fn failed(&self, device: usize) -> bool {
        self.truth_failed.get(device).copied().unwrap_or(false)
    }

    /// Earliest scheduled event or staged re-dispatch.
    fn next_t(&self) -> f64 {
        let ev = self.events.iter().map(FaultEvent::t).fold(f64::INFINITY, f64::min);
        let rd = self.pending.iter().map(|p| p.0).fold(f64::INFINITY, f64::min);
        ev.min(rd)
    }

    /// Pop the earliest event due at or before `now` (tie order:
    /// [`FaultEvent::order`]).
    fn pop_due(&mut self, now: f64) -> Option<FaultEvent> {
        let mut best: Option<usize> = None;
        for (i, e) in self.events.iter().enumerate() {
            if e.t() > now {
                continue;
            }
            let better = match best {
                None => true,
                Some(b) => {
                    let (bt, bo) = (self.events[b].t(), self.events[b].order());
                    e.t() < bt || (e.t() == bt && e.order() < bo)
                }
            };
            if better {
                best = Some(i);
            }
        }
        best.map(|i| self.events.remove(i))
    }

    /// Stage `r` for re-dispatch a backoff after `t`, or expire it when
    /// the retry budget / freshness deadline is spent. Already-resolved
    /// ids are dropped silently (the id completed or shed elsewhere).
    /// Expired requests get a shed-flagged outcome via `done` but are
    /// counted in [`FaultStats::expired`](super::faults::FaultStats),
    /// *not* the fleet shed counter — the conservation law is
    /// `offered == completed + shed + expired`.
    fn requeue(
        &mut self,
        r: Request,
        t: f64,
        metrics: &mut FleetMetrics,
        done: &mut Vec<(Request, f64, bool)>,
    ) {
        if self.resolved.contains(&r.id) {
            return;
        }
        let Some(rp) = self.plan.recovery.as_ref() else {
            // No recovery armed: the request dies with its shard.
            self.resolved.insert(r.id);
            metrics.faults.expired += 1;
            done.push((r, t, true));
            return;
        };
        let at = t + rp.backoff_base_s * 2f64.powi(r.retries as i32);
        if u32::from(r.retries) + 1 > u32::from(rp.retry_budget)
            || at - r.arrival_s > rp.retry_deadline_s
        {
            self.resolved.insert(r.id);
            metrics.faults.expired += 1;
            done.push((r, t, true));
            return;
        }
        let mut copy = r;
        copy.retries += 1;
        metrics.faults.retries += 1;
        self.pending.push((at, copy));
    }
}

/// Where grown devices come from.
enum Provisioner<'a> {
    /// Homogeneous: a factory builds the `i`-th provisioned device (`i`
    /// counts grows over the whole run, for unique labels).
    Factory(&'a mut dyn FnMut(usize) -> Box<dyn Backend>),
    /// Heterogeneous: each grow picks the cheapest catalog entry whose
    /// capacity covers the current demand deficit (and whose service
    /// latency fits the SLO) — see [`DeviceCatalog::pick`].
    Catalog(&'a DeviceCatalog),
}

/// The autoscaler driver state handed to [`drive`].
struct ScalingCtx<'a> {
    auto: &'a mut Autoscaler,
    provisioner: Provisioner<'a>,
}

/// Sustainable throughput of the capacity that is staying (active +
/// provisioning devices) at the run's batching policy, frames/s — what
/// the heterogeneous grow path measures its deficit against (the same
/// [`capacity_fps`](super::device::capacity_fps) definition the catalog
/// probes with, so deficit and feasibility agree).
fn planned_capacity_fps(pool: &ShardPool, batch: &BatchPolicy) -> f64 {
    pool.devices
        .iter()
        .filter(|d| {
            d.lifecycle.accepts_new() || matches!(d.lifecycle, Lifecycle::Provisioning { .. })
        })
        .map(|d| super::device::capacity_fps(d.backend.as_ref(), batch.max_batch))
        .sum()
}

fn observe(pool: &ShardPool, stats: EpochStats, now: f64, epoch_s: f64) -> EpochObservation {
    let active = pool.active_count();
    let serving = pool.serving_count();
    EpochObservation {
        now_s: now,
        epoch_s,
        active_devices: active,
        draining_devices: serving - active,
        provisioning_devices: pool.provisioning_count(),
        utilization: (stats.busy_s / (epoch_s * serving.max(1) as f64)).clamp(0.0, 1.0),
        completed: stats.completed,
        shed: stats.shed,
        p99_s: stats.p99_s,
        backlog: pool.backlog(),
    }
}

/// Everything one [`drive_core`] run accumulated, before it is assembled
/// into a [`FleetReport`]. [`simulate_parallel`] merges one of these per
/// epoch shard (in fixed shard order) and assembles once; the serial
/// entry points assemble theirs directly — with a single shard the two
/// paths are the same bytes.
struct DriveOut {
    metrics: FleetMetrics,
    ledger: EnergyLedger,
    offered: u64,
    offered_by_class: [u64; 3],
    devices_start: usize,
    devices_peak: usize,
    events: Vec<ScalingEvent>,
    /// `last_completion.max(final now)` — the horizon throughput is
    /// measured against.
    last_t: f64,
    outcomes: Vec<RequestOutcome>,
}

/// The unified DES driver behind every `simulate*` entry point. Besides
/// the report it returns per-request outcomes (completed-at / shed) for
/// the scenario accuracy pipeline; report-only entry points drop them.
fn drive(
    pool: &mut ShardPool,
    arrivals: Arrivals<'_>,
    cfg: &SimConfig,
    scaling: Option<ScalingCtx<'_>>,
    mode: DriveMode,
) -> (FleetReport, Vec<RequestOutcome>) {
    let out = drive_core(pool, arrivals, cfg, scaling, mode);
    assemble_report(pool, cfg, out)
}

/// The DES event loop proper: admission, fault machinery, settle,
/// autoscaling, virtual-time advance. Returns the raw accumulators so
/// [`simulate_parallel`] can merge shard runs before report assembly.
fn drive_core(
    pool: &mut ShardPool,
    mut arrivals: Arrivals<'_>,
    cfg: &SimConfig,
    mut scaling: Option<ScalingCtx<'_>>,
    mode: DriveMode,
) -> DriveOut {
    assert!(!pool.is_empty(), "simulate needs at least one device");
    let mut metrics = FleetMetrics::new(pool.len(), cfg.slo_s);
    let mut quota = cfg.admission.runtime_quota();
    let mut frt = cfg.faults.as_ref().map(|p| FaultRt::new(p, pool.len()));
    let mut events: Vec<ScalingEvent> = Vec::new();
    let mut now = 0.0f64;
    let mut last_completion = 0.0f64;
    // Pre-loaded queues (tests seed skew this way) count as offered, so
    // the conservation law offered == completed + shed holds for them too.
    let mut offered = pool.backlog() as u64;
    let mut offered_by_class = [0u64; 3];
    for d in &pool.devices {
        for r in &d.queue {
            offered_by_class[r.class.index()] += 1;
        }
    }
    let mut grows = 0usize;
    let mut next_epoch = scaling.as_ref().map(|s| s.auto.cfg.epoch_s);
    let devices_start = pool.serving_count();
    let mut devices_peak = pool.active_count();
    let mut done: Vec<(Request, f64, bool)> = Vec::new();
    let mut outcomes: Vec<RequestOutcome> = Vec::new();
    // Energy accounting: per-device idle/busy power and frame GOP are
    // static per backend, cached once per registration.
    let mut ledger = EnergyLedger::new(cfg.energy_epoch_s);
    let mut powers: Vec<(f64, f64, f64)> = pool
        .devices
        .iter()
        .map(|d| (d.backend.power_w(0.0), d.backend.power_w(1.0), d.backend.gop_per_frame()))
        .collect();
    // Per-device effective batch cap, cached so the optimized hot path
    // never makes a virtual `max_batch()` call per decision (extended in
    // lockstep with `powers` when the autoscaler grows the pool).
    let mut caps: Vec<usize> = pool
        .devices
        .iter()
        .map(|d| cfg.batch.effective_cap(d.backend.max_batch()))
        .collect();

    loop {
        // 0. Provisioned devices whose warm-up has finished join the pool.
        for i in 0..pool.devices.len() {
            if let Lifecycle::Provisioning { ready_at } = pool.devices[i].lifecycle {
                if ready_at <= now {
                    pool.devices[i].lifecycle = Lifecycle::Active;
                    // A reboot landing closes the repair clock: MTTR is
                    // crash → serving again.
                    if let Some(f) = frt.as_mut() {
                        if f.rebooting[i] {
                            f.rebooting[i] = false;
                            metrics.faults.recovered_devices += 1;
                            metrics.faults.mttr_total_s += ready_at - f.crash_t[i];
                        }
                    }
                    devices_peak = devices_peak.max(pool.active_count());
                    events.push(ScalingEvent {
                        t_s: ready_at,
                        kind: ScaleEventKind::Activated { device: i },
                        serving_after: pool.serving_count(),
                    });
                }
            }
        }

        // 1. Admit every arrival due by `now`: token buckets first, then
        // routing + the bounded queue's shed policy.
        while let Some(mut req) = arrivals.pop_due(now) {
            offered += 1;
            offered_by_class[req.class.index()] += 1;
            // Front-door link drop: the frame is lost before admission
            // (a shed for every conservation law, counted separately in
            // the fault report; the camera still gets its token back).
            if let Some(f) = frt.as_mut() {
                if f.plan.drops_link(req.id) {
                    metrics.faults.link_drops += 1;
                    f.resolved.insert(req.id);
                    metrics.record_shed(req.class);
                    done.push((req, now, true));
                    continue;
                }
            }
            if let Some(q) = quota.as_mut() {
                if !q.try_take(req.class, now) {
                    metrics.record_quota_shed(req.class);
                    if let Some(f) = frt.as_mut() {
                        f.resolved.insert(req.id);
                    }
                    done.push((req, now, true));
                    continue;
                }
            }
            let idx = match mode {
                DriveMode::Optimized => pool.route_fast(now),
                DriveMode::Reference => pool.route(now),
            };
            // Total blackout: route's last-resort fallback found no
            // live shard (every device failed for good) — the front
            // door sheds. Unreachable without a fault plan (the
            // autoscaler's min-devices clamp keeps one device alive).
            if frt.is_some()
                && matches!(
                    pool.devices[idx].lifecycle,
                    Lifecycle::Retired | Lifecycle::Failed
                )
            {
                if let Some(f) = frt.as_mut() {
                    f.resolved.insert(req.id);
                }
                metrics.record_shed(req.class);
                done.push((req, now, true));
                continue;
            }
            let d = &mut pool.devices[idx];
            // Degradation rung from the routed queue's fill fraction,
            // stamped before the shed policy runs — the live front door
            // reads the same shard's depth counter at the same point.
            if let Some(l) = cfg.admission.ladder() {
                req.rung = l.rung_for(d.queue.len(), cfg.queue_depth);
            }
            match admit(&mut d.queue, cfg.queue_depth, cfg.shed, req) {
                Admission::Admitted => {}
                Admission::AdmittedEvicted(old) => {
                    // An evicted re-dispatch copy is displaced, not
                    // refused: it goes back through the retry path.
                    if old.retries > 0 {
                        frt.as_mut()
                            .expect("retry copies only exist under a fault plan")
                            .requeue(old, now, &mut metrics, &mut done);
                    } else {
                        if let Some(f) = frt.as_mut() {
                            f.resolved.insert(old.id);
                        }
                        metrics.record_shed(old.class);
                        done.push((old, now, true));
                    }
                }
                Admission::Rejected => {
                    if let Some(f) = frt.as_mut() {
                        f.resolved.insert(req.id);
                    }
                    metrics.record_shed(req.class);
                    done.push((req, now, true));
                }
            }
        }

        // 1b. Fault machinery. Crashes land *after* the same instant's
        // arrivals (the front door hears about traffic before the
        // watchdog hears about failures — the live runtime's turn order),
        // then detections and straggler checks, then staged re-dispatches
        // re-enter routing + admission.
        if let Some(f) = frt.as_mut() {
            while let Some(ev) = f.pop_due(now) {
                match ev {
                    FaultEvent::Crash { device, t } => {
                        // A board that is off (failed, rebooting,
                        // retired) cannot crash again.
                        if device >= pool.devices.len()
                            || f.truth_failed[device]
                            || !pool.devices[device].lifecycle.serves()
                        {
                            continue;
                        }
                        metrics.faults.injected_crashes += 1;
                        f.truth_failed[device] = true;
                        f.crash_t[device] = t;
                        // The in-flight batch is stranded, not lost:
                        // detection re-dispatches it (or end-of-run
                        // expiry accounts for it).
                        let d = &mut pool.devices[device];
                        f.stranded[device] = std::mem::take(&mut d.in_flight);
                        d.busy = false;
                        if let Some(rp) = f.plan.recovery.as_ref() {
                            f.events.push(FaultEvent::Detect {
                                device,
                                t: t + rp.heartbeat_timeout_s,
                            });
                        }
                    }
                    FaultEvent::Detect { device, t } => {
                        if !f.truth_failed[device] {
                            continue;
                        }
                        metrics.faults.detected += 1;
                        f.truth_failed[device] = false;
                        pool.devices[device].lifecycle = Lifecycle::Failed;
                        events.push(ScalingEvent {
                            t_s: t,
                            kind: ScaleEventKind::Failed { device },
                            serving_after: pool.serving_count(),
                        });
                        // Everything the dead shard held — the stranded
                        // in-flight batch first (oldest work), then its
                        // queue — goes back through re-dispatch.
                        let stranded = std::mem::take(&mut f.stranded[device]);
                        let queued: Vec<Request> =
                            pool.devices[device].queue.drain(..).collect();
                        for r in stranded.into_iter().chain(queued) {
                            f.requeue(r, t, &mut metrics, &mut done);
                        }
                        let reboot = f.plan.recovery.as_ref().map_or(false, |rp| rp.reboot);
                        if reboot {
                            let delay = f.plan.recovery.as_ref().unwrap().reboot_delay_s;
                            pool.devices[device].lifecycle =
                                Lifecycle::Provisioning { ready_at: t + delay };
                            f.rebooting[device] = true;
                            events.push(ScalingEvent {
                                t_s: t,
                                kind: ScaleEventKind::Provisioning { device },
                                serving_after: pool.serving_count(),
                            });
                        }
                    }
                    FaultEvent::Straggler { device, t } => {
                        // Fires only while the guarded batch is still
                        // running (a crash cleared `busy` and is handled
                        // by its own detection; a finished batch needs
                        // no rescue).
                        if f.truth_failed[device]
                            || !pool.devices[device].busy
                            || pool.devices[device].free_at <= t
                        {
                            continue;
                        }
                        metrics.faults.detected += 1;
                        // Copies of the hung batch go back through
                        // re-dispatch; the original stays in flight and
                        // whichever finishes second is suppressed.
                        let copies: Vec<Request> = pool.devices[device]
                            .in_flight
                            .iter()
                            .filter(|r| !f.resolved.contains(&r.id))
                            .copied()
                            .collect();
                        for r in copies {
                            f.requeue(r, t, &mut metrics, &mut done);
                        }
                    }
                }
            }

            // Staged re-dispatches due now re-enter routing + admission
            // (deterministic order: fire time, then id). Retry copies
            // bypass the front-door quota and link drops — the request
            // already paid both on arrival.
            f.pending.sort_by(|a, b| {
                a.0.partial_cmp(&b.0).unwrap().then(a.1.id.cmp(&b.1.id))
            });
            while let Some(pos) = f.pending.iter().position(|p| p.0 <= now) {
                let (_, r) = f.pending.remove(pos);
                if f.resolved.contains(&r.id) {
                    continue;
                }
                let idx = match mode {
                    DriveMode::Optimized => pool.route_fast(now),
                    DriveMode::Reference => pool.route(now),
                };
                if matches!(
                    pool.devices[idx].lifecycle,
                    Lifecycle::Retired | Lifecycle::Failed
                ) {
                    // Nothing routable anywhere right now: back off and
                    // try again (or expire on budget/deadline).
                    f.requeue(r, now, &mut metrics, &mut done);
                    continue;
                }
                let d = &mut pool.devices[idx];
                match admit(&mut d.queue, cfg.queue_depth, cfg.shed, r) {
                    Admission::Admitted => metrics.faults.redispatched += 1,
                    Admission::AdmittedEvicted(old) => {
                        metrics.faults.redispatched += 1;
                        if old.retries > 0 {
                            f.requeue(old, now, &mut metrics, &mut done);
                        } else {
                            f.resolved.insert(old.id);
                            metrics.record_shed(old.class);
                            done.push((old, now, true));
                        }
                    }
                    Admission::Rejected => f.requeue(r, now, &mut metrics, &mut done),
                }
            }
        }

        // 2. Complete / steal / dispatch until quiescent.
        settle(pool, now, cfg, &mut metrics, &mut done, &mut frt, mode, &caps);
        for d in &pool.devices {
            if d.busy {
                last_completion = last_completion.max(d.free_at);
            }
        }
        for (r, t, shed) in done.drain(..) {
            outcomes.push(RequestOutcome { id: r.id, camera: r.camera, t_s: t, shed, rung: r.rung });
            arrivals.on_done(&r, t);
        }

        // 3. Retire draining devices that went idle.
        for i in 0..pool.devices.len() {
            if matches!(pool.devices[i].lifecycle, Lifecycle::Draining)
                && !pool.devices[i].busy
                && pool.devices[i].queue.is_empty()
                // A crashed drainer is not "drained": its stranded work
                // is still unaccounted until the watchdog rules on it.
                && !frt.as_ref().map_or(false, |f| f.failed(i))
            {
                pool.devices[i].lifecycle = Lifecycle::Retired;
                let serving_after = pool.serving_count();
                events.push(ScalingEvent {
                    t_s: now,
                    kind: ScaleEventKind::Retired { device: i },
                    serving_after,
                });
            }
        }

        // 4. Epoch boundary: let the autoscaler resize the pool.
        if let (Some(ctx), Some(epoch_end)) = (scaling.as_mut(), next_epoch) {
            if now + 1e-12 >= epoch_end {
                let epoch_s = ctx.auto.cfg.epoch_s;
                let obs = observe(pool, metrics.take_epoch(), now, epoch_s);
                match ctx.auto.decide(&obs) {
                    ScaleAction::Grow(n) => {
                        // The epoch's demand in frames/s (sheds are
                        // demand the fleet failed to serve).
                        let demand_fps = (obs.completed + obs.shed) as f64 / epoch_s;
                        for _ in 0..n {
                            let backend = match &mut ctx.provisioner {
                                Provisioner::Factory(factory) => factory(grows),
                                Provisioner::Catalog(catalog) => {
                                    // Deficit shrinks as this loop adds
                                    // capacity, so a 2-device grow can
                                    // mix device kinds.
                                    let deficit = demand_fps
                                        - planned_capacity_fps(pool, &cfg.batch);
                                    let e = catalog.pick(deficit, cfg.slo_s);
                                    catalog.build(e, grows)
                                }
                            };
                            powers.push((
                                backend.power_w(0.0),
                                backend.power_w(1.0),
                                backend.gop_per_frame(),
                            ));
                            caps.push(cfg.batch.effective_cap(backend.max_batch()));
                            grows += 1;
                            let ready_at = now + ctx.auto.cfg.provision_delay_s;
                            let idx = pool.register_provisioning(backend, ready_at);
                            metrics.add_device();
                            if let Some(f) = frt.as_mut() {
                                f.add_device();
                            }
                            let serving_after = pool.serving_count();
                            events.push(ScalingEvent {
                                t_s: now,
                                kind: ScaleEventKind::Provisioning { device: idx },
                                serving_after,
                            });
                        }
                    }
                    ScaleAction::Shrink(n) => {
                        for _ in 0..n {
                            let idx = match ctx.auto.cfg.drain_order {
                                // Newest active device drains first:
                                // replicas retire before the seed boards.
                                DrainOrder::NewestFirst => pool
                                    .devices
                                    .iter()
                                    .rposition(|d| matches!(d.lifecycle, Lifecycle::Active)),
                                // Energy-aware: the hottest (preferably
                                // already idle) device drains first.
                                DrainOrder::MostExpensiveFirst => pool.most_expensive_active(),
                            };
                            let Some(idx) = idx else {
                                break;
                            };
                            pool.devices[idx].lifecycle = Lifecycle::Draining;
                            let serving_after = pool.serving_count();
                            events.push(ScalingEvent {
                                t_s: now,
                                kind: ScaleEventKind::DrainStarted { device: idx },
                                serving_after,
                            });
                        }
                    }
                    ScaleAction::Hold => {}
                }
                next_epoch = Some(epoch_end + epoch_s);
            }
        }

        let arrivals_left = arrivals.pending();
        let recovery_on = frt.as_ref().map_or(false, |f| f.plan.recovery.is_some());
        let work_left = pool.devices.iter().enumerate().any(|(i, d)| {
            // A dead shard's backlog cannot drain without recovery; it
            // is flushed to expired outcomes after the loop.
            if !recovery_on && frt.as_ref().map_or(false, |f| f.failed(i)) {
                return false;
            }
            d.busy || !d.queue.is_empty()
        });
        // The fault machinery keeps the run alive until every scheduled
        // event fires, every staged re-dispatch lands, and every reboot
        // completes — MTTR and recovery accounting stay exact.
        let fault_work = frt.as_ref().map_or(false, |f| {
            !f.pending.is_empty() || !f.events.is_empty() || f.rebooting.iter().any(|&b| b)
        });
        if !arrivals_left && !work_left && !fault_work {
            break;
        }

        // 5. Advance virtual time to the next event.
        let mut t =
            next_event(pool, arrivals.peek(), &cfg.batch, now, frt.as_ref(), mode, &caps);
        if let Some(epoch_end) = next_epoch {
            t = t.min(epoch_end);
        }
        if !t.is_finite() {
            // Only possible if every queue emptied and nothing is busy —
            // already handled above, but guard against a stall.
            break;
        }
        // The DES invariant the property tests lean on: virtual time
        // never runs backwards.
        assert!(t + 1e-12 >= now, "virtual time went backwards: {t} < {now}");
        let t = t.max(now);
        // Accrue energy over the step: between events every device's
        // lifecycle and busy state are constant (the next event is
        // clamped to every free_at / ready_at), so power is piecewise
        // constant and the ledger is exact. A zero-length step accrues
        // nothing (`accrue` no-ops on it), so it is skipped outright.
        if t > now {
            for (i, d) in pool.devices.iter().enumerate() {
                let (idle_w, busy_w, _) = powers[i];
                // A crashed board draws nothing (it is down, whatever the
                // router still believes).
                let state = if frt.as_ref().map_or(false, |f| f.failed(i)) {
                    Lifecycle::Failed
                } else {
                    d.lifecycle
                };
                ledger.accrue(i, state, now, t, if d.busy { busy_w } else { idle_w });
            }
        }
        now = t;
    }

    // Fold any batched completion records before anything below reads
    // the per-device counters (served-GOP needs the final completed
    // counts). A no-op in reference mode.
    metrics.fold_pending();

    // End-of-run flush: work stranded on crashed shards nothing ever
    // recovered (recovery off — the watchdog never ruled) expires, so
    // every id still reaches the outcome log exactly once.
    if let Some(f) = frt.as_mut() {
        debug_assert!(f.pending.is_empty(), "staged re-dispatches must drain before exit");
        for i in 0..pool.devices.len() {
            if !f.truth_failed[i] {
                continue;
            }
            let stranded = std::mem::take(&mut f.stranded[i]);
            let queued: Vec<Request> = pool.devices[i].queue.drain(..).collect();
            for r in stranded.into_iter().chain(queued) {
                if f.resolved.insert(r.id) {
                    metrics.faults.expired += 1;
                    outcomes.push(RequestOutcome {
                        id: r.id,
                        camera: r.camera,
                        t_s: now,
                        shed: true,
                        rung: r.rung,
                    });
                }
            }
        }
    }

    for (stats, &(_, _, gop)) in metrics.per_device.iter().zip(&powers) {
        ledger.served_gop += stats.completed as f64 * gop;
    }
    // Devices registered in the run's last instant never accrued: give
    // them explicit zero rows so ledger and device reports align.
    while ledger.per_device_j.len() < pool.devices.len() {
        ledger.per_device_j.push(0.0);
    }
    DriveOut {
        metrics,
        ledger,
        offered,
        offered_by_class,
        devices_start,
        devices_peak,
        events,
        last_t: last_completion.max(now),
        outcomes,
    }
}

/// Turn a (possibly merged) [`DriveOut`] into the final [`FleetReport`]
/// + outcome log against the pool it ran on.
fn assemble_report(
    pool: &ShardPool,
    cfg: &SimConfig,
    out: DriveOut,
) -> (FleetReport, Vec<RequestOutcome>) {
    let DriveOut {
        metrics,
        ledger,
        offered,
        offered_by_class,
        devices_start,
        devices_peak,
        events,
        last_t,
        mut outcomes,
    } = out;
    let backends: Vec<&dyn Backend> = pool.devices.iter().map(|d| d.backend.as_ref()).collect();
    let mut report = metrics.report(&backends, last_t);
    report.offered = offered;
    report.devices_start = devices_start;
    report.devices_peak = devices_peak;
    report.devices_final = pool.serving_count();
    report.scaling = events;
    for (dr, ds) in report.devices.iter_mut().zip(&pool.devices) {
        dr.state = ds.lifecycle.label();
    }
    for (i, c) in report.classes.iter_mut().enumerate() {
        c.offered = offered_by_class[i];
    }
    report.energy = ledger;
    if let Some(plan) = cfg.faults.as_ref() {
        let availability =
            if offered == 0 { 1.0 } else { report.completed as f64 / offered as f64 };
        report.faults = Some(metrics.faults.to_report(plan, availability));
    }
    if let Some(l) = cfg.admission.ladder() {
        report.variants = l.variant_serves(&metrics.variant_served);
        report.effective_accuracy = Some(l.effective_accuracy(&metrics.variant_served, offered));
    }
    // Outcomes in trace order, not completion order (batch completions
    // interleave): the scenario pipeline indexes them by request id.
    outcomes.sort_by_key(|o| o.id);
    (report, outcomes)
}

/// Run an open-loop trace through a fixed pool. The pool's queues may be
/// pre-loaded (tests use this to create skew); devices are expected idle
/// at start.
pub fn simulate(pool: &mut ShardPool, trace: &[Request], cfg: &SimConfig) -> FleetReport {
    drive(pool, Arrivals::Open { trace, next: 0 }, cfg, None, DriveMode::Optimized).0
}

/// [`simulate`] on the frozen pre-optimization dispatch loop
/// ([`DriveMode::Reference`]) — the differential oracle the
/// scale-invariance suite pins the optimized path against, byte for
/// byte. Test/bench oracle only: quadratic in fleet size per settle.
pub fn simulate_reference(pool: &mut ShardPool, trace: &[Request], cfg: &SimConfig) -> FleetReport {
    drive(pool, Arrivals::Open { trace, next: 0 }, cfg, None, DriveMode::Reference).0
}

/// As [`simulate`], also returning per-request outcomes (in trace-id
/// order) — the scenario pipeline replays these for accuracy scoring.
pub fn simulate_logged(
    pool: &mut ShardPool,
    trace: &[Request],
    cfg: &SimConfig,
) -> (FleetReport, Vec<RequestOutcome>) {
    drive(pool, Arrivals::Open { trace, next: 0 }, cfg, None, DriveMode::Optimized)
}

/// [`simulate_logged`] on the reference dispatch loop (test oracle).
pub fn simulate_logged_reference(
    pool: &mut ShardPool,
    trace: &[Request],
    cfg: &SimConfig,
) -> (FleetReport, Vec<RequestOutcome>) {
    drive(pool, Arrivals::Open { trace, next: 0 }, cfg, None, DriveMode::Reference)
}

/// Epoch-sharded parallel DES over an open-loop trace: camera streams
/// are dealt across `shards` independent sub-fleets (camera `c` → shard
/// `c % shards`, devices dealt round-robin by [`ShardPool::
/// split_round_robin`]), each sub-fleet runs the whole virtual horizon
/// on its own worker, and the per-shard accumulators merge in fixed
/// shard order. Conservative in virtual time by construction — no event
/// ever crosses a shard boundary, so no shard can observe another's
/// future — and byte-deterministic: the report is a pure function of
/// `(pool, trace, cfg, shards)`, independent of `threads` and of
/// scheduling (`tests/fleet_scale.rs` pins 1/2/4-thread runs to
/// identical bytes). With `shards == 1` nothing is merged and the
/// result is bit-identical to [`simulate`].
///
/// Sharding changes the model, deliberately: routing and stealing stay
/// inside a shard, so `shards > 1` is *a different (more realistic,
/// cellular) fleet topology*, not a reordered run of the global one —
/// which is why the merge can stay exact instead of approximate.
/// Requires a front door that is per-request stateless across cameras:
/// no fault plan (global link/crash schedules would couple shards) and
/// no [`AdmissionPolicy::ClassQuota`] (a global token bucket).
pub fn simulate_parallel(
    pool: ShardPool,
    trace: &[Request],
    cfg: &SimConfig,
    shards: usize,
    threads: usize,
) -> FleetReport {
    assert!(
        cfg.faults.is_none(),
        "simulate_parallel cannot shard a fault plan (global schedules couple shards)"
    );
    assert!(
        cfg.admission.runtime_quota().is_none(),
        "simulate_parallel cannot shard a global class quota"
    );
    let pools = pool.split_round_robin(shards);
    // Stable partition: each sub-trace keeps the global arrival order of
    // its cameras' requests (and their original ids).
    let mut sub_traces: Vec<Vec<Request>> = (0..shards).map(|_| Vec::new()).collect();
    for r in trace {
        sub_traces[r.camera % shards].push(*r);
    }
    let threads = threads.clamp(1, shards);
    // Deterministic static schedule: worker w runs shards w, w+T, w+2T…
    // sequentially. Results are keyed by shard index, so OS scheduling
    // cannot reorder the merge.
    let mut jobs: Vec<Option<(ShardPool, Vec<Request>)>> =
        pools.into_iter().zip(sub_traces).map(Some).collect();
    let mut shard_outs: Vec<Option<(ShardPool, DriveOut)>> = (0..shards).map(|_| None).collect();
    std::thread::scope(|scope| {
        let mut handles = Vec::with_capacity(threads);
        for w in 0..threads {
            let mine: Vec<(usize, ShardPool, Vec<Request>)> = (w..shards)
                .step_by(threads)
                .map(|s| {
                    let (p, t) = jobs[s].take().expect("each shard is scheduled once");
                    (s, p, t)
                })
                .collect();
            handles.push(scope.spawn(move || {
                mine.into_iter()
                    .map(|(s, mut p, t)| {
                        let out = drive_core(
                            &mut p,
                            Arrivals::Open { trace: &t, next: 0 },
                            cfg,
                            None,
                            DriveMode::Optimized,
                        );
                        (s, p, out)
                    })
                    .collect::<Vec<_>>()
            }));
        }
        for h in handles {
            for (s, p, out) in h.join().expect("shard worker panicked") {
                shard_outs[s] = Some((p, out));
            }
        }
    });
    // Merge in fixed shard order: device-indexed rows concatenate
    // (shard-major, matching the merged pool below), scalar counters
    // add, and the f64 accumulators absorb left to right — one fixed
    // association, whatever the thread count was.
    let mut it = shard_outs.into_iter().map(|s| s.expect("every shard ran"));
    let (mut merged_pool, mut acc) = it.next().expect("shards >= 1");
    for (p, out) in it {
        acc.metrics.absorb(out.metrics);
        acc.ledger.absorb(out.ledger);
        acc.offered += out.offered;
        for (a, b) in acc.offered_by_class.iter_mut().zip(out.offered_by_class) {
            *a += b;
        }
        acc.devices_start += out.devices_start;
        acc.devices_peak += out.devices_peak;
        acc.events.extend(out.events);
        acc.last_t = acc.last_t.max(out.last_t);
        acc.outcomes.extend(out.outcomes);
        merged_pool.devices.extend(p.devices);
    }
    assemble_report(&merged_pool, cfg, acc).0
}

/// Run an open-loop trace with the autoscaler resizing the pool between
/// epochs. `factory` builds the `i`-th provisioned device.
pub fn simulate_autoscaled(
    pool: &mut ShardPool,
    trace: &[Request],
    cfg: &SimConfig,
    auto: &mut Autoscaler,
    factory: &mut dyn FnMut(usize) -> Box<dyn Backend>,
) -> FleetReport {
    simulate_autoscaled_logged(pool, trace, cfg, auto, factory).0
}

/// As [`simulate_autoscaled`], also returning per-request outcomes.
pub fn simulate_autoscaled_logged(
    pool: &mut ShardPool,
    trace: &[Request],
    cfg: &SimConfig,
    auto: &mut Autoscaler,
    factory: &mut dyn FnMut(usize) -> Box<dyn Backend>,
) -> (FleetReport, Vec<RequestOutcome>) {
    drive(
        pool,
        Arrivals::Open { trace, next: 0 },
        cfg,
        Some(ScalingCtx { auto, provisioner: Provisioner::Factory(factory) }),
        DriveMode::Optimized,
    )
}

/// [`simulate_autoscaled`] on the reference dispatch loop (test oracle).
pub fn simulate_autoscaled_reference(
    pool: &mut ShardPool,
    trace: &[Request],
    cfg: &SimConfig,
    auto: &mut Autoscaler,
    factory: &mut dyn FnMut(usize) -> Box<dyn Backend>,
) -> FleetReport {
    drive(
        pool,
        Arrivals::Open { trace, next: 0 },
        cfg,
        Some(ScalingCtx { auto, provisioner: Provisioner::Factory(factory) }),
        DriveMode::Reference,
    )
    .0
}

/// Heterogeneous autoscaling on an open-loop trace: every grow picks the
/// cheapest catalog device predicted to restore the SLO
/// ([`DeviceCatalog::pick`]); pair with
/// [`DrainOrder::MostExpensiveFirst`] for energy-aware scale-in.
pub fn simulate_autoscaled_hetero(
    pool: &mut ShardPool,
    trace: &[Request],
    cfg: &SimConfig,
    auto: &mut Autoscaler,
    catalog: &DeviceCatalog,
) -> FleetReport {
    check_catalog(catalog, cfg);
    drive(
        pool,
        Arrivals::Open { trace, next: 0 },
        cfg,
        Some(ScalingCtx { auto, provisioner: Provisioner::Catalog(catalog) }),
        DriveMode::Optimized,
    )
    .0
}

/// [`simulate_autoscaled_hetero`] on the reference dispatch loop (test
/// oracle).
pub fn simulate_autoscaled_hetero_reference(
    pool: &mut ShardPool,
    trace: &[Request],
    cfg: &SimConfig,
    auto: &mut Autoscaler,
    catalog: &DeviceCatalog,
) -> FleetReport {
    check_catalog(catalog, cfg);
    drive(
        pool,
        Arrivals::Open { trace, next: 0 },
        cfg,
        Some(ScalingCtx { auto, provisioner: Provisioner::Catalog(catalog) }),
        DriveMode::Reference,
    )
    .0
}

/// The heterogeneous entry points' contract: a non-empty catalog whose
/// capacities were probed at the batch size this run actually serves —
/// otherwise the grow path's deficit (measured at `cfg.batch`) and the
/// entries' feasibility (probed at `catalog.batch`) silently disagree
/// and "cheapest feasible" stops meaning anything.
fn check_catalog(catalog: &DeviceCatalog, cfg: &SimConfig) {
    assert!(!catalog.is_empty(), "heterogeneous autoscaling needs a non-empty catalog");
    assert_eq!(
        catalog.batch,
        cfg.batch.max_batch.max(1),
        "catalog probed at batch {} but the fleet batches up to {}",
        catalog.batch,
        cfg.batch.max_batch
    );
}

/// Run closed-loop clients against a fixed pool.
pub fn simulate_closed_loop(
    pool: &mut ShardPool,
    clients: &ClosedLoopConfig,
    cfg: &SimConfig,
) -> FleetReport {
    drive(pool, Arrivals::closed(clients.clone()), cfg, None, DriveMode::Optimized).0
}

/// [`simulate_closed_loop`] on the reference dispatch loop (test oracle).
pub fn simulate_closed_loop_reference(
    pool: &mut ShardPool,
    clients: &ClosedLoopConfig,
    cfg: &SimConfig,
) -> FleetReport {
    drive(pool, Arrivals::closed(clients.clone()), cfg, None, DriveMode::Reference).0
}

/// Closed-loop clients plus autoscaling: the full feedback system — load
/// adapts to capacity while capacity adapts to load.
pub fn simulate_closed_loop_autoscaled(
    pool: &mut ShardPool,
    clients: &ClosedLoopConfig,
    cfg: &SimConfig,
    auto: &mut Autoscaler,
    factory: &mut dyn FnMut(usize) -> Box<dyn Backend>,
) -> FleetReport {
    drive(
        pool,
        Arrivals::closed(clients.clone()),
        cfg,
        Some(ScalingCtx { auto, provisioner: Provisioner::Factory(factory) }),
        DriveMode::Optimized,
    )
    .0
}

/// Closed-loop clients plus heterogeneous autoscaling.
pub fn simulate_closed_loop_autoscaled_hetero(
    pool: &mut ShardPool,
    clients: &ClosedLoopConfig,
    cfg: &SimConfig,
    auto: &mut Autoscaler,
    catalog: &DeviceCatalog,
) -> FleetReport {
    check_catalog(catalog, cfg);
    drive(
        pool,
        Arrivals::closed(clients.clone()),
        cfg,
        Some(ScalingCtx { auto, provisioner: Provisioner::Catalog(catalog) }),
        DriveMode::Optimized,
    )
    .0
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::baselines::Platform;
    use crate::serving::autoscale::{AutoscaleConfig, SloTracking, TargetUtilization};
    use crate::serving::device::BaselineDevice;

    /// A deterministic synthetic device: 5 ms overhead + 5 ms/frame.
    fn test_device() -> BaselineDevice {
        let p = Platform { name: "test-dev", overhead_s: 5e-3, sustained_gops: 100.0, power_w: 10.0 };
        BaselineDevice::new(p, 0.5, 16)
    }

    fn one_device_pool() -> ShardPool {
        let mut pool = ShardPool::new();
        pool.register(Box::new(test_device()));
        pool
    }

    #[test]
    fn poisson_trace_hits_rate_and_is_deterministic() {
        let a = poisson_trace(200.0, 10.0, 7);
        let b = poisson_trace(200.0, 10.0, 7);
        assert_eq!(a.len(), b.len());
        assert!((a[5].arrival_s - b[5].arrival_s).abs() < 1e-15);
        // 2000 expected arrivals; 3σ ≈ 134.
        assert!((a.len() as f64 - 2000.0).abs() < 150.0, "{} arrivals", a.len());
        assert!(a.windows(2).all(|w| w[0].arrival_s <= w[1].arrival_s));
    }

    #[test]
    fn multi_camera_trace_is_sorted_bursty_and_seeded() {
        let scene = SceneConfig::default();
        let a = multi_camera_trace(&scene, 8, 30.0, 5.0, 11);
        let b = multi_camera_trace(&scene, 8, 30.0, 5.0, 11);
        assert_eq!(a.len(), b.len());
        assert!(a.windows(2).all(|w| w[0].arrival_s <= w[1].arrival_s));
        // Nominal 8×30×5 = 1200 frames, plus bursts.
        assert!(a.len() > 1200, "{} frames", a.len());
        assert!(a.iter().all(|r| r.arrival_s < 5.0));
        assert!(a.iter().any(|r| r.camera == 7));
        // Ids are the post-sort positions.
        assert!(a.iter().enumerate().all(|(i, r)| r.id == i as u64));
    }

    #[test]
    fn degenerate_object_range_never_bursts() {
        // min == max: every frame sits exactly on the midpoint, so no
        // frame is "busy" and the trace is the nominal rate, not 2×.
        let scene = SceneConfig { min_objects: 2, max_objects: 2, ..Default::default() };
        let a = multi_camera_trace(&scene, 4, 20.0, 5.0, 3);
        let nominal = 4.0 * 20.0 * 5.0;
        assert!(
            (a.len() as f64) <= nominal * 1.05,
            "{} frames for nominal {nominal}",
            a.len()
        );
    }

    /// The batcher's core trade-off, measured end to end: at saturating
    /// load, batching lifts throughput; at light load, waiting for a
    /// batch costs latency.
    #[test]
    fn batching_trades_latency_for_throughput() {
        // Saturating: 10 ms/request unbatched → capacity 100/s; offer 180/s.
        let trace = poisson_trace(180.0, 8.0, 42);
        let base = SimConfig {
            queue_depth: 16,
            shed: ShedPolicy::RejectNewest,
            work_stealing: false,
            slo_s: 0.25,
            ..Default::default()
        };
        let unbatched = SimConfig { batch: BatchPolicy::unbatched(), ..base.clone() };
        let batched =
            SimConfig { batch: BatchPolicy::new(8, 0.020), ..base.clone() };
        let r1 = simulate(&mut one_device_pool(), &trace, &unbatched);
        let r8 = simulate(&mut one_device_pool(), &trace, &batched);
        assert!(
            r8.throughput_fps() > 1.5 * r1.throughput_fps(),
            "batched {:.0} fps !> 1.5× unbatched {:.0} fps",
            r8.throughput_fps(),
            r1.throughput_fps()
        );
        assert!(r8.shed < r1.shed, "batching should shed less: {} vs {}", r8.shed, r1.shed);

        // Light load: 20/s on a 100/s device — batching only adds waiting.
        let light = poisson_trace(20.0, 8.0, 43);
        let r1l = simulate(&mut one_device_pool(), &light, &unbatched);
        let r8l = simulate(
            &mut one_device_pool(),
            &light,
            &SimConfig { batch: BatchPolicy::new(8, 0.050), ..base.clone() },
        );
        assert!(
            r8l.p50_s > r1l.p50_s,
            "waiting for batches must raise median latency: {} !> {}",
            r8l.p50_s,
            r1l.p50_s
        );
    }

    /// Work stealing rescues a skewed backlog: preload one device's
    /// queue, leave its sibling idle.
    #[test]
    fn work_stealing_balances_skewed_load() {
        let skewed_pool = || {
            let mut pool = ShardPool::new();
            pool.register(Box::new(test_device()));
            pool.register(Box::new(test_device()));
            for i in 0..40 {
                pool.devices[0]
                    .queue
                    .push_back(Request {
                        id: i,
                        camera: 0,
                        arrival_s: 0.0,
                        objects: 1,
                        class: SloClass::Standard,
                        rung: 0,
                        retries: 0,
                    });
            }
            pool
        };
        let cfg = SimConfig {
            batch: BatchPolicy::new(4, 0.005),
            work_stealing: true,
            ..Default::default()
        };
        let no_steal = SimConfig { work_stealing: false, ..cfg.clone() };

        let mut p = skewed_pool();
        let stolen = simulate(&mut p, &[], &cfg);
        let mut p = skewed_pool();
        let idle = simulate(&mut p, &[], &no_steal);

        assert_eq!(stolen.completed, 40);
        assert_eq!(idle.completed, 40);
        let thief = &stolen.devices[1];
        assert!(thief.stolen > 0, "idle sibling must steal");
        assert!(thief.completed > 0, "and serve what it stole");
        assert!(
            stolen.makespan_s < 0.75 * idle.makespan_s,
            "stealing must cut the drain time: {} !< 0.75×{}",
            stolen.makespan_s,
            idle.makespan_s
        );
        assert!(stolen.max_s < idle.max_s, "tail latency improves too");
    }

    #[test]
    fn overload_sheds_and_violates_slo() {
        // 5× overload on a shallow queue.
        let trace = poisson_trace(500.0, 4.0, 9);
        let cfg = SimConfig {
            batch: BatchPolicy::unbatched(),
            queue_depth: 4,
            shed: ShedPolicy::DropOldest,
            slo_s: 0.015,
            work_stealing: false,
            ..Default::default()
        };
        let r = simulate(&mut one_device_pool(), &trace, &cfg);
        assert!(r.shed > 0, "overload must shed");
        assert!(r.completed > 0);
        assert!(r.slo_violations > 0);
        assert!(r.slo_attainment() < 1.0);
        // Bounded queue + drop-oldest keeps the served tail bounded:
        // worst case ≈ (queue_depth+1) × service time, far below open-loop.
        assert!(r.max_s < 0.2, "drop-oldest must bound latency, got {}", r.max_s);
    }

    #[test]
    fn simulation_is_deterministic() {
        let scene = SceneConfig::default();
        let mk = || {
            let mut pool = ShardPool::new();
            pool.register(Box::new(test_device()));
            pool.register(Box::new(test_device()));
            pool
        };
        let trace = multi_camera_trace(&scene, 6, 25.0, 4.0, 5);
        let cfg = SimConfig::default();
        let a = simulate(&mut mk(), &trace, &cfg);
        let b = simulate(&mut mk(), &trace, &cfg);
        assert_eq!(a.completed, b.completed);
        assert_eq!(a.shed, b.shed);
        assert!((a.p99_s - b.p99_s).abs() < 1e-15);
        assert!((a.makespan_s - b.makespan_s).abs() < 1e-15);
    }

    #[test]
    fn all_requests_accounted_for() {
        let trace = poisson_trace(150.0, 3.0, 21);
        let cfg = SimConfig { queue_depth: 8, ..Default::default() };
        let r = simulate(&mut one_device_pool(), &trace, &cfg);
        assert_eq!(r.completed + r.shed, trace.len() as u64);
        assert_eq!(r.offered, trace.len() as u64);
        let per_dev: u64 = r.devices.iter().map(|d| d.completed).sum();
        assert_eq!(per_dev, r.completed);
    }

    #[test]
    fn logged_outcomes_cover_every_request_in_id_order() {
        let trace = poisson_trace(300.0, 2.0, 21);
        let cfg = SimConfig {
            queue_depth: 4,
            shed: ShedPolicy::DropOldest,
            work_stealing: false,
            ..Default::default()
        };
        let (r, outcomes) = simulate_logged(&mut one_device_pool(), &trace, &cfg);
        assert_eq!(outcomes.len(), trace.len());
        assert!(outcomes.iter().enumerate().all(|(i, o)| o.id == i as u64));
        let shed = outcomes.iter().filter(|o| o.shed).count() as u64;
        assert_eq!(shed, r.shed, "outcome log agrees with the report");
        assert_eq!(outcomes.len() as u64 - shed, r.completed);
        // Completion times are causal: never before the arrival.
        for (o, req) in outcomes.iter().zip(&trace) {
            assert!(o.t_s + 1e-12 >= req.arrival_s);
            assert_eq!(o.camera, req.camera);
        }
    }

    // ---- autoscaling ----

    fn grow_setup() -> (Vec<Request>, SimConfig) {
        // 3× overload on one 100/s device for 8 s.
        let trace = poisson_trace(300.0, 8.0, 17);
        let cfg = SimConfig {
            batch: BatchPolicy::unbatched(),
            queue_depth: 16,
            shed: ShedPolicy::DropOldest,
            slo_s: 0.500,
            work_stealing: true,
            ..Default::default()
        };
        (trace, cfg)
    }

    fn util_autoscaler(max: usize) -> Autoscaler {
        Autoscaler::new(
            AutoscaleConfig {
                epoch_s: 0.25,
                provision_delay_s: 0.4,
                min_devices: 1,
                max_devices: max,
                cooldown_epochs: 0,
                ..Default::default()
            },
            Box::new(TargetUtilization::default()),
        )
    }

    #[test]
    fn autoscaler_grows_under_overload_and_sheds_less() {
        let (trace, cfg) = grow_setup();
        let fixed = simulate(&mut one_device_pool(), &trace, &cfg);
        assert!(fixed.shed > 0, "fixed pool must shed at 3× overload");

        let mut auto = util_autoscaler(6);
        let mut factory =
            |_i: usize| -> Box<dyn Backend> { Box::new(test_device()) };
        let r = simulate_autoscaled(&mut one_device_pool(), &trace, &cfg, &mut auto, &mut factory);

        assert_eq!(r.offered, r.completed + r.shed, "conservation with autoscaling");
        assert!(r.shed < fixed.shed / 2, "autoscaled shed {} !< {}/2", r.shed, fixed.shed);
        assert!(r.completed > fixed.completed);
        assert!(r.devices_peak > r.devices_start, "pool must actually grow");
        assert!(r.devices_peak <= 6);
        assert!(
            r.scaling.iter().any(|e| matches!(e.kind, ScaleEventKind::Provisioning { .. })),
            "scaling events must be recorded"
        );
        assert!(
            r.scaling.iter().any(|e| matches!(e.kind, ScaleEventKind::Activated { .. })),
            "provisioned devices must activate"
        );
        assert!(r.p99_s <= cfg.slo_s, "grown pool holds p99 {} under SLO", r.p99_s);
    }

    #[test]
    fn autoscaler_drains_and_retires_when_load_drops() {
        // 2.5 s of 3× overload, then 6 s of light load: the pool must
        // grow, then drain back down, conserving every request.
        let mut trace = poisson_trace(300.0, 2.5, 5);
        for mut r in poisson_trace(20.0, 6.0, 6) {
            r.arrival_s += 2.5;
            r.id += 10_000_000; // keep ids unique across the two segments
            trace.push(r);
        }
        let cfg = SimConfig {
            batch: BatchPolicy::unbatched(),
            queue_depth: 16,
            shed: ShedPolicy::DropOldest,
            slo_s: 0.500,
            work_stealing: true,
            ..Default::default()
        };
        let mut auto = util_autoscaler(6);
        let mut factory =
            |_i: usize| -> Box<dyn Backend> { Box::new(test_device()) };
        let r = simulate_autoscaled(&mut one_device_pool(), &trace, &cfg, &mut auto, &mut factory);

        assert_eq!(r.offered, r.completed + r.shed);
        assert!(r.devices_peak > 1);
        assert!(
            r.scaling.iter().any(|e| matches!(e.kind, ScaleEventKind::DrainStarted { .. })),
            "idle capacity must start draining"
        );
        assert!(
            r.scaling.iter().any(|e| matches!(e.kind, ScaleEventKind::Retired { .. })),
            "drained devices must retire"
        );
        assert!(r.devices_final < r.devices_peak, "pool must shrink back");
        assert!(r.devices.iter().any(|d| d.state == "retired"));
    }

    #[test]
    fn autoscaled_run_is_deterministic() {
        let (trace, cfg) = grow_setup();
        let run = || {
            let mut auto = Autoscaler::new(
                AutoscaleConfig {
                    epoch_s: 0.25,
                    provision_delay_s: 0.4,
                    min_devices: 1,
                    max_devices: 5,
                    cooldown_epochs: 1,
                    ..Default::default()
                },
                Box::new(SloTracking::new(0.100)),
            );
            let mut factory =
                |_i: usize| -> Box<dyn Backend> { Box::new(test_device()) };
            simulate_autoscaled(&mut one_device_pool(), &trace, &cfg, &mut auto, &mut factory)
        };
        let a = run();
        let b = run();
        assert_eq!(format!("{a:?}"), format!("{b:?}"));
        assert!(!a.scaling.is_empty());
    }

    // ---- closed loop ----

    #[test]
    fn closed_loop_adapts_to_capacity_and_conserves() {
        // 8 cameras × window 2 on one 100/s device: a 30 FPS open-loop
        // fleet would need 240/s; the closed loop self-paces instead.
        let cl = ClosedLoopConfig {
            cameras: 8,
            max_outstanding: 2,
            period_s: 1.0 / 30.0,
            think_s: 0.002,
            horizon_s: 6.0,
            seed: 9,
            ..Default::default()
        };
        let cfg = SimConfig {
            batch: BatchPolicy::new(4, 0.010),
            queue_depth: 64,
            shed: ShedPolicy::DropOldest,
            slo_s: 0.250,
            work_stealing: false,
            ..Default::default()
        };
        let r = simulate_closed_loop(&mut one_device_pool(), &cl, &cfg);
        assert_eq!(r.offered, r.completed + r.shed, "closed-loop conservation");
        assert!(r.completed > 0);
        // The in-system population is capped at cameras × K = 16, well
        // under the 64-deep queue: the closed loop can never shed.
        assert_eq!(r.shed, 0, "window cap must prevent shedding");
        // Offered load adapted: far below the open-loop 240/s × 6 s.
        assert!(r.offered < 240 * 6, "offered {} should self-pace", r.offered);
        // But the device stayed saturated: roughly its capacity served.
        assert!(r.throughput_fps() > 50.0, "throughput {}", r.throughput_fps());
    }

    #[test]
    fn closed_loop_is_deterministic() {
        let cl = ClosedLoopConfig { cameras: 4, horizon_s: 3.0, seed: 31, ..Default::default() };
        let cfg = SimConfig::default();
        let a = simulate_closed_loop(&mut one_device_pool(), &cl, &cfg);
        let b = simulate_closed_loop(&mut one_device_pool(), &cl, &cfg);
        assert_eq!(format!("{a:?}"), format!("{b:?}"));
    }

    // ---- SLO classes ----

    #[test]
    fn classes_flow_from_trace_to_report() {
        let scene = SceneConfig::default();
        let mut trace = multi_camera_trace(&scene, 6, 20.0, 3.0, 13);
        crate::serving::assign_slo_classes(&mut trace);
        let cfg = SimConfig { shed: ShedPolicy::ClassAware, ..Default::default() };
        let r = simulate(&mut one_device_pool(), &trace, &cfg);
        // Per-class conservation and coverage: every class saw traffic
        // (6 cameras cycle the 3 classes) and offered splits exactly.
        let mut offered = 0;
        for c in &r.classes {
            assert_eq!(c.offered, c.completed + c.shed, "{:?}", c.class);
            assert!(c.offered > 0, "{:?} saw no traffic", c.class);
            offered += c.offered;
        }
        assert_eq!(offered, r.offered);
        let per_class_completed: u64 = r.classes.iter().map(|c| c.completed).sum();
        assert_eq!(per_class_completed, r.completed);
        // Class SLOs scale off the fleet SLO.
        assert!((r.classes[0].slo_s - 0.5 * cfg.slo_s).abs() < 1e-15);
        assert!((r.classes[2].slo_s - 2.0 * cfg.slo_s).abs() < 1e-15);
    }

    #[test]
    fn unclassed_runs_report_all_traffic_as_standard() {
        let trace = poisson_trace(100.0, 2.0, 3);
        let r = simulate(&mut one_device_pool(), &trace, &SimConfig::default());
        assert_eq!(r.classes[SloClass::Standard.index()].offered, r.offered);
        assert_eq!(r.classes[SloClass::Interactive.index()].offered, 0);
        assert_eq!(r.classes[SloClass::Batchable.index()].offered, 0);
        assert_eq!(r.classes[SloClass::Interactive.index()].attainment(), 1.0);
    }

    // ---- energy ledger ----

    #[test]
    fn ledger_accrues_makespan_energy_for_a_fixed_pool() {
        let trace = poisson_trace(80.0, 2.0, 5);
        let cfg = SimConfig::default();
        let r = simulate(&mut one_device_pool(), &trace, &cfg);
        let e = &r.energy;
        // One 10 W device (BaselineDevice power is load-independent)
        // over the whole run: total energy == 10 W × final virtual time,
        // which is at least the makespan.
        assert!(e.total_j() >= 10.0 * r.makespan_s - 1e-9, "{} vs {}", e.total_j(), r.makespan_s);
        assert!(e.epochs.iter().all(|b| {
            b.provisioning_j >= 0.0 && b.active_j >= 0.0 && b.draining_j >= 0.0
        }));
        // Fixed pool: all energy is active-state energy.
        assert_eq!(e.provisioning_j(), 0.0);
        assert_eq!(e.draining_j(), 0.0);
        let per_dev: f64 = e.per_device_j.iter().sum();
        assert!((e.total_j() - per_dev).abs() < 1e-9 * e.total_j().max(1.0));
        // Served arithmetic: completed × the device's 0.5 GOP per frame.
        assert!((e.served_gop - 0.5 * r.completed as f64).abs() < 1e-9);
        assert!(e.fleet_gops_per_w() > 0.0);
    }

    #[test]
    fn ledger_splits_states_under_autoscaling() {
        let (trace, cfg) = grow_setup();
        let mut auto = util_autoscaler(6);
        let mut factory = |_i: usize| -> Box<dyn Backend> { Box::new(test_device()) };
        let r = simulate_autoscaled(&mut one_device_pool(), &trace, &cfg, &mut auto, &mut factory);
        assert!(r.devices_peak > 1);
        let e = &r.energy;
        // Warm-ups and (if any scale-in happened) drains burn joules in
        // their own columns.
        assert!(e.provisioning_j() > 0.0, "provisioning energy must be visible");
        assert!(e.total_j() > e.provisioning_j());
        assert_eq!(e.per_device_j.len(), r.devices.len());
    }

    // ---- heterogeneous autoscaling ----

    /// A catalog of two synthetic kinds: a cheap slow device and a fast
    /// hot one, both comfortably under the SLO.
    fn synth_catalog() -> DeviceCatalog {
        let mut cat = DeviceCatalog::new(1);
        // "small": 50 fps at 6 W.
        let small = Platform { name: "small", overhead_s: 0.0, sustained_gops: 5.0, power_w: 6.0 };
        cat.register(
            "small",
            Box::new(move |_| Box::new(BaselineDevice::new(small.clone(), 0.1, 1))),
        );
        // "big": 200 fps at 20 W.
        let big = Platform { name: "big", overhead_s: 0.0, sustained_gops: 20.0, power_w: 20.0 };
        cat.register(
            "big",
            Box::new(move |_| Box::new(BaselineDevice::new(big.clone(), 0.1, 1))),
        );
        cat
    }

    #[test]
    fn hetero_autoscaler_scales_out_with_the_cheapest_sufficient_device() {
        // One 100 fps device offered 130 fps: a ~30 fps deficit, which
        // the 50 fps / 6 W catalog entry covers — the 20 W entry would
        // be a waste of joules.
        let trace = poisson_trace(130.0, 8.0, 77);
        let cfg = SimConfig {
            batch: BatchPolicy::unbatched(),
            queue_depth: 16,
            shed: ShedPolicy::DropOldest,
            slo_s: 0.500,
            work_stealing: true,
            ..Default::default()
        };
        let mut auto = Autoscaler::new(
            AutoscaleConfig {
                epoch_s: 0.25,
                provision_delay_s: 0.3,
                min_devices: 1,
                max_devices: 6,
                cooldown_epochs: 0,
                drain_order: DrainOrder::MostExpensiveFirst,
            },
            Box::new(TargetUtilization::default()),
        );
        let catalog = synth_catalog();
        let r = simulate_autoscaled_hetero(&mut one_device_pool(), &trace, &cfg, &mut auto, &catalog);
        assert_eq!(r.offered, r.completed + r.shed, "conservation with hetero autoscaling");
        assert!(r.devices_peak > 1, "the pool must grow");
        // Every provisioned device is the cheap kind: the deficit never
        // exceeded the small entry's capacity.
        let provisioned: Vec<&str> =
            r.devices.iter().skip(1).map(|d| d.name.as_ref()).collect();
        assert!(!provisioned.is_empty());
        assert!(
            provisioned.iter().all(|n| *n == "small"),
            "expected only cheap devices, got {provisioned:?}"
        );
    }

    #[test]
    fn hetero_runs_are_deterministic() {
        let (trace, cfg) = grow_setup();
        let run = || {
            let mut auto = Autoscaler::new(
                AutoscaleConfig {
                    epoch_s: 0.25,
                    provision_delay_s: 0.4,
                    min_devices: 1,
                    max_devices: 5,
                    cooldown_epochs: 0,
                    drain_order: DrainOrder::MostExpensiveFirst,
                },
                Box::new(TargetUtilization::default()),
            );
            let catalog = synth_catalog();
            simulate_autoscaled_hetero(&mut one_device_pool(), &trace, &cfg, &mut auto, &catalog)
        };
        let a = run();
        let b = run();
        assert_eq!(format!("{a:?}"), format!("{b:?}"));
        assert!(!a.scaling.is_empty());
    }

    /// The optimized hot path is the same simulator as the frozen
    /// reference loop, byte for byte (the full cross-config sweep lives
    /// in `tests/fleet_scale.rs`; this is the in-crate smoke check).
    #[test]
    fn optimized_path_matches_reference_bytes() {
        let trace = poisson_trace(300.0, 6.0, 23);
        let cfg = SimConfig { queue_depth: 32, shed: ShedPolicy::DropOldest, ..Default::default() };
        let mk = || {
            let mut pool = ShardPool::new();
            for _ in 0..3 {
                pool.register(Box::new(test_device()));
            }
            pool
        };
        let opt = simulate(&mut mk(), &trace, &cfg);
        let reference = simulate_reference(&mut mk(), &trace, &cfg);
        assert_eq!(format!("{opt:?}"), format!("{reference:?}"));
    }

    /// One shard means nothing is split and nothing is merged:
    /// `simulate_parallel` degenerates to `simulate` exactly.
    #[test]
    fn parallel_one_shard_is_bitwise_simulate() {
        let scene = SceneConfig::default();
        let trace = multi_camera_trace(&scene, 8, 25.0, 4.0, 31);
        let cfg = SimConfig { queue_depth: 32, shed: ShedPolicy::DropOldest, ..Default::default() };
        let mk = || {
            let mut pool = ShardPool::new();
            for _ in 0..4 {
                pool.register(Box::new(test_device()));
            }
            pool
        };
        let serial = simulate(&mut mk(), &trace, &cfg);
        let par = simulate_parallel(mk(), &trace, &cfg, 1, 2);
        assert_eq!(format!("{serial:?}"), format!("{par:?}"));
    }
}
