//! Streaming fleet metrics: latency quantiles, throughput, per-device
//! utilization, SLO violations (fleet-wide and per [`SloClass`]), and
//! the fleet [`EnergyLedger`].
//!
//! Quantiles come from a log-spaced streaming histogram (constant memory,
//! one pass — the shape HDRHistogram uses) so the fleet can track p99
//! over millions of requests without retaining them; resolution is the
//! bin ratio (~4% relative error), which the tests verify against a
//! brute-force percentile. Per-device compute utilization reuses
//! [`crate::scheduler::TuningResult::utilization`] through
//! [`super::device::Backend::power_w`] rather than duplicating the
//! formula.
//!
//! The energy ledger is the fleet-level face of the paper's headline
//! metric (GOP/s/W, Table IV / Figure 8): the DES driver accrues
//! `power × time` per device into per-epoch bins split by lifecycle
//! state — provisioning (warm-up paid at idle power), active and
//! draining — and credits each completion with the frame's
//! giga-operations ([`super::device::Backend::gop_per_frame`]), so a
//! whole fleet's efficiency is `served GOP / total J`, the same
//! GOP-per-joule the paper reports for one board.

use super::autoscale::ScalingEvent;
use super::device::Backend;
use super::faults::{FaultReport, FaultStats};
use super::shard::Lifecycle;
use super::SloClass;

/// Streaming latency histogram with log-spaced bins.
#[derive(Debug, Clone)]
pub struct LatencyHistogram {
    /// Lower edge of bin 0, seconds.
    lo: f64,
    /// Geometric bin width (upper/lower edge ratio).
    ratio: f64,
    bins: Vec<u64>,
    count: u64,
    sum_s: f64,
    min_s: f64,
    max_s: f64,
}

impl LatencyHistogram {
    /// 512 bins at 4% spacing: covers ~10 µs to ~5×10^3 s.
    pub fn new() -> Self {
        Self {
            lo: 1e-5,
            ratio: 1.04,
            bins: vec![0; 512],
            count: 0,
            sum_s: 0.0,
            min_s: f64::INFINITY,
            max_s: 0.0,
        }
    }

    fn index(&self, latency_s: f64) -> usize {
        if latency_s <= self.lo {
            return 0;
        }
        let idx = ((latency_s / self.lo).ln() / self.ratio.ln()).floor() as usize;
        idx.min(self.bins.len() - 1)
    }

    pub fn record(&mut self, latency_s: f64) {
        let i = self.index(latency_s);
        self.record_at(i, latency_s);
    }

    /// Record with a pre-computed bin index. Every histogram in the
    /// fleet shares one geometry (`lo`, `ratio`, 512 bins), so the
    /// batched fold computes `index()` — two `ln()` calls — once per
    /// sample and feeds the same index to the fleet, epoch and class
    /// histograms. Bit-identical to [`LatencyHistogram::record`]: the
    /// per-field arithmetic is the same, in the same order.
    fn record_at(&mut self, i: usize, latency_s: f64) {
        self.bins[i] += 1;
        self.count += 1;
        self.sum_s += latency_s;
        self.min_s = self.min_s.min(latency_s);
        self.max_s = self.max_s.max(latency_s);
    }

    /// Zero every accumulator in place, keeping the bin allocation (the
    /// epoch window resets once per autoscaler epoch; reallocating 512
    /// bins each time is pure churn). Equivalent to `*self = new()`.
    pub fn reset(&mut self) {
        self.bins.fill(0);
        self.count = 0;
        self.sum_s = 0.0;
        self.min_s = f64::INFINITY;
        self.max_s = 0.0;
    }

    /// Fold another histogram of the same geometry into this one (the
    /// parallel DES merges per-shard histograms in fixed shard order,
    /// so the merged `sum_s` is deterministic).
    pub fn merge(&mut self, other: &LatencyHistogram) {
        assert!(
            self.lo == other.lo && self.ratio == other.ratio && self.bins.len() == other.bins.len(),
            "histogram geometries differ"
        );
        for (a, b) in self.bins.iter_mut().zip(&other.bins) {
            *a += b;
        }
        self.count += other.count;
        self.sum_s += other.sum_s;
        self.min_s = self.min_s.min(other.min_s);
        self.max_s = self.max_s.max(other.max_s);
    }

    pub fn count(&self) -> u64 {
        self.count
    }

    pub fn mean_s(&self) -> f64 {
        if self.count == 0 {
            0.0
        } else {
            self.sum_s / self.count as f64
        }
    }

    pub fn max_s(&self) -> f64 {
        self.max_s
    }

    /// The `q`-quantile (`q` in `[0, 1]`), to bin resolution. Returns the
    /// geometric midpoint of the bin holding the target rank, clamped to
    /// the observed min/max so tiny samples stay sensible.
    pub fn quantile(&self, q: f64) -> f64 {
        if self.count == 0 {
            return 0.0;
        }
        let target = ((q.clamp(0.0, 1.0) * self.count as f64).ceil() as u64).max(1);
        let mut cum = 0u64;
        for (i, &n) in self.bins.iter().enumerate() {
            cum += n;
            if cum >= target {
                let mid = self.lo * self.ratio.powi(i as i32) * self.ratio.sqrt();
                return mid.clamp(self.min_s, self.max_s);
            }
        }
        self.max_s
    }
}

impl Default for LatencyHistogram {
    fn default() -> Self {
        Self::new()
    }
}

/// Energy accrued over one ledger epoch, split by device lifecycle
/// state (J).
#[derive(Debug, Clone, Default)]
pub struct EpochEnergy {
    /// Warm-up energy: devices provisioning during the epoch.
    pub provisioning_j: f64,
    /// Serving energy of active devices (busy and idle time both —
    /// static board power burns either way, which is why scale-in is an
    /// energy decision).
    pub active_j: f64,
    /// Energy of draining devices finishing their backlog.
    pub draining_j: f64,
}

impl EpochEnergy {
    pub fn total_j(&self) -> f64 {
        self.provisioning_j + self.active_j + self.draining_j
    }
}

/// The fleet-wide energy ledger: joules per epoch per device state, plus
/// the served arithmetic volume, accrued exactly by the DES driver
/// (power is piecewise-constant between events).
#[derive(Debug, Clone)]
pub struct EnergyLedger {
    /// Ledger bin width, virtual s ([`crate::serving::SimConfig`]'s
    /// `energy_epoch_s`).
    pub epoch_s: f64,
    /// Energy per epoch bin (bin `i` covers `[i·epoch_s, (i+1)·epoch_s)`).
    pub epochs: Vec<EpochEnergy>,
    /// Total energy per device slot (same indices as the device reports;
    /// sums to the same total as `epochs`).
    pub per_device_j: Vec<f64>,
    /// Giga-operations of every completed frame.
    pub served_gop: f64,
}

impl EnergyLedger {
    /// Minimum bin width: bins are allocated densely over the whole run
    /// (`horizon / epoch_s` of them), so sub-millisecond widths would
    /// let one long trace exhaust memory.
    pub const MIN_EPOCH_S: f64 = 1e-3;

    pub fn new(epoch_s: f64) -> Self {
        assert!(
            epoch_s >= Self::MIN_EPOCH_S,
            "ledger epoch must be at least {} s (got {epoch_s})",
            Self::MIN_EPOCH_S
        );
        Self { epoch_s, epochs: Vec::new(), per_device_j: Vec::new(), served_gop: 0.0 }
    }

    /// A zero ledger (what [`FleetMetrics::report`] defaults to; the DES
    /// driver replaces it with the accrued one).
    pub fn empty() -> Self {
        Self { epoch_s: 0.0, epochs: Vec::new(), per_device_j: Vec::new(), served_gop: 0.0 }
    }

    /// Accrue `power_w` over `[from_s, to_s)` for `device` in lifecycle
    /// `state`, split across epoch bins. Retired and failed devices draw
    /// nothing (a crashed board is powered off until its reboot
    /// re-provisions it).
    pub(super) fn accrue(
        &mut self,
        device: usize,
        state: Lifecycle,
        from_s: f64,
        to_s: f64,
        power_w: f64,
    ) {
        if matches!(state, Lifecycle::Retired | Lifecycle::Failed) || to_s <= from_s {
            return;
        }
        while self.per_device_j.len() <= device {
            self.per_device_j.push(0.0);
        }
        let mut t = from_s;
        let mut bin = (t / self.epoch_s).floor() as usize;
        loop {
            let seg_end = ((bin + 1) as f64 * self.epoch_s).min(to_s);
            if seg_end <= t {
                // Floating-point bin edge: `fl((bin+1)·epoch_s)` can
                // equal `t` while `t / epoch_s` still floors into
                // `bin` — step to the next bin instead of spinning on a
                // zero-length segment.
                bin += 1;
                continue;
            }
            let j = power_w * (seg_end - t);
            while self.epochs.len() <= bin {
                self.epochs.push(EpochEnergy::default());
            }
            match state {
                Lifecycle::Provisioning { .. } => self.epochs[bin].provisioning_j += j,
                Lifecycle::Active => self.epochs[bin].active_j += j,
                Lifecycle::Draining => self.epochs[bin].draining_j += j,
                Lifecycle::Retired | Lifecycle::Failed => unreachable!("filtered above"),
            }
            self.per_device_j[device] += j;
            if seg_end >= to_s {
                break;
            }
            t = seg_end;
            bin += 1;
        }
    }

    /// Total fleet energy over the run (sum of the epoch bins).
    pub fn total_j(&self) -> f64 {
        self.epochs.iter().map(EpochEnergy::total_j).sum()
    }

    pub fn provisioning_j(&self) -> f64 {
        self.epochs.iter().map(|e| e.provisioning_j).sum()
    }

    pub fn active_j(&self) -> f64 {
        self.epochs.iter().map(|e| e.active_j).sum()
    }

    pub fn draining_j(&self) -> f64 {
        self.epochs.iter().map(|e| e.draining_j).sum()
    }

    /// The paper's efficiency metric at fleet scope: served GOP per
    /// joule (numerically GOP/s/W). Zero when nothing was accrued.
    pub fn fleet_gops_per_w(&self) -> f64 {
        let j = self.total_j();
        if j <= 0.0 {
            0.0
        } else {
            self.served_gop / j
        }
    }

    /// Merge another shard's ledger into this one (parallel DES merge,
    /// fixed shard order): epoch bins add elementwise, the other
    /// shard's device rows append after this one's.
    pub(super) fn absorb(&mut self, other: EnergyLedger) {
        assert!(
            self.epoch_s == other.epoch_s,
            "ledger epochs differ: {} vs {}",
            self.epoch_s,
            other.epoch_s
        );
        if self.epochs.len() < other.epochs.len() {
            self.epochs.resize(other.epochs.len(), EpochEnergy::default());
        }
        for (a, b) in self.epochs.iter_mut().zip(&other.epochs) {
            a.provisioning_j += b.provisioning_j;
            a.active_j += b.active_j;
            a.draining_j += b.draining_j;
        }
        self.per_device_j.extend(other.per_device_j);
        self.served_gop += other.served_gop;
    }
}

/// Final per-class figures: the latency quantiles and the class-scaled
/// SLO verdicts for one [`SloClass`]'s traffic.
#[derive(Debug, Clone)]
pub struct ClassReport {
    pub class: SloClass,
    /// Requests of this class offered to the front door.
    pub offered: u64,
    pub completed: u64,
    pub shed: u64,
    /// The subset of `shed` turned away by an admission quota
    /// ([`crate::serving::admission::AdmissionPolicy::ClassQuota`])
    /// before reaching any queue — zero under
    /// [`AdmissionPolicy::Open`](crate::serving::admission::AdmissionPolicy::Open).
    pub quota_shed: u64,
    pub p50_s: f64,
    pub p95_s: f64,
    pub p99_s: f64,
    pub mean_s: f64,
    pub max_s: f64,
    /// The class-scaled objective (fleet SLO × [`SloClass::slo_factor`]).
    pub slo_s: f64,
    /// Completions that exceeded the class-scaled objective.
    pub violations: u64,
}

impl ClassReport {
    /// Fraction of offered requests of this class that met the class
    /// SLO (sheds count as violations). 1.0 when the class saw no
    /// traffic.
    pub fn attainment(&self) -> f64 {
        let offered = self.completed + self.shed;
        if offered == 0 {
            return 1.0;
        }
        (self.completed - self.violations) as f64 / offered as f64
    }
}

/// Final per-device figures.
#[derive(Debug, Clone)]
pub struct DeviceReport {
    pub name: String,
    /// Lifecycle state at the end of the run ("active" for fixed pools).
    pub state: &'static str,
    pub completed: u64,
    pub batches: u64,
    /// Mean closed-batch size.
    pub mean_batch: f64,
    /// Fraction of the makespan the device was serving.
    pub busy_frac: f64,
    /// Average board power at that busy fraction, W.
    pub power_w: f64,
    /// Requests this device pulled from a sibling's queue.
    pub stolen: u64,
}

/// Accuracy over one scenario regime's frames (a segment of the
/// scenario's timeline: "night", "rush-hour", …).
#[derive(Debug, Clone)]
pub struct RegimeReport {
    pub name: String,
    /// Frames whose arrival fell inside this regime.
    pub offered: u64,
    pub completed: u64,
    pub shed: u64,
    /// mAP@0.5 over this regime's frames (shed frames contribute their
    /// ground truth but no detections).
    pub map: f64,
}

/// Fleet-level accuracy of one scenario run: what the shed rate *cost*,
/// measured against exact synthetic ground truth. Attached to a
/// [`FleetReport`] by the scenario pipeline
/// ([`crate::scenario::run_scenario_des`] and friends); plain serving
/// runs leave it `None`.
#[derive(Debug, Clone)]
pub struct ScenarioReport {
    pub name: String,
    pub cameras: usize,
    /// Frames the scenario emitted (== the fleet's offered requests).
    pub frames_offered: u64,
    pub frames_completed: u64,
    pub frames_shed: u64,
    /// mAP@0.5 of the *served* pipeline: shed frames keep their ground
    /// truth but produce no detections, so shedding directly costs mAP.
    pub map: f64,
    /// mAP@0.5 of the detector run offline on every frame — the accuracy
    /// ceiling; `map == offline_map` exactly when nothing sheds.
    pub offline_map: f64,
    /// Fraction of ground-truth object-frames covered by a track within
    /// the gate (1.0 = every object tracked through every frame).
    pub continuity: f64,
    /// Track-identity switches per ground-truth object (0.0 = every
    /// object kept one id for its whole life).
    pub fragmentation: f64,
    /// Mean |GM-PHD cardinality − true object count| over frames.
    pub cardinality_mae: f64,
    /// Per-regime accuracy breakdown, in the scenario's segment order.
    pub regimes: Vec<RegimeReport>,
}

/// Completions served at one rung of a
/// [`VariantLadder`](super::ladder::VariantLadder) (fleet-wide).
#[derive(Debug, Clone, PartialEq)]
pub struct VariantServe {
    /// The rung's display name (`full`, `pruned-40`, …).
    pub name: String,
    /// Requests completed at this rung.
    pub served: u64,
    /// The rung's nominal standalone mAP (reporting context; scenario
    /// runs carry the *measured* figure in [`ScenarioReport::map`]).
    pub map: f64,
}

/// Fleet-level summary of one simulated run.
#[derive(Debug, Clone)]
pub struct FleetReport {
    /// Requests offered to the front door (every one either completes,
    /// is shed, or — under an active fault plan — expires its retry
    /// budget: `offered == completed + shed + faults.expired`, the
    /// conservation law the property tests pin down).
    pub offered: u64,
    pub completed: u64,
    pub shed: u64,
    /// Time from first arrival to last completion, s.
    pub makespan_s: f64,
    pub p50_s: f64,
    pub p95_s: f64,
    pub p99_s: f64,
    pub mean_s: f64,
    pub max_s: f64,
    /// The latency objective requests were judged against, s.
    pub slo_s: f64,
    /// Completed requests whose end-to-end latency exceeded the SLO.
    pub slo_violations: u64,
    /// Serving devices at the start of the run.
    pub devices_start: usize,
    /// Peak concurrently-*active* devices over the run (draining
    /// remnants excluded, so the autoscaler's max-devices clamp bounds
    /// this exactly).
    pub devices_peak: usize,
    /// Serving devices at the end of the run.
    pub devices_final: usize,
    /// Autoscaler actions in time order (empty for fixed pools).
    pub scaling: Vec<ScalingEvent>,
    pub devices: Vec<DeviceReport>,
    /// Per-class latency/SLO breakdown, indexed like [`SloClass::ALL`].
    pub classes: Vec<ClassReport>,
    /// The fleet energy ledger (zero for reports built outside the DES
    /// driver).
    pub energy: EnergyLedger,
    /// Accuracy-in-the-loop results when the run was driven by the
    /// scenario pipeline; `None` for plain serving runs.
    pub scenario: Option<ScenarioReport>,
    /// Per-variant serve counts when the run used
    /// [`AdmissionPolicy::Degrade`](super::AdmissionPolicy::Degrade);
    /// empty otherwise.
    pub variants: Vec<VariantServe>,
    /// Fleet-level effective accuracy under the ladder's nominal
    /// operating points: `Σ served_k × map_k / offered` (a shed frame
    /// scores zero). `None` without a ladder.
    pub effective_accuracy: Option<f64>,
    /// Fault-injection and recovery accounting when the run carried a
    /// [`FaultPlan`](super::FaultPlan); `None` for fault-free runs.
    pub faults: Option<FaultReport>,
}

impl FleetReport {
    /// Aggregate served throughput, frames per second.
    pub fn throughput_fps(&self) -> f64 {
        if self.makespan_s <= 0.0 {
            0.0
        } else {
            self.completed as f64 / self.makespan_s
        }
    }

    /// Fraction of *offered* requests that met the SLO (shed requests
    /// count as violations — a shed frame never met its deadline).
    pub fn slo_attainment(&self) -> f64 {
        let offered = self.completed + self.shed;
        if offered == 0 {
            return 1.0;
        }
        (self.completed - self.slo_violations) as f64 / offered as f64
    }
}

/// Streaming accumulator the simulator feeds.
#[derive(Debug, Clone)]
pub(super) struct DeviceStats {
    pub busy_s: f64,
    pub completed: u64,
    pub batches: u64,
    pub stolen: u64,
}

/// Snapshot of one autoscaling epoch (what [`FleetMetrics::take_epoch`]
/// returns and resets).
#[derive(Debug, Clone, Copy)]
pub struct EpochStats {
    pub completed: u64,
    pub shed: u64,
    /// p99 of the epoch's completions, s (0 when none completed).
    pub p99_s: f64,
    /// Service time dispatched during the epoch, device-seconds.
    pub busy_s: f64,
}

/// Per-[`SloClass`] streaming stats.
#[derive(Debug)]
struct ClassStats {
    hist: LatencyHistogram,
    shed: u64,
    quota_shed: u64,
    violations: u64,
}

/// One buffered completion awaiting the epoch-boundary fold.
#[derive(Debug, Clone, Copy)]
struct PendingCompletion {
    device: u32,
    latency_s: f64,
    class: SloClass,
    rung: u8,
}

/// Cap on the pending-completion buffer: folds amortize the histogram
/// index math without letting the buffer grow with the trace.
const PENDING_CAP: usize = 65_536;

#[derive(Debug)]
pub struct FleetMetrics {
    pub(super) hist: LatencyHistogram,
    pub(super) shed: u64,
    pub(super) slo_s: f64,
    pub(super) slo_violations: u64,
    pub(super) per_device: Vec<DeviceStats>,
    /// Completions per ladder rung (index = rung; grows on demand, so a
    /// ladder-less run never allocates past rung 0).
    pub(super) variant_served: Vec<u64>,
    /// Per-class streams, indexed like [`SloClass::ALL`].
    per_class: Vec<ClassStats>,
    /// Rolling per-epoch window the autoscaler observes.
    epoch_hist: LatencyHistogram,
    epoch_shed: u64,
    epoch_busy_s: f64,
    /// Completions buffered by [`FleetMetrics::pend_completion`], folded
    /// in recording order by [`FleetMetrics::fold_pending`].
    pending: Vec<PendingCompletion>,
    /// Fault/recovery counters the drivers feed when a
    /// [`FaultPlan`](super::FaultPlan) is active (zero otherwise).
    pub faults: FaultStats,
}

impl FleetMetrics {
    pub fn new(n_devices: usize, slo_s: f64) -> Self {
        Self {
            hist: LatencyHistogram::new(),
            shed: 0,
            slo_s,
            slo_violations: 0,
            per_device: (0..n_devices)
                .map(|_| DeviceStats { busy_s: 0.0, completed: 0, batches: 0, stolen: 0 })
                .collect(),
            variant_served: Vec::new(),
            per_class: SloClass::ALL
                .iter()
                .map(|_| ClassStats {
                    hist: LatencyHistogram::new(),
                    shed: 0,
                    quota_shed: 0,
                    violations: 0,
                })
                .collect(),
            epoch_hist: LatencyHistogram::new(),
            epoch_shed: 0,
            epoch_busy_s: 0.0,
            pending: Vec::new(),
            faults: FaultStats::default(),
        }
    }

    /// Start tracking one more device (autoscaler provisioning).
    pub fn add_device(&mut self) {
        self.per_device.push(DeviceStats { busy_s: 0.0, completed: 0, batches: 0, stolen: 0 });
    }

    /// Record one completed request of `class` on `device`. The
    /// fleet-wide violation counter judges against the base SLO (as
    /// before classes existed); the per-class counter judges against the
    /// class-scaled SLO.
    pub fn record_completion(&mut self, device: usize, latency_s: f64, class: SloClass) {
        self.hist.record(latency_s);
        self.epoch_hist.record(latency_s);
        if latency_s > self.slo_s {
            self.slo_violations += 1;
        }
        let c = &mut self.per_class[class.index()];
        c.hist.record(latency_s);
        if latency_s > self.slo_s * class.slo_factor() {
            c.violations += 1;
        }
        self.per_device[device].completed += 1;
    }

    /// Record one closed batch (its service time busies the device).
    pub fn record_batch(&mut self, device: usize, service_s: f64) {
        self.per_device[device].batches += 1;
        self.per_device[device].busy_s += service_s;
        self.epoch_busy_s += service_s;
    }

    /// Record the ladder rung a completion was served at (rung 0 = the
    /// full model — also what every request reads without a ladder).
    pub fn record_variant(&mut self, rung: u8) {
        let i = rung as usize;
        if self.variant_served.len() <= i {
            self.variant_served.resize(i + 1, 0);
        }
        self.variant_served[i] += 1;
    }

    /// Buffer one completion (+ its variant rung) for the next fold —
    /// the optimized DES driver's batched equivalent of
    /// `record_completion` + `record_variant`. Folds itself once the
    /// buffer hits [`PENDING_CAP`], so memory stays bounded on
    /// million-request traces.
    pub fn pend_completion(&mut self, device: usize, latency_s: f64, class: SloClass, rung: u8) {
        self.pending.push(PendingCompletion { device: device as u32, latency_s, class, rung });
        if self.pending.len() >= PENDING_CAP {
            self.fold_pending();
        }
    }

    /// Replay the buffered completions, in recording order, into every
    /// accumulator `record_completion` + `record_variant` feed. The one
    /// optimization over the per-sample path: the log-spaced bin index
    /// is computed once per sample and shared by the fleet, epoch and
    /// class histograms (identical geometry ⇒ identical index), so the
    /// fold is bit-identical while paying a third of the `ln()` calls.
    pub fn fold_pending(&mut self) {
        // Swap the buffer out so `self` stays borrowable; swap it back
        // to keep the allocation.
        let mut pending = std::mem::take(&mut self.pending);
        for p in &pending {
            let latency_s = p.latency_s;
            let i = self.hist.index(latency_s);
            self.hist.record_at(i, latency_s);
            self.epoch_hist.record_at(i, latency_s);
            if latency_s > self.slo_s {
                self.slo_violations += 1;
            }
            let c = &mut self.per_class[p.class.index()];
            c.hist.record_at(i, latency_s);
            if latency_s > self.slo_s * p.class.slo_factor() {
                c.violations += 1;
            }
            self.per_device[p.device as usize].completed += 1;
            self.record_variant(p.rung);
        }
        pending.clear();
        self.pending = pending;
    }

    /// Merge another shard's metrics into this one (parallel DES merge,
    /// fixed shard order). Both sides must be folded; the other shard's
    /// device rows append after this one's (shard-major device order).
    pub(super) fn absorb(&mut self, other: FleetMetrics) {
        assert!(self.pending.is_empty() && other.pending.is_empty(), "fold before absorbing");
        assert!(self.slo_s == other.slo_s, "shards must share one SLO");
        self.hist.merge(&other.hist);
        self.shed += other.shed;
        self.slo_violations += other.slo_violations;
        self.per_device.extend(other.per_device);
        if self.variant_served.len() < other.variant_served.len() {
            self.variant_served.resize(other.variant_served.len(), 0);
        }
        for (a, b) in self.variant_served.iter_mut().zip(&other.variant_served) {
            *a += b;
        }
        for (a, b) in self.per_class.iter_mut().zip(&other.per_class) {
            a.hist.merge(&b.hist);
            a.shed += b.shed;
            a.quota_shed += b.quota_shed;
            a.violations += b.violations;
        }
        self.epoch_hist.merge(&other.epoch_hist);
        self.epoch_shed += other.epoch_shed;
        self.epoch_busy_s += other.epoch_busy_s;
        self.faults.absorb(&other.faults);
    }

    pub fn record_shed(&mut self, class: SloClass) {
        self.shed += 1;
        self.epoch_shed += 1;
        self.per_class[class.index()].shed += 1;
    }

    /// A request turned away by the admission quota (still a shed for
    /// every conservation law; additionally counted per class so quota
    /// pressure is visible separately from queue pressure).
    pub fn record_quota_shed(&mut self, class: SloClass) {
        self.record_shed(class);
        self.per_class[class.index()].quota_shed += 1;
    }

    pub fn record_steal(&mut self, device: usize, n: usize) {
        self.per_device[device].stolen += n as u64;
    }

    /// Snapshot the current epoch window and reset it (called at every
    /// autoscaling epoch boundary). Folds any buffered completions
    /// first, so the epoch the autoscaler observes is complete.
    pub fn take_epoch(&mut self) -> EpochStats {
        self.fold_pending();
        let stats = EpochStats {
            completed: self.epoch_hist.count(),
            shed: self.epoch_shed,
            p99_s: self.epoch_hist.quantile(0.99),
            busy_s: self.epoch_busy_s,
        };
        self.epoch_hist.reset();
        self.epoch_shed = 0;
        self.epoch_busy_s = 0.0;
        stats
    }

    /// Per-class reports from the streaming class stats. `offered`
    /// defaults to `completed + shed` (the DES driver overwrites it with
    /// its independently-counted admissions, which the conservation
    /// property tests compare).
    pub(super) fn class_reports(&self) -> Vec<ClassReport> {
        SloClass::ALL
            .iter()
            .map(|&class| {
                let s = &self.per_class[class.index()];
                ClassReport {
                    class,
                    offered: s.hist.count() + s.shed,
                    completed: s.hist.count(),
                    shed: s.shed,
                    quota_shed: s.quota_shed,
                    p50_s: s.hist.quantile(0.50),
                    p95_s: s.hist.quantile(0.95),
                    p99_s: s.hist.quantile(0.99),
                    mean_s: s.hist.mean_s(),
                    max_s: s.hist.max_s(),
                    slo_s: self.slo_s * class.slo_factor(),
                    violations: s.violations,
                }
            })
            .collect()
    }

    /// Finalize against the devices that produced the stats. Fleet-sizing
    /// fields default to a fixed pool (`backends.len()` throughout, no
    /// scaling events); the autoscaled driver overwrites them, and fills
    /// in the energy ledger it accrued.
    pub fn report(&self, backends: &[&dyn Backend], makespan_s: f64) -> FleetReport {
        debug_assert!(self.pending.is_empty(), "fold_pending before reporting");
        let devices = self
            .per_device
            .iter()
            .zip(backends)
            .map(|(s, b)| {
                let busy_frac = if makespan_s > 0.0 {
                    (s.busy_s / makespan_s).clamp(0.0, 1.0)
                } else {
                    0.0
                };
                DeviceReport {
                    name: b.name().to_string(),
                    state: "active",
                    completed: s.completed,
                    batches: s.batches,
                    mean_batch: if s.batches == 0 {
                        0.0
                    } else {
                        s.completed as f64 / s.batches as f64
                    },
                    busy_frac,
                    power_w: b.power_w(busy_frac),
                    stolen: s.stolen,
                }
            })
            .collect();
        FleetReport {
            offered: self.hist.count() + self.shed,
            completed: self.hist.count(),
            shed: self.shed,
            makespan_s,
            p50_s: self.hist.quantile(0.50),
            p95_s: self.hist.quantile(0.95),
            p99_s: self.hist.quantile(0.99),
            mean_s: self.hist.mean_s(),
            max_s: self.hist.max_s(),
            slo_s: self.slo_s,
            slo_violations: self.slo_violations,
            devices_start: backends.len(),
            devices_peak: backends.len(),
            devices_final: backends.len(),
            scaling: Vec::new(),
            devices,
            classes: self.class_reports(),
            energy: EnergyLedger::empty(),
            scenario: None,
            variants: Vec::new(),
            effective_accuracy: None,
            faults: None,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::Rng;

    /// Brute-force percentile (nearest-rank) for cross-checking.
    fn brute_quantile(samples: &mut [f64], q: f64) -> f64 {
        samples.sort_by(|a, b| a.partial_cmp(b).unwrap());
        let rank = ((q * samples.len() as f64).ceil() as usize).clamp(1, samples.len());
        samples[rank - 1]
    }

    #[test]
    fn quantiles_match_brute_force_within_bin_resolution() {
        let mut rng = Rng::new(99);
        let mut h = LatencyHistogram::new();
        let mut samples = Vec::new();
        // Log-normal-ish latencies around 10 ms with a heavy tail.
        for _ in 0..20_000 {
            let s = (0.010 * (0.6 * rng.normal()).exp()).max(1e-5);
            h.record(s);
            samples.push(s);
        }
        for q in [0.50, 0.95, 0.99] {
            let exact = brute_quantile(&mut samples, q);
            let approx = h.quantile(q);
            let rel = (approx - exact).abs() / exact;
            // One 4% bin of slack either side.
            assert!(rel < 0.05, "q{q}: approx {approx} vs exact {exact} (rel {rel})");
        }
    }

    #[test]
    fn mean_and_count_are_exact() {
        let mut h = LatencyHistogram::new();
        for s in [0.001, 0.002, 0.003] {
            h.record(s);
        }
        assert_eq!(h.count(), 3);
        assert!((h.mean_s() - 0.002).abs() < 1e-15);
        assert!((h.max_s() - 0.003).abs() < 1e-15);
    }

    #[test]
    fn empty_histogram_is_zero() {
        let h = LatencyHistogram::new();
        assert_eq!(h.count(), 0);
        assert_eq!(h.quantile(0.99), 0.0);
        assert_eq!(h.mean_s(), 0.0);
    }

    #[test]
    fn single_sample_quantile_clamps_to_observation() {
        let mut h = LatencyHistogram::new();
        h.record(0.0123);
        // All quantiles of a single observation are that observation.
        assert!((h.quantile(0.5) - 0.0123).abs() / 0.0123 < 0.05);
        assert_eq!(h.quantile(0.99), h.quantile(0.01));
    }

    #[test]
    fn slo_violations_counted() {
        let mut m = FleetMetrics::new(1, 0.010);
        m.record_completion(0, 0.005, SloClass::Standard);
        m.record_completion(0, 0.015, SloClass::Standard);
        m.record_completion(0, 0.020, SloClass::Standard);
        assert_eq!(m.slo_violations, 2);
    }

    #[test]
    fn class_violations_judged_against_scaled_slo() {
        let mut m = FleetMetrics::new(1, 0.100);
        // 70 ms: under the fleet SLO (100 ms) and the batchable SLO
        // (200 ms), but over the interactive SLO (50 ms).
        m.record_completion(0, 0.070, SloClass::Interactive);
        m.record_completion(0, 0.070, SloClass::Standard);
        m.record_completion(0, 0.070, SloClass::Batchable);
        m.record_shed(SloClass::Batchable);
        m.record_quota_shed(SloClass::Batchable);
        assert_eq!(m.slo_violations, 0, "fleet-wide counter uses the base SLO");
        let classes = m.class_reports();
        // A quota shed is a shed (conservation) *and* shows up in the
        // quota column.
        assert_eq!(classes[SloClass::Batchable.index()].quota_shed, 1);
        assert_eq!(classes[SloClass::Interactive.index()].quota_shed, 0);
        assert_eq!(classes[SloClass::Interactive.index()].violations, 1);
        assert_eq!(classes[SloClass::Standard.index()].violations, 0);
        assert_eq!(classes[SloClass::Batchable.index()].violations, 0);
        assert_eq!(classes[SloClass::Batchable.index()].shed, 2);
        assert_eq!(classes[SloClass::Batchable.index()].offered, 3);
        assert!((classes[SloClass::Interactive.index()].slo_s - 0.050).abs() < 1e-15);
        // Attainment: interactive 0/1 met, batchable 1 of 3 offered met
        // (both kinds of shed count against it).
        assert_eq!(classes[SloClass::Interactive.index()].attainment(), 0.0);
        assert!((classes[SloClass::Batchable.index()].attainment() - 1.0 / 3.0).abs() < 1e-15);
        let std = &classes[SloClass::Standard.index()];
        assert!(std.p99_s > 0.0);
        assert_eq!(std.attainment(), 1.0);
    }

    #[test]
    fn energy_ledger_bins_across_epochs_and_states() {
        let mut l = EnergyLedger::new(0.5);
        // 10 W active from 0.2 s to 1.3 s: bins get 3 J / 5 J / 3 J.
        l.accrue(0, Lifecycle::Active, 0.2, 1.3, 10.0);
        assert_eq!(l.epochs.len(), 3);
        assert!((l.epochs[0].active_j - 3.0).abs() < 1e-12);
        assert!((l.epochs[1].active_j - 5.0).abs() < 1e-12);
        assert!((l.epochs[2].active_j - 3.0).abs() < 1e-12);
        // A provisioning device lands in its own column.
        l.accrue(1, Lifecycle::Provisioning { ready_at: 1.0 }, 0.0, 0.5, 4.0);
        assert!((l.epochs[0].provisioning_j - 2.0).abs() < 1e-12);
        l.accrue(0, Lifecycle::Draining, 1.3, 1.4, 10.0);
        assert!((l.epochs[2].draining_j - 1.0).abs() < 1e-12);
        // Retired draws nothing; zero-length intervals are no-ops.
        l.accrue(0, Lifecycle::Retired, 0.0, 10.0, 10.0);
        l.accrue(0, Lifecycle::Active, 2.0, 2.0, 10.0);
        // Totals agree across the two accumulation views.
        let total = l.total_j();
        let per_dev: f64 = l.per_device_j.iter().sum();
        assert!((total - per_dev).abs() < 1e-9 * total.max(1.0));
        assert!((total - (11.0 + 2.0 + 1.0)).abs() < 1e-9);
        assert!(
            (l.provisioning_j() + l.active_j() + l.draining_j() - total).abs() < 1e-12
        );
        // Efficiency: served GOP over joules.
        l.served_gop = 28.0;
        assert!((l.fleet_gops_per_w() - 28.0 / total).abs() < 1e-12);
        assert_eq!(EnergyLedger::empty().fleet_gops_per_w(), 0.0);
    }

    /// The quantile's bin midpoint is a *closed form* of the bin index
    /// (`lo · ratio^i · √ratio`), not a running product accumulated bin
    /// by bin — so it carries no per-step multiplication drift (the
    /// PR 6 `postproc::map` bug class). Pin it bit-for-bit.
    #[test]
    fn quantile_midpoint_is_the_closed_form_of_the_bin_index() {
        let mut h = LatencyHistogram::new();
        // Two samples around 10 ms that straddle their bin's geometric
        // midpoint, so the min/max clamp leaves the midpoint untouched.
        let i = h.index(0.010);
        let mid = h.lo * h.ratio.powi(i as i32) * h.ratio.sqrt();
        let (lo_edge, hi_edge) = (h.lo * h.ratio.powi(i as i32), h.lo * h.ratio.powi(i as i32 + 1));
        let (a, b) = (lo_edge * 1.001, hi_edge * 0.999);
        assert!(a < mid && mid < b, "samples must straddle the midpoint");
        assert_eq!(h.index(a), i);
        assert_eq!(h.index(b), i);
        h.record(a);
        h.record(b);
        for q in [0.01, 0.50, 0.99] {
            assert_eq!(
                h.quantile(q).to_bits(),
                mid.to_bits(),
                "q{q} must be the exact closed-form midpoint"
            );
        }
        // Same closed form deep into the histogram (bin 400 ≈ 66 s):
        // powi(400), not 400 chained multiplies.
        let mut h2 = LatencyHistogram::new();
        let edge400 = h2.lo * h2.ratio.powi(400);
        h2.record(edge400 * 1.001);
        h2.record(edge400 * 1.039);
        assert_eq!(h2.index(edge400 * 1.001), 400);
        assert_eq!(h2.index(edge400 * 1.039), 400);
        let mid2 = edge400 * h2.ratio.sqrt();
        assert_eq!(h2.quantile(0.5).to_bits(), mid2.to_bits());
    }

    /// Ledger bin edges are the *closed form* `(bin+1) · epoch_s`
    /// recomputed per bin from the integer index — not a running
    /// `t += epoch_s` — so long accruals stay exact. With a power-of-two
    /// epoch every full bin's energy is exactly representable: assert
    /// bitwise, no tolerance.
    #[test]
    fn ledger_accrual_is_exact_over_thousands_of_bins() {
        let mut l = EnergyLedger::new(0.5);
        // 8 W from 0 to 2048.25 s: 4096 full bins of exactly 4 J plus a
        // final half-filled bin of exactly 2 J.
        l.accrue(0, Lifecycle::Active, 0.0, 2048.25, 8.0);
        assert_eq!(l.epochs.len(), 4097);
        for (i, b) in l.epochs.iter().take(4096).enumerate() {
            assert_eq!(b.active_j.to_bits(), 4.0f64.to_bits(), "bin {i} drifted");
        }
        assert_eq!(l.epochs[4096].active_j.to_bits(), 2.0f64.to_bits());
        // Per-device and per-epoch views agree exactly: every addend is
        // an exactly-representable small value.
        assert_eq!(l.per_device_j[0], 8.0 * 2048.25);
        // A second accrual landing deep in the run splits on the same
        // exact edges: [4000.25, 4000.5) and [4000.5, 4001.0) at 2 W.
        let mut l2 = EnergyLedger::new(0.5);
        l2.accrue(0, Lifecycle::Draining, 4000.25, 4001.0, 2.0);
        assert_eq!(l2.epochs[8000].draining_j.to_bits(), 0.5f64.to_bits());
        assert_eq!(l2.epochs[8001].draining_j.to_bits(), 1.0f64.to_bits());
        assert_eq!(l2.epochs[..8000].iter().map(EpochEnergy::total_j).sum::<f64>(), 0.0);
    }

    #[test]
    fn variant_counters_grow_on_demand() {
        let mut m = FleetMetrics::new(1, 0.1);
        assert!(m.variant_served.is_empty(), "no allocation before the first completion");
        m.record_variant(0);
        m.record_variant(2);
        m.record_variant(2);
        assert_eq!(m.variant_served, vec![1, 0, 2]);
    }

    #[test]
    fn epoch_window_snapshots_and_resets() {
        let mut m = FleetMetrics::new(1, 0.100);
        m.record_completion(0, 0.010, SloClass::Standard);
        m.record_completion(0, 0.030, SloClass::Standard);
        m.record_shed(SloClass::Standard);
        m.record_batch(0, 0.040);
        let e = m.take_epoch();
        assert_eq!(e.completed, 2);
        assert_eq!(e.shed, 1);
        assert!((e.busy_s - 0.040).abs() < 1e-15);
        assert!(e.p99_s > 0.0);
        // Window is empty again; cumulative totals are untouched.
        let e2 = m.take_epoch();
        assert_eq!(e2.completed, 0);
        assert_eq!(e2.shed, 0);
        assert_eq!(e2.busy_s, 0.0);
        assert_eq!(m.hist.count(), 2);
        assert_eq!(m.shed, 1);
    }

    /// Million-sample quantile accuracy: the log-spaced histogram's
    /// relative error stays within one 4% bin at 10^6 samples, same as
    /// at trace scale (constant memory — the bins never grow).
    #[test]
    fn quantiles_stay_accurate_at_a_million_samples() {
        let mut rng = Rng::new(4242);
        let mut h = LatencyHistogram::new();
        let mut samples = Vec::with_capacity(1_000_000);
        for _ in 0..1_000_000 {
            let s = (0.020 * (0.8 * rng.normal()).exp()).max(1e-5);
            h.record(s);
            samples.push(s);
        }
        assert_eq!(h.count(), 1_000_000);
        for q in [0.50, 0.95, 0.99, 0.999] {
            let exact = brute_quantile(&mut samples, q);
            let approx = h.quantile(q);
            let rel = (approx - exact).abs() / exact;
            assert!(rel < 0.05, "q{q}: approx {approx} vs exact {exact} (rel {rel})");
        }
        // Exact accumulators stay exact: count and mean agree with the
        // running sum to f64 precision.
        let mean: f64 = samples.iter().sum::<f64>() / 1e6;
        assert!((h.mean_s() - mean).abs() < 1e-12);
    }

    /// Saturation: latencies past the last bin edge (~5×10^3 s) all land
    /// in the top bin, and the quantile clamps to the observed max
    /// rather than inventing a mid-bin value past it.
    #[test]
    fn histogram_saturates_into_the_top_bin() {
        let mut h = LatencyHistogram::new();
        let top_edge = h.lo * h.ratio.powi(511);
        for i in 0..100_000u64 {
            h.record(top_edge * (1.0 + i as f64)); // far past the range
        }
        assert_eq!(h.bins[511], 100_000, "everything saturates into bin 511");
        // Every quantile reads the top bin's closed-form midpoint (it
        // sits inside the observed [min, max] envelope here, so the
        // clamp leaves it alone) — saturation degrades resolution, not
        // correctness.
        let mid = h.lo * h.ratio.powi(511) * h.ratio.sqrt();
        assert_eq!(h.quantile(0.5).to_bits(), mid.to_bits());
        assert_eq!(h.quantile(0.999).to_bits(), mid.to_bits());
        // Below-range samples symmetrically pin to bin 0.
        let mut l = LatencyHistogram::new();
        l.record(1e-9);
        assert_eq!(l.bins[0], 1);
    }

    /// The batched fold is bit-identical to per-sample recording at 10^6
    /// completions: every accumulator (fleet/epoch/class histograms,
    /// violation counters, per-device counts, variant counters) matches
    /// exactly, fold boundaries landing mid-stream included.
    #[test]
    fn batched_fold_matches_per_sample_recording_bitwise() {
        let mut rng = Rng::new(77);
        let mut direct = FleetMetrics::new(4, 0.050);
        let mut batched = FleetMetrics::new(4, 0.050);
        for i in 0..1_000_000u64 {
            let lat = (0.030 * (0.7 * rng.normal()).exp()).max(1e-5);
            let class = SloClass::ALL[(i % 3) as usize];
            let dev = (i % 4) as usize;
            let rung = (i % 2) as u8;
            direct.record_completion(dev, lat, class);
            direct.record_variant(rung);
            batched.pend_completion(dev, lat, class, rung);
            // Interleaved sheds hit both the same way (they bypass the
            // buffer — only completions batch).
            if i % 97 == 0 {
                direct.record_shed(class);
                batched.record_shed(class);
            }
        }
        batched.fold_pending();
        assert_eq!(direct.hist.count(), batched.hist.count());
        assert_eq!(direct.hist.sum_s.to_bits(), batched.hist.sum_s.to_bits());
        assert_eq!(direct.hist.bins, batched.hist.bins);
        assert_eq!(direct.hist.min_s.to_bits(), batched.hist.min_s.to_bits());
        assert_eq!(direct.hist.max_s.to_bits(), batched.hist.max_s.to_bits());
        assert_eq!(direct.slo_violations, batched.slo_violations);
        assert_eq!(direct.shed, batched.shed);
        assert_eq!(direct.variant_served, batched.variant_served);
        assert_eq!(direct.epoch_hist.bins, batched.epoch_hist.bins);
        assert_eq!(direct.epoch_hist.sum_s.to_bits(), batched.epoch_hist.sum_s.to_bits());
        for (a, b) in direct.per_class.iter().zip(&batched.per_class) {
            assert_eq!(a.hist.bins, b.hist.bins);
            assert_eq!(a.hist.sum_s.to_bits(), b.hist.sum_s.to_bits());
            assert_eq!(a.violations, b.violations);
            assert_eq!(a.shed, b.shed);
        }
        for (a, b) in direct.per_device.iter().zip(&batched.per_device) {
            assert_eq!(a.completed, b.completed);
        }
        // An epoch snapshot after folding agrees too (and resets both
        // windows identically).
        let (ea, eb) = (direct.take_epoch(), batched.take_epoch());
        assert_eq!(ea.completed, eb.completed);
        assert_eq!(ea.p99_s.to_bits(), eb.p99_s.to_bits());
    }

    /// `reset()` leaves the histogram indistinguishable from a fresh one.
    #[test]
    fn reset_equals_fresh_histogram() {
        let mut h = LatencyHistogram::new();
        for s in [0.001, 0.5, 900.0] {
            h.record(s);
        }
        h.reset();
        let fresh = LatencyHistogram::new();
        assert_eq!(h.bins, fresh.bins);
        assert_eq!(h.count(), 0);
        assert_eq!(h.sum_s.to_bits(), fresh.sum_s.to_bits());
        assert_eq!(h.min_s.to_bits(), fresh.min_s.to_bits());
        assert_eq!(h.max_s.to_bits(), fresh.max_s.to_bits());
    }

    /// Histogram merge: integer accumulators (bins, count) and the
    /// min/max envelope reproduce the unsharded whole exactly, so every
    /// quantile — a pure function of bins + min/max — is bit-identical.
    /// (`sum_s` re-associates across the shard boundary, so the mean
    /// agrees to f64 precision, not bitwise; the parallel DES's
    /// byte-determinism claim is across runs and thread counts, where
    /// the merge order is fixed.)
    #[test]
    fn merge_reproduces_the_unsharded_histogram() {
        let mut rng = Rng::new(5);
        let (mut whole, mut a, mut b) =
            (LatencyHistogram::new(), LatencyHistogram::new(), LatencyHistogram::new());
        let samples: Vec<f64> =
            (0..10_000).map(|_| (0.01 * (rng.normal()).exp()).max(1e-5)).collect();
        for s in &samples[..5_000] {
            a.record(*s);
            whole.record(*s);
        }
        for s in &samples[5_000..] {
            b.record(*s);
            whole.record(*s);
        }
        a.merge(&b);
        assert_eq!(a.bins, whole.bins);
        assert_eq!(a.count(), whole.count());
        assert_eq!(a.min_s.to_bits(), whole.min_s.to_bits());
        assert_eq!(a.max_s.to_bits(), whole.max_s.to_bits());
        for q in [0.01, 0.5, 0.99] {
            assert_eq!(a.quantile(q).to_bits(), whole.quantile(q).to_bits());
        }
        assert!((a.mean_s() - whole.mean_s()).abs() < 1e-12);
        // Merging the same halves twice is deterministic bitwise.
        let mut a2 = LatencyHistogram::new();
        for s in &samples[..5_000] {
            a2.record(*s);
        }
        let mut b2 = LatencyHistogram::new();
        for s in &samples[5_000..] {
            b2.record(*s);
        }
        a2.merge(&b2);
        assert_eq!(a2.sum_s.to_bits(), a.sum_s.to_bits());
    }

    /// Ledger exactness at 10^6 accrual segments: a power-of-two epoch
    /// and exactly-representable segment lengths make every bin's energy
    /// exactly representable, so the sum over a million accruals carries
    /// zero drift — bitwise.
    #[test]
    fn ledger_epoch_sums_stay_exact_at_a_million_segments() {
        let mut l = EnergyLedger::new(0.5);
        // 10^6 segments of 0.125 s at 8 W: 1 J each, 4 per bin.
        for i in 0..1_000_000u64 {
            let from = i as f64 * 0.125;
            l.accrue(0, Lifecycle::Active, from, from + 0.125, 8.0);
        }
        assert_eq!(l.epochs.len(), 250_000);
        for (i, b) in l.epochs.iter().enumerate() {
            assert_eq!(b.active_j.to_bits(), 4.0f64.to_bits(), "bin {i} drifted");
        }
        assert_eq!(l.per_device_j[0].to_bits(), 1_000_000.0f64.to_bits());
        // Ledger absorb: elementwise-added halves reproduce the whole.
        let mut h1 = EnergyLedger::new(0.5);
        let mut h2 = EnergyLedger::new(0.5);
        h1.accrue(0, Lifecycle::Active, 0.0, 10.0, 4.0);
        h2.accrue(0, Lifecycle::Draining, 5.0, 20.0, 2.0);
        h2.served_gop = 3.0;
        let (t1, t2) = (h1.total_j(), h2.total_j());
        h1.absorb(h2);
        assert_eq!(h1.total_j().to_bits(), (t1 + t2).to_bits());
        assert_eq!(h1.per_device_j.len(), 2, "absorbed device rows append");
        assert_eq!(h1.served_gop, 3.0);
    }

    #[test]
    fn absorb_merges_shard_metrics() {
        let mut a = FleetMetrics::new(1, 0.1);
        let mut b = FleetMetrics::new(2, 0.1);
        a.record_completion(0, 0.05, SloClass::Standard);
        a.record_variant(0);
        b.record_completion(1, 0.2, SloClass::Interactive);
        b.record_variant(1);
        b.record_shed(SloClass::Batchable);
        a.absorb(b);
        assert_eq!(a.hist.count(), 2);
        assert_eq!(a.shed, 1);
        assert_eq!(a.slo_violations, 1);
        assert_eq!(a.per_device.len(), 3, "device rows concatenate");
        assert_eq!(a.per_device[2].completed, 1);
        assert_eq!(a.variant_served, vec![1, 1]);
        assert_eq!(a.per_class[SloClass::Interactive.index()].violations, 1);
    }

    #[test]
    fn add_device_extends_per_device_stats() {
        let mut m = FleetMetrics::new(1, 0.1);
        m.add_device();
        m.record_completion(1, 0.005, SloClass::Standard);
        m.record_batch(1, 0.005);
        let p = crate::baselines::Platform {
            name: "a",
            overhead_s: 1e-3,
            sustained_gops: 10.0,
            power_w: 1.0,
        };
        let d0 = crate::serving::device::BaselineDevice::new(p.clone(), 0.1, 4);
        let d1 = crate::serving::device::BaselineDevice::new(p, 0.1, 4);
        let backends: Vec<&dyn Backend> = vec![&d0, &d1];
        let r = m.report(&backends, 1.0);
        assert_eq!(r.devices.len(), 2);
        assert_eq!(r.devices[1].completed, 1);
        assert_eq!(r.offered, r.completed + r.shed);
    }
}
