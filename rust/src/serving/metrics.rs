//! Streaming fleet metrics: latency quantiles, throughput, per-device
//! utilization, SLO violations.
//!
//! Quantiles come from a log-spaced streaming histogram (constant memory,
//! one pass — the shape HDRHistogram uses) so the fleet can track p99
//! over millions of requests without retaining them; resolution is the
//! bin ratio (~4% relative error), which the tests verify against a
//! brute-force percentile. Per-device compute utilization reuses
//! [`crate::scheduler::TuningResult::utilization`] through
//! [`super::device::Backend::power_w`] rather than duplicating the
//! formula.

use super::autoscale::ScalingEvent;
use super::device::Backend;

/// Streaming latency histogram with log-spaced bins.
#[derive(Debug, Clone)]
pub struct LatencyHistogram {
    /// Lower edge of bin 0, seconds.
    lo: f64,
    /// Geometric bin width (upper/lower edge ratio).
    ratio: f64,
    bins: Vec<u64>,
    count: u64,
    sum_s: f64,
    min_s: f64,
    max_s: f64,
}

impl LatencyHistogram {
    /// 512 bins at 4% spacing: covers ~10 µs to ~5×10^3 s.
    pub fn new() -> Self {
        Self {
            lo: 1e-5,
            ratio: 1.04,
            bins: vec![0; 512],
            count: 0,
            sum_s: 0.0,
            min_s: f64::INFINITY,
            max_s: 0.0,
        }
    }

    fn index(&self, latency_s: f64) -> usize {
        if latency_s <= self.lo {
            return 0;
        }
        let idx = ((latency_s / self.lo).ln() / self.ratio.ln()).floor() as usize;
        idx.min(self.bins.len() - 1)
    }

    pub fn record(&mut self, latency_s: f64) {
        let i = self.index(latency_s);
        self.bins[i] += 1;
        self.count += 1;
        self.sum_s += latency_s;
        self.min_s = self.min_s.min(latency_s);
        self.max_s = self.max_s.max(latency_s);
    }

    pub fn count(&self) -> u64 {
        self.count
    }

    pub fn mean_s(&self) -> f64 {
        if self.count == 0 {
            0.0
        } else {
            self.sum_s / self.count as f64
        }
    }

    pub fn max_s(&self) -> f64 {
        self.max_s
    }

    /// The `q`-quantile (`q` in `[0, 1]`), to bin resolution. Returns the
    /// geometric midpoint of the bin holding the target rank, clamped to
    /// the observed min/max so tiny samples stay sensible.
    pub fn quantile(&self, q: f64) -> f64 {
        if self.count == 0 {
            return 0.0;
        }
        let target = ((q.clamp(0.0, 1.0) * self.count as f64).ceil() as u64).max(1);
        let mut cum = 0u64;
        for (i, &n) in self.bins.iter().enumerate() {
            cum += n;
            if cum >= target {
                let mid = self.lo * self.ratio.powi(i as i32) * self.ratio.sqrt();
                return mid.clamp(self.min_s, self.max_s);
            }
        }
        self.max_s
    }
}

impl Default for LatencyHistogram {
    fn default() -> Self {
        Self::new()
    }
}

/// Final per-device figures.
#[derive(Debug, Clone)]
pub struct DeviceReport {
    pub name: String,
    /// Lifecycle state at the end of the run ("active" for fixed pools).
    pub state: &'static str,
    pub completed: u64,
    pub batches: u64,
    /// Mean closed-batch size.
    pub mean_batch: f64,
    /// Fraction of the makespan the device was serving.
    pub busy_frac: f64,
    /// Average board power at that busy fraction, W.
    pub power_w: f64,
    /// Requests this device pulled from a sibling's queue.
    pub stolen: u64,
}

/// Fleet-level summary of one simulated run.
#[derive(Debug, Clone)]
pub struct FleetReport {
    /// Requests offered to the front door (every one either completes or
    /// is shed — the conservation law the property tests pin down).
    pub offered: u64,
    pub completed: u64,
    pub shed: u64,
    /// Time from first arrival to last completion, s.
    pub makespan_s: f64,
    pub p50_s: f64,
    pub p95_s: f64,
    pub p99_s: f64,
    pub mean_s: f64,
    pub max_s: f64,
    /// The latency objective requests were judged against, s.
    pub slo_s: f64,
    /// Completed requests whose end-to-end latency exceeded the SLO.
    pub slo_violations: u64,
    /// Serving devices at the start of the run.
    pub devices_start: usize,
    /// Peak concurrently-*active* devices over the run (draining
    /// remnants excluded, so the autoscaler's max-devices clamp bounds
    /// this exactly).
    pub devices_peak: usize,
    /// Serving devices at the end of the run.
    pub devices_final: usize,
    /// Autoscaler actions in time order (empty for fixed pools).
    pub scaling: Vec<ScalingEvent>,
    pub devices: Vec<DeviceReport>,
}

impl FleetReport {
    /// Aggregate served throughput, frames per second.
    pub fn throughput_fps(&self) -> f64 {
        if self.makespan_s <= 0.0 {
            0.0
        } else {
            self.completed as f64 / self.makespan_s
        }
    }

    /// Fraction of *offered* requests that met the SLO (shed requests
    /// count as violations — a shed frame never met its deadline).
    pub fn slo_attainment(&self) -> f64 {
        let offered = self.completed + self.shed;
        if offered == 0 {
            return 1.0;
        }
        (self.completed - self.slo_violations) as f64 / offered as f64
    }
}

/// Streaming accumulator the simulator feeds.
#[derive(Debug, Clone)]
pub(super) struct DeviceStats {
    pub busy_s: f64,
    pub completed: u64,
    pub batches: u64,
    pub stolen: u64,
}

/// Snapshot of one autoscaling epoch (what [`FleetMetrics::take_epoch`]
/// returns and resets).
#[derive(Debug, Clone, Copy)]
pub struct EpochStats {
    pub completed: u64,
    pub shed: u64,
    /// p99 of the epoch's completions, s (0 when none completed).
    pub p99_s: f64,
    /// Service time dispatched during the epoch, device-seconds.
    pub busy_s: f64,
}

#[derive(Debug)]
pub struct FleetMetrics {
    pub(super) hist: LatencyHistogram,
    pub(super) shed: u64,
    pub(super) slo_s: f64,
    pub(super) slo_violations: u64,
    pub(super) per_device: Vec<DeviceStats>,
    /// Rolling per-epoch window the autoscaler observes.
    epoch_hist: LatencyHistogram,
    epoch_shed: u64,
    epoch_busy_s: f64,
}

impl FleetMetrics {
    pub fn new(n_devices: usize, slo_s: f64) -> Self {
        Self {
            hist: LatencyHistogram::new(),
            shed: 0,
            slo_s,
            slo_violations: 0,
            per_device: (0..n_devices)
                .map(|_| DeviceStats { busy_s: 0.0, completed: 0, batches: 0, stolen: 0 })
                .collect(),
            epoch_hist: LatencyHistogram::new(),
            epoch_shed: 0,
            epoch_busy_s: 0.0,
        }
    }

    /// Start tracking one more device (autoscaler provisioning).
    pub fn add_device(&mut self) {
        self.per_device.push(DeviceStats { busy_s: 0.0, completed: 0, batches: 0, stolen: 0 });
    }

    /// Record one completed request on `device`.
    pub fn record_completion(&mut self, device: usize, latency_s: f64) {
        self.hist.record(latency_s);
        self.epoch_hist.record(latency_s);
        if latency_s > self.slo_s {
            self.slo_violations += 1;
        }
        self.per_device[device].completed += 1;
    }

    /// Record one closed batch (its service time busies the device).
    pub fn record_batch(&mut self, device: usize, service_s: f64) {
        self.per_device[device].batches += 1;
        self.per_device[device].busy_s += service_s;
        self.epoch_busy_s += service_s;
    }

    pub fn record_shed(&mut self) {
        self.shed += 1;
        self.epoch_shed += 1;
    }

    pub fn record_steal(&mut self, device: usize, n: usize) {
        self.per_device[device].stolen += n as u64;
    }

    /// Snapshot the current epoch window and reset it (called at every
    /// autoscaling epoch boundary).
    pub fn take_epoch(&mut self) -> EpochStats {
        let stats = EpochStats {
            completed: self.epoch_hist.count(),
            shed: self.epoch_shed,
            p99_s: self.epoch_hist.quantile(0.99),
            busy_s: self.epoch_busy_s,
        };
        self.epoch_hist = LatencyHistogram::new();
        self.epoch_shed = 0;
        self.epoch_busy_s = 0.0;
        stats
    }

    /// Finalize against the devices that produced the stats. Fleet-sizing
    /// fields default to a fixed pool (`backends.len()` throughout, no
    /// scaling events); the autoscaled driver overwrites them.
    pub fn report(&self, backends: &[&dyn Backend], makespan_s: f64) -> FleetReport {
        let devices = self
            .per_device
            .iter()
            .zip(backends)
            .map(|(s, b)| {
                let busy_frac = if makespan_s > 0.0 {
                    (s.busy_s / makespan_s).clamp(0.0, 1.0)
                } else {
                    0.0
                };
                DeviceReport {
                    name: b.name().to_string(),
                    state: "active",
                    completed: s.completed,
                    batches: s.batches,
                    mean_batch: if s.batches == 0 {
                        0.0
                    } else {
                        s.completed as f64 / s.batches as f64
                    },
                    busy_frac,
                    power_w: b.power_w(busy_frac),
                    stolen: s.stolen,
                }
            })
            .collect();
        FleetReport {
            offered: self.hist.count() + self.shed,
            completed: self.hist.count(),
            shed: self.shed,
            makespan_s,
            p50_s: self.hist.quantile(0.50),
            p95_s: self.hist.quantile(0.95),
            p99_s: self.hist.quantile(0.99),
            mean_s: self.hist.mean_s(),
            max_s: self.hist.max_s(),
            slo_s: self.slo_s,
            slo_violations: self.slo_violations,
            devices_start: backends.len(),
            devices_peak: backends.len(),
            devices_final: backends.len(),
            scaling: Vec::new(),
            devices,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::Rng;

    /// Brute-force percentile (nearest-rank) for cross-checking.
    fn brute_quantile(samples: &mut [f64], q: f64) -> f64 {
        samples.sort_by(|a, b| a.partial_cmp(b).unwrap());
        let rank = ((q * samples.len() as f64).ceil() as usize).clamp(1, samples.len());
        samples[rank - 1]
    }

    #[test]
    fn quantiles_match_brute_force_within_bin_resolution() {
        let mut rng = Rng::new(99);
        let mut h = LatencyHistogram::new();
        let mut samples = Vec::new();
        // Log-normal-ish latencies around 10 ms with a heavy tail.
        for _ in 0..20_000 {
            let s = (0.010 * (0.6 * rng.normal()).exp()).max(1e-5);
            h.record(s);
            samples.push(s);
        }
        for q in [0.50, 0.95, 0.99] {
            let exact = brute_quantile(&mut samples, q);
            let approx = h.quantile(q);
            let rel = (approx - exact).abs() / exact;
            // One 4% bin of slack either side.
            assert!(rel < 0.05, "q{q}: approx {approx} vs exact {exact} (rel {rel})");
        }
    }

    #[test]
    fn mean_and_count_are_exact() {
        let mut h = LatencyHistogram::new();
        for s in [0.001, 0.002, 0.003] {
            h.record(s);
        }
        assert_eq!(h.count(), 3);
        assert!((h.mean_s() - 0.002).abs() < 1e-15);
        assert!((h.max_s() - 0.003).abs() < 1e-15);
    }

    #[test]
    fn empty_histogram_is_zero() {
        let h = LatencyHistogram::new();
        assert_eq!(h.count(), 0);
        assert_eq!(h.quantile(0.99), 0.0);
        assert_eq!(h.mean_s(), 0.0);
    }

    #[test]
    fn single_sample_quantile_clamps_to_observation() {
        let mut h = LatencyHistogram::new();
        h.record(0.0123);
        // All quantiles of a single observation are that observation.
        assert!((h.quantile(0.5) - 0.0123).abs() / 0.0123 < 0.05);
        assert_eq!(h.quantile(0.99), h.quantile(0.01));
    }

    #[test]
    fn slo_violations_counted() {
        let mut m = FleetMetrics::new(1, 0.010);
        m.record_completion(0, 0.005);
        m.record_completion(0, 0.015);
        m.record_completion(0, 0.020);
        assert_eq!(m.slo_violations, 2);
    }

    #[test]
    fn epoch_window_snapshots_and_resets() {
        let mut m = FleetMetrics::new(1, 0.100);
        m.record_completion(0, 0.010);
        m.record_completion(0, 0.030);
        m.record_shed();
        m.record_batch(0, 0.040);
        let e = m.take_epoch();
        assert_eq!(e.completed, 2);
        assert_eq!(e.shed, 1);
        assert!((e.busy_s - 0.040).abs() < 1e-15);
        assert!(e.p99_s > 0.0);
        // Window is empty again; cumulative totals are untouched.
        let e2 = m.take_epoch();
        assert_eq!(e2.completed, 0);
        assert_eq!(e2.shed, 0);
        assert_eq!(e2.busy_s, 0.0);
        assert_eq!(m.hist.count(), 2);
        assert_eq!(m.shed, 1);
    }

    #[test]
    fn add_device_extends_per_device_stats() {
        let mut m = FleetMetrics::new(1, 0.1);
        m.add_device();
        m.record_completion(1, 0.005);
        m.record_batch(1, 0.005);
        let p = crate::baselines::Platform {
            name: "a",
            overhead_s: 1e-3,
            sustained_gops: 10.0,
            power_w: 1.0,
        };
        let d0 = crate::serving::device::BaselineDevice::new(p.clone(), 0.1, 4);
        let d1 = crate::serving::device::BaselineDevice::new(p, 0.1, 4);
        let backends: Vec<&dyn Backend> = vec![&d0, &d1];
        let r = m.report(&backends, 1.0);
        assert_eq!(r.devices.len(), 2);
        assert_eq!(r.devices[1].completed, 1);
        assert_eq!(r.offered, r.completed + r.shed);
    }
}
