//! Device abstraction: anything that can serve a batch of inference
//! requests with a predictable service time.
//!
//! A [`GemminiDevice`] derives its batch latency from the existing cycle
//! model: one tuned inference costs `TuningResult::latency_s`, of which
//! the weight-streaming portion is paid *once per batch* under the
//! paper's weight-stationary dataflow (weights stay in the PE array while
//! the batch's activations stream through), and a fixed host-dispatch
//! overhead is paid once per invocation (the TVM-runtime/RPC cost the
//! Section VI system pays per frame). That decomposition is what dynamic
//! batching amortizes. A [`BaselineDevice`] wraps a [`Platform`] from
//! [`crate::baselines`] so the fleet can mix FPGAs with CPUs/GPUs.

use crate::baselines::Platform;
use crate::energy::FpgaPowerModel;
use crate::fpga::resources::Board;
use crate::gemmini::config::GemminiConfig;
use crate::ir::Graph;
use crate::scheduler::{TuningEngine, TuningResult};

/// Default host-dispatch overhead per accelerator invocation, seconds
/// (runtime dispatch + request marshalling; the Section VI system pays
/// this through the TVM runtime and ethernet hop).
pub const DEFAULT_DISPATCH_S: f64 = 2e-3;

/// A serving backend: batch service time + power as a function of load.
///
/// `Send + Sync` is part of the contract: the live threaded runtime
/// (`serving::live`) shares each backend between its shard worker (for
/// service times) and the front-door router (for outstanding-work
/// estimates). Backends are plain calibrated models, so the bound costs
/// implementors nothing.
pub trait Backend: Send + Sync {
    /// Human-readable device name (unique within a pool).
    fn name(&self) -> &str;

    /// Wall-clock seconds to serve a batch of `batch` requests
    /// (`batch >= 1`). Must be monotonically non-decreasing in `batch`.
    fn batch_latency_s(&self, batch: usize) -> f64;

    /// Largest batch the device can hold (activation memory bound).
    fn max_batch(&self) -> usize {
        32
    }

    /// Average board power at a busy fraction in `[0, 1]`.
    fn power_w(&self, busy_frac: f64) -> f64;

    /// Giga-operations one served frame performs on this device (the
    /// workload's arithmetic volume; what the fleet [energy
    /// ledger](crate::serving::EnergyLedger) credits per completion when
    /// computing fleet-wide GOP/s/W).
    fn gop_per_frame(&self) -> f64;
}

/// A tuned Gemmini accelerator as a serving device.
#[derive(Debug, Clone)]
pub struct GemminiDevice {
    pub label: String,
    pub board: Board,
    pub config: GemminiConfig,
    /// Host overhead paid once per invocation, s.
    pub dispatch_s: f64,
    /// Weight-streaming time paid once per batch (weight-stationary
    /// reuse), s.
    pub weights_s: f64,
    /// Per-frame compute + activation-movement time, s.
    pub per_frame_s: f64,
    /// MAC-array utilization of the tuned schedule while computing
    /// (from [`TuningResult::utilization`]); scales dynamic power.
    pub compute_util: f64,
    /// Giga-operations per served frame (2 ops per MAC over the tuned
    /// layers).
    pub gop: f64,
    batch_cap: usize,
}

/// Split a tuned single-frame latency into the per-batch weight pass and
/// the per-frame remainder, flooring compute at 5% of the frame —
/// DDR-dominated schedules are legal, a *negative* remainder is not.
/// Returns the floored per-frame time and whether `weights_s` was
/// inconsistent with the frame latency (`weights_s >= frame_s`, i.e. the
/// clamp is masking a modeling bug rather than absorbing a DDR-heavy but
/// self-consistent split).
pub(crate) fn split_frame_s(frame_s: f64, weights_s: f64) -> (f64, bool) {
    ((frame_s - weights_s).max(frame_s * 0.05), weights_s >= frame_s)
}

impl GemminiDevice {
    /// Build a device from a tuned model on a config. The weight volume
    /// comes from the tuned layers' GEMM shapes (`k×n` int8 weights per
    /// layer); its streaming time is DDR-bandwidth-bound and independent
    /// of the PL clock, exactly like the cycle model's DMA path.
    pub fn from_tuning(
        label: &str,
        board: Board,
        config: GemminiConfig,
        tuning: &TuningResult,
        dispatch_s: f64,
    ) -> Self {
        let weight_bytes: u64 =
            tuning.layers.iter().map(|l| (l.geom.k * l.geom.n) as u64).sum();
        let weights_s = weight_bytes as f64 / (config.ddr_gbs * 1e9);
        let frame_s = tuning.latency_s(&config, true);
        // The single-frame latency includes one weight pass; everything
        // else (compute, activation movement) repeats per frame.
        let (per_frame_s, inconsistent) = split_frame_s(frame_s, weights_s);
        if inconsistent {
            // `weights_s >= frame_s` means the DDR model claims the
            // weight stream alone outlasts the whole tuned inference —
            // the two models disagree. The floor keeps the device usable,
            // but quietly clamping would hide the modeling bug.
            debug_assert!(
                weights_s < frame_s,
                "{label}: weight-stream time {weights_s:.6} s >= tuned frame latency \
                 {frame_s:.6} s — the DDR model and the tuned latency are inconsistent"
            );
            eprintln!(
                "warning: {label}: weight-stream time {weights_s:.6} s exceeds the tuned \
                 frame latency {frame_s:.6} s; flooring per-frame compute at 5% — check \
                 ddr_gbs against the tuning's DMA model"
            );
        }
        let compute_util = tuning.utilization(&config, true);
        let gop = frame_gop(tuning);
        // Batch activations must fit the accumulator working set; a
        // coarse bound that scales with on-chip memory.
        let batch_cap = (config.accumulator_kib / 16).clamp(1, 64);
        Self {
            label: label.to_string(),
            board,
            config,
            dispatch_s,
            weights_s,
            per_frame_s,
            compute_util,
            gop,
            batch_cap,
        }
    }

    /// Build a device through a shared [`TuningEngine`]: tunes the graph
    /// at batch 1 (and, when `batch >= 2`, at the serving batch size) and
    /// derives the latency decomposition like
    /// [`from_tuning`](Self::from_tuning) /
    /// [`from_batch_tuning`](Self::from_batch_tuning). Because the engine
    /// memoizes by geometry (and can be cache-file backed), stamping out N
    /// fleet replicas costs one schedule search, not N — replicas 2..N are
    /// pure cache hits.
    pub fn from_engine(
        label: &str,
        board: Board,
        engine: &mut TuningEngine,
        g: &Graph,
        measure_k: usize,
        batch: usize,
        dispatch_s: f64,
    ) -> Self {
        let config = engine.config().clone();
        let single = engine.tune_graph(g, measure_k);
        if batch >= 2 {
            let batched = engine.tune_graph_batch(g, measure_k, batch);
            Self::from_batch_tuning(label, board, config, &single, &batched, batch, dispatch_s)
        } else {
            Self::from_tuning(label, board, config, &single, dispatch_s)
        }
    }

    /// Build a device whose batch-latency decomposition is *measured* by
    /// batch-aware tuning instead of analytically split: `single` is the
    /// graph tuned at batch 1 ([`crate::scheduler::tune_graph`]) and
    /// `batched` the same graph tuned for `batch` frames per invocation
    /// ([`crate::scheduler::tune_graph_batch`]). The marginal per-frame
    /// cost is the measured slope between the two operating points (on
    /// schedules searched for the batched GEMM shapes), and the per-batch
    /// intercept is whatever those schedules could *not* amortize — so
    /// the serving model inherits the cycle model's view of batching
    /// rather than assuming the weight stream is the only shared cost.
    pub fn from_batch_tuning(
        label: &str,
        board: Board,
        config: GemminiConfig,
        single: &TuningResult,
        batched: &TuningResult,
        batch: usize,
        dispatch_s: f64,
    ) -> Self {
        assert!(batch >= 2, "batch-aware tuning needs batch >= 2 (got {batch})");
        let t1 = single.latency_s(&config, true);
        let tb = batched.latency_s(&config, true);
        // Slope/intercept of the measured (1, t1) → (batch, tb) line,
        // floored so the model stays strictly monotone in batch size.
        let per_frame_s = ((tb - t1) / (batch as f64 - 1.0)).max(0.01 * t1).min(t1);
        let weights_s = (t1 - per_frame_s).max(0.0);
        let compute_util = batched.utilization(&config, true);
        // Per-frame arithmetic comes from the batch-1 tuning (the
        // batched geometry's MACs are `batch ×` one frame's).
        let gop = frame_gop(single);
        // A device tuned for `batch` must admit at least that batch.
        let batch_cap = (config.accumulator_kib / 16).clamp(1, 64).max(batch);
        Self {
            label: label.to_string(),
            board,
            config,
            dispatch_s,
            weights_s,
            per_frame_s,
            compute_util,
            gop,
            batch_cap,
        }
    }
}

/// GOP of one frame under a tuning: 2 ops per MAC over the tuned layers.
fn frame_gop(tuning: &TuningResult) -> f64 {
    let macs: u64 = tuning.layers.iter().map(|l| l.geom.macs()).sum();
    2.0 * macs as f64 / 1e9
}

/// Sustainable throughput of one device under a batching cap, frames/s.
/// The single definition every capacity consumer shares — the
/// autoscaler's demand deficit ([`crate::serving::sim`]), the catalog's
/// feasibility probe ([`DeviceCatalog::register`]), and the bench /
/// example sizing all must agree for [`DeviceCatalog::pick`] to mean
/// what it says.
pub fn capacity_fps(backend: &dyn Backend, max_batch: usize) -> f64 {
    let b = max_batch.min(backend.max_batch()).max(1);
    b as f64 / backend.batch_latency_s(b)
}

impl Backend for GemminiDevice {
    fn name(&self) -> &str {
        &self.label
    }

    fn batch_latency_s(&self, batch: usize) -> f64 {
        self.dispatch_s + self.weights_s + batch as f64 * self.per_frame_s
    }

    fn max_batch(&self) -> usize {
        self.batch_cap
    }

    fn power_w(&self, busy_frac: f64) -> f64 {
        let model = FpgaPowerModel::for_board(self.board);
        model.power_w(&self.config, busy_frac.clamp(0.0, 1.0) * self.compute_util)
    }

    fn gop_per_frame(&self) -> f64 {
        self.gop
    }
}

/// A CPU/GPU baseline platform as a serving device (reuses the calibrated
/// Figure 7 / Table IV models). Baselines gain less from batching: only
/// the per-invocation overhead amortizes.
#[derive(Debug, Clone)]
pub struct BaselineDevice {
    pub platform: Platform,
    /// Workload size per frame, giga-operations.
    pub gop: f64,
    batch_cap: usize,
}

impl BaselineDevice {
    pub fn new(platform: Platform, gop: f64, batch_cap: usize) -> Self {
        Self { platform, gop, batch_cap: batch_cap.max(1) }
    }
}

impl Backend for BaselineDevice {
    fn name(&self) -> &str {
        self.platform.name
    }

    fn batch_latency_s(&self, batch: usize) -> f64 {
        self.platform.overhead_s + batch as f64 * self.gop / self.platform.sustained_gops
    }

    fn max_batch(&self) -> usize {
        self.batch_cap
    }

    fn power_w(&self, _busy_frac: f64) -> f64 {
        self.platform.power_w
    }

    fn gop_per_frame(&self) -> f64 {
        self.gop
    }
}

/// One provisionable device kind in a [`DeviceCatalog`], stamped with the
/// static figures the cheapest-feasible policy decides on. The figures
/// are probed from a prototype instance at registration, so they always
/// agree with what the built replicas will actually do.
pub struct CatalogEntry {
    /// Label prefix (replica labels append an index).
    pub label: String,
    /// Sustainable throughput at the catalog's serving batch, frames/s.
    pub fps_capacity: f64,
    /// Board power while serving (busy fraction 1), W.
    pub busy_power_w: f64,
    /// Board power while idle/provisioning, W.
    pub idle_power_w: f64,
    /// Full-batch service latency, s (a device whose batch already
    /// misses the SLO can never restore it).
    pub service_latency_s: f64,
    /// Energy one frame costs at saturation, J (= busy W / capacity).
    pub energy_per_frame_j: f64,
    build: Box<dyn Fn(usize) -> Box<dyn Backend>>,
}

impl std::fmt::Debug for CatalogEntry {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.debug_struct("CatalogEntry")
            .field("label", &self.label)
            .field("fps_capacity", &self.fps_capacity)
            .field("busy_power_w", &self.busy_power_w)
            .field("idle_power_w", &self.idle_power_w)
            .field("service_latency_s", &self.service_latency_s)
            .field("energy_per_frame_j", &self.energy_per_frame_j)
            .finish()
    }
}

/// The device kinds the heterogeneous autoscaler may provision, with the
/// selection rule the ISSUE's energy-smoke gate pins down: **scale out
/// with the lowest-power device the policy predicts restores the SLO**.
///
/// A grow decision arrives with a capacity deficit (demanded FPS minus
/// planned FPS). An entry is *feasible* when its capacity covers the
/// deficit and its full-batch service latency fits under the SLO; among
/// feasible entries the minimum busy power wins (ties: larger capacity,
/// then registration order). When nothing is feasible the largest
/// capacity wins (ties: lower power) — the deficit is then split across
/// several grows. Both rules prefer a dominating entry over a dominated
/// one, so the policy can never pick a device that another entry beats
/// on both power and capacity (`tests/energy_ledger.rs` property-tests
/// this).
pub struct DeviceCatalog {
    /// The serving batch size capacities were probed at.
    pub batch: usize,
    entries: Vec<CatalogEntry>,
}

impl DeviceCatalog {
    pub fn new(batch: usize) -> Self {
        Self { batch: batch.max(1), entries: Vec::new() }
    }

    /// Register a device kind, probing capacity/power/latency from a
    /// prototype built with `build(0)`. `build` must be deterministic —
    /// the prototype's figures stand in for every later replica's.
    pub fn register(&mut self, label: &str, build: Box<dyn Fn(usize) -> Box<dyn Backend>>) {
        let probe = build(0);
        let b = self.batch.min(probe.max_batch()).max(1);
        let service_latency_s = probe.batch_latency_s(b);
        let fps_capacity = capacity_fps(probe.as_ref(), self.batch);
        let busy_power_w = probe.power_w(1.0);
        let idle_power_w = probe.power_w(0.0);
        self.register_with(
            label,
            fps_capacity,
            busy_power_w,
            idle_power_w,
            service_latency_s,
            build,
        );
    }

    /// Register an entry with explicit figures (tests and synthetic
    /// fleets; [`register`](Self::register) probes them from a prototype).
    pub fn register_with(
        &mut self,
        label: &str,
        fps_capacity: f64,
        busy_power_w: f64,
        idle_power_w: f64,
        service_latency_s: f64,
        build: Box<dyn Fn(usize) -> Box<dyn Backend>>,
    ) {
        assert!(fps_capacity > 0.0 && busy_power_w > 0.0);
        self.entries.push(CatalogEntry {
            label: label.to_string(),
            fps_capacity,
            busy_power_w,
            idle_power_w,
            service_latency_s,
            energy_per_frame_j: busy_power_w / fps_capacity,
            build,
        });
    }

    pub fn entries(&self) -> &[CatalogEntry] {
        &self.entries
    }

    pub fn is_empty(&self) -> bool {
        self.entries.is_empty()
    }

    /// The cheapest-feasible selection rule (see the type docs). Returns
    /// the index of the entry to provision for a capacity deficit of
    /// `deficit_fps` under a latency objective of `slo_s`.
    pub fn pick(&self, deficit_fps: f64, slo_s: f64) -> usize {
        assert!(!self.entries.is_empty(), "pick on an empty catalog");
        let deficit = deficit_fps.max(0.0);
        let feasible = |e: &CatalogEntry| e.fps_capacity >= deficit && e.service_latency_s <= slo_s;
        let mut best: Option<usize> = None;
        for (i, e) in self.entries.iter().enumerate() {
            let better = match best {
                None => true,
                Some(j) => {
                    let b = &self.entries[j];
                    // Lexicographic preference; the final latency key
                    // makes every strict-dominance axis a tie-breaker,
                    // so a dominated entry can never win.
                    let key = |e: &CatalogEntry, feas: bool| {
                        if feas {
                            (e.busy_power_w, -e.fps_capacity, e.service_latency_s)
                        } else {
                            (-e.fps_capacity, e.busy_power_w, e.service_latency_s)
                        }
                    };
                    match (feasible(e), feasible(b)) {
                        (true, false) => true,
                        (false, true) => false,
                        (f, _) => key(e, f) < key(b, f),
                    }
                }
            };
            if better {
                best = Some(i);
            }
        }
        best.expect("non-empty catalog")
    }

    /// Whether entry `a` is strictly dominated by entry `b`: no worse on
    /// both axes the policy optimizes (power down, capacity up) and
    /// strictly worse on at least one. The `make check` energy-smoke
    /// gate asserts [`pick`](Self::pick) never returns a dominated entry.
    pub fn is_dominated(&self, a: usize, b: usize) -> bool {
        let (ea, eb) = (&self.entries[a], &self.entries[b]);
        eb.busy_power_w <= ea.busy_power_w
            && eb.fps_capacity >= ea.fps_capacity
            && eb.service_latency_s <= ea.service_latency_s
            && (eb.busy_power_w < ea.busy_power_w
                || eb.fps_capacity > ea.fps_capacity
                || eb.service_latency_s < ea.service_latency_s)
    }

    /// Build replica `i` of entry `idx` (labels append the replica
    /// index the driver hands in).
    pub fn build(&self, idx: usize, i: usize) -> Box<dyn Backend> {
        (self.entries[idx].build)(i)
    }

    /// The paper's hardware as a provisioning catalog — the one
    /// registration the CLI, bench and example all share:
    ///
    /// 1. the tuned "ours" ZCU102 build (batch-aware when
    ///    `ours_batched` is given; requires `batch >= 2` then),
    /// 2. optionally the same architecture at the ZCU111 clock
    ///    (schedules transfer: identical architecture, only the clock
    ///    differs, as in [`super::shard::ShardPool::paper_boards`]),
    /// 3. the original 16×16 configuration (slower, cooler — the entry
    ///    that makes cheapest-feasible scale-out interesting),
    /// 4. optionally an embedded-GPU baseline serving `baseline_gop`
    ///    GOP per frame.
    pub fn paper_catalog(
        batch: usize,
        ours: &TuningResult,
        ours_batched: Option<&TuningResult>,
        with_zcu111: bool,
        original: &TuningResult,
        baseline_gop: Option<f64>,
        dispatch_s: f64,
    ) -> Self {
        let mut cat = Self::new(batch);
        let batch = cat.batch;
        {
            let cfg = GemminiConfig::ours_zcu102();
            let t1 = ours.clone();
            let tb = ours_batched.cloned();
            cat.register(
                "ZCU102-Gemmini (ours)",
                Box::new(move |i| {
                    let label = format!("ZCU102-Gemmini (hetero {i})");
                    Box::new(match &tb {
                        Some(tb) => GemminiDevice::from_batch_tuning(
                            &label,
                            Board::Zcu102,
                            cfg.clone(),
                            &t1,
                            tb,
                            batch,
                            dispatch_s,
                        ),
                        None => GemminiDevice::from_tuning(
                            &label,
                            Board::Zcu102,
                            cfg.clone(),
                            &t1,
                            dispatch_s,
                        ),
                    })
                }),
            );
        }
        if with_zcu111 {
            let t1 = ours.clone();
            cat.register(
                "ZCU111-Gemmini (ours)",
                Box::new(move |i| {
                    Box::new(GemminiDevice::from_tuning(
                        &format!("ZCU111-Gemmini (hetero {i})"),
                        Board::Zcu111,
                        GemminiConfig::ours_zcu111(),
                        &t1,
                        dispatch_s,
                    ))
                }),
            );
        }
        {
            let cfg = GemminiConfig::original_zcu102();
            let t = original.clone();
            cat.register(
                "ZCU102-Gemmini (original)",
                Box::new(move |i| {
                    Box::new(GemminiDevice::from_tuning(
                        &format!("ZCU102-Gemmini (original {i})"),
                        Board::Zcu102,
                        cfg.clone(),
                        &t,
                        dispatch_s,
                    ))
                }),
            );
        }
        if let Some(gop) = baseline_gop {
            cat.register(
                "NVIDIA Jetson AGX Xavier",
                Box::new(move |_i| {
                    Box::new(BaselineDevice::new(crate::baselines::xavier(), gop, 8))
                }),
            );
        }
        cat
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::baselines::{xavier, Platform};
    use crate::scheduler::tune_graph;
    use crate::workload::{yolov7_tiny, ModelVariant};

    /// Tuned device plus the cycle model's single-frame latency it was
    /// derived from.
    fn tuned_device() -> (GemminiDevice, f64) {
        let cfg = GemminiConfig::ours_zcu102();
        let mut g = yolov7_tiny(160, ModelVariant::Pruned88, 8);
        crate::passes::replace_activations(&mut g);
        let t = tune_graph(&cfg, &g, 1);
        let frame_s = t.latency_s(&cfg, true);
        (GemminiDevice::from_tuning("zcu102", Board::Zcu102, cfg, &t, DEFAULT_DISPATCH_S), frame_s)
    }

    #[test]
    fn split_frame_flags_weight_stream_exceeding_frame_latency() {
        // Consistent split: remainder survives untouched, no flag.
        let (p, bad) = split_frame_s(0.010, 0.002);
        assert!((p - 0.008).abs() < 1e-15);
        assert!(!bad);
        // DDR-dominated but self-consistent: the 5% floor engages
        // (remainder 2% < floor) without flagging an inconsistency.
        let (p, bad) = split_frame_s(0.010, 0.0098);
        assert_eq!(p, 0.010 * 0.05);
        assert!(!bad);
        // Boundary: weights_s == frame_s leaves zero compute — already
        // an inconsistency, not a legal DDR-bound schedule.
        let (p, bad) = split_frame_s(0.010, 0.010);
        assert_eq!(p, 0.010 * 0.05);
        assert!(bad);
        // Past the boundary the floor masks a *negative* remainder —
        // exactly the case `from_tuning` must surface instead of
        // clamping quietly.
        let (p, bad) = split_frame_s(0.010, 0.012);
        assert_eq!(p, 0.010 * 0.05);
        assert!(bad);
    }

    #[test]
    fn batch_amortizes_per_invocation_cost() {
        let (d, _) = tuned_device();
        let b1 = d.batch_latency_s(1);
        let b8 = d.batch_latency_s(8);
        // Monotone in batch size…
        assert!(b8 > b1);
        // …but sub-linear: 8 frames cost less than 8 single invocations.
        assert!(b8 < 8.0 * b1, "batch 8 {b8} !< 8×{b1}");
        // Per-frame latency strictly improves.
        assert!(b8 / 8.0 < b1);
    }

    #[test]
    fn batch1_matches_cycle_model_plus_dispatch() {
        let (d, frame_s) = tuned_device();
        // weights_s + per_frame_s must reconstruct the cycle model's
        // tuned single-frame latency (exactly, unless the 5% compute
        // floor kicked in, which bounds the deviation at 5%).
        let single = d.batch_latency_s(1) - d.dispatch_s;
        assert!(single > 0.0);
        assert!(
            (single - frame_s).abs() <= 0.05 * frame_s + 1e-15,
            "decomposition {single} drifted from cycle-model latency {frame_s}"
        );
        // Weight streaming is a strict fraction of the frame: the tuned
        // cycles already include moving those bytes at the same DDR
        // bandwidth.
        assert!(d.weights_s > 0.0 && d.weights_s < frame_s);
        assert!(d.per_frame_s > 0.0);
    }

    #[test]
    fn batch_tuned_device_reproduces_measured_operating_points() {
        let cfg = GemminiConfig::ours_zcu102();
        let mut g = yolov7_tiny(160, ModelVariant::Pruned88, 8);
        crate::passes::replace_activations(&mut g);
        let t1 = tune_graph(&cfg, &g, 1);
        let batch = 4;
        let tb = crate::scheduler::tuner::tune_graph_batch(&cfg, &g, 1, batch);
        let d = GemminiDevice::from_batch_tuning(
            "zcu102-b4",
            Board::Zcu102,
            cfg.clone(),
            &t1,
            &tb,
            batch,
            DEFAULT_DISPATCH_S,
        );
        // The linear model passes through the measured batch point
        // (exactly, unless the monotonicity floor kicked in).
        let at_batch = d.batch_latency_s(batch) - d.dispatch_s;
        let measured = tb.latency_s(&cfg, true);
        assert!(
            (at_batch - measured).abs() <= 0.05 * measured,
            "batched point {at_batch} drifted from measured {measured}"
        );
        // Still monotone and sub-linear, and it can hold its own batch.
        assert!(d.per_frame_s > 0.0 && d.weights_s >= 0.0);
        assert!(d.batch_latency_s(batch) < batch as f64 * d.batch_latency_s(1));
        assert!(d.max_batch() >= batch);
        // Anchored to the same single-frame point as the analytic split:
        // intercept + slope reconstructs t1 at batch 1 (up to the floor).
        let analytic = GemminiDevice::from_tuning(
            "zcu102-analytic",
            Board::Zcu102,
            cfg,
            &t1,
            DEFAULT_DISPATCH_S,
        );
        let b1_tuned = d.batch_latency_s(1);
        let b1_analytic = analytic.batch_latency_s(1);
        assert!(
            (b1_tuned - b1_analytic).abs() <= 0.06 * b1_analytic,
            "batch-1 anchors diverge: {b1_tuned} vs {b1_analytic}"
        );
    }

    #[test]
    fn engine_built_replicas_are_cache_hits_and_match_manual_path() {
        let cfg = GemminiConfig::ours_zcu102();
        let mut g = yolov7_tiny(96, ModelVariant::Pruned88, 8);
        crate::passes::replace_activations(&mut g);
        let batch = 4;
        let mut engine = crate::scheduler::TuningEngine::new(cfg.clone());
        let d1 = GemminiDevice::from_engine(
            "replica 0", Board::Zcu102, &mut engine, &g, 1, batch, DEFAULT_DISPATCH_S,
        );
        let d2 = GemminiDevice::from_engine(
            "replica 1", Board::Zcu102, &mut engine, &g, 1, batch, DEFAULT_DISPATCH_S,
        );
        // Replica 2 simulated nothing: its last tuning call was all hits.
        assert_eq!(engine.last_stats().sim_instrs, 0);
        assert_eq!(engine.last_stats().tuned, 0);
        assert!(d1.weights_s == d2.weights_s && d1.per_frame_s == d2.per_frame_s);
        // And the decomposition equals the manual two-tuning construction.
        let t1 = tune_graph(&cfg, &g, 1);
        let tb = crate::scheduler::tune_graph_batch(&cfg, &g, 1, batch);
        let manual = GemminiDevice::from_batch_tuning(
            "manual", Board::Zcu102, cfg, &t1, &tb, batch, DEFAULT_DISPATCH_S,
        );
        assert!(manual.weights_s == d1.weights_s && manual.per_frame_s == d1.per_frame_s);
    }

    #[test]
    fn gemmini_power_scales_with_load() {
        let (d, _) = tuned_device();
        assert!(d.power_w(1.0) > d.power_w(0.0));
        assert!(d.compute_util > 0.0 && d.compute_util <= 1.0);
    }

    #[test]
    fn baseline_device_wraps_platform() {
        let d = BaselineDevice::new(xavier(), 0.5, 8);
        let b1 = d.batch_latency_s(1);
        assert!((b1 - (d.platform.overhead_s + 0.5 / d.platform.sustained_gops)).abs() < 1e-12);
        assert!(d.batch_latency_s(4) < 4.0 * b1);
        assert_eq!(d.max_batch(), 8);
        assert!(d.power_w(0.5) > 0.0);
        assert!((d.gop_per_frame() - 0.5).abs() < 1e-12);
    }

    #[test]
    fn gemmini_device_reports_frame_gop() {
        let (d, _) = tuned_device();
        // 2 ops per MAC over the tuned layers, in giga-ops.
        assert!(d.gop_per_frame() > 0.0);
        assert_eq!(d.gop_per_frame(), d.gop);
    }

    /// A synthetic catalog entry: `fps` capacity at `watts` busy power.
    fn synth(cat: &mut DeviceCatalog, fps: f64, watts: f64) {
        let p = Platform {
            name: "synth",
            overhead_s: 0.0,
            sustained_gops: fps, // 1 GOP per frame → fps frames/s
            power_w: watts,
        };
        let label = format!("synth-{fps:.0}fps-{watts:.0}w");
        cat.register_with(
            &label,
            fps,
            watts,
            watts,
            1.0 / fps,
            Box::new(move |_| Box::new(BaselineDevice::new(p.clone(), 1.0, 1))),
        );
    }

    #[test]
    fn catalog_picks_cheapest_feasible_device() {
        let mut cat = DeviceCatalog::new(1);
        synth(&mut cat, 50.0, 6.0); // cheap, small
        synth(&mut cat, 200.0, 9.0); // fast, mid
        synth(&mut cat, 300.0, 30.0); // fastest, hot
        let slo = 1.0;
        // Small deficit: the 6 W device suffices and wins.
        assert_eq!(cat.pick(30.0, slo), 0);
        // Deficit past the cheap device's capacity: next-cheapest
        // feasible.
        assert_eq!(cat.pick(120.0, slo), 1);
        assert_eq!(cat.pick(250.0, slo), 2);
        // Nothing feasible: the largest capacity takes the first bite.
        assert_eq!(cat.pick(1000.0, slo), 2);
        // Zero deficit (shed-forced grow): cheapest overall.
        assert_eq!(cat.pick(0.0, slo), 0);
    }

    #[test]
    fn catalog_latency_infeasibility_excludes_slow_devices() {
        let mut cat = DeviceCatalog::new(1);
        synth(&mut cat, 50.0, 6.0); // service latency 20 ms
        synth(&mut cat, 200.0, 9.0); // service latency 5 ms
        // With a 10 ms SLO the 6 W device can never restore it.
        assert_eq!(cat.pick(10.0, 0.010), 1);
        // With a roomy SLO it is back on the table.
        assert_eq!(cat.pick(10.0, 0.100), 0);
    }

    #[test]
    fn catalog_dominance_is_detected() {
        let mut cat = DeviceCatalog::new(1);
        synth(&mut cat, 100.0, 10.0);
        synth(&mut cat, 90.0, 12.0); // dominated: slower and hotter
        synth(&mut cat, 300.0, 12.0); // not dominated: faster
        assert!(cat.is_dominated(1, 0));
        assert!(!cat.is_dominated(0, 1));
        assert!(!cat.is_dominated(2, 0));
        assert!(!cat.is_dominated(0, 2));
        // The dominated entry is never picked at any deficit.
        for deficit in [0.0, 50.0, 95.0, 150.0, 500.0] {
            assert_ne!(cat.pick(deficit, 1.0), 1, "deficit {deficit}");
        }
    }

    #[test]
    fn paper_catalog_registers_expected_entries() {
        let cfg = GemminiConfig::ours_zcu102();
        let mut g = yolov7_tiny(96, ModelVariant::Pruned88, 8);
        crate::passes::replace_activations(&mut g);
        let t = tune_graph(&cfg, &g, 1);
        let t_orig = tune_graph(&GemminiConfig::original_zcu102(), &g, 1);
        let full = DeviceCatalog::paper_catalog(
            4,
            &t,
            None,
            true,
            &t_orig,
            Some(g.gops()),
            DEFAULT_DISPATCH_S,
        );
        let labels: Vec<&str> = full.entries().iter().map(|e| e.label.as_str()).collect();
        assert_eq!(
            labels,
            vec![
                "ZCU102-Gemmini (ours)",
                "ZCU111-Gemmini (ours)",
                "ZCU102-Gemmini (original)",
                "NVIDIA Jetson AGX Xavier",
            ]
        );
        // The original config is the cheaper FPGA entry but slower; the
        // GPU is the hottest.
        let (ours, orig, gpu) = (&full.entries()[0], &full.entries()[2], &full.entries()[3]);
        assert!(orig.busy_power_w < ours.busy_power_w);
        assert!(orig.fps_capacity < ours.fps_capacity);
        assert!(gpu.busy_power_w > ours.busy_power_w);
        // Replica labels carry the grow index.
        assert!(full.build(2, 7).name().contains("original 7"));
        // Minimal form: just the ours/original pair.
        let pair =
            DeviceCatalog::paper_catalog(1, &t, None, false, &t_orig, None, DEFAULT_DISPATCH_S);
        assert_eq!(pair.entries().len(), 2);
        assert_eq!(pair.batch, 1);
    }

    #[test]
    fn catalog_probe_matches_built_replicas() {
        let mut cat = DeviceCatalog::new(4);
        cat.register(
            "xavier",
            Box::new(|_i| Box::new(BaselineDevice::new(xavier(), 0.5, 8))),
        );
        let e = &cat.entries()[0];
        let built = cat.build(0, 3);
        let b = 4.min(built.max_batch());
        assert!((e.service_latency_s - built.batch_latency_s(b)).abs() < 1e-12);
        assert!((e.fps_capacity - b as f64 / built.batch_latency_s(b)).abs() < 1e-9);
        assert!((e.busy_power_w - built.power_w(1.0)).abs() < 1e-12);
        assert!(
            (e.energy_per_frame_j - e.busy_power_w / e.fps_capacity).abs() < 1e-12
        );
    }
}
