//! Device abstraction: anything that can serve a batch of inference
//! requests with a predictable service time.
//!
//! A [`GemminiDevice`] derives its batch latency from the existing cycle
//! model: one tuned inference costs `TuningResult::latency_s`, of which
//! the weight-streaming portion is paid *once per batch* under the
//! paper's weight-stationary dataflow (weights stay in the PE array while
//! the batch's activations stream through), and a fixed host-dispatch
//! overhead is paid once per invocation (the TVM-runtime/RPC cost the
//! Section VI system pays per frame). That decomposition is what dynamic
//! batching amortizes. A [`BaselineDevice`] wraps a [`Platform`] from
//! [`crate::baselines`] so the fleet can mix FPGAs with CPUs/GPUs.

use crate::baselines::Platform;
use crate::energy::FpgaPowerModel;
use crate::fpga::resources::Board;
use crate::gemmini::config::GemminiConfig;
use crate::ir::Graph;
use crate::scheduler::{TuningEngine, TuningResult};

/// Default host-dispatch overhead per accelerator invocation, seconds
/// (runtime dispatch + request marshalling; the Section VI system pays
/// this through the TVM runtime and ethernet hop).
pub const DEFAULT_DISPATCH_S: f64 = 2e-3;

/// A serving backend: batch service time + power as a function of load.
pub trait Backend {
    /// Human-readable device name (unique within a pool).
    fn name(&self) -> &str;

    /// Wall-clock seconds to serve a batch of `batch` requests
    /// (`batch >= 1`). Must be monotonically non-decreasing in `batch`.
    fn batch_latency_s(&self, batch: usize) -> f64;

    /// Largest batch the device can hold (activation memory bound).
    fn max_batch(&self) -> usize {
        32
    }

    /// Average board power at a busy fraction in `[0, 1]`.
    fn power_w(&self, busy_frac: f64) -> f64;
}

/// A tuned Gemmini accelerator as a serving device.
#[derive(Debug, Clone)]
pub struct GemminiDevice {
    pub label: String,
    pub board: Board,
    pub config: GemminiConfig,
    /// Host overhead paid once per invocation, s.
    pub dispatch_s: f64,
    /// Weight-streaming time paid once per batch (weight-stationary
    /// reuse), s.
    pub weights_s: f64,
    /// Per-frame compute + activation-movement time, s.
    pub per_frame_s: f64,
    /// MAC-array utilization of the tuned schedule while computing
    /// (from [`TuningResult::utilization`]); scales dynamic power.
    pub compute_util: f64,
    batch_cap: usize,
}

impl GemminiDevice {
    /// Build a device from a tuned model on a config. The weight volume
    /// comes from the tuned layers' GEMM shapes (`k×n` int8 weights per
    /// layer); its streaming time is DDR-bandwidth-bound and independent
    /// of the PL clock, exactly like the cycle model's DMA path.
    pub fn from_tuning(
        label: &str,
        board: Board,
        config: GemminiConfig,
        tuning: &TuningResult,
        dispatch_s: f64,
    ) -> Self {
        let weight_bytes: u64 =
            tuning.layers.iter().map(|l| (l.geom.k * l.geom.n) as u64).sum();
        let weights_s = weight_bytes as f64 / (config.ddr_gbs * 1e9);
        let frame_s = tuning.latency_s(&config, true);
        // The single-frame latency includes one weight pass; everything
        // else (compute, activation movement) repeats per frame.
        let per_frame_s = (frame_s - weights_s).max(frame_s * 0.05);
        let compute_util = tuning.utilization(&config, true);
        // Batch activations must fit the accumulator working set; a
        // coarse bound that scales with on-chip memory.
        let batch_cap = (config.accumulator_kib / 16).clamp(1, 64);
        Self {
            label: label.to_string(),
            board,
            config,
            dispatch_s,
            weights_s,
            per_frame_s,
            compute_util,
            batch_cap,
        }
    }

    /// Build a device through a shared [`TuningEngine`]: tunes the graph
    /// at batch 1 (and, when `batch >= 2`, at the serving batch size) and
    /// derives the latency decomposition like
    /// [`from_tuning`](Self::from_tuning) /
    /// [`from_batch_tuning`](Self::from_batch_tuning). Because the engine
    /// memoizes by geometry (and can be cache-file backed), stamping out N
    /// fleet replicas costs one schedule search, not N — replicas 2..N are
    /// pure cache hits.
    pub fn from_engine(
        label: &str,
        board: Board,
        engine: &mut TuningEngine,
        g: &Graph,
        measure_k: usize,
        batch: usize,
        dispatch_s: f64,
    ) -> Self {
        let config = engine.config().clone();
        let single = engine.tune_graph(g, measure_k);
        if batch >= 2 {
            let batched = engine.tune_graph_batch(g, measure_k, batch);
            Self::from_batch_tuning(label, board, config, &single, &batched, batch, dispatch_s)
        } else {
            Self::from_tuning(label, board, config, &single, dispatch_s)
        }
    }

    /// Build a device whose batch-latency decomposition is *measured* by
    /// batch-aware tuning instead of analytically split: `single` is the
    /// graph tuned at batch 1 ([`crate::scheduler::tune_graph`]) and
    /// `batched` the same graph tuned for `batch` frames per invocation
    /// ([`crate::scheduler::tune_graph_batch`]). The marginal per-frame
    /// cost is the measured slope between the two operating points (on
    /// schedules searched for the batched GEMM shapes), and the per-batch
    /// intercept is whatever those schedules could *not* amortize — so
    /// the serving model inherits the cycle model's view of batching
    /// rather than assuming the weight stream is the only shared cost.
    pub fn from_batch_tuning(
        label: &str,
        board: Board,
        config: GemminiConfig,
        single: &TuningResult,
        batched: &TuningResult,
        batch: usize,
        dispatch_s: f64,
    ) -> Self {
        assert!(batch >= 2, "batch-aware tuning needs batch >= 2 (got {batch})");
        let t1 = single.latency_s(&config, true);
        let tb = batched.latency_s(&config, true);
        // Slope/intercept of the measured (1, t1) → (batch, tb) line,
        // floored so the model stays strictly monotone in batch size.
        let per_frame_s = ((tb - t1) / (batch as f64 - 1.0)).max(0.01 * t1).min(t1);
        let weights_s = (t1 - per_frame_s).max(0.0);
        let compute_util = batched.utilization(&config, true);
        // A device tuned for `batch` must admit at least that batch.
        let batch_cap = (config.accumulator_kib / 16).clamp(1, 64).max(batch);
        Self {
            label: label.to_string(),
            board,
            config,
            dispatch_s,
            weights_s,
            per_frame_s,
            compute_util,
            batch_cap,
        }
    }
}

impl Backend for GemminiDevice {
    fn name(&self) -> &str {
        &self.label
    }

    fn batch_latency_s(&self, batch: usize) -> f64 {
        self.dispatch_s + self.weights_s + batch as f64 * self.per_frame_s
    }

    fn max_batch(&self) -> usize {
        self.batch_cap
    }

    fn power_w(&self, busy_frac: f64) -> f64 {
        let model = FpgaPowerModel::for_board(self.board);
        model.power_w(&self.config, busy_frac.clamp(0.0, 1.0) * self.compute_util)
    }
}

/// A CPU/GPU baseline platform as a serving device (reuses the calibrated
/// Figure 7 / Table IV models). Baselines gain less from batching: only
/// the per-invocation overhead amortizes.
#[derive(Debug, Clone)]
pub struct BaselineDevice {
    pub platform: Platform,
    /// Workload size per frame, giga-operations.
    pub gop: f64,
    batch_cap: usize,
}

impl BaselineDevice {
    pub fn new(platform: Platform, gop: f64, batch_cap: usize) -> Self {
        Self { platform, gop, batch_cap: batch_cap.max(1) }
    }
}

impl Backend for BaselineDevice {
    fn name(&self) -> &str {
        self.platform.name
    }

    fn batch_latency_s(&self, batch: usize) -> f64 {
        self.platform.overhead_s + batch as f64 * self.gop / self.platform.sustained_gops
    }

    fn max_batch(&self) -> usize {
        self.batch_cap
    }

    fn power_w(&self, _busy_frac: f64) -> f64 {
        self.platform.power_w
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::baselines::xavier;
    use crate::scheduler::tune_graph;
    use crate::workload::{yolov7_tiny, ModelVariant};

    /// Tuned device plus the cycle model's single-frame latency it was
    /// derived from.
    fn tuned_device() -> (GemminiDevice, f64) {
        let cfg = GemminiConfig::ours_zcu102();
        let mut g = yolov7_tiny(160, ModelVariant::Pruned88, 8);
        crate::passes::replace_activations(&mut g);
        let t = tune_graph(&cfg, &g, 1);
        let frame_s = t.latency_s(&cfg, true);
        (GemminiDevice::from_tuning("zcu102", Board::Zcu102, cfg, &t, DEFAULT_DISPATCH_S), frame_s)
    }

    #[test]
    fn batch_amortizes_per_invocation_cost() {
        let (d, _) = tuned_device();
        let b1 = d.batch_latency_s(1);
        let b8 = d.batch_latency_s(8);
        // Monotone in batch size…
        assert!(b8 > b1);
        // …but sub-linear: 8 frames cost less than 8 single invocations.
        assert!(b8 < 8.0 * b1, "batch 8 {b8} !< 8×{b1}");
        // Per-frame latency strictly improves.
        assert!(b8 / 8.0 < b1);
    }

    #[test]
    fn batch1_matches_cycle_model_plus_dispatch() {
        let (d, frame_s) = tuned_device();
        // weights_s + per_frame_s must reconstruct the cycle model's
        // tuned single-frame latency (exactly, unless the 5% compute
        // floor kicked in, which bounds the deviation at 5%).
        let single = d.batch_latency_s(1) - d.dispatch_s;
        assert!(single > 0.0);
        assert!(
            (single - frame_s).abs() <= 0.05 * frame_s + 1e-15,
            "decomposition {single} drifted from cycle-model latency {frame_s}"
        );
        // Weight streaming is a strict fraction of the frame: the tuned
        // cycles already include moving those bytes at the same DDR
        // bandwidth.
        assert!(d.weights_s > 0.0 && d.weights_s < frame_s);
        assert!(d.per_frame_s > 0.0);
    }

    #[test]
    fn batch_tuned_device_reproduces_measured_operating_points() {
        let cfg = GemminiConfig::ours_zcu102();
        let mut g = yolov7_tiny(160, ModelVariant::Pruned88, 8);
        crate::passes::replace_activations(&mut g);
        let t1 = tune_graph(&cfg, &g, 1);
        let batch = 4;
        let tb = crate::scheduler::tuner::tune_graph_batch(&cfg, &g, 1, batch);
        let d = GemminiDevice::from_batch_tuning(
            "zcu102-b4",
            Board::Zcu102,
            cfg.clone(),
            &t1,
            &tb,
            batch,
            DEFAULT_DISPATCH_S,
        );
        // The linear model passes through the measured batch point
        // (exactly, unless the monotonicity floor kicked in).
        let at_batch = d.batch_latency_s(batch) - d.dispatch_s;
        let measured = tb.latency_s(&cfg, true);
        assert!(
            (at_batch - measured).abs() <= 0.05 * measured,
            "batched point {at_batch} drifted from measured {measured}"
        );
        // Still monotone and sub-linear, and it can hold its own batch.
        assert!(d.per_frame_s > 0.0 && d.weights_s >= 0.0);
        assert!(d.batch_latency_s(batch) < batch as f64 * d.batch_latency_s(1));
        assert!(d.max_batch() >= batch);
        // Anchored to the same single-frame point as the analytic split:
        // intercept + slope reconstructs t1 at batch 1 (up to the floor).
        let analytic = GemminiDevice::from_tuning(
            "zcu102-analytic",
            Board::Zcu102,
            cfg,
            &t1,
            DEFAULT_DISPATCH_S,
        );
        let b1_tuned = d.batch_latency_s(1);
        let b1_analytic = analytic.batch_latency_s(1);
        assert!(
            (b1_tuned - b1_analytic).abs() <= 0.06 * b1_analytic,
            "batch-1 anchors diverge: {b1_tuned} vs {b1_analytic}"
        );
    }

    #[test]
    fn engine_built_replicas_are_cache_hits_and_match_manual_path() {
        let cfg = GemminiConfig::ours_zcu102();
        let mut g = yolov7_tiny(96, ModelVariant::Pruned88, 8);
        crate::passes::replace_activations(&mut g);
        let batch = 4;
        let mut engine = crate::scheduler::TuningEngine::new(cfg.clone());
        let d1 = GemminiDevice::from_engine(
            "replica 0", Board::Zcu102, &mut engine, &g, 1, batch, DEFAULT_DISPATCH_S,
        );
        let d2 = GemminiDevice::from_engine(
            "replica 1", Board::Zcu102, &mut engine, &g, 1, batch, DEFAULT_DISPATCH_S,
        );
        // Replica 2 simulated nothing: its last tuning call was all hits.
        assert_eq!(engine.last_stats().sim_instrs, 0);
        assert_eq!(engine.last_stats().tuned, 0);
        assert!(d1.weights_s == d2.weights_s && d1.per_frame_s == d2.per_frame_s);
        // And the decomposition equals the manual two-tuning construction.
        let t1 = tune_graph(&cfg, &g, 1);
        let tb = crate::scheduler::tune_graph_batch(&cfg, &g, 1, batch);
        let manual = GemminiDevice::from_batch_tuning(
            "manual", Board::Zcu102, cfg, &t1, &tb, batch, DEFAULT_DISPATCH_S,
        );
        assert!(manual.weights_s == d1.weights_s && manual.per_frame_s == d1.per_frame_s);
    }

    #[test]
    fn gemmini_power_scales_with_load() {
        let (d, _) = tuned_device();
        assert!(d.power_w(1.0) > d.power_w(0.0));
        assert!(d.compute_util > 0.0 && d.compute_util <= 1.0);
    }

    #[test]
    fn baseline_device_wraps_platform() {
        let d = BaselineDevice::new(xavier(), 0.5, 8);
        let b1 = d.batch_latency_s(1);
        assert!((b1 - (d.platform.overhead_s + 0.5 / d.platform.sustained_gops)).abs() < 1e-12);
        assert!(d.batch_latency_s(4) < 4.0 * b1);
        assert_eq!(d.max_batch(), 8);
        assert!(d.power_w(0.5) > 0.0);
    }
}
