//! The live threaded serving runtime: real worker threads behind the
//! same [`Backend`]/[`FleetReport`] interfaces as the DES.
//!
//! Everything else in `serving/` *models* the fleet; this module *runs*
//! it. One worker thread per shard consumes from a bounded
//! [`SharedTopic`] front door (the
//! [`Topic::try_publish`](crate::pipeline::Topic::try_publish) overflow
//! semantics end to end, per-class
//! [`OverflowPolicy`](crate::pipeline::OverflowPolicy) mapped from
//! [`ShedPolicy::overflow_for`](super::ShedPolicy::overflow_for)), a
//! wall-clock batcher honors the same
//! max-batch/max-wait/class-`wait_factor` rules as the DES batcher
//! (literally the same [`BatchPolicy::decide`]), the front-door router
//! does least-outstanding-work routing over the live shards' queue
//! depths and busy horizons, and shutdown drains every queued frame
//! before the shards retire — the
//! [`TrafficPipeline::shutdown_drain`](crate::pipeline::TrafficPipeline::shutdown_drain)
//! close-then-drain-then-join contract at fleet scale.
//!
//! Two clocks drive it ([`ClockMode`]):
//!
//! - **Wall**: threads genuinely sleep and race; `time_scale` maps
//!   modeled seconds to wall seconds so a 10 s trace can smoke-test in
//!   2 s. Service time is the backend's *modeled* batch latency (there
//!   is no FPGA in this container), so what the wall clock exercises is
//!   the real concurrency structure — channels, eviction under racing
//!   consumers, condvar wakeups, drain ordering — not device physics.
//! - **Virtual**: a conservative turn-based protocol serializes the
//!   threads on a shared virtual clock: the participant with the
//!   earliest pending event (ties to the lowest index, front door
//!   first) holds the turn, everyone else waits. Execution order
//!   becomes a pure function of the trace — byte-identical reports
//!   across runs *and across worker-thread counts* — which is what lets
//!   `tests/live_vs_des.rs` use the DES as a differential oracle for
//!   this runtime.
//!
//! The live path deliberately has **no work stealing** (workers own
//! their queues; cross-thread queue surgery is exactly the shared
//! mutable state this design avoids), so differential comparisons run
//! the DES with `work_stealing: false` — [`serve_live`] asserts it.
//!
//! When [`SimConfig::faults`] carries a [`FaultPlan`], the same crash /
//! straggler / spike / link-drop schedule the DES injects plays out on
//! the real threads: each worker owns its shard's crashes (truth), the
//! router only learns at watchdog detection (knowledge), stranded and
//! queued work re-enters service through backoff-staged re-dispatch to
//! healthy shards, and a shared resolved-id set enforces the
//! exactly-once outcome invariant across racing copies. The
//! [`LiveConfig::drain_timeout_s`] watchdog bounds shutdown: a worker
//! whose in-flight batch outlives the drain deadline abandons it (the
//! batch expires, accounted exactly once) instead of deadlocking the
//! close-then-drain-then-join contract.

use std::collections::{HashSet, VecDeque};
use std::sync::atomic::{AtomicBool, AtomicU64, AtomicUsize, Ordering};
use std::sync::mpsc::TryRecvError;
use std::sync::{Arc, Condvar, Mutex};
use std::thread;
use std::time::{Duration, Instant};

use crate::pipeline::{PublishOutcome, SharedTopic};

use super::admission::ClassQuota;
use super::autoscale::{ScaleEventKind, ScalingEvent};
use super::batcher::{BatchPolicy, Decision};
use super::device::Backend;
use super::faults::FaultPlan;
use super::ladder::VariantLadder;
use super::metrics::{EnergyLedger, FleetMetrics, FleetReport};
use super::shard::{Lifecycle, ShardPool};
use super::sim::SimConfig;
use super::{Request, RequestOutcome, ShedPolicy};

/// Which clock paces the runtime.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum ClockMode {
    /// Deterministic turn-based virtual time (tests, differential runs).
    Virtual,
    /// Real time, scaled by [`LiveConfig::time_scale`].
    Wall,
}

/// Knobs of the live runtime.
#[derive(Debug, Clone)]
pub struct LiveConfig {
    /// Worker threads serving the shards (dealt round-robin);
    /// `0` means one thread per shard. In virtual-clock mode the report
    /// is byte-identical for any thread count — a property
    /// `tests/serving_invariants.rs` pins down.
    pub threads: usize,
    pub clock: ClockMode,
    /// Wall seconds per modeled second (wall mode only): `0.25` runs a
    /// 10 s trace in ~2.5 s of wall time.
    pub time_scale: f64,
    /// Shutdown watchdog: once the topics close, a worker whose
    /// in-flight batch is still unfinished this many modeled seconds
    /// later abandons it — the batch's requests expire (exactly-once
    /// accounted, shed-flagged outcomes) and the worker leaves as
    /// failed, so one hung shard can never deadlock `shutdown_drain`.
    /// `f64::INFINITY` (the default) waits forever, the historical
    /// behavior.
    pub drain_timeout_s: f64,
}

impl Default for LiveConfig {
    fn default() -> Self {
        Self { threads: 0, clock: ClockMode::Wall, time_scale: 1.0, drain_timeout_s: f64::INFINITY }
    }
}

impl LiveConfig {
    /// The deterministic test configuration.
    pub fn virtual_clock() -> Self {
        Self { clock: ClockMode::Virtual, ..Default::default() }
    }

    /// Wall clock at `time_scale` wall seconds per modeled second.
    pub fn wall(time_scale: f64) -> Self {
        Self { clock: ClockMode::Wall, time_scale: time_scale.max(1e-3), ..Default::default() }
    }

    pub fn with_threads(mut self, threads: usize) -> Self {
        self.threads = threads;
        self
    }

    /// Arm the shutdown-drain watchdog (modeled seconds).
    pub fn with_drain_timeout(mut self, timeout_s: f64) -> Self {
        self.drain_timeout_s = timeout_s;
        self
    }
}

// ---------------------------------------------------------------------
// The virtual clock: a conservative turn-based protocol.
// ---------------------------------------------------------------------

/// Where a participant stands in the turn protocol.
#[derive(Debug, Clone, Copy, PartialEq)]
enum Slot {
    /// Holds the turn and is executing its slice.
    Running,
    /// Parked until this virtual time (`INFINITY` = waiting for input).
    Until(f64),
    /// Left the protocol for good.
    Done,
}

struct VcState {
    now: f64,
    slots: Vec<Slot>,
}

/// The shared virtual clock. Invariant: at most one participant is
/// `Running` at any instant; the turn is handed to the earliest parked
/// participant (ties to the lowest index, so the front door — index
/// 0 — admits arrivals before shards complete batches stamped at the
/// same instant, exactly the DES driver's step order).
struct VirtualClock {
    state: Mutex<VcState>,
    cv: Condvar,
}

impl VirtualClock {
    /// Participant 0 (the front door) starts with the turn; shard
    /// workers start idle-parked.
    fn new(participants: usize) -> Self {
        let mut slots = vec![Slot::Until(f64::INFINITY); participants];
        slots[0] = Slot::Running;
        Self { state: Mutex::new(VcState { now: 0.0, slots }), cv: Condvar::new() }
    }

    /// Advance the clock to the earliest parked participant and give it
    /// the turn. No-op while someone is still running or every live
    /// participant is idle-parked.
    fn hand_off(s: &mut VcState) {
        if s.slots.iter().any(|x| matches!(x, Slot::Running)) {
            return;
        }
        let mut best: Option<(f64, usize)> = None;
        for (i, x) in s.slots.iter().enumerate() {
            if let Slot::Until(t) = x {
                if t.is_finite() && best.map_or(true, |(bt, _)| *t < bt) {
                    best = Some((*t, i));
                }
            }
        }
        if let Some((t, i)) = best {
            s.now = s.now.max(t);
            s.slots[i] = Slot::Running;
        }
    }

    /// Give the turn away until virtual time `t` (never parks in the
    /// past — a stale deadline re-runs at the current instant).
    fn park(&self, p: usize, t: f64) {
        let mut s = self.state.lock().expect("clock lock");
        let until = t.max(s.now);
        s.slots[p] = Slot::Until(until);
        Self::hand_off(&mut s);
        drop(s);
        self.cv.notify_all();
    }

    /// Leave the protocol (drained shard retiring, or the front door
    /// after the trace closes).
    fn done(&self, p: usize) {
        let mut s = self.state.lock().expect("clock lock");
        s.slots[p] = Slot::Done;
        Self::hand_off(&mut s);
        drop(s);
        self.cv.notify_all();
    }

    /// Called by the turn holder after publishing into `p`'s queue:
    /// pull an idle or later-parked consumer forward to the current
    /// instant so it observes the message in event order.
    fn nudge(&self, p: usize) {
        let mut s = self.state.lock().expect("clock lock");
        if let Slot::Until(t) = s.slots[p] {
            if t > s.now {
                let now = s.now;
                s.slots[p] = Slot::Until(now);
            }
        }
    }

    /// Wake every idle-parked participant at the current instant (the
    /// shutdown broadcast: they re-check their closed topics).
    fn wake_idle(&self) {
        let mut s = self.state.lock().expect("clock lock");
        let now = s.now;
        for x in s.slots.iter_mut() {
            if matches!(x, Slot::Until(t) if t.is_infinite()) {
                *x = Slot::Until(now);
            }
        }
    }

    /// Pull *every* parked participant forward to the current instant —
    /// the fault-mode shutdown broadcast. Busy workers re-check the
    /// drain deadline, dead workers flush; `step` is idempotent for a
    /// shard with nothing due, so early wakes never change a decision.
    fn wake_all(&self) {
        let mut s = self.state.lock().expect("clock lock");
        let now = s.now;
        for x in s.slots.iter_mut() {
            if matches!(x, Slot::Until(t) if *t > now) {
                *x = Slot::Until(now);
            }
        }
    }

    /// Block until one of `ids` holds the turn; `None` once all of them
    /// are done.
    fn wait_any(&self, ids: &[usize]) -> Option<(usize, f64)> {
        let mut s = self.state.lock().expect("clock lock");
        loop {
            if ids.iter().all(|&p| matches!(s.slots[p], Slot::Done)) {
                return None;
            }
            if let Some(&p) = ids.iter().find(|&&p| matches!(s.slots[p], Slot::Running)) {
                return Some((p, s.now));
            }
            s = self.cv.wait(s).expect("clock wait");
        }
    }

    /// The final virtual time (meaningful once every participant is
    /// done).
    fn final_now(&self) -> f64 {
        self.state.lock().expect("clock lock").now
    }
}

// ---------------------------------------------------------------------
// The wall clock + per-thread wakeups.
// ---------------------------------------------------------------------

/// Monotonic wall time mapped into modeled seconds.
struct WallClock {
    start: Instant,
    /// Wall seconds per modeled second.
    scale: f64,
}

impl WallClock {
    fn now(&self) -> f64 {
        self.start.elapsed().as_secs_f64() / self.scale
    }

    /// Sleep (in bounded slices) until modeled time `t`.
    fn sleep_until(&self, t: f64) {
        loop {
            let now = self.now();
            if now >= t {
                return;
            }
            let wall = ((t - now) * self.scale).min(0.05);
            thread::sleep(Duration::from_secs_f64(wall.max(0.0)));
        }
    }
}

/// A counting wakeup: the router kicks the worker thread owning a shard
/// after publishing to it, so wall-mode workers block instead of
/// polling.
struct Kick {
    count: Mutex<u64>,
    cv: Condvar,
}

impl Kick {
    fn new() -> Self {
        Self { count: Mutex::new(0), cv: Condvar::new() }
    }

    fn seen(&self) -> u64 {
        *self.count.lock().expect("kick lock")
    }

    fn kick(&self) {
        *self.count.lock().expect("kick lock") += 1;
        self.cv.notify_all();
    }

    /// Wait until the count moves past `seen` or `timeout` elapses
    /// (spurious wakeups are harmless: the worker re-scans its shards).
    fn wait(&self, seen: u64, timeout: Option<Duration>) {
        let g = self.count.lock().expect("kick lock");
        if *g != seen {
            return;
        }
        match timeout {
            Some(d) => drop(self.cv.wait_timeout(g, d).expect("kick wait")),
            None => drop(self.cv.wait(g).expect("kick wait")),
        }
    }
}

// ---------------------------------------------------------------------
// Shards.
// ---------------------------------------------------------------------

/// The router-visible face of one live shard.
struct ShardShared {
    /// Admitted-but-undispatched requests (topic + worker buffer) —
    /// the live "queue depth" the router routes on.
    queued: AtomicUsize,
    busy: AtomicBool,
    /// `f64::to_bits` of the in-flight batch's completion time.
    free_at_bits: AtomicU64,
    /// Known-failed (watchdog-detected): the router stops routing here.
    /// Truth lags knowledge — a crashed-but-undetected shard keeps this
    /// `false` and keeps receiving work, exactly like the DES.
    down: AtomicBool,
}

impl ShardShared {
    fn new() -> Self {
        Self {
            queued: AtomicUsize::new(0),
            busy: AtomicBool::new(false),
            free_at_bits: AtomicU64::new(0),
            down: AtomicBool::new(false),
        }
    }

    /// The DES [`outstanding_s`](crate::serving::shard::DeviceState::outstanding_s)
    /// estimate over live state: remaining service of the in-flight
    /// batch plus the modeled service of the queue with one more
    /// request appended.
    fn outstanding_s(&self, backend: &dyn Backend, now: f64) -> f64 {
        let busy_rem = if self.busy.load(Ordering::SeqCst) {
            (f64::from_bits(self.free_at_bits.load(Ordering::SeqCst)) - now).max(0.0)
        } else {
            0.0
        };
        busy_rem + backend.batch_latency_s(self.queued.load(Ordering::SeqCst) + 1)
    }
}

// ---------------------------------------------------------------------
// Fault machinery (the live mirror of the DES `FaultRt`).
// ---------------------------------------------------------------------

/// Per-shard fault state. The DES keeps one `FaultRt` for the whole
/// pool; the live runtime splits it per worker because each worker owns
/// its shard's failures — only the resolved-id set (the exactly-once
/// gate) is shared, plus read-only handles to every shard's topic and
/// router face for failover re-dispatch.
struct LiveFaults {
    plan: FaultPlan,
    /// Ids with a terminal outcome (completed / shed / expired), shared
    /// with the front door and every worker: first resolution wins,
    /// later completions of stale copies are suppressed.
    resolved: Arc<Mutex<HashSet<u64>>>,
    /// Failover targets: every shard's topic, router face, and backend.
    topics: Vec<Arc<SharedTopic<Request>>>,
    shared: Vec<Arc<ShardShared>>,
    backends: Vec<Arc<dyn Backend>>,
    shed: ShedPolicy,
    /// This shard's scheduled crash instants, ascending; `next_crash`
    /// indexes the first not yet injected.
    crashes: Vec<f64>,
    next_crash: usize,
    /// Truth: crashed, watchdog not yet fired.
    crashed: bool,
    /// Crash instant (base of the MTTR measurement).
    crash_t: f64,
    /// Watchdog fire time for the current crash (recovery only).
    detect_at: f64,
    /// Reboot completion time (recovery with reboot only).
    ready_at: f64,
    /// Straggler check armed against the in-flight batch.
    straggler_at: f64,
    /// Knowledge: detected as failed, excluded from routing.
    is_down: bool,
    rebooting: bool,
    /// The in-flight batch stranded by the current crash, awaiting
    /// detection (or end-of-run expiry).
    stranded: Vec<Request>,
    /// Requests staged for re-dispatch: `(redispatch_at, copy)`.
    pending: Vec<(f64, Request)>,
    /// Dispatched-batch ordinal (the spike draw's index).
    ordinal: u64,
}

/// Stage `r` for re-dispatch a backoff after `t`, or expire it when the
/// retry budget / freshness deadline is spent — the live mirror of the
/// DES `FaultRt::requeue`, shared by the workers and the front door.
/// Expired requests get a shed-flagged outcome but count in
/// [`FaultStats::expired`](super::faults::FaultStats), *not* the fleet
/// shed counter: the conservation law is
/// `offered == completed + shed + expired`.
#[allow(clippy::too_many_arguments)]
fn stage_or_expire(
    plan: &FaultPlan,
    r: Request,
    t: f64,
    resolved: &Mutex<HashSet<u64>>,
    metrics: &Mutex<FleetMetrics>,
    outcomes: &Mutex<Vec<RequestOutcome>>,
    pending: &mut Vec<(f64, Request)>,
) {
    if resolved.lock().expect("resolved lock").contains(&r.id) {
        return;
    }
    let expire = |r: Request| {
        resolved.lock().expect("resolved lock").insert(r.id);
        metrics.lock().expect("metrics lock").faults.expired += 1;
        outcomes.lock().expect("outcomes lock").push(RequestOutcome {
            id: r.id,
            camera: r.camera,
            t_s: t,
            shed: true,
            rung: r.rung,
        });
    };
    let Some(rp) = plan.recovery.as_ref() else {
        // No recovery armed: the request dies with its shard.
        expire(r);
        return;
    };
    let at = t + rp.backoff_base_s * 2f64.powi(r.retries as i32);
    if u32::from(r.retries) + 1 > u32::from(rp.retry_budget)
        || at - r.arrival_s > rp.retry_deadline_s
    {
        expire(r);
        return;
    }
    let mut copy = r;
    copy.retries += 1;
    metrics.lock().expect("metrics lock").faults.retries += 1;
    pending.push((at, copy));
}

/// Re-dispatch every staged copy due by `now` to the least-loaded shard
/// the router still believes in (deterministic order: fire time, then
/// id — the DES drain order). Retry copies bypass the front-door quota
/// and link drops: the request already paid both on arrival. Returns
/// the shards to wake via `wakes`.
#[allow(clippy::too_many_arguments)]
fn redispatch_staged(
    plan: &FaultPlan,
    now: f64,
    pending: &mut Vec<(f64, Request)>,
    resolved: &Mutex<HashSet<u64>>,
    metrics: &Mutex<FleetMetrics>,
    outcomes: &Mutex<Vec<RequestOutcome>>,
    topics: &[Arc<SharedTopic<Request>>],
    shared: &[Arc<ShardShared>],
    backends: &[Arc<dyn Backend>],
    shed: ShedPolicy,
    wakes: &mut Vec<usize>,
) {
    pending.sort_by(|a, b| {
        a.0.partial_cmp(&b.0).expect("finite redispatch times").then(a.1.id.cmp(&b.1.id))
    });
    while let Some(pos) = pending.iter().position(|p| p.0 <= now) {
        let (_, r) = pending.remove(pos);
        if resolved.lock().expect("resolved lock").contains(&r.id) {
            continue;
        }
        // Least outstanding work over the shards not known-failed.
        let mut best: Option<(f64, usize)> = None;
        for (i, sh) in shared.iter().enumerate() {
            if sh.down.load(Ordering::SeqCst) {
                continue;
            }
            let est = sh.outstanding_s(backends[i].as_ref(), now);
            if best.map_or(true, |(b, _)| est < b) {
                best = Some((est, i));
            }
        }
        let Some((_, best)) = best else {
            // Nothing routable anywhere right now: back off and try
            // again (or expire on budget/deadline).
            stage_or_expire(plan, r, now, resolved, metrics, outcomes, pending);
            continue;
        };
        let policy = shed.overflow_for(r.class);
        match topics[best].try_publish(r, policy) {
            PublishOutcome::Delivered => {
                metrics.lock().expect("metrics lock").faults.redispatched += 1;
                shared[best].queued.fetch_add(1, Ordering::SeqCst);
                wakes.push(best);
            }
            PublishOutcome::DeliveredDroppedOldest(old) => {
                metrics.lock().expect("metrics lock").faults.redispatched += 1;
                wakes.push(best);
                // An evicted re-dispatch copy is displaced, not
                // refused: it goes back through the retry path.
                if old.retries > 0 {
                    stage_or_expire(plan, old, now, resolved, metrics, outcomes, pending);
                } else {
                    resolved.lock().expect("resolved lock").insert(old.id);
                    metrics.lock().expect("metrics lock").record_shed(old.class);
                    outcomes.lock().expect("outcomes lock").push(RequestOutcome {
                        id: old.id,
                        camera: old.camera,
                        t_s: now,
                        shed: true,
                        rung: old.rung,
                    });
                }
            }
            PublishOutcome::Rejected | PublishOutcome::Closed => {
                stage_or_expire(plan, r, now, resolved, metrics, outcomes, pending);
            }
        }
    }
}

/// What a shard's slice of work decided.
enum Step {
    /// Re-run the shard at this modeled time (or earlier on a nudge).
    Park(f64),
    /// Drained and retired.
    Done,
}

/// One live shard's worker-side state machine. `step` runs one slice:
/// finish a due batch, refill the batching buffer from the topic,
/// decide (dispatch / wait / idle) — the same sequence the DES driver's
/// `settle` performs per device, minus stealing.
struct ShardRuntime {
    idx: usize,
    backend: Arc<dyn Backend>,
    topic: Arc<SharedTopic<Request>>,
    shared: Arc<ShardShared>,
    policy: BatchPolicy,
    /// The run's degradation ladder, when
    /// [`AdmissionPolicy::Degrade`](super::AdmissionPolicy::Degrade) is
    /// in force — mixed-batch service times use it exactly as the DES
    /// does.
    ladder: Option<VariantLadder>,
    /// [`BatchPolicy::effective_cap`] for this backend: the refill
    /// headroom, so the worker never buffers more than one closable
    /// batch and the topic keeps playing the DES's bounded queue.
    cap: usize,
    local: VecDeque<Request>,
    in_flight: Vec<Request>,
    /// Drained batch buffer parked for reuse by the next dispatch, so
    /// the steady-state worker loop allocates no batch vectors (the DES
    /// dispatcher recycles the same way).
    spare: Vec<Request>,
    busy: bool,
    busy_until: f64,
    closed: bool,
    idle_w: f64,
    busy_w: f64,
    /// Modeled time energy has been accrued to.
    last_accrued: f64,
    metrics: Arc<Mutex<FleetMetrics>>,
    ledger: Arc<Mutex<EnergyLedger>>,
    max_completion: Arc<Mutex<f64>>,
    accrued_to: Arc<Mutex<Vec<f64>>>,
    retire_log: Arc<Mutex<Vec<ScalingEvent>>>,
    serving_count: Arc<AtomicUsize>,
    outcomes: Arc<Mutex<Vec<RequestOutcome>>>,
    /// Fault-injection state when the run carries a [`FaultPlan`].
    faults: Option<LiveFaults>,
    /// `f64::to_bits` of the close instant (`INFINITY` until the front
    /// door closes the topics) — the shutdown watchdog's reference.
    closed_at: Arc<AtomicU64>,
    /// [`LiveConfig::drain_timeout_s`].
    drain_timeout_s: f64,
    /// Shards that left as failed (watchdog-detected or
    /// shutdown-abandoned) — the report marks their device state.
    final_failed: Arc<Mutex<Vec<usize>>>,
}

impl ShardRuntime {
    /// Accrue device power over `[last_accrued, to]` into the shared
    /// ledger (all live time is `Active`-state time, like a DES fixed
    /// pool).
    fn accrue(&mut self, to: f64, busy: bool) {
        if to > self.last_accrued {
            self.ledger.lock().expect("ledger lock").accrue(
                self.idx,
                Lifecycle::Active,
                self.last_accrued,
                to,
                if busy { self.busy_w } else { self.idle_w },
            );
            self.last_accrued = to;
            self.accrued_to.lock().expect("accrued lock")[self.idx] = to;
        }
    }

    /// Has the front door closed the topics yet (modeled time)?
    fn closed_now(&self) -> bool {
        f64::from_bits(self.closed_at.load(Ordering::SeqCst)).is_finite() || self.closed
    }

    /// The shutdown watchdog's deadline: close instant plus the drain
    /// timeout (`INFINITY` while the run is open or the watchdog is
    /// unarmed).
    fn drain_deadline(&self) -> f64 {
        f64::from_bits(self.closed_at.load(Ordering::SeqCst)) + self.drain_timeout_s
    }

    /// Earliest future fault wake: next crash, watchdog fire, reboot
    /// completion, straggler check, or staged re-dispatch.
    fn fault_horizon(&self) -> f64 {
        let Some(f) = &self.faults else { return f64::INFINITY };
        let mut t = f.detect_at.min(f.ready_at).min(f.straggler_at);
        if let Some(&c) = f.crashes.get(f.next_crash) {
            t = t.min(c);
        }
        t.min(f.pending.iter().map(|p| p.0).fold(f64::INFINITY, f64::min))
    }

    /// Earliest fault transition due at or before `now` (`INFINITY` if
    /// none) — reboot completions, crashes, detections, stragglers.
    fn next_fault_due(&self, now: f64) -> f64 {
        let Some(f) = &self.faults else { return f64::INFINITY };
        let mut t = f.ready_at.min(f.detect_at).min(f.straggler_at);
        if let Some(&c) = f.crashes.get(f.next_crash) {
            t = t.min(c);
        }
        if t <= now {
            t
        } else {
            f64::INFINITY
        }
    }

    /// No fault work left for this shard: every scheduled crash
    /// consumed, nothing crashed or down, nothing staged.
    fn fault_quiescent(&self) -> bool {
        self.faults.as_ref().map_or(true, |f| {
            f.next_crash >= f.crashes.len() && !f.crashed && !f.is_down && f.pending.is_empty()
        })
    }

    /// Execute the one fault transition due at `t` (ties in the DES
    /// order: reboot activation, then crash, detection, straggler).
    fn fault_transition(&mut self, t: f64) {
        let Some(f) = self.faults.as_mut() else { return };
        if f.ready_at == t {
            // Reboot landed: the repair clock closes (MTTR is crash →
            // serving again) and the router believes in us again.
            f.ready_at = f64::INFINITY;
            f.rebooting = false;
            f.is_down = false;
            self.shared.down.store(false, Ordering::SeqCst);
            {
                let mut m = self.metrics.lock().expect("metrics lock");
                m.faults.recovered_devices += 1;
                m.faults.mttr_total_s += t - f.crash_t;
            }
            // The dead window drew no power (the DES bills a crashed
            // board nothing): skip the ledger forward without accruing.
            if t > self.last_accrued {
                self.last_accrued = t;
                self.accrued_to.lock().expect("accrued lock")[self.idx] = t;
            }
            let after = self.serving_count.fetch_add(1, Ordering::SeqCst) + 1;
            self.retire_log.lock().expect("retire lock").push(ScalingEvent {
                t_s: t,
                kind: ScaleEventKind::Activated { device: self.idx },
                serving_after: after,
            });
            return;
        }
        if f.crashes.get(f.next_crash) == Some(&t) {
            f.next_crash += 1;
            // A board that is already off cannot crash again.
            if f.crashed || f.is_down {
                return;
            }
            f.crashed = true;
            f.crash_t = t;
            f.straggler_at = f64::INFINITY;
            // The in-flight batch is stranded, not lost: detection
            // re-dispatches it (or end-of-run expiry accounts for it).
            f.stranded = std::mem::take(&mut self.in_flight);
            self.busy = false;
            self.shared.busy.store(false, Ordering::SeqCst);
            self.metrics.lock().expect("metrics lock").faults.injected_crashes += 1;
            if let Some(rp) = f.plan.recovery.as_ref() {
                f.detect_at = t + rp.heartbeat_timeout_s;
            }
            return;
        }
        if f.detect_at == t {
            f.detect_at = f64::INFINITY;
            if !f.crashed {
                return;
            }
            // The watchdog rules: truth becomes knowledge. Everything
            // the dead shard held — the stranded in-flight batch first
            // (oldest work), then its buffered and queued frames — goes
            // back through re-dispatch.
            f.crashed = false;
            f.is_down = true;
            self.shared.down.store(true, Ordering::SeqCst);
            self.metrics.lock().expect("metrics lock").faults.detected += 1;
            let after = self.serving_count.fetch_sub(1, Ordering::SeqCst).saturating_sub(1);
            self.retire_log.lock().expect("retire lock").push(ScalingEvent {
                t_s: t,
                kind: ScaleEventKind::Failed { device: self.idx },
                serving_after: after,
            });
            let mut work: Vec<Request> = std::mem::take(&mut f.stranded);
            let mut undispatched = self.local.len();
            work.extend(self.local.drain(..));
            loop {
                match self.topic.try_recv() {
                    Ok(r) => {
                        work.push(r);
                        undispatched += 1;
                    }
                    Err(TryRecvError::Empty) => break,
                    Err(TryRecvError::Disconnected) => {
                        self.closed = true;
                        break;
                    }
                }
            }
            if undispatched > 0 {
                self.shared.queued.fetch_sub(undispatched, Ordering::SeqCst);
            }
            for r in work {
                stage_or_expire(
                    &f.plan,
                    r,
                    t,
                    &f.resolved,
                    &self.metrics,
                    &self.outcomes,
                    &mut f.pending,
                );
            }
            if let Some(rp) = f.plan.recovery.as_ref() {
                if rp.reboot {
                    f.ready_at = t + rp.reboot_delay_s;
                    f.rebooting = true;
                    let serving = self.serving_count.load(Ordering::SeqCst);
                    self.retire_log.lock().expect("retire lock").push(ScalingEvent {
                        t_s: t,
                        kind: ScaleEventKind::Provisioning { device: self.idx },
                        serving_after: serving,
                    });
                } else {
                    self.final_failed.lock().expect("failed lock").push(self.idx);
                    self.accrued_to.lock().expect("accrued lock")[self.idx] = f64::INFINITY;
                }
            }
            return;
        }
        if f.straggler_at == t {
            f.straggler_at = f64::INFINITY;
            // Fires only while the guarded batch is still running (a
            // crash cleared `busy`; a finished batch needs no rescue).
            if f.crashed || !self.busy || self.busy_until <= t {
                return;
            }
            self.metrics.lock().expect("metrics lock").faults.detected += 1;
            // Copies of the hung batch go back through re-dispatch; the
            // original stays in flight and whichever finishes second is
            // suppressed.
            let copies: Vec<Request> = {
                let res = f.resolved.lock().expect("resolved lock");
                self.in_flight.iter().filter(|r| !res.contains(&r.id)).copied().collect()
            };
            for r in copies {
                stage_or_expire(
                    &f.plan,
                    r,
                    t,
                    &f.resolved,
                    &self.metrics,
                    &self.outcomes,
                    &mut f.pending,
                );
            }
        }
    }

    /// Send every staged copy due by `now` back out through failover
    /// routing.
    fn fault_redispatch(&mut self, now: f64, wakes: &mut Vec<usize>) {
        let Some(f) = self.faults.as_mut() else { return };
        if f.pending.is_empty() {
            return;
        }
        redispatch_staged(
            &f.plan,
            now,
            &mut f.pending,
            &f.resolved,
            &self.metrics,
            &self.outcomes,
            &f.topics,
            &f.shared,
            &f.backends,
            f.shed,
            wakes,
        );
    }

    /// While known-failed: requeue anything that raced into our topic
    /// before the router saw `down` (wall-mode only; a no-op under the
    /// virtual clock).
    fn drain_down_topic(&mut self, now: f64) {
        let mut work = Vec::new();
        loop {
            match self.topic.try_recv() {
                Ok(r) => work.push(r),
                Err(TryRecvError::Empty) => break,
                Err(TryRecvError::Disconnected) => {
                    self.closed = true;
                    break;
                }
            }
        }
        if work.is_empty() {
            return;
        }
        self.shared.queued.fetch_sub(work.len(), Ordering::SeqCst);
        let Some(f) = self.faults.as_mut() else { return };
        for r in work {
            stage_or_expire(
                &f.plan,
                r,
                now,
                &f.resolved,
                &self.metrics,
                &self.outcomes,
                &mut f.pending,
            );
        }
    }

    /// End-of-run flush for a crashed shard nothing ever recovered
    /// (recovery off — the watchdog never ruled): stranded, buffered,
    /// and queued work expires, so every id still reaches the outcome
    /// log exactly once. The DES post-loop flush, worker-side.
    fn flush_dead(&mut self, now: f64) {
        let Some(f) = self.faults.as_mut() else { return };
        let mut work: Vec<Request> = std::mem::take(&mut f.stranded);
        let mut undispatched = self.local.len();
        work.extend(self.local.drain(..));
        loop {
            match self.topic.try_recv() {
                Ok(r) => {
                    work.push(r);
                    undispatched += 1;
                }
                Err(TryRecvError::Empty) | Err(TryRecvError::Disconnected) => break,
            }
        }
        if undispatched > 0 {
            self.shared.queued.fetch_sub(undispatched, Ordering::SeqCst);
        }
        let mut res = f.resolved.lock().expect("resolved lock");
        let mut m = self.metrics.lock().expect("metrics lock");
        let mut o = self.outcomes.lock().expect("outcomes lock");
        for r in work {
            if res.insert(r.id) {
                m.faults.expired += 1;
                o.push(RequestOutcome {
                    id: r.id,
                    camera: r.camera,
                    t_s: now,
                    shed: true,
                    rung: r.rung,
                });
            }
        }
        self.accrued_to.lock().expect("accrued lock")[self.idx] = f64::INFINITY;
    }

    /// The shutdown watchdog fired: abandon the hung in-flight batch
    /// and everything behind it (all expired, exactly-once accounted)
    /// and leave as failed so the join completes.
    fn abandon_at_shutdown(&mut self, now: f64) {
        let batch = std::mem::take(&mut self.in_flight);
        self.busy = false;
        self.shared.busy.store(false, Ordering::SeqCst);
        self.shared.down.store(true, Ordering::SeqCst);
        let mut work = batch;
        let mut undispatched = self.local.len();
        work.extend(self.local.drain(..));
        loop {
            match self.topic.try_recv() {
                Ok(r) => {
                    work.push(r);
                    undispatched += 1;
                }
                Err(TryRecvError::Empty) | Err(TryRecvError::Disconnected) => break,
            }
        }
        if undispatched > 0 {
            self.shared.queued.fetch_sub(undispatched, Ordering::SeqCst);
        }
        // Lock order everywhere is resolved → metrics → outcomes.
        let keep: Vec<Request> = match self.faults.as_ref() {
            Some(f) => {
                let mut res = f.resolved.lock().expect("resolved lock");
                work.into_iter().filter(|r| res.insert(r.id)).collect()
            }
            None => work,
        };
        {
            let mut m = self.metrics.lock().expect("metrics lock");
            let mut o = self.outcomes.lock().expect("outcomes lock");
            for r in keep {
                m.faults.expired += 1;
                o.push(RequestOutcome {
                    id: r.id,
                    camera: r.camera,
                    t_s: now,
                    shed: true,
                    rung: r.rung,
                });
            }
        }
        self.final_failed.lock().expect("failed lock").push(self.idx);
        self.accrued_to.lock().expect("accrued lock")[self.idx] = f64::INFINITY;
        let after = self.serving_count.fetch_sub(1, Ordering::SeqCst).saturating_sub(1);
        self.retire_log.lock().expect("retire lock").push(ScalingEvent {
            t_s: now,
            kind: ScaleEventKind::Failed { device: self.idx },
            serving_after: after,
        });
    }

    /// Finish the in-flight batch. Completions are stamped at the
    /// modeled service end (`busy_until`), not the thread's wake time,
    /// so wall-mode scheduling jitter paces execution without polluting
    /// the latency model. Under a fault plan, completions whose id
    /// already resolved (a re-dispatched copy finished first) are
    /// suppressed — counted, never double-reported.
    fn finish_batch(&mut self) {
        let done_at = self.busy_until;
        let mut batch = std::mem::take(&mut self.in_flight);
        // Under a fault plan, compact the batch down to first-resolved
        // completions in place; the no-fault hot path touches neither
        // the resolved set nor any scratch allocation.
        if let Some(f) = &self.faults {
            let mut res = f.resolved.lock().expect("resolved lock");
            let before = batch.len();
            batch.retain(|r| res.insert(r.id));
            let dupes = (before - batch.len()) as u64;
            drop(res);
            if dupes > 0 {
                self.metrics.lock().expect("metrics lock").faults.duplicates_suppressed += dupes;
            }
        }
        {
            let mut m = self.metrics.lock().expect("metrics lock");
            for r in &batch {
                m.record_completion(self.idx, done_at - r.arrival_s, r.class);
                m.record_variant(r.rung);
            }
        }
        {
            let mut o = self.outcomes.lock().expect("outcomes lock");
            for r in &batch {
                o.push(RequestOutcome {
                    id: r.id,
                    camera: r.camera,
                    t_s: done_at,
                    shed: false,
                    rung: r.rung,
                });
            }
        }
        batch.clear();
        self.spare = batch;
        {
            let mut mc = self.max_completion.lock().expect("completion lock");
            *mc = mc.max(done_at);
        }
        if let Some(f) = self.faults.as_mut() {
            f.straggler_at = f64::INFINITY;
        }
        self.busy = false;
        self.shared.busy.store(false, Ordering::SeqCst);
    }

    fn step(&mut self, now: f64) -> (Step, Vec<usize>) {
        let mut wakes = Vec::new();
        let step = self.step_inner(now, &mut wakes);
        // Every park also honors the fault schedule and (while busy)
        // the shutdown watchdog deadline.
        let step = match step {
            Step::Park(t) => {
                let mut t = t.min(self.fault_horizon());
                if self.busy {
                    t = t.min(self.drain_deadline());
                }
                Step::Park(t)
            }
            Step::Done => Step::Done,
        };
        (step, wakes)
    }

    fn step_inner(&mut self, now: f64, wakes: &mut Vec<usize>) -> Step {
        if self.faults.is_some() {
            // 0. Fault transitions and due batch completions interleave
            // in event-time order, fault-first on ties — the DES
            // processes its fault events before settling the same
            // instant's completions.
            loop {
                let comp_t = if self.busy && self.busy_until <= now {
                    self.busy_until
                } else {
                    f64::INFINITY
                };
                let fault_t = self.next_fault_due(now);
                if !comp_t.is_finite() && !fault_t.is_finite() {
                    break;
                }
                if fault_t <= comp_t {
                    self.fault_transition(fault_t);
                } else {
                    self.finish_batch();
                }
            }
            // Staged copies due now go back out through failover
            // routing (a crashed owner still re-dispatches its staged
            // work — the schedule belongs to the fleet, not the board).
            self.fault_redispatch(now, wakes);
            if self.faults.as_ref().map_or(false, |f| f.crashed) {
                // Crashed, watchdog hasn't ruled: execute nothing. The
                // topic keeps filling — the router doesn't know yet.
                if self.closed_now()
                    && self.faults.as_ref().map_or(false, |f| f.plan.recovery.is_none())
                {
                    self.flush_dead(now);
                    return Step::Done;
                }
                return Step::Park(f64::INFINITY);
            }
            if self.faults.as_ref().map_or(false, |f| f.is_down) {
                self.drain_down_topic(now);
                let f = self.faults.as_ref().expect("fault state");
                if self.closed_now()
                    && !f.rebooting
                    && f.pending.is_empty()
                    && f.next_crash >= f.crashes.len()
                {
                    // Detected-failed for good and the run is over:
                    // nothing left to re-dispatch, leave the protocol.
                    return Step::Done;
                }
                return Step::Park(f64::INFINITY);
            }
        }
        // 1. Finish the in-flight batch (no-fault path; under faults
        // the interleave loop above already settled due completions).
        if self.busy {
            if self.busy_until > now {
                if self.drain_deadline() <= now {
                    // Shutdown watchdog: the batch outlived the drain
                    // deadline — abandon it rather than hold the join.
                    self.abandon_at_shutdown(now);
                    return Step::Done;
                }
                // Woken mid-service (a nudge): arrivals just queue.
                return Step::Park(self.busy_until);
            }
            self.finish_batch();
        }
        // 2. Refill the batching buffer up to one closable batch. When
        // the buffer stays short the topic is empty, so the batcher's
        // deadline scan below always sees the whole undispatched queue.
        while self.local.len() < self.cap {
            match self.topic.try_recv() {
                Ok(r) => self.local.push_back(r),
                Err(TryRecvError::Empty) => break,
                Err(TryRecvError::Disconnected) => {
                    self.closed = true;
                    break;
                }
            }
        }
        // 3. The same batching decision the DES makes.
        match self.policy.decide(&self.local, now, self.backend.max_batch()) {
            Decision::Dispatch(n) => {
                let mut batch = std::mem::take(&mut self.spare);
                batch.extend(self.local.drain(..n));
                // Same mixed-batch service model as the DES dispatch.
                let mut service = match &self.ladder {
                    Some(l) => l.batch_service_s(self.backend.as_ref(), &batch),
                    None => self.backend.batch_latency_s(batch.len()),
                };
                // Fault injection at dispatch: slowdown windows and
                // per-batch spikes inflate the modeled service time; a
                // batch slow enough to cross the heartbeat timeout gets
                // a straggler check armed against it.
                let mut spiked = false;
                if let Some(f) = self.faults.as_mut() {
                    let ord = f.ordinal;
                    f.ordinal += 1;
                    let spike = f.plan.spike(self.idx, ord);
                    spiked = spike > 1.0;
                    service *= f.plan.slowdown(self.idx, now) * spike;
                    if let Some(rp) = f.plan.recovery.as_ref() {
                        if service > rp.heartbeat_timeout_s {
                            f.straggler_at = now + rp.heartbeat_timeout_s;
                        }
                    }
                }
                self.accrue(now, false);
                self.busy = true;
                self.busy_until = now + service;
                self.accrue(self.busy_until, true);
                self.shared.free_at_bits.store(self.busy_until.to_bits(), Ordering::SeqCst);
                self.shared.busy.store(true, Ordering::SeqCst);
                self.shared.queued.fetch_sub(n, Ordering::SeqCst);
                {
                    let mut m = self.metrics.lock().expect("metrics lock");
                    if spiked {
                        m.faults.spikes += 1;
                    }
                    m.record_batch(self.idx, service);
                }
                self.in_flight = batch;
                Step::Park(self.busy_until)
            }
            Decision::WaitUntil(t) => Step::Park(t),
            Decision::Idle => {
                if self.closed {
                    if !self.fault_quiescent() {
                        // Future crashes, staged copies, or an open
                        // fault window keep the shard in the protocol
                        // (the DES runs until its fault work drains).
                        return Step::Park(f64::INFINITY);
                    }
                    // Drain-to-retire: the topic closed and everything
                    // admitted has been served.
                    self.accrue(now, false);
                    let serving_after =
                        self.serving_count.fetch_sub(1, Ordering::SeqCst).saturating_sub(1);
                    self.retire_log.lock().expect("retire lock").push(ScalingEvent {
                        t_s: now,
                        kind: ScaleEventKind::Retired { device: self.idx },
                        serving_after,
                    });
                    Step::Done
                } else {
                    Step::Park(f64::INFINITY)
                }
            }
        }
    }
}

/// Virtual-mode worker: run whichever owned shard holds the turn.
fn run_virtual(clock: &VirtualClock, mut shards: Vec<ShardRuntime>) {
    let ids: Vec<usize> = shards.iter().map(|s| s.idx + 1).collect();
    while let Some((pid, now)) = clock.wait_any(&ids) {
        let s = shards.iter_mut().find(|s| s.idx + 1 == pid).expect("owned shard");
        let (step, wakes) = s.step(now);
        // Failover re-dispatches published into other shards' topics:
        // pull those consumers forward so they observe the message in
        // event order, exactly like the front door's nudge.
        for w in wakes {
            clock.nudge(w + 1);
        }
        match step {
            Step::Park(t) => clock.park(pid, t),
            Step::Done => clock.done(pid),
        }
    }
}

/// Wall-mode worker: step the owned shards, sleep until the earliest
/// park or the next kick. Every wake re-steps *every* live shard, not
/// just the ones whose park came due — a kick only says "one of your
/// topics got a message", and an idle shard is parked at infinity, so a
/// due-time guard would never drain it again (and a batch-waiting shard
/// could dispatch early once the kick fills its batch). `step` is
/// idempotent for a shard with nothing to do, so the extra calls are
/// free.
fn run_wall(wall: &WallClock, kicks: &[Arc<Kick>], me: usize, mut shards: Vec<ShardRuntime>) {
    let kick = &kicks[me];
    let mut parks: Vec<Option<f64>> = vec![Some(0.0); shards.len()];
    loop {
        let seen = kick.seen();
        let now = wall.now();
        for (k, s) in shards.iter_mut().enumerate() {
            if parks[k].is_some() {
                let (step, wakes) = s.step(now);
                // Failover re-dispatch landed on another thread's
                // shard: kick its owner awake (self-kicks just cost
                // one extra scan).
                for w in wakes {
                    kicks[w % kicks.len()].kick();
                }
                match step {
                    Step::Park(t) => parks[k] = Some(t),
                    Step::Done => parks[k] = None,
                }
            }
        }
        if parks.iter().all(Option::is_none) {
            return;
        }
        let next = parks.iter().flatten().fold(f64::INFINITY, |a, &b| a.min(b));
        if next <= wall.now() {
            continue; // a park came due while we were scanning
        }
        if next.is_finite() {
            let wall_wait = ((next - wall.now()).max(0.0) * wall.scale).max(1e-4);
            kick.wait(seen, Some(Duration::from_secs_f64(wall_wait)));
        } else {
            kick.wait(seen, None);
        }
    }
}

// ---------------------------------------------------------------------
// The front door.
// ---------------------------------------------------------------------

/// Router-side accounting the report assembly needs after the join.
struct FrontDoor<'a> {
    cfg: &'a SimConfig,
    quota: Option<ClassQuota>,
    backends: &'a [Arc<dyn Backend>],
    topics: &'a [Arc<SharedTopic<Request>>],
    shared: &'a [Arc<ShardShared>],
    metrics: &'a Mutex<FleetMetrics>,
    outcomes: &'a Mutex<Vec<RequestOutcome>>,
    offered: u64,
    offered_by_class: [u64; 3],
    faults: Option<&'a FaultPlan>,
    resolved: Option<&'a Mutex<HashSet<u64>>>,
    /// Retry copies the front door itself displaced (an admission
    /// eviction hit a re-dispatched copy): staged here and re-sent at
    /// their backoff times between arrivals.
    pending: Vec<(f64, Request)>,
}

impl FrontDoor<'_> {
    /// Mark `id` terminally resolved (no-op without a fault plan).
    fn resolve(&self, id: u64) {
        if let Some(res) = self.resolved {
            res.lock().expect("resolved lock").insert(id);
        }
    }

    /// Earliest staged re-dispatch owned by the front door.
    fn pending_next(&self) -> f64 {
        self.pending.iter().map(|p| p.0).fold(f64::INFINITY, f64::min)
    }

    /// Send the front door's staged copies due by `now` back out;
    /// returns the shards to wake.
    fn redispatch_due(&mut self, now: f64) -> Vec<usize> {
        let mut wakes = Vec::new();
        if let (Some(plan), Some(res)) = (self.faults, self.resolved) {
            if !self.pending.is_empty() {
                redispatch_staged(
                    plan,
                    now,
                    &mut self.pending,
                    res,
                    self.metrics,
                    self.outcomes,
                    self.topics,
                    self.shared,
                    self.backends,
                    self.cfg.shed,
                    &mut wakes,
                );
            }
        }
        wakes
    }

    /// Admit one arrival at modeled time `now`: link drops, then token
    /// buckets, then least-outstanding-work routing, then the per-class
    /// overflow policy through the topic. Returns the shard to nudge
    /// when the message was delivered.
    fn admit(&mut self, mut req: Request, now: f64) -> Option<usize> {
        self.offered += 1;
        self.offered_by_class[req.class.index()] += 1;
        // Front-door link drop: the frame is lost before admission (a
        // shed for every conservation law, counted separately in the
        // fault report).
        if let Some(p) = self.faults {
            if p.drops_link(req.id) {
                {
                    let mut m = self.metrics.lock().expect("metrics lock");
                    m.faults.link_drops += 1;
                    m.record_shed(req.class);
                }
                self.resolve(req.id);
                self.outcomes.lock().expect("outcomes lock").push(RequestOutcome {
                    id: req.id,
                    camera: req.camera,
                    t_s: now,
                    shed: true,
                    rung: req.rung,
                });
                return None;
            }
        }
        if let Some(q) = self.quota.as_mut() {
            if !q.try_take(req.class, now) {
                self.metrics.lock().expect("metrics lock").record_quota_shed(req.class);
                self.resolve(req.id);
                self.outcomes.lock().expect("outcomes lock").push(RequestOutcome {
                    id: req.id,
                    camera: req.camera,
                    t_s: now,
                    shed: true,
                    rung: req.rung,
                });
                return None;
            }
        }
        // Least outstanding work over live queue depths, ties to the
        // lowest index (the DES `ShardPool::route`), skipping shards
        // the watchdog declared dead.
        let mut routed: Option<(f64, usize)> = None;
        for (i, sh) in self.shared.iter().enumerate() {
            if self.faults.is_some() && sh.down.load(Ordering::SeqCst) {
                continue;
            }
            let est = sh.outstanding_s(self.backends[i].as_ref(), now);
            if routed.map_or(true, |(b, _)| est < b) {
                routed = Some((est, i));
            }
        }
        let Some((_, best)) = routed else {
            // Total blackout: every shard known-failed — the front door
            // sheds (only reachable under a fault plan).
            self.resolve(req.id);
            self.metrics.lock().expect("metrics lock").record_shed(req.class);
            self.outcomes.lock().expect("outcomes lock").push(RequestOutcome {
                id: req.id,
                camera: req.camera,
                t_s: now,
                shed: true,
                rung: req.rung,
            });
            return None;
        };
        // Degradation rung from the routed shard's undispatched depth —
        // the same observable the DES reads from its routed queue at
        // the same point in the admission sequence.
        if let Some(l) = self.cfg.admission.ladder() {
            req.rung = l.rung_for(
                self.shared[best].queued.load(Ordering::SeqCst),
                self.cfg.queue_depth,
            );
        }
        let policy = self.cfg.shed.overflow_for(req.class);
        let class = req.class;
        let (id, camera, rung) = (req.id, req.camera, req.rung);
        match self.topics[best].try_publish(req, policy) {
            PublishOutcome::Delivered => {
                self.shared[best].queued.fetch_add(1, Ordering::SeqCst);
                Some(best)
            }
            PublishOutcome::DeliveredDroppedOldest(old) => {
                // Net queue depth is unchanged: one in, one out — and
                // the eviction report is what keeps live shed
                // accounting exact per class. An evicted re-dispatch
                // copy is displaced, not refused: it goes back through
                // the retry path.
                if old.retries > 0 {
                    let plan = self.faults.expect("retry copies only exist under a fault plan");
                    let res = self.resolved.expect("retry copies only exist under a fault plan");
                    stage_or_expire(
                        plan,
                        old,
                        now,
                        res,
                        self.metrics,
                        self.outcomes,
                        &mut self.pending,
                    );
                } else {
                    self.resolve(old.id);
                    self.metrics.lock().expect("metrics lock").record_shed(old.class);
                    self.outcomes.lock().expect("outcomes lock").push(RequestOutcome {
                        id: old.id,
                        camera: old.camera,
                        t_s: now,
                        shed: true,
                        rung: old.rung,
                    });
                }
                Some(best)
            }
            PublishOutcome::Rejected | PublishOutcome::Closed => {
                self.resolve(id);
                self.metrics.lock().expect("metrics lock").record_shed(class);
                self.outcomes.lock().expect("outcomes lock").push(RequestOutcome {
                    id,
                    camera,
                    t_s: now,
                    shed: true,
                    rung,
                });
                None
            }
        }
    }
}

// ---------------------------------------------------------------------
// The entry point.
// ---------------------------------------------------------------------

/// Serve an open-loop trace on real threads and report through the same
/// [`FleetReport`] the DES produces. Consumes the pool (the live
/// runtime owns its devices); the trace must be sorted by arrival time.
///
/// Differential configs must set `work_stealing: false` — the live
/// path has none, and a silent mismatch would make the DES oracle lie.
pub fn serve_live(
    pool: ShardPool,
    trace: &[Request],
    cfg: &SimConfig,
    live: &LiveConfig,
) -> FleetReport {
    serve_live_logged(pool, trace, cfg, live).0
}

/// As [`serve_live`], also returning per-request outcomes sorted by
/// trace id. Sorting (not thread arrival order) is what keeps the log
/// identical across worker-thread counts in virtual-clock mode.
pub fn serve_live_logged(
    pool: ShardPool,
    trace: &[Request],
    cfg: &SimConfig,
    live: &LiveConfig,
) -> (FleetReport, Vec<RequestOutcome>) {
    assert!(
        !cfg.work_stealing,
        "the live runtime has no work stealing; run it (and any DES oracle) with \
         work_stealing: false"
    );
    assert!(
        trace.windows(2).all(|w| w[0].arrival_s <= w[1].arrival_s),
        "live serving replays traces in arrival order"
    );
    assert!(
        cfg.queue_depth >= cfg.batch.max_batch,
        "live fidelity contract: queue_depth ({}) must cover one full batch ({}) — \
         shallower topics would let the worker's batching buffer exceed the bound the \
         DES models",
        cfg.queue_depth,
        cfg.batch.max_batch
    );
    let backends: Vec<Arc<dyn Backend>> =
        pool.into_backends().into_iter().map(Arc::from).collect();
    let n = backends.len();
    assert!(n > 0, "live serving needs at least one device");
    let threads = if live.threads == 0 { n } else { live.threads.clamp(1, n) };

    let metrics = Arc::new(Mutex::new(FleetMetrics::new(n, cfg.slo_s)));
    let ledger = Arc::new(Mutex::new(EnergyLedger::new(cfg.energy_epoch_s)));
    let max_completion = Arc::new(Mutex::new(0.0f64));
    let accrued_to = Arc::new(Mutex::new(vec![0.0f64; n]));
    let retire_log = Arc::new(Mutex::new(Vec::new()));
    let serving_count = Arc::new(AtomicUsize::new(n));
    let outcomes = Arc::new(Mutex::new(Vec::new()));
    let topics: Vec<Arc<SharedTopic<Request>>> =
        (0..n).map(|_| Arc::new(SharedTopic::bounded(cfg.queue_depth.max(1)))).collect();
    let shared: Vec<Arc<ShardShared>> = (0..n).map(|_| Arc::new(ShardShared::new())).collect();

    // Fault plumbing: one shared resolved-id set (the exactly-once
    // gate), one close signal, per-worker crash schedules.
    let resolved: Option<Arc<Mutex<HashSet<u64>>>> =
        cfg.faults.as_ref().map(|p| {
            p.validate();
            Arc::new(Mutex::new(HashSet::new()))
        });
    let closed_at = Arc::new(AtomicU64::new(f64::INFINITY.to_bits()));
    let final_failed: Arc<Mutex<Vec<usize>>> = Arc::new(Mutex::new(Vec::new()));
    let mk_faults = |i: usize| {
        cfg.faults.as_ref().map(|p| {
            let mut crashes: Vec<f64> =
                p.crashes.iter().filter(|c| c.device == i).map(|c| c.at_s).collect();
            crashes.sort_by(|a, b| a.partial_cmp(b).expect("finite crash times"));
            LiveFaults {
                plan: p.clone(),
                resolved: resolved.clone().expect("resolved set exists under a plan"),
                topics: topics.clone(),
                shared: shared.clone(),
                backends: backends.clone(),
                shed: cfg.shed,
                crashes,
                next_crash: 0,
                crashed: false,
                crash_t: 0.0,
                detect_at: f64::INFINITY,
                ready_at: f64::INFINITY,
                straggler_at: f64::INFINITY,
                is_down: false,
                rebooting: false,
                stranded: Vec::new(),
                pending: Vec::new(),
                ordinal: 0,
            }
        })
    };

    let mut runtimes: Vec<ShardRuntime> = (0..n)
        .map(|i| ShardRuntime {
            idx: i,
            backend: backends[i].clone(),
            topic: topics[i].clone(),
            shared: shared[i].clone(),
            policy: cfg.batch,
            ladder: cfg.admission.ladder().cloned(),
            cap: cfg.batch.effective_cap(backends[i].max_batch()),
            local: VecDeque::new(),
            in_flight: Vec::new(),
            spare: Vec::new(),
            busy: false,
            busy_until: 0.0,
            closed: false,
            idle_w: backends[i].power_w(0.0),
            busy_w: backends[i].power_w(1.0),
            last_accrued: 0.0,
            metrics: metrics.clone(),
            ledger: ledger.clone(),
            max_completion: max_completion.clone(),
            accrued_to: accrued_to.clone(),
            retire_log: retire_log.clone(),
            serving_count: serving_count.clone(),
            outcomes: outcomes.clone(),
            faults: mk_faults(i),
            closed_at: closed_at.clone(),
            drain_timeout_s: live.drain_timeout_s,
            final_failed: final_failed.clone(),
        })
        .collect();
    // Deal shards round-robin to worker threads (shard i → thread
    // i % threads), so `--live-threads 1` serializes on one core and
    // per-shard ownership never changes.
    let mut per_thread: Vec<Vec<ShardRuntime>> = (0..threads).map(|_| Vec::new()).collect();
    for rt in runtimes.drain(..) {
        let t = rt.idx % threads;
        per_thread[t].push(rt);
    }

    let mut front = FrontDoor {
        cfg,
        quota: cfg.admission.runtime_quota(),
        backends: &backends,
        topics: &topics,
        shared: &shared,
        metrics: &*metrics,
        outcomes: &*outcomes,
        offered: 0,
        offered_by_class: [0; 3],
        faults: cfg.faults.as_ref(),
        resolved: resolved.as_deref(),
        pending: Vec::new(),
    };

    let final_now = match live.clock {
        ClockMode::Virtual => {
            let clock = Arc::new(VirtualClock::new(n + 1));
            thread::scope(|scope| {
                for group in per_thread.drain(..) {
                    let clock = clock.clone();
                    scope.spawn(move || run_virtual(&clock, group));
                }
                // The front door runs on this thread as participant 0,
                // pacing arrivals and its own staged re-dispatches.
                let mut next = 0;
                let mut vnow = 0.0;
                loop {
                    let arrival = trace.get(next).map_or(f64::INFINITY, |r| r.arrival_s);
                    let due = arrival.min(front.pending_next());
                    if !due.is_finite() {
                        break;
                    }
                    clock.park(0, due);
                    let (_, now) = clock.wait_any(&[0]).expect("front door active");
                    vnow = now;
                    while next < trace.len() && trace[next].arrival_s <= now {
                        let req = trace[next];
                        next += 1;
                        if let Some(shard) = front.admit(req, now) {
                            clock.nudge(shard + 1);
                        }
                    }
                    for w in front.redispatch_due(now) {
                        clock.nudge(w + 1);
                    }
                }
                // Drain-to-retire: stamp the close instant (the drain
                // watchdog's reference), close every topic, wake the
                // shards so they observe the hang-up, and leave the
                // protocol.
                closed_at.store(vnow.to_bits(), Ordering::SeqCst);
                for t in &topics {
                    t.close();
                }
                if cfg.faults.is_some() || live.drain_timeout_s.is_finite() {
                    clock.wake_all();
                } else {
                    clock.wake_idle();
                }
                clock.done(0);
            });
            clock.final_now()
        }
        ClockMode::Wall => {
            let wall = Arc::new(WallClock { start: Instant::now(), scale: live.time_scale.max(1e-3) });
            let kicks: Arc<Vec<Arc<Kick>>> =
                Arc::new((0..threads).map(|_| Arc::new(Kick::new())).collect());
            thread::scope(|scope| {
                for (t, group) in per_thread.drain(..).enumerate() {
                    let wall = wall.clone();
                    let kicks = kicks.clone();
                    scope.spawn(move || run_wall(&wall, &kicks, t, group));
                }
                for req in trace {
                    wall.sleep_until(req.arrival_s);
                    let now = wall.now();
                    if let Some(shard) = front.admit(*req, now) {
                        kicks[shard % threads].kick();
                    }
                    for w in front.redispatch_due(now) {
                        kicks[w % threads].kick();
                    }
                }
                // Drain the front door's own staged copies before the
                // hang-up (their backoffs are short by construction).
                loop {
                    let due = front.pending_next();
                    if !due.is_finite() {
                        break;
                    }
                    wall.sleep_until(due);
                    let now = wall.now();
                    for w in front.redispatch_due(now) {
                        kicks[w % threads].kick();
                    }
                }
                closed_at.store(wall.now().to_bits(), Ordering::SeqCst);
                for t in &topics {
                    t.close();
                }
                for k in kicks.iter() {
                    k.kick();
                }
            });
            wall.now()
        }
    };

    // The front door's counters outlive its borrows of the shared
    // state (the workers are joined; only accounting remains).
    let offered = front.offered;
    let offered_by_class = front.offered_by_class;

    // Trailing idle energy: every shard accrued up to its own last
    // event; extend to the run's end so the ledger covers the same
    // span as the DES's (which accrues every device to the final event
    // time).
    {
        let mut led = ledger.lock().expect("ledger lock");
        let accrued = accrued_to.lock().expect("accrued lock");
        for (i, &last) in accrued.iter().enumerate() {
            if final_now > last {
                led.accrue(i, Lifecycle::Active, last, final_now, backends[i].power_w(0.0));
            }
        }
    }

    let Ok(metrics) = Arc::try_unwrap(metrics) else { unreachable!("workers joined") };
    let metrics = metrics.into_inner().expect("metrics lock");
    let Ok(ledger) = Arc::try_unwrap(ledger) else { unreachable!("workers joined") };
    let mut ledger = ledger.into_inner().expect("ledger lock");
    for (i, stats) in metrics.per_device.iter().enumerate() {
        ledger.served_gop += stats.completed as f64 * backends[i].gop_per_frame();
    }
    while ledger.per_device_j.len() < n {
        ledger.per_device_j.push(0.0);
    }
    let last_completion = *max_completion.lock().expect("completion lock");
    let backend_refs: Vec<&dyn Backend> = backends.iter().map(|b| b.as_ref()).collect();
    let mut report = metrics.report(&backend_refs, last_completion.max(final_now));
    report.offered = offered;
    for (i, c) in report.classes.iter_mut().enumerate() {
        c.offered = offered_by_class[i];
    }
    report.devices_start = n;
    report.devices_peak = n;
    report.devices_final = serving_count.load(Ordering::SeqCst);
    let Ok(retire_log) = Arc::try_unwrap(retire_log) else { unreachable!("workers joined") };
    let mut events = retire_log.into_inner().expect("retire lock");
    events.sort_by(|a, b| {
        a.t_s.partial_cmp(&b.t_s).expect("finite event times").then_with(|| {
            let d = |e: &ScalingEvent| match e.kind {
                ScaleEventKind::Retired { device } => device,
                _ => usize::MAX,
            };
            d(a).cmp(&d(b))
        })
    });
    report.scaling = events;
    for d in report.devices.iter_mut() {
        d.state = "retired";
    }
    // Shards that left as failed (watchdog-detected without reboot, or
    // shutdown-abandoned) never drained: mark them.
    for &i in final_failed.lock().expect("failed lock").iter() {
        if let Some(d) = report.devices.get_mut(i) {
            d.state = "failed";
        }
    }
    report.energy = ledger;
    if let Some(plan) = cfg.faults.as_ref() {
        let availability =
            if offered == 0 { 1.0 } else { report.completed as f64 / offered as f64 };
        report.faults = Some(metrics.faults.to_report(plan, availability));
    }
    if let Some(l) = cfg.admission.ladder() {
        report.variants = l.variant_serves(&metrics.variant_served);
        report.effective_accuracy = Some(l.effective_accuracy(&metrics.variant_served, offered));
    }
    let Ok(outcomes) = Arc::try_unwrap(outcomes) else { unreachable!("workers joined") };
    let mut outcomes = outcomes.into_inner().expect("outcomes lock");
    outcomes.sort_by_key(|o| o.id);
    (report, outcomes)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::baselines::Platform;
    use crate::serving::device::BaselineDevice;
    use crate::serving::sim::poisson_trace;
    use crate::serving::ShedPolicy;

    /// 5 ms overhead + 5 ms/frame, 10 W — the DES test device.
    fn test_device() -> BaselineDevice {
        let p =
            Platform { name: "live-dev", overhead_s: 5e-3, sustained_gops: 100.0, power_w: 10.0 };
        BaselineDevice::new(p, 0.5, 16)
    }

    fn pool(n: usize) -> ShardPool {
        let mut pool = ShardPool::new();
        for _ in 0..n {
            pool.register(Box::new(test_device()));
        }
        pool
    }

    fn base_cfg() -> SimConfig {
        SimConfig {
            batch: BatchPolicy::new(4, 0.010),
            queue_depth: 16,
            shed: ShedPolicy::DropOldest,
            slo_s: 0.250,
            work_stealing: false,
            ..Default::default()
        }
    }

    #[test]
    fn virtual_clock_serves_and_conserves() {
        let trace = poisson_trace(120.0, 2.0, 42);
        let r = serve_live(pool(2), &trace, &base_cfg(), &LiveConfig::virtual_clock());
        assert_eq!(r.offered, trace.len() as u64);
        assert_eq!(r.completed + r.shed, r.offered, "live conservation");
        assert!(r.completed > 0);
        assert!(r.devices.iter().all(|d| d.state == "retired"), "drain-to-retire");
        assert_eq!(r.devices_final, 0);
        assert_eq!(r.scaling.len(), 2, "each shard logs its retirement");
        assert!(r.energy.total_j() > 0.0);
    }

    #[test]
    fn virtual_clock_is_deterministic() {
        let trace = poisson_trace(200.0, 1.5, 7);
        let cfg = base_cfg();
        let a = serve_live(pool(3), &trace, &cfg, &LiveConfig::virtual_clock());
        let b = serve_live(pool(3), &trace, &cfg, &LiveConfig::virtual_clock());
        assert_eq!(format!("{a:?}"), format!("{b:?}"));
    }

    #[test]
    fn wall_clock_smoke_conserves() {
        // 0.5 s of modeled traffic at 20× speed: finishes in tens of
        // wall milliseconds; only counting invariants are asserted
        // (latencies carry scheduling jitter by design).
        let trace = poisson_trace(150.0, 0.5, 3);
        let r = serve_live(pool(2), &trace, &base_cfg(), &LiveConfig::wall(0.05));
        assert_eq!(r.offered, trace.len() as u64);
        assert_eq!(r.completed + r.shed, r.offered);
        assert!(r.completed > 0);
    }

    #[test]
    fn logged_outcomes_are_thread_count_invariant() {
        let trace = poisson_trace(400.0, 1.0, 5);
        let cfg = SimConfig { queue_depth: 8, ..base_cfg() };
        let (r, o1) = serve_live_logged(pool(3), &trace, &cfg, &LiveConfig::virtual_clock());
        let (_, o3) =
            serve_live_logged(pool(3), &trace, &cfg, &LiveConfig::virtual_clock().with_threads(1));
        assert_eq!(o1.len(), trace.len(), "every request gets an outcome");
        assert!(o1.iter().enumerate().all(|(i, o)| o.id == i as u64));
        assert_eq!(o1, o3, "outcome log must not depend on worker-thread count");
        assert_eq!(o1.iter().filter(|o| o.shed).count() as u64, r.shed);
    }

    #[test]
    #[should_panic(expected = "work_stealing")]
    fn live_rejects_work_stealing_configs() {
        let cfg = SimConfig { work_stealing: true, ..base_cfg() };
        let _ = serve_live(pool(1), &[], &cfg, &LiveConfig::virtual_clock());
    }
}
