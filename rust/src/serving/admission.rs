//! Bounded admission queues with load shedding.
//!
//! Generalizes the backpressure of [`crate::pipeline::topic`]: where the
//! Section VI pipeline *blocks* the producer when a DDS-style queue is
//! full, an open-loop fleet cannot block a camera — it must shed. Each
//! device's queue is bounded; when full, the shed policy decides whether
//! the newest request is rejected or the oldest queued request is evicted
//! (same semantics as [`crate::pipeline::OverflowPolicy`], which
//! [`admit_via_topic`] reuses directly for live threaded front doors).

use std::collections::VecDeque;

use crate::pipeline::{OverflowPolicy, Topic};

use super::ladder::VariantLadder;
use super::{Request, SloClass};

/// What to do when a bounded queue is full.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum ShedPolicy {
    /// Reject the incoming request (newest-first shedding).
    RejectNewest,
    /// Evict the oldest queued request to admit the new one (freshest
    /// frames win — the right call for perception pipelines where a
    /// stale frame is worthless once a newer one exists).
    DropOldest,
    /// Shed the lowest [`super::SloClass`] first: evict the oldest
    /// queued request of the lowest-priority class present, provided the
    /// incoming request's class is at least that low — otherwise the
    /// incoming request itself is the cheapest frame to lose and is
    /// rejected. Within one class this degenerates to drop-oldest, so a
    /// single-class fleet behaves like [`ShedPolicy::DropOldest`].
    ClassAware,
}

impl ShedPolicy {
    /// The equivalent live-pipeline overflow policy (the live `Topic`
    /// front door carries no class metadata, so class-aware shedding
    /// degrades to its single-class behavior, drop-oldest).
    pub fn overflow(self) -> OverflowPolicy {
        match self {
            ShedPolicy::RejectNewest => OverflowPolicy::Reject,
            ShedPolicy::DropOldest | ShedPolicy::ClassAware => OverflowPolicy::DropOldest,
        }
    }

    /// The live front door's per-class overflow mapping: a FIFO topic
    /// cannot evict by class the way [`admit`] does, but the publisher
    /// *does* know the incoming request's class. Under
    /// [`ShedPolicy::ClassAware`] the lowest class sheds itself
    /// (mirroring [`admit`]'s "the incoming request is the cheapest
    /// frame to lose" branch) while higher classes evict the oldest
    /// queued message — so a live fleet still sheds batchable traffic
    /// first, it just cannot reach *past* newer high-class frames to do
    /// it. The other policies ignore the class.
    pub fn overflow_for(self, class: SloClass) -> OverflowPolicy {
        match self {
            ShedPolicy::RejectNewest => OverflowPolicy::Reject,
            ShedPolicy::DropOldest => OverflowPolicy::DropOldest,
            ShedPolicy::ClassAware => {
                if class.priority() == 0 {
                    OverflowPolicy::Reject
                } else {
                    OverflowPolicy::DropOldest
                }
            }
        }
    }
}

/// Per-class token buckets ahead of the queue: class `c` may admit a
/// sustained `rate[c]` requests/s with bursts up to `burst[c]`. Buckets
/// are independent — one class exhausting its quota cannot consume
/// another's tokens, which is the starvation-freedom the property tests
/// pin down ("no class starves while its bucket has tokens"). Shared by
/// the DES driver and the live threaded front door: both refill off
/// their own clock (virtual or wall-mapped) through [`try_take`].
///
/// [`try_take`]: ClassQuota::try_take
#[derive(Debug, Clone)]
pub struct ClassQuota {
    /// Sustained admits per second, per [`SloClass::index`].
    pub rate: [f64; 3],
    /// Bucket capacity (burst headroom), tokens.
    pub burst: [f64; 3],
    tokens: [f64; 3],
    last_s: f64,
}

impl ClassQuota {
    /// Buckets start full at `t = 0`.
    pub fn new(rate: [f64; 3], burst: [f64; 3]) -> Self {
        assert!(rate.iter().all(|r| *r >= 0.0), "quota rates must be non-negative");
        assert!(burst.iter().all(|b| *b >= 1.0), "burst must admit at least one request");
        Self { rate, burst, tokens: burst, last_s: 0.0 }
    }

    /// One rate/burst for every class.
    pub fn uniform(rate: f64, burst: f64) -> Self {
        Self::new([rate; 3], [burst; 3])
    }

    /// Take one token from `class`'s bucket at time `now_s`. Refills
    /// every bucket first (time may only move forward; out-of-order
    /// calls refill nothing rather than going backwards).
    pub fn try_take(&mut self, class: SloClass, now_s: f64) -> bool {
        let dt = (now_s - self.last_s).max(0.0);
        self.last_s = self.last_s.max(now_s);
        for i in 0..3 {
            self.tokens[i] = (self.tokens[i] + self.rate[i] * dt).min(self.burst[i]);
        }
        let t = &mut self.tokens[class.index()];
        if *t >= 1.0 {
            *t -= 1.0;
            true
        } else {
            false
        }
    }

    /// Current token balance of `class` (diagnostics/tests).
    pub fn tokens(&self, class: SloClass) -> f64 {
        self.tokens[class.index()]
    }
}

/// What stands in front of the bounded queues.
#[derive(Debug, Clone, Default)]
pub enum AdmissionPolicy {
    /// No quotas: every arrival proceeds straight to the shed policy.
    #[default]
    Open,
    /// Per-class token buckets: an arrival whose class is out of tokens
    /// is shed at the front door (a *quota* shed, counted separately in
    /// [`super::metrics::ClassReport::quota_shed`]) before it can
    /// displace queued work of any class.
    ClassQuota(ClassQuota),
    /// Graceful degradation: every arrival is admitted at the rung of
    /// the carried [`VariantLadder`] selected by the routed queue's fill
    /// fraction — a loaded fleet serves a cheaper, slightly less
    /// accurate variant *before* the shed policy ever has to evict.
    /// Sheds still happen when even the deepest rung cannot keep up.
    Degrade(VariantLadder),
}

impl AdmissionPolicy {
    /// The mutable per-run quota state (the config itself stays
    /// immutable — both drivers clone the buckets at start of run).
    pub(super) fn runtime_quota(&self) -> Option<ClassQuota> {
        match self {
            AdmissionPolicy::Open | AdmissionPolicy::Degrade(_) => None,
            AdmissionPolicy::ClassQuota(q) => Some(q.clone()),
        }
    }

    /// The degradation ladder, when this policy carries one. Both
    /// drivers consult it at admission (rung stamping) and dispatch
    /// (mixed-batch service time); `None` means every request is served
    /// at rung 0, bit-identical to the pre-ladder behavior.
    pub fn ladder(&self) -> Option<&VariantLadder> {
        match self {
            AdmissionPolicy::Degrade(l) => Some(l),
            _ => None,
        }
    }
}

/// Outcome of one admission attempt.
#[derive(Debug, Clone, PartialEq)]
pub enum Admission {
    /// Admitted without displacing anything.
    Admitted,
    /// Admitted; the returned (oldest) request was shed to make room.
    AdmittedEvicted(Request),
    /// Queue full under [`ShedPolicy::RejectNewest`]; the new request
    /// was shed.
    Rejected,
}

/// Admit `req` into a bounded queue, shedding per `policy`. Returns what
/// happened so the caller can count sheds.
pub fn admit(
    queue: &mut VecDeque<Request>,
    capacity: usize,
    policy: ShedPolicy,
    req: Request,
) -> Admission {
    if queue.len() < capacity.max(1) {
        queue.push_back(req);
        return Admission::Admitted;
    }
    match policy {
        ShedPolicy::RejectNewest => Admission::Rejected,
        ShedPolicy::DropOldest => {
            // capacity >= 1, so the queue is non-empty here.
            let evicted = queue.pop_front().expect("non-empty full queue");
            queue.push_back(req);
            Admission::AdmittedEvicted(evicted)
        }
        ShedPolicy::ClassAware => {
            // The cheapest frame to lose is the oldest of the lowest
            // priority present (queue is non-empty: capacity >= 1).
            let worst = queue.iter().map(|r| r.class.priority()).min().expect("non-empty");
            if req.class.priority() >= worst {
                let pos = queue
                    .iter()
                    .position(|r| r.class.priority() == worst)
                    .expect("a request of the worst class exists");
                let evicted = queue.remove(pos).expect("position is in range");
                queue.push_back(req);
                Admission::AdmittedEvicted(evicted)
            } else {
                Admission::Rejected
            }
        }
    }
}

/// Admit into a live threaded [`Topic`] front door with the same shed
/// semantics (reuses [`Topic::try_publish`]). Returns `true` when the
/// message was delivered.
pub fn admit_via_topic<T>(topic: &Topic<T>, msg: T, policy: ShedPolicy) -> bool {
    topic.try_publish(msg, policy.overflow()).delivered()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::pipeline::topic;
    use crate::serving::SloClass;

    fn req(id: u64, t: f64) -> Request {
        Request { id, camera: 0, arrival_s: t, objects: 1, class: SloClass::Standard, rung: 0, retries: 0 }
    }

    fn classed(id: u64, class: SloClass) -> Request {
        Request { id, camera: 0, arrival_s: id as f64, objects: 1, class, rung: 0, retries: 0 }
    }

    #[test]
    fn admits_until_capacity() {
        let mut q = VecDeque::new();
        for i in 0..3 {
            assert_eq!(admit(&mut q, 3, ShedPolicy::RejectNewest, req(i, 0.0)), Admission::Admitted);
        }
        assert_eq!(admit(&mut q, 3, ShedPolicy::RejectNewest, req(3, 0.0)), Admission::Rejected);
        assert_eq!(q.len(), 3);
        assert_eq!(q.front().unwrap().id, 0);
    }

    #[test]
    fn drop_oldest_keeps_fresh_frames() {
        let mut q = VecDeque::new();
        for i in 0..2 {
            admit(&mut q, 2, ShedPolicy::DropOldest, req(i, i as f64));
        }
        match admit(&mut q, 2, ShedPolicy::DropOldest, req(2, 2.0)) {
            Admission::AdmittedEvicted(old) => assert_eq!(old.id, 0),
            other => panic!("expected eviction, got {other:?}"),
        }
        let ids: Vec<u64> = q.iter().map(|r| r.id).collect();
        assert_eq!(ids, vec![1, 2]);
    }

    #[test]
    fn class_aware_evicts_lowest_class_first() {
        let mut q = VecDeque::new();
        admit(&mut q, 3, ShedPolicy::ClassAware, classed(0, SloClass::Batchable));
        admit(&mut q, 3, ShedPolicy::ClassAware, classed(1, SloClass::Interactive));
        admit(&mut q, 3, ShedPolicy::ClassAware, classed(2, SloClass::Batchable));
        // A standard frame displaces the *oldest batchable*, not the
        // oldest overall (which is also batchable here) nor the
        // interactive one.
        match admit(&mut q, 3, ShedPolicy::ClassAware, classed(3, SloClass::Standard)) {
            Admission::AdmittedEvicted(old) => {
                assert_eq!(old.id, 0);
                assert_eq!(old.class, SloClass::Batchable);
            }
            other => panic!("expected eviction, got {other:?}"),
        }
        let ids: Vec<u64> = q.iter().map(|r| r.id).collect();
        assert_eq!(ids, vec![1, 2, 3]);
        // An incoming interactive evicts the remaining batchable (2),
        // leaving [interactive 1, standard 3, interactive 4].
        admit(&mut q, 3, ShedPolicy::ClassAware, classed(4, SloClass::Interactive));
        assert_eq!(q.len(), 3);
        let classes: Vec<SloClass> = q.iter().map(|r| r.class).collect();
        assert!(!classes.contains(&SloClass::Batchable));
        // With only higher classes queued, an incoming batchable is
        // itself the cheapest frame, and is rejected.
        assert_eq!(
            admit(&mut q, 3, ShedPolicy::ClassAware, classed(5, SloClass::Batchable)),
            Admission::Rejected
        );
    }

    #[test]
    fn class_aware_degenerates_to_drop_oldest_within_one_class() {
        let mut q = VecDeque::new();
        for i in 0..2 {
            admit(&mut q, 2, ShedPolicy::ClassAware, req(i, i as f64));
        }
        match admit(&mut q, 2, ShedPolicy::ClassAware, req(2, 2.0)) {
            Admission::AdmittedEvicted(old) => assert_eq!(old.id, 0),
            other => panic!("expected drop-oldest eviction, got {other:?}"),
        }
        let ids: Vec<u64> = q.iter().map(|r| r.id).collect();
        assert_eq!(ids, vec![1, 2]);
    }

    #[test]
    fn class_quota_refills_and_isolates_buckets() {
        let mut q = ClassQuota::new([10.0, 10.0, 2.0], [2.0, 2.0, 2.0]);
        // Burst: two batchable admits at t=0, then the bucket is dry.
        assert!(q.try_take(SloClass::Batchable, 0.0));
        assert!(q.try_take(SloClass::Batchable, 0.0));
        assert!(!q.try_take(SloClass::Batchable, 0.0));
        // Other buckets are untouched by the batchable flood.
        assert!(q.try_take(SloClass::Interactive, 0.0));
        assert!((q.tokens(SloClass::Standard) - 2.0).abs() < 1e-12);
        // 0.5 s at 2 tokens/s refills one batchable token.
        assert!(q.try_take(SloClass::Batchable, 0.5));
        assert!(!q.try_take(SloClass::Batchable, 0.5));
        // Refill clamps at burst, and time never runs backwards.
        assert!(q.try_take(SloClass::Standard, 10.0));
        let before = q.tokens(SloClass::Standard);
        assert!(q.try_take(SloClass::Standard, 5.0), "stale timestamp still admits");
        assert!(q.tokens(SloClass::Standard) <= before);
    }

    #[test]
    fn class_aware_overflow_maps_lowest_class_to_reject() {
        use crate::pipeline::OverflowPolicy;
        let p = ShedPolicy::ClassAware;
        assert_eq!(p.overflow_for(SloClass::Batchable), OverflowPolicy::Reject);
        assert_eq!(p.overflow_for(SloClass::Standard), OverflowPolicy::DropOldest);
        assert_eq!(p.overflow_for(SloClass::Interactive), OverflowPolicy::DropOldest);
        // The class-blind policies ignore the class.
        for c in SloClass::ALL {
            assert_eq!(ShedPolicy::RejectNewest.overflow_for(c), OverflowPolicy::Reject);
            assert_eq!(ShedPolicy::DropOldest.overflow_for(c), OverflowPolicy::DropOldest);
        }
    }

    #[test]
    fn topic_front_door_sheds_like_the_queue() {
        let t = topic::<u64>(2);
        assert!(admit_via_topic(&t, 0, ShedPolicy::RejectNewest));
        assert!(admit_via_topic(&t, 1, ShedPolicy::RejectNewest));
        // Full: reject sheds the newest, drop-oldest admits.
        assert!(!admit_via_topic(&t, 2, ShedPolicy::RejectNewest));
        assert!(admit_via_topic(&t, 3, ShedPolicy::DropOldest));
        assert_eq!(t.rx.try_recv(), Ok(1));
        assert_eq!(t.rx.try_recv(), Ok(3));
    }
}
