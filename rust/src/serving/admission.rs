//! Bounded admission queues with load shedding.
//!
//! Generalizes the backpressure of [`crate::pipeline::topic`]: where the
//! Section VI pipeline *blocks* the producer when a DDS-style queue is
//! full, an open-loop fleet cannot block a camera — it must shed. Each
//! device's queue is bounded; when full, the shed policy decides whether
//! the newest request is rejected or the oldest queued request is evicted
//! (same semantics as [`crate::pipeline::OverflowPolicy`], which
//! [`admit_via_topic`] reuses directly for live threaded front doors).

use std::collections::VecDeque;

use crate::pipeline::{OverflowPolicy, Topic};

use super::Request;

/// What to do when a bounded queue is full.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum ShedPolicy {
    /// Reject the incoming request (newest-first shedding).
    RejectNewest,
    /// Evict the oldest queued request to admit the new one (freshest
    /// frames win — the right call for perception pipelines where a
    /// stale frame is worthless once a newer one exists).
    DropOldest,
    /// Shed the lowest [`super::SloClass`] first: evict the oldest
    /// queued request of the lowest-priority class present, provided the
    /// incoming request's class is at least that low — otherwise the
    /// incoming request itself is the cheapest frame to lose and is
    /// rejected. Within one class this degenerates to drop-oldest, so a
    /// single-class fleet behaves like [`ShedPolicy::DropOldest`].
    ClassAware,
}

impl ShedPolicy {
    /// The equivalent live-pipeline overflow policy (the live `Topic`
    /// front door carries no class metadata, so class-aware shedding
    /// degrades to its single-class behavior, drop-oldest).
    pub fn overflow(self) -> OverflowPolicy {
        match self {
            ShedPolicy::RejectNewest => OverflowPolicy::Reject,
            ShedPolicy::DropOldest | ShedPolicy::ClassAware => OverflowPolicy::DropOldest,
        }
    }
}

/// Outcome of one admission attempt.
#[derive(Debug, Clone, PartialEq)]
pub enum Admission {
    /// Admitted without displacing anything.
    Admitted,
    /// Admitted; the returned (oldest) request was shed to make room.
    AdmittedEvicted(Request),
    /// Queue full under [`ShedPolicy::RejectNewest`]; the new request
    /// was shed.
    Rejected,
}

/// Admit `req` into a bounded queue, shedding per `policy`. Returns what
/// happened so the caller can count sheds.
pub fn admit(
    queue: &mut VecDeque<Request>,
    capacity: usize,
    policy: ShedPolicy,
    req: Request,
) -> Admission {
    if queue.len() < capacity.max(1) {
        queue.push_back(req);
        return Admission::Admitted;
    }
    match policy {
        ShedPolicy::RejectNewest => Admission::Rejected,
        ShedPolicy::DropOldest => {
            // capacity >= 1, so the queue is non-empty here.
            let evicted = queue.pop_front().expect("non-empty full queue");
            queue.push_back(req);
            Admission::AdmittedEvicted(evicted)
        }
        ShedPolicy::ClassAware => {
            // The cheapest frame to lose is the oldest of the lowest
            // priority present (queue is non-empty: capacity >= 1).
            let worst = queue.iter().map(|r| r.class.priority()).min().expect("non-empty");
            if req.class.priority() >= worst {
                let pos = queue
                    .iter()
                    .position(|r| r.class.priority() == worst)
                    .expect("a request of the worst class exists");
                let evicted = queue.remove(pos).expect("position is in range");
                queue.push_back(req);
                Admission::AdmittedEvicted(evicted)
            } else {
                Admission::Rejected
            }
        }
    }
}

/// Admit into a live threaded [`Topic`] front door with the same shed
/// semantics (reuses [`Topic::try_publish`]). Returns `true` when the
/// message was delivered.
pub fn admit_via_topic<T>(topic: &Topic<T>, msg: T, policy: ShedPolicy) -> bool {
    topic.try_publish(msg, policy.overflow()).delivered()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::pipeline::topic;
    use crate::serving::SloClass;

    fn req(id: u64, t: f64) -> Request {
        Request { id, camera: 0, arrival_s: t, objects: 1, class: SloClass::Standard }
    }

    fn classed(id: u64, class: SloClass) -> Request {
        Request { id, camera: 0, arrival_s: id as f64, objects: 1, class }
    }

    #[test]
    fn admits_until_capacity() {
        let mut q = VecDeque::new();
        for i in 0..3 {
            assert_eq!(admit(&mut q, 3, ShedPolicy::RejectNewest, req(i, 0.0)), Admission::Admitted);
        }
        assert_eq!(admit(&mut q, 3, ShedPolicy::RejectNewest, req(3, 0.0)), Admission::Rejected);
        assert_eq!(q.len(), 3);
        assert_eq!(q.front().unwrap().id, 0);
    }

    #[test]
    fn drop_oldest_keeps_fresh_frames() {
        let mut q = VecDeque::new();
        for i in 0..2 {
            admit(&mut q, 2, ShedPolicy::DropOldest, req(i, i as f64));
        }
        match admit(&mut q, 2, ShedPolicy::DropOldest, req(2, 2.0)) {
            Admission::AdmittedEvicted(old) => assert_eq!(old.id, 0),
            other => panic!("expected eviction, got {other:?}"),
        }
        let ids: Vec<u64> = q.iter().map(|r| r.id).collect();
        assert_eq!(ids, vec![1, 2]);
    }

    #[test]
    fn class_aware_evicts_lowest_class_first() {
        let mut q = VecDeque::new();
        admit(&mut q, 3, ShedPolicy::ClassAware, classed(0, SloClass::Batchable));
        admit(&mut q, 3, ShedPolicy::ClassAware, classed(1, SloClass::Interactive));
        admit(&mut q, 3, ShedPolicy::ClassAware, classed(2, SloClass::Batchable));
        // A standard frame displaces the *oldest batchable*, not the
        // oldest overall (which is also batchable here) nor the
        // interactive one.
        match admit(&mut q, 3, ShedPolicy::ClassAware, classed(3, SloClass::Standard)) {
            Admission::AdmittedEvicted(old) => {
                assert_eq!(old.id, 0);
                assert_eq!(old.class, SloClass::Batchable);
            }
            other => panic!("expected eviction, got {other:?}"),
        }
        let ids: Vec<u64> = q.iter().map(|r| r.id).collect();
        assert_eq!(ids, vec![1, 2, 3]);
        // An incoming interactive evicts the remaining batchable (2),
        // leaving [interactive 1, standard 3, interactive 4].
        admit(&mut q, 3, ShedPolicy::ClassAware, classed(4, SloClass::Interactive));
        assert_eq!(q.len(), 3);
        let classes: Vec<SloClass> = q.iter().map(|r| r.class).collect();
        assert!(!classes.contains(&SloClass::Batchable));
        // With only higher classes queued, an incoming batchable is
        // itself the cheapest frame, and is rejected.
        assert_eq!(
            admit(&mut q, 3, ShedPolicy::ClassAware, classed(5, SloClass::Batchable)),
            Admission::Rejected
        );
    }

    #[test]
    fn class_aware_degenerates_to_drop_oldest_within_one_class() {
        let mut q = VecDeque::new();
        for i in 0..2 {
            admit(&mut q, 2, ShedPolicy::ClassAware, req(i, i as f64));
        }
        match admit(&mut q, 2, ShedPolicy::ClassAware, req(2, 2.0)) {
            Admission::AdmittedEvicted(old) => assert_eq!(old.id, 0),
            other => panic!("expected drop-oldest eviction, got {other:?}"),
        }
        let ids: Vec<u64> = q.iter().map(|r| r.id).collect();
        assert_eq!(ids, vec![1, 2]);
    }

    #[test]
    fn topic_front_door_sheds_like_the_queue() {
        let t = topic::<u64>(2);
        assert!(admit_via_topic(&t, 0, ShedPolicy::RejectNewest));
        assert!(admit_via_topic(&t, 1, ShedPolicy::RejectNewest));
        // Full: reject sheds the newest, drop-oldest admits.
        assert!(!admit_via_topic(&t, 2, ShedPolicy::RejectNewest));
        assert!(admit_via_topic(&t, 3, ShedPolicy::DropOldest));
        assert_eq!(t.rx.try_recv(), Ok(1));
        assert_eq!(t.rx.try_recv(), Ok(3));
    }
}
