//! Fault injection & failure recovery: crash/straggler-tolerant serving.
//!
//! A deployed fleet of FPGA boards fails in ways PRs 1–7 never modeled:
//! a board crashes mid-batch (power, bitstream corruption), a board
//! straggles (thermal throttling, DDR contention), a batch takes a
//! one-off latency spike, a camera's uplink drops frames before the
//! front door ever sees them. [`FaultPlan`] describes all four as a
//! *seedable, data-independent schedule* that the DES driver
//! ([`super::sim`]) and the live threaded runtime ([`super::live`])
//! inject **identically**, plus the [`RecoveryPolicy`] machinery that
//! survives it: heartbeat-timeout detection, bounded-budget
//! deadline-aware re-dispatch with exponential backoff, failover
//! routing that excludes unhealthy shards, and reboot-style replacement
//! through the existing [`Lifecycle`](super::shard::Lifecycle).
//!
//! Determinism contract: every fault draw is a **pure function** of
//! `(plan seed, identity)` — link drops hash the request id, latency
//! spikes hash `(device, per-device batch ordinal)`, crash and slowdown
//! windows are explicit `(device, time)` entries. No shared RNG stream
//! exists whose draw *order* could differ between the event-driven DES
//! and the turn-based live runtime; wherever the two drivers dispatch
//! the same batches at the same virtual instants (the zero-shed regime
//! the differential harness pins down), they inject byte-identical
//! faults. `SimConfig::faults = None` compiles every fault branch away
//! at runtime: the no-plan paths are bit-identical to the pre-fault
//! code, which `tests/fault_recovery.rs` asserts.
//!
//! Exactly-once accounting: a request id resolves to **exactly one** of
//! completed / shed / expired, no matter how many copies recovery puts
//! in flight. A straggler's original batch may finish *after* its
//! re-dispatched copy (or vice versa) — the first resolution wins and
//! later completions are suppressed (counted in
//! [`FaultReport::duplicates_suppressed`]), so
//! `offered == completed + shed + expired` holds under any injected
//! schedule in both drivers.

use crate::util::rng::Rng;

/// One device crash: at `at_s` the device stops completing, dispatching
/// and heartbeating. Its in-flight batch and queue are stranded until
/// the watchdog notices (or forever, without a [`RecoveryPolicy`]).
/// A crash aimed at a device that is already down or rebooting is
/// skipped (a board cannot crash while it is off).
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct CrashFault {
    /// Device index in registration order.
    pub device: usize,
    /// Absolute crash time, seconds.
    pub at_s: f64,
}

/// A hang/straggler window: batches *dispatched* by `device` with
/// `from_s <= t < to_s` take `factor`× their modeled service time.
/// Factors large enough to cross the heartbeat timeout turn into
/// detected hangs (the straggler watchdog re-dispatches copies of the
/// in-flight batch, and the eventual double completion is suppressed).
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct SlowdownFault {
    pub device: usize,
    pub from_s: f64,
    pub to_s: f64,
    /// Service-time multiplier, ≥ 1.
    pub factor: f64,
}

/// Detection + recovery knobs. `None` on the plan means faults are
/// injected but *nothing* recovers: the router keeps feeding dead
/// shards, stranded work expires at end of run — the baseline the
/// `BENCH_faults.json` sweep compares against.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct RecoveryPolicy {
    /// Heartbeat timeout: a crash is detected this long after it
    /// happens, and a dispatched batch whose service time exceeds this
    /// is treated as a hung straggler (its in-flight requests get
    /// re-dispatched copies).
    pub heartbeat_timeout_s: f64,
    /// Maximum dispatch attempts per request (the original counts as
    /// attempt 0); a request past the budget expires instead of
    /// retrying.
    pub retry_budget: u8,
    /// Exponential backoff base: attempt `k` (1-based) re-dispatches
    /// `backoff_base_s × 2^(k−1)` after the failure was detected.
    pub backoff_base_s: f64,
    /// Deadline-aware retry: a re-dispatch that would land more than
    /// this long after the request's arrival expires instead (stale
    /// frames are worthless to a perception pipeline).
    pub retry_deadline_s: f64,
    /// Reboot the crashed board: after detection the device re-enters
    /// the pool through `Lifecycle::Provisioning` (power-cycle +
    /// bitstream re-program) and comes back clean `reboot_delay_s`
    /// later. `false` leaves it failed for good.
    pub reboot: bool,
    pub reboot_delay_s: f64,
}

impl Default for RecoveryPolicy {
    fn default() -> Self {
        Self {
            heartbeat_timeout_s: 0.25,
            retry_budget: 3,
            backoff_base_s: 0.010,
            retry_deadline_s: 2.0,
            reboot: true,
            reboot_delay_s: 1.0,
        }
    }
}

/// The seedable fault schedule both drivers inject.
#[derive(Debug, Clone, PartialEq)]
pub struct FaultPlan {
    /// Seed for the per-identity hash draws (spikes, link drops).
    pub seed: u64,
    pub crashes: Vec<CrashFault>,
    pub slowdowns: Vec<SlowdownFault>,
    /// Per-batch probability of a transient latency spike.
    pub spike_prob: f64,
    /// Service-time multiplier of a spiked batch, ≥ 1.
    pub spike_factor: f64,
    /// Per-request probability the front-door link drops the frame
    /// before admission (counted as a shed, and separately in
    /// [`FaultReport::link_drops`]).
    pub link_drop_prob: f64,
    /// Detection/recovery machinery; `None` injects without recovering.
    pub recovery: Option<RecoveryPolicy>,
}

impl FaultPlan {
    /// A plan that injects nothing (useful as a parse/merge base; runs
    /// carrying it must be bit-identical to `faults: None`, which
    /// `tests/fault_recovery.rs` asserts).
    pub fn none(seed: u64) -> Self {
        Self {
            seed,
            crashes: Vec::new(),
            slowdowns: Vec::new(),
            spike_prob: 0.0,
            spike_factor: 1.0,
            link_drop_prob: 0.0,
            recovery: None,
        }
    }

    /// The CLI's demo plan: crash device 1 a third of the way into
    /// `horizon_s`, a 4× slowdown window on device 0 in the second
    /// half, mild spikes and link drops, recovery on.
    pub fn demo(seed: u64, horizon_s: f64) -> Self {
        Self {
            seed,
            crashes: vec![CrashFault { device: 1, at_s: horizon_s / 3.0 }],
            slowdowns: vec![SlowdownFault {
                device: 0,
                from_s: horizon_s * 0.5,
                to_s: horizon_s * 0.6,
                factor: 4.0,
            }],
            spike_prob: 0.02,
            spike_factor: 3.0,
            link_drop_prob: 0.01,
            recovery: Some(RecoveryPolicy::default()),
        }
    }

    /// Validate the plan's invariants (all entry points call this).
    pub fn validate(&self) {
        assert!(
            (0.0..=1.0).contains(&self.spike_prob),
            "spike_prob must be a probability"
        );
        assert!(
            (0.0..=1.0).contains(&self.link_drop_prob),
            "link_drop_prob must be a probability"
        );
        assert!(self.spike_factor >= 1.0, "a spike cannot speed a batch up");
        for c in &self.crashes {
            assert!(c.at_s >= 0.0, "crash times must be non-negative");
        }
        for s in &self.slowdowns {
            assert!(s.factor >= 1.0, "a slowdown cannot speed a batch up");
            assert!(s.from_s < s.to_s, "empty slowdown window");
        }
        if let Some(r) = &self.recovery {
            assert!(r.heartbeat_timeout_s > 0.0, "heartbeat timeout must be positive");
            assert!(r.backoff_base_s > 0.0, "backoff base must be positive");
            assert!(r.retry_deadline_s > 0.0, "retry deadline must be positive");
            assert!(r.reboot_delay_s >= 0.0, "reboot delay must be non-negative");
        }
    }

    /// `true` when the plan can never perturb a run (lets both drivers
    /// keep the fault machinery armed but provably inert).
    pub fn is_noop(&self) -> bool {
        self.crashes.is_empty()
            && self.slowdowns.is_empty()
            && self.spike_prob == 0.0
            && self.link_drop_prob == 0.0
    }

    /// Pure-function unit draw in `[0, 1)` for `(salt, a, b)` under the
    /// plan seed. A fresh seeded [`Rng`] per identity — no stream whose
    /// draw order could differ between drivers.
    fn unit(&self, salt: u64, a: u64, b: u64) -> f64 {
        let k = self
            .seed
            .wrapping_add(salt.wrapping_mul(0x9E37_79B9_7F4A_7C15))
            .wrapping_add(a.wrapping_mul(0xBF58_476D_1CE4_E5B9))
            .wrapping_add(b.wrapping_mul(0x94D0_49BB_1331_11EB));
        Rng::new(k).f64()
    }

    /// Does the front-door link drop request `id`? Pure in `(seed, id)`.
    pub fn drops_link(&self, id: u64) -> bool {
        self.link_drop_prob > 0.0 && self.unit(1, id, 0) < self.link_drop_prob
    }

    /// Latency-spike factor for `device`'s `ordinal`-th dispatched
    /// batch (1.0 = no spike). Pure in `(seed, device, ordinal)`.
    pub fn spike(&self, device: usize, ordinal: u64) -> f64 {
        if self.spike_prob > 0.0 && self.unit(2, device as u64, ordinal) < self.spike_prob {
            self.spike_factor
        } else {
            1.0
        }
    }

    /// Product of the slowdown factors covering `(device, t)`.
    pub fn slowdown(&self, device: usize, t: f64) -> f64 {
        self.slowdowns
            .iter()
            .filter(|s| s.device == device && s.from_s <= t && t < s.to_s)
            .map(|s| s.factor)
            .product()
    }

    /// Combined service-time multiplier for `device`'s `ordinal`-th
    /// batch dispatched at `t`. Both drivers scale the modeled batch
    /// service time by exactly this.
    pub fn service_factor(&self, device: usize, t: f64, ordinal: u64) -> f64 {
        self.slowdown(device, t) * self.spike(device, ordinal)
    }

    /// The crash scheduled for `device`, if any (first in time order).
    pub fn crash_for(&self, device: usize) -> Option<f64> {
        self.crashes
            .iter()
            .filter(|c| c.device == device)
            .map(|c| c.at_s)
            .fold(None, |acc: Option<f64>, t| Some(acc.map_or(t, |a| a.min(t))))
    }

    /// Parse the CLI `--faults` spec: comma-separated tokens
    /// `crash=DEV@T` (repeatable), `slow=DEV@FROM..TO*F`,
    /// `spikes=P*F`, `drops=P`, `seed=N`, `recover=on|off`,
    /// `timeout=S`, `budget=N`, `backoff=S`, `deadline=S`,
    /// `reboot=S|off`. Unknown or malformed tokens are an `Err` so the
    /// CLI can warn and fall back. Recovery defaults to on.
    pub fn parse(spec: &str, default_seed: u64) -> Result<Self, String> {
        let mut plan = FaultPlan::none(default_seed);
        let mut rec = RecoveryPolicy::default();
        let mut recover = true;
        for tok in spec.split(',').map(str::trim).filter(|t| !t.is_empty()) {
            let (key, val) = tok.split_once('=').ok_or_else(|| format!("token '{tok}' wants key=value"))?;
            let bad = |what: &str| format!("token '{tok}': bad {what}");
            match key {
                "crash" => {
                    let (d, t) = val.split_once('@').ok_or_else(|| bad("DEV@T"))?;
                    plan.crashes.push(CrashFault {
                        device: d.parse().map_err(|_| bad("device"))?,
                        at_s: t.parse().map_err(|_| bad("time"))?,
                    });
                }
                "slow" => {
                    let (d, rest) = val.split_once('@').ok_or_else(|| bad("DEV@FROM..TO*F"))?;
                    let (range, f) = rest.split_once('*').ok_or_else(|| bad("FROM..TO*F"))?;
                    let (from, to) = range.split_once("..").ok_or_else(|| bad("FROM..TO"))?;
                    plan.slowdowns.push(SlowdownFault {
                        device: d.parse().map_err(|_| bad("device"))?,
                        from_s: from.parse().map_err(|_| bad("from"))?,
                        to_s: to.parse().map_err(|_| bad("to"))?,
                        factor: f.parse().map_err(|_| bad("factor"))?,
                    });
                }
                "spikes" => {
                    let (p, f) = val.split_once('*').ok_or_else(|| bad("P*F"))?;
                    plan.spike_prob = p.parse().map_err(|_| bad("probability"))?;
                    plan.spike_factor = f.parse().map_err(|_| bad("factor"))?;
                }
                "drops" => plan.link_drop_prob = val.parse().map_err(|_| bad("probability"))?,
                "seed" => plan.seed = val.parse().map_err(|_| bad("seed"))?,
                "recover" => {
                    recover = match val {
                        "on" => true,
                        "off" => false,
                        _ => return Err(bad("on|off")),
                    }
                }
                "timeout" => rec.heartbeat_timeout_s = val.parse().map_err(|_| bad("seconds"))?,
                "budget" => rec.retry_budget = val.parse().map_err(|_| bad("count"))?,
                "backoff" => rec.backoff_base_s = val.parse().map_err(|_| bad("seconds"))?,
                "deadline" => rec.retry_deadline_s = val.parse().map_err(|_| bad("seconds"))?,
                "reboot" => {
                    if val == "off" {
                        rec.reboot = false;
                    } else {
                        rec.reboot = true;
                        rec.reboot_delay_s = val.parse().map_err(|_| bad("seconds|off"))?;
                    }
                }
                _ => return Err(format!("unknown fault token '{key}'")),
            }
        }
        plan.recovery = recover.then_some(rec);
        plan.validate();
        Ok(plan)
    }
}

/// Running fault/recovery counters, accumulated by whichever driver is
/// serving (lives on [`FleetMetrics`](super::metrics::FleetMetrics) so
/// the live workers share one set behind the metrics lock).
#[derive(Debug, Clone, Default, PartialEq)]
pub struct FaultStats {
    pub injected_crashes: u64,
    pub spikes: u64,
    pub link_drops: u64,
    /// Watchdog detections (crashes noticed + stragglers declared).
    pub detected: u64,
    /// Re-dispatch attempts scheduled.
    pub retries: u64,
    /// Re-dispatched copies actually admitted somewhere.
    pub redispatched: u64,
    /// Completions of an id that had already resolved (straggler
    /// originals racing their recovered copies) — suppressed, never
    /// double-counted.
    pub duplicates_suppressed: u64,
    /// Requests that ran out of retry budget / deadline, or were
    /// stranded on a dead shard with no recovery armed.
    pub expired: u64,
    /// Boards recovered through the reboot path.
    pub recovered_devices: u64,
    /// Summed crash→active repair time of recovered boards.
    pub mttr_total_s: f64,
}

impl FaultStats {
    /// Add another driver's counters into this one (the parallel DES
    /// merges per-shard stats in fixed shard order; u64 counters and
    /// the MTTR sum both commute, so the merge is exact).
    pub fn absorb(&mut self, other: &FaultStats) {
        self.injected_crashes += other.injected_crashes;
        self.spikes += other.spikes;
        self.link_drops += other.link_drops;
        self.detected += other.detected;
        self.retries += other.retries;
        self.redispatched += other.redispatched;
        self.duplicates_suppressed += other.duplicates_suppressed;
        self.expired += other.expired;
        self.recovered_devices += other.recovered_devices;
        self.mttr_total_s += other.mttr_total_s;
    }

    /// Freeze into the report row. `availability` is supplied by the
    /// driver (completed / offered after the final overwrite).
    pub fn to_report(&self, plan: &FaultPlan, availability: f64) -> FaultReport {
        FaultReport {
            injected_crashes: self.injected_crashes,
            slowdown_windows: plan.slowdowns.len() as u64,
            spikes: self.spikes,
            link_drops: self.link_drops,
            detected: self.detected,
            retries: self.retries,
            redispatched: self.redispatched,
            duplicates_suppressed: self.duplicates_suppressed,
            expired: self.expired,
            recovered_devices: self.recovered_devices,
            mttr_s: if self.recovered_devices == 0 {
                0.0
            } else {
                self.mttr_total_s / self.recovered_devices as f64
            },
            availability,
        }
    }
}

/// Fault/recovery accounting on [`FleetReport`](super::metrics::FleetReport),
/// rendered by [`fleet_table`](crate::report::fleet_table). Present iff
/// the run carried a [`FaultPlan`]; the exactly-once invariant is
/// `offered == completed + shed + expired`.
#[derive(Debug, Clone, PartialEq)]
pub struct FaultReport {
    pub injected_crashes: u64,
    pub slowdown_windows: u64,
    pub spikes: u64,
    pub link_drops: u64,
    pub detected: u64,
    pub retries: u64,
    pub redispatched: u64,
    pub duplicates_suppressed: u64,
    pub expired: u64,
    pub recovered_devices: u64,
    /// Mean crash→active repair time over recovered boards (0 when
    /// none recovered).
    pub mttr_s: f64,
    /// `completed / offered` — the headline the `BENCH_faults.json`
    /// sweep compares recovery-on vs recovery-off at each crash rate.
    pub availability: f64,
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn draws_are_pure_functions_of_identity() {
        let p = FaultPlan { link_drop_prob: 0.3, spike_prob: 0.2, ..FaultPlan::none(7) };
        for id in 0..200u64 {
            assert_eq!(p.drops_link(id), p.drops_link(id));
            assert_eq!(p.spike(1, id).to_bits(), p.spike(1, id).to_bits());
        }
        // Different identities draw independently; the empirical rate
        // lands near the probability.
        let drops = (0..10_000).filter(|&i| p.drops_link(i)).count();
        assert!((drops as f64 / 10_000.0 - 0.3).abs() < 0.03, "drop rate {drops}");
        let spikes = (0..10_000).filter(|&i| p.spike(0, i) > 1.0).count();
        assert!((spikes as f64 / 10_000.0 - 0.2).abs() < 0.03, "spike rate {spikes}");
        // Seeds decorrelate the draws.
        let q = FaultPlan { seed: 8, ..p.clone() };
        assert!((0..1000u64).any(|i| p.drops_link(i) != q.drops_link(i)));
    }

    #[test]
    fn slowdown_windows_cover_half_open_ranges() {
        let p = FaultPlan {
            slowdowns: vec![
                SlowdownFault { device: 0, from_s: 1.0, to_s: 2.0, factor: 3.0 },
                SlowdownFault { device: 0, from_s: 1.5, to_s: 2.5, factor: 2.0 },
                SlowdownFault { device: 1, from_s: 0.0, to_s: 9.0, factor: 5.0 },
            ],
            ..FaultPlan::none(0)
        };
        assert_eq!(p.slowdown(0, 0.5), 1.0);
        assert_eq!(p.slowdown(0, 1.0), 3.0);
        assert_eq!(p.slowdown(0, 1.7), 6.0, "overlapping windows multiply");
        assert_eq!(p.slowdown(0, 2.0), 2.0, "to_s is exclusive");
        assert_eq!(p.slowdown(2, 1.0), 1.0, "other devices untouched");
    }

    #[test]
    fn noop_plan_never_perturbs() {
        let p = FaultPlan::none(123);
        assert!(p.is_noop());
        for id in 0..100u64 {
            assert!(!p.drops_link(id));
            assert_eq!(p.service_factor(0, id as f64, id), 1.0);
        }
        assert_eq!(p.crash_for(0), None);
    }

    #[test]
    fn parse_round_trips_the_cli_spec() {
        let p = FaultPlan::parse(
            "crash=1@3.5, crash=0@5, slow=2@1..4*3, spikes=0.05*4, drops=0.02, \
             seed=99, timeout=0.5, budget=2, backoff=0.02, deadline=1.5, reboot=0.8",
            7,
        )
        .unwrap();
        assert_eq!(p.seed, 99);
        assert_eq!(p.crashes, vec![
            CrashFault { device: 1, at_s: 3.5 },
            CrashFault { device: 0, at_s: 5.0 },
        ]);
        assert_eq!(p.slowdowns.len(), 1);
        assert_eq!(p.spike_prob, 0.05);
        assert_eq!(p.link_drop_prob, 0.02);
        let r = p.recovery.unwrap();
        assert_eq!(r.heartbeat_timeout_s, 0.5);
        assert_eq!(r.retry_budget, 2);
        assert_eq!(r.backoff_base_s, 0.02);
        assert_eq!(r.retry_deadline_s, 1.5);
        assert!(r.reboot);
        assert_eq!(r.reboot_delay_s, 0.8);
        // recover=off strips the policy; junk is an Err, not a panic.
        assert!(FaultPlan::parse("crash=0@1,recover=off", 7).unwrap().recovery.is_none());
        assert!(FaultPlan::parse("crash=0", 7).is_err());
        assert!(FaultPlan::parse("warp=9", 7).is_err());
        // The default seed flows through when the spec names none.
        assert_eq!(FaultPlan::parse("drops=0.1", 7).unwrap().seed, 7);
    }

    #[test]
    fn crash_for_picks_the_earliest() {
        let p = FaultPlan {
            crashes: vec![
                CrashFault { device: 3, at_s: 9.0 },
                CrashFault { device: 3, at_s: 4.0 },
            ],
            ..FaultPlan::none(0)
        };
        assert_eq!(p.crash_for(3), Some(4.0));
    }

    #[test]
    fn stats_freeze_into_the_report() {
        let mut s = FaultStats::default();
        s.injected_crashes = 2;
        s.recovered_devices = 2;
        s.mttr_total_s = 3.0;
        s.expired = 4;
        let p = FaultPlan::demo(1, 10.0);
        let r = s.to_report(&p, 0.95);
        assert_eq!(r.mttr_s, 1.5);
        assert_eq!(r.slowdown_windows, 1);
        assert_eq!(r.availability, 0.95);
        assert_eq!(FaultStats::default().to_report(&p, 1.0).mttr_s, 0.0);
    }
}
