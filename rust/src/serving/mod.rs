//! Multi-device inference serving: the fleet layer above one board.
//!
//! The paper deploys one YOLOv7-tiny on one ZCU102 and wires it into the
//! Section VI traffic-monitoring system. This subsystem grows that into a
//! *fleet*: N heterogeneous devices (tuned Gemmini configs and/or CPU/GPU
//! baselines) behind a shard pool, fed by many concurrent camera streams,
//! with dynamic batching, bounded admission queues with load shedding,
//! and streaming latency-SLO metrics — all driven by a deterministic
//! discrete-event simulator so fleet-level decisions (batch policy, queue
//! depth, device mix) are benchmarkable offline, the same way the Gemmini
//! cycle simulator makes per-layer schedules benchmarkable offline.
//!
//! Module map (see `rust/src/serving/README.md` for the fleet model):
//!
//! - [`device`] — the [`Backend`] trait + Gemmini/baseline impls; batch
//!   service times derived from the existing cycle model, or measured by
//!   batch-aware schedule tuning
//!   ([`GemminiDevice::from_batch_tuning`]);
//! - [`batcher`] — max-batch/max-wait dynamic batching policy;
//! - [`shard`] — the device pool: least-outstanding-work routing, work
//!   stealing, and the provision → serve → drain → retire
//!   [`shard::Lifecycle`];
//! - [`admission`] — bounded per-device queues with shed policies
//!   (generalizing [`crate::pipeline::Topic`]'s overflow handling);
//! - [`autoscale`] — closed-loop pool sizing between DES epochs
//!   (target-utilization and p99-SLO-tracking policies, modeled
//!   provisioning delay);
//! - [`metrics`] — streaming p50/p95/p99, throughput, utilization, SLO
//!   violation counters, per-epoch windows, scaling events;
//! - [`sim`] — the discrete-event driver + arrival models (open-loop
//!   Poisson / bursty multi-camera traces, closed-loop window-limited
//!   clients), with fixed-pool and autoscaled entry points.

pub mod admission;
pub mod autoscale;
pub mod batcher;
pub mod device;
pub mod metrics;
pub mod shard;
pub mod sim;

pub use admission::ShedPolicy;
pub use autoscale::{
    AutoscaleConfig, Autoscaler, ScaleAction, ScaleEventKind, ScalePolicy, ScalingEvent,
    SloTracking, TargetUtilization,
};
pub use batcher::BatchPolicy;
pub use device::{Backend, BaselineDevice, GemminiDevice};
pub use metrics::{FleetReport, LatencyHistogram};
pub use shard::{Lifecycle, ShardPool};
pub use sim::{
    multi_camera_trace, poisson_trace, simulate, simulate_autoscaled, simulate_closed_loop,
    simulate_closed_loop_autoscaled, ClosedLoopConfig, SimConfig,
};

/// One inference request: a camera frame arriving at the fleet front door.
#[derive(Debug, Clone, PartialEq)]
pub struct Request {
    /// Monotonically increasing id over the whole trace.
    pub id: u64,
    /// Which camera stream emitted the frame.
    pub camera: usize,
    /// Arrival time at the fleet, seconds since trace start.
    pub arrival_s: f64,
    /// Objects in the frame (scene-complexity hint from the trace
    /// generator; drives burstiness, not service time).
    pub objects: usize,
}
