//! Multi-device inference serving: the fleet layer above one board.
//!
//! The paper deploys one YOLOv7-tiny on one ZCU102 and wires it into the
//! Section VI traffic-monitoring system. This subsystem grows that into a
//! *fleet*: N heterogeneous devices (tuned Gemmini configs and/or CPU/GPU
//! baselines) behind a shard pool, fed by many concurrent camera streams,
//! with dynamic batching, bounded admission queues with load shedding,
//! and streaming latency-SLO metrics — all driven by a deterministic
//! discrete-event simulator so fleet-level decisions (batch policy, queue
//! depth, device mix) are benchmarkable offline, the same way the Gemmini
//! cycle simulator makes per-layer schedules benchmarkable offline.
//!
//! Module map (see `rust/src/serving/README.md` for the fleet model):
//!
//! - [`device`] — the [`Backend`] trait + Gemmini/baseline impls; batch
//!   service times derived from the existing cycle model, or measured by
//!   batch-aware schedule tuning
//!   ([`GemminiDevice::from_batch_tuning`]); plus the [`DeviceCatalog`]
//!   the heterogeneous autoscaler provisions from (cheapest-feasible
//!   device choice);
//! - [`batcher`] — max-batch/max-wait dynamic batching policy
//!   (class-aware wait deadlines);
//! - [`shard`] — the device pool: least-outstanding-work routing, work
//!   stealing, and the provision → serve → drain → retire
//!   [`shard::Lifecycle`];
//! - [`admission`] — bounded per-device queues with shed policies
//!   (generalizing [`crate::pipeline::Topic`]'s overflow handling;
//!   [`ShedPolicy::ClassAware`] sheds the lowest [`SloClass`] first);
//! - [`ladder`] — the graceful-degradation [`VariantLadder`]: full /
//!   pruned / reduced-resolution model variants served by queue
//!   pressure under [`AdmissionPolicy::Degrade`], so overload costs
//!   accuracy gradually instead of shedding frames outright;
//! - [`faults`] — seedable fault injection ([`FaultPlan`]: crashes,
//!   hang/straggler slowdowns, per-batch latency spikes, front-door
//!   link drops) plus the [`RecoveryPolicy`] machinery (heartbeat
//!   watchdog, bounded-budget deadline-aware re-dispatch, failover
//!   routing, reboot replacement) both drivers inject identically;
//! - [`autoscale`] — closed-loop pool sizing between DES epochs
//!   (target-utilization and p99-SLO-tracking policies, modeled
//!   provisioning delay, energy-aware drain ordering);
//! - [`metrics`] — streaming p50/p95/p99, throughput, utilization, SLO
//!   violation counters (fleet-wide and per [`SloClass`]), per-epoch
//!   windows, scaling events, and the per-epoch [`EnergyLedger`];
//! - [`sim`] — the discrete-event driver + arrival models (open-loop
//!   Poisson / bursty multi-camera traces, closed-loop window-limited
//!   clients), with fixed-pool, autoscaled and heterogeneous-autoscaled
//!   entry points;
//! - [`live`] — the *real* multi-threaded serving runtime behind the
//!   same interfaces: one worker thread per shard consuming a bounded
//!   [`crate::pipeline::SharedTopic`] front door, wall- or
//!   virtual-clocked ([`serve_live`]); the DES above is its
//!   differential oracle (`tests/live_vs_des.rs`);
//! - [`scenario`] (re-export of [`crate::scenario`]) — traffic-monitoring
//!   scenarios that close the loop from simulated cameras to fleet-level
//!   accuracy: both drivers also come in `_logged` variants
//!   ([`simulate_logged`], [`serve_live_logged`]) that return the
//!   per-request [`RequestOutcome`] log the scenario pipeline scores
//!   (mAP, track continuity/fragmentation) into a
//!   [`ScenarioReport`] on the [`FleetReport`].

pub mod admission;
pub mod autoscale;
pub mod batcher;
pub mod device;
pub mod faults;
pub mod ladder;
pub mod live;
pub mod metrics;
pub mod shard;
pub mod sim;

pub use crate::scenario;
pub use admission::{AdmissionPolicy, ClassQuota, ShedPolicy};
pub use faults::{CrashFault, FaultPlan, FaultReport, RecoveryPolicy, SlowdownFault};
pub use ladder::{LadderRung, VariantLadder};
pub use autoscale::{
    AutoscaleConfig, Autoscaler, DrainOrder, ScaleAction, ScaleEventKind, ScalePolicy,
    ScalingEvent, SloTracking, TargetUtilization,
};
pub use batcher::BatchPolicy;
pub use live::{serve_live, serve_live_logged, ClockMode, LiveConfig};
pub use device::{capacity_fps, Backend, BaselineDevice, CatalogEntry, DeviceCatalog, GemminiDevice};
pub use metrics::{
    ClassReport, EnergyLedger, EpochEnergy, FleetReport, LatencyHistogram, RegimeReport,
    ScenarioReport, VariantServe,
};
pub use shard::{Lifecycle, ShardPool};
pub use sim::{
    multi_camera_trace, poisson_trace, simulate, simulate_autoscaled, simulate_autoscaled_hetero,
    simulate_autoscaled_hetero_reference, simulate_autoscaled_logged, simulate_autoscaled_reference,
    simulate_closed_loop, simulate_closed_loop_autoscaled, simulate_closed_loop_autoscaled_hetero,
    simulate_closed_loop_reference, simulate_logged, simulate_logged_reference, simulate_parallel,
    simulate_reference, ClosedLoopConfig, SimConfig,
};

/// The latency class a camera's frames are served under. The paper's
/// Section VI system has one camera and one implicit deadline; a fleet
/// serves many streams with different stakes — an operator watching a
/// junction live (interactive), routine monitoring (standard), and
/// offline analytics that only need eventual throughput (batchable).
/// The class scales the fleet SLO ([`SloClass::slo_factor`]), tightens
/// or relaxes the batcher's wait deadline ([`SloClass::wait_factor`]),
/// and orders shedding under overload ([`ShedPolicy::ClassAware`]).
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum SloClass {
    /// Tight deadline: half the fleet SLO, minimal batching delay.
    Interactive,
    /// The fleet SLO as-is (the default; class-unaware runs behave
    /// exactly as before classes existed).
    Standard,
    /// Throughput-oriented: double the fleet SLO, patient batching.
    Batchable,
}

impl SloClass {
    /// All classes, in priority order (highest first). Indexes match
    /// [`SloClass::index`].
    pub const ALL: [SloClass; 3] = [SloClass::Interactive, SloClass::Standard, SloClass::Batchable];

    /// Dense index for per-class tables.
    pub fn index(self) -> usize {
        match self {
            SloClass::Interactive => 0,
            SloClass::Standard => 1,
            SloClass::Batchable => 2,
        }
    }

    /// Shedding priority: higher keeps its frames longer under overload.
    pub fn priority(self) -> u8 {
        match self {
            SloClass::Interactive => 2,
            SloClass::Standard => 1,
            SloClass::Batchable => 0,
        }
    }

    /// Multiplier on the fleet SLO this class is judged against.
    pub fn slo_factor(self) -> f64 {
        match self {
            SloClass::Interactive => 0.5,
            SloClass::Standard => 1.0,
            SloClass::Batchable => 2.0,
        }
    }

    /// Multiplier on the batcher's max-wait deadline for this class's
    /// frames (interactive frames pull the batch closed sooner).
    pub fn wait_factor(self) -> f64 {
        match self {
            SloClass::Interactive => 0.25,
            SloClass::Standard => 1.0,
            SloClass::Batchable => 1.5,
        }
    }

    pub fn label(self) -> &'static str {
        match self {
            SloClass::Interactive => "interactive",
            SloClass::Standard => "standard",
            SloClass::Batchable => "batchable",
        }
    }

    /// The default camera → class assignment (`repro fleet --classes`,
    /// [`assign_slo_classes`]): cameras cycle through the classes so a
    /// trace offers all three symmetrically.
    pub fn for_camera(camera: usize) -> SloClass {
        SloClass::ALL[camera % 3]
    }
}

/// Stamp every request's class from its camera via
/// [`SloClass::for_camera`]. Trace generators emit [`SloClass::Standard`]
/// by default so class-unaware experiments are unchanged; call this on a
/// trace to turn on the class mix.
pub fn assign_slo_classes(trace: &mut [Request]) {
    for r in trace {
        r.class = SloClass::for_camera(r.camera);
    }
}

/// What happened to one request: completed (with its completion time) or
/// shed. The scenario pipeline replays these against the rendered frames
/// to score fleet-level accuracy — a shed frame is a missed measurement
/// for that camera's tracker.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct RequestOutcome {
    /// The request's trace id ([`Request::id`]).
    pub id: u64,
    pub camera: usize,
    /// Completion time for served requests; the shed decision time for
    /// shed ones.
    pub t_s: f64,
    /// True if the request was shed (quota, queue overflow, or eviction)
    /// instead of served.
    pub shed: bool,
    /// The [`VariantLadder`] rung the request was served at (0 = the
    /// full model; always 0 without [`AdmissionPolicy::Degrade`]). The
    /// scenario pipeline scores the rung's own detector head, so the
    /// measured accuracy reflects what was actually served.
    pub rung: u8,
}

/// One inference request: a camera frame arriving at the fleet front door.
/// `Copy` (48 bytes of plain data) — the drivers move requests between
/// queues, batches and recovery staging by value, so the hot paths never
/// touch the allocator per request.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct Request {
    /// Monotonically increasing id over the whole trace.
    pub id: u64,
    /// Which camera stream emitted the frame.
    pub camera: usize,
    /// Arrival time at the fleet, seconds since trace start.
    pub arrival_s: f64,
    /// Objects in the frame (scene-complexity hint from the trace
    /// generator; drives burstiness, not service time).
    pub objects: usize,
    /// The latency class the frame is admitted, batched, shed and judged
    /// under.
    pub class: SloClass,
    /// The degradation rung stamped at admission (0 = full model).
    /// [`AdmissionPolicy::Degrade`] raises it with queue pressure; every
    /// other policy leaves it 0.
    pub rung: u8,
    /// Dispatch attempts already spent on this request *instance*
    /// (0 = the original admission). Fault recovery re-dispatches
    /// copies with the counter bumped, bounding the retry storm by
    /// [`RecoveryPolicy::retry_budget`]; without a [`FaultPlan`] it
    /// stays 0 everywhere.
    pub retries: u8,
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn class_orderings_are_consistent() {
        // Priority strictly decreases along ALL; slo/wait factors grow.
        for w in SloClass::ALL.windows(2) {
            assert!(w[0].priority() > w[1].priority());
            assert!(w[0].slo_factor() < w[1].slo_factor());
            assert!(w[0].wait_factor() <= w[1].wait_factor());
        }
        // Standard is the do-nothing class: factors of exactly 1.
        assert_eq!(SloClass::Standard.slo_factor(), 1.0);
        assert_eq!(SloClass::Standard.wait_factor(), 1.0);
        for (i, c) in SloClass::ALL.iter().enumerate() {
            assert_eq!(c.index(), i);
        }
    }

    #[test]
    fn camera_assignment_cycles_classes() {
        let mut trace: Vec<Request> = (0..6)
            .map(|i| Request {
                id: i as u64,
                camera: i,
                arrival_s: i as f64,
                objects: 1,
                class: SloClass::Standard,
                rung: 0,
                retries: 0,
            })
            .collect();
        assign_slo_classes(&mut trace);
        assert_eq!(trace[0].class, SloClass::Interactive);
        assert_eq!(trace[1].class, SloClass::Standard);
        assert_eq!(trace[2].class, SloClass::Batchable);
        assert_eq!(trace[3].class, SloClass::Interactive);
    }
}
