//! Graceful-degradation ladder: accuracy-aware multi-variant serving.
//!
//! The paper maps an accuracy/latency trade twice — input-size sweep
//! (Fig. 3) and iterative pruning (Fig. 4) — but a classic serving fleet
//! only ever trades latency for *dropped frames*. A [`VariantLadder`]
//! gives every backend a ladder of model variants (full / pruned /
//! reduced-input-resolution), each with its own per-frame speedup and its
//! own calibrated synthetic-detector head, and
//! [`AdmissionPolicy::Degrade`](super::AdmissionPolicy::Degrade) steps a
//! request down the ladder as queue pressure grows — *before* any shed
//! decision. A degraded frame is served (cheaper, slightly less accurate)
//! instead of evicted (worth nothing), so under overload the fleet's
//! effective accuracy falls gently along the Pareto frontier instead of
//! cliff-dropping with the shed rate.
//!
//! Determinism contract (what makes the live-vs-DES differential harness
//! apply unchanged): rung selection is a pure function of the routed
//! queue's depth at admission — the DES reads `queue.len()`, the live
//! front door reads the same shard's depth counter, and in the zero-shed
//! regime both observe identical values at identical virtual instants.
//! Rung 0 *is* the base variant: speedup 1, the default detector config —
//! so a `Degrade` run that never crosses a threshold is bit-identical to
//! `AdmissionPolicy::Open`, and `scenario::evaluate_scenario`'s offline
//! ceiling stays the rung-0 detector regardless of what was served.
//!
//! Mixed-batch service time: batching devices are affine in batch size
//! (`batch_latency_s(n) = intercept + n × marginal` for both
//! [`GemminiDevice`](super::GemminiDevice) and
//! [`BaselineDevice`](super::BaselineDevice)), so a degraded frame can
//! only shrink the *marginal* term — the dispatch/weight-stream intercept
//! is paid by the invocation, not the frame. [`batch_service_s`]
//! subtracts `marginal × (1 − 1/speedup)` per degraded frame, which keeps
//! service time ≥ the intercept, monotone in batch composition, and
//! exactly `batch_latency_s(n)` when every frame is rung 0.
//!
//! [`batch_service_s`]: VariantLadder::batch_service_s

use crate::dataset::detector::SyntheticDetectorConfig;
use crate::scheduler::TuningEngine;

use super::device::Backend;
use super::metrics::VariantServe;
use super::Request;

/// One rung of the ladder: a servable model variant.
#[derive(Debug, Clone)]
pub struct LadderRung {
    /// Display name (`full`, `pruned-40`, …).
    pub name: String,
    /// Per-frame speedup over the base variant (≥ 1; rung 0 is exactly 1).
    pub speedup: f64,
    /// Calibrated synthetic-detector head for this variant — what
    /// `scenario::evaluate_scenario` scores when a frame was served at
    /// this rung. Rung 0 must be the default config (the offline ceiling).
    pub detector: SyntheticDetectorConfig,
    /// Nominal standalone mAP of the variant (Fig. 3/4 operating point).
    /// Reporting only: scenario runs measure the served accuracy for
    /// real; this feeds the fleet-level figure when no scenario ran.
    pub nominal_map: f64,
}

/// A ladder of model variants plus the queue-pressure thresholds that
/// step requests down it. Carried by
/// [`AdmissionPolicy::Degrade`](super::AdmissionPolicy::Degrade).
#[derive(Debug, Clone)]
pub struct VariantLadder {
    /// Rung 0 = the full model; higher rungs are progressively cheaper
    /// and less accurate.
    pub rungs: Vec<LadderRung>,
    /// Pressure thresholds, ascending, one per step down:
    /// `queued / queue_depth >= thresholds[k]` serves rung ≥ `k + 1`.
    pub thresholds: Vec<f64>,
}

impl VariantLadder {
    /// Validate the ladder's invariants (called by every constructor;
    /// public so hand-built ladders can self-check).
    pub fn validate(&self) {
        assert!(!self.rungs.is_empty(), "a ladder needs at least the base rung");
        assert_eq!(
            self.thresholds.len(),
            self.rungs.len() - 1,
            "one threshold per step down the ladder"
        );
        assert_eq!(self.rungs[0].speedup, 1.0, "rung 0 must be the base variant");
        for w in self.thresholds.windows(2) {
            assert!(w[0] < w[1], "thresholds must ascend: {:?}", self.thresholds);
        }
        for (i, r) in self.rungs.iter().enumerate() {
            assert!(r.speedup >= 1.0, "rung {i} ({}) slower than base", r.name);
            assert!((0.0..=1.0).contains(&r.nominal_map), "rung {i} nominal mAP");
        }
    }

    /// The standard three-rung ladder at the paper's Fig. 4 operating
    /// points, with analytic speedups — no tuning required, so tests and
    /// benches construct it cheaply. [`paper_ladder`](Self::paper_ladder)
    /// replaces the speedups with tuned measurements.
    pub fn standard() -> Self {
        let l = Self {
            rungs: vec![
                LadderRung {
                    name: "full".into(),
                    speedup: 1.0,
                    detector: SyntheticDetectorConfig::default(),
                    nominal_map: 0.86,
                },
                LadderRung {
                    name: "pruned-40".into(),
                    speedup: 1.5,
                    detector: SyntheticDetectorConfig {
                        miss_rate: 0.12,
                        fp_rate: 0.33,
                        center_jitter: 0.013,
                        size_jitter: 0.10,
                        score_sigma: 0.10,
                        confusion: 0.07,
                        ..Default::default()
                    },
                    nominal_map: 0.79,
                },
                LadderRung {
                    name: "pruned-88-small".into(),
                    speedup: 2.25,
                    detector: SyntheticDetectorConfig {
                        miss_rate: 0.20,
                        fp_rate: 0.38,
                        center_jitter: 0.018,
                        size_jitter: 0.14,
                        score_sigma: 0.13,
                        confusion: 0.10,
                        ..Default::default()
                    },
                    nominal_map: 0.68,
                },
            ],
            thresholds: vec![0.5, 0.8],
        };
        l.validate();
        l
    }

    /// The tuned ladder: the standard rungs with speedups *measured* by
    /// the cycle model through a shared cache-backed [`TuningEngine`] —
    /// the base model at `size`, `Pruned40` at `size` (Fig. 4 first
    /// operating point), and `Pruned88` at a 2/3-resolution input
    /// snapped to a multiple of 32 (Fig. 3 machinery). Replicas tuning
    /// through the same engine (or the same `--tuning-cache` file) are
    /// warm hits, so a fleet of N ladders costs one search. Each variant's
    /// search rides the engine's analytical pre-filter ranking, and — when
    /// the engine was armed with `with_transfer` — seeds its shortlist
    /// from the neighboring variants already in the cache.
    pub fn paper_ladder(engine: &mut TuningEngine, size: usize, measure_k: usize) -> Self {
        use crate::workload::{yolov7_tiny, ModelVariant};
        let cfg = engine.config().clone();
        let mut latency = |size: usize, v: ModelVariant| -> f64 {
            let mut g = yolov7_tiny(size, v, 80);
            crate::passes::replace_activations(&mut g);
            engine.tune_graph(&g, measure_k).latency_s(&cfg, true)
        };
        let base = latency(size, ModelVariant::Base);
        let p40 = latency(size, ModelVariant::Pruned40);
        let small = (size * 2 / 3 / 32 * 32).max(32);
        let p88 = latency(small, ModelVariant::Pruned88);
        let mut l = Self::standard();
        l.rungs[1].speedup = (base / p40).max(1.0);
        l.rungs[2].name = format!("pruned-88@{small}");
        l.rungs[2].speedup = (base / p88).max(1.0);
        l.validate();
        l
    }

    /// Number of rungs.
    pub fn len(&self) -> usize {
        self.rungs.len()
    }

    /// `true` when only the base rung exists (degradation disabled).
    pub fn is_empty(&self) -> bool {
        self.rungs.len() <= 1
    }

    /// Per-frame speedup of a rung (out-of-range clamps to the deepest).
    pub fn speedup(&self, rung: u8) -> f64 {
        let i = (rung as usize).min(self.rungs.len() - 1);
        self.rungs[i].speedup
    }

    /// The rung a request admitted against a queue holding `queued` of
    /// `queue_depth` slots is served at: the number of thresholds at or
    /// below the queue's fill fraction. Pure function of the observed
    /// depth — the DES and the live front door compute it identically.
    pub fn rung_for(&self, queued: usize, queue_depth: usize) -> u8 {
        let pressure = queued as f64 / queue_depth.max(1) as f64;
        self.thresholds.iter().filter(|&&t| pressure >= t).count() as u8
    }

    /// Service time of a mixed-variant batch on `backend`: the full-model
    /// batch latency minus `marginal × (1 − 1/speedup)` per degraded
    /// frame, where `marginal = batch_latency_s(2) − batch_latency_s(1)`
    /// is the device's exact per-frame slope (both device models are
    /// affine in batch size). All-rung-0 batches cost exactly
    /// `batch_latency_s(n)`, bit for bit.
    pub fn batch_service_s(&self, backend: &dyn Backend, batch: &[Request]) -> f64 {
        let full = backend.batch_latency_s(batch.len());
        if self.is_empty() {
            return full;
        }
        let marginal = backend.batch_latency_s(2) - backend.batch_latency_s(1);
        let saved: f64 =
            batch.iter().map(|r| marginal * (1.0 - 1.0 / self.speedup(r.rung))).sum();
        full - saved
    }

    /// Per-variant serve rows for the fleet report: rung names zipped
    /// with the metrics' per-rung completion counters (missing counters
    /// read 0; overflow counts — rungs beyond the ladder — fold into the
    /// deepest rung, matching [`speedup`](Self::speedup)'s clamp).
    pub fn variant_serves(&self, served: &[u64]) -> Vec<VariantServe> {
        let mut rows: Vec<VariantServe> = self
            .rungs
            .iter()
            .enumerate()
            .map(|(i, r)| VariantServe {
                name: r.name.clone(),
                served: served.get(i).copied().unwrap_or(0),
                map: r.nominal_map,
            })
            .collect();
        if served.len() > self.rungs.len() {
            let overflow: u64 = served[self.rungs.len()..].iter().sum();
            rows.last_mut().expect("validated non-empty").served += overflow;
        }
        rows
    }

    /// Fleet-level effective accuracy from nominal operating points:
    /// `Σ served_k × nominal_map_k / offered` — a shed frame contributes
    /// zero. Scenario runs report the *measured* analogue
    /// (`ScenarioReport::map`); this figure makes plain fleet runs
    /// comparable without ground truth.
    pub fn effective_accuracy(&self, served: &[u64], offered: u64) -> f64 {
        if offered == 0 {
            return 0.0;
        }
        let sum: f64 = self
            .variant_serves(served)
            .iter()
            .map(|v| v.served as f64 * v.map)
            .sum();
        sum / offered as f64
    }
}

#[cfg(test)]
mod tests {
    use super::super::{BaselineDevice, SloClass};
    use super::*;
    use crate::baselines::Platform;

    fn req(rung: u8) -> Request {
        Request {
            id: 0,
            camera: 0,
            arrival_s: 0.0,
            objects: 1,
            class: SloClass::Standard,
            rung,
            retries: 0,
        }
    }

    fn dev() -> BaselineDevice {
        let p =
            Platform { name: "lad-dev", overhead_s: 5e-3, sustained_gops: 100.0, power_w: 10.0 };
        BaselineDevice::new(p, 0.5, 16)
    }

    #[test]
    fn standard_ladder_validates_and_rungs_monotone() {
        let l = VariantLadder::standard();
        assert_eq!(l.len(), 3);
        for w in l.rungs.windows(2) {
            assert!(w[1].speedup > w[0].speedup, "speedup must grow down the ladder");
            assert!(w[1].nominal_map < w[0].nominal_map, "accuracy must fall down the ladder");
        }
    }

    #[test]
    fn rung_selection_follows_queue_pressure() {
        let l = VariantLadder::standard();
        assert_eq!(l.rung_for(0, 16), 0);
        assert_eq!(l.rung_for(7, 16), 0); // 43.75% < 50%
        assert_eq!(l.rung_for(8, 16), 1); // exactly 50%
        assert_eq!(l.rung_for(12, 16), 1); // 75% < 80%
        assert_eq!(l.rung_for(13, 16), 2); // 81.25%
        assert_eq!(l.rung_for(16, 16), 2);
        // Degenerate depth never divides by zero.
        assert_eq!(l.rung_for(5, 0), 2);
    }

    #[test]
    fn base_batches_cost_exactly_the_backend_latency() {
        let l = VariantLadder::standard();
        let d = dev();
        for n in [1usize, 3, 8] {
            let batch: Vec<Request> = (0..n).map(|_| req(0)).collect();
            assert_eq!(
                l.batch_service_s(&d, &batch).to_bits(),
                d.batch_latency_s(n).to_bits(),
                "all-base batch of {n} must be bit-identical to the plain latency"
            );
        }
    }

    #[test]
    fn degraded_batches_save_marginal_time_and_keep_the_intercept() {
        let l = VariantLadder::standard();
        let d = dev();
        let full = l.batch_service_s(&d, &[req(0), req(0), req(0), req(0)]);
        let mixed = l.batch_service_s(&d, &[req(0), req(1), req(2), req(0)]);
        let deep = l.batch_service_s(&d, &[req(2), req(2), req(2), req(2)]);
        assert!(mixed < full, "degrading frames must shorten the batch");
        assert!(deep < mixed, "deeper rungs must save more");
        // The intercept (dispatch overhead) is per-invocation: even a
        // fully degraded batch costs more than the overhead alone.
        let marginal = d.batch_latency_s(2) - d.batch_latency_s(1);
        let intercept = d.batch_latency_s(1) - marginal;
        assert!(deep > intercept, "service {deep} fell below the intercept {intercept}");
        // Out-of-range rungs clamp to the deepest.
        assert_eq!(
            l.batch_service_s(&d, &[req(9)]).to_bits(),
            l.batch_service_s(&d, &[req(2)]).to_bits()
        );
    }

    #[test]
    fn effective_accuracy_weighs_serves_and_charges_sheds() {
        let l = VariantLadder::standard();
        // 60 full + 30 pruned-40 + 10 deep served of 120 offered
        // (20 shed): sheds score zero.
        let eff = l.effective_accuracy(&[60, 30, 10], 120);
        let expect = (60.0 * 0.86 + 30.0 * 0.79 + 10.0 * 0.68) / 120.0;
        assert!((eff - expect).abs() < 1e-12);
        // All served at rung 0 ⇒ the nominal base accuracy.
        assert!((l.effective_accuracy(&[100, 0, 0], 100) - 0.86).abs() < 1e-12);
        assert_eq!(l.effective_accuracy(&[0, 0, 0], 0), 0.0);
        // Overflow counters fold into the deepest rung.
        let rows = l.variant_serves(&[1, 2, 3, 4]);
        assert_eq!(rows.len(), 3);
        assert_eq!(rows[2].served, 7);
    }
}
