//! The shard pool: N heterogeneous devices, least-outstanding-work
//! routing, work stealing, and the device lifecycle the autoscaler
//! drives.
//!
//! Routing estimates each device's time-to-drain (remaining service of
//! the in-flight batch plus the estimated service of its queue with the
//! candidate request appended) and picks the minimum — so a 167 MHz
//! ZCU111 naturally absorbs more streams than a 100 MHz original-config
//! board, without static weights. When a device goes idle with an empty
//! queue, it steals the newer half of the most-backlogged sibling's
//! queue (FIFO order is preserved for the victim's older requests).
//!
//! Devices move through a [`Lifecycle`]: `Provisioning` (warming up,
//! invisible to routing) → `Active` (serving + accepting) → `Draining`
//! (serving its backlog, accepting nothing) → `Retired` (kept in the vec
//! so device indices and per-device metrics stay stable across scaling).

use std::cell::Cell;
use std::collections::VecDeque;

use crate::fpga::resources::Board;
use crate::gemmini::config::GemminiConfig;
use crate::scheduler::TuningResult;

use super::device::{Backend, GemminiDevice};
use super::Request;

/// Where a device sits in the provision → serve → drain → retire arc.
#[derive(Debug, Clone, Copy, PartialEq)]
pub enum Lifecycle {
    /// Serving and accepting new work.
    Active,
    /// Warming up (bitstream programming + runtime attach); joins the
    /// pool at `ready_at`.
    Provisioning { ready_at: f64 },
    /// Serving its backlog but accepting no new work.
    Draining,
    /// Drained and out of service (kept for stable indices/reports).
    Retired,
    /// Crashed and detected as such by the watchdog: executes nothing,
    /// accepts nothing. A rebooting device transitions back through
    /// `Provisioning` once fault recovery re-provisions it.
    Failed,
}

impl Lifecycle {
    /// Whether the device currently executes batches.
    pub fn serves(self) -> bool {
        matches!(self, Lifecycle::Active | Lifecycle::Draining)
    }

    /// Whether new requests may be routed or stolen into the device.
    pub fn accepts_new(self) -> bool {
        matches!(self, Lifecycle::Active)
    }

    /// Short state label for reports.
    pub fn label(self) -> &'static str {
        match self {
            Lifecycle::Active => "active",
            Lifecycle::Provisioning { .. } => "warming",
            Lifecycle::Draining => "draining",
            Lifecycle::Retired => "retired",
            Lifecycle::Failed => "failed",
        }
    }
}

/// One registered device plus its serving state.
pub struct DeviceState {
    pub backend: Box<dyn Backend>,
    /// Admitted requests waiting to be batched.
    pub queue: VecDeque<Request>,
    /// Whether a batch is currently in flight.
    pub busy: bool,
    /// Absolute time the in-flight batch completes, s.
    pub free_at: f64,
    /// The in-flight batch's requests (latencies recorded at completion).
    pub in_flight: Vec<Request>,
    /// Autoscaling lifecycle state (always `Active` in fixed pools).
    pub lifecycle: Lifecycle,
    /// Recycled batch buffer: the DES completion loop parks the drained
    /// in-flight `Vec` here and the next dispatch reuses it, so steady
    /// state allocates no batch vectors at all.
    pub spare: Vec<Request>,
    /// One-entry memo of `backend.batch_latency_s(len)` keyed by `len`
    /// (`usize::MAX` = empty). The model is a pure function of the
    /// batch size, so a hit returns the identical f64 the virtual call
    /// would — routing's hot path skips the vtable + model math while
    /// the queue length sits still between arrivals.
    service_memo: Cell<(usize, f64)>,
}

impl DeviceState {
    fn new(backend: Box<dyn Backend>) -> Self {
        Self {
            backend,
            queue: VecDeque::new(),
            busy: false,
            free_at: 0.0,
            in_flight: Vec::new(),
            lifecycle: Lifecycle::Active,
            spare: Vec::new(),
            service_memo: Cell::new((usize::MAX, 0.0)),
        }
    }

    /// `backend.batch_latency_s(n)` through the one-entry memo.
    pub fn service_for(&self, n: usize) -> f64 {
        let (k, v) = self.service_memo.get();
        if k == n {
            return v;
        }
        let s = self.backend.batch_latency_s(n);
        self.service_memo.set((n, s));
        s
    }

    /// Estimated seconds until this device could finish one more request
    /// arriving at `now`.
    pub fn outstanding_s(&self, now: f64) -> f64 {
        let busy_rem = if self.busy { (self.free_at - now).max(0.0) } else { 0.0 };
        busy_rem + self.backend.batch_latency_s(self.queue.len() + 1)
    }

    /// [`DeviceState::outstanding_s`] through the service memo —
    /// bit-identical (same pure function of the queue length), without
    /// the virtual call on a memo hit.
    fn outstanding_fast_s(&self, now: f64) -> f64 {
        let busy_rem = if self.busy { (self.free_at - now).max(0.0) } else { 0.0 };
        busy_rem + self.service_for(self.queue.len() + 1)
    }
}

/// The registered fleet.
#[derive(Default)]
pub struct ShardPool {
    pub devices: Vec<DeviceState>,
}

impl ShardPool {
    pub fn new() -> Self {
        Self { devices: Vec::new() }
    }

    /// Register an active device; returns its index.
    pub fn register(&mut self, backend: Box<dyn Backend>) -> usize {
        self.devices.push(DeviceState::new(backend));
        self.devices.len() - 1
    }

    /// Register a device that is still warming up; it starts serving at
    /// `ready_at` (the autoscaler's provisioning path). Returns its index.
    pub fn register_provisioning(&mut self, backend: Box<dyn Backend>, ready_at: f64) -> usize {
        let mut d = DeviceState::new(backend);
        d.lifecycle = Lifecycle::Provisioning { ready_at };
        self.devices.push(d);
        self.devices.len() - 1
    }

    /// The paper's two tuned boards as a pool: the "ours" ZCU102 build
    /// plus the same architecture at the ZCU111's 167 MHz, sharing one
    /// `TuningResult` (identical architecture, so the tuned schedules
    /// transfer; only the clock differs). The CLI, bench and example all
    /// start from this and register extra devices on top.
    pub fn paper_boards(tuning: &TuningResult, dispatch_s: f64) -> Self {
        let mut pool = Self::new();
        pool.register(Box::new(GemminiDevice::from_tuning(
            "ZCU102-Gemmini (ours)",
            Board::Zcu102,
            GemminiConfig::ours_zcu102(),
            tuning,
            dispatch_s,
        )));
        pool.register(Box::new(GemminiDevice::from_tuning(
            "ZCU111-Gemmini (ours)",
            Board::Zcu111,
            GemminiConfig::ours_zcu111(),
            tuning,
            dispatch_s,
        )));
        pool
    }

    pub fn len(&self) -> usize {
        self.devices.len()
    }

    pub fn is_empty(&self) -> bool {
        self.devices.is_empty()
    }

    /// Devices currently accepting new work.
    pub fn active_count(&self) -> usize {
        self.devices.iter().filter(|d| d.lifecycle.accepts_new()).count()
    }

    /// Devices currently executing batches (active + draining).
    pub fn serving_count(&self) -> usize {
        self.devices.iter().filter(|d| d.lifecycle.serves()).count()
    }

    /// Devices still warming up.
    pub fn provisioning_count(&self) -> usize {
        self.devices
            .iter()
            .filter(|d| matches!(d.lifecycle, Lifecycle::Provisioning { .. }))
            .count()
    }

    /// Total queued (not yet dispatched) requests across the pool.
    pub fn backlog(&self) -> usize {
        self.devices.iter().map(|d| d.queue.len()).sum()
    }

    /// Hand the registered backends over to a runtime that owns its own
    /// serving state — the live threaded path (`serving::live`) spawns
    /// one worker per backend and has no use for the DES bookkeeping.
    /// Panics if any DES state is non-trivial (pre-loaded queues or
    /// in-flight batches belong to simulations, not live startups).
    pub fn into_backends(self) -> Vec<Box<dyn Backend>> {
        self.devices
            .into_iter()
            .map(|d| {
                assert!(
                    d.queue.is_empty() && !d.busy && matches!(d.lifecycle, Lifecycle::Active),
                    "live serving starts from an idle, active pool"
                );
                d.backend
            })
            .collect()
    }

    /// Least-outstanding-work routing over devices accepting new work:
    /// the device that would finish the new request soonest. Ties break
    /// to the lowest index (deterministic). If scale-in transiently left
    /// none active, fall back to a still-serving (draining) device, then
    /// to one that is warming up — it will serve once it activates, so a
    /// request parked there is never stranded (the autoscaler's
    /// min-devices clamp guarantees active + provisioning ≥ 1).
    pub fn route(&self, now: f64) -> usize {
        let mut best = None;
        let mut best_s = f64::INFINITY;
        for (i, d) in self.devices.iter().enumerate() {
            if !d.lifecycle.accepts_new() {
                continue;
            }
            let est = d.outstanding_s(now);
            if est < best_s {
                best_s = est;
                best = Some(i);
            }
        }
        best.unwrap_or_else(|| {
            self.devices
                .iter()
                .position(|d| d.lifecycle.serves())
                .or_else(|| {
                    self.devices
                        .iter()
                        .position(|d| matches!(d.lifecycle, Lifecycle::Provisioning { .. }))
                })
                .unwrap_or(0)
        })
    }

    /// [`ShardPool::route`] with the per-device service memo: identical
    /// choice (the memo returns the identical estimate), but the
    /// per-arrival scan skips the virtual latency-model call for every
    /// device whose queue length hasn't changed since its last estimate.
    pub fn route_fast(&self, now: f64) -> usize {
        let mut best = None;
        let mut best_s = f64::INFINITY;
        for (i, d) in self.devices.iter().enumerate() {
            if !d.lifecycle.accepts_new() {
                continue;
            }
            let est = d.outstanding_fast_s(now);
            if est < best_s {
                best_s = est;
                best = Some(i);
            }
        }
        best.unwrap_or_else(|| {
            self.devices
                .iter()
                .position(|d| d.lifecycle.serves())
                .or_else(|| {
                    self.devices
                        .iter()
                        .position(|d| matches!(d.lifecycle, Lifecycle::Provisioning { .. }))
                })
                .unwrap_or(0)
        })
    }

    /// Split the pool into `shards` independent sub-pools, device `i`
    /// going to pool `i % shards` — the device-side partition of the
    /// parallel DES ([`crate::serving::sim::simulate_parallel`]), which
    /// pairs it with the camera-side partition `camera % shards`. Every
    /// device must be idle and active (sub-simulations start clean).
    /// Panics if `shards` is 0 or exceeds the device count.
    pub fn split_round_robin(self, shards: usize) -> Vec<ShardPool> {
        assert!(shards > 0, "need at least one shard");
        assert!(
            shards <= self.devices.len(),
            "cannot split {} devices into {shards} shards",
            self.devices.len()
        );
        let mut pools: Vec<ShardPool> = (0..shards).map(|_| ShardPool::new()).collect();
        for (i, d) in self.devices.into_iter().enumerate() {
            assert!(
                d.queue.is_empty() && !d.busy && matches!(d.lifecycle, Lifecycle::Active),
                "parallel simulation starts from an idle, active pool"
            );
            pools[i % shards].devices.push(d);
        }
        pools
    }

    /// The active device the energy-aware autoscaler drains first: the
    /// highest idle power among active devices. Power ranks first —
    /// whether a device happens to be mid-batch at the epoch instant is
    /// a transient, while its board watts burn for as long as it stays
    /// in the pool (a draining device finishes its backlog anyway, so
    /// draining a busy board costs only delayed retirement, never lost
    /// work). Idle-right-now breaks power ties, then the newest index
    /// (replicas before seed boards, matching the homogeneous drain
    /// order). `None` when nothing is active.
    pub fn most_expensive_active(&self) -> Option<usize> {
        let mut best: Option<(f64, bool, usize)> = None;
        for (i, d) in self.devices.iter().enumerate() {
            if !matches!(d.lifecycle, Lifecycle::Active) {
                continue;
            }
            let idle_now = !d.busy && d.queue.is_empty();
            let key = (d.backend.power_w(0.0), idle_now, i);
            let better = match &best {
                None => true,
                // Tuple order: hottest, then idle-now, then newest.
                Some(b) => key > *b,
            };
            if better {
                best = Some(key);
            }
        }
        best.map(|(_, _, i)| i)
    }

    /// Steal the newer half of the most-backlogged sibling's queue into
    /// idle device `idx`. Returns how many requests moved.
    pub fn steal_into(&mut self, idx: usize) -> usize {
        debug_assert!(self.devices[idx].queue.is_empty());
        // Victim: largest queue with at least 2 requests (stealing a lone
        // request just moves the same work without helping latency).
        let mut victim = None;
        let mut victim_len = 1;
        for (i, d) in self.devices.iter().enumerate() {
            if i != idx && d.queue.len() > victim_len {
                victim_len = d.queue.len();
                victim = Some(i);
            }
        }
        let Some(v) = victim else { return 0 };
        let take = victim_len / 2;
        let keep = victim_len - take;
        let stolen = self.devices[v].queue.split_off(keep);
        let n = stolen.len();
        self.devices[idx].queue.extend(stolen);
        n
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::baselines::{rpi4, xavier};
    use crate::serving::device::BaselineDevice;

    fn req(id: u64, t: f64) -> Request {
        Request {
            id,
            camera: 0,
            arrival_s: t,
            objects: 1,
            class: crate::serving::SloClass::Standard,
            rung: 0,
            retries: 0,
        }
    }

    fn pool2() -> ShardPool {
        let mut p = ShardPool::new();
        // Xavier ~19× the RPi4's sustained throughput on this workload.
        p.register(Box::new(BaselineDevice::new(xavier(), 0.5, 8)));
        p.register(Box::new(BaselineDevice::new(rpi4(), 0.5, 8)));
        p
    }

    #[test]
    fn routes_to_idle_fast_device() {
        let p = pool2();
        assert_eq!(p.route(0.0), 0, "empty pool routes to the faster device");
    }

    #[test]
    fn routing_accounts_for_queue_depth_and_speed() {
        let mut p = pool2();
        // Pile work on the fast device until the slow one wins.
        for i in 0..64 {
            p.devices[0].queue.push_back(req(i, 0.0));
        }
        assert_eq!(p.route(0.0), 1, "deep queue on the fast device diverts to the slow one");
    }

    #[test]
    fn routing_accounts_for_busy_remainder() {
        let mut p = pool2();
        p.devices[0].busy = true;
        p.devices[0].free_at = 1000.0; // wedged for a long time
        assert_eq!(p.route(0.0), 1);
    }

    #[test]
    fn steal_takes_newer_half_preserving_victim_order() {
        let mut p = pool2();
        for i in 0..5 {
            p.devices[0].queue.push_back(req(i, i as f64));
        }
        let n = p.steal_into(1);
        assert_eq!(n, 2);
        let victim: Vec<u64> = p.devices[0].queue.iter().map(|r| r.id).collect();
        let thief: Vec<u64> = p.devices[1].queue.iter().map(|r| r.id).collect();
        assert_eq!(victim, vec![0, 1, 2]);
        assert_eq!(thief, vec![3, 4]);
    }

    #[test]
    fn no_steal_from_single_request_queues() {
        let mut p = pool2();
        p.devices[0].queue.push_back(req(0, 0.0));
        assert_eq!(p.steal_into(1), 0);
        assert_eq!(p.devices[0].queue.len(), 1);
    }

    #[test]
    fn routing_skips_non_active_devices() {
        let mut p = pool2();
        // Fast device warming up: everything routes to the slow one.
        p.devices[0].lifecycle = Lifecycle::Provisioning { ready_at: 5.0 };
        assert_eq!(p.route(0.0), 1);
        // Draining devices take no new work either.
        p.devices[0].lifecycle = Lifecycle::Draining;
        assert_eq!(p.route(0.0), 1);
        // With nothing active, fall back to a still-serving device.
        p.devices[1].lifecycle = Lifecycle::Retired;
        assert_eq!(p.route(0.0), 0);
    }

    #[test]
    fn lifecycle_predicates() {
        assert!(Lifecycle::Active.serves() && Lifecycle::Active.accepts_new());
        assert!(Lifecycle::Draining.serves() && !Lifecycle::Draining.accepts_new());
        let warming = Lifecycle::Provisioning { ready_at: 1.0 };
        assert!(!warming.serves() && !warming.accepts_new());
        assert!(!Lifecycle::Retired.serves());
        assert_eq!(warming.label(), "warming");
    }

    #[test]
    fn most_expensive_active_ranks_power_then_idleness_then_newest() {
        let mut p = pool2(); // xavier (30 W) then rpi4 (6.5 W)
        // Both idle: the hotter xavier drains first.
        assert_eq!(p.most_expensive_active(), Some(0));
        // Xavier busy, rpi4 idle: the 30 W board *still* drains first —
        // busy-at-this-instant is a transient, its watts are not.
        p.devices[0].busy = true;
        assert_eq!(p.most_expensive_active(), Some(0));
        // Nothing active → None.
        p.devices[0].lifecycle = Lifecycle::Draining;
        p.devices[1].lifecycle = Lifecycle::Retired;
        assert_eq!(p.most_expensive_active(), None);
        // Equal power: the idle device beats the busy one…
        let mut q = ShardPool::new();
        q.register(Box::new(BaselineDevice::new(rpi4(), 0.5, 8)));
        q.register(Box::new(BaselineDevice::new(rpi4(), 0.5, 8)));
        q.devices[1].busy = true;
        assert_eq!(q.most_expensive_active(), Some(0));
        // …and with idleness equal too, the newest index wins.
        q.devices[1].busy = false;
        assert_eq!(q.most_expensive_active(), Some(1));
    }

    #[test]
    fn route_fast_matches_route_and_memo_is_exact() {
        let mut p = pool2();
        for i in 0..7 {
            p.devices[0].queue.push_back(req(i, 0.0));
        }
        p.devices[1].busy = true;
        p.devices[1].free_at = 0.3;
        for now in [0.0, 0.1, 0.25, 0.5] {
            assert_eq!(p.route(now), p.route_fast(now));
        }
        // The memo returns the identical f64 across repeated hits and
        // after the key changes.
        let d = &p.devices[0];
        let direct = d.backend.batch_latency_s(8);
        assert_eq!(d.service_for(8).to_bits(), direct.to_bits());
        assert_eq!(d.service_for(8).to_bits(), direct.to_bits(), "memo hit is exact");
        assert_eq!(d.service_for(3).to_bits(), d.backend.batch_latency_s(3).to_bits());
    }

    #[test]
    fn split_round_robin_deals_devices_cyclically() {
        let mut p = ShardPool::new();
        for _ in 0..5 {
            p.register(Box::new(BaselineDevice::new(xavier(), 0.5, 8)));
        }
        let pools = p.split_round_robin(2);
        assert_eq!(pools.len(), 2);
        assert_eq!(pools[0].len(), 3);
        assert_eq!(pools[1].len(), 2);
    }

    #[test]
    fn provisioning_registration_is_invisible_until_activated() {
        let mut p = pool2();
        let idx = p.register_provisioning(Box::new(BaselineDevice::new(xavier(), 0.5, 8)), 2.0);
        assert_eq!(idx, 2);
        assert_eq!(p.active_count(), 2);
        assert_eq!(p.serving_count(), 2);
        assert_eq!(p.provisioning_count(), 1);
        p.devices[idx].lifecycle = Lifecycle::Active;
        assert_eq!(p.active_count(), 3);
        assert_eq!(p.provisioning_count(), 0);
    }
}
