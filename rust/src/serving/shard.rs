//! The shard pool: N heterogeneous devices, least-outstanding-work
//! routing, and work stealing.
//!
//! Routing estimates each device's time-to-drain (remaining service of
//! the in-flight batch plus the estimated service of its queue with the
//! candidate request appended) and picks the minimum — so a 167 MHz
//! ZCU111 naturally absorbs more streams than a 100 MHz original-config
//! board, without static weights. When a device goes idle with an empty
//! queue, it steals the newer half of the most-backlogged sibling's
//! queue (FIFO order is preserved for the victim's older requests).

use std::collections::VecDeque;

use crate::fpga::resources::Board;
use crate::gemmini::config::GemminiConfig;
use crate::scheduler::TuningResult;

use super::device::{Backend, GemminiDevice};
use super::Request;

/// One registered device plus its serving state.
pub struct DeviceState {
    pub backend: Box<dyn Backend>,
    /// Admitted requests waiting to be batched.
    pub queue: VecDeque<Request>,
    /// Whether a batch is currently in flight.
    pub busy: bool,
    /// Absolute time the in-flight batch completes, s.
    pub free_at: f64,
    /// The in-flight batch's requests (latencies recorded at completion).
    pub in_flight: Vec<Request>,
}

impl DeviceState {
    fn new(backend: Box<dyn Backend>) -> Self {
        Self { backend, queue: VecDeque::new(), busy: false, free_at: 0.0, in_flight: Vec::new() }
    }

    /// Estimated seconds until this device could finish one more request
    /// arriving at `now`.
    pub fn outstanding_s(&self, now: f64) -> f64 {
        let busy_rem = if self.busy { (self.free_at - now).max(0.0) } else { 0.0 };
        busy_rem + self.backend.batch_latency_s(self.queue.len() + 1)
    }
}

/// The registered fleet.
#[derive(Default)]
pub struct ShardPool {
    pub devices: Vec<DeviceState>,
}

impl ShardPool {
    pub fn new() -> Self {
        Self { devices: Vec::new() }
    }

    /// Register a device; returns its index.
    pub fn register(&mut self, backend: Box<dyn Backend>) -> usize {
        self.devices.push(DeviceState::new(backend));
        self.devices.len() - 1
    }

    /// The paper's two tuned boards as a pool: the "ours" ZCU102 build
    /// plus the same architecture at the ZCU111's 167 MHz, sharing one
    /// `TuningResult` (identical architecture, so the tuned schedules
    /// transfer; only the clock differs). The CLI, bench and example all
    /// start from this and register extra devices on top.
    pub fn paper_boards(tuning: &TuningResult, dispatch_s: f64) -> Self {
        let mut pool = Self::new();
        pool.register(Box::new(GemminiDevice::from_tuning(
            "ZCU102-Gemmini (ours)",
            Board::Zcu102,
            GemminiConfig::ours_zcu102(),
            tuning,
            dispatch_s,
        )));
        pool.register(Box::new(GemminiDevice::from_tuning(
            "ZCU111-Gemmini (ours)",
            Board::Zcu111,
            GemminiConfig::ours_zcu111(),
            tuning,
            dispatch_s,
        )));
        pool
    }

    pub fn len(&self) -> usize {
        self.devices.len()
    }

    pub fn is_empty(&self) -> bool {
        self.devices.is_empty()
    }

    /// Least-outstanding-work routing: the device that would finish the
    /// new request soonest. Ties break to the lowest index
    /// (deterministic).
    pub fn route(&self, now: f64) -> usize {
        let mut best = 0;
        let mut best_s = f64::INFINITY;
        for (i, d) in self.devices.iter().enumerate() {
            let est = d.outstanding_s(now);
            if est < best_s {
                best_s = est;
                best = i;
            }
        }
        best
    }

    /// Steal the newer half of the most-backlogged sibling's queue into
    /// idle device `idx`. Returns how many requests moved.
    pub fn steal_into(&mut self, idx: usize) -> usize {
        debug_assert!(self.devices[idx].queue.is_empty());
        // Victim: largest queue with at least 2 requests (stealing a lone
        // request just moves the same work without helping latency).
        let mut victim = None;
        let mut victim_len = 1;
        for (i, d) in self.devices.iter().enumerate() {
            if i != idx && d.queue.len() > victim_len {
                victim_len = d.queue.len();
                victim = Some(i);
            }
        }
        let Some(v) = victim else { return 0 };
        let take = victim_len / 2;
        let keep = victim_len - take;
        let stolen = self.devices[v].queue.split_off(keep);
        let n = stolen.len();
        self.devices[idx].queue.extend(stolen);
        n
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::baselines::{rpi4, xavier};
    use crate::serving::device::BaselineDevice;

    fn req(id: u64, t: f64) -> Request {
        Request { id, camera: 0, arrival_s: t, objects: 1 }
    }

    fn pool2() -> ShardPool {
        let mut p = ShardPool::new();
        // Xavier ~19× the RPi4's sustained throughput on this workload.
        p.register(Box::new(BaselineDevice::new(xavier(), 0.5, 8)));
        p.register(Box::new(BaselineDevice::new(rpi4(), 0.5, 8)));
        p
    }

    #[test]
    fn routes_to_idle_fast_device() {
        let p = pool2();
        assert_eq!(p.route(0.0), 0, "empty pool routes to the faster device");
    }

    #[test]
    fn routing_accounts_for_queue_depth_and_speed() {
        let mut p = pool2();
        // Pile work on the fast device until the slow one wins.
        for i in 0..64 {
            p.devices[0].queue.push_back(req(i, 0.0));
        }
        assert_eq!(p.route(0.0), 1, "deep queue on the fast device diverts to the slow one");
    }

    #[test]
    fn routing_accounts_for_busy_remainder() {
        let mut p = pool2();
        p.devices[0].busy = true;
        p.devices[0].free_at = 1000.0; // wedged for a long time
        assert_eq!(p.route(0.0), 1);
    }

    #[test]
    fn steal_takes_newer_half_preserving_victim_order() {
        let mut p = pool2();
        for i in 0..5 {
            p.devices[0].queue.push_back(req(i, i as f64));
        }
        let n = p.steal_into(1);
        assert_eq!(n, 2);
        let victim: Vec<u64> = p.devices[0].queue.iter().map(|r| r.id).collect();
        let thief: Vec<u64> = p.devices[1].queue.iter().map(|r| r.id).collect();
        assert_eq!(victim, vec![0, 1, 2]);
        assert_eq!(thief, vec![3, 4]);
    }

    #[test]
    fn no_steal_from_single_request_queues() {
        let mut p = pool2();
        p.devices[0].queue.push_back(req(0, 0.0));
        assert_eq!(p.steal_into(1), 0);
        assert_eq!(p.devices[0].queue.len(), 1);
    }
}
