//! Closed-loop autoscaling: grow/shrink the shard pool between DES epochs.
//!
//! The paper sizes one board for one camera; PR 1 made the fleet size a
//! *static* knob. This module closes the loop: at every epoch boundary the
//! simulator hands a [`ScalePolicy`] what it observed (utilization, epoch
//! p99, sheds, backlog) and the policy answers grow/shrink/hold. Growing
//! provisions a new device through a caller-supplied factory with a
//! modeled warm-up delay (bitstream programming + runtime attach — a
//! ZCU102 does not join a fleet instantly); shrinking drains the
//! newest-provisioned active device (replicas retire before the seed
//! boards) and retires it once its queue and in-flight batch are empty.
//! Everything is deterministic: no wall clock, no randomness, so an
//! autoscaled run is as reproducible as a fixed-pool run.

use std::fmt;

/// What a policy sees at one epoch boundary.
#[derive(Debug, Clone)]
pub struct EpochObservation {
    /// Virtual time of the boundary, s.
    pub now_s: f64,
    /// Epoch length, s.
    pub epoch_s: f64,
    /// Devices currently serving *and* accepting new work.
    pub active_devices: usize,
    /// Devices serving their backlog but on the way out (their busy time
    /// is in `utilization`, their capacity is not staying).
    pub draining_devices: usize,
    /// Devices still warming up (capacity already on the way).
    pub provisioning_devices: usize,
    /// Mean busy fraction of serving devices over the epoch, in `[0, 1]`
    /// (service time credited at dispatch, so a batch spanning the
    /// boundary counts toward the epoch that dispatched it).
    pub utilization: f64,
    /// Requests completed during the epoch.
    pub completed: u64,
    /// Requests shed during the epoch.
    pub shed: u64,
    /// p99 latency over the epoch's completions, s (0 when none).
    pub p99_s: f64,
    /// Requests queued across the pool at the boundary.
    pub backlog: usize,
}

/// A policy's verdict for one epoch.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum ScaleAction {
    /// Provision this many new devices.
    Grow(usize),
    /// Drain (then retire) this many active devices.
    Shrink(usize),
    Hold,
}

/// An autoscaling policy: observation in, action out. Implementations may
/// keep state (e.g. consecutive-calm counters) but must stay
/// deterministic.
pub trait ScalePolicy {
    fn name(&self) -> &'static str;
    fn decide(&mut self, obs: &EpochObservation) -> ScaleAction;
}

/// Size the pool so mean busy fraction sits near `target`: grow when the
/// epoch's demand (in device-equivalents) needs more devices than are
/// active or already provisioning, shrink when it needs fewer than
/// `target - band` would. Shedding means utilization understates true
/// demand (a saturated device reads 1.0 no matter the overload), so any
/// shed forces at least one grow.
#[derive(Debug, Clone)]
pub struct TargetUtilization {
    pub target: f64,
    pub band: f64,
}

impl Default for TargetUtilization {
    fn default() -> Self {
        Self { target: 0.60, band: 0.15 }
    }
}

impl ScalePolicy for TargetUtilization {
    fn name(&self) -> &'static str {
        "target-utilization"
    }

    fn decide(&mut self, obs: &EpochObservation) -> ScaleAction {
        // Same capacity base the Autoscaler clamp uses: active devices
        // can legitimately be 0 while a replacement is provisioning.
        let planned = obs.active_devices + obs.provisioning_devices;
        // Device-equivalents of observed work, sized to the target.
        // Utilization is normalized over *serving* devices (active +
        // draining), so demand must be reconstructed over the same base —
        // a saturated drainer's load needs replacing, not ignoring.
        let serving = (obs.active_devices + obs.draining_devices).max(1);
        let demand = obs.utilization * serving as f64;
        let mut desired = (demand / self.target).ceil() as usize;
        if obs.shed > 0 {
            desired = desired.max(planned + 1);
        }
        if desired > planned {
            ScaleAction::Grow(desired - planned)
        } else if obs.provisioning_devices == 0
            && obs.utilization < self.target - self.band
            && desired < planned
        {
            // Shrink one device at a time: scale-in mistakes cost a
            // provisioning delay to undo, so be conservative.
            ScaleAction::Shrink(1)
        } else {
            ScaleAction::Hold
        }
    }
}

/// Track the latency objective directly: grow when the epoch p99 breaches
/// the SLO (two devices at once when requests were shed — a shed frame is
/// a hard breach), shrink only after `calm_epochs` consecutive epochs
/// comfortably under it with low utilization.
#[derive(Debug, Clone)]
pub struct SloTracking {
    /// The latency objective, s.
    pub slo_s: f64,
    /// "Comfortably under": p99 below `margin × slo`.
    pub margin: f64,
    /// Consecutive calm epochs required before a shrink.
    pub calm_epochs: usize,
    calm: usize,
}

impl SloTracking {
    pub fn new(slo_s: f64) -> Self {
        Self { slo_s, margin: 0.5, calm_epochs: 3, calm: 0 }
    }
}

impl ScalePolicy for SloTracking {
    fn name(&self) -> &'static str {
        "slo-tracking"
    }

    fn decide(&mut self, obs: &EpochObservation) -> ScaleAction {
        if obs.shed > 0 || obs.p99_s > self.slo_s {
            self.calm = 0;
            if obs.provisioning_devices > 0 {
                // Capacity is already on the way; adding more before it
                // lands overshoots.
                return ScaleAction::Hold;
            }
            return ScaleAction::Grow(if obs.shed > 0 { 2 } else { 1 });
        }
        if obs.completed > 0 && obs.p99_s < self.margin * self.slo_s && obs.utilization < 0.5 {
            self.calm += 1;
            if self.calm >= self.calm_epochs {
                self.calm = 0;
                return ScaleAction::Shrink(1);
            }
        } else {
            self.calm = 0;
        }
        ScaleAction::Hold
    }
}

/// Which active device a shrink decision drains first.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum DrainOrder {
    /// Newest-provisioned active device first (replicas retire before
    /// the seed boards) — the homogeneous default.
    NewestFirst,
    /// Energy-aware: the most expensive device first — highest idle
    /// power among active devices, idle-right-now breaking power ties
    /// ([`super::shard::ShardPool::most_expensive_active`]). What the
    /// heterogeneous fleet uses: a 30 W embedded GPU drains before a
    /// 6 W FPGA when both are surplus, even if the GPU happens to be
    /// mid-batch at the epoch instant.
    MostExpensiveFirst,
}

/// Fleet-level autoscaling knobs (policy-independent).
#[derive(Debug, Clone)]
pub struct AutoscaleConfig {
    /// Policy evaluation interval, virtual s.
    pub epoch_s: f64,
    /// Warm-up between a grow decision and the device serving, s.
    pub provision_delay_s: f64,
    /// Never drain below this many serving devices (treated as ≥ 1: the
    /// fleet must always keep or be provisioning at least one device, or
    /// late arrivals would have nowhere to go).
    pub min_devices: usize,
    /// Never provision beyond this many active + provisioning devices.
    pub max_devices: usize,
    /// Epochs to stay quiet after any action (damps oscillation).
    pub cooldown_epochs: usize,
    /// Scale-in ordering (energy-aware fleets drain the most expensive
    /// device first).
    pub drain_order: DrainOrder,
}

impl Default for AutoscaleConfig {
    fn default() -> Self {
        Self {
            epoch_s: 1.0,
            provision_delay_s: 2.0,
            min_devices: 1,
            max_devices: 8,
            cooldown_epochs: 1,
            drain_order: DrainOrder::NewestFirst,
        }
    }
}

/// A policy plus the clamps the simulator consults each epoch.
pub struct Autoscaler {
    pub cfg: AutoscaleConfig,
    pub policy: Box<dyn ScalePolicy>,
    cooldown: usize,
}

impl Autoscaler {
    pub fn new(cfg: AutoscaleConfig, policy: Box<dyn ScalePolicy>) -> Self {
        // A non-positive epoch would pin the DES clock at the first
        // boundary (the driver clamps each time step to the next epoch).
        assert!(cfg.epoch_s > 0.0, "epoch_s must be positive (got {})", cfg.epoch_s);
        assert!(
            cfg.provision_delay_s >= 0.0,
            "provision_delay_s must be non-negative (got {})",
            cfg.provision_delay_s
        );
        Self { cfg, policy, cooldown: 0 }
    }

    /// The policy's decision clamped to `[min_devices, max_devices]` and
    /// gated by the cooldown.
    pub fn decide(&mut self, obs: &EpochObservation) -> ScaleAction {
        if self.cooldown > 0 {
            self.cooldown -= 1;
            return ScaleAction::Hold;
        }
        let planned = obs.active_devices + obs.provisioning_devices;
        let action = match self.policy.decide(obs) {
            ScaleAction::Grow(n) => {
                let n = n.min(self.cfg.max_devices.saturating_sub(planned));
                if n == 0 {
                    ScaleAction::Hold
                } else {
                    ScaleAction::Grow(n)
                }
            }
            ScaleAction::Shrink(n) => {
                let n = n.min(planned.saturating_sub(self.cfg.min_devices.max(1)));
                if n == 0 {
                    ScaleAction::Hold
                } else {
                    ScaleAction::Shrink(n)
                }
            }
            ScaleAction::Hold => ScaleAction::Hold,
        };
        if action != ScaleAction::Hold {
            self.cooldown = self.cfg.cooldown_epochs;
        }
        action
    }
}

/// One scaling action, recorded into the [`super::metrics::FleetReport`].
#[derive(Debug, Clone, PartialEq)]
pub struct ScalingEvent {
    /// Virtual time of the event, s.
    pub t_s: f64,
    pub kind: ScaleEventKind,
    /// Serving (active + draining) devices right after the event.
    pub serving_after: usize,
}

#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum ScaleEventKind {
    /// A new device began its warm-up.
    Provisioning { device: usize },
    /// A provisioned device finished warm-up and joined the pool.
    Activated { device: usize },
    /// An active device stopped taking new work.
    DrainStarted { device: usize },
    /// A draining device went idle and left service.
    Retired { device: usize },
    /// The watchdog declared a crashed device dead (fault recovery).
    Failed { device: usize },
}

impl fmt::Display for ScaleEventKind {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self {
            ScaleEventKind::Provisioning { device } => write!(f, "provision device {device}"),
            ScaleEventKind::Activated { device } => write!(f, "activate device {device}"),
            ScaleEventKind::DrainStarted { device } => write!(f, "drain device {device}"),
            ScaleEventKind::Retired { device } => write!(f, "retire device {device}"),
            ScaleEventKind::Failed { device } => write!(f, "fail device {device}"),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn obs(
        active: usize,
        provisioning: usize,
        util: f64,
        shed: u64,
        p99_s: f64,
    ) -> EpochObservation {
        EpochObservation {
            now_s: 1.0,
            epoch_s: 1.0,
            active_devices: active,
            draining_devices: 0,
            provisioning_devices: provisioning,
            utilization: util,
            completed: 100,
            shed,
            p99_s,
            backlog: 0,
        }
    }

    #[test]
    fn target_utilization_replaces_draining_capacity() {
        let mut p = TargetUtilization::default();
        // One saturated active device + one saturated drainer: demand is
        // 2 device-equivalents, so the pool must grow toward 4, not 2.
        let mut o = obs(1, 0, 1.0, 0, 0.01);
        o.draining_devices = 1;
        assert_eq!(p.decide(&o), ScaleAction::Grow(3));
    }

    #[test]
    fn target_utilization_tracks_demand() {
        let mut p = TargetUtilization::default();
        // In band: hold.
        assert_eq!(p.decide(&obs(2, 0, 0.55, 0, 0.01)), ScaleAction::Hold);
        // Saturated: 2 devices at 1.0 need ceil(2/0.6)=4 → grow 2.
        assert_eq!(p.decide(&obs(2, 0, 1.0, 0, 0.01)), ScaleAction::Grow(2));
        // Shedding forces a grow even if utilization looks tame.
        assert!(matches!(p.decide(&obs(2, 0, 0.6, 5, 0.01)), ScaleAction::Grow(_)));
        // Idle: shrink one at a time.
        assert_eq!(p.decide(&obs(4, 0, 0.10, 0, 0.01)), ScaleAction::Shrink(1));
        // Capacity already provisioning: no double-grow at mild pressure.
        assert_eq!(p.decide(&obs(2, 2, 0.70, 0, 0.01)), ScaleAction::Hold);
    }

    #[test]
    fn slo_tracking_breach_grows_and_calm_shrinks() {
        let mut p = SloTracking::new(0.100);
        assert_eq!(p.decide(&obs(2, 0, 0.8, 0, 0.150)), ScaleAction::Grow(1));
        // Sheds are a hard breach: bigger step.
        assert_eq!(p.decide(&obs(2, 0, 1.0, 9, 0.150)), ScaleAction::Grow(2));
        // Breach with capacity on the way: hold.
        assert_eq!(p.decide(&obs(2, 1, 1.0, 0, 0.150)), ScaleAction::Hold);
        // Three consecutive calm epochs, then shrink.
        assert_eq!(p.decide(&obs(3, 0, 0.2, 0, 0.020)), ScaleAction::Hold);
        assert_eq!(p.decide(&obs(3, 0, 0.2, 0, 0.020)), ScaleAction::Hold);
        assert_eq!(p.decide(&obs(3, 0, 0.2, 0, 0.020)), ScaleAction::Shrink(1));
        // A breach resets the calm streak.
        assert_eq!(p.decide(&obs(2, 0, 0.2, 0, 0.020)), ScaleAction::Hold);
        assert_eq!(p.decide(&obs(2, 0, 0.9, 0, 0.200)), ScaleAction::Grow(1));
        assert_eq!(p.decide(&obs(2, 0, 0.2, 0, 0.020)), ScaleAction::Hold);
    }

    #[test]
    fn autoscaler_clamps_and_cools_down() {
        let cfg = AutoscaleConfig {
            epoch_s: 1.0,
            provision_delay_s: 1.0,
            min_devices: 2,
            max_devices: 4,
            cooldown_epochs: 1,
            drain_order: DrainOrder::NewestFirst,
        };
        let mut a = Autoscaler::new(cfg, Box::new(TargetUtilization::default()));
        // Wants 4 devices (2 at util 1.0 → ceil(2/0.6)=4) but max is 4 → grow 2.
        assert_eq!(a.decide(&obs(2, 0, 1.0, 0, 0.0)), ScaleAction::Grow(2));
        // Cooldown epoch: hold regardless of pressure.
        assert_eq!(a.decide(&obs(2, 2, 1.0, 50, 0.0)), ScaleAction::Hold);
        // At max: a further grow clamps to hold.
        assert_eq!(a.decide(&obs(4, 0, 1.0, 50, 0.0)), ScaleAction::Hold);
        // Shrink clamps at min_devices.
        let mut b = Autoscaler::new(
            AutoscaleConfig { min_devices: 3, cooldown_epochs: 0, ..AutoscaleConfig::default() },
            Box::new(TargetUtilization::default()),
        );
        assert_eq!(b.decide(&obs(3, 0, 0.05, 0, 0.0)), ScaleAction::Hold);
        assert_eq!(b.decide(&obs(4, 0, 0.05, 0, 0.0)), ScaleAction::Shrink(1));
    }

    #[test]
    fn event_kinds_render() {
        let e = ScalingEvent {
            t_s: 1.5,
            kind: ScaleEventKind::Provisioning { device: 3 },
            serving_after: 2,
        };
        assert_eq!(format!("{}", e.kind), "provision device 3");
        assert_eq!(format!("{}", ScaleEventKind::Retired { device: 1 }), "retire device 1");
        assert!((e.t_s - 1.5).abs() < 1e-15);
    }
}
