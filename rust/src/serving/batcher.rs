//! Dynamic batching: the max-batch/max-wait policy.
//!
//! Every queued request waits at most `max_wait_s` before its batch is
//! closed; a batch closes early the moment `max_batch` requests are
//! queued. `max_batch = 1` degenerates to request-at-a-time serving (the
//! paper's single-board deployment); `max_wait_s = 0` greedily batches
//! whatever is queued when the device frees up. The policy trades the
//! head request's queueing delay against amortizing the per-invocation
//! overhead (dispatch + weight streaming) measured by
//! [`crate::serving::device`].
//!
//! The wait deadline is class-aware: each queued request's deadline is
//! `arrival + max_wait × class.wait_factor()` and the batch closes at
//! the *earliest* deadline in the queue, so an interactive frame stuck
//! behind patient batchable traffic still pulls its batch closed early.
//! All-[`Standard`](crate::serving::SloClass::Standard) queues (factor
//! 1) behave exactly as the class-unaware policy did.

use std::collections::VecDeque;

use super::Request;

/// The dynamic-batching knobs.
#[derive(Debug, Clone, Copy)]
pub struct BatchPolicy {
    /// Close a batch as soon as this many requests are queued.
    pub max_batch: usize,
    /// Close a (non-empty) batch once its oldest request has waited this
    /// long, seconds.
    pub max_wait_s: f64,
}

impl BatchPolicy {
    /// Request-at-a-time serving (no batching).
    pub fn unbatched() -> Self {
        Self { max_batch: 1, max_wait_s: 0.0 }
    }

    pub fn new(max_batch: usize, max_wait_s: f64) -> Self {
        Self { max_batch: max_batch.max(1), max_wait_s: max_wait_s.max(0.0) }
    }
}

impl Default for BatchPolicy {
    fn default() -> Self {
        Self { max_batch: 8, max_wait_s: 10e-3 }
    }
}

/// What an idle device should do with its queue at time `now`.
#[derive(Debug, Clone, Copy, PartialEq)]
pub enum Decision {
    /// Close a batch of this many requests (front of the queue) now.
    Dispatch(usize),
    /// Keep accumulating; re-evaluate at this absolute time at the
    /// latest (the oldest request's wait deadline).
    WaitUntil(f64),
    /// Nothing queued.
    Idle,
}

impl BatchPolicy {
    /// The batch size actually closable on a device: the policy's
    /// `max_batch` clamped to the backend's activation-memory bound.
    /// The DES dispatcher and the live worker's channel-drain headroom
    /// must use the same number or live queues would buffer more than
    /// the simulator models.
    pub fn effective_cap(&self, device_cap: usize) -> usize {
        self.max_batch.min(device_cap.max(1))
    }

    /// Earliest class-scaled wait deadline across the queue (for a
    /// uniform-class FIFO queue this is the head request's deadline,
    /// the pre-class behavior). The single source of truth for both
    /// [`BatchPolicy::decide`] and the DES driver's inlined dispatch
    /// check — sharing the exact fold is what keeps the optimized hot
    /// path bit-identical to the reference path.
    pub fn earliest_deadline_s(&self, queue: &VecDeque<Request>) -> f64 {
        queue
            .iter()
            .map(|r| r.arrival_s + self.max_wait_s * r.class.wait_factor())
            .fold(f64::INFINITY, f64::min)
    }

    /// Evaluate the policy against a device queue. `device_cap` is the
    /// backend's activation-memory bound on batch size.
    pub fn decide(&self, queue: &VecDeque<Request>, now: f64, device_cap: usize) -> Decision {
        let cap = self.effective_cap(device_cap);
        if queue.is_empty() {
            return Decision::Idle;
        }
        if queue.len() >= cap {
            return Decision::Dispatch(cap);
        }
        // This scan only runs on queues shorter than the batch cap —
        // longer ones dispatched above — so the cost is O(max_batch),
        // not O(queue_depth).
        let deadline = self.earliest_deadline_s(queue);
        if now >= deadline {
            Decision::Dispatch(queue.len())
        } else {
            Decision::WaitUntil(deadline)
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    use crate::serving::SloClass;

    fn queue(arrivals: &[f64]) -> VecDeque<Request> {
        arrivals
            .iter()
            .enumerate()
            .map(|(i, &t)| Request {
                id: i as u64,
                camera: 0,
                arrival_s: t,
                objects: 1,
                class: SloClass::Standard,
                rung: 0,
                retries: 0,
            })
            .collect()
    }

    #[test]
    fn empty_queue_is_idle() {
        let p = BatchPolicy::default();
        assert_eq!(p.decide(&queue(&[]), 0.0, 32), Decision::Idle);
    }

    #[test]
    fn full_batch_dispatches_immediately() {
        let p = BatchPolicy::new(4, 1.0);
        let q = queue(&[0.0, 0.0, 0.0, 0.0, 0.0]);
        assert_eq!(p.decide(&q, 0.0, 32), Decision::Dispatch(4));
    }

    #[test]
    fn device_cap_limits_batch() {
        let p = BatchPolicy::new(16, 1.0);
        let q = queue(&[0.0; 8]);
        assert_eq!(p.decide(&q, 0.0, 4), Decision::Dispatch(4));
    }

    #[test]
    fn partial_batch_waits_then_flushes_at_deadline() {
        let p = BatchPolicy::new(8, 0.010);
        let q = queue(&[1.000, 1.002]);
        match p.decide(&q, 1.004, 32) {
            Decision::WaitUntil(t) => assert!((t - 1.010).abs() < 1e-12),
            other => panic!("expected WaitUntil, got {other:?}"),
        }
        assert_eq!(p.decide(&q, 1.010, 32), Decision::Dispatch(2));
    }

    #[test]
    fn unbatched_always_dispatches_one() {
        let p = BatchPolicy::unbatched();
        assert_eq!(p.decide(&queue(&[5.0]), 5.0, 32), Decision::Dispatch(1));
        assert_eq!(p.decide(&queue(&[5.0, 5.0, 5.0]), 5.0, 32), Decision::Dispatch(1));
    }

    #[test]
    fn zero_wait_greedily_flushes() {
        let p = BatchPolicy::new(8, 0.0);
        assert_eq!(p.decide(&queue(&[2.0, 2.1, 2.2]), 2.2, 32), Decision::Dispatch(3));
    }

    #[test]
    fn interactive_frame_pulls_the_deadline_forward() {
        let p = BatchPolicy::new(8, 0.020);
        let mut q = queue(&[1.000, 1.004]);
        // A later interactive arrival deadlines at 1.004 + 0.25×20 ms =
        // 1.009, earlier than the head's 1.020.
        q[1].class = SloClass::Interactive;
        match p.decide(&q, 1.005, 32) {
            Decision::WaitUntil(t) => assert!((t - 1.009).abs() < 1e-12, "{t}"),
            other => panic!("expected WaitUntil, got {other:?}"),
        }
        assert_eq!(p.decide(&q, 1.009, 32), Decision::Dispatch(2));
        // A batchable queue waits longer than a standard one.
        let mut qb = queue(&[1.000]);
        qb[0].class = SloClass::Batchable;
        match p.decide(&qb, 1.001, 32) {
            Decision::WaitUntil(t) => assert!((t - 1.030).abs() < 1e-12, "{t}"),
            other => panic!("expected WaitUntil, got {other:?}"),
        }
    }
}
