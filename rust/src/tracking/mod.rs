//! GM-PHD multi-object tracking + ground-plane projection — the Section VI
//! case-study's "main ECU" stage (world-space tracking with velocity
//! estimation via a Gaussian Mixture Probability Hypothesis Density
//! filter, fed by the FPGA detector through homography projection).

pub mod gmphd;
pub mod homography;

pub use gmphd::{GmPhd, GmPhdConfig, Track};
pub use homography::Homography;
