//! Homography projection: image plane → ground plane (the case study
//! projects detections from a calibrated camera to world coordinates).

/// A 3×3 projective transform, row-major.
#[derive(Debug, Clone, Copy)]
pub struct Homography {
    pub h: [f64; 9],
}

impl Homography {
    pub fn identity() -> Self {
        Self { h: [1.0, 0.0, 0.0, 0.0, 1.0, 0.0, 0.0, 0.0, 1.0] }
    }

    /// A simple calibrated overhead camera: scale + ground offset.
    pub fn scale_offset(sx: f64, sy: f64, tx: f64, ty: f64) -> Self {
        Self { h: [sx, 0.0, tx, 0.0, sy, ty, 0.0, 0.0, 1.0] }
    }

    /// Project an image point (normalized coords) to the ground plane.
    pub fn project(&self, x: f64, y: f64) -> (f64, f64) {
        let h = &self.h;
        let w = h[6] * x + h[7] * y + h[8];
        ((h[0] * x + h[1] * y + h[2]) / w, (h[3] * x + h[4] * y + h[5]) / w)
    }

    /// The inverse transform (adjugate over determinant), or `None` for
    /// a degenerate (non-invertible) homography. Projective transforms
    /// are scale-free, so the adjugate alone would already invert the
    /// mapping; dividing by the determinant keeps the matrix numerically
    /// comparable to the forward one.
    pub fn inverse(&self) -> Option<Homography> {
        let h = &self.h;
        let c0 = h[4] * h[8] - h[5] * h[7];
        let c1 = h[5] * h[6] - h[3] * h[8];
        let c2 = h[3] * h[7] - h[4] * h[6];
        let det = h[0] * c0 + h[1] * c1 + h[2] * c2;
        if det.abs() < 1e-12 || !det.is_finite() {
            return None;
        }
        let adj = [
            c0,
            h[2] * h[7] - h[1] * h[8],
            h[1] * h[5] - h[2] * h[4],
            c1,
            h[0] * h[8] - h[2] * h[6],
            h[2] * h[3] - h[0] * h[5],
            c2,
            h[1] * h[6] - h[0] * h[7],
            h[0] * h[4] - h[1] * h[3],
        ];
        let mut out = [0.0; 9];
        for (o, a) in out.iter_mut().zip(adj) {
            *o = a / det;
        }
        Some(Homography { h: out })
    }

    /// Project a ground-plane point back into the image (the inverse of
    /// [`Homography::project`]). Panics on a degenerate homography —
    /// calibrated cameras are invertible by construction; use
    /// [`Homography::inverse`] directly to handle the degenerate case.
    pub fn unproject(&self, x: f64, y: f64) -> (f64, f64) {
        self.inverse().expect("degenerate homography has no unprojection").project(x, y)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn identity_is_noop() {
        let h = Homography::identity();
        assert_eq!(h.project(0.3, 0.7), (0.3, 0.7));
    }

    #[test]
    fn scale_offset_maps_to_world() {
        let h = Homography::scale_offset(20.0, 30.0, -10.0, -15.0);
        let (x, y) = h.project(0.5, 0.5);
        assert!((x - 0.0).abs() < 1e-9);
        assert!((y - 0.0).abs() < 1e-9);
        let (x, y) = h.project(1.0, 1.0);
        assert!((x - 10.0).abs() < 1e-9 && (y - 15.0).abs() < 1e-9);
    }

    #[test]
    fn scale_offset_inverse_is_closed_form() {
        let h = Homography::scale_offset(16.0, 16.0, 40.0, 0.0);
        let inv = h.inverse().expect("affine scale+offset is invertible");
        // Inverse of [s,0,t] is [1/s,0,-t/s] (row-wise).
        assert!((inv.h[0] - 1.0 / 16.0).abs() < 1e-12);
        assert!((inv.h[2] + 40.0 / 16.0).abs() < 1e-12);
        assert!((inv.h[4] - 1.0 / 16.0).abs() < 1e-12);
        let (x, y) = h.unproject(48.0, 8.0);
        assert!((x - 0.5).abs() < 1e-12 && (y - 0.5).abs() < 1e-12);
    }

    #[test]
    fn degenerate_homography_has_no_inverse() {
        // Rank-deficient: second row is a multiple of the first.
        let h = Homography { h: [1.0, 2.0, 3.0, 2.0, 4.0, 6.0, 0.0, 0.0, 1.0] };
        assert!(h.inverse().is_none());
    }
}
