//! Homography projection: image plane → ground plane (the case study
//! projects detections from a calibrated camera to world coordinates).

/// A 3×3 projective transform, row-major.
#[derive(Debug, Clone, Copy)]
pub struct Homography {
    pub h: [f64; 9],
}

impl Homography {
    pub fn identity() -> Self {
        Self { h: [1.0, 0.0, 0.0, 0.0, 1.0, 0.0, 0.0, 0.0, 1.0] }
    }

    /// A simple calibrated overhead camera: scale + ground offset.
    pub fn scale_offset(sx: f64, sy: f64, tx: f64, ty: f64) -> Self {
        Self { h: [sx, 0.0, tx, 0.0, sy, ty, 0.0, 0.0, 1.0] }
    }

    /// Project an image point (normalized coords) to the ground plane.
    pub fn project(&self, x: f64, y: f64) -> (f64, f64) {
        let h = &self.h;
        let w = h[6] * x + h[7] * y + h[8];
        ((h[0] * x + h[1] * y + h[2]) / w, (h[3] * x + h[4] * y + h[5]) / w)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn identity_is_noop() {
        let h = Homography::identity();
        assert_eq!(h.project(0.3, 0.7), (0.3, 0.7));
    }

    #[test]
    fn scale_offset_maps_to_world() {
        let h = Homography::scale_offset(20.0, 30.0, -10.0, -15.0);
        let (x, y) = h.project(0.5, 0.5);
        assert!((x - 0.0).abs() < 1e-9);
        assert!((y - 0.0).abs() < 1e-9);
        let (x, y) = h.project(1.0, 1.0);
        assert!((x - 10.0).abs() < 1e-9 && (y - 15.0).abs() < 1e-9);
    }
}
