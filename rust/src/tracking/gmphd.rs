//! Gaussian-Mixture PHD filter over a constant-velocity model.
//!
//! State per component: [x, y, vx, vy] with diagonal-ish covariance.
//! Standard GM-PHD recursion (Vo & Ma 2006): predict, update with
//! detection likelihoods, prune/merge, extract tracks above weight 0.5.

/// One Gaussian component of the PHD intensity.
#[derive(Debug, Clone)]
struct Component {
    w: f64,
    x: [f64; 4],
    /// Covariance, row-major 4×4.
    p: [[f64; 4]; 4],
    id: usize,
}

/// An extracted track.
#[derive(Debug, Clone)]
pub struct Track {
    pub id: usize,
    pub x: f64,
    pub y: f64,
    pub vx: f64,
    pub vy: f64,
    pub weight: f64,
}

/// Filter parameters.
#[derive(Debug, Clone)]
pub struct GmPhdConfig {
    pub dt: f64,
    /// Survival and detection probabilities.
    pub p_survive: f64,
    pub p_detect: f64,
    /// Process / measurement noise std.
    pub sigma_process: f64,
    pub sigma_meas: f64,
    /// Clutter density (false alarms per unit area).
    pub clutter: f64,
    /// Birth weight for each measurement-driven birth component.
    pub birth_weight: f64,
    pub prune_threshold: f64,
    pub merge_dist: f64,
    pub max_components: usize,
}

impl Default for GmPhdConfig {
    fn default() -> Self {
        Self {
            dt: 0.1,
            p_survive: 0.98,
            p_detect: 0.9,
            sigma_process: 0.5,
            sigma_meas: 0.3,
            clutter: 0.05,
            birth_weight: 0.25,
            prune_threshold: 1e-4,
            merge_dist: 1.0,
            max_components: 60,
        }
    }
}

fn matmul4(a: &[[f64; 4]; 4], b: &[[f64; 4]; 4]) -> [[f64; 4]; 4] {
    let mut o = [[0.0; 4]; 4];
    for i in 0..4 {
        for k in 0..4 {
            let av = a[i][k];
            if av == 0.0 {
                continue;
            }
            for j in 0..4 {
                o[i][j] += av * b[k][j];
            }
        }
    }
    o
}

/// `a · bᵀ`.
fn matmul4_bt(a: &[[f64; 4]; 4], b: &[[f64; 4]; 4]) -> [[f64; 4]; 4] {
    let mut o = [[0.0; 4]; 4];
    for i in 0..4 {
        for j in 0..4 {
            let mut s = 0.0;
            for k in 0..4 {
                s += a[i][k] * b[j][k];
            }
            o[i][j] = s;
        }
    }
    o
}

/// The GM-PHD filter.
pub struct GmPhd {
    cfg: GmPhdConfig,
    comps: Vec<Component>,
    next_id: usize,
}

impl GmPhd {
    pub fn new(cfg: GmPhdConfig) -> Self {
        Self { cfg, comps: Vec::new(), next_id: 0 }
    }

    /// Predict + update with this frame's measurements (world x, y).
    pub fn step(&mut self, measurements: &[(f64, f64)]) {
        let c = self.cfg.clone();
        // ---- predict: x := Fx, P := F P Fᵀ + Q (constant-velocity F) ----
        let mut f_mat = [[0.0f64; 4]; 4];
        for (i, row) in f_mat.iter_mut().enumerate() {
            row[i] = 1.0;
        }
        f_mat[0][2] = c.dt;
        f_mat[1][3] = c.dt;
        for comp in self.comps.iter_mut() {
            comp.w *= c.p_survive;
            comp.x[0] += comp.x[2] * c.dt;
            comp.x[1] += comp.x[3] * c.dt;
            let fp = matmul4(&f_mat, &comp.p);
            let mut p = matmul4_bt(&fp, &f_mat); // F P Fᵀ
            let q = c.sigma_process * c.sigma_process * c.dt;
            for (i, row) in p.iter_mut().enumerate() {
                row[i] += q * if i < 2 { 0.5 } else { 1.0 };
            }
            comp.p = p;
        }

        // ---- update ----
        let r = c.sigma_meas * c.sigma_meas;
        let mut updated: Vec<Component> = self
            .comps
            .iter()
            .map(|comp| Component { w: comp.w * (1.0 - c.p_detect), ..comp.clone() })
            .collect();
        for &(zx, zy) in measurements {
            let mut batch: Vec<Component> = Vec::new();
            let mut denom = c.clutter;
            for comp in &self.comps {
                // Innovation with H = [I2 0]; S = P[0..2,0..2] + R.
                let sxx = comp.p[0][0] + r;
                let syy = comp.p[1][1] + r;
                let dx = zx - comp.x[0];
                let dy = zy - comp.x[1];
                let maha = dx * dx / sxx + dy * dy / syy;
                let lik = (-0.5 * maha).exp()
                    / (2.0 * std::f64::consts::PI * (sxx * syy).sqrt());
                let w = c.p_detect * comp.w * lik;
                denom += w;
                // Kalman update with H = [I₂ 0] and diagonal S:
                // K = P Hᵀ S⁻¹;  x' = x + K ν;  P' = (I − K H) P.
                let mut kmat = [[0.0f64; 2]; 4];
                for i in 0..4 {
                    kmat[i][0] = comp.p[i][0] / sxx;
                    kmat[i][1] = comp.p[i][1] / syy;
                }
                let mut x = comp.x;
                for i in 0..4 {
                    x[i] += kmat[i][0] * dx + kmat[i][1] * dy;
                }
                let mut p = comp.p;
                for i in 0..4 {
                    for j in 0..4 {
                        p[i][j] -=
                            kmat[i][0] * comp.p[0][j] + kmat[i][1] * comp.p[1][j];
                    }
                }
                batch.push(Component { w, x, p, id: comp.id });
            }
            for mut comp in batch {
                comp.w /= denom;
                updated.push(comp);
            }
            // Measurement-driven birth — only where no existing component
            // already explains the measurement (otherwise the zero-velocity
            // birth would merge into the track and bias its velocity).
            let explained = self.comps.iter().any(|comp| {
                let dx = comp.x[0] - zx;
                let dy = comp.x[1] - zy;
                comp.w > 0.1 && dx * dx + dy * dy < c.merge_dist * c.merge_dist
            });
            if !explained {
                let mut p = [[0.0; 4]; 4];
                p[0][0] = 0.5;
                p[1][1] = 0.5;
                p[2][2] = 2.0;
                p[3][3] = 2.0;
                updated.push(Component {
                    w: c.birth_weight,
                    x: [zx, zy, 0.0, 0.0],
                    p,
                    id: self.next_id,
                });
                self.next_id += 1;
            }
        }

        // ---- prune & merge ----
        updated.retain(|cmp| cmp.w > c.prune_threshold && cmp.w.is_finite());
        updated.sort_by(|a, b| b.w.partial_cmp(&a.w).unwrap());
        let mut merged: Vec<Component> = Vec::new();
        for comp in updated {
            if let Some(m) = merged.iter_mut().find(|m| {
                let dx = m.x[0] - comp.x[0];
                let dy = m.x[1] - comp.x[1];
                dx * dx + dy * dy < c.merge_dist * c.merge_dist
            }) {
                let wsum = m.w + comp.w;
                for i in 0..4 {
                    m.x[i] = (m.x[i] * m.w + comp.x[i] * comp.w) / wsum;
                }
                m.w = wsum;
            } else {
                merged.push(comp);
            }
        }
        merged.truncate(c.max_components);
        self.comps = merged;
    }

    /// Tracks with weight ≥ 0.5 (expected-cardinality extraction).
    pub fn tracks(&self) -> Vec<Track> {
        self.comps
            .iter()
            .filter(|c| c.w >= 0.5)
            .map(|c| Track { id: c.id, x: c.x[0], y: c.x[1], vx: c.x[2], vy: c.x[3], weight: c.w })
            .collect()
    }

    /// Estimated number of objects (sum of weights).
    pub fn cardinality(&self) -> f64 {
        self.comps.iter().map(|c| c.w).sum()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn tracks_single_constant_velocity_target() {
        let mut f = GmPhd::new(GmPhdConfig::default());
        for t in 0..30 {
            let x = 0.0 + 0.5 * t as f64 * 0.1; // 0.5 m/s
            f.step(&[(x, 2.0)]);
        }
        let tracks = f.tracks();
        assert_eq!(tracks.len(), 1, "cardinality {}", f.cardinality());
        let tr = &tracks[0];
        assert!((tr.y - 2.0).abs() < 0.3, "y {}", tr.y);
        assert!((tr.vx - 0.5).abs() < 0.3, "vx {}", tr.vx);
        assert!(tr.vy.abs() < 0.3);
    }

    #[test]
    fn tracks_two_separated_targets() {
        let mut f = GmPhd::new(GmPhdConfig::default());
        for t in 0..25 {
            let dt = t as f64 * 0.1;
            f.step(&[(dt, 0.0), (10.0 - dt, 8.0)]);
        }
        assert_eq!(f.tracks().len(), 2);
        assert!((f.cardinality() - 2.0).abs() < 0.5);
    }

    #[test]
    fn missed_detections_tolerated() {
        let mut f = GmPhd::new(GmPhdConfig::default());
        for t in 0..30 {
            if t % 4 == 3 {
                f.step(&[]); // dropout frame
            } else {
                f.step(&[(1.0, 1.0)]);
            }
        }
        assert_eq!(f.tracks().len(), 1);
    }

    #[test]
    fn clutter_does_not_spawn_persistent_tracks() {
        let mut f = GmPhd::new(GmPhdConfig::default());
        let mut rng = crate::util::Rng::new(9);
        for _ in 0..30 {
            // one real target + one random clutter point far away
            let cx = rng.range_f64(-20.0, 20.0);
            let cy = rng.range_f64(10.0, 30.0);
            f.step(&[(0.0, 0.0), (cx, cy)]);
        }
        let tracks = f.tracks();
        // The persistent target tracked; clutter components stay < 0.5.
        assert!(!tracks.is_empty());
        assert!(tracks.iter().any(|t| t.x.abs() < 0.5 && t.y.abs() < 0.5));
        assert!(f.cardinality() < 2.5);
    }

    #[test]
    fn empty_filter_is_empty() {
        let f = GmPhd::new(GmPhdConfig::default());
        assert!(f.tracks().is_empty());
        assert_eq!(f.cardinality(), 0.0);
    }
}
