//! YOLOv7-tiny architecture as an IR graph.
//!
//! Reconstructed from the official `yolov7-tiny.yaml`: a stem of two
//! stride-2 convs, four ELAN-tiny blocks separated by maxpools, an
//! SPPCSP-tiny neck, an FPN/PAN head with two more ELAN-tiny blocks per
//! path, and three detection heads. All activations are LeakyReLU(0.1) in
//! the original (the paper replaces them with ReLU6, Section IV-B2).
//!
//! Counting convolutions: stem 2 + 4 backbone ELANs × 5 + SPPCSP 4 +
//! FPN (2 laterals + 2 reductions + 2 ELANs × 5) + PAN (2 downsamples +
//! 2 ELANs × 5) + 3 pre-head 3×3 + 3 detect 1×1 = **58**, matching the
//! paper ("58 convolution layers", Section V-C).

use crate::ir::{ActivationKind, Graph, GraphBuilder, NodeId, PaddingMode};

/// Which model version (Section IV-B3: the paper evaluates the original and
/// the 40 %- and 88 %-sparse pruned models).
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum ModelVariant {
    /// Un-pruned YOLOv7-tiny.
    Base,
    /// 40 % parameter sparsity (mAP kept above 30 %).
    Pruned40,
    /// 88 % parameter sparsity (minimum-latency extreme).
    Pruned88,
}

impl ModelVariant {
    /// Fraction of filters *retained* per prunable conv, derived from the
    /// target parameter sparsity. Parameters of a conv scale roughly with
    /// retained_in × retained_out, so retained ≈ sqrt(1 − sparsity).
    pub fn channel_keep(self) -> f64 {
        match self {
            ModelVariant::Base => 1.0,
            ModelVariant::Pruned40 => (1.0f64 - 0.40).sqrt(), // ≈ 0.775
            ModelVariant::Pruned88 => (1.0f64 - 0.88).sqrt(), // ≈ 0.346
        }
    }

    pub fn label(self) -> &'static str {
        match self {
            ModelVariant::Base => "YOLOv7-tiny",
            ModelVariant::Pruned40 => "YOLOv7-tiny 40%",
            ModelVariant::Pruned88 => "YOLOv7-tiny 88%",
        }
    }

    pub fn all() -> [ModelVariant; 3] {
        [ModelVariant::Base, ModelVariant::Pruned40, ModelVariant::Pruned88]
    }
}

/// Internal channel-scaling helper: keeps channels a multiple of 8 (what
/// structured filter pruning on a systolic-array target would do) and ≥8.
fn scale_c(c: usize, keep: f64) -> usize {
    let scaled = ((c as f64 * keep) / 8.0).round() as usize * 8;
    scaled.max(8)
}

struct Ctx {
    b: GraphBuilder,
    act: ActivationKind,
    keep: f64,
}

impl Ctx {
    fn conv(&mut self, x: NodeId, c: usize, k: usize, s: usize) -> NodeId {
        let oc = scale_c(c, self.keep);
        self.b.conv2d(x, oc, k, s, PaddingMode::Same, self.act, None, None)
    }

    /// Detection convs are never pruned (they must emit the full
    /// anchors×(5+classes) channels).
    fn conv_fixed(&mut self, x: NodeId, c: usize, k: usize, s: usize, act: ActivationKind) -> NodeId {
        self.b.conv2d(x, c, k, s, PaddingMode::Same, act, None, None)
    }

    /// ELAN-tiny block: two parallel 1×1 branches, two chained 3×3 convs,
    /// 4-way concat, 1×1 merge. 5 convolutions.
    fn elan(&mut self, x: NodeId, c_hidden: usize, c_out: usize) -> NodeId {
        let c1 = self.conv(x, c_hidden, 1, 1);
        let c2 = self.conv(x, c_hidden, 1, 1);
        let c3 = self.conv(c2, c_hidden, 3, 1);
        let c4 = self.conv(c3, c_hidden, 3, 1);
        let cat = self.b.concat(&[c4, c3, c2, c1]);
        self.conv(cat, c_out, 1, 1)
    }

    /// SPPCSP-tiny: 1×1 reduce ×2 (split), maxpool 5/9/13 pyramid on one
    /// branch, concat, 1×1 merge, concat with bypass, 1×1 out.
    /// 4 convolutions. We model the 5/9/13 pools as three stride-1 pools
    /// (padding folded into shape preservation: kernel k, stride 1 on a
    /// padded map keeps H×W — we approximate with kernel 1 shape-wise but
    /// keep distinct nodes so the scheduler sees three pool ops).
    fn sppcsp(&mut self, x: NodeId, c_out: usize) -> NodeId {
        let a = self.conv(x, c_out, 1, 1);
        let bypass = self.conv(x, c_out, 1, 1);
        // SAME-padded stride-1 maxpools keep spatial dims; our builder pools
        // are VALID, so emulate with kernel=1 stride=1 (shape-preserving)
        // and account for the true 5/9/13 windows in the scheduler's cost
        // via the op parameters' kernel field where possible.
        let p5 = self.b.maxpool(a, 1, 1);
        let p9 = self.b.maxpool(p5, 1, 1);
        let p13 = self.b.maxpool(p9, 1, 1);
        let cat = self.b.concat(&[a, p5, p9, p13]);
        let m = self.conv(cat, c_out, 1, 1);
        let cat2 = self.b.concat(&[m, bypass]);
        self.conv(cat2, c_out, 1, 1)
    }
}

/// Build YOLOv7-tiny as an IR graph.
///
/// * `input_size` — square input resolution (the paper sweeps 160–640 and
///   picks 480, Figure 3). Must be divisible by 32.
/// * `variant` — pruning level (Section IV-B3).
/// * `num_classes` — 80 for COCO; the synthetic benchmark uses 8.
pub fn yolov7_tiny(input_size: usize, variant: ModelVariant, num_classes: usize) -> Graph {
    assert_eq!(input_size % 32, 0, "input size must be divisible by 32");
    let keep = variant.channel_keep();
    let mut ctx = Ctx {
        b: GraphBuilder::new(format!("yolov7-tiny-{}@{}", variant.label(), input_size)),
        act: ActivationKind::LeakyRelu(0.1),
        keep,
    };

    let x = ctx.b.input("image", vec![1, input_size, input_size, 3]);

    // ---- Backbone ----
    let s1 = ctx.conv(x, 32, 3, 2); // P1/2
    let s2 = ctx.conv(s1, 64, 3, 2); // P2/4
    let e1 = ctx.elan(s2, 32, 64);
    let p3 = ctx.b.maxpool(e1, 2, 2); // P3/8
    let e2 = ctx.elan(p3, 64, 128);
    let p4 = ctx.b.maxpool(e2, 2, 2); // P4/16
    let e3 = ctx.elan(p4, 128, 256);
    let p5 = ctx.b.maxpool(e3, 2, 2); // P5/32
    let e4 = ctx.elan(p5, 256, 512);

    // ---- Neck ----
    let spp = ctx.sppcsp(e4, 256);

    // ---- FPN (top-down) ----
    let f1 = ctx.conv(spp, 128, 1, 1);
    let f1u = ctx.b.upsample(f1, 2);
    let l4 = ctx.conv(e3, 128, 1, 1); // lateral from P4
    let f1c = ctx.b.concat(&[f1u, l4]);
    let fe1 = ctx.elan(f1c, 64, 128); // head ELAN @ P4 scale

    let f2 = ctx.conv(fe1, 64, 1, 1);
    let f2u = ctx.b.upsample(f2, 2);
    let l3 = ctx.conv(e2, 64, 1, 1); // lateral from P3
    let f2c = ctx.b.concat(&[f2u, l3]);
    let fe2 = ctx.elan(f2c, 32, 64); // head ELAN @ P3 scale

    // ---- PAN (bottom-up) ----
    let d1 = ctx.conv(fe2, 128, 3, 2);
    let d1c = ctx.b.concat(&[d1, fe1]);
    let pe1 = ctx.elan(d1c, 64, 128);

    let d2 = ctx.conv(pe1, 256, 3, 2);
    let d2c = ctx.b.concat(&[d2, spp]);
    let pe2 = ctx.elan(d2c, 128, 256);

    // ---- Heads: 3×3 expand + 1×1 detect at each scale ----
    let head_c = 3 * (5 + num_classes);
    let h3 = ctx.conv(fe2, 128, 3, 1);
    let det3 = ctx.conv_fixed(h3, head_c, 1, 1, ActivationKind::None);
    let h4 = ctx.conv(pe1, 256, 3, 1);
    let det4 = ctx.conv_fixed(h4, head_c, 1, 1, ActivationKind::None);
    let h5 = ctx.conv(pe2, 512, 3, 1);
    let det5 = ctx.conv_fixed(h5, head_c, 1, 1, ActivationKind::None);

    // ---- Float tail: decode each head for NMS (the paper's "second part",
    //      Section IV-D — runs on the PS) ----
    let b3 = ctx.b.box_decode(det3, 3, num_classes);
    let b4 = ctx.b.box_decode(det4, 3, num_classes);
    let b5 = ctx.b.box_decode(det5, 3, num_classes);

    ctx.b.finish(&[b3, b4, b5])
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::ir::Op;

    #[test]
    fn has_58_convolutions() {
        let g = yolov7_tiny(480, ModelVariant::Base, 80);
        let convs = g.count(|n| matches!(n.op, Op::Conv2d { .. }));
        assert_eq!(convs, 58, "paper: 58 convolution layers");
    }

    #[test]
    fn param_count_close_to_6m() {
        // Paper: YOLOv7-tiny has 6.2 M parameters. Our reconstruction
        // should land in the same ballpark (±25 %).
        let g = yolov7_tiny(480, ModelVariant::Base, 80);
        let p = g.param_count() as f64 / 1e6;
        assert!((4.5..8.0).contains(&p), "got {p} M params");
    }

    #[test]
    fn gflops_halve_from_640_to_480() {
        // Figure 3 rationale: 480×480 cuts GFLOPs by "almost 50 %" vs 640.
        let g640 = yolov7_tiny(640, ModelVariant::Base, 80);
        let g480 = yolov7_tiny(480, ModelVariant::Base, 80);
        let ratio = g480.gops() / g640.gops();
        assert!((0.5..0.62).contains(&ratio), "ratio {ratio}");
    }

    #[test]
    fn base_gflops_plausible() {
        // Official repo: 13.7 GFLOPs at 640. Allow generous tolerance for
        // reconstruction details (we model SPPCSP pools shape-only).
        let g = yolov7_tiny(640, ModelVariant::Base, 80);
        let gf = g.gops();
        assert!((9.0..18.0).contains(&gf), "got {gf} GFLOPs");
    }

    #[test]
    fn pruned_variants_reduce_params() {
        let base = yolov7_tiny(480, ModelVariant::Base, 80).param_count() as f64;
        let p40 = yolov7_tiny(480, ModelVariant::Pruned40, 80).param_count() as f64;
        let p88 = yolov7_tiny(480, ModelVariant::Pruned88, 80).param_count() as f64;
        let s40 = 1.0 - p40 / base;
        let s88 = 1.0 - p88 / base;
        assert!((0.30..0.50).contains(&s40), "40% variant sparsity {s40}");
        assert!((0.80..0.93).contains(&s88), "88% variant sparsity {s88}");
    }

    #[test]
    fn pruned_gflops_reduction_matches_paper() {
        // Paper: up to 78 % GFLOPs reduction at 88 % sparsity.
        let base = yolov7_tiny(480, ModelVariant::Base, 80).gops();
        let p88 = yolov7_tiny(480, ModelVariant::Pruned88, 80).gops();
        let red = 1.0 - p88 / base;
        assert!((0.70..0.92).contains(&red), "GFLOP reduction {red}");
    }

    #[test]
    fn all_activations_leaky_before_pass() {
        let g = yolov7_tiny(480, ModelVariant::Base, 80);
        let leaky = g.count(|n| {
            matches!(
                n.op,
                Op::Conv2d { activation: ActivationKind::LeakyRelu(_), .. }
            )
        });
        // All but the 3 detect convs are LeakyReLU.
        assert_eq!(leaky, 55);
    }

    #[test]
    fn three_detection_scales() {
        let g = yolov7_tiny(480, ModelVariant::Base, 80);
        assert_eq!(g.outputs.len(), 3);
        let decodes = g.count(|n| matches!(n.op, Op::BoxDecode { .. }));
        assert_eq!(decodes, 3);
        // Scales: 480/8=60, 480/16=30, 480/32=15 cells.
        let cells: Vec<usize> =
            g.outputs.iter().map(|&o| g.node(o).output.shape[1] / 3).collect();
        assert_eq!(cells, vec![60 * 60, 30 * 30, 15 * 15]);
    }

    #[test]
    fn graph_valid_at_multiple_sizes() {
        for size in [160, 320, 480, 640] {
            for v in ModelVariant::all() {
                let g = yolov7_tiny(size, v, 8);
                assert!(g.validate().is_ok(), "{size} {v:?}");
            }
        }
    }
}
