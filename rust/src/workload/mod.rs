//! Workload definitions.
//!
//! The performance experiments (Figures 5–7, Table IV) depend only on layer
//! *shapes*, which are public: this module reconstructs the exact
//! YOLOv7-tiny operator trace (58 convolutions plus pool/upsample/concat)
//! at any input size, and derives the 40 %/88 % pruned variants the paper
//! evaluates.

pub mod yolov7_tiny;

pub use yolov7_tiny::{yolov7_tiny, ModelVariant};
