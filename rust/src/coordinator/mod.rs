//! The end-to-end deployment workflow (Figure 2 of the paper).
//!
//! Orchestrates the full chain: pretrained model → activation replacement →
//! (optional iterative pruning) → framework conversion + int8 quantization →
//! PS/PL partitioning → per-layer schedule tuning on the Gemmini simulator →
//! deployment report (mAP, latency, energy). This is the paper's *system*
//! contribution expressed as a library: every evaluation harness
//! (rust/benches/) and the `repro` CLI drive this module.

use crate::baselines;
use crate::dataset::detector::evaluate_detector;
use crate::dataset::scenes::Scene;
use crate::energy::{EnergyReport, FpgaPowerModel};
use crate::fpga::resources::Board;
use crate::fpga::zynq::ZynqSoc;
use crate::gemmini::config::GemminiConfig;
use crate::ir::interp::Value;
use crate::ir::Graph;
use crate::partition::{all_placements, partition_graph, PlacementLatency};
use crate::passes::{quantize_graph, replace_activations, QuantizeOptions};
use crate::postproc::nms::NmsConfig;
use crate::scheduler::{tune_graph, TuningResult};

/// Options for one deployment run.
#[derive(Debug, Clone)]
pub struct DeployOptions {
    pub config: GemminiConfig,
    pub board: Board,
    /// AutoTVM-style measurement budget per layer.
    pub measure_k: usize,
    /// fp16 output scaling (Section III-A).
    pub fp16_scale: bool,
    pub nms: NmsConfig,
}

impl Default for DeployOptions {
    fn default() -> Self {
        Self {
            config: GemminiConfig::ours_zcu102(),
            board: Board::Zcu102,
            measure_k: 4,
            fp16_scale: true,
            nms: NmsConfig::default(),
        }
    }
}

/// Everything the workflow produces for one model.
#[derive(Debug, Clone)]
pub struct DeploymentReport {
    /// mAP of the deployed (quantized) model on the validation scenes,
    /// when scenes were provided.
    pub map: Option<f64>,
    /// Per-layer tuning outcome.
    pub tuning: TuningResult,
    /// The four Figure-6 placements, best first.
    pub placements: Vec<PlacementLatency>,
    /// End-to-end latency of the best (mixed) placement, seconds.
    pub latency_s: f64,
    /// Energy per inference on this platform.
    pub energy: EnergyReport,
    /// Untuned (CISC default) accelerator latency, for the §V-A claims.
    pub default_latency_s: f64,
}

impl DeploymentReport {
    pub fn fps(&self) -> f64 {
        1.0 / self.latency_s
    }
}

/// Run the full deployment workflow on a float graph.
///
/// `calib`: calibration batches for quantization. `val`: validation scenes
/// for mAP (pass `&[]` for workload-only graphs like YOLOv7-tiny, whose
/// weights are synthetic).
pub fn deploy(
    graph: &Graph,
    calib: &[Vec<Value>],
    val: &[Scene],
    opts: &DeployOptions,
) -> DeploymentReport {
    // 1. Hardware-aware model modification (Section IV-B2).
    let mut g = graph.clone();
    replace_activations(&mut g);

    // 2. Quantization (Section IV-B4).
    let q = quantize_graph(
        &g,
        calib,
        &QuantizeOptions { fp16_scale: opts.fp16_scale, fixed_point_requant: true },
    );

    // 3. Accuracy of the deployed model.
    let map = if val.is_empty() { None } else { Some(evaluate_detector(&q, val, &opts.nms)) };

    // 4. Schedule tuning on the accelerator simulator (Section IV-C).
    let tuning = tune_graph(&opts.config, &q, opts.measure_k);
    let main_pl_s = tuning.latency_s(&opts.config, true);
    let default_pl_s = tuning.latency_s(&opts.config, false);

    // 5. Partitioning (Section IV-D) and placement evaluation (Fig. 6).
    let part = partition_graph(&q);
    let soc = ZynqSoc::new(opts.board);
    let placements = all_placements(&part, &soc, &opts.config, main_pl_s);
    let best = placements[0].clone();
    let latency_s = best.total_s();
    let default_latency_s = default_pl_s + best.post_s + best.transfer_s;

    // 6. Energy (Table IV). Utilization proxy: macs over cycles at the
    // tuned schedule (see `TuningResult::utilization`).
    let power = FpgaPowerModel::for_board(opts.board);
    let power_w = power.power_w(&opts.config, tuning.utilization(&opts.config, true));
    let gop = part.main_gop + part.tail_gflop;
    let energy = EnergyReport::new(
        &format!("{}-Gemmini", opts.board.name()),
        &q.name,
        latency_s,
        power_w,
        gop,
    );

    DeploymentReport { map, tuning, placements, latency_s, energy, default_latency_s }
}

/// Latency + energy of the same workload on every baseline platform
/// (Figure 7 / Table IV columns other than ours).
pub fn baseline_energies(model: &str, gop: f64) -> Vec<EnergyReport> {
    baselines::all_baselines().iter().map(|p| p.energy(model, gop)).collect()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::dataset::detector::{build_detector, default_weights};
    use crate::dataset::scenes::{validation_set, SceneConfig};

    #[test]
    fn full_workflow_on_detector() {
        let w = default_weights();
        let g = build_detector(96, &w);
        let scenes = validation_set(&SceneConfig { size: 96, ..Default::default() }, 6, 5);
        let calib: Vec<Vec<Value>> =
            scenes.iter().take(2).map(|s| vec![s.image.clone()]).collect();
        let opts = DeployOptions { measure_k: 2, ..Default::default() };
        let r = deploy(&g, &calib, &scenes, &opts);
        assert!(r.map.is_some());
        assert!(r.latency_s > 0.0);
        assert!(r.latency_s < r.default_latency_s * 1.001);
        assert!(r.energy.energy_j > 0.0);
        assert_eq!(r.placements.len(), 4);
        // Placements sorted best-first. (The mixed-wins claim of Fig. 6 is
        // asserted on the YOLO-sized workload in partition::tests — this
        // 0.03-GOP toy detector can legitimately favour the PS.)
        for w in r.placements.windows(2) {
            assert!(w[0].total_s() <= w[1].total_s());
        }
        // Post-processing never wins on the PL scalar core.
        assert!(r.placements[0].post == crate::partition::Side::Ps);
    }
}
