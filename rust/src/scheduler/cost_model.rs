//! Analytic latency estimate for a (layer, schedule) pair.
//!
//! AutoTVM measures every candidate on hardware; measuring every candidate
//! on the cycle-approximate simulator is affordable but not free, so (like
//! AutoTVM's learned cost model) we rank candidates analytically and only
//! *measure* the top few ([`super::search`]).

use crate::gemmini::config::GemminiConfig;

use super::codegen::ConvGeom;
use super::space::{LoopOrder, RiscSchedule};

/// Estimated cycles for a RISC schedule.
pub fn estimate_risc(cfg: &GemminiConfig, g: &ConvGeom, s: &RiscSchedule) -> f64 {
    let dim = cfg.dim as f64;
    let (mt, nt, kt) = (g.mt(cfg.dim), g.nt(cfg.dim), g.kt(cfg.dim));
    let blocks = mt.div_ceil(s.mb) as f64;

    // ---- DMA bytes ----
    let a_bytes = (g.m * g.k) as f64; // A loaded once (block caching)
    let b_bytes = blocks * (kt * nt) as f64 * dim * dim; // B reloaded per block
    let bias_bytes = if g.bias { blocks * (nt * s.mb) as f64 * dim * dim * 4.0 } else { 0.0 };
    let c_bytes = (g.m * g.n) as f64;
    let dma_bytes = a_bytes + b_bytes + bias_bytes + c_bytes;
    // DMA instruction counts: each mvin/mvout pays one DRAM round-trip on
    // the (serialized) DMA timeline, plus extra batches when its row count
    // exceeds the in-flight window.
    let lat_batches = |rows: usize| (rows as f64 / cfg.max_in_flight as f64).ceil();
    let a_reqs = (mt * kt * g.kernel) as f64 * lat_batches(cfg.dim / g.kernel.max(1).min(cfg.dim));
    let b_reqs = blocks * (kt * nt) as f64;
    let bias_reqs = if g.bias { blocks * (nt * s.mb) as f64 } else { 0.0 };
    let c_reqs = (mt * nt) as f64;
    let reqs = a_reqs + b_reqs + bias_reqs + c_reqs;
    // Request latency pipelines (ROB in-flight window); bus occupancy is
    // transfer + per-row issue beats.
    let rows_total = (g.m * kt) as f64 + b_reqs * dim + (mt * nt) as f64 * dim;
    let dma_cycles = dma_bytes / cfg.bus_bytes_per_cycle() as f64
        + rows_total
        + reqs / cfg.max_in_flight as f64 * cfg.dram_latency as f64;

    // ---- execute cycles ----
    let compute_rows = (g.m * kt * nt) as f64;
    let full_preloads = blocks * (kt * nt) as f64;
    let reuse_preloads = full_preloads * (s.mb as f64 - 1.0);
    let exec_cycles = compute_rows
        + full_preloads * (dim + cfg.scratchpad_read_delay as f64)
        + reuse_preloads;

    // ---- overlap ----
    // Fully double-buffered: max of the two engines. Single-buffered: the
    // block's load and compute phases serialize.
    let overlap = match (s.double_buffer_a, s.double_buffer_b) {
        (true, true) => 0.95,
        (true, false) | (false, true) => 0.6,
        (false, false) => 0.25,
    };
    let serial = dma_cycles + exec_cycles;
    let ideal = dma_cycles.max(exec_cycles);
    let mut est = ideal + (serial - ideal) * (1.0 - overlap);
    // Single scratchpad port: loads and computes contend.
    if cfg.scratchpad_ports == 1 {
        est += 0.5 * dma_cycles.min(exec_cycles);
    }
    // KOuter keeps more accumulator tiles live; mvouts cluster at block
    // end and serialize against the last computes.
    if matches!(s.order, LoopOrder::KOuter) {
        est += c_reqs / blocks * cfg.dram_latency as f64 * 0.25;
    }
    est
}

/// Estimated cycles for the CISC default schedule (single-buffered,
/// B reloaded per output tile, one accumulator tile).
pub fn estimate_cisc(cfg: &GemminiConfig, g: &ConvGeom) -> f64 {
    let dim = cfg.dim as f64;
    let (mt, nt, kt) = (g.mt(cfg.dim), g.nt(cfg.dim), g.kt(cfg.dim));
    // A reloaded per n-tile, B reloaded per (m,n,k) tile.
    let a_bytes = (g.m * g.k * nt) as f64;
    let b_bytes = (mt * nt * kt) as f64 * dim * dim;
    let c_bytes = (g.m * g.n) as f64;
    let dma_bytes = a_bytes + b_bytes + c_bytes;
    let bias_reqs = if g.bias { (mt * nt) as f64 } else { 0.0 };
    let reqs = (mt * kt * g.kernel * nt + mt * nt * kt + mt * nt) as f64 + bias_reqs;
    let rows_total = (g.m * kt * nt) as f64 + (mt * nt * kt) as f64 * dim + (mt * nt) as f64 * dim;
    let dma_cycles = dma_bytes / cfg.bus_bytes_per_cycle() as f64
        + rows_total
        + reqs / cfg.max_in_flight as f64 * cfg.dram_latency as f64;
    let compute_rows = (g.m * kt * nt) as f64;
    let preloads = (mt * nt * kt) as f64;
    let exec = compute_rows + preloads * (dim + cfg.scratchpad_read_delay as f64);
    // Single-buffered FSM: very little overlap.
    dma_cycles + exec * 0.85
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::gemmini::isa::Activation;
    use crate::gemmini::memory::DramAllocator;
    use crate::gemmini::sim::Simulator;
    use crate::scheduler::codegen::{alloc_buffers, lower_cisc, lower_risc};

    fn geom(m: usize, n: usize, k: usize) -> ConvGeom {
        ConvGeom {
            m,
            n,
            k,
            kernel: 1,
            scale: 1.0,
            activation: Activation::None,
            bias: false,
            label: "t".into(),
        }
    }

    /// The cost model must *rank* schedules consistently with the
    /// simulator (Spearman-ish check over the space on a real layer).
    #[test]
    fn cost_model_ranks_like_simulator() {
        let cfg = GemminiConfig { dim: 8, scratchpad_kib: 32, accumulator_kib: 16, ..GemminiConfig::original_zcu102() };
        let g = geom(128, 16, 32);
        let space = crate::scheduler::space::enumerate(&cfg, g.kt(8), g.nt(8));
        let mut pairs: Vec<(f64, u64)> = Vec::new();
        for s in &space {
            let est = estimate_risc(&cfg, &g, s);
            let mut alloc = DramAllocator::new(1 << 22);
            let bufs = alloc_buffers(&g, &mut alloc);
            let mut sim = Simulator::new(cfg.clone(), 1 << 22);
            let meas = sim.run(&lower_risc(&cfg, &g, &bufs, s)).cycles;
            pairs.push((est, meas));
        }
        // Rank correlation over the space.
        let n = pairs.len() as f64;
        let rank = |v: Vec<f64>| -> Vec<f64> {
            let mut idx: Vec<usize> = (0..v.len()).collect();
            idx.sort_by(|&a, &b| v[a].partial_cmp(&v[b]).unwrap());
            let mut r = vec![0.0; v.len()];
            for (pos, &i) in idx.iter().enumerate() {
                r[i] = pos as f64;
            }
            r
        };
        let re = rank(pairs.iter().map(|p| p.0).collect());
        let rm = rank(pairs.iter().map(|p| p.1 as f64).collect());
        let d2: f64 = re.iter().zip(&rm).map(|(a, b)| (a - b) * (a - b)).sum();
        let rho = 1.0 - 6.0 * d2 / (n * (n * n - 1.0));
        assert!(rho > 0.5, "rank correlation {rho} too weak ({pairs:?})");
    }

    #[test]
    fn cisc_estimate_in_simulator_ballpark() {
        let cfg = GemminiConfig { dim: 8, scratchpad_kib: 32, accumulator_kib: 16, ..GemminiConfig::original_zcu102() };
        let g = geom(64, 16, 24);
        let est = estimate_cisc(&cfg, &g);
        let mut alloc = DramAllocator::new(1 << 22);
        let bufs = alloc_buffers(&g, &mut alloc);
        let mut sim = Simulator::new(cfg.clone(), 1 << 22);
        let meas = sim.run(&lower_cisc(&g, &bufs)).cycles as f64;
        let ratio = est / meas;
        assert!((0.3..3.0).contains(&ratio), "est {est} vs meas {meas}");
    }
}
