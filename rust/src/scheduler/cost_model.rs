//! Legacy entry points of the analytic latency estimate.
//!
//! AutoTVM measures every candidate on hardware; measuring every candidate
//! on the cycle-approximate simulator is affordable but not free, so (like
//! AutoTVM's learned cost model) we rank candidates analytically and only
//! *measure* the top few ([`super::search`]). The model itself now lives
//! in [`super::prefilter`] as a per-level memory-hierarchy model; these
//! functions delegate there so older call sites keep ranking with the one
//! shared model.
//!
//! History note: the original single-formula `estimate_risc` carried a
//! mis-clamped DMA batching term — `lat_batches(dim / kernel.max(1)
//! .min(dim))` clamped the *kernel* instead of the quotient, so the
//! "extra batches when row count exceeds the in-flight window" term was
//! dead for exactly the 3×3/5×5 conv layers the paper tunes. The
//! hierarchy model derives the per-request row count from the actual
//! mvin fragmentation (`codegen::emit_a_mvin`); the regression test
//! below pins the fix per kernel size.

use crate::gemmini::config::GemminiConfig;

use super::codegen::ConvGeom;
use super::prefilter;
use super::space::RiscSchedule;

/// Estimated cycles for a RISC schedule. Delegates to
/// [`prefilter::estimate_schedule`].
pub fn estimate_risc(cfg: &GemminiConfig, g: &ConvGeom, s: &RiscSchedule) -> f64 {
    prefilter::estimate_schedule(cfg, g, s)
}

/// Estimated cycles for the CISC default schedule (single-buffered,
/// B reloaded per output tile, one accumulator tile). Delegates to
/// [`prefilter::estimate_default`].
pub fn estimate_cisc(cfg: &GemminiConfig, g: &ConvGeom) -> f64 {
    prefilter::estimate_default(cfg, g)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::gemmini::isa::Activation;
    use crate::gemmini::memory::DramAllocator;
    use crate::gemmini::sim::Simulator;
    use crate::scheduler::codegen::{alloc_buffers, lower_cisc, lower_risc};

    fn geom(m: usize, n: usize, k: usize) -> ConvGeom {
        ConvGeom {
            m,
            n,
            k,
            kernel: 1,
            scale: 1.0,
            activation: Activation::None,
            bias: false,
            label: "t".into(),
        }
    }

    /// Spearman rank correlation between estimates and measured cycles
    /// over a whole schedule space.
    fn spearman_rho(cfg: &GemminiConfig, g: &ConvGeom) -> f64 {
        let space =
            crate::scheduler::space::enumerate(cfg, g.mt(cfg.dim), g.kt(cfg.dim), g.nt(cfg.dim));
        let mut pairs: Vec<(f64, u64)> = Vec::new();
        for s in &space {
            let est = estimate_risc(cfg, g, s);
            let mut alloc = DramAllocator::new(1 << 22);
            let bufs = alloc_buffers(g, &mut alloc);
            let mut sim = Simulator::new(cfg.clone(), 1 << 22);
            let meas = sim.run(&lower_risc(cfg, g, &bufs, s)).cycles;
            pairs.push((est, meas));
        }
        let n = pairs.len() as f64;
        let rank = |v: Vec<f64>| -> Vec<f64> {
            let mut idx: Vec<usize> = (0..v.len()).collect();
            idx.sort_by(|&a, &b| v[a].partial_cmp(&v[b]).unwrap());
            let mut r = vec![0.0; v.len()];
            for (pos, &i) in idx.iter().enumerate() {
                r[i] = pos as f64;
            }
            r
        };
        let re = rank(pairs.iter().map(|p| p.0).collect());
        let rm = rank(pairs.iter().map(|p| p.1 as f64).collect());
        let d2: f64 = re.iter().zip(&rm).map(|(a, b)| (a - b) * (a - b)).sum();
        1.0 - 6.0 * d2 / (n * (n * n - 1.0))
    }

    /// The cost model must *rank* schedules consistently with the
    /// simulator (Spearman-ish check over the space on a real layer).
    #[test]
    fn cost_model_ranks_like_simulator() {
        let cfg = GemminiConfig { dim: 8, scratchpad_kib: 32, accumulator_kib: 16, ..GemminiConfig::original_zcu102() };
        let rho = spearman_rho(&cfg, &geom(128, 16, 32));
        assert!(rho > 0.5, "rank correlation {rho} too weak");
    }

    /// Regression for the mis-clamped A-request batching term: the
    /// ranking quality must hold for every conv kernel size the paper
    /// tunes, not just kernel=1 — and on narrow in-flight windows, where
    /// the batching term is live (`dim.div_ceil(kernel)` rows per mvin
    /// request vs a 4-deep window), not only on the shipped configs
    /// whose window swallows a full `dim`-row mvin.
    #[test]
    fn batching_term_ranks_per_kernel() {
        for dim in [8usize, 16] {
            let cfg = GemminiConfig {
                dim,
                scratchpad_kib: 32,
                accumulator_kib: 16,
                max_in_flight: 4,
                ..GemminiConfig::original_zcu102()
            };
            for kernel in [1usize, 3, 5, 7] {
                let g = ConvGeom {
                    kernel,
                    // K = kernel² × 8 input channels, as a real conv has.
                    ..geom(128, 16, kernel * kernel * 8)
                };
                let rho = spearman_rho(&cfg, &g);
                assert!(rho > 0.5, "dim {dim} kernel {kernel}: rho {rho} too weak");
            }
        }
    }

    #[test]
    fn cisc_estimate_in_simulator_ballpark() {
        let cfg = GemminiConfig { dim: 8, scratchpad_kib: 32, accumulator_kib: 16, ..GemminiConfig::original_zcu102() };
        let g = geom(64, 16, 24);
        let est = estimate_cisc(&cfg, &g);
        let mut alloc = DramAllocator::new(1 << 22);
        let bufs = alloc_buffers(&g, &mut alloc);
        let mut sim = Simulator::new(cfg.clone(), 1 << 22);
        let meas = sim.run(&lower_cisc(&g, &bufs)).cycles as f64;
        let ratio = est / meas;
        assert!((0.3..3.0).contains(&ratio), "est {est} vs meas {meas}");
    }
}
