//! Schedule exploration for Gemmini layers (Sections IV-C, V-A).
//!
//! The paper expands the TVM→Gemmini integration so convolutions, max
//! pooling, resize and concat lower to RISC-type instruction streams whose
//! schedule (tile-block size, loop order, double buffering) is *tunable*,
//! then uses AutoTVM to search that space per layer, falling back to the
//! CISC state machines when the tuned schedule loses. This module is that
//! machinery re-implemented natively:
//!
//! - [`space`] — the per-layer schedule space (analogue of AutoTVM knobs);
//! - [`codegen`] — lowering IR layers to RISC streams for a schedule, or
//!   to the CISC FSM instruction (the "Default" of Figure 5);
//! - [`cost_model`] — analytic latency estimate used to prune the search;
//! - [`search`] — random + local search, with the top candidates measured
//!   on the cycle-approximate simulator (AutoTVM's measure step);
//! - [`tuner`] — whole-model orchestration producing the Figure 5 data.

pub mod codegen;
pub mod cost_model;
pub mod search;
pub mod space;
pub mod tuner;

pub use codegen::{layer_geometry, lower_cisc, lower_risc, ConvGeom};
pub use space::{LoopOrder, RiscSchedule};
pub use tuner::{tune_graph, tune_graph_batch, LayerTuning, TuningResult};
