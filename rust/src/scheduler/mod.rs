//! Schedule exploration for Gemmini layers (Sections IV-C, V-A).
//!
//! The paper expands the TVM→Gemmini integration so convolutions, max
//! pooling, resize and concat lower to RISC-type instruction streams whose
//! schedule (tile-block size, loop order, double buffering) is *tunable*,
//! then uses AutoTVM to search that space per layer, falling back to the
//! CISC state machines when the tuned schedule loses. This module is that
//! machinery re-implemented natively:
//!
//! - [`space`] — the per-layer schedule space (analogue of AutoTVM knobs);
//! - [`codegen`] — lowering IR layers to RISC streams for a schedule, or
//!   to the CISC FSM instruction (the "Default" of Figure 5);
//! - [`prefilter`] — the FactorFlow-style analytical ranker: per-level
//!   traffic against the [`MemLevel`] hierarchy derived from the config,
//!   producing the measurement shortlist (ROADMAP item 4);
//! - [`cost_model`] — legacy estimate entry points (delegate to
//!   [`prefilter`]);
//! - [`search`] — random + local search, with the top candidates measured
//!   on the cycle-approximate simulator (AutoTVM's measure step);
//! - [`cache`] — the persistent tuning cache (AutoTVM-log analogue) and
//!   the memoization keys;
//! - [`tuner`] — whole-model orchestration producing the Figure 5 data,
//!   built on the [`TuningEngine`].
//!
//! # The tuning engine
//!
//! Whole-graph tuning is the workflow's dominant cost (measuring 58
//! YOLOv7-tiny layers × candidates on the cycle simulator), so the tuner
//! itself is an optimized hot path:
//!
//! - **Geometry memoization.** A layer's measured cycles depend only on
//!   its GEMM shape `(m, n, k)`, kernel fragmentation, bias presence, the
//!   accelerator config and the trial budget — so results are keyed by
//!   `(`[`GemminiConfig::fingerprint`]`, `[`GeomKey`]`, measure_k)` and
//!   repeated shapes (YOLO's ELAN blocks repeat heavily) are tuned once.
//! - **Parallel search.** Unique geometries are measured concurrently
//!   with `std::thread::scope` (no external crates); each worker owns one
//!   reused simulator, and results land in per-job slots, so per-layer
//!   cycles, report ordering and JSON bytes are identical at any thread
//!   count.
//! - **Persistent cache.** [`TuningCache`] reads/writes an
//!   AutoTVM-log-style JSON file so repeated `repro` / `repro fleet` runs
//!   warm-start (`repro tune --tuning-cache <path>`); the config
//!   fingerprint in every key invalidates entries when the accelerator
//!   changes, and corrupt/stale files degrade to a cold run, never an
//!   error.
//! - **Simulator reuse.** One timing simulator per worker (and one for
//!   movement ops) replaces the old fresh-256 MiB-DRAM-per-candidate
//!   path; reuse is cycle-exact (see [`crate::gemmini::sim`]).
//! - **Transfer tuning** (opt-in, `TuningEngine::with_transfer`). A cold
//!   `(config, resolution, batch)` point seeds each layer's shortlist
//!   from the cached winner of the nearest neighboring geometry (same
//!   [`GeomKey`] modulo m-scaling, or a sibling config fingerprint) plus
//!   the pre-filter's top pick, measuring a handful of candidates
//!   instead of the full top-k. Whenever the shortlist contains the
//!   full-search winner the result is byte-identical to the full path;
//!   [`EngineStats`] reports the ranker hit-rate (audited via
//!   `TuningEngine::with_transfer_audit`).
//!
//! The free functions [`tune_graph`] / [`tune_graph_batch`] keep the
//! original API on a throwaway engine; hold a [`TuningEngine`] across
//! calls (or attach a cache file) to also reuse results *between* graphs,
//! batch sizes and fleet replicas.
//!
//! [`GemminiConfig::fingerprint`]: crate::gemmini::config::GemminiConfig::fingerprint
//! [`MemLevel`]: crate::gemmini::config::MemLevel

pub mod cache;
pub mod codegen;
pub mod cost_model;
pub mod prefilter;
pub mod search;
pub mod space;
pub mod tuner;

pub use cache::{CacheKey, GeomKey, TuningCache};
pub use codegen::{layer_geometry, lower_cisc, lower_risc, ConvGeom};
pub use prefilter::{estimate_default, estimate_schedule, rank, shortlist, sort_ranked};
pub use search::{
    tune_layer, tune_layer_transfer, tune_layer_with, MeasureCtx, SearchResult, TransferOutcome,
    TransferSeed,
};
pub use space::{LoopOrder, RiscSchedule};
pub use tuner::{
    tune_graph, tune_graph_batch, EngineStats, LayerTuning, TuningEngine, TuningResult,
};
