//! Whole-model tuning (produces Figure 5 and the latency numbers behind
//! Figures 6/7 and Table IV), plus the [`TuningEngine`] that makes it
//! cheap: geometry memoization, parallel search and a persistent
//! warm-start cache. The free functions [`tune_graph`] /
//! [`tune_graph_batch`] keep their original signatures and results —
//! they now run on a throwaway engine, so every caller inherits the
//! memoized parallel path for free.

use std::collections::HashSet;
use std::sync::atomic::{AtomicUsize, Ordering};

use crate::gemmini::config::GemminiConfig;
use crate::gemmini::sim::Simulator;
use crate::ir::{Graph, Op};
use crate::util::json::Json;

use super::cache::{CacheKey, GeomKey, TuningCache};
use super::codegen::{layer_geometry, lower_move_op, ConvGeom};
use super::search::{
    tune_layer_transfer, tune_layer_with, MeasureCtx, SearchResult, TransferSeed,
};

/// Tuning outcome for one GEMM-shaped layer.
#[derive(Debug, Clone)]
pub struct LayerTuning {
    pub label: String,
    pub geom: ConvGeom,
    pub result: SearchResult,
}

/// Tuning outcome for a whole graph.
#[derive(Debug, Clone)]
pub struct TuningResult {
    pub layers: Vec<LayerTuning>,
    /// Cycles of the data-movement ops (pool / upsample / concat),
    /// identical under both schedules.
    pub move_cycles: u64,
}

impl TuningResult {
    /// Total conv/dense cycles with the default CISC schedules.
    pub fn default_conv_cycles(&self) -> u64 {
        self.layers.iter().map(|l| l.result.default_cycles).sum()
    }

    /// Total conv/dense cycles with the best (tuned-or-fallback) schedules.
    pub fn tuned_conv_cycles(&self) -> u64 {
        self.layers.iter().map(|l| l.result.best_cycles).sum()
    }

    /// Whole-model accelerator cycles.
    pub fn total_cycles(&self, tuned: bool) -> u64 {
        self.move_cycles + if tuned { self.tuned_conv_cycles() } else { self.default_conv_cycles() }
    }

    /// Whole-model latency in seconds at the config's clock.
    pub fn latency_s(&self, cfg: &GemminiConfig, tuned: bool) -> f64 {
        self.total_cycles(tuned) as f64 / (cfg.clock_mhz * 1e6)
    }

    /// MAC-array utilization of the schedule on `cfg`: achieved MACs per
    /// cycle over the array's peak. This is the proxy the deployment
    /// workflow feeds the power model (`coordinator::deploy`) and the
    /// serving fleet reports per device (`serving::metrics`).
    pub fn utilization(&self, cfg: &GemminiConfig, tuned: bool) -> f64 {
        let total_macs: u64 = self.layers.iter().map(|l| l.geom.macs()).sum();
        let cycles = self.total_cycles(tuned).max(1);
        (total_macs as f64 / (cycles as f64 * cfg.peak_macs_per_cycle() as f64)).clamp(0.0, 1.0)
    }

    /// Fraction of layers the tuner improved (paper: "> 60 % of the
    /// convolution layers were improved after tuning").
    pub fn fraction_improved(&self) -> f64 {
        if self.layers.is_empty() {
            return 0.0;
        }
        self.layers.iter().filter(|l| l.result.improved()).count() as f64
            / self.layers.len() as f64
    }

    /// Mean improvement of total conv latency (paper: "a mean 50 %
    /// improvement across all models in the latency of the convolutions").
    pub fn conv_improvement(&self) -> f64 {
        1.0 - self.tuned_conv_cycles() as f64 / self.default_conv_cycles() as f64
    }

    pub fn to_json(&self) -> Json {
        Json::obj(vec![
            ("default_conv_cycles", Json::Num(self.default_conv_cycles() as f64)),
            ("tuned_conv_cycles", Json::Num(self.tuned_conv_cycles() as f64)),
            ("move_cycles", Json::Num(self.move_cycles as f64)),
            ("conv_improvement", Json::Num(self.conv_improvement())),
            ("fraction_improved", Json::Num(self.fraction_improved())),
            (
                "layers",
                Json::Arr(self.layers.iter().map(|l| l.result.to_json(&l.label)).collect()),
            ),
        ])
    }
}

/// Work accounting for one engine tuning call (deterministic — the
/// `sim_instrs` counter is the proxy the perf smoke gate checks instead
/// of wall clock).
#[derive(Debug, Clone, Copy, Default, PartialEq, Eq)]
pub struct EngineStats {
    /// Conv/dense layers in the graph.
    pub conv_layers: usize,
    /// Distinct `(shape, trial-budget)` geometries among them.
    pub unique_geometries: usize,
    /// Layers actually searched this call (cache misses).
    pub tuned: usize,
    /// Layers served by an entry produced earlier in this same call
    /// (intra-graph shape dedup).
    pub memo_hits: usize,
    /// Layers served by an entry that pre-dated this call (a previous
    /// call on this engine, or a loaded cache file).
    pub cache_hits: usize,
    /// Data-movement ops (pool / upsample / concat) costed.
    pub move_ops: usize,
    /// Movement ops served from the `(bytes_in, bytes_out)` memo table.
    pub move_memo_hits: usize,
    /// Instructions simulated during this call (post CISC expansion).
    pub sim_instrs: u64,
    /// Worker threads the parallel search phase used.
    pub threads_used: usize,
    /// Cold layers whose shortlist was transfer-seeded from a cached
    /// donor instead of searched top-k
    /// ([`TuningEngine::with_transfer`]).
    pub transfer_seeded: usize,
    /// Audited transfer layers whose shortlist contained the full
    /// search's winner ([`TuningEngine::with_transfer_audit`]).
    pub shortlist_hits: usize,
    /// Audited transfer layers whose shortlist missed the full search's
    /// winner (the transfer result may then differ from the full path).
    pub shortlist_misses: usize,
    /// Instructions the audit's reference full searches simulated —
    /// kept out of `sim_instrs`, which accounts the serving path only.
    pub audit_instrs: u64,
}

impl EngineStats {
    /// The ranker hit-rate the ISSUE's transfer-tuning contract reports:
    /// of the audited transfer-seeded layers, the fraction whose
    /// shortlist contained the full search's winner. `None` until an
    /// audited transfer run has scored at least one layer.
    pub fn hit_rate(&self) -> Option<f64> {
        let scored = self.shortlist_hits + self.shortlist_misses;
        (scored > 0).then(|| self.shortlist_hits as f64 / scored as f64)
    }

    /// JSON object for the CLI's machine-readable report (`repro tune`
    /// prints it alongside the tuning result).
    pub fn to_json(&self) -> Json {
        Json::obj(vec![
            ("conv_layers", Json::Num(self.conv_layers as f64)),
            ("unique_geometries", Json::Num(self.unique_geometries as f64)),
            ("tuned", Json::Num(self.tuned as f64)),
            ("memo_hits", Json::Num(self.memo_hits as f64)),
            ("cache_hits", Json::Num(self.cache_hits as f64)),
            ("move_ops", Json::Num(self.move_ops as f64)),
            ("move_memo_hits", Json::Num(self.move_memo_hits as f64)),
            ("sim_instrs", Json::Num(self.sim_instrs as f64)),
            ("threads_used", Json::Num(self.threads_used as f64)),
            ("transfer_seeded", Json::Num(self.transfer_seeded as f64)),
            ("shortlist_hits", Json::Num(self.shortlist_hits as f64)),
            ("shortlist_misses", Json::Num(self.shortlist_misses as f64)),
            ("audit_instrs", Json::Num(self.audit_instrs as f64)),
            (
                "shortlist_hit_rate",
                match self.hit_rate() {
                    Some(r) => Json::Num(r),
                    None => Json::Null,
                },
            ),
        ])
    }

    /// Fold another call's accounting into this one (counters add;
    /// `threads_used` takes the max).
    fn fold(&mut self, o: &EngineStats) {
        self.conv_layers += o.conv_layers;
        self.unique_geometries += o.unique_geometries;
        self.tuned += o.tuned;
        self.memo_hits += o.memo_hits;
        self.cache_hits += o.cache_hits;
        self.move_ops += o.move_ops;
        self.move_memo_hits += o.move_memo_hits;
        self.sim_instrs += o.sim_instrs;
        self.threads_used = self.threads_used.max(o.threads_used);
        self.transfer_seeded += o.transfer_seeded;
        self.shortlist_hits += o.shortlist_hits;
        self.shortlist_misses += o.shortlist_misses;
        self.audit_instrs += o.audit_instrs;
    }
}

/// The tuning engine: whole-graph schedule search with geometry
/// memoization, parallel measurement and an optional persistent cache.
///
/// - **Memoization** — `tune_layer` results are keyed by
///   `(config fingerprint, shape key, measure_k)` ([`CacheKey`]), so each
///   unique geometry is measured once per engine (and once *ever* with a
///   cache file), not once per layer per call.
/// - **Parallelism** — unique geometries are tuned concurrently with
///   `std::thread::scope`; results land in per-job slots, so per-layer
///   cycles, report ordering and JSON bytes are identical at any thread
///   count.
/// - **Warm start** — attach a [`TuningCache`] loaded from disk
///   ([`TuningCache::load`]) and repeated runs skip simulation entirely;
///   entries from other configs are invisible thanks to the fingerprint
///   in the key.
///
/// Results are bit-identical to the unmemoized single-threaded path: the
/// search is deterministic per geometry, and reused simulators are
/// cycle-exact (see `gemmini::sim`).
pub struct TuningEngine {
    cfg: GemminiConfig,
    config_fp: u64,
    memoize: bool,
    threads: usize,
    /// Transfer tuning on cold layers (opt-in; see
    /// [`with_transfer`](Self::with_transfer)).
    transfer: bool,
    /// Score transfer shortlists against reference full searches
    /// ([`with_transfer_audit`](Self::with_transfer_audit)).
    audit: bool,
    cache: TuningCache,
    /// One reused simulator for movement-op costing (satellite fix: the
    /// old path rebuilt a 64 MiB-DRAM simulator per movement op).
    move_sim: Option<Simulator>,
    last: EngineStats,
    total: EngineStats,
}

/// Simulated DRAM for movement-op streams (matches the old per-op value).
const MOVE_DRAM_BYTES: usize = 1 << 26;

impl TuningEngine {
    pub fn new(cfg: GemminiConfig) -> Self {
        let config_fp = cfg.fingerprint();
        let threads =
            std::thread::available_parallelism().map(|n| n.get()).unwrap_or(1);
        Self {
            cfg,
            config_fp,
            memoize: true,
            threads,
            transfer: false,
            audit: false,
            cache: TuningCache::in_memory(),
            move_sim: None,
            last: EngineStats::default(),
            total: EngineStats::default(),
        }
    }

    /// Attach a cache (typically [`TuningCache::load`]ed from disk).
    /// Marks this engine's config fingerprint live in the cache, so its
    /// entries survive save-time compaction even on a pure-hit run.
    pub fn with_cache(mut self, cache: TuningCache) -> Self {
        self.cache = cache;
        self.cache.touch(self.config_fp);
        self
    }

    /// Override the worker-thread count (default: available parallelism).
    pub fn with_threads(mut self, threads: usize) -> Self {
        self.threads = threads.max(1);
        self
    }

    /// Disable memoization (every layer and movement op simulated from
    /// scratch — the pre-engine behavior; used as the perf baseline).
    pub fn with_memoization(mut self, on: bool) -> Self {
        self.memoize = on;
        self
    }

    /// Enable transfer tuning (default **off**, preserving the engine's
    /// bit-exact-vs-reference contract): a cold layer whose cache lookup
    /// misses but whose [`TuningCache::nearest_donor`] hits is tuned
    /// through [`tune_layer_transfer`] — a two-candidate shortlist:
    /// the pre-filter's top pick plus the best-ranked schedule carrying
    /// the donor winner's double-buffer/loop-order combination —
    /// instead of the full top-`measure_k` search. Donors are resolved
    /// serially at triage time against the pre-call cache state, so
    /// results stay byte-identical at any thread count. Requires
    /// memoization (silently inert without it).
    pub fn with_transfer(mut self, on: bool) -> Self {
        self.transfer = on;
        self
    }

    /// Audit transfer tuning (default off): every transfer-seeded layer
    /// *also* runs the reference full search on a separate audit
    /// simulator, scoring whether the shortlist contained the full
    /// search's winner (`EngineStats::shortlist_hits`/`misses`, surfaced
    /// as [`EngineStats::hit_rate`]). Served results still come from the
    /// transfer path; the audit only measures. Audit simulation is
    /// accounted in `audit_instrs`, not `sim_instrs`.
    pub fn with_transfer_audit(mut self, on: bool) -> Self {
        self.audit = on;
        self
    }

    pub fn config(&self) -> &GemminiConfig {
        &self.cfg
    }

    pub fn cache(&self) -> &TuningCache {
        &self.cache
    }

    /// Work accounting of the most recent `tune_graph*` call.
    pub fn last_stats(&self) -> EngineStats {
        self.last
    }

    /// Cumulative accounting over every call on this engine (what a
    /// whole `repro fleet` run did, replica tunings included; per-call
    /// counters summed, so `unique_geometries` is per-call uniques
    /// summed, not globally distinct keys).
    pub fn total_stats(&self) -> EngineStats {
        self.total
    }

    /// Persist the cache to its backing file (no-op when in-memory).
    pub fn save_cache(&self) -> std::io::Result<()> {
        self.cache.save()
    }

    pub fn tune_graph(&mut self, g: &Graph, measure_k: usize) -> TuningResult {
        self.tune_graph_batch(g, measure_k, 1)
    }

    /// Engine-backed [`tune_graph_batch`] (same semantics and results).
    pub fn tune_graph_batch(
        &mut self,
        g: &Graph,
        measure_k: usize,
        batch: usize,
    ) -> TuningResult {
        let batch = batch.max(1);
        let mut stats = EngineStats { threads_used: 1, ..EngineStats::default() };

        enum Work {
            Conv(ConvGeom),
            Move { bytes_in: usize, bytes_out: usize },
        }
        let mut work: Vec<(String, Work)> = Vec::new();
        let mut unique: HashSet<GeomKey> = HashSet::new();
        for n in &g.nodes {
            match &n.op {
                Op::Conv2d { .. } | Op::Dense { .. } => {
                    let mut geom = layer_geometry(g, n.id).expect("geometry");
                    geom.m *= batch;
                    stats.conv_layers += 1;
                    unique.insert(geom.shape_key());
                    work.push((n.output.name.clone(), Work::Conv(geom)));
                }
                Op::MaxPool2d { .. } | Op::Upsample { .. } | Op::Concat => {
                    let bytes_in: usize = n
                        .inputs
                        .iter()
                        .map(|&i| g.node(i).output.numel())
                        .sum::<usize>()
                        * batch;
                    let bytes_out = n.output.numel() * batch;
                    work.push((String::new(), Work::Move { bytes_in, bytes_out }));
                }
                _ => {}
            }
        }
        stats.unique_geometries = unique.len();

        // Phase 1 (memoized path): triage conv layers against the cache,
        // then tune the unique misses in parallel. First-seen order keeps
        // the job list — and therefore everything downstream — stable.
        // Transfer donors are resolved here, serially, against the
        // pre-call cache state: in-batch insertions only land after
        // `tune_jobs`, so donor choice (and with it every result) is
        // independent of worker scheduling and thread count.
        if self.memoize {
            let mut queued: HashSet<CacheKey> = HashSet::new();
            let mut jobs: Vec<TuneJob> = Vec::new();
            for (_, w) in &work {
                if let Work::Conv(geom) = w {
                    let key = self.layer_key(geom, measure_k);
                    if self.cache.get_layer(&key).is_some() {
                        stats.cache_hits += 1;
                    } else if queued.contains(&key) {
                        stats.memo_hits += 1;
                    } else {
                        let seed = if self.transfer {
                            self.cache.nearest_donor(&key).map(|(dk, dr)| TransferSeed {
                                schedule: dr.best_schedule,
                                donor_default: dr.default_cycles,
                                donor_best: dr.best_cycles,
                                donor_m: dk.geom.m,
                                scalable: dk.config_fp == key.config_fp,
                            })
                        } else {
                            None
                        };
                        if seed.is_some() {
                            stats.transfer_seeded += 1;
                        }
                        queued.insert(key);
                        jobs.push(TuneJob { key, geom: geom.clone(), seed });
                    }
                }
            }
            stats.tuned = jobs.len();
            let results = self.tune_jobs(&jobs, measure_k, &mut stats);
            for (job, result) in jobs.iter().zip(results) {
                self.cache.insert_layer(job.key, result);
            }
        }

        // Phase 2: assemble per-layer results in graph node order.
        let mut layers = Vec::new();
        let mut move_cycles = 0u64;
        let mut solo: Option<MeasureCtx> = None;
        for (label, w) in work {
            match w {
                Work::Conv(geom) => {
                    let result = if self.memoize {
                        let key = self.layer_key(&geom, measure_k);
                        self.cache.get_layer(&key).expect("tuned in phase 1").clone()
                    } else {
                        stats.tuned += 1;
                        if solo.is_none() {
                            solo = Some(MeasureCtx::new(&self.cfg));
                        }
                        tune_layer_with(solo.as_mut().unwrap(), &geom, measure_k)
                    };
                    layers.push(LayerTuning { label, geom, result });
                }
                Work::Move { bytes_in, bytes_out } => {
                    move_cycles += self.move_op_cycles(bytes_in, bytes_out, &mut stats);
                }
            }
        }
        if let Some(ctx) = solo {
            stats.sim_instrs += ctx.sim_instrs;
        }
        self.total.fold(&stats);
        self.last = stats;
        TuningResult { layers, move_cycles }
    }

    fn layer_key(&self, geom: &ConvGeom, measure_k: usize) -> CacheKey {
        CacheKey { config_fp: self.config_fp, geom: geom.shape_key(), measure_k }
    }

    /// Cycles of one data-movement op, memoized by `(bytes_in, bytes_out)`
    /// and measured on the engine's one reused simulator.
    fn move_op_cycles(
        &mut self,
        bytes_in: usize,
        bytes_out: usize,
        stats: &mut EngineStats,
    ) -> u64 {
        stats.move_ops += 1;
        if self.memoize {
            if let Some(cycles) = self.cache.get_move(self.config_fp, bytes_in, bytes_out) {
                stats.move_memo_hits += 1;
                return cycles;
            }
        }
        let stream = lower_move_op(&self.cfg, bytes_in, bytes_out);
        if self.move_sim.is_none() {
            self.move_sim = Some(Simulator::new(self.cfg.clone(), MOVE_DRAM_BYTES));
        }
        let res = self.move_sim.as_mut().unwrap().run(&stream);
        stats.sim_instrs += res.instrs;
        if self.memoize {
            self.cache.insert_move(self.config_fp, bytes_in, bytes_out, res.cycles);
        }
        res.cycles
    }

    /// Tune `jobs` concurrently. Each worker owns a [`MeasureCtx`] (plus
    /// a lazily-created audit context when auditing) and pulls job
    /// indices from a shared counter; results land in the slot of their
    /// job index, so the output order (and every result) is independent
    /// of scheduling and thread count.
    fn tune_jobs(
        &self,
        jobs: &[TuneJob],
        measure_k: usize,
        stats: &mut EngineStats,
    ) -> Vec<SearchResult> {
        if jobs.is_empty() {
            return Vec::new();
        }
        let threads = self.threads.min(jobs.len()).max(1);
        stats.threads_used = threads;
        let audit = self.audit;
        let cfg = &self.cfg;
        if threads == 1 {
            let mut worker = TuneWorker::new(cfg, audit, measure_k);
            let out: Vec<SearchResult> = jobs.iter().map(|j| worker.run(j)).collect();
            worker.account(stats);
            return out;
        }
        let next = AtomicUsize::new(0);
        let mut slots: Vec<Option<SearchResult>> = vec![None; jobs.len()];
        std::thread::scope(|scope| {
            let handles: Vec<_> = (0..threads)
                .map(|_| {
                    scope.spawn(|| {
                        let mut worker = TuneWorker::new(cfg, audit, measure_k);
                        let mut mine = Vec::new();
                        loop {
                            let i = next.fetch_add(1, Ordering::Relaxed);
                            if i >= jobs.len() {
                                break;
                            }
                            mine.push((i, worker.run(&jobs[i])));
                        }
                        (mine, worker)
                    })
                })
                .collect();
            for h in handles {
                let (mine, worker) = h.join().expect("tuning worker panicked");
                // Per-worker counters are order-independent sums, so the
                // fold is deterministic regardless of scheduling.
                worker.account(stats);
                for (i, r) in mine {
                    slots[i] = Some(r);
                }
            }
        });
        slots.into_iter().map(|s| s.expect("every job index was claimed")).collect()
    }
}

/// One unit of phase-1 tuning work: a cache-missed unique geometry,
/// optionally carrying the transfer seed its donor lookup produced.
struct TuneJob {
    key: CacheKey,
    geom: ConvGeom,
    seed: Option<TransferSeed>,
}

/// Per-worker measurement state: the serving [`MeasureCtx`], plus a
/// separate audit context (so audit simulation never perturbs the
/// serving path's reused-simulator determinism) and the audit tallies.
struct TuneWorker {
    cfg: GemminiConfig,
    ctx: MeasureCtx,
    audit_ctx: Option<MeasureCtx>,
    audit: bool,
    measure_k: usize,
    shortlist_hits: usize,
    shortlist_misses: usize,
}

impl TuneWorker {
    fn new(cfg: &GemminiConfig, audit: bool, measure_k: usize) -> Self {
        Self {
            cfg: cfg.clone(),
            ctx: MeasureCtx::new(cfg),
            audit_ctx: None,
            audit,
            measure_k,
            shortlist_hits: 0,
            shortlist_misses: 0,
        }
    }

    fn run(&mut self, job: &TuneJob) -> SearchResult {
        let Some(seed) = &job.seed else {
            return tune_layer_with(&mut self.ctx, &job.geom, self.measure_k);
        };
        let out = tune_layer_transfer(&mut self.ctx, &job.geom, seed);
        if self.audit {
            let actx =
                self.audit_ctx.get_or_insert_with(|| MeasureCtx::new(&self.cfg));
            let full = tune_layer_with(actx, &job.geom, self.measure_k);
            // Hit = the transfer shortlist covered the full search's
            // winner: its winning RISC schedule was measured, or — when
            // CISC won the full search — the default was measured, not
            // estimated.
            let hit = match full.best_schedule {
                Some(w) => out.shortlist.contains(&w),
                None => !out.result.default_est,
            };
            if hit {
                self.shortlist_hits += 1;
            } else {
                self.shortlist_misses += 1;
            }
        }
        out.result
    }

    fn account(&self, stats: &mut EngineStats) {
        stats.sim_instrs += self.ctx.sim_instrs;
        stats.audit_instrs += self.audit_ctx.as_ref().map_or(0, |c| c.sim_instrs);
        stats.shortlist_hits += self.shortlist_hits;
        stats.shortlist_misses += self.shortlist_misses;
    }
}

/// Tune every conv/dense layer of a graph and cost its movement ops.
/// `measure_k` bounds how many schedule candidates are measured per layer
/// (the AutoTVM trial budget).
pub fn tune_graph(cfg: &GemminiConfig, g: &Graph, measure_k: usize) -> TuningResult {
    tune_graph_batch(cfg, g, measure_k, 1)
}

/// Tune the graph *for a serving batch size*: every conv/dense GEMM
/// serves `batch` frames per invocation, so its activation rows scale to
/// `batch × m` while the `k × n` weight volume is unchanged, and movement
/// ops move `batch ×` the bytes. The returned [`TuningResult`]'s latency
/// is the *whole-batch* latency, measured on schedules searched for the
/// batched geometry. This replaces the analytic weight-stream split
/// [`crate::serving::GemminiDevice::from_tuning`] assumes with what the
/// cycle model actually does to a batch: weight tiles re-stream per
/// A-block (not once per batch), partial m-tiles fill up, and per-stream
/// fixed overheads amortize — so the measured amortization is usually
/// *smaller* than the analytic split's optimistic "weights once per
/// batch" story, and the serving model inherits the honest number.
pub fn tune_graph_batch(
    cfg: &GemminiConfig,
    g: &Graph,
    measure_k: usize,
    batch: usize,
) -> TuningResult {
    TuningEngine::new(cfg.clone()).tune_graph_batch(g, measure_k, batch)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::workload::{yolov7_tiny, ModelVariant};

    /// Tuning a (small-resolution) YOLOv7-tiny reproduces the paper's
    /// §V-A claims in shape: substantial mean conv improvement, most
    /// layers improved, never a regression.
    #[test]
    fn tuning_improves_yolov7_tiny_layers() {
        let cfg = GemminiConfig::ours_zcu102();
        let mut g = yolov7_tiny(160, ModelVariant::Pruned88, 8);
        crate::passes::replace_activations(&mut g);
        let t = tune_graph(&cfg, &g, 4);
        assert_eq!(t.layers.len(), 58);
        assert!(t.tuned_conv_cycles() <= t.default_conv_cycles());
        assert!(
            t.conv_improvement() > 0.2,
            "mean conv improvement {}",
            t.conv_improvement()
        );
        assert!(
            t.fraction_improved() > 0.5,
            "fraction improved {}",
            t.fraction_improved()
        );
        assert!(t.move_cycles > 0);
    }

    #[test]
    fn tuned_latency_reported_in_seconds() {
        let cfg = GemminiConfig::ours_zcu102();
        let mut g = yolov7_tiny(160, ModelVariant::Pruned88, 8);
        crate::passes::replace_activations(&mut g);
        let t = tune_graph(&cfg, &g, 2);
        let lat = t.latency_s(&cfg, true);
        assert!(lat > 0.0 && lat < 1.0, "latency {lat}");
        assert!(t.latency_s(&cfg, false) >= lat);
    }

    #[test]
    fn utilization_is_macs_over_peak_cycles() {
        let cfg = GemminiConfig::ours_zcu102();
        let mut g = yolov7_tiny(160, ModelVariant::Pruned88, 8);
        crate::passes::replace_activations(&mut g);
        let t = tune_graph(&cfg, &g, 2);
        let u_tuned = t.utilization(&cfg, true);
        let u_default = t.utilization(&cfg, false);
        assert!(u_tuned > 0.0 && u_tuned <= 1.0, "utilization {u_tuned}");
        // Fewer cycles for the same MACs → tuned utilization never lower.
        assert!(u_tuned >= u_default, "{u_tuned} < {u_default}");
        // Matches the closed-form definition.
        let macs: u64 = t.layers.iter().map(|l| l.geom.macs()).sum();
        let expect = macs as f64
            / (t.total_cycles(true) as f64 * cfg.peak_macs_per_cycle() as f64);
        assert!((u_tuned - expect.clamp(0.0, 1.0)).abs() < 1e-12);
    }

    #[test]
    fn batch_tuning_amortizes_weight_streams() {
        let cfg = GemminiConfig::ours_zcu102();
        let mut g = yolov7_tiny(160, ModelVariant::Pruned88, 8);
        crate::passes::replace_activations(&mut g);
        let t1 = tune_graph(&cfg, &g, 1);
        let batch = 4;
        let tb = tune_graph_batch(&cfg, &g, 1, batch);
        assert_eq!(tb.layers.len(), t1.layers.len());
        // Geometry scaled: activation rows × batch, weights unchanged.
        for (a, b) in t1.layers.iter().zip(&tb.layers) {
            assert_eq!(b.geom.m, batch * a.geom.m, "{}", a.label);
            assert_eq!(b.geom.k, a.geom.k);
            assert_eq!(b.geom.n, a.geom.n);
        }
        // The batched invocation beats `batch` single invocations: the
        // per-layer weight load is paid once, not `batch` times.
        let lat1 = t1.latency_s(&cfg, true);
        let latb = tb.latency_s(&cfg, true);
        assert!(latb > lat1, "a batch costs more than one frame");
        assert!(
            latb < batch as f64 * lat1,
            "batch {batch}: {latb} !< {batch}×{lat1}"
        );
        // Deterministic: same inputs, same cycles.
        let tb2 = tune_graph_batch(&cfg, &g, 1, batch);
        assert_eq!(tb.tuned_conv_cycles(), tb2.tuned_conv_cycles());
        assert_eq!(tb.move_cycles, tb2.move_cycles);
        // batch=1 degenerates to the standard tuner.
        let t1b = tune_graph_batch(&cfg, &g, 1, 1);
        assert_eq!(t1b.tuned_conv_cycles(), t1.tuned_conv_cycles());
    }

    #[test]
    fn json_roundtrip() {
        let cfg = GemminiConfig::ours_zcu102();
        let mut g = yolov7_tiny(160, ModelVariant::Pruned88, 8);
        crate::passes::replace_activations(&mut g);
        let t = tune_graph(&cfg, &g, 1);
        let s = t.to_json().dump();
        assert!(Json::parse(&s).is_ok());
    }

    #[test]
    fn engine_dedupes_repeated_geometries() {
        let cfg = GemminiConfig::ours_zcu102();
        let mut g = yolov7_tiny(160, ModelVariant::Pruned88, 8);
        crate::passes::replace_activations(&mut g);
        let mut e = TuningEngine::new(cfg);
        let t = e.tune_graph(&g, 1);
        let s = e.last_stats();
        assert_eq!(s.conv_layers, 58);
        assert_eq!(t.layers.len(), 58);
        // The ELAN blocks repeat shapes: the unique count must be well
        // below the layer count, and the accounting must balance.
        assert!(s.unique_geometries < s.conv_layers, "{s:?}");
        assert_eq!(s.tuned, s.unique_geometries);
        assert_eq!(s.tuned + s.memo_hits + s.cache_hits, s.conv_layers, "{s:?}");
        assert_eq!(s.cache_hits, 0);
        assert!(s.move_ops > 0 && s.sim_instrs > 0);

        // A repeat call on the same engine is pure cache: zero simulation.
        let t2 = e.tune_graph(&g, 1);
        let s2 = e.last_stats();
        assert_eq!(s2.tuned, 0);
        assert_eq!(s2.cache_hits, s2.conv_layers);
        assert_eq!(s2.move_memo_hits, s2.move_ops);
        assert_eq!(s2.sim_instrs, 0);
        assert_eq!(t.to_json().dump(), t2.to_json().dump());
        assert_eq!(t.move_cycles, t2.move_cycles);

        // Cumulative accounting spans both calls.
        let tot = e.total_stats();
        assert_eq!(tot.conv_layers, s.conv_layers + s2.conv_layers);
        assert_eq!(tot.cache_hits, s.cache_hits + s2.cache_hits);
        assert_eq!(tot.sim_instrs, s.sim_instrs, "warm call added no instrs");
    }

    #[test]
    fn transfer_engine_seeds_batch_scaled_geometries() {
        let cfg = GemminiConfig::ours_zcu102();
        let mut g = yolov7_tiny(160, ModelVariant::Pruned88, 8);
        crate::passes::replace_activations(&mut g);
        let mut e = TuningEngine::new(cfg)
            .with_transfer(true)
            .with_transfer_audit(true);
        // Cold cache: nothing can donate, transfer is a no-op.
        let t1 = e.tune_graph(&g, 4);
        let s1 = e.last_stats();
        assert_eq!(s1.transfer_seeded, 0, "{s1:?}");
        assert_eq!(s1.audit_instrs, 0);
        assert!(s1.hit_rate().is_none());
        // Batch 2 scales every GEMM's m: each unique geometry now has an
        // m-neighbor donor from the batch-1 call.
        let t2 = e.tune_graph_batch(&g, 4, 2);
        let s2 = e.last_stats();
        assert!(s2.tuned > 0);
        assert_eq!(s2.transfer_seeded, s2.tuned, "{s2:?}");
        // Audit scored every seeded layer on a separate context.
        assert_eq!(s2.shortlist_hits + s2.shortlist_misses, s2.transfer_seeded);
        assert!(s2.audit_instrs > 0);
        assert!(e.last_stats().hit_rate().is_some());
        // The transfer path simulates much less than the audit's
        // reference full searches over the same layers (moves included).
        assert!(
            s2.sim_instrs < s2.audit_instrs,
            "transfer {} !< full-search {}",
            s2.sim_instrs,
            s2.audit_instrs
        );
        // Tuner invariants survive the seeded path.
        assert_eq!(t2.layers.len(), t1.layers.len());
        for l in &t2.layers {
            assert!(l.result.best_cycles <= l.result.default_cycles, "{}", l.label);
        }
    }

    #[test]
    fn engine_matches_unmemoized_reference() {
        let cfg = GemminiConfig::ours_zcu102();
        let mut g = yolov7_tiny(160, ModelVariant::Pruned88, 8);
        crate::passes::replace_activations(&mut g);
        let mut cold = TuningEngine::new(cfg.clone()).with_memoization(false);
        let t_cold = cold.tune_graph(&g, 1);
        let mut memo = TuningEngine::new(cfg);
        let t_memo = memo.tune_graph(&g, 1);
        assert_eq!(t_cold.to_json().dump(), t_memo.to_json().dump());
        assert_eq!(t_cold.move_cycles, t_memo.move_cycles);
        // Memoization strictly reduces simulated work.
        assert!(
            memo.last_stats().sim_instrs < cold.last_stats().sim_instrs,
            "memo {} !< cold {}",
            memo.last_stats().sim_instrs,
            cold.last_stats().sim_instrs
        );
    }
}
