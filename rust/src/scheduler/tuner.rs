//! Whole-model tuning (produces Figure 5 and the latency numbers behind
//! Figures 6/7 and Table IV).

use crate::gemmini::config::GemminiConfig;
use crate::gemmini::sim::Simulator;
use crate::ir::{Graph, Op};
use crate::util::json::Json;

use super::codegen::{layer_geometry, lower_move_op, ConvGeom};
use super::search::{tune_layer, SearchResult};

/// Tuning outcome for one GEMM-shaped layer.
#[derive(Debug, Clone)]
pub struct LayerTuning {
    pub label: String,
    pub geom: ConvGeom,
    pub result: SearchResult,
}

/// Tuning outcome for a whole graph.
#[derive(Debug, Clone)]
pub struct TuningResult {
    pub layers: Vec<LayerTuning>,
    /// Cycles of the data-movement ops (pool / upsample / concat),
    /// identical under both schedules.
    pub move_cycles: u64,
}

impl TuningResult {
    /// Total conv/dense cycles with the default CISC schedules.
    pub fn default_conv_cycles(&self) -> u64 {
        self.layers.iter().map(|l| l.result.default_cycles).sum()
    }

    /// Total conv/dense cycles with the best (tuned-or-fallback) schedules.
    pub fn tuned_conv_cycles(&self) -> u64 {
        self.layers.iter().map(|l| l.result.best_cycles).sum()
    }

    /// Whole-model accelerator cycles.
    pub fn total_cycles(&self, tuned: bool) -> u64 {
        self.move_cycles + if tuned { self.tuned_conv_cycles() } else { self.default_conv_cycles() }
    }

    /// Whole-model latency in seconds at the config's clock.
    pub fn latency_s(&self, cfg: &GemminiConfig, tuned: bool) -> f64 {
        self.total_cycles(tuned) as f64 / (cfg.clock_mhz * 1e6)
    }

    /// MAC-array utilization of the schedule on `cfg`: achieved MACs per
    /// cycle over the array's peak. This is the proxy the deployment
    /// workflow feeds the power model (`coordinator::deploy`) and the
    /// serving fleet reports per device (`serving::metrics`).
    pub fn utilization(&self, cfg: &GemminiConfig, tuned: bool) -> f64 {
        let total_macs: u64 = self.layers.iter().map(|l| l.geom.macs()).sum();
        let cycles = self.total_cycles(tuned).max(1);
        (total_macs as f64 / (cycles as f64 * cfg.peak_macs_per_cycle() as f64)).clamp(0.0, 1.0)
    }

    /// Fraction of layers the tuner improved (paper: "> 60 % of the
    /// convolution layers were improved after tuning").
    pub fn fraction_improved(&self) -> f64 {
        if self.layers.is_empty() {
            return 0.0;
        }
        self.layers.iter().filter(|l| l.result.improved()).count() as f64
            / self.layers.len() as f64
    }

    /// Mean improvement of total conv latency (paper: "a mean 50 %
    /// improvement across all models in the latency of the convolutions").
    pub fn conv_improvement(&self) -> f64 {
        1.0 - self.tuned_conv_cycles() as f64 / self.default_conv_cycles() as f64
    }

    pub fn to_json(&self) -> Json {
        Json::obj(vec![
            ("default_conv_cycles", Json::Num(self.default_conv_cycles() as f64)),
            ("tuned_conv_cycles", Json::Num(self.tuned_conv_cycles() as f64)),
            ("move_cycles", Json::Num(self.move_cycles as f64)),
            ("conv_improvement", Json::Num(self.conv_improvement())),
            ("fraction_improved", Json::Num(self.fraction_improved())),
            (
                "layers",
                Json::Arr(self.layers.iter().map(|l| l.result.to_json(&l.label)).collect()),
            ),
        ])
    }
}

/// Tune every conv/dense layer of a graph and cost its movement ops.
/// `measure_k` bounds how many schedule candidates are measured per layer
/// (the AutoTVM trial budget).
pub fn tune_graph(cfg: &GemminiConfig, g: &Graph, measure_k: usize) -> TuningResult {
    tune_graph_batch(cfg, g, measure_k, 1)
}

/// Tune the graph *for a serving batch size*: every conv/dense GEMM
/// serves `batch` frames per invocation, so its activation rows scale to
/// `batch × m` while the `k × n` weight volume is unchanged, and movement
/// ops move `batch ×` the bytes. The returned [`TuningResult`]'s latency
/// is the *whole-batch* latency, measured on schedules searched for the
/// batched geometry. This replaces the analytic weight-stream split
/// [`crate::serving::GemminiDevice::from_tuning`] assumes with what the
/// cycle model actually does to a batch: weight tiles re-stream per
/// A-block (not once per batch), partial m-tiles fill up, and per-stream
/// fixed overheads amortize — so the measured amortization is usually
/// *smaller* than the analytic split's optimistic "weights once per
/// batch" story, and the serving model inherits the honest number.
pub fn tune_graph_batch(
    cfg: &GemminiConfig,
    g: &Graph,
    measure_k: usize,
    batch: usize,
) -> TuningResult {
    let batch = batch.max(1);
    let mut layers = Vec::new();
    let mut move_cycles = 0u64;
    for n in &g.nodes {
        match &n.op {
            Op::Conv2d { .. } | Op::Dense { .. } => {
                let mut geom = layer_geometry(g, n.id).expect("geometry");
                geom.m *= batch;
                let result = tune_layer(cfg, &geom, measure_k);
                layers.push(LayerTuning { label: n.output.name.clone(), geom, result });
            }
            Op::MaxPool2d { .. } | Op::Upsample { .. } | Op::Concat => {
                let bytes_in: usize =
                    n.inputs.iter().map(|&i| g.node(i).output.numel()).sum::<usize>() * batch;
                let bytes_out = n.output.numel() * batch;
                let mut sim = Simulator::new(cfg.clone(), 1 << 26);
                move_cycles += sim.run(&lower_move_op(cfg, bytes_in, bytes_out)).cycles;
            }
            _ => {}
        }
    }
    TuningResult { layers, move_cycles }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::workload::{yolov7_tiny, ModelVariant};

    /// Tuning a (small-resolution) YOLOv7-tiny reproduces the paper's
    /// §V-A claims in shape: substantial mean conv improvement, most
    /// layers improved, never a regression.
    #[test]
    fn tuning_improves_yolov7_tiny_layers() {
        let cfg = GemminiConfig::ours_zcu102();
        let mut g = yolov7_tiny(160, ModelVariant::Pruned88, 8);
        crate::passes::replace_activations(&mut g);
        let t = tune_graph(&cfg, &g, 4);
        assert_eq!(t.layers.len(), 58);
        assert!(t.tuned_conv_cycles() <= t.default_conv_cycles());
        assert!(
            t.conv_improvement() > 0.2,
            "mean conv improvement {}",
            t.conv_improvement()
        );
        assert!(
            t.fraction_improved() > 0.5,
            "fraction improved {}",
            t.fraction_improved()
        );
        assert!(t.move_cycles > 0);
    }

    #[test]
    fn tuned_latency_reported_in_seconds() {
        let cfg = GemminiConfig::ours_zcu102();
        let mut g = yolov7_tiny(160, ModelVariant::Pruned88, 8);
        crate::passes::replace_activations(&mut g);
        let t = tune_graph(&cfg, &g, 2);
        let lat = t.latency_s(&cfg, true);
        assert!(lat > 0.0 && lat < 1.0, "latency {lat}");
        assert!(t.latency_s(&cfg, false) >= lat);
    }

    #[test]
    fn utilization_is_macs_over_peak_cycles() {
        let cfg = GemminiConfig::ours_zcu102();
        let mut g = yolov7_tiny(160, ModelVariant::Pruned88, 8);
        crate::passes::replace_activations(&mut g);
        let t = tune_graph(&cfg, &g, 2);
        let u_tuned = t.utilization(&cfg, true);
        let u_default = t.utilization(&cfg, false);
        assert!(u_tuned > 0.0 && u_tuned <= 1.0, "utilization {u_tuned}");
        // Fewer cycles for the same MACs → tuned utilization never lower.
        assert!(u_tuned >= u_default, "{u_tuned} < {u_default}");
        // Matches the closed-form definition.
        let macs: u64 = t.layers.iter().map(|l| l.geom.macs()).sum();
        let expect = macs as f64
            / (t.total_cycles(true) as f64 * cfg.peak_macs_per_cycle() as f64);
        assert!((u_tuned - expect.clamp(0.0, 1.0)).abs() < 1e-12);
    }

    #[test]
    fn batch_tuning_amortizes_weight_streams() {
        let cfg = GemminiConfig::ours_zcu102();
        let mut g = yolov7_tiny(160, ModelVariant::Pruned88, 8);
        crate::passes::replace_activations(&mut g);
        let t1 = tune_graph(&cfg, &g, 1);
        let batch = 4;
        let tb = tune_graph_batch(&cfg, &g, 1, batch);
        assert_eq!(tb.layers.len(), t1.layers.len());
        // Geometry scaled: activation rows × batch, weights unchanged.
        for (a, b) in t1.layers.iter().zip(&tb.layers) {
            assert_eq!(b.geom.m, batch * a.geom.m, "{}", a.label);
            assert_eq!(b.geom.k, a.geom.k);
            assert_eq!(b.geom.n, a.geom.n);
        }
        // The batched invocation beats `batch` single invocations: the
        // per-layer weight load is paid once, not `batch` times.
        let lat1 = t1.latency_s(&cfg, true);
        let latb = tb.latency_s(&cfg, true);
        assert!(latb > lat1, "a batch costs more than one frame");
        assert!(
            latb < batch as f64 * lat1,
            "batch {batch}: {latb} !< {batch}×{lat1}"
        );
        // Deterministic: same inputs, same cycles.
        let tb2 = tune_graph_batch(&cfg, &g, 1, batch);
        assert_eq!(tb.tuned_conv_cycles(), tb2.tuned_conv_cycles());
        assert_eq!(tb.move_cycles, tb2.move_cycles);
        // batch=1 degenerates to the standard tuner.
        let t1b = tune_graph_batch(&cfg, &g, 1, 1);
        assert_eq!(t1b.tuned_conv_cycles(), t1.tuned_conv_cycles());
    }

    #[test]
    fn json_roundtrip() {
        let cfg = GemminiConfig::ours_zcu102();
        let mut g = yolov7_tiny(160, ModelVariant::Pruned88, 8);
        crate::passes::replace_activations(&mut g);
        let t = tune_graph(&cfg, &g, 1);
        let s = t.to_json().dump();
        assert!(Json::parse(&s).is_ok());
    }
}
