//! The per-layer schedule space (the AutoTVM knobs of Section IV-C).

use crate::gemmini::config::GemminiConfig;

/// Loop nesting inside one m-block: which of the (n, k) loops is outer.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum LoopOrder {
    /// `for n { for k { preload B(k,n); for m: compute } }` — B loaded
    /// kt times per (block, n); accumulator written once per n.
    NOuter,
    /// `for k { for n { … } }` — same loads, different accumulate pattern:
    /// every (m, n) accumulator tile stays live across the whole k loop,
    /// so `mb × nt` tiles must fit in the accumulator.
    KOuter,
}

/// A RISC-type schedule for one GEMM-shaped layer. `Eq`/`Hash` so tuned
/// schedules can serve as memoization-cache values/keys
/// (see [`super::cache`]).
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub struct RiscSchedule {
    /// m-tiles processed per block (A block cached in scratchpad across
    /// the whole n/k loop — the reuse CISC's fixed schedule lacks).
    pub mb: usize,
    /// Double-buffer A blocks (prefetch next block during compute).
    pub double_buffer_a: bool,
    /// Double-buffer B tiles (prefetch next B during compute).
    pub double_buffer_b: bool,
    /// Loop order inside a block.
    pub order: LoopOrder,
}

impl RiscSchedule {
    /// Scratchpad rows needed for a layer with `kt` K-tiles.
    pub fn sp_rows_needed(&self, cfg: &GemminiConfig, kt: usize) -> usize {
        let a_block = self.mb * cfg.dim * kt;
        let a_bufs = if self.double_buffer_a { 2 } else { 1 };
        let b_bufs = if self.double_buffer_b { 2 } else { 1 };
        a_block * a_bufs + cfg.dim * b_bufs
    }

    /// Accumulator rows needed (`nt` N-tiles for the KOuter order).
    pub fn acc_rows_needed(&self, cfg: &GemminiConfig, nt: usize) -> usize {
        match self.order {
            LoopOrder::NOuter => self.mb * cfg.dim,
            LoopOrder::KOuter => self.mb * nt.max(1) * cfg.dim,
        }
    }

    /// Whether this schedule fits the accelerator for a layer of `kt`
    /// K-tiles and `nt` N-tiles.
    pub fn fits(&self, cfg: &GemminiConfig, kt: usize, nt: usize) -> bool {
        self.sp_rows_needed(cfg, kt) <= cfg.scratchpad_rows()
            && self.acc_rows_needed(cfg, nt) <= cfg.accumulator_rows()
    }
}

/// Enumerate the valid schedule space for a layer (`mt` m-tiles, `kt`
/// K-tiles, `nt` N-tiles). This is the space AutoTVM would search.
///
/// Block sizes are capped at `mt`: an `mb > mt` candidate lowers to the
/// exact same stream as `mb = mt` (the block loop clamps to the tiles
/// that exist) but `sp_rows_needed` would charge scratchpad for the full
/// phantom block — on small scratchpads that over-rejected the only
/// whole-layer-in-one-block schedules a small-M layer has. Capping also
/// admits non-power-of-two `mb = mt` blocks (e.g. 3 tiles) that the
/// fixed palette never offered.
pub fn enumerate(cfg: &GemminiConfig, mt: usize, kt: usize, nt: usize) -> Vec<RiscSchedule> {
    let mt = mt.max(1);
    let mut out = Vec::new();
    let mut prev_mb = 0usize;
    for &mb in &[1usize, 2, 4, 8, 16] {
        let mb = mb.min(mt);
        if mb == prev_mb {
            continue; // capped duplicates collapse (palette is sorted)
        }
        prev_mb = mb;
        for &da in &[false, true] {
            for &db in &[false, true] {
                for &order in &[LoopOrder::NOuter, LoopOrder::KOuter] {
                    let s = RiscSchedule { mb, double_buffer_a: da, double_buffer_b: db, order };
                    if s.fits(cfg, kt, nt) {
                        out.push(s);
                    }
                }
            }
        }
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn space_nonempty_for_typical_layers() {
        let cfg = GemminiConfig::ours_zcu102();
        // 3×3×64→128 conv at 60×60: M=3600→mt=113, K=576→kt=18, N=128→nt=4.
        let s = enumerate(&cfg, 113, 18, 4);
        assert!(s.len() >= 8, "space size {}", s.len());
        // Always contains the trivial schedule.
        assert!(s.contains(&RiscSchedule {
            mb: 1,
            double_buffer_a: false,
            double_buffer_b: false,
            order: LoopOrder::NOuter
        }));
    }

    #[test]
    fn capacity_prunes_large_blocks() {
        let cfg = GemminiConfig::original_zcu102();
        // Huge K (first layers at 480²): kt = 64 → A blocks get big.
        let all = enumerate(&cfg, 1000, 64, 2);
        let max_mb = all.iter().map(|s| s.mb).max().unwrap();
        assert!(max_mb <= 8, "mb {max_mb} should be capacity-limited");
        // Small K: bigger blocks allowed.
        let small = enumerate(&cfg, 1000, 2, 2);
        assert!(small.iter().map(|s| s.mb).max().unwrap() >= max_mb);
    }

    #[test]
    fn small_m_layers_keep_whole_layer_blocks() {
        // dim=8, 8 KiB scratchpad → 1024 rows. A small-M layer (mt=3)
        // with kt=20: a double-buffered whole-layer block needs
        // 3·8·20·2 + 8 = 968 rows — it fits. The old fixed palette only
        // offered mb=4 (1288 rows, rejected), so the space lost every
        // double-buffered single-block candidate.
        let cfg = GemminiConfig {
            dim: 8,
            scratchpad_kib: 8,
            accumulator_kib: 16,
            ..GemminiConfig::original_zcu102()
        };
        let (mt, kt, nt) = (3, 20, 2);
        let phantom =
            RiscSchedule { mb: 4, double_buffer_a: true, double_buffer_b: false, order: LoopOrder::NOuter };
        assert!(!phantom.fits(&cfg, kt, nt), "uncapped mb=4 must overflow");
        let space = enumerate(&cfg, mt, kt, nt);
        // Every candidate respects the cap…
        assert!(space.iter().all(|s| s.mb <= mt), "{space:?}");
        // …and the capped mb=mt double-buffered block is back.
        assert!(
            space.contains(&RiscSchedule {
                mb: mt,
                double_buffer_a: true,
                double_buffer_b: false,
                order: LoopOrder::NOuter
            }),
            "{space:?}"
        );
        // No duplicate candidates from the capped palette.
        let mut seen = std::collections::HashSet::new();
        assert!(space.iter().all(|s| seen.insert(*s)), "{space:?}");
    }

    #[test]
    fn kouter_constrained_by_accumulator() {
        let cfg = GemminiConfig::original_zcu102(); // 64 acc tiles @dim16
        let s = RiscSchedule {
            mb: 16,
            double_buffer_a: false,
            double_buffer_b: false,
            order: LoopOrder::KOuter,
        };
        // nt=8 → needs 128 tiles > 64.
        assert!(!s.fits(&cfg, 4, 8));
        let s2 = RiscSchedule { order: LoopOrder::NOuter, ..s };
        assert!(s2.fits(&cfg, 4, 8));
    }
}
