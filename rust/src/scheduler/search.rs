//! Schedule search (the AutoTVM loop of Section V-A).
//!
//! Strategy: enumerate the valid space, rank every candidate with the
//! analytic cost model, then *measure* the top `measure_k` candidates on
//! the cycle-approximate simulator and keep the best measurement — the
//! same explore-then-measure structure AutoTVM uses, with the simulator
//! standing in for the FPGA (DESIGN.md §2).

use crate::gemmini::config::GemminiConfig;
use crate::gemmini::memory::DramAllocator;
use crate::gemmini::sim::Simulator;
use crate::util::json::Json;

use super::codegen::{alloc_buffers, lower_cisc, lower_risc, ConvGeom};
use super::cost_model::{estimate_cisc, estimate_risc};
use super::space::{enumerate, RiscSchedule};

/// Result of tuning one layer.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct SearchResult {
    /// Cycles of the CISC default schedule (measured).
    pub default_cycles: u64,
    /// Best tuned cycles (measured); equals `default_cycles` when the
    /// fallback wins (the paper: "when the schedule using RISC-type
    /// instructions is not as good as the default one, we default to the
    /// CISC-type schedules").
    pub best_cycles: u64,
    /// The winning RISC schedule, `None` when CISC won.
    pub best_schedule: Option<RiscSchedule>,
    /// Candidates measured on the simulator.
    pub measured: usize,
    /// Size of the enumerated space.
    pub space_size: usize,
}

impl SearchResult {
    pub fn speedup(&self) -> f64 {
        self.default_cycles as f64 / self.best_cycles as f64
    }

    pub fn improved(&self) -> bool {
        self.best_cycles < self.default_cycles
    }

    pub fn to_json(&self, label: &str) -> Json {
        Json::obj(vec![
            ("layer", Json::Str(label.into())),
            ("default_cycles", Json::Num(self.default_cycles as f64)),
            ("best_cycles", Json::Num(self.best_cycles as f64)),
            ("speedup", Json::Num(self.speedup())),
            ("improved", Json::Bool(self.improved())),
            (
                "schedule",
                match &self.best_schedule {
                    Some(s) => Json::Str(format!("{s:?}")),
                    None => Json::Str("cisc-default".into()),
                },
            ),
        ])
    }
}

/// Simulated DRAM capacity for layer measurements (fits the largest
/// batched YOLOv7 GEMM with room to spare).
const MEASURE_DRAM_BYTES: usize = 1 << 28;

/// Reusable measurement state for schedule search: one timing-only
/// simulator shared across every candidate (and every layer) a tuning
/// worker measures, instead of reallocating the 256 MiB simulated DRAM
/// per candidate. Reuse is cycle-exact: `Simulator::run` measures from
/// the stream's own start and all residual hazard state is bounded by
/// the previous stream's horizon (see `gemmini::sim` module docs).
/// `sim_instrs` accumulates instructions simulated through this context —
/// the deterministic work proxy the tuning engine's perf gate checks.
pub struct MeasureCtx {
    cfg: GemminiConfig,
    sim: Simulator,
    /// Instructions simulated (post CISC expansion) since construction.
    pub sim_instrs: u64,
}

impl MeasureCtx {
    pub fn new(cfg: &GemminiConfig) -> Self {
        Self {
            cfg: cfg.clone(),
            sim: Simulator::new(cfg.clone(), MEASURE_DRAM_BYTES),
            sim_instrs: 0,
        }
    }

    /// Measure one schedule (timing-only).
    fn measure(
        &mut self,
        geom: &ConvGeom,
        bufs: &super::codegen::LayerBuffers,
        sched: Option<&RiscSchedule>,
    ) -> u64 {
        let stream = match sched {
            Some(s) => lower_risc(&self.cfg, geom, bufs, s),
            None => lower_cisc(geom, bufs),
        };
        let res = self.sim.run(&stream);
        self.sim_instrs += res.instrs;
        res.cycles
    }
}

/// Tune one layer: cost-model ranking + top-k measurement + CISC fallback.
pub fn tune_layer(cfg: &GemminiConfig, geom: &ConvGeom, measure_k: usize) -> SearchResult {
    tune_layer_with(&mut MeasureCtx::new(cfg), geom, measure_k)
}

/// [`tune_layer`] against a caller-owned [`MeasureCtx`] (the tuning
/// engine keeps one per worker thread so simulator state is reused across
/// layers).
pub fn tune_layer_with(
    ctx: &mut MeasureCtx,
    geom: &ConvGeom,
    measure_k: usize,
) -> SearchResult {
    // Buffers are allocated once per layer from a fresh bump allocator,
    // so every candidate (and every layer) sees identical addresses.
    let mut alloc = DramAllocator::new(MEASURE_DRAM_BYTES);
    let bufs = alloc_buffers(geom, &mut alloc);
    let default_cycles = ctx.measure(geom, &bufs, None);
    let dim = ctx.cfg.dim;
    let space = enumerate(&ctx.cfg, geom.kt(dim), geom.nt(dim));
    let mut ranked: Vec<(f64, RiscSchedule)> =
        space.iter().map(|s| (estimate_risc(&ctx.cfg, geom, s), *s)).collect();
    ranked.sort_by(|a, b| a.0.partial_cmp(&b.0).unwrap());
    // Skip measuring candidates the model says are far worse than CISC.
    let cisc_est = estimate_cisc(&ctx.cfg, geom);
    let mut best_cycles = default_cycles;
    let mut best_schedule = None;
    let mut measured = 0;
    for (est, s) in ranked.iter().take(measure_k) {
        if *est > 3.0 * cisc_est {
            break;
        }
        let cycles = ctx.measure(geom, &bufs, Some(s));
        measured += 1;
        if cycles < best_cycles {
            best_cycles = cycles;
            best_schedule = Some(*s);
        }
    }
    SearchResult { default_cycles, best_cycles, best_schedule, measured, space_size: space.len() }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::gemmini::isa::Activation;

    fn small_cfg() -> GemminiConfig {
        GemminiConfig { dim: 8, scratchpad_kib: 32, accumulator_kib: 16, ..GemminiConfig::original_zcu102() }
    }

    fn geom(m: usize, n: usize, k: usize, kernel: usize) -> ConvGeom {
        ConvGeom {
            m,
            n,
            k,
            kernel,
            scale: 1.0,
            activation: Activation::None,
            bias: false,
            label: format!("gemm{m}x{n}x{k}"),
        }
    }

    #[test]
    fn tuned_never_worse_than_default() {
        let cfg = small_cfg();
        for g in [geom(64, 16, 32, 1), geom(16, 8, 72, 3), geom(256, 8, 8, 1)] {
            let r = tune_layer(&cfg, &g, 6);
            assert!(r.best_cycles <= r.default_cycles, "{}: {r:?}", g.label);
            assert!(r.speedup() >= 1.0);
        }
    }

    #[test]
    fn reuse_heavy_layer_improves_substantially() {
        // Large M (conv over many pixels): block caching should win big.
        let cfg = small_cfg();
        let r = tune_layer(&cfg, &geom(512, 16, 32, 3), 8);
        assert!(r.improved(), "{r:?}");
        assert!(r.speedup() > 1.2, "speedup {}", r.speedup());
        assert!(r.best_schedule.is_some());
    }

    #[test]
    fn reused_context_matches_fresh_measurements() {
        // One simulator reused across layers and candidates must be
        // cycle-identical to the fresh-simulator-per-measurement path.
        let cfg = small_cfg();
        let mut ctx = MeasureCtx::new(&cfg);
        for g in [geom(64, 16, 32, 1), geom(16, 8, 72, 3), geom(256, 8, 8, 1)] {
            let shared = tune_layer_with(&mut ctx, &g, 4);
            let fresh = tune_layer(&cfg, &g, 4);
            assert_eq!(shared.default_cycles, fresh.default_cycles, "{}", g.label);
            assert_eq!(shared.best_cycles, fresh.best_cycles, "{}", g.label);
            assert_eq!(shared.best_schedule, fresh.best_schedule, "{}", g.label);
            assert_eq!(shared.measured, fresh.measured, "{}", g.label);
        }
        assert!(ctx.sim_instrs > 0);
    }

    #[test]
    fn search_result_serializes() {
        let cfg = small_cfg();
        let r = tune_layer(&cfg, &geom(32, 8, 16, 1), 3);
        let j = r.to_json("conv_1");
        let s = j.dump();
        assert!(s.contains("conv_1"));
        assert!(Json::parse(&s).is_ok());
    }
}
