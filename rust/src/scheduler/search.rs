//! Schedule search (the AutoTVM loop of Section V-A).
//!
//! Strategy: enumerate the valid space, rank every candidate with the
//! analytical pre-filter ([`super::prefilter`]), then *measure* the top
//! `measure_k` candidates on the cycle-approximate simulator and keep
//! the best measurement — the same explore-then-measure structure
//! AutoTVM uses, with the simulator standing in for the FPGA
//! (DESIGN.md §2). [`tune_layer_transfer`] is the transfer-tuning
//! variant: the shortlist is seeded from a neighboring cached winner
//! instead of the full top-k.

use crate::gemmini::config::GemminiConfig;
use crate::gemmini::memory::DramAllocator;
use crate::gemmini::sim::Simulator;
use crate::util::json::Json;

use super::codegen::{alloc_buffers, lower_cisc, lower_risc, ConvGeom};
use super::prefilter;
use super::space::{enumerate, RiscSchedule};

/// Result of tuning one layer.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct SearchResult {
    /// Cycles of the CISC default schedule (measured, unless
    /// `default_est` says otherwise).
    pub default_cycles: u64,
    /// Best tuned cycles (measured); equals `default_cycles` when the
    /// fallback wins (the paper: "when the schedule using RISC-type
    /// instructions is not as good as the default one, we default to the
    /// CISC-type schedules").
    pub best_cycles: u64,
    /// The winning RISC schedule, `None` when CISC won.
    pub best_schedule: Option<RiscSchedule>,
    /// Candidates measured on the simulator.
    pub measured: usize,
    /// Size of the enumerated space.
    pub space_size: usize,
    /// `default_cycles` is a transfer-scaled *estimate* carried over from
    /// the donor geometry, not a measurement ([`tune_layer_transfer`]'s
    /// decisive-donor skip). Always `false` on the full-search path.
    pub default_est: bool,
}

impl SearchResult {
    pub fn speedup(&self) -> f64 {
        self.default_cycles as f64 / self.best_cycles as f64
    }

    pub fn improved(&self) -> bool {
        self.best_cycles < self.default_cycles
    }

    pub fn to_json(&self, label: &str) -> Json {
        Json::obj(vec![
            ("layer", Json::Str(label.into())),
            ("default_cycles", Json::Num(self.default_cycles as f64)),
            ("best_cycles", Json::Num(self.best_cycles as f64)),
            ("speedup", Json::Num(self.speedup())),
            ("improved", Json::Bool(self.improved())),
            (
                "schedule",
                match &self.best_schedule {
                    Some(s) => Json::Str(format!("{s:?}")),
                    None => Json::Str("cisc-default".into()),
                },
            ),
            ("default_est", Json::Bool(self.default_est)),
        ])
    }
}

/// Simulated DRAM capacity for layer measurements (fits the largest
/// batched YOLOv7 GEMM with room to spare).
const MEASURE_DRAM_BYTES: usize = 1 << 28;

/// Reusable measurement state for schedule search: one timing-only
/// simulator shared across every candidate (and every layer) a tuning
/// worker measures, instead of reallocating the 256 MiB simulated DRAM
/// per candidate. Reuse is cycle-exact: `Simulator::run` measures from
/// the stream's own start and all residual hazard state is bounded by
/// the previous stream's horizon (see `gemmini::sim` module docs).
/// `sim_instrs` accumulates instructions simulated through this context —
/// the deterministic work proxy the tuning engine's perf gate checks.
pub struct MeasureCtx {
    cfg: GemminiConfig,
    sim: Simulator,
    /// Instructions simulated (post CISC expansion) since construction.
    pub sim_instrs: u64,
}

impl MeasureCtx {
    pub fn new(cfg: &GemminiConfig) -> Self {
        Self {
            cfg: cfg.clone(),
            sim: Simulator::new(cfg.clone(), MEASURE_DRAM_BYTES),
            sim_instrs: 0,
        }
    }

    /// Measure one schedule (timing-only).
    fn measure(
        &mut self,
        geom: &ConvGeom,
        bufs: &super::codegen::LayerBuffers,
        sched: Option<&RiscSchedule>,
    ) -> u64 {
        let stream = match sched {
            Some(s) => lower_risc(&self.cfg, geom, bufs, s),
            None => lower_cisc(geom, bufs),
        };
        let res = self.sim.run(&stream);
        self.sim_instrs += res.instrs;
        res.cycles
    }
}

/// Tune one layer: cost-model ranking + top-k measurement + CISC fallback.
pub fn tune_layer(cfg: &GemminiConfig, geom: &ConvGeom, measure_k: usize) -> SearchResult {
    tune_layer_with(&mut MeasureCtx::new(cfg), geom, measure_k)
}

/// [`tune_layer`] against a caller-owned [`MeasureCtx`] (the tuning
/// engine keeps one per worker thread so simulator state is reused across
/// layers).
pub fn tune_layer_with(
    ctx: &mut MeasureCtx,
    geom: &ConvGeom,
    measure_k: usize,
) -> SearchResult {
    // Buffers are allocated once per layer from a fresh bump allocator,
    // so every candidate (and every layer) sees identical addresses.
    let mut alloc = DramAllocator::new(MEASURE_DRAM_BYTES);
    let bufs = alloc_buffers(geom, &mut alloc);
    let default_cycles = ctx.measure(geom, &bufs, None);
    let dim = ctx.cfg.dim;
    let space = enumerate(&ctx.cfg, geom.mt(dim), geom.kt(dim), geom.nt(dim));
    // Rank the whole space through the hierarchy model (NaN-safe,
    // tie-stable — see `prefilter::sort_ranked`).
    let ranked = prefilter::rank(&ctx.cfg, geom, &space);
    // Skip measuring candidates the model says are far worse than CISC.
    let cisc_est = prefilter::estimate_default(&ctx.cfg, geom);
    let mut best_cycles = default_cycles;
    let mut best_schedule = None;
    let mut measured = 0;
    for (est, s) in ranked.iter().take(measure_k) {
        if *est > 3.0 * cisc_est {
            break;
        }
        let cycles = ctx.measure(geom, &bufs, Some(s));
        measured += 1;
        if cycles < best_cycles {
            best_cycles = cycles;
            best_schedule = Some(*s);
        }
    }
    SearchResult {
        default_cycles,
        best_cycles,
        best_schedule,
        measured,
        space_size: space.len(),
        default_est: false,
    }
}

/// A seed for transfer tuning: the cached result of the *donor* — the
/// nearest previously-tuned neighbor of the target point (same
/// [`super::cache::GeomKey`] modulo m-scaling on the same config, or the
/// same geometry on a sibling config fingerprint).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct TransferSeed {
    /// The donor's winning RISC schedule (`None` when CISC won there).
    pub schedule: Option<RiscSchedule>,
    /// The donor's measured CISC default cycles.
    pub donor_default: u64,
    /// The donor's best measured cycles.
    pub donor_best: u64,
    /// The donor's GEMM m dimension (for m-scaling the default estimate).
    pub donor_m: usize,
    /// Donor differs from the target only in `m` on the same config —
    /// its cycle counts scale with the m-tile count, so a decisively-won
    /// donor lets us skip re-measuring the CISC default.
    pub scalable: bool,
}

/// What [`tune_layer_transfer`] measured: the result plus the exact
/// candidate shortlist, so the engine's audit mode can score whether the
/// full-search winner was in it (the ranker hit-rate of `EngineStats`).
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct TransferOutcome {
    pub result: SearchResult,
    /// RISC candidates measured, in pre-filter rank order.
    pub shortlist: Vec<RiscSchedule>,
}

/// How decisively the donor's RISC winner must have beaten its CISC
/// default before we trust an m-scaled estimate instead of re-measuring
/// the default at the target point.
const TRANSFER_DECISIVE_MARGIN: f64 = 1.25;

/// Transfer-tune one layer: instead of measuring the pre-filter's full
/// top-k, measure a two-candidate shortlist — the pre-filter's top pick
/// and the best-ranked schedule carrying the donor winner's
/// double-buffering/loop-order combination (the target re-derives the
/// block size from its own ranking) — and, when the donor won decisively
/// on a same-config m-neighbor, skip re-measuring the CISC default and
/// carry an m-scaled estimate (`SearchResult::default_est`).
///
/// Shortlist candidates are measured in pre-filter rank order with the
/// same strict-improvement rule as [`tune_layer_with`], so whenever the
/// shortlist contains the full search's winner the returned schedule
/// (and its measured cycles) are byte-identical to the full path.
pub fn tune_layer_transfer(
    ctx: &mut MeasureCtx,
    geom: &ConvGeom,
    seed: &TransferSeed,
) -> TransferOutcome {
    let mut alloc = DramAllocator::new(MEASURE_DRAM_BYTES);
    let bufs = alloc_buffers(geom, &mut alloc);
    let dim = ctx.cfg.dim;
    let (mt, kt, nt) = (geom.mt(dim), geom.kt(dim), geom.nt(dim));
    let space = enumerate(&ctx.cfg, mt, kt, nt);
    let ranked = prefilter::rank(&ctx.cfg, geom, &space);

    // Candidate set: the pre-filter's top pick, plus the first ranked
    // schedule sharing the donor winner's (double-buffer, loop-order)
    // combination. The donor's literal block size is its *own* mt-cap
    // and rarely exists in the target's mb palette; what transfers is
    // the buffering/loop-order choice, and the target re-derives the
    // block size from its own ranking (within a combination the ranking
    // orders block sizes the same way the simulator does). Walking
    // `ranked` keeps rank order and dedups when the top pick already
    // carries the donor's combination.
    let combo = |s: &RiscSchedule| (s.double_buffer_a, s.double_buffer_b, s.order);
    let donor_combo = seed.schedule.map(|s| combo(&s));
    let mut shortlist: Vec<RiscSchedule> = Vec::new();
    let mut combo_taken = false;
    for (i, (_, s)) in ranked.iter().enumerate() {
        if i == 0 {
            shortlist.push(*s);
            combo_taken = donor_combo == Some(combo(s));
        } else if !combo_taken && donor_combo == Some(combo(s)) {
            shortlist.push(*s);
            combo_taken = true;
        }
    }

    let mut best_risc: Option<(u64, RiscSchedule)> = None;
    let mut measured = 0;
    for s in &shortlist {
        let cycles = ctx.measure(geom, &bufs, Some(s));
        measured += 1;
        let better = match best_risc {
            Some((b, _)) => cycles < b,
            None => true,
        };
        if better {
            best_risc = Some((cycles, *s));
        }
    }

    // Decisive donor on a same-config m-neighbor: its default-vs-best
    // ratio transfers, so estimate the target default by m-tile scaling
    // instead of simulating the (expensive, ~3× a RISC stream) CISC
    // expansion. The estimate is only trusted while it loses to the
    // measured RISC winner — if it would *win*, fall back to measuring.
    let donor_mt = seed.donor_m.div_ceil(dim).max(1);
    let decisive = seed.scalable
        && seed.schedule.is_some()
        && seed.donor_default as f64 >= TRANSFER_DECISIVE_MARGIN * seed.donor_best as f64;
    let est_default = (seed.donor_default as f64 * mt as f64 / donor_mt as f64).round() as u64;
    let (default_cycles, default_est) = match (decisive, best_risc) {
        (true, Some((best, _))) if best < est_default => (est_default, true),
        _ => (ctx.measure(geom, &bufs, None), false),
    };

    // CISC fallback exactly as the full path: the default wins ties.
    let (best_cycles, best_schedule) = match best_risc {
        Some((cycles, s)) if cycles < default_cycles => (cycles, Some(s)),
        _ => (default_cycles, None),
    };
    TransferOutcome {
        result: SearchResult {
            default_cycles,
            best_cycles,
            best_schedule,
            measured,
            space_size: space.len(),
            default_est,
        },
        shortlist,
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::gemmini::isa::Activation;

    fn small_cfg() -> GemminiConfig {
        GemminiConfig { dim: 8, scratchpad_kib: 32, accumulator_kib: 16, ..GemminiConfig::original_zcu102() }
    }

    fn geom(m: usize, n: usize, k: usize, kernel: usize) -> ConvGeom {
        ConvGeom {
            m,
            n,
            k,
            kernel,
            scale: 1.0,
            activation: Activation::None,
            bias: false,
            label: format!("gemm{m}x{n}x{k}"),
        }
    }

    #[test]
    fn tuned_never_worse_than_default() {
        let cfg = small_cfg();
        for g in [geom(64, 16, 32, 1), geom(16, 8, 72, 3), geom(256, 8, 8, 1)] {
            let r = tune_layer(&cfg, &g, 6);
            assert!(r.best_cycles <= r.default_cycles, "{}: {r:?}", g.label);
            assert!(r.speedup() >= 1.0);
        }
    }

    #[test]
    fn reuse_heavy_layer_improves_substantially() {
        // Large M (conv over many pixels): block caching should win big.
        let cfg = small_cfg();
        let r = tune_layer(&cfg, &geom(512, 16, 32, 3), 8);
        assert!(r.improved(), "{r:?}");
        assert!(r.speedup() > 1.2, "speedup {}", r.speedup());
        assert!(r.best_schedule.is_some());
    }

    #[test]
    fn reused_context_matches_fresh_measurements() {
        // One simulator reused across layers and candidates must be
        // cycle-identical to the fresh-simulator-per-measurement path.
        let cfg = small_cfg();
        let mut ctx = MeasureCtx::new(&cfg);
        for g in [geom(64, 16, 32, 1), geom(16, 8, 72, 3), geom(256, 8, 8, 1)] {
            let shared = tune_layer_with(&mut ctx, &g, 4);
            let fresh = tune_layer(&cfg, &g, 4);
            assert_eq!(shared.default_cycles, fresh.default_cycles, "{}", g.label);
            assert_eq!(shared.best_cycles, fresh.best_cycles, "{}", g.label);
            assert_eq!(shared.best_schedule, fresh.best_schedule, "{}", g.label);
            assert_eq!(shared.measured, fresh.measured, "{}", g.label);
        }
        assert!(ctx.sim_instrs > 0);
    }

    #[test]
    fn search_result_serializes() {
        let cfg = small_cfg();
        let r = tune_layer(&cfg, &geom(32, 8, 16, 1), 3);
        assert!(!r.default_est, "full search always measures the default");
        let j = r.to_json("conv_1");
        let s = j.dump();
        assert!(s.contains("conv_1"));
        assert!(Json::parse(&s).is_ok());
    }

    #[test]
    fn transfer_matches_full_search_on_shortlist_hits() {
        // Tune a donor, then transfer-tune an m-scaled sibling. Whenever
        // the shortlist contains the full search's winner, the transfer
        // result must be byte-identical to the full path's.
        let cfg = small_cfg();
        let donor_geom = geom(512, 16, 32, 3);
        let donor = tune_layer(&cfg, &donor_geom, 8);
        assert!(donor.best_schedule.is_some(), "{donor:?}");
        let target = geom(1024, 16, 32, 3);
        let seed = TransferSeed {
            schedule: donor.best_schedule,
            donor_default: donor.default_cycles,
            donor_best: donor.best_cycles,
            donor_m: donor_geom.m,
            scalable: true,
        };
        let mut ctx = MeasureCtx::new(&cfg);
        let out = tune_layer_transfer(&mut ctx, &target, &seed);
        assert!(!out.shortlist.is_empty());
        assert!(out.shortlist.len() <= 2, "{:?}", out.shortlist);
        assert_eq!(out.result.measured, out.shortlist.len());
        assert!(out.result.best_cycles <= out.result.default_cycles);
        let full = tune_layer(&cfg, &target, 8);
        if let Some(w) = full.best_schedule {
            if out.shortlist.contains(&w) {
                assert_eq!(out.result.best_schedule, full.best_schedule);
                assert_eq!(out.result.best_cycles, full.best_cycles);
            }
        }
        // A decisive donor skips the CISC default measurement and scales
        // its estimate by the m-tile ratio instead.
        if out.result.default_est {
            assert!(donor.default_cycles as f64 >= 1.25 * donor.best_cycles as f64);
            let scaled = (donor.default_cycles as f64 * target.mt(cfg.dim) as f64
                / donor_geom.mt(cfg.dim) as f64)
                .round() as u64;
            assert_eq!(out.result.default_cycles, scaled);
        }
    }

    #[test]
    fn transfer_without_donor_schedule_measures_default() {
        // A donor that fell back to CISC cannot seed a schedule; the
        // transfer path must still measure the default and return a
        // valid (possibly CISC-winning) result.
        let cfg = small_cfg();
        let target = geom(64, 16, 32, 1);
        let seed = TransferSeed {
            schedule: None,
            donor_default: 10_000,
            donor_best: 10_000,
            donor_m: 64,
            scalable: true,
        };
        let mut ctx = MeasureCtx::new(&cfg);
        let out = tune_layer_transfer(&mut ctx, &target, &seed);
        assert!(!out.result.default_est);
        assert!(out.result.best_cycles <= out.result.default_cycles);
        // Shortlist degrades to the pre-filter's top pick alone.
        assert_eq!(out.shortlist.len(), 1);
    }
}
