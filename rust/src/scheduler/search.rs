//! Schedule search (the AutoTVM loop of Section V-A).
//!
//! Strategy: enumerate the valid space, rank every candidate with the
//! analytic cost model, then *measure* the top `measure_k` candidates on
//! the cycle-approximate simulator and keep the best measurement — the
//! same explore-then-measure structure AutoTVM uses, with the simulator
//! standing in for the FPGA (DESIGN.md §2).

use crate::gemmini::config::GemminiConfig;
use crate::gemmini::memory::DramAllocator;
use crate::gemmini::sim::Simulator;
use crate::util::json::Json;

use super::codegen::{alloc_buffers, lower_cisc, lower_risc, ConvGeom};
use super::cost_model::{estimate_cisc, estimate_risc};
use super::space::{enumerate, RiscSchedule};

/// Result of tuning one layer.
#[derive(Debug, Clone)]
pub struct SearchResult {
    /// Cycles of the CISC default schedule (measured).
    pub default_cycles: u64,
    /// Best tuned cycles (measured); equals `default_cycles` when the
    /// fallback wins (the paper: "when the schedule using RISC-type
    /// instructions is not as good as the default one, we default to the
    /// CISC-type schedules").
    pub best_cycles: u64,
    /// The winning RISC schedule, `None` when CISC won.
    pub best_schedule: Option<RiscSchedule>,
    /// Candidates measured on the simulator.
    pub measured: usize,
    /// Size of the enumerated space.
    pub space_size: usize,
}

impl SearchResult {
    pub fn speedup(&self) -> f64 {
        self.default_cycles as f64 / self.best_cycles as f64
    }

    pub fn improved(&self) -> bool {
        self.best_cycles < self.default_cycles
    }

    pub fn to_json(&self, label: &str) -> Json {
        Json::obj(vec![
            ("layer", Json::Str(label.into())),
            ("default_cycles", Json::Num(self.default_cycles as f64)),
            ("best_cycles", Json::Num(self.best_cycles as f64)),
            ("speedup", Json::Num(self.speedup())),
            ("improved", Json::Bool(self.improved())),
            (
                "schedule",
                match &self.best_schedule {
                    Some(s) => Json::Str(format!("{s:?}")),
                    None => Json::Str("cisc-default".into()),
                },
            ),
        ])
    }
}

/// Measure one schedule on a fresh simulator (timing-only).
fn measure(cfg: &GemminiConfig, geom: &ConvGeom, sched: Option<&RiscSchedule>) -> u64 {
    let mut alloc = DramAllocator::new(1 << 28);
    let bufs = alloc_buffers(geom, &mut alloc);
    let mut sim = Simulator::new(cfg.clone(), 1 << 28);
    let stream = match sched {
        Some(s) => lower_risc(cfg, geom, &bufs, s),
        None => lower_cisc(geom, &bufs),
    };
    sim.run(&stream).cycles
}

/// Tune one layer: cost-model ranking + top-k measurement + CISC fallback.
pub fn tune_layer(cfg: &GemminiConfig, geom: &ConvGeom, measure_k: usize) -> SearchResult {
    let default_cycles = measure(cfg, geom, None);
    let space = enumerate(cfg, geom.kt(cfg.dim), geom.nt(cfg.dim));
    let mut ranked: Vec<(f64, RiscSchedule)> =
        space.iter().map(|s| (estimate_risc(cfg, geom, s), *s)).collect();
    ranked.sort_by(|a, b| a.0.partial_cmp(&b.0).unwrap());
    // Skip measuring candidates the model says are far worse than CISC.
    let cisc_est = estimate_cisc(cfg, geom);
    let mut best_cycles = default_cycles;
    let mut best_schedule = None;
    let mut measured = 0;
    for (est, s) in ranked.iter().take(measure_k) {
        if *est > 3.0 * cisc_est {
            break;
        }
        let cycles = measure(cfg, geom, Some(s));
        measured += 1;
        if cycles < best_cycles {
            best_cycles = cycles;
            best_schedule = Some(*s);
        }
    }
    SearchResult { default_cycles, best_cycles, best_schedule, measured, space_size: space.len() }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::gemmini::isa::Activation;

    fn small_cfg() -> GemminiConfig {
        GemminiConfig { dim: 8, scratchpad_kib: 32, accumulator_kib: 16, ..GemminiConfig::original_zcu102() }
    }

    fn geom(m: usize, n: usize, k: usize, kernel: usize) -> ConvGeom {
        ConvGeom {
            m,
            n,
            k,
            kernel,
            scale: 1.0,
            activation: Activation::None,
            bias: false,
            label: format!("gemm{m}x{n}x{k}"),
        }
    }

    #[test]
    fn tuned_never_worse_than_default() {
        let cfg = small_cfg();
        for g in [geom(64, 16, 32, 1), geom(16, 8, 72, 3), geom(256, 8, 8, 1)] {
            let r = tune_layer(&cfg, &g, 6);
            assert!(r.best_cycles <= r.default_cycles, "{}: {r:?}", g.label);
            assert!(r.speedup() >= 1.0);
        }
    }

    #[test]
    fn reuse_heavy_layer_improves_substantially() {
        // Large M (conv over many pixels): block caching should win big.
        let cfg = small_cfg();
        let r = tune_layer(&cfg, &geom(512, 16, 32, 3), 8);
        assert!(r.improved(), "{r:?}");
        assert!(r.speedup() > 1.2, "speedup {}", r.speedup());
        assert!(r.best_schedule.is_some());
    }

    #[test]
    fn search_result_serializes() {
        let cfg = small_cfg();
        let r = tune_layer(&cfg, &geom(32, 8, 16, 1), 3);
        let j = r.to_json("conv_1");
        let s = j.dump();
        assert!(s.contains("conv_1"));
        assert!(Json::parse(&s).is_ok());
    }
}
