//! FactorFlow-style analytical pre-filter (ROADMAP item 4).
//!
//! AutoTVM measures every candidate on hardware; we rank the whole
//! schedule space analytically and only *measure* a short top-k list
//! ([`super::search`]). This module is that ranking stage, modelled the
//! way FactorFlow models a spatial architecture: each level of the
//! Gemmini memory hierarchy ([`MemLevel`], derived from
//! [`GemminiConfig`]) contributes bytes moved against its bandwidth
//! ceiling, per-access latency amortized over its in-flight window, and
//! a capacity feasibility constraint, instead of one opaque formula.
//!
//! The hierarchy as the pre-filter sees it:
//!
//! * **DRAM → scratchpad / accumulator** ([`GemminiConfig::dram_level`])
//!   — every mvin/mvout occupies the bus for `bytes / bytes_per_cycle`
//!   plus one issue beat per row, and pays the DRAM round-trip latency
//!   pipelined over the DMA's in-flight request window.
//! * **Scratchpad → PE array** ([`GemminiConfig::scratchpad_level`]) —
//!   each full B-tile preload streams [`GemminiConfig::pe_fanout`] rows
//!   and pays the scratchpad read latency; `REUSE_WEIGHTS` preloads
//!   ([`super::codegen`]) collapse to a single issue beat.
//! * **Accumulator** ([`GemminiConfig::accumulator_level`]) — bounds how
//!   many output tiles a `KOuter` schedule may keep live (feasibility is
//!   checked by [`RiscSchedule::fits`]) and drains to DRAM in a burst at
//!   block end, which the `KOuter` penalty term charges.
//!
//! Numerically the combined estimate is calibrated against the
//! cycle-approximate simulator (see the rank-correlation tests in
//! [`super::cost_model`]); the legacy `estimate_risc`/`estimate_cisc`
//! entry points delegate here so every caller ranks with one model.

use crate::gemmini::config::{GemminiConfig, MemLevel};

use super::codegen::ConvGeom;
use super::space::{LoopOrder, RiscSchedule};

/// Traffic one schedule pushes through one memory level.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct LevelUse {
    /// The level the traffic crosses.
    pub level: MemLevel,
    /// Payload bytes moved across the level.
    pub bytes: f64,
    /// Discrete requests issued (mvin/mvout instructions).
    pub requests: f64,
    /// Rows issued (each row costs one issue beat on the link).
    pub rows: f64,
}

impl LevelUse {
    /// Cycles the link itself is busy: transfer time against the
    /// bandwidth ceiling plus one issue beat per row.
    pub fn occupancy_cycles(&self) -> f64 {
        self.bytes / self.level.bytes_per_cycle + self.rows
    }

    /// Cycles spent waiting on per-access latency, pipelined across the
    /// level's in-flight window.
    pub fn latency_cycles(&self) -> f64 {
        self.requests / self.level.in_flight * self.level.access_latency
    }

    /// Total estimated cycles this level contributes.
    pub fn cycles(&self) -> f64 {
        self.occupancy_cycles() + self.latency_cycles()
    }
}

/// Execute-pipe usage: rows streamed through the PE array plus B-tile
/// preload traffic out of the scratchpad.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct ExecUse {
    /// Rows issued through the systolic array (one row per cycle).
    pub compute_rows: f64,
    /// Full B-tile preloads (stream `pe_fanout` rows + scratchpad read
    /// latency each).
    pub full_preloads: f64,
    /// `REUSE_WEIGHTS` preloads (single issue beat each).
    pub reuse_preloads: f64,
    /// Cycles one full preload stalls the pipe: PE fanout rows plus the
    /// scratchpad level's access latency.
    pub preload_overhead: f64,
}

impl ExecUse {
    /// Total estimated execute-pipe cycles.
    pub fn cycles(&self) -> f64 {
        self.compute_rows + self.full_preloads * self.preload_overhead + self.reuse_preloads
    }
}

/// DRAM-level traffic of a RISC schedule: A loaded once (block caching),
/// B reloaded per block, bias and C streamed through the accumulator.
pub fn dram_use_risc(cfg: &GemminiConfig, g: &ConvGeom, s: &RiscSchedule) -> LevelUse {
    let dram = cfg.dram_level();
    let dim = cfg.dim as f64;
    let (mt, nt, kt) = (g.mt(cfg.dim), g.nt(cfg.dim), g.kt(cfg.dim));
    let blocks = mt.div_ceil(s.mb) as f64;

    let a_bytes = (g.m * g.k) as f64; // A loaded once (block caching)
    let b_bytes = blocks * (kt * nt) as f64 * dim * dim; // B reloaded per block
    let bias_bytes = if g.bias { blocks * (nt * s.mb) as f64 * dim * dim * 4.0 } else { 0.0 };
    let c_bytes = (g.m * g.n) as f64;

    // Each mvin/mvout pays one DRAM round-trip on the (serialized) DMA
    // timeline, plus extra batches when its row count exceeds the
    // in-flight window. A-tile mvins are fragmented by the conv kernel
    // into `kernel` strided requests of `dim.div_ceil(kernel)` rows each
    // (`codegen::emit_a_mvin`), so the batching term sees the *per-request
    // row count*, not the kernel size.
    let lat_batches = |rows: usize| (rows as f64 / dram.in_flight).ceil();
    let a_rows_per_req = cfg.dim.div_ceil(g.kernel.clamp(1, cfg.dim));
    let a_reqs = (mt * kt * g.kernel) as f64 * lat_batches(a_rows_per_req);
    let b_reqs = blocks * (kt * nt) as f64;
    let bias_reqs = if g.bias { blocks * (nt * s.mb) as f64 } else { 0.0 };
    let c_reqs = (mt * nt) as f64;

    LevelUse {
        level: dram,
        bytes: a_bytes + b_bytes + bias_bytes + c_bytes,
        requests: a_reqs + b_reqs + bias_reqs + c_reqs,
        rows: (g.m * kt) as f64 + b_reqs * dim + (mt * nt) as f64 * dim,
    }
}

/// Execute-pipe usage of a RISC schedule.
pub fn exec_use_risc(cfg: &GemminiConfig, g: &ConvGeom, s: &RiscSchedule) -> ExecUse {
    let (mt, nt, kt) = (g.mt(cfg.dim), g.nt(cfg.dim), g.kt(cfg.dim));
    let blocks = mt.div_ceil(s.mb) as f64;
    let sp = cfg.scratchpad_level();
    let full_preloads = blocks * (kt * nt) as f64;
    ExecUse {
        compute_rows: (g.m * kt * nt) as f64,
        full_preloads,
        reuse_preloads: full_preloads * (s.mb as f64 - 1.0),
        preload_overhead: cfg.pe_fanout() as f64 + sp.access_latency,
    }
}

/// Estimated cycles for a RISC schedule: per-level contributions combined
/// with an overlap model (how much of the DMA timeline hides behind
/// compute) plus contention penalties the levels expose.
pub fn estimate_schedule(cfg: &GemminiConfig, g: &ConvGeom, s: &RiscSchedule) -> f64 {
    let dram = dram_use_risc(cfg, g, s);
    let exec = exec_use_risc(cfg, g, s);
    let dma_cycles = dram.cycles();
    let exec_cycles = exec.cycles();

    // Fully double-buffered: max of the two engines. Single-buffered: the
    // block's load and compute phases serialize.
    let overlap = match (s.double_buffer_a, s.double_buffer_b) {
        (true, true) => 0.95,
        (true, false) | (false, true) => 0.6,
        (false, false) => 0.25,
    };
    let serial = dma_cycles + exec_cycles;
    let ideal = dma_cycles.max(exec_cycles);
    let mut est = ideal + (serial - ideal) * (1.0 - overlap);
    // Single scratchpad port: loads and computes contend for the level.
    if cfg.scratchpad_level().in_flight < 2.0 {
        est += 0.5 * dma_cycles.min(exec_cycles);
    }
    // KOuter keeps more accumulator tiles live; the accumulator drains to
    // DRAM in a burst at block end that serializes against the last
    // computes.
    if matches!(s.order, LoopOrder::KOuter) {
        let (mt, nt) = (g.mt(cfg.dim), g.nt(cfg.dim));
        let blocks = mt.div_ceil(s.mb) as f64;
        est += (mt * nt) as f64 / blocks * cfg.dram_level().access_latency * 0.25;
    }
    est
}

/// Estimated cycles for the CISC default schedule (single-buffered FSM,
/// A reloaded per n-tile, B reloaded per output tile, one accumulator
/// tile live).
pub fn estimate_default(cfg: &GemminiConfig, g: &ConvGeom) -> f64 {
    let dram = cfg.dram_level();
    let dim = cfg.dim as f64;
    let (mt, nt, kt) = (g.mt(cfg.dim), g.nt(cfg.dim), g.kt(cfg.dim));
    let bias_reqs = if g.bias { (mt * nt) as f64 } else { 0.0 };
    let link = LevelUse {
        level: dram,
        bytes: (g.m * g.k * nt) as f64 + (mt * nt * kt) as f64 * dim * dim + (g.m * g.n) as f64,
        requests: (mt * kt * g.kernel * nt + mt * nt * kt + mt * nt) as f64 + bias_reqs,
        rows: (g.m * kt * nt) as f64
            + (mt * nt * kt) as f64 * dim
            + (mt * nt) as f64 * dim,
    };
    let exec = ExecUse {
        compute_rows: (g.m * kt * nt) as f64,
        full_preloads: (mt * nt * kt) as f64,
        reuse_preloads: 0.0,
        preload_overhead: cfg.pe_fanout() as f64 + cfg.scratchpad_level().access_latency,
    };
    // Single-buffered FSM: very little overlap.
    link.cycles() + exec.cycles() * 0.85
}

/// Total order over schedules used to break estimate ties: ranking must
/// be byte-stable regardless of enumeration order or thread count.
fn sched_key(s: &RiscSchedule) -> (usize, bool, bool, u8) {
    let order = match s.order {
        LoopOrder::NOuter => 0u8,
        LoopOrder::KOuter => 1u8,
    };
    (s.mb, s.double_buffer_a, s.double_buffer_b, order)
}

/// Sort `(estimate, schedule)` pairs by estimate. Uses `f64::total_cmp`
/// so a NaN estimate from a degenerate config cannot panic the tuning
/// worker (NaN sorts last), and breaks exact-estimate ties with a
/// deterministic schedule key.
pub fn sort_ranked(ranked: &mut [(f64, RiscSchedule)]) {
    ranked.sort_by(|a, b| {
        a.0.total_cmp(&b.0).then_with(|| sched_key(&a.1).cmp(&sched_key(&b.1)))
    });
}

/// Rank a schedule space for a layer: estimate every candidate through
/// the hierarchy model and sort best-first (NaN-safe, tie-stable).
pub fn rank(cfg: &GemminiConfig, g: &ConvGeom, space: &[RiscSchedule]) -> Vec<(f64, RiscSchedule)> {
    let mut ranked: Vec<(f64, RiscSchedule)> =
        space.iter().map(|s| (estimate_schedule(cfg, g, s), *s)).collect();
    sort_ranked(&mut ranked);
    ranked
}

/// The measurement shortlist: the top `k` ranked candidates.
pub fn shortlist(
    cfg: &GemminiConfig,
    g: &ConvGeom,
    space: &[RiscSchedule],
    k: usize,
) -> Vec<(f64, RiscSchedule)> {
    let mut ranked = rank(cfg, g, space);
    ranked.truncate(k);
    ranked
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::gemmini::isa::Activation;
    use crate::scheduler::space::enumerate;

    fn geom(m: usize, n: usize, k: usize, kernel: usize) -> ConvGeom {
        ConvGeom {
            m,
            n,
            k,
            kernel,
            scale: 1.0,
            activation: Activation::None,
            bias: false,
            label: "t".into(),
        }
    }

    #[test]
    fn level_use_accounts_bandwidth_and_latency() {
        let cfg = GemminiConfig::original_zcu102();
        let g = geom(128, 32, 64, 1);
        let s = RiscSchedule {
            mb: 2,
            double_buffer_a: false,
            double_buffer_b: false,
            order: LoopOrder::NOuter,
        };
        let u = dram_use_risc(&cfg, &g, &s);
        assert_eq!(u.level.name, "dram");
        assert!(u.bytes > 0.0 && u.requests > 0.0 && u.rows > 0.0);
        // Halving the bus bandwidth strictly increases occupancy cycles.
        let slow = GemminiConfig { ddr_gbs: cfg.ddr_gbs / 2.0, ..cfg.clone() };
        let su = dram_use_risc(&slow, &g, &s);
        assert!(su.occupancy_cycles() > u.occupancy_cycles());
        // Halving the in-flight window strictly increases latency cycles.
        let narrow = GemminiConfig { max_in_flight: cfg.max_in_flight / 2, ..cfg.clone() };
        let nu = dram_use_risc(&narrow, &g, &s);
        assert!(nu.latency_cycles() > u.latency_cycles());
    }

    #[test]
    fn estimates_match_legacy_entry_points() {
        // `cost_model::estimate_risc`/`estimate_cisc` delegate here; the
        // delegation must be exact so every caller ranks identically.
        let cfg = GemminiConfig::ours_zcu102();
        let g = geom(256, 64, 144, 3);
        for s in enumerate(&cfg, g.mt(cfg.dim), g.kt(cfg.dim), g.nt(cfg.dim)) {
            assert_eq!(
                estimate_schedule(&cfg, &g, &s),
                crate::scheduler::cost_model::estimate_risc(&cfg, &g, &s)
            );
        }
        assert_eq!(
            estimate_default(&cfg, &g),
            crate::scheduler::cost_model::estimate_cisc(&cfg, &g)
        );
    }

    #[test]
    fn sort_ranked_is_nan_safe_and_tie_stable() {
        let s = |mb: usize, da: bool, db: bool, order: LoopOrder| RiscSchedule {
            mb,
            double_buffer_a: da,
            double_buffer_b: db,
            order,
        };
        // A NaN estimate (degenerate config: zero bandwidth) must not
        // panic and must sort last.
        let mut ranked = vec![
            (f64::NAN, s(4, false, false, LoopOrder::NOuter)),
            (100.0, s(2, true, false, LoopOrder::KOuter)),
            (100.0, s(1, false, false, LoopOrder::NOuter)),
            (50.0, s(8, true, true, LoopOrder::NOuter)),
        ];
        sort_ranked(&mut ranked);
        assert_eq!(ranked[0].1.mb, 8);
        // Exact tie broken by schedule key: mb=1 before mb=2.
        assert_eq!(ranked[1].1.mb, 1);
        assert_eq!(ranked[2].1.mb, 2);
        assert!(ranked[3].0.is_nan());
        // Reversed input order produces the identical ranking.
        let mut rev: Vec<_> = ranked.clone();
        rev.reverse();
        sort_ranked(&mut rev);
        let keys: Vec<_> = ranked.iter().map(|(_, s)| *s).collect();
        let rkeys: Vec<_> = rev.iter().map(|(_, s)| *s).collect();
        assert_eq!(keys, rkeys);
    }

    #[test]
    fn shortlist_truncates_rank_order() {
        let cfg = GemminiConfig::original_zcu102();
        let g = geom(512, 32, 128, 1);
        let space = enumerate(&cfg, g.mt(cfg.dim), g.kt(cfg.dim), g.nt(cfg.dim));
        let full = rank(&cfg, &g, &space);
        let top = shortlist(&cfg, &g, &space, 3);
        assert_eq!(top.len(), 3.min(full.len()));
        assert_eq!(&full[..top.len()], &top[..]);
        // Best-first: estimates are non-decreasing.
        for w in full.windows(2) {
            assert!(w[0].0 <= w[1].0);
        }
    }
}
