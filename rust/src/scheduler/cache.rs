//! Persistent tuning cache + memoization keys (the AutoTVM-log analogue).
//!
//! AutoTVM keeps a JSON log of measured schedules so repeated tuning runs
//! warm-start instead of re-measuring; this module is that idea for our
//! native tuner. A [`TuningCache`] maps [`CacheKey`]s — `(GemminiConfig`
//! fingerprint, GEMM shape key, trial budget)` — to the [`SearchResult`]
//! the search produced, plus a parallel table of data-movement-op cycle
//! results keyed by `(fingerprint, bytes_in, bytes_out)`. Because every
//! entry carries the config fingerprint
//! ([`crate::gemmini::config::GemminiConfig::fingerprint`]), entries from
//! a different accelerator configuration are simply never hit —
//! fingerprint invalidation without destroying other configs' entries
//! (one cache file can serve a whole heterogeneous fleet).
//!
//! File format (version 1, written/parsed with [`crate::util::json`]):
//!
//! ```json
//! {"version":1,
//!  "layers":[{"cfg":"<16-hex fingerprint>","m":..,"n":..,"k":..,
//!             "kernel":..,"bias":false,"measure_k":..,
//!             "default_cycles":..,"best_cycles":..,"measured":..,
//!             "space_size":..,
//!             "schedule":{"mb":..,"dba":..,"dbb":..,"order":"n"}}],
//!  "moves":[{"cfg":"<16-hex>","bytes_in":..,"bytes_out":..,"cycles":..}]}
//! ```
//!
//! Loading is fail-soft: a missing, unreadable, corrupt or
//! wrong-version file yields an empty cache (tuning proceeds cold and the
//! next save rewrites the file) — a stale cache must never make tuning
//! fail or change its results.

use std::collections::HashMap;
use std::path::{Path, PathBuf};

use crate::util::json::Json;

use super::codegen::ConvGeom;
// (The config type itself is only named in docs/tests; keys carry its
// `fingerprint()` as a plain u64.)
use super::search::SearchResult;
use super::space::{LoopOrder, RiscSchedule};

const CACHE_VERSION: f64 = 1.0;

/// The timing-relevant shape of a GEMM-shaped layer. Two layers with equal
/// keys produce identical instruction streams modulo the store-path
/// parameters (`scale`, `activation`), which cost a fixed one-cycle
/// `ConfigSt` regardless of value — so their measured cycles, and
/// therefore their [`SearchResult`], are identical. That is what makes
/// per-shape memoization exact: YOLOv7-tiny's 58 conv layers collapse to
/// ~36 unique keys, and post-quantization per-layer scales don't defeat
/// the dedup.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub struct GeomKey {
    pub m: usize,
    pub n: usize,
    pub k: usize,
    /// Kernel size drives the A-load DMA fragmentation.
    pub kernel: usize,
    /// Bias adds accumulator-preload mvins to the stream.
    pub bias: bool,
}

impl ConvGeom {
    /// The memoization key of this layer's geometry (drops the label and
    /// the timing-invariant store-path parameters).
    pub fn shape_key(&self) -> GeomKey {
        GeomKey { m: self.m, n: self.n, k: self.k, kernel: self.kernel, bias: self.bias }
    }
}

/// Full memoization key of one layer-tuning result.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub struct CacheKey {
    /// [`crate::gemmini::config::GemminiConfig::fingerprint`] of the
    /// config the result was measured on.
    pub config_fp: u64,
    pub geom: GeomKey,
    /// The AutoTVM trial budget the search ran with.
    pub measure_k: usize,
}

/// In-memory + optionally file-backed store of tuning results.
#[derive(Debug, Default)]
pub struct TuningCache {
    layers: HashMap<CacheKey, SearchResult>,
    moves: HashMap<(u64, usize, usize), u64>,
    path: Option<PathBuf>,
}

impl TuningCache {
    /// A cache that lives only for this process (no file backing).
    pub fn in_memory() -> Self {
        Self::default()
    }

    /// Load a cache from `path`, remembering the path for [`save`].
    /// Fail-soft: any read/parse/version problem yields an empty cache.
    ///
    /// [`save`]: TuningCache::save
    pub fn load(path: impl AsRef<Path>) -> Self {
        let path = path.as_ref().to_path_buf();
        let mut cache = Self { path: Some(path.clone()), ..Self::default() };
        let Ok(text) = std::fs::read_to_string(&path) else {
            return cache;
        };
        let Ok(root) = Json::parse(&text) else {
            return cache;
        };
        if root.get("version").and_then(Json::as_f64) != Some(CACHE_VERSION) {
            return cache;
        }
        if let Some(arr) = root.get("layers").and_then(Json::as_arr) {
            for e in arr {
                if let Some((key, result)) = parse_layer_entry(e) {
                    cache.layers.insert(key, result);
                }
            }
        }
        if let Some(arr) = root.get("moves").and_then(Json::as_arr) {
            for e in arr {
                if let Some((key, cycles)) = parse_move_entry(e) {
                    cache.moves.insert(key, cycles);
                }
            }
        }
        cache
    }

    /// Write the cache to its backing file (no-op for in-memory caches).
    /// Entries are sorted so the file is deterministic and diff-friendly.
    /// Written via a per-process temp file + rename, so readers never see
    /// a torn file and a crash mid-write cannot destroy the previous
    /// cache (concurrent writers still resolve last-writer-wins on the
    /// whole file).
    pub fn save(&self) -> std::io::Result<()> {
        let Some(path) = &self.path else {
            return Ok(());
        };
        let mut tmp = path.as_os_str().to_owned();
        tmp.push(format!(".{}.tmp", std::process::id()));
        let tmp = PathBuf::from(tmp);
        std::fs::write(&tmp, self.to_json().dump())?;
        std::fs::rename(&tmp, path)
    }

    pub fn path(&self) -> Option<&Path> {
        self.path.as_deref()
    }

    pub fn get_layer(&self, key: &CacheKey) -> Option<&SearchResult> {
        self.layers.get(key)
    }

    pub fn insert_layer(&mut self, key: CacheKey, result: SearchResult) {
        self.layers.insert(key, result);
    }

    pub fn get_move(&self, config_fp: u64, bytes_in: usize, bytes_out: usize) -> Option<u64> {
        self.moves.get(&(config_fp, bytes_in, bytes_out)).copied()
    }

    pub fn insert_move(&mut self, config_fp: u64, bytes_in: usize, bytes_out: usize, cycles: u64) {
        self.moves.insert((config_fp, bytes_in, bytes_out), cycles);
    }

    pub fn layer_entries(&self) -> usize {
        self.layers.len()
    }

    pub fn move_entries(&self) -> usize {
        self.moves.len()
    }

    pub fn is_empty(&self) -> bool {
        self.layers.is_empty() && self.moves.is_empty()
    }

    fn to_json(&self) -> Json {
        let mut lkeys: Vec<&CacheKey> = self.layers.keys().collect();
        lkeys.sort_by_key(|c| {
            (c.config_fp, c.geom.m, c.geom.n, c.geom.k, c.geom.kernel, c.geom.bias, c.measure_k)
        });
        let layers: Vec<Json> = lkeys
            .into_iter()
            .map(|key| layer_entry_json(key, &self.layers[key]))
            .collect();
        let mut mkeys: Vec<&(u64, usize, usize)> = self.moves.keys().collect();
        mkeys.sort();
        let moves: Vec<Json> = mkeys
            .into_iter()
            .map(|&(fp, bi, bo)| {
                Json::obj(vec![
                    ("cfg", Json::Str(format!("{fp:016x}"))),
                    ("bytes_in", Json::Num(bi as f64)),
                    ("bytes_out", Json::Num(bo as f64)),
                    ("cycles", Json::Num(self.moves[&(fp, bi, bo)] as f64)),
                ])
            })
            .collect();
        Json::obj(vec![
            ("version", Json::Num(CACHE_VERSION)),
            ("layers", Json::Arr(layers)),
            ("moves", Json::Arr(moves)),
        ])
    }
}

fn layer_entry_json(key: &CacheKey, r: &SearchResult) -> Json {
    let schedule = match &r.best_schedule {
        None => Json::Null,
        Some(s) => Json::obj(vec![
            ("mb", Json::Num(s.mb as f64)),
            ("dba", Json::Bool(s.double_buffer_a)),
            ("dbb", Json::Bool(s.double_buffer_b)),
            (
                "order",
                Json::Str(
                    match s.order {
                        LoopOrder::NOuter => "n",
                        LoopOrder::KOuter => "k",
                    }
                    .into(),
                ),
            ),
        ]),
    };
    Json::obj(vec![
        ("cfg", Json::Str(format!("{:016x}", key.config_fp))),
        ("m", Json::Num(key.geom.m as f64)),
        ("n", Json::Num(key.geom.n as f64)),
        ("k", Json::Num(key.geom.k as f64)),
        ("kernel", Json::Num(key.geom.kernel as f64)),
        ("bias", Json::Bool(key.geom.bias)),
        ("measure_k", Json::Num(key.measure_k as f64)),
        ("default_cycles", Json::Num(r.default_cycles as f64)),
        ("best_cycles", Json::Num(r.best_cycles as f64)),
        ("measured", Json::Num(r.measured as f64)),
        ("space_size", Json::Num(r.space_size as f64)),
        ("schedule", schedule),
    ])
}

fn parse_layer_entry(e: &Json) -> Option<(CacheKey, SearchResult)> {
    let config_fp = u64::from_str_radix(e.get("cfg")?.as_str()?, 16).ok()?;
    let num = |field: &str| -> Option<usize> {
        let v = e.get(field)?.as_f64()?;
        (v >= 0.0 && v.fract() == 0.0).then_some(v as usize)
    };
    let geom = GeomKey {
        m: num("m")?,
        n: num("n")?,
        k: num("k")?,
        kernel: num("kernel")?,
        bias: e.get("bias")?.as_bool()?,
    };
    let measure_k = num("measure_k")?;
    let default_cycles = num("default_cycles")? as u64;
    let best_cycles = num("best_cycles")? as u64;
    // Reject inconsistent entries (the tuner never regresses below CISC).
    if best_cycles > default_cycles {
        return None;
    }
    let best_schedule = match e.get("schedule")? {
        Json::Null => None,
        s => {
            let mb = s.get("mb")?.as_f64()?;
            // Same integrality guard as the other numeric fields, plus
            // the space's invariant that blocks hold ≥ 1 m-tile.
            if mb < 1.0 || mb.fract() != 0.0 {
                return None;
            }
            Some(RiscSchedule {
                mb: mb as usize,
                double_buffer_a: s.get("dba")?.as_bool()?,
                double_buffer_b: s.get("dbb")?.as_bool()?,
                order: match s.get("order")?.as_str()? {
                    "n" => LoopOrder::NOuter,
                    "k" => LoopOrder::KOuter,
                    _ => return None,
                },
            })
        }
    };
    Some((
        CacheKey { config_fp, geom, measure_k },
        SearchResult {
            default_cycles,
            best_cycles,
            best_schedule,
            measured: num("measured")?,
            space_size: num("space_size")?,
        },
    ))
}

fn parse_move_entry(e: &Json) -> Option<((u64, usize, usize), u64)> {
    let fp = u64::from_str_radix(e.get("cfg")?.as_str()?, 16).ok()?;
    let num = |field: &str| -> Option<u64> {
        let v = e.get(field)?.as_f64()?;
        (v >= 0.0 && v.fract() == 0.0).then_some(v as u64)
    };
    Some((
        (fp, num("bytes_in")? as usize, num("bytes_out")? as usize),
        num("cycles")?,
    ))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::gemmini::config::GemminiConfig;

    fn sample_key(fp: u64) -> CacheKey {
        CacheKey {
            config_fp: fp,
            geom: GeomKey { m: 1600, n: 24, k: 72, kernel: 3, bias: false },
            measure_k: 4,
        }
    }

    fn sample_result(sched: Option<RiscSchedule>) -> SearchResult {
        SearchResult {
            default_cycles: 1000,
            best_cycles: if sched.is_some() { 700 } else { 1000 },
            best_schedule: sched,
            measured: 4,
            space_size: 18,
        }
    }

    #[test]
    fn roundtrip_through_file() {
        let path = std::env::temp_dir()
            .join(format!("gemmini_edge_cache_rt_{}.json", std::process::id()));
        let fp = GemminiConfig::ours_zcu102().fingerprint();
        let mut c = TuningCache::load(&path);
        let sched = RiscSchedule {
            mb: 4,
            double_buffer_a: true,
            double_buffer_b: false,
            order: LoopOrder::KOuter,
        };
        c.insert_layer(sample_key(fp), sample_result(Some(sched)));
        c.insert_layer(
            CacheKey { measure_k: 2, ..sample_key(fp) },
            sample_result(None),
        );
        c.insert_move(fp, 4096, 1024, 555);
        c.save().unwrap();
        let back = TuningCache::load(&path);
        assert_eq!(back.layer_entries(), 2);
        assert_eq!(back.move_entries(), 1);
        let got = back.get_layer(&sample_key(fp)).unwrap();
        assert_eq!(got, &sample_result(Some(sched)));
        assert_eq!(back.get_move(fp, 4096, 1024), Some(555));
        // Different fingerprint → miss (config invalidation).
        assert!(back.get_layer(&sample_key(fp ^ 1)).is_none());
        assert_eq!(back.get_move(fp ^ 1, 4096, 1024), None);
        std::fs::remove_file(&path).ok();
    }

    #[test]
    fn corrupt_and_wrong_version_files_yield_empty_cache() {
        let dir = std::env::temp_dir();
        let pid = std::process::id();
        for (tag, text) in [
            ("garbage", "not json {{{"),
            ("truncated", "{\"version\":1,\"layers\":[{\"cfg\":"),
            ("wrong_version", "{\"version\":99,\"layers\":[],\"moves\":[]}"),
            ("wrong_shape", "[1,2,3]"),
        ] {
            let path = dir.join(format!("gemmini_edge_cache_{tag}_{pid}.json"));
            std::fs::write(&path, text).unwrap();
            let c = TuningCache::load(&path);
            assert!(c.is_empty(), "{tag} should load empty");
            // The cache remains usable: it can be saved over the bad file.
            assert!(c.save().is_ok());
            std::fs::remove_file(&path).ok();
        }
        // Missing file: also empty, also fine.
        let c = TuningCache::load(dir.join(format!("gemmini_edge_cache_missing_{pid}.json")));
        assert!(c.is_empty());
    }

    #[test]
    fn malformed_entries_are_skipped_not_fatal() {
        let good = layer_entry_json(&sample_key(7), &sample_result(None)).dump();
        let text = format!(
            "{{\"version\":1,\"layers\":[{{\"cfg\":\"zz\"}},{good},{{\"m\":1}}],\"moves\":[{{}}]}}"
        );
        let path = std::env::temp_dir()
            .join(format!("gemmini_edge_cache_partial_{}.json", std::process::id()));
        std::fs::write(&path, text).unwrap();
        let c = TuningCache::load(&path);
        assert_eq!(c.layer_entries(), 1);
        assert_eq!(c.move_entries(), 0);
        std::fs::remove_file(&path).ok();
    }

    #[test]
    fn in_memory_save_is_noop() {
        let mut c = TuningCache::in_memory();
        c.insert_move(1, 2, 3, 4);
        assert!(c.save().is_ok());
        assert!(c.path().is_none());
    }
}
