//! Persistent tuning cache + memoization keys (the AutoTVM-log analogue).
//!
//! AutoTVM keeps a JSON log of measured schedules so repeated tuning runs
//! warm-start instead of re-measuring; this module is that idea for our
//! native tuner. A [`TuningCache`] maps [`CacheKey`]s — `(GemminiConfig`
//! fingerprint, GEMM shape key, trial budget)` — to the [`SearchResult`]
//! the search produced, plus a parallel table of data-movement-op cycle
//! results keyed by `(fingerprint, bytes_in, bytes_out)`. Because every
//! entry carries the config fingerprint
//! ([`crate::gemmini::config::GemminiConfig::fingerprint`]), entries from
//! a different accelerator configuration are simply never hit —
//! fingerprint invalidation without destroying other configs' entries
//! (one cache file can serve a whole heterogeneous fleet).
//!
//! File format (version 1, written/parsed with [`crate::util::json`]):
//!
//! ```json
//! {"version":1,
//!  "layers":[{"cfg":"<16-hex fingerprint>","m":..,"n":..,"k":..,
//!             "kernel":..,"bias":false,"measure_k":..,
//!             "default_cycles":..,"best_cycles":..,"measured":..,
//!             "space_size":..,
//!             "schedule":{"mb":..,"dba":..,"dbb":..,"order":"n"}}],
//!  "moves":[{"cfg":"<16-hex>","bytes_in":..,"bytes_out":..,"cycles":..}]}
//! ```
//!
//! Loading is fail-soft: a missing, unreadable, corrupt or
//! wrong-version file yields an empty cache (tuning proceeds cold and the
//! next save rewrites the file) — a stale cache must never make tuning
//! fail or change its results.
//!
//! **Compaction on save.** Fingerprints accumulate: every config edit
//! and every `TIMING_MODEL_VERSION` bump strands the old fingerprint's
//! entries in the file, unreachable forever (nothing can ever look them
//! up again), so a long-lived cache file only grows. When a save would
//! exceed [`TuningCache::max_entries`], entries whose fingerprint was
//! never *touched* this process (attached by an engine or written to —
//! see [`TuningCache::touch`]) are treated as superseded and dropped
//! first; if the live set alone still exceeds the cap, a deterministic
//! sorted prefix is kept. The in-memory cache is never compacted — only
//! what gets persisted — so dropping never changes a running process's
//! results, and a dropped entry merely costs a cold re-tune later.

use std::collections::{HashMap, HashSet};
use std::path::{Path, PathBuf};

use crate::util::json::Json;

use super::codegen::ConvGeom;
// (The config type itself is only named in docs/tests; keys carry its
// `fingerprint()` as a plain u64.)
use super::search::SearchResult;
use super::space::{LoopOrder, RiscSchedule};

const CACHE_VERSION: f64 = 1.0;

/// The timing-relevant shape of a GEMM-shaped layer. Two layers with equal
/// keys produce identical instruction streams modulo the store-path
/// parameters (`scale`, `activation`), which cost a fixed one-cycle
/// `ConfigSt` regardless of value — so their measured cycles, and
/// therefore their [`SearchResult`], are identical. That is what makes
/// per-shape memoization exact: YOLOv7-tiny's 58 conv layers collapse to
/// ~36 unique keys, and post-quantization per-layer scales don't defeat
/// the dedup.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub struct GeomKey {
    pub m: usize,
    pub n: usize,
    pub k: usize,
    /// Kernel size drives the A-load DMA fragmentation.
    pub kernel: usize,
    /// Bias adds accumulator-preload mvins to the stream.
    pub bias: bool,
}

impl ConvGeom {
    /// The memoization key of this layer's geometry (drops the label and
    /// the timing-invariant store-path parameters).
    pub fn shape_key(&self) -> GeomKey {
        GeomKey { m: self.m, n: self.n, k: self.k, kernel: self.kernel, bias: self.bias }
    }
}

/// Full memoization key of one layer-tuning result.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub struct CacheKey {
    /// [`crate::gemmini::config::GemminiConfig::fingerprint`] of the
    /// config the result was measured on.
    pub config_fp: u64,
    pub geom: GeomKey,
    /// The AutoTVM trial budget the search ran with.
    pub measure_k: usize,
}

/// Persisted-entry cap a save compacts down to (see the module docs).
const DEFAULT_MAX_ENTRIES: usize = 4096;

/// In-memory + optionally file-backed store of tuning results.
#[derive(Debug)]
pub struct TuningCache {
    layers: HashMap<CacheKey, SearchResult>,
    moves: HashMap<(u64, usize, usize), u64>,
    path: Option<PathBuf>,
    /// Fingerprints in active use this process (engines attach theirs;
    /// inserts record theirs) — what compaction keeps under pressure.
    touched: HashSet<u64>,
    /// Persisted-entry budget enforced by [`save`](TuningCache::save).
    max_entries: usize,
}

impl Default for TuningCache {
    fn default() -> Self {
        Self {
            layers: HashMap::new(),
            moves: HashMap::new(),
            path: None,
            touched: HashSet::new(),
            max_entries: DEFAULT_MAX_ENTRIES,
        }
    }
}

impl TuningCache {
    /// A cache that lives only for this process (no file backing).
    pub fn in_memory() -> Self {
        Self::default()
    }

    /// Override the persisted-entry budget (tests exercise small caps;
    /// the default is [`DEFAULT_MAX_ENTRIES`]).
    pub fn with_max_entries(mut self, max_entries: usize) -> Self {
        self.max_entries = max_entries.max(1);
        self
    }

    /// Mark a config fingerprint as live: its entries survive
    /// compaction. [`crate::scheduler::TuningEngine::with_cache`] calls
    /// this with the engine's fingerprint; inserts imply it.
    pub fn touch(&mut self, config_fp: u64) {
        self.touched.insert(config_fp);
    }

    /// Load a cache from `path`, remembering the path for [`save`].
    /// Fail-soft: any read/parse/version problem yields an empty cache.
    ///
    /// [`save`]: TuningCache::save
    pub fn load(path: impl AsRef<Path>) -> Self {
        let path = path.as_ref().to_path_buf();
        let mut cache = Self { path: Some(path.clone()), ..Self::default() };
        let Ok(text) = std::fs::read_to_string(&path) else {
            return cache;
        };
        let Ok(root) = Json::parse(&text) else {
            return cache;
        };
        if root.get("version").and_then(Json::as_f64) != Some(CACHE_VERSION) {
            return cache;
        }
        if let Some(arr) = root.get("layers").and_then(Json::as_arr) {
            for e in arr {
                if let Some((key, result)) = parse_layer_entry(e) {
                    cache.layers.insert(key, result);
                }
            }
        }
        if let Some(arr) = root.get("moves").and_then(Json::as_arr) {
            for e in arr {
                if let Some((key, cycles)) = parse_move_entry(e) {
                    cache.moves.insert(key, cycles);
                }
            }
        }
        cache
    }

    /// Write the cache to its backing file (no-op for in-memory caches).
    /// Entries are sorted so the file is deterministic and diff-friendly,
    /// and compacted to [`max_entries`](Self::with_max_entries): under
    /// pressure, superseded fingerprints (never touched this process)
    /// are evicted first. Written via a per-process temp file + rename,
    /// so readers never see a torn file and a crash mid-write cannot
    /// destroy the previous cache (concurrent writers still resolve
    /// last-writer-wins on the whole file).
    pub fn save(&self) -> std::io::Result<()> {
        let Some(path) = &self.path else {
            return Ok(());
        };
        let mut tmp = path.as_os_str().to_owned();
        tmp.push(format!(".{}.tmp", std::process::id()));
        let tmp = PathBuf::from(tmp);
        std::fs::write(&tmp, self.to_json().dump())?;
        std::fs::rename(&tmp, path)
    }

    pub fn path(&self) -> Option<&Path> {
        self.path.as_deref()
    }

    pub fn get_layer(&self, key: &CacheKey) -> Option<&SearchResult> {
        self.layers.get(key)
    }

    pub fn insert_layer(&mut self, key: CacheKey, result: SearchResult) {
        self.touched.insert(key.config_fp);
        self.layers.insert(key, result);
    }

    pub fn get_move(&self, config_fp: u64, bytes_in: usize, bytes_out: usize) -> Option<u64> {
        self.moves.get(&(config_fp, bytes_in, bytes_out)).copied()
    }

    pub fn insert_move(&mut self, config_fp: u64, bytes_in: usize, bytes_out: usize, cycles: u64) {
        self.touched.insert(config_fp);
        self.moves.insert((config_fp, bytes_in, bytes_out), cycles);
    }

    /// The transfer-tuning donor for a key that missed: the nearest
    /// previously-tuned neighbor whose winner can seed the target's
    /// shortlist ([`crate::scheduler::search::tune_layer_transfer`]).
    /// Two phases, both with deterministic total-order tie-breaks (the
    /// result must not depend on `HashMap` iteration order or thread
    /// count):
    ///
    /// 1. **m-neighbor** — same config fingerprint, same
    ///    `(n, k, kernel, bias, measure_k)`, different `m`; nearest `m`
    ///    wins (ties to the smaller `m`). Same `GeomKey` modulo
    ///    m-scaling: the schedule space and ranking are nearly
    ///    identical, and cycle counts scale with the m-tile count
    ///    (`TransferSeed::scalable`).
    /// 2. **config sibling** — identical geometry and budget on a
    ///    different config fingerprint; smallest fingerprint wins. The
    ///    winner still seeds well (good block shapes transfer across
    ///    sibling configs) but cycles don't scale, so the default is
    ///    always re-measured.
    ///
    /// Callers detect which phase hit by comparing the donor key's
    /// `config_fp` with the target's.
    pub fn nearest_donor(&self, key: &CacheKey) -> Option<(CacheKey, SearchResult)> {
        let g = key.geom;
        let m_neighbor = self
            .layers
            .iter()
            .filter(|(k, _)| {
                k.config_fp == key.config_fp
                    && k.measure_k == key.measure_k
                    && k.geom.n == g.n
                    && k.geom.k == g.k
                    && k.geom.kernel == g.kernel
                    && k.geom.bias == g.bias
                    && k.geom.m != g.m
            })
            .min_by_key(|(k, _)| (k.geom.m.abs_diff(g.m), k.geom.m));
        if let Some((k, r)) = m_neighbor {
            return Some((*k, r.clone()));
        }
        self.layers
            .iter()
            .filter(|(k, _)| {
                k.geom == g && k.measure_k == key.measure_k && k.config_fp != key.config_fp
            })
            .min_by_key(|(k, _)| k.config_fp)
            .map(|(k, r)| (*k, r.clone()))
    }

    pub fn layer_entries(&self) -> usize {
        self.layers.len()
    }

    pub fn move_entries(&self) -> usize {
        self.moves.len()
    }

    pub fn is_empty(&self) -> bool {
        self.layers.is_empty() && self.moves.is_empty()
    }

    /// The deterministic, compacted entry selection [`save`] persists
    /// (see the module docs for the eviction order).
    ///
    /// [`save`]: TuningCache::save
    fn persisted_keys(&self) -> (Vec<&CacheKey>, Vec<&(u64, usize, usize)>) {
        let mut lkeys: Vec<&CacheKey> = self.layers.keys().collect();
        lkeys.sort_by_key(|c| {
            (c.config_fp, c.geom.m, c.geom.n, c.geom.k, c.geom.kernel, c.geom.bias, c.measure_k)
        });
        let mut mkeys: Vec<&(u64, usize, usize)> = self.moves.keys().collect();
        mkeys.sort();
        if lkeys.len() + mkeys.len() > self.max_entries {
            lkeys.retain(|k| self.touched.contains(&k.config_fp));
            mkeys.retain(|k| self.touched.contains(&k.0));
        }
        if lkeys.len() + mkeys.len() > self.max_entries {
            // The live set alone is over budget: keep a deterministic
            // sorted prefix, layer entries first (they are the ones
            // that skip whole schedule searches).
            let keep_l = lkeys.len().min(self.max_entries);
            lkeys.truncate(keep_l);
            mkeys.truncate(self.max_entries - keep_l);
        }
        (lkeys, mkeys)
    }

    fn to_json(&self) -> Json {
        let (lkeys, mkeys) = self.persisted_keys();
        let layers: Vec<Json> = lkeys
            .into_iter()
            .map(|key| layer_entry_json(key, &self.layers[key]))
            .collect();
        let moves: Vec<Json> = mkeys
            .into_iter()
            .map(|&(fp, bi, bo)| {
                Json::obj(vec![
                    ("cfg", Json::Str(format!("{fp:016x}"))),
                    ("bytes_in", Json::Num(bi as f64)),
                    ("bytes_out", Json::Num(bo as f64)),
                    ("cycles", Json::Num(self.moves[&(fp, bi, bo)] as f64)),
                ])
            })
            .collect();
        Json::obj(vec![
            ("version", Json::Num(CACHE_VERSION)),
            ("layers", Json::Arr(layers)),
            ("moves", Json::Arr(moves)),
        ])
    }
}

fn layer_entry_json(key: &CacheKey, r: &SearchResult) -> Json {
    let schedule = match &r.best_schedule {
        None => Json::Null,
        Some(s) => Json::obj(vec![
            ("mb", Json::Num(s.mb as f64)),
            ("dba", Json::Bool(s.double_buffer_a)),
            ("dbb", Json::Bool(s.double_buffer_b)),
            (
                "order",
                Json::Str(
                    match s.order {
                        LoopOrder::NOuter => "n",
                        LoopOrder::KOuter => "k",
                    }
                    .into(),
                ),
            ),
        ]),
    };
    Json::obj(vec![
        ("cfg", Json::Str(format!("{:016x}", key.config_fp))),
        ("m", Json::Num(key.geom.m as f64)),
        ("n", Json::Num(key.geom.n as f64)),
        ("k", Json::Num(key.geom.k as f64)),
        ("kernel", Json::Num(key.geom.kernel as f64)),
        ("bias", Json::Bool(key.geom.bias)),
        ("measure_k", Json::Num(key.measure_k as f64)),
        ("default_cycles", Json::Num(r.default_cycles as f64)),
        ("best_cycles", Json::Num(r.best_cycles as f64)),
        ("measured", Json::Num(r.measured as f64)),
        ("space_size", Json::Num(r.space_size as f64)),
        ("schedule", schedule),
        ("default_est", Json::Bool(r.default_est)),
    ])
}

fn parse_layer_entry(e: &Json) -> Option<(CacheKey, SearchResult)> {
    let config_fp = u64::from_str_radix(e.get("cfg")?.as_str()?, 16).ok()?;
    let num = |field: &str| -> Option<usize> {
        let v = e.get(field)?.as_f64()?;
        (v >= 0.0 && v.fract() == 0.0).then_some(v as usize)
    };
    let geom = GeomKey {
        m: num("m")?,
        n: num("n")?,
        k: num("k")?,
        kernel: num("kernel")?,
        bias: e.get("bias")?.as_bool()?,
    };
    let measure_k = num("measure_k")?;
    let default_cycles = num("default_cycles")? as u64;
    let best_cycles = num("best_cycles")? as u64;
    // Reject inconsistent entries (the tuner never regresses below CISC).
    if best_cycles > default_cycles {
        return None;
    }
    let best_schedule = match e.get("schedule")? {
        Json::Null => None,
        s => {
            let mb = s.get("mb")?.as_f64()?;
            // Same integrality guard as the other numeric fields, plus
            // the space's invariant that blocks hold ≥ 1 m-tile.
            if mb < 1.0 || mb.fract() != 0.0 {
                return None;
            }
            Some(RiscSchedule {
                mb: mb as usize,
                double_buffer_a: s.get("dba")?.as_bool()?,
                double_buffer_b: s.get("dbb")?.as_bool()?,
                order: match s.get("order")?.as_str()? {
                    "n" => LoopOrder::NOuter,
                    "k" => LoopOrder::KOuter,
                    _ => return None,
                },
            })
        }
    };
    Some((
        CacheKey { config_fp, geom, measure_k },
        SearchResult {
            default_cycles,
            best_cycles,
            best_schedule,
            measured: num("measured")?,
            space_size: num("space_size")?,
            // Optional for version-1 files written before transfer
            // tuning existed: a measured default is the safe default.
            default_est: e.get("default_est").and_then(Json::as_bool).unwrap_or(false),
        },
    ))
}

fn parse_move_entry(e: &Json) -> Option<((u64, usize, usize), u64)> {
    let fp = u64::from_str_radix(e.get("cfg")?.as_str()?, 16).ok()?;
    let num = |field: &str| -> Option<u64> {
        let v = e.get(field)?.as_f64()?;
        (v >= 0.0 && v.fract() == 0.0).then_some(v as u64)
    };
    Some((
        (fp, num("bytes_in")? as usize, num("bytes_out")? as usize),
        num("cycles")?,
    ))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::gemmini::config::GemminiConfig;

    fn sample_key(fp: u64) -> CacheKey {
        CacheKey {
            config_fp: fp,
            geom: GeomKey { m: 1600, n: 24, k: 72, kernel: 3, bias: false },
            measure_k: 4,
        }
    }

    fn sample_result(sched: Option<RiscSchedule>) -> SearchResult {
        SearchResult {
            default_cycles: 1000,
            best_cycles: if sched.is_some() { 700 } else { 1000 },
            best_schedule: sched,
            measured: 4,
            space_size: 18,
            default_est: false,
        }
    }

    #[test]
    fn roundtrip_through_file() {
        let path = std::env::temp_dir()
            .join(format!("gemmini_edge_cache_rt_{}.json", std::process::id()));
        let fp = GemminiConfig::ours_zcu102().fingerprint();
        let mut c = TuningCache::load(&path);
        let sched = RiscSchedule {
            mb: 4,
            double_buffer_a: true,
            double_buffer_b: false,
            order: LoopOrder::KOuter,
        };
        c.insert_layer(sample_key(fp), sample_result(Some(sched)));
        c.insert_layer(
            CacheKey { measure_k: 2, ..sample_key(fp) },
            sample_result(None),
        );
        c.insert_move(fp, 4096, 1024, 555);
        c.save().unwrap();
        let back = TuningCache::load(&path);
        assert_eq!(back.layer_entries(), 2);
        assert_eq!(back.move_entries(), 1);
        let got = back.get_layer(&sample_key(fp)).unwrap();
        assert_eq!(got, &sample_result(Some(sched)));
        assert_eq!(back.get_move(fp, 4096, 1024), Some(555));
        // Different fingerprint → miss (config invalidation).
        assert!(back.get_layer(&sample_key(fp ^ 1)).is_none());
        assert_eq!(back.get_move(fp ^ 1, 4096, 1024), None);
        std::fs::remove_file(&path).ok();
    }

    #[test]
    fn save_is_atomic_tempfile_plus_rename() {
        let path = std::env::temp_dir()
            .join(format!("gemmini_edge_cache_atomic_{}.json", std::process::id()));
        let tmp = {
            let mut s = path.as_os_str().to_owned();
            s.push(format!(".{}.tmp", std::process::id()));
            PathBuf::from(s)
        };
        let fp = GemminiConfig::ours_zcu102().fingerprint();
        // A crashed writer left garbage at the temp path: the next save
        // must clobber it wholesale, not merge with it.
        std::fs::write(&tmp, "torn half-write {{{").unwrap();
        let mut c = TuningCache::load(&path);
        c.insert_layer(sample_key(fp), sample_result(None));
        c.save().unwrap();
        assert!(!tmp.exists(), "save must consume its temp file via rename");
        let back = TuningCache::load(&path);
        assert_eq!(back.layer_entries(), 1);
        // Re-save over an existing destination: the file is replaced
        // whole (rename), never appended to or left torn.
        let mut c2 = TuningCache::load(&path);
        c2.insert_move(fp, 4096, 1024, 42);
        c2.save().unwrap();
        assert!(!tmp.exists(), "re-save must also consume its temp file");
        let again = TuningCache::load(&path);
        assert_eq!(again.layer_entries(), 1);
        assert_eq!(again.move_entries(), 1);
        std::fs::remove_file(&path).ok();
    }

    #[test]
    fn corrupt_and_wrong_version_files_yield_empty_cache() {
        let dir = std::env::temp_dir();
        let pid = std::process::id();
        for (tag, text) in [
            ("garbage", "not json {{{"),
            ("truncated", "{\"version\":1,\"layers\":[{\"cfg\":"),
            ("wrong_version", "{\"version\":99,\"layers\":[],\"moves\":[]}"),
            ("wrong_shape", "[1,2,3]"),
        ] {
            let path = dir.join(format!("gemmini_edge_cache_{tag}_{pid}.json"));
            std::fs::write(&path, text).unwrap();
            let c = TuningCache::load(&path);
            assert!(c.is_empty(), "{tag} should load empty");
            // The cache remains usable: it can be saved over the bad file.
            assert!(c.save().is_ok());
            std::fs::remove_file(&path).ok();
        }
        // Missing file: also empty, also fine.
        let c = TuningCache::load(dir.join(format!("gemmini_edge_cache_missing_{pid}.json")));
        assert!(c.is_empty());
    }

    #[test]
    fn malformed_entries_are_skipped_not_fatal() {
        let good = layer_entry_json(&sample_key(7), &sample_result(None)).dump();
        let text = format!(
            "{{\"version\":1,\"layers\":[{{\"cfg\":\"zz\"}},{good},{{\"m\":1}}],\"moves\":[{{}}]}}"
        );
        let path = std::env::temp_dir()
            .join(format!("gemmini_edge_cache_partial_{}.json", std::process::id()));
        std::fs::write(&path, text).unwrap();
        let c = TuningCache::load(&path);
        assert_eq!(c.layer_entries(), 1);
        assert_eq!(c.move_entries(), 0);
        std::fs::remove_file(&path).ok();
    }

    #[test]
    fn in_memory_save_is_noop() {
        let mut c = TuningCache::in_memory();
        c.insert_move(1, 2, 3, 4);
        assert!(c.save().is_ok());
        assert!(c.path().is_none());
    }

    #[test]
    fn save_compacts_untouched_fingerprints_under_pressure() {
        let path = std::env::temp_dir()
            .join(format!("gemmini_edge_cache_compact_{}.json", std::process::id()));
        let _ = std::fs::remove_file(&path);
        // Writer: 30 junk fingerprints + 1 real one (all touched here,
        // because inserting implies touching).
        let mut w = TuningCache::load(&path);
        for fp in 1..=30u64 {
            w.insert_layer(sample_key(fp), sample_result(None));
            w.insert_move(fp, 100, 50, fp);
        }
        w.insert_layer(sample_key(0xFEED), sample_result(None));
        w.save().unwrap();
        assert_eq!(TuningCache::load(&path).layer_entries(), 31);

        // Reader with a tight budget touches only the real fingerprint:
        // the junk is evicted from the file, the live entries survive.
        let mut r = TuningCache::load(&path).with_max_entries(8);
        r.touch(0xFEED);
        r.insert_move(0xFEED, 7, 7, 7);
        r.save().unwrap();
        let back = TuningCache::load(&path);
        assert_eq!(back.layer_entries(), 1);
        assert_eq!(back.move_entries(), 1);
        assert!(back.get_layer(&sample_key(0xFEED)).is_some());
        assert_eq!(back.get_move(0xFEED, 7, 7), Some(7));
        // The in-memory cache was never compacted.
        assert_eq!(r.layer_entries(), 31);

        // Live set over budget: deterministic prefix truncation, and
        // repeated saves of the same cache produce identical bytes.
        let mut big = TuningCache::load(&path).with_max_entries(4);
        for m in 0..10usize {
            big.insert_layer(
                CacheKey {
                    config_fp: 0xFEED,
                    geom: GeomKey { m, n: 1, k: 1, kernel: 1, bias: false },
                    measure_k: 1,
                },
                sample_result(None),
            );
        }
        big.save().unwrap();
        let first = std::fs::read_to_string(&path).unwrap();
        big.save().unwrap();
        assert_eq!(first, std::fs::read_to_string(&path).unwrap());
        assert_eq!(TuningCache::load(&path).layer_entries(), 4);
        std::fs::remove_file(&path).ok();
    }

    #[test]
    fn default_est_roundtrips_and_defaults_false() {
        let path = std::env::temp_dir()
            .join(format!("gemmini_edge_cache_destflag_{}.json", std::process::id()));
        let _ = std::fs::remove_file(&path);
        let mut c = TuningCache::load(&path);
        let est = SearchResult { default_est: true, ..sample_result(None) };
        c.insert_layer(sample_key(1), est.clone());
        c.save().unwrap();
        let back = TuningCache::load(&path);
        assert_eq!(back.get_layer(&sample_key(1)), Some(&est));
        // Pre-transfer version-1 files lack the field: parse as measured.
        let mut entry = layer_entry_json(&sample_key(2), &sample_result(None)).dump();
        entry = entry.replace(",\"default_est\":false", "");
        assert!(!entry.contains("default_est"), "{entry}");
        std::fs::write(&path, format!("{{\"version\":1,\"layers\":[{entry}],\"moves\":[]}}"))
            .unwrap();
        let old = TuningCache::load(&path);
        assert_eq!(old.get_layer(&sample_key(2)), Some(&sample_result(None)));
        std::fs::remove_file(&path).ok();
    }

    #[test]
    fn nearest_donor_prefers_m_neighbors_deterministically() {
        let mut c = TuningCache::in_memory();
        let key_m = |fp: u64, m: usize| CacheKey {
            geom: GeomKey { m, ..sample_key(fp).geom },
            ..sample_key(fp)
        };
        // No donors at all.
        assert!(c.nearest_donor(&sample_key(1)).is_none());
        // A sibling-config donor with the identical geometry…
        c.insert_layer(sample_key(9), sample_result(None));
        let (dk, _) = c.nearest_donor(&sample_key(1)).unwrap();
        assert_eq!(dk.config_fp, 9);
        // …loses to any same-config m-neighbor.
        c.insert_layer(key_m(1, 3200), sample_result(None));
        let (dk, _) = c.nearest_donor(&sample_key(1)).unwrap();
        assert_eq!((dk.config_fp, dk.geom.m), (1, 3200));
        // Nearest m wins; equidistant ties go to the smaller m.
        c.insert_layer(key_m(1, 800), sample_result(None));
        let (dk, _) = c.nearest_donor(&sample_key(1)).unwrap();
        assert_eq!(dk.geom.m, 800, "|1600-800| = |1600-3200|·1/2 … nearest");
        c.insert_layer(key_m(1, 2400), sample_result(None));
        let (dk, _) = c.nearest_donor(&sample_key(1)).unwrap();
        assert_eq!(dk.geom.m, 800, "equidistant 800/2400 → smaller m");
        // The exact key itself is never its own donor.
        c.insert_layer(sample_key(1), sample_result(None));
        let (dk, _) = c.nearest_donor(&sample_key(1)).unwrap();
        assert_ne!(dk, sample_key(1));
        // A different measure_k never donates.
        let other_k = CacheKey { measure_k: 9, ..sample_key(2) };
        c.insert_layer(other_k, sample_result(None));
        assert!(c.nearest_donor(&CacheKey { measure_k: 5, ..sample_key(2) }).is_none());
    }

    #[test]
    fn nearest_donor_config_siblings_tie_break_on_fingerprint() {
        let mut c = TuningCache::in_memory();
        for fp in [7u64, 3, 5] {
            c.insert_layer(sample_key(fp), sample_result(None));
        }
        let (dk, _) = c.nearest_donor(&sample_key(1)).unwrap();
        assert_eq!(dk.config_fp, 3, "smallest sibling fingerprint wins");
    }

    #[test]
    fn small_caches_never_compact() {
        let path = std::env::temp_dir()
            .join(format!("gemmini_edge_cache_nocompact_{}.json", std::process::id()));
        let _ = std::fs::remove_file(&path);
        let mut w = TuningCache::load(&path);
        for fp in 1..=5u64 {
            w.insert_layer(sample_key(fp), sample_result(None));
        }
        w.save().unwrap();
        // A reader that touches nothing still persists everything while
        // under budget: compaction only fires under pressure.
        let r = TuningCache::load(&path);
        r.save().unwrap();
        assert_eq!(TuningCache::load(&path).layer_entries(), 5);
        std::fs::remove_file(&path).ok();
    }
}
