//! Lowering IR layers to Gemmini instruction streams.
//!
//! Convolutions and dense layers become tiled GEMMs (conv via im2col, with
//! the gather cost charged as fragmented DMA — see
//! [`crate::gemmini::cisc`]). Two lowerings exist per layer:
//!
//! - [`lower_cisc`] — the single CISC FSM instruction with its fixed
//!   internal schedule (Figure 5's "Default");
//! - [`lower_risc`] — a RISC stream shaped by a [`RiscSchedule`]: A-block
//!   caching, weight-reuse preloads, double buffering and loop-order
//!   selection (Figure 5's "AutoTVM" candidates).
//!
//! Max pooling, upsample/resize and concat lower to DMA movement streams
//! ([`lower_move_op`]) — they are bandwidth-bound on Gemmini; their
//! numerics run on the IR interpreter (the simulator provides timing).

use crate::gemmini::config::GemminiConfig;
use crate::gemmini::isa::{Activation, Instr, MvinDst, REUSE_WEIGHTS};
use crate::gemmini::memory::DramAllocator;
use crate::ir::{ActivationKind, Graph, NodeId, Op};

use super::space::{LoopOrder, RiscSchedule};

/// GEMM-shaped geometry of one layer.
#[derive(Debug, Clone)]
pub struct ConvGeom {
    /// GEMM dims: `C[m×n] = A[m×k]·B[k×n]`.
    pub m: usize,
    pub n: usize,
    pub k: usize,
    /// Kernel size (1 for dense / 1×1 convs): the DMA gather fragmentation.
    pub kernel: usize,
    /// Requantization scale and fused activation for the store path.
    pub scale: f32,
    pub activation: Activation,
    /// Whether a bias vector exists.
    pub bias: bool,
    /// Human label (layer name).
    pub label: String,
}

impl ConvGeom {
    pub fn mt(&self, dim: usize) -> usize {
        self.m.div_ceil(dim)
    }
    pub fn nt(&self, dim: usize) -> usize {
        self.n.div_ceil(dim)
    }
    pub fn kt(&self, dim: usize) -> usize {
        self.k.div_ceil(dim)
    }
    /// MACs for this layer.
    pub fn macs(&self) -> u64 {
        (self.m * self.n * self.k) as u64
    }
}

/// DRAM addresses for one layer's operands.
#[derive(Debug, Clone)]
pub struct LayerBuffers {
    /// A operand (staged im2col for convs), `m × k` int8, stride `k`.
    pub a_addr: usize,
    /// B operand (weights in GEMM layout), `k × n` int8, stride `n`.
    pub b_addr: usize,
    /// Bias (int32, `n` entries) — present iff geometry has bias.
    pub bias_addr: Option<usize>,
    /// Output, `m × n` int8, stride `n`.
    pub c_addr: usize,
}

/// Allocate DRAM for a layer.
pub fn alloc_buffers(g: &ConvGeom, alloc: &mut DramAllocator) -> LayerBuffers {
    LayerBuffers {
        a_addr: alloc.alloc(g.m * g.k),
        b_addr: alloc.alloc(g.k * g.n),
        bias_addr: if g.bias { Some(alloc.alloc(g.n * 4)) } else { None },
        c_addr: alloc.alloc(g.m * g.n),
    }
}

/// Extract GEMM geometry from a conv/dense node (post-quantization graph:
/// scales come from the quant params; float graphs get scale 1.0).
pub fn layer_geometry(g: &Graph, id: NodeId) -> Option<ConvGeom> {
    let n = g.node(id);
    match &n.op {
        Op::Conv2d { out_channels, kernel, activation, bias, .. } => {
            let w = g.node(n.inputs[1]);
            let ic = *w.output.shape.last().unwrap();
            let oh = n.output.shape[1];
            let ow = n.output.shape[2];
            let scale = requant_scale(g, id);
            Some(ConvGeom {
                m: oh * ow,
                n: *out_channels,
                k: kernel * kernel * ic,
                kernel: *kernel,
                scale,
                activation: hw_activation(*activation, g, id),
                bias: *bias,
                label: n.output.name.clone(),
            })
        }
        Op::Dense { out_features, activation, bias } => {
            let w = g.node(n.inputs[1]);
            let inf = *w.output.shape.last().unwrap();
            let scale = requant_scale(g, id);
            Some(ConvGeom {
                m: n.output.shape[0],
                n: *out_features,
                k: inf,
                kernel: 1,
                scale,
                activation: hw_activation(*activation, g, id),
                bias: *bias,
                label: n.output.name.clone(),
            })
        }
        _ => None,
    }
}

fn requant_scale(g: &Graph, id: NodeId) -> f32 {
    let n = g.node(id);
    match (n.output.quant, g.node(n.inputs[0]).output.quant, g.node(n.inputs[1]).output.quant) {
        (Some(o), Some(x), Some(w)) => {
            x.effective_scale() * w.effective_scale() / o.effective_scale()
        }
        _ => 1.0,
    }
}

fn hw_activation(a: ActivationKind, g: &Graph, id: NodeId) -> Activation {
    match a {
        ActivationKind::Relu => Activation::Relu,
        ActivationKind::Relu6 => {
            let qmax = g
                .node(id)
                .output
                .quant
                .map(|q| (6.0 / q.effective_scale()).round().clamp(1.0, 127.0) as i8)
                .unwrap_or(127);
            Activation::Relu6 { qmax }
        }
        _ => Activation::None,
    }
}

/// Lower a layer to the CISC FSM instruction (the "Default" schedule).
pub fn lower_cisc(geom: &ConvGeom, bufs: &LayerBuffers) -> Vec<Instr> {
    vec![Instr::LoopWs {
        m: geom.m,
        n: geom.n,
        k: geom.k,
        a_addr: bufs.a_addr,
        b_addr: bufs.b_addr,
        bias_addr: bufs.bias_addr,
        c_addr: bufs.c_addr,
        scale: geom.scale,
        activation: geom.activation,
    }]
}

/// Lower a layer to a tuned RISC stream for the given schedule.
///
/// Scratchpad layout: `[A slot 0 | A slot 1? | B slot 0 | B slot 1?]`,
/// where an A slot holds `mb` m-tiles × `kt` k-tiles. Accumulator holds
/// `mb` (NOuter) or `mb × nt` (KOuter) tiles.
pub fn lower_risc(
    cfg: &GemminiConfig,
    geom: &ConvGeom,
    bufs: &LayerBuffers,
    s: &RiscSchedule,
) -> Vec<Instr> {
    let dim = cfg.dim;
    let (mt, nt, kt) = (geom.mt(dim), geom.nt(dim), geom.kt(dim));
    assert!(s.fits(cfg, kt, nt), "schedule does not fit: {s:?}");
    let a_slot_rows = s.mb * dim * kt;
    let a_slots = if s.double_buffer_a { 2 } else { 1 };
    let b_base = a_slot_rows * a_slots;
    let b_slots = if s.double_buffer_b { 2 } else { 1 };

    let mut out = Vec::new();
    out.push(Instr::ConfigEx { acc_shift: 0 });
    out.push(Instr::ConfigSt { scale: geom.scale, activation: geom.activation });

    let blocks = mt.div_ceil(s.mb);
    let mut b_rot = 0usize;
    for blk in 0..blocks {
        let m0 = blk * s.mb; // first m-tile of the block
        let mbe = s.mb.min(mt - m0); // tiles in this block
        let a_base = (blk % a_slots) * a_slot_rows;

        // ---- load the A block: per (ki, mi), fragmented by kernel rows ----
        for ki in 0..kt {
            let k_eff = dim.min(geom.k - ki * dim);
            for mi in 0..mbe {
                let rows = dim.min(geom.m - (m0 + mi) * dim);
                emit_a_mvin(
                    &mut out,
                    bufs.a_addr + ((m0 + mi) * dim) * geom.k + ki * dim,
                    a_base + (ki * s.mb + mi) * dim,
                    rows,
                    k_eff,
                    geom.k,
                    geom.kernel,
                );
            }
        }

        // acc tile row for (mi, ni) under the chosen order.
        let acc_row = |mi: usize, ni: usize| -> usize {
            match s.order {
                LoopOrder::NOuter => mi * dim,
                LoopOrder::KOuter => (mi * nt + ni) * dim,
            }
        };

        match s.order {
            LoopOrder::NOuter => {
                for ni in 0..nt {
                    let n_eff = dim.min(geom.n - ni * dim);
                    if let Some(bias) = bufs.bias_addr {
                        for mi in 0..mbe {
                            let rows = dim.min(geom.m - (m0 + mi) * dim);
                            out.push(Instr::Mvin {
                                dram_addr: bias + ni * dim * 4,
                                dst: MvinDst::Accumulator { row: acc_row(mi, ni) },
                                rows,
                                cols: n_eff,
                                stride_bytes: 0,
                            });
                        }
                    }
                    for ki in 0..kt {
                        let k_eff = dim.min(geom.k - ki * dim);
                        let b_row = b_base + (b_rot % b_slots) * dim;
                        b_rot += 1;
                        out.push(Instr::Mvin {
                            dram_addr: bufs.b_addr + (ki * dim) * geom.n + ni * dim,
                            dst: MvinDst::Scratchpad { row: b_row },
                            rows: k_eff,
                            cols: n_eff,
                            stride_bytes: geom.n,
                        });
                        for mi in 0..mbe {
                            let rows = dim.min(geom.m - (m0 + mi) * dim);
                            let accumulate = ki > 0 || bufs.bias_addr.is_some();
                            out.push(Instr::Preload {
                                b_row: if mi == 0 { b_row } else { REUSE_WEIGHTS },
                                acc_row: acc_row(mi, ni),
                                accumulate,
                            });
                            out.push(Instr::Compute {
                                a_row: a_base + (ki * s.mb + mi) * dim,
                                rows,
                                cols: k_eff,
                            });
                        }
                    }
                    for mi in 0..mbe {
                        let rows = dim.min(geom.m - (m0 + mi) * dim);
                        out.push(Instr::Mvout {
                            acc_row: acc_row(mi, ni),
                            dram_addr: bufs.c_addr + ((m0 + mi) * dim) * geom.n + ni * dim,
                            rows,
                            cols: n_eff,
                            stride_bytes: geom.n,
                        });
                    }
                }
            }
            LoopOrder::KOuter => {
                if let Some(bias) = bufs.bias_addr {
                    for ni in 0..nt {
                        let n_eff = dim.min(geom.n - ni * dim);
                        for mi in 0..mbe {
                            let rows = dim.min(geom.m - (m0 + mi) * dim);
                            out.push(Instr::Mvin {
                                dram_addr: bias + ni * dim * 4,
                                dst: MvinDst::Accumulator { row: acc_row(mi, ni) },
                                rows,
                                cols: n_eff,
                                stride_bytes: 0,
                            });
                        }
                    }
                }
                for ki in 0..kt {
                    let k_eff = dim.min(geom.k - ki * dim);
                    for ni in 0..nt {
                        let n_eff = dim.min(geom.n - ni * dim);
                        let b_row = b_base + (b_rot % b_slots) * dim;
                        b_rot += 1;
                        out.push(Instr::Mvin {
                            dram_addr: bufs.b_addr + (ki * dim) * geom.n + ni * dim,
                            dst: MvinDst::Scratchpad { row: b_row },
                            rows: k_eff,
                            cols: n_eff,
                            stride_bytes: geom.n,
                        });
                        for mi in 0..mbe {
                            let rows = dim.min(geom.m - (m0 + mi) * dim);
                            let accumulate = ki > 0 || bufs.bias_addr.is_some();
                            out.push(Instr::Preload {
                                b_row: if mi == 0 { b_row } else { REUSE_WEIGHTS },
                                acc_row: acc_row(mi, ni),
                                accumulate,
                            });
                            out.push(Instr::Compute {
                                a_row: a_base + (ki * s.mb + mi) * dim,
                                rows,
                                cols: k_eff,
                            });
                        }
                    }
                }
                for ni in 0..nt {
                    let n_eff = dim.min(geom.n - ni * dim);
                    for mi in 0..mbe {
                        let rows = dim.min(geom.m - (m0 + mi) * dim);
                        out.push(Instr::Mvout {
                            acc_row: acc_row(mi, ni),
                            dram_addr: bufs.c_addr + ((m0 + mi) * dim) * geom.n + ni * dim,
                            rows,
                            cols: n_eff,
                            stride_bytes: geom.n,
                        });
                    }
                }
            }
        }
    }
    out.push(Instr::Flush);
    out
}

/// Split an A-tile mvin into `frag` chunks modelling the conv FSM's
/// per-kernel-row gather (matches the CISC expansion's accounting).
fn emit_a_mvin(
    out: &mut Vec<Instr>,
    dram_addr: usize,
    sp_row: usize,
    rows: usize,
    cols: usize,
    stride: usize,
    frag: usize,
) {
    let frag = frag.clamp(1, rows);
    let chunk = rows.div_ceil(frag);
    let mut r0 = 0;
    while r0 < rows {
        let r = chunk.min(rows - r0);
        out.push(Instr::Mvin {
            dram_addr: dram_addr + r0 * stride,
            dst: MvinDst::Scratchpad { row: sp_row + r0 },
            rows: r,
            cols,
            stride_bytes: stride,
        });
        r0 += r;
    }
}

/// Lower a data-movement op (maxpool / upsample / concat) to a DMA stream:
/// `bytes_in` DRAM→scratchpad, `bytes_out` accumulator→DRAM writeback.
/// Timing-only (numerics run on the IR interpreter).
pub fn lower_move_op(cfg: &GemminiConfig, bytes_in: usize, bytes_out: usize) -> Vec<Instr> {
    let dim = cfg.dim;
    let row_bytes = dim; // one scratchpad row per burst
    let mut out = vec![Instr::ConfigSt { scale: 1.0, activation: Activation::None }];
    let mut emitted = 0usize;
    while emitted < bytes_in {
        let rows = ((bytes_in - emitted).div_ceil(row_bytes)).min(dim);
        out.push(Instr::Mvin {
            dram_addr: emitted,
            dst: MvinDst::Scratchpad { row: 0 },
            rows,
            cols: dim,
            stride_bytes: row_bytes,
        });
        emitted += rows * row_bytes;
    }
    let mut written = 0usize;
    while written < bytes_out {
        let rows = ((bytes_out - written).div_ceil(row_bytes)).min(dim);
        out.push(Instr::Mvout {
            acc_row: 0,
            dram_addr: (1 << 22) + written,
            rows,
            cols: dim,
            stride_bytes: row_bytes,
        });
        written += rows * row_bytes;
    }
    out.push(Instr::Flush);
    out
}

/// Stage the im2col matrix for a conv layer into `bufs.a_addr`
/// (functional-mode helper; mirrors `cisc::stage_im2col`).
#[allow(clippy::too_many_arguments)]
pub fn stage_conv_operands(
    dram: &mut crate::gemmini::memory::Dram,
    geom: &ConvGeom,
    bufs: &LayerBuffers,
    input_nhwc: &[i8],
    in_h: usize,
    in_w: usize,
    in_c: usize,
    stride: usize,
    pad: usize,
    weights_oihw: &[i8], // IR layout [oc, kh, kw, ic]
    bias: Option<&[i32]>,
) {
    let k = geom.kernel;
    // A: im2col M×K.
    let (oh, ow) = crate::gemmini::cisc::conv_out_dims(in_h, in_w, k, stride, pad);
    assert_eq!(oh * ow, geom.m);
    for oy in 0..oh {
        for ox in 0..ow {
            let patch = oy * ow + ox;
            for kh in 0..k {
                for kw in 0..k {
                    let iy = (oy * stride + kh) as isize - pad as isize;
                    let ix = (ox * stride + kw) as isize - pad as isize;
                    let dst = bufs.a_addr + patch * geom.k + (kh * k + kw) * in_c;
                    for c in 0..in_c {
                        let v = if iy < 0 || ix < 0 || iy >= in_h as isize || ix >= in_w as isize
                        {
                            0
                        } else {
                            input_nhwc[((iy as usize) * in_w + ix as usize) * in_c + c]
                        };
                        dram.write_i8(dst + c, v);
                    }
                }
            }
        }
    }
    // B: weights [oc,kh,kw,ic] -> GEMM K×N with K=(kh,kw,ic), N=oc.
    for o in 0..geom.n {
        for kh in 0..k {
            for kw in 0..k {
                for c in 0..in_c {
                    let krow = (kh * k + kw) * in_c + c;
                    let v = weights_oihw[((o * k + kh) * k + kw) * in_c + c];
                    dram.write_i8(bufs.b_addr + krow * geom.n + o, v);
                }
            }
        }
    }
    if let (Some(addr), Some(b)) = (bufs.bias_addr, bias) {
        for (i, &v) in b.iter().enumerate() {
            dram.write_i32(addr + i * 4, v);
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::gemmini::sim::Simulator;
    use crate::util::prop;
    use crate::util::Rng;

    fn cfg4() -> GemminiConfig {
        GemminiConfig { dim: 4, scratchpad_kib: 8, accumulator_kib: 4, ..GemminiConfig::original_zcu102() }
    }

    fn ref_gemm(a: &[i8], b: &[i8], bias: Option<&[i32]>, m: usize, n: usize, k: usize, scale: f32) -> Vec<i8> {
        let mut c = vec![0i8; m * n];
        for i in 0..m {
            for j in 0..n {
                let mut acc: i32 = bias.map(|b| b[j]).unwrap_or(0);
                for x in 0..k {
                    acc += a[i * k + x] as i32 * b[x * n + j] as i32;
                }
                c[i * n + j] = ((acc as f32 * scale).round() as i32).clamp(-128, 127) as i8;
            }
        }
        c
    }

    fn check_schedule(m: usize, n: usize, k: usize, s: RiscSchedule, bias: bool, seed: u64) {
        let cfg = cfg4();
        let geom = ConvGeom {
            m,
            n,
            k,
            kernel: 1,
            scale: 0.5,
            activation: Activation::None,
            bias,
            label: "t".into(),
        };
        if !s.fits(&cfg, geom.kt(4), geom.nt(4)) {
            return;
        }
        let mut alloc = DramAllocator::new(1 << 20);
        let bufs = alloc_buffers(&geom, &mut alloc);
        let mut sim = Simulator::new_functional(cfg.clone(), 1 << 20);
        let mut rng = Rng::new(seed);
        let a: Vec<i8> = (0..m * k).map(|_| (rng.below(11) as i8) - 5).collect();
        let b: Vec<i8> = (0..k * n).map(|_| (rng.below(9) as i8) - 4).collect();
        let bv: Vec<i32> = (0..n).map(|_| (rng.below(7) as i32) - 3).collect();
        sim.dram.write_i8_matrix(bufs.a_addr, &a, m, k, k);
        sim.dram.write_i8_matrix(bufs.b_addr, &b, k, n, n);
        if let Some(addr) = bufs.bias_addr {
            sim.dram.write_i32_matrix(addr, &bv, 1, n, 0);
        }
        let stream = lower_risc(&cfg, &geom, &bufs, &s);
        sim.run(&stream);
        let got = sim.dram.read_i8_matrix(bufs.c_addr, m, n, n);
        let want = ref_gemm(&a, &b, bias.then_some(&bv[..]), m, n, k, 0.5);
        assert_eq!(got, want, "m={m} n={n} k={k} sched={s:?}");
    }

    #[test]
    fn risc_schedules_all_compute_same_result() {
        for &order in &[LoopOrder::NOuter, LoopOrder::KOuter] {
            for &mb in &[1, 2, 4] {
                for &db in &[false, true] {
                    let s = RiscSchedule {
                        mb,
                        double_buffer_a: db,
                        double_buffer_b: db,
                        order,
                    };
                    check_schedule(10, 6, 9, s, false, 42);
                    check_schedule(8, 8, 8, s, true, 43);
                }
            }
        }
    }

    #[test]
    fn property_random_shapes_and_schedules() {
        prop::check(
            7,
            25,
            |r| {
                let m = r.range(1, 20);
                let n = r.range(1, 12);
                let k = r.range(1, 16);
                let s = RiscSchedule {
                    mb: *r.choose(&[1usize, 2, 4]),
                    double_buffer_a: r.chance(0.5),
                    double_buffer_b: r.chance(0.5),
                    order: if r.chance(0.5) { LoopOrder::NOuter } else { LoopOrder::KOuter },
                };
                let bias = r.chance(0.5);
                let seed = r.next_u64();
                (m, n, k, s, bias, seed)
            },
            |&(m, n, k, s, bias, seed)| {
                check_schedule(m, n, k, s, bias, seed);
                Ok(())
            },
        );
    }

    #[test]
    fn risc_beats_cisc_on_reuse_heavy_layer() {
        // A GEMM with many m-tiles: A-block caching + weight reuse should
        // beat the single-buffered CISC schedule.
        let cfg = cfg4();
        let geom = ConvGeom {
            m: 64,
            n: 8,
            k: 16,
            kernel: 1,
            scale: 1.0,
            activation: Activation::None,
            bias: false,
            label: "t".into(),
        };
        let mut alloc = DramAllocator::new(1 << 20);
        let bufs = alloc_buffers(&geom, &mut alloc);
        let mut sim = Simulator::new(cfg.clone(), 1 << 20);
        let cisc = sim.run(&lower_cisc(&geom, &bufs)).cycles;
        let s = RiscSchedule {
            mb: 4,
            double_buffer_a: true,
            double_buffer_b: true,
            order: LoopOrder::NOuter,
        };
        let mut sim2 = Simulator::new(cfg.clone(), 1 << 20);
        let risc = sim2.run(&lower_risc(&cfg, &geom, &bufs, &s)).cycles;
        assert!(risc < cisc, "risc {risc} !< cisc {cisc}");
    }

    #[test]
    fn conv_geometry_from_graph() {
        use crate::ir::{GraphBuilder, PaddingMode};
        let mut b = GraphBuilder::new("t");
        let x = b.input("x", vec![1, 16, 16, 8]);
        let c = b.conv2d(x, 24, 3, 2, PaddingMode::Same, ActivationKind::Relu6, None, None);
        let g = b.finish(&[c]);
        let geom = layer_geometry(&g, c).unwrap();
        assert_eq!(geom.m, 8 * 8);
        assert_eq!(geom.n, 24);
        assert_eq!(geom.k, 9 * 8);
        assert_eq!(geom.kernel, 3);
    }

    #[test]
    fn move_op_stream_scales_with_bytes() {
        let cfg = cfg4();
        let mut sim = Simulator::new(cfg.clone(), 1 << 24);
        let small = sim.run(&lower_move_op(&cfg, 1024, 1024)).cycles;
        let mut sim2 = Simulator::new(cfg, 1 << 24);
        let big = sim2.run(&lower_move_op(&sim2.cfg.clone(), 8192, 8192)).cycles;
        assert!(big > 2 * small);
    }

    #[test]
    fn staged_conv_executes_correctly_end_to_end() {
        // Full conv through stage + lower_risc vs direct reference.
        let cfg = cfg4();
        let (ih, iw, ic, oc, k, stride, pad) = (5usize, 5usize, 2usize, 3usize, 3usize, 1usize, 1usize);
        let (oh, ow) = crate::gemmini::cisc::conv_out_dims(ih, iw, k, stride, pad);
        let geom = ConvGeom {
            m: oh * ow,
            n: oc,
            k: k * k * ic,
            kernel: k,
            scale: 1.0,
            activation: Activation::None,
            bias: false,
            label: "conv".into(),
        };
        let mut alloc = DramAllocator::new(1 << 20);
        let bufs = alloc_buffers(&geom, &mut alloc);
        let mut rng = Rng::new(9);
        let input: Vec<i8> = (0..ih * iw * ic).map(|_| (rng.below(9) as i8) - 4).collect();
        let w: Vec<i8> = (0..oc * k * k * ic).map(|_| (rng.below(7) as i8) - 3).collect();
        let mut sim = Simulator::new_functional(cfg.clone(), 1 << 20);
        stage_conv_operands(&mut sim.dram, &geom, &bufs, &input, ih, iw, ic, stride, pad, &w, None);
        let s = RiscSchedule { mb: 2, double_buffer_a: true, double_buffer_b: false, order: LoopOrder::NOuter };
        sim.run(&lower_risc(&cfg, &geom, &bufs, &s));
        let got = sim.dram.read_i8_matrix(bufs.c_addr, geom.m, geom.n, geom.n);
        // direct reference
        for oy in 0..oh {
            for ox in 0..ow {
                for n in 0..oc {
                    let mut acc = 0i32;
                    for kh in 0..k {
                        for kw in 0..k {
                            let iy = (oy + kh) as isize - pad as isize;
                            let ix = (ox + kw) as isize - pad as isize;
                            if iy < 0 || ix < 0 || iy >= ih as isize || ix >= iw as isize {
                                continue;
                            }
                            for c in 0..ic {
                                acc += input[((iy as usize) * iw + ix as usize) * ic + c] as i32
                                    * w[((n * k + kh) * k + kw) * ic + c] as i32;
                            }
                        }
                    }
                    assert_eq!(
                        got[(oy * ow + ox) * oc + n] as i32,
                        acc.clamp(-128, 127),
                        "({oy},{ox},{n})"
                    );
                }
            }
        }
    }
}
