//! Mean average precision (COCO-style 101-point interpolated AP at a
//! single IoU threshold — the metric of Table I and Figures 3/4).

use super::bbox::{BBox, Detection};

/// Ground-truth object in one image.
#[derive(Debug, Clone, Copy)]
pub struct GroundTruth {
    pub bbox: BBox,
    pub class: usize,
}

/// AP for one class across a dataset.
/// `dets`: (image index, detection) sorted or not; `gts`: (image, truth).
fn average_precision(
    dets: &[(usize, Detection)],
    gts: &[(usize, GroundTruth)],
    iou_thr: f32,
) -> Option<f64> {
    let npos = gts.len();
    if npos == 0 {
        return None; // class absent from the dataset: skipped by mAP
    }
    let mut dets: Vec<&(usize, Detection)> = dets.iter().collect();
    dets.sort_by(|a, b| b.1.score.partial_cmp(&a.1.score).unwrap());
    let mut matched = vec![false; gts.len()];
    let mut tp = Vec::with_capacity(dets.len());
    for (img, d) in dets {
        let mut best = -1f32;
        let mut best_gt = usize::MAX;
        for (gi, (gimg, gt)) in gts.iter().enumerate() {
            if gimg != img || matched[gi] {
                continue;
            }
            let iou = d.bbox.iou(&gt.bbox);
            if iou > best {
                best = iou;
                best_gt = gi;
            }
        }
        if best >= iou_thr && best_gt != usize::MAX {
            matched[best_gt] = true;
            tp.push(true);
        } else {
            tp.push(false);
        }
    }
    // precision-recall curve
    let mut cum_tp = 0f64;
    let mut precisions = Vec::with_capacity(tp.len());
    let mut recalls = Vec::with_capacity(tp.len());
    for (i, &t) in tp.iter().enumerate() {
        if t {
            cum_tp += 1.0;
        }
        precisions.push(cum_tp / (i + 1) as f64);
        recalls.push(cum_tp / npos as f64);
    }
    // 101-point interpolation. Sum the interpolated precisions first and
    // divide once: 101 accumulations of `p / 101.0` drift a few ulps, so
    // all-perfect detections would score 1.0000000000000007 instead of
    // exactly 1.0.
    let mut sum = 0f64;
    for r in 0..=100 {
        let r = r as f64 / 100.0;
        let p = precisions
            .iter()
            .zip(&recalls)
            .filter(|(_, &rec)| rec >= r)
            .map(|(&p, _)| p)
            .fold(0f64, f64::max);
        sum += p;
    }
    Some(sum / 101.0)
}

/// Dataset-level mAP@`iou_thr` over `num_classes` classes.
///
/// `detections[i]` / `truths[i]` belong to image `i`.
pub fn mean_average_precision(
    detections: &[Vec<Detection>],
    truths: &[Vec<GroundTruth>],
    num_classes: usize,
    iou_thr: f32,
) -> f64 {
    assert_eq!(detections.len(), truths.len(), "image count mismatch");
    let mut aps = Vec::new();
    for c in 0..num_classes {
        let dets: Vec<(usize, Detection)> = detections
            .iter()
            .enumerate()
            .flat_map(|(i, v)| v.iter().filter(|d| d.class == c).map(move |d| (i, *d)))
            .collect();
        let gts: Vec<(usize, GroundTruth)> = truths
            .iter()
            .enumerate()
            .flat_map(|(i, v)| v.iter().filter(|g| g.class == c).map(move |g| (i, *g)))
            .collect();
        if let Some(ap) = average_precision(&dets, &gts, iou_thr) {
            aps.push(ap);
        }
    }
    if aps.is_empty() {
        0.0
    } else {
        aps.iter().sum::<f64>() / aps.len() as f64
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn d(cx: f32, s: f32, score: f32, class: usize) -> Detection {
        Detection { bbox: BBox::new(cx, 0.5, s, s), score, class }
    }
    fn g(cx: f32, s: f32, class: usize) -> GroundTruth {
        GroundTruth { bbox: BBox::new(cx, 0.5, s, s), class }
    }

    #[test]
    fn perfect_detections_give_map_one() {
        let dets = vec![vec![d(0.3, 0.1, 0.9, 0), d(0.7, 0.1, 0.8, 1)]];
        let gts = vec![vec![g(0.3, 0.1, 0), g(0.7, 0.1, 1)]];
        let m = mean_average_precision(&dets, &gts, 2, 0.5);
        assert!((m - 1.0).abs() < 1e-2, "mAP {m}");
    }

    #[test]
    fn no_detections_give_zero() {
        let dets = vec![vec![]];
        let gts = vec![vec![g(0.3, 0.1, 0)]];
        assert_eq!(mean_average_precision(&dets, &gts, 2, 0.5), 0.0);
    }

    #[test]
    fn false_positives_lower_precision() {
        let perfect = vec![vec![d(0.3, 0.1, 0.9, 0)]];
        let noisy = vec![vec![d(0.3, 0.1, 0.9, 0), d(0.8, 0.1, 0.95, 0)]];
        let gts = vec![vec![g(0.3, 0.1, 0)]];
        let m_p = mean_average_precision(&perfect, &gts, 1, 0.5);
        let m_n = mean_average_precision(&noisy, &gts, 1, 0.5);
        assert!(m_n < m_p, "{m_n} !< {m_p}");
    }

    #[test]
    fn localization_error_beyond_iou_is_miss() {
        let dets = vec![vec![d(0.5, 0.1, 0.9, 0)]];
        let gts = vec![vec![g(0.3, 0.1, 0)]]; // far away
        let m = mean_average_precision(&dets, &gts, 1, 0.5);
        assert!(m < 0.05, "mAP {m}");
    }

    #[test]
    fn duplicate_detections_counted_once() {
        let dets = vec![vec![d(0.3, 0.1, 0.9, 0), d(0.3, 0.1, 0.85, 0)]];
        let gts = vec![vec![g(0.3, 0.1, 0)]];
        let m = mean_average_precision(&dets, &gts, 1, 0.5);
        // Second detection is a false positive at recall 1.0: AP stays
        // high but below a clean single detection.
        let clean = mean_average_precision(&vec![vec![d(0.3, 0.1, 0.9, 0)]], &gts, 1, 0.5);
        assert!(m <= clean);
    }

    #[test]
    fn absent_classes_skipped_not_zeroed() {
        // Class 1 has no ground truth anywhere: mAP is class-0 AP only.
        let dets = vec![vec![d(0.3, 0.1, 0.9, 0)]];
        let gts = vec![vec![g(0.3, 0.1, 0)]];
        let m1 = mean_average_precision(&dets, &gts, 1, 0.5);
        let m2 = mean_average_precision(&dets, &gts, 5, 0.5);
        assert!((m1 - m2).abs() < 1e-9);
    }

    #[test]
    fn multi_image_aggregation() {
        let dets = vec![
            vec![d(0.3, 0.1, 0.9, 0)],
            vec![], // miss on image 2
        ];
        let gts = vec![vec![g(0.3, 0.1, 0)], vec![g(0.6, 0.1, 0)]];
        let m = mean_average_precision(&dets, &gts, 1, 0.5);
        assert!(m > 0.3 && m < 0.7, "recall-limited mAP {m}");
    }
}
