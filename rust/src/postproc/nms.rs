//! Non-maximum suppression (the float post-processing the paper keeps on
//! the PS and deliberately excludes from quantization, Section IV-B4).

use super::bbox::{BBox, Detection};

/// NMS parameters.
#[derive(Debug, Clone, Copy)]
pub struct NmsConfig {
    /// Minimum objectness × class score to keep a candidate.
    pub score_threshold: f32,
    /// IoU above which a lower-scored box is suppressed.
    pub iou_threshold: f32,
    /// Cap on detections returned per image.
    pub max_detections: usize,
}

impl Default for NmsConfig {
    fn default() -> Self {
        Self { score_threshold: 0.25, iou_threshold: 0.45, max_detections: 300 }
    }
}

/// Class-aware greedy NMS over scored candidates.
pub fn nms(mut candidates: Vec<Detection>, cfg: &NmsConfig) -> Vec<Detection> {
    candidates.retain(|d| d.score >= cfg.score_threshold);
    candidates.sort_by(|a, b| b.score.partial_cmp(&a.score).unwrap());
    let mut keep: Vec<Detection> = Vec::new();
    'outer: for d in candidates {
        for k in &keep {
            if k.class == d.class && k.bbox.iou(&d.bbox) > cfg.iou_threshold {
                continue 'outer;
            }
        }
        keep.push(d);
        if keep.len() >= cfg.max_detections {
            break;
        }
    }
    keep
}

/// Decode a `BoxDecode` output tensor (`[1, boxes, 5+classes]`, see
/// [`crate::ir::interp`]) into candidates and run NMS.
pub fn decode_and_nms(decoded: &[f32], num_classes: usize, cfg: &NmsConfig) -> Vec<Detection> {
    let per = 5 + num_classes;
    assert_eq!(decoded.len() % per, 0, "decoded tensor not a multiple of {per}");
    let mut cands = Vec::new();
    for chunk in decoded.chunks(per) {
        let obj = chunk[4];
        if obj < cfg.score_threshold * 0.5 {
            continue; // cheap pre-filter
        }
        // Best class.
        let (class, &cls_score) = chunk[5..]
            .iter()
            .enumerate()
            .max_by(|a, b| a.1.partial_cmp(b.1).unwrap())
            .unwrap();
        let score = obj * cls_score;
        if score < cfg.score_threshold {
            continue;
        }
        cands.push(Detection {
            bbox: BBox::new(chunk[0], chunk[1], chunk[2], chunk[3]),
            score,
            class,
        });
    }
    nms(cands, cfg)
}

/// FLOP estimate for the NMS-prep tail on `n` candidate boxes with `c`
/// classes (sigmoids, decode arithmetic, pairwise IoU) — used by the
/// Figure 6 partitioning experiment to cost the PS-side work.
pub fn postproc_gflop(n: usize, c: usize) -> f64 {
    // decode: ~8 flops/box + (5+c) sigmoids (~4 flops each); NMS pairwise
    // IoU on the ~n/10 surviving boxes (~16 flops per pair).
    let decode = n as f64 * (8.0 + 4.0 * (5 + c) as f64);
    let surv = (n / 10).max(1) as f64;
    let pairwise = surv * surv * 16.0;
    (decode + pairwise) / 1e9
}

#[cfg(test)]
mod tests {
    use super::*;

    fn det(cx: f32, cy: f32, s: f32, score: f32, class: usize) -> Detection {
        Detection { bbox: BBox::new(cx, cy, s, s), score, class }
    }

    #[test]
    fn suppresses_overlapping_same_class() {
        let out = nms(
            vec![det(0.5, 0.5, 0.2, 0.9, 0), det(0.51, 0.5, 0.2, 0.8, 0)],
            &NmsConfig::default(),
        );
        assert_eq!(out.len(), 1);
        assert!((out[0].score - 0.9).abs() < 1e-6);
    }

    #[test]
    fn keeps_overlapping_different_class() {
        let out = nms(
            vec![det(0.5, 0.5, 0.2, 0.9, 0), det(0.51, 0.5, 0.2, 0.8, 1)],
            &NmsConfig::default(),
        );
        assert_eq!(out.len(), 2);
    }

    #[test]
    fn keeps_distant_same_class() {
        let out = nms(
            vec![det(0.2, 0.2, 0.1, 0.9, 0), det(0.8, 0.8, 0.1, 0.8, 0)],
            &NmsConfig::default(),
        );
        assert_eq!(out.len(), 2);
    }

    #[test]
    fn score_threshold_filters() {
        let out = nms(vec![det(0.5, 0.5, 0.2, 0.1, 0)], &NmsConfig::default());
        assert!(out.is_empty());
    }

    #[test]
    fn decode_and_nms_end_to_end() {
        // Two boxes; one strong, one weak-overlapping.
        let c = 3;
        let mut raw = Vec::new();
        // box 1: strong class 2
        raw.extend_from_slice(&[0.5, 0.5, 0.2, 0.2, 0.95, 0.1, 0.1, 0.9]);
        // box 2: overlapping, lower
        raw.extend_from_slice(&[0.52, 0.5, 0.2, 0.2, 0.7, 0.1, 0.1, 0.8]);
        // box 3: far away class 0
        raw.extend_from_slice(&[0.1, 0.1, 0.1, 0.1, 0.9, 0.85, 0.05, 0.05]);
        let out = decode_and_nms(&raw, c, &NmsConfig::default());
        assert_eq!(out.len(), 2);
        assert_eq!(out[0].class, 2);
        assert_eq!(out[1].class, 0);
    }

    #[test]
    fn max_detections_cap() {
        let cands: Vec<Detection> =
            (0..50).map(|i| det(0.01 * i as f32 + 0.1, 0.5, 0.01, 0.9, 0)).collect();
        let cfg = NmsConfig { max_detections: 10, ..Default::default() };
        assert_eq!(nms(cands, &cfg).len(), 10);
    }

    #[test]
    fn postproc_gflop_positive_and_scales() {
        assert!(postproc_gflop(1000, 80) > postproc_gflop(100, 80));
    }
}
