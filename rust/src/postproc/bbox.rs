//! Bounding boxes and IoU.

/// An axis-aligned box in normalized [0,1] image coordinates,
/// center-size parameterization (YOLO convention).
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct BBox {
    pub cx: f32,
    pub cy: f32,
    pub w: f32,
    pub h: f32,
}

impl BBox {
    pub fn new(cx: f32, cy: f32, w: f32, h: f32) -> Self {
        Self { cx, cy, w, h }
    }

    pub fn x0(&self) -> f32 {
        self.cx - self.w / 2.0
    }
    pub fn y0(&self) -> f32 {
        self.cy - self.h / 2.0
    }
    pub fn x1(&self) -> f32 {
        self.cx + self.w / 2.0
    }
    pub fn y1(&self) -> f32 {
        self.cy + self.h / 2.0
    }

    pub fn area(&self) -> f32 {
        self.w.max(0.0) * self.h.max(0.0)
    }

    /// Intersection-over-union.
    pub fn iou(&self, other: &BBox) -> f32 {
        let ix = (self.x1().min(other.x1()) - self.x0().max(other.x0())).max(0.0);
        let iy = (self.y1().min(other.y1()) - self.y0().max(other.y0())).max(0.0);
        let inter = ix * iy;
        let union = self.area() + other.area() - inter;
        if union <= 0.0 {
            0.0
        } else {
            inter / union
        }
    }
}

/// A scored, classified detection.
#[derive(Debug, Clone, Copy)]
pub struct Detection {
    pub bbox: BBox,
    pub score: f32,
    pub class: usize,
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn iou_identical_is_one() {
        let b = BBox::new(0.5, 0.5, 0.2, 0.2);
        assert!((b.iou(&b) - 1.0).abs() < 1e-6);
    }

    #[test]
    fn iou_disjoint_is_zero() {
        let a = BBox::new(0.2, 0.2, 0.1, 0.1);
        let b = BBox::new(0.8, 0.8, 0.1, 0.1);
        assert_eq!(a.iou(&b), 0.0);
    }

    #[test]
    fn iou_half_overlap() {
        // Two unit-width boxes offset by half a width: inter = 0.5·area,
        // union = 1.5·area → IoU = 1/3.
        let a = BBox::new(0.5, 0.5, 0.2, 0.2);
        let b = BBox::new(0.6, 0.5, 0.2, 0.2);
        assert!((a.iou(&b) - 1.0 / 3.0).abs() < 1e-5);
    }

    #[test]
    fn iou_symmetric() {
        let a = BBox::new(0.4, 0.4, 0.3, 0.2);
        let b = BBox::new(0.5, 0.45, 0.2, 0.3);
        assert!((a.iou(&b) - b.iou(&a)).abs() < 1e-7);
    }
}
