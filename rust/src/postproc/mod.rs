//! Post-processing: box decoding output → NMS → detections, plus the mAP
//! metric (the paper's "second part" of the model, Section IV-D — runs on
//! the PS, never on the accelerator).

pub mod bbox;
pub mod map;
pub mod nms;

pub use bbox::{BBox, Detection};
pub use map::{mean_average_precision, GroundTruth};
pub use nms::{decode_and_nms, nms, NmsConfig};
