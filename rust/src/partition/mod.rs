//! Dtype-based model partitioning (Sections IV-D and V-B, Figure 6).
//!
//! After quantization the graph has two clearly distinct parts: the int8
//! "main part" (accelerator-eligible) and the float NMS-preparation tail.
//! The partitioner splits on the Quantize/Dequantize boundary — exactly
//! the paper's criterion ("separating the model into two parts based on
//! the data type used on each of them") — and the placement evaluator
//! prices each of the four (main, post) × (PS, PL) placements, including
//! the shared-memory transfer over the ACP port.

use crate::fpga::zynq::ZynqSoc;
use crate::gemmini::config::GemminiConfig;
use crate::ir::{DType, Graph, NodeId, Op};

/// Result of splitting a quantized graph.
#[derive(Debug, Clone)]
pub struct Partition {
    /// Nodes of the int8 main part (including Quantize boundary nodes).
    pub main: Vec<NodeId>,
    /// Nodes of the float tail (Dequantize onwards).
    pub tail: Vec<NodeId>,
    /// Bytes crossing the boundary per inference (the head tensors).
    pub boundary_bytes: usize,
    /// GOP of the main part, GFLOP of the tail.
    pub main_gop: f64,
    pub tail_gflop: f64,
}

/// Split a quantized graph by datatype.
pub fn partition_graph(g: &Graph) -> Partition {
    let mut main = Vec::new();
    let mut tail = Vec::new();
    let mut boundary_bytes = 0usize;
    for n in &g.nodes {
        if matches!(n.op, Op::Input | Op::Const) {
            continue;
        }
        let is_int8 = n.output.dtype == DType::Int8 || matches!(n.op, Op::Quantize);
        if is_int8 {
            main.push(n.id);
        } else {
            tail.push(n.id);
            if matches!(n.op, Op::Dequantize) {
                boundary_bytes += g.node(n.inputs[0]).output.size_bytes();
            }
        }
    }
    // Main GOP: conv/dense MACs in the int8 region ×2.
    let mut macs = 0u64;
    for &id in &main {
        let n = g.node(id);
        if let Op::Conv2d { kernel, .. } = &n.op {
            let ic = *g.node(n.inputs[1]).output.shape.last().unwrap();
            macs += (n.output.shape[1] * n.output.shape[2] * n.output.shape[3]
                * kernel
                * kernel
                * ic) as u64;
        }
    }
    // Tail GFLOP: decode + NMS arithmetic on the candidate boxes.
    let mut boxes = 0usize;
    let mut classes = 1usize;
    for &id in &tail {
        if let Op::BoxDecode { num_classes, .. } = g.node(id).op {
            boxes += g.node(id).output.shape[1];
            classes = num_classes;
        }
    }
    Partition {
        main,
        tail,
        boundary_bytes,
        main_gop: macs as f64 * 2.0 / 1e9,
        tail_gflop: crate::postproc::nms::postproc_gflop(boxes, classes),
    }
}

/// Where a part runs (Figure 6's axes).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Side {
    /// ARM cores (Processing System).
    Ps,
    /// FPGA fabric: the accelerator for int8 work, the RocketCore scalar
    /// for float work (Gemmini cannot run the tail's ops).
    Pl,
}

/// Latency breakdown of one placement.
#[derive(Debug, Clone)]
pub struct PlacementLatency {
    pub main: Side,
    pub post: Side,
    pub main_s: f64,
    pub post_s: f64,
    pub transfer_s: f64,
}

impl PlacementLatency {
    pub fn total_s(&self) -> f64 {
        self.main_s + self.post_s + self.transfer_s
    }

    pub fn label(&self) -> String {
        let s = |x: Side| match x {
            Side::Ps => "PS",
            Side::Pl => "PL",
        };
        format!("main={} post={}", s(self.main), s(self.post))
    }
}

/// RocketCore scalar float throughput (GFLOP/s): an in-order core at the
/// PL clock doing unvectorized float math — why running the tail "on the
/// PL takes a lot of time" (Section V-B).
fn rocket_gflops(cfg: &GemminiConfig) -> f64 {
    0.10 * cfg.clock_mhz / 100.0
}

/// Price one placement. `main_pl_s` is the tuned accelerator latency of
/// the main part (from the scheduler) — the other three cells derive from
/// the SoC model.
pub fn evaluate_placement(
    p: &Partition,
    soc: &ZynqSoc,
    cfg: &GemminiConfig,
    main_pl_s: f64,
    main: Side,
    post: Side,
) -> PlacementLatency {
    let main_s = match main {
        Side::Pl => main_pl_s,
        Side::Ps => soc.ps_int8_seconds(p.main_gop, 4),
    };
    let post_s = match post {
        Side::Ps => soc.ps_float_seconds(p.tail_gflop, 1),
        Side::Pl => p.tail_gflop / rocket_gflops(cfg),
    };
    // Transfer only when the two parts run on different sides.
    let transfer_s =
        if main != post { soc.transfer_seconds(p.boundary_bytes) } else { 0.0 };
    PlacementLatency { main, post, main_s, post_s, transfer_s }
}

/// All four placements, best-first (the Figure 6 bars).
pub fn all_placements(
    p: &Partition,
    soc: &ZynqSoc,
    cfg: &GemminiConfig,
    main_pl_s: f64,
) -> Vec<PlacementLatency> {
    let mut v: Vec<PlacementLatency> = [
        (Side::Pl, Side::Ps),
        (Side::Pl, Side::Pl),
        (Side::Ps, Side::Ps),
        (Side::Ps, Side::Pl),
    ]
    .iter()
    .map(|&(m, q)| evaluate_placement(p, soc, cfg, main_pl_s, m, q))
    .collect();
    v.sort_by(|a, b| a.total_s().partial_cmp(&b.total_s()).unwrap());
    v
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::fpga::resources::Board;
    use crate::ir::graph::WeightData;
    use crate::ir::interp::Value;
    use crate::passes::{quantize_graph, replace_activations, QuantizeOptions};
    use crate::util::Rng;
    use crate::workload::{yolov7_tiny, ModelVariant};

    fn quantized_yolo() -> Graph {
        let mut rng = Rng::new(11);
        let mut g = yolov7_tiny(160, ModelVariant::Pruned88, 4);
        replace_activations(&mut g);
        for w in g.weights.values_mut() {
            if let WeightData::F32(v) = w {
                for x in v.iter_mut() {
                    *x = rng.normal() as f32 * 0.05;
                }
            }
        }
        let input = Value::new(
            vec![1, 160, 160, 3],
            (0..160 * 160 * 3).map(|_| rng.f64() as f32).collect(),
        );
        quantize_graph(&g, &[vec![input]], &QuantizeOptions::default())
    }

    #[test]
    fn split_is_clean_and_complete() {
        let q = quantized_yolo();
        let p = partition_graph(&q);
        // Main holds all 58 convs; tail holds the 3 decodes.
        let convs_in_main = p
            .main
            .iter()
            .filter(|&&id| matches!(q.node(id).op, Op::Conv2d { .. }))
            .count();
        assert_eq!(convs_in_main, 58);
        let decodes_in_tail = p
            .tail
            .iter()
            .filter(|&&id| matches!(q.node(id).op, Op::BoxDecode { .. }))
            .count();
        assert_eq!(decodes_in_tail, 3);
        assert!(p.boundary_bytes > 0);
        assert!(p.main_gop > 0.0);
        assert!(p.tail_gflop > 0.0);
        // Main part dominates compute (paper's premise).
        assert!(p.main_gop > 10.0 * p.tail_gflop);
    }

    #[test]
    fn mixed_placement_wins_figure6() {
        let q = quantized_yolo();
        let p = partition_graph(&q);
        let soc = ZynqSoc::new(Board::Zcu102);
        let cfg = GemminiConfig::ours_zcu102();
        // Tuned accelerator latency: ~100 GOP/s effective on the main part
        // (the tuner's typical outcome for this config).
        let main_pl_s = p.main_gop / 100.0;
        let placements = all_placements(&p, &soc, &cfg, main_pl_s);
        // Best: main on PL, post on PS (the paper's mixed deployment).
        assert_eq!(placements[0].main, Side::Pl);
        assert_eq!(placements[0].post, Side::Ps);
        // Worst for the post-processing: PL (scalar RocketCore).
        let pl_pl = placements.iter().find(|p| p.main == Side::Pl && p.post == Side::Pl).unwrap();
        let pl_ps = &placements[0];
        assert!(pl_pl.post_s > 5.0 * pl_ps.post_s);
    }

    #[test]
    fn transfer_cost_negligible() {
        // Paper: "the cost is negligible and can be ignored".
        let q = quantized_yolo();
        let p = partition_graph(&q);
        let soc = ZynqSoc::new(Board::Zcu102);
        let cfg = GemminiConfig::ours_zcu102();
        let best = &all_placements(&p, &soc, &cfg, p.main_gop / 100.0)[0];
        assert!(best.transfer_s < 0.02 * best.total_s(), "{best:?}");
    }
}
