//! Zynq UltraScale+ SoC model: the PS (ARM cores) / PL (FPGA) split and
//! the shared-memory path between them.
//!
//! Section IV-D / V-B: the paper runs the int8 main graph on the PL
//! (Gemmini) and the float NMS tail on the PS (Cortex-A53s), moving
//! intermediate tensors through shared DRAM via the ACP port — a cost the
//! paper measures as "negligible". We model it explicitly so the Figure 6
//! bench can show it is indeed negligible rather than assume it.


use super::resources::Board;

/// PS-side (ARM Cortex-A53 quad) parameters.
#[derive(Debug, Clone, Copy)]
pub struct PsModel {
    /// Core clock, MHz (1200 on both boards' A53 clusters).
    pub clock_mhz: f64,
    pub cores: usize,
    /// Sustained float GFLOP/s for NEON f32 code (per core).
    pub gflops_per_core: f64,
    /// Sustained int8 GOP/s per core for quantized NN kernels.
    pub int8_gops_per_core: f64,
}

/// The heterogeneous SoC: PS + PL + the ACP shared-memory path.
#[derive(Debug, Clone, Copy)]
pub struct ZynqSoc {
    pub board: Board,
    pub ps: PsModel,
    /// ACP/HPC port bandwidth between PL and PS-coherent DRAM, GB/s.
    pub acp_bandwidth_gbs: f64,
    /// One-off synchronization latency per transfer, microseconds.
    pub acp_latency_us: f64,
}

impl ZynqSoc {
    pub fn new(board: Board) -> Self {
        Self {
            board,
            ps: PsModel {
                clock_mhz: 1200.0,
                cores: 4,
                // A53 NEON: 2×128-bit FMA-ish pipes in practice ~2.4 GFLOP/s
                // sustained on NN post-processing code.
                gflops_per_core: 2.4,
                int8_gops_per_core: 7.0,
            },
            // HPC0 port, 128-bit @ ~300 MHz effective.
            acp_bandwidth_gbs: 4.2,
            acp_latency_us: 3.0,
        }
    }

    /// Seconds to move `bytes` from PL-visible DRAM to PS caches (or back).
    /// Because both sides share the same physical DRAM and the ACP keeps
    /// coherence, this is a cache-maintenance + burst-read cost, not a copy
    /// of the whole tensor over a slow link.
    pub fn transfer_seconds(&self, bytes: usize) -> f64 {
        self.acp_latency_us * 1e-6 + bytes as f64 / (self.acp_bandwidth_gbs * 1e9)
    }

    /// Seconds for the PS to execute `gflop` of float work, assuming the
    /// post-processing parallelizes over `par` cores.
    pub fn ps_float_seconds(&self, gflop: f64, par: usize) -> f64 {
        let cores = par.min(self.ps.cores).max(1);
        gflop / (self.ps.gflops_per_core * cores as f64)
    }

    /// Seconds for the PS to execute `gop` of int8 NN work (the
    /// "main part on PS" scenario of Figure 6).
    pub fn ps_int8_seconds(&self, gop: f64, par: usize) -> f64 {
        let cores = par.min(self.ps.cores).max(1);
        gop / (self.ps.int8_gops_per_core * cores as f64)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn transfer_cost_is_microseconds_for_head_tensors() {
        // The three YOLO head tensors at 480×480 ≈ 1.1 MB int8 total.
        let soc = ZynqSoc::new(Board::Zcu102);
        let t = soc.transfer_seconds(1_100_000);
        assert!(t < 0.5e-3, "transfer {t}s should be ≪ 1 ms"); // negligible vs ~100 ms inference
    }

    #[test]
    fn ps_float_parallelizes() {
        let soc = ZynqSoc::new(Board::Zcu102);
        let t1 = soc.ps_float_seconds(1.0, 1);
        let t4 = soc.ps_float_seconds(1.0, 4);
        assert!((t1 / t4 - 4.0).abs() < 1e-9);
    }

    #[test]
    fn ps_int8_slower_than_accelerator() {
        // PS quad int8 ≈ 28 GOP/s vs Gemmini ours peak 307 GOP/s.
        let soc = ZynqSoc::new(Board::Zcu102);
        let ps = soc.ps_int8_seconds(7.0, 4);
        let pl_peak = 7.0 / crate::gemmini::GemminiConfig::ours_zcu102().peak_gops();
        assert!(ps > 5.0 * pl_peak);
    }
}
