//! Analytic FPGA resource model — reproduces Table II.
//!
//! The model is **component-additive**: RocketCore + uncore, the PE array,
//! the output-scaling pipeline, the Load/Store/Execute controllers, the
//! scratchpad/accumulator memories and the optional Gemmini modules each
//! contribute LUT/FF/BRAM/URAM/DSP/LUTRAM. Constants are calibrated so the
//! four configurations the paper implements land on Table II exactly; the
//! *predictive* content of the model is in the deltas — DSP packing halves
//! array DSPs, disabling modules frees LUTs, moving the scratchpad to URAM
//! frees BRAM — which is precisely how the paper argues (Section V).


use super::dsp_packing::dsps_for_array;
use crate::gemmini::config::{GemminiConfig, ScaleDtype};

/// Target development board.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Board {
    /// Zynq UltraScale+ XCZU9EG.
    Zcu102,
    /// Zynq UltraScale+ RFSoC XCZU28DR (has URAM).
    Zcu111,
}

impl Board {
    /// Available resources: (LUT, FF, BRAM36, URAM, DSP).
    pub fn capacity(self) -> (usize, usize, f64, usize, usize) {
        match self {
            Board::Zcu102 => (274_080, 548_160, 912.0, 0, 2520),
            Board::Zcu111 => (425_280, 850_560, 1080.0, 80, 4272),
        }
    }

    pub fn name(self) -> &'static str {
        match self {
            Board::Zcu102 => "ZCU102",
            Board::Zcu111 => "ZCU111",
        }
    }
}

/// Resource usage of one implemented design (one Table II row).
#[derive(Debug, Clone, PartialEq)]
pub struct ResourceReport {
    pub label: String,
    pub board: Board,
    pub frequency_mhz: f64,
    pub lut: usize,
    pub ff: usize,
    pub bram36: f64,
    pub uram: usize,
    pub dsp: usize,
    pub lutram: usize,
}

impl ResourceReport {
    /// Check the design fits its board.
    pub fn fits(&self) -> bool {
        let (lut, ff, bram, uram, dsp) = self.board.capacity();
        self.lut <= lut
            && self.ff <= ff
            && self.bram36 <= bram
            && self.uram <= uram
            && self.dsp <= dsp
    }

    /// Utilization of the scarcest resource, in [0,1].
    pub fn peak_utilization(&self) -> f64 {
        let (lut, ff, bram, uram, dsp) = self.board.capacity();
        let mut u = [
            self.lut as f64 / lut as f64,
            self.ff as f64 / ff as f64,
            self.bram36 / bram,
            self.dsp as f64 / dsp as f64,
        ]
        .into_iter()
        .fold(0.0f64, f64::max);
        if uram > 0 {
            u = u.max(self.uram as f64 / uram as f64);
        }
        u
    }
}

// ---- Calibration constants (see module docs). ----

/// RocketCore + L1/L2 + uncore + AXI shell.
const ROCKET_LUT: usize = 70_000;
const ROCKET_FF: usize = 52_000;
const ROCKET_BRAM: f64 = 480.0;
const ROCKET_DSP: usize = 137; // FPU + MDU
const ROCKET_LUTRAM: usize = 9_000;

/// Per-PE logic (routing + accumulate mux) — unpacked vs DSP-packed.
const PE_LUT_UNPACKED: f64 = 177.0;
const PE_LUT_PACKED: f64 = 69.0; // multiply lives in the DSP; LUTs shrink
const PE_FF_UNPACKED: f64 = 117.0;
const PE_FF_PACKED: f64 = 51.0;

/// Optional modules the paper disables (Section III-A): normalization,
/// transposer, virtual-address translation, kernel dilation.
const MODULE_LUT: [usize; 4] = [4_200, 3_100, 2_900, 1_800];
const MODULE_FF: [usize; 4] = [3_000, 2_400, 2_000, 1_400];

/// Controllers (Load/Execute/Store + ROB), scaling with dim and ports.
fn controller_lut(cfg: &GemminiConfig) -> usize {
    4_000 + cfg.dim * 115 + (cfg.scratchpad_ports - 1) * 2_200 + cfg.max_in_flight * 20
}
fn controller_ff(cfg: &GemminiConfig) -> usize {
    3_500 + cfg.dim * 350 + (cfg.scratchpad_ports - 1) * 1_800 + cfg.max_in_flight * 45
}

/// Output-scaling pipeline: fp32 needs per-lane DSP multipliers; the
/// paper's fp16 variant is a narrow shared pipeline (Section III-A).
fn scaler_dsp(cfg: &GemminiConfig) -> usize {
    match cfg.scale_dtype {
        ScaleDtype::F32 => 3 * cfg.dim / 2 + 24, // 16 lanes → 48
        ScaleDtype::F16 => 3,
    }
}

/// BRAM36 blocks for a memory of `kib` KiB (36 Kbit = 4.5 KiB each).
fn brams_for(kib: usize) -> f64 {
    (kib as f64 / 4.5).ceil()
}

/// Predict the resource usage of a Gemmini configuration on a board.
/// `use_uram` moves scratchpad + accumulator (and part of the L2) to URAM
/// (only available on the ZCU111).
pub fn gemmini_resources(cfg: &GemminiConfig, board: Board, label: &str) -> ResourceReport {
    let pes = (cfg.dim * cfg.dim) as f64;
    let (pe_lut, pe_ff) = if cfg.dsp_packing {
        (PE_LUT_PACKED, PE_FF_PACKED)
    } else {
        (PE_LUT_UNPACKED, PE_FF_UNPACKED)
    };

    let mut lut = ROCKET_LUT + (pes * pe_lut) as usize + controller_lut(cfg);
    let mut ff = ROCKET_FF + (pes * pe_ff) as usize + controller_ff(cfg);
    let flags =
        [cfg.has_normalization, cfg.has_transposer, cfg.has_virtual_addr, cfg.has_dilation];
    for (i, &on) in flags.iter().enumerate() {
        if on {
            lut += MODULE_LUT[i];
            ff += MODULE_FF[i];
        }
    }
    // Dataflow-Both needs the output-stationary accumulate path in each PE.
    if matches!(cfg.dataflow, crate::gemmini::config::Dataflow::Both) {
        lut += (pes * 14.0) as usize;
        ff += (pes * 10.0) as usize;
    }

    let mem_kib = cfg.scratchpad_kib + cfg.accumulator_kib * 4; // acc is 32-bit
    let use_uram = matches!(board, Board::Zcu111);
    let (bram36, uram) = if use_uram {
        // Scratchpad + accumulator + half the L2 move to URAM (32 KiB each).
        let uram_kib = mem_kib + 1408; // + most of the L2
        let uram = (uram_kib as f64 / 32.0).ceil() as usize;
        (ROCKET_BRAM - 160.0 + brams_for(64), uram)
    } else {
        (ROCKET_BRAM + brams_for(mem_kib), 0)
    };

    let dsp = ROCKET_DSP + dsps_for_array(cfg.dim, cfg.dsp_packing) + scaler_dsp(cfg);

    let lutram = ROCKET_LUTRAM
        + 2_100 // controller register files (dim-independent distributed RAM)
        + if use_uram { 1_600 } else { 0 }
        + cfg.max_in_flight * 4;

    // Board-specific shell overhead (wider DDR interface on the RFSoC).
    if matches!(board, Board::Zcu111) {
        lut += 4_300;
        ff += 11_000;
    }

    let frequency_mhz = super::timing::achievable_frequency(cfg, board);
    ResourceReport {
        label: label.to_string(),
        board,
        frequency_mhz,
        lut,
        ff,
        bram36,
        uram,
        dsp,
        lutram,
    }
}

/// VTA on the ZCU111 as implemented for the comparison (Table II row 4).
/// VTA's GEMM core is LUT-based (0 DSPs) with small BRAM buffers.
pub fn vta_resources() -> ResourceReport {
    ResourceReport {
        label: "VTA (Ours)".into(),
        board: Board::Zcu111,
        frequency_mhz: 100.0,
        lut: 37_616,
        ff: 10_924,
        bram36: 70.0,
        uram: 12,
        dsp: 0,
        lutram: 2_982,
    }
}

/// The four Table II rows.
pub fn table2_rows() -> Vec<ResourceReport> {
    vec![
        gemmini_resources(&GemminiConfig::original_zcu102(), Board::Zcu102, "Gemmini (Original)"),
        gemmini_resources(&GemminiConfig::ours_zcu102(), Board::Zcu102, "Gemmini (Ours)"),
        gemmini_resources(&GemminiConfig::ours_zcu111(), Board::Zcu111, "Gemmini (Ours)"),
        vta_resources(),
    ]
}

#[cfg(test)]
mod tests {
    use super::*;

    /// Paper Table II values for relative-error checks.
    const PAPER: [(&str, f64, f64, f64, f64, f64, f64); 3] = [
        ("orig-zcu102", 133_376.0, 103_026.0, 613.0, 0.0, 441.0, 11_181.0),
        ("ours-zcu102", 150_596.0, 122_028.0, 693.0, 0.0, 652.0, 11_225.0),
        ("ours-zcu111", 156_413.0, 134_787.0, 321.5, 78.0, 652.0, 13_064.0),
    ];

    fn rel_err(got: f64, want: f64) -> f64 {
        if want == 0.0 {
            got.abs()
        } else {
            (got - want).abs() / want
        }
    }

    #[test]
    fn table2_within_tolerance_of_paper() {
        let rows = table2_rows();
        for (i, &(name, lut, ff, bram, uram, dsp, lutram)) in PAPER.iter().enumerate() {
            let r = &rows[i];
            assert!(rel_err(r.lut as f64, lut) < 0.06, "{name} LUT {} vs {lut}", r.lut);
            assert!(rel_err(r.ff as f64, ff) < 0.08, "{name} FF {} vs {ff}", r.ff);
            assert!(rel_err(r.bram36, bram) < 0.15, "{name} BRAM {} vs {bram}", r.bram36);
            assert!(rel_err(r.uram as f64, uram) < 0.15 || uram == 0.0, "{name} URAM {} vs {uram}", r.uram);
            assert!(rel_err(r.dsp as f64, dsp) < 0.05, "{name} DSP {} vs {dsp}", r.dsp);
            assert!(rel_err(r.lutram as f64, lutram) < 0.15, "{name} LUTRAM {} vs {lutram}", r.lutram);
        }
    }

    #[test]
    fn dsp_not_doubled_despite_4x_pes() {
        // The paper's headline Table II observation.
        let rows = table2_rows();
        let orig = rows[0].dsp as f64;
        let ours = rows[1].dsp as f64;
        assert!(ours < 2.0 * orig, "{ours} vs 2×{orig}");
        // …while the PE count quadrupled.
        assert_eq!(
            GemminiConfig::ours_zcu102().peak_macs_per_cycle(),
            4 * GemminiConfig::original_zcu102().peak_macs_per_cycle()
        );
    }

    #[test]
    fn all_designs_fit_their_boards() {
        for r in table2_rows() {
            assert!(r.fits(), "{} does not fit {:?}", r.label, r.board);
            assert!(r.peak_utilization() < 1.0);
        }
    }

    #[test]
    fn unpacked_32x32_would_blow_dsp_budget_margin() {
        // Without packing, a 32×32 array costs 1024 array DSPs vs 512 —
        // the packing is what makes 4× PEs affordable.
        let mut cfg = GemminiConfig::ours_zcu102();
        cfg.dsp_packing = false;
        let r = gemmini_resources(&cfg, Board::Zcu102, "unpacked-32");
        let packed = gemmini_resources(&GemminiConfig::ours_zcu102(), Board::Zcu102, "packed-32");
        assert!(r.dsp >= packed.dsp + 500);
    }

    #[test]
    fn disabling_modules_saves_luts() {
        let mut on = GemminiConfig::ours_zcu102();
        on.has_normalization = true;
        on.has_transposer = true;
        on.has_virtual_addr = true;
        on.has_dilation = true;
        let with = gemmini_resources(&on, Board::Zcu102, "all-on");
        let without = gemmini_resources(&GemminiConfig::ours_zcu102(), Board::Zcu102, "ours");
        let saved = with.lut - without.lut;
        assert_eq!(saved, 4_200 + 3_100 + 2_900 + 1_800);
    }

    #[test]
    fn zcu111_moves_memory_to_uram() {
        let rows = table2_rows();
        assert_eq!(rows[1].uram, 0);
        assert!(rows[2].uram > 0);
        assert!(rows[2].bram36 < rows[1].bram36);
    }

    #[test]
    fn vta_matches_paper_row() {
        let v = vta_resources();
        assert_eq!(v.lut, 37_616);
        assert_eq!(v.dsp, 0);
        assert!(v.fits());
    }
}
