//! FPGA mapping models (Section III-A of the paper).
//!
//! The paper's hardware contribution is making Gemmini *fit and go fast* on
//! Xilinx UltraScale+ parts: mapping PEs onto DSP48E2 slices, packing two
//! int8 weight multiplies per DSP, disabling unused modules, and narrowing
//! the output-scaling datatype. We cannot run Vivado here, so this module
//! provides an **analytic resource and timing model** calibrated against
//! the paper's own Table II — detailed enough that the resource deltas
//! (packing halves DSP usage; bigger arrays raise LUT/FF/BRAM) follow from
//! the same arithmetic the paper argues with.

pub mod dsp_packing;
pub mod resources;
pub mod timing;
pub mod zynq;

pub use resources::{Board, ResourceReport};
pub use zynq::ZynqSoc;
