//! The DSP-packing technique (Sommer et al., FPL 2022; Section III-A /
//! Figure 1 of the paper).
//!
//! A DSP48E2 computes `P = (A + D) × B + C` with a 27×18-bit multiplier.
//! Two 8-bit weights `w0, w1` are packed into one 27-bit `A + D` operand
//! with a guard band, multiplied by one shared 8-bit activation `a`, and
//! the two 16-bit products recovered from disjoint bit fields of `P`
//! (plus a correction for the sign of the low product). This halves DSP
//! usage per PE pair: a 32×32 array needs 512 DSPs instead of 1024.
//!
//! This module implements the actual packing arithmetic (bit-exact, so we
//! can *prove* the halving claim is functionally sound, not just assert
//! it) and the resource accounting used by [`super::resources`].

/// Offset of the high product in the packed operand (bits). 18 gives a
/// 2-bit guard band over the 16-bit low product, enough to absorb the
/// low product's sign borrow.
const SHIFT: u32 = 18;

/// Pack two int8 weights into one 27-bit multiplier operand:
/// `packed = (w1 << SHIFT) + w0` (two's complement in 27 bits).
pub fn pack_weights(w0: i8, w1: i8) -> i64 {
    ((w1 as i64) << SHIFT) + w0 as i64
}

/// Multiply the packed operand by a shared int8 activation, as the DSP
/// does: one wide multiply.
pub fn packed_multiply(packed: i64, a: i8) -> i64 {
    packed * a as i64
}

/// Unpack the two products from the wide result.
/// `p0 = w0·a`, `p1 = w1·a`, both exact int16-range values.
pub fn unpack_products(p: i64) -> (i32, i32) {
    // Low field: bits [0, SHIFT). Interpret as signed SHIFT-bit value.
    let mask = (1i64 << SHIFT) - 1;
    let mut lo = p & mask;
    if lo >= (1i64 << (SHIFT - 1)) {
        lo -= 1i64 << SHIFT;
    }
    // High field: remove the (sign-extended) low part, then shift.
    let hi = (p - lo) >> SHIFT;
    (hi as i32, lo as i32)
}

/// Multiply one activation by two weights using the packed scheme;
/// returns `(hi, lo)` = `(w1·a, w0·a)`.
pub fn dsp_pair_mac(a: i8, w0: i8, w1: i8) -> (i32, i32) {
    let (p1, p0) = unpack_products(packed_multiply(pack_weights(w0, w1), a));
    (p1, p0)
}

/// DSPs required for a `dim × dim` int8 PE array.
pub fn dsps_for_array(dim: usize, packed: bool) -> usize {
    let pes = dim * dim;
    if packed {
        pes / 2
    } else {
        pes
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn exhaustive_pair_products() {
        // Bit-exact over the full int8 × int8 × int8 cube is 2^24 ≈ 16M —
        // too slow for a unit test; sample a dense sub-lattice instead
        // (every 7th/13th/17th value) plus all extremes.
        let mut vals: Vec<i8> = (-128i16..=127).step_by(7).map(|v| v as i8).collect();
        vals.extend([-128, -1, 0, 1, 127]);
        for &a in &vals {
            for &w0 in &vals {
                for &w1 in &vals {
                    let (p1, p0) = dsp_pair_mac(a, w0, w1);
                    assert_eq!(p0, w0 as i32 * a as i32, "a={a} w0={w0} w1={w1}");
                    assert_eq!(p1, w1 as i32 * a as i32, "a={a} w0={w0} w1={w1}");
                }
            }
        }
    }

    #[test]
    fn extreme_values_exact() {
        for (a, w0, w1) in [
            (-128i8, -128i8, -128i8),
            (127, 127, 127),
            (-128, 127, -128),
            (127, -128, 127),
            (-1, -1, -1),
        ] {
            let (p1, p0) = dsp_pair_mac(a, w0, w1);
            assert_eq!(p0, w0 as i32 * a as i32);
            assert_eq!(p1, w1 as i32 * a as i32);
        }
    }

    #[test]
    fn packed_operand_fits_27_bits() {
        // DSP48E2 A:D pre-adder result is 27 bits signed.
        for (w0, w1) in [(-128i8, -128i8), (127, 127), (-128, 127), (127, -128)] {
            let p = pack_weights(w0, w1);
            assert!(p.abs() < (1 << 26), "packed {p} exceeds 27-bit signed");
        }
    }

    #[test]
    fn halves_dsp_usage() {
        assert_eq!(dsps_for_array(16, false), 256);
        assert_eq!(dsps_for_array(16, true), 128);
        assert_eq!(dsps_for_array(32, false), 1024);
        assert_eq!(dsps_for_array(32, true), 512);
    }

    #[test]
    fn paper_headline_4x_pes_under_2x_dsps() {
        // Table II: our 32×32 packed design uses 652 DSPs total vs 441 for
        // the 16×16 unpacked original — "not even doubled" despite 4× PEs.
        // The array-only numbers: 512 packed vs 256 unpacked.
        let orig_array = dsps_for_array(16, false);
        let ours_array = dsps_for_array(32, true);
        assert!(ours_array < 2 * orig_array + 1);
    }
}
