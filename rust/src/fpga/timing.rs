//! Frequency model: which clock a configuration closes timing at.
//!
//! The paper's modifications raise the Gemmini clock from 100 MHz to
//! 150 MHz on the ZCU102 (167 MHz on the faster ZCU111 speed grade):
//! mapping PE multiplies onto DSP48E2 hard blocks shortens the critical
//! path, and the deeper scratchpad read pipeline (Table III: read delay
//! 4 → 8) breaks the SRAM-to-array path.

use super::resources::Board;
use crate::gemmini::config::{GemminiConfig, ScaleDtype};

/// Critical-path estimate in ns for the configuration's slowest stage.
pub fn critical_path_ns(cfg: &GemminiConfig) -> f64 {
    // LUT-fabric int8 multiply + accumulate chain: ~9 ns. A DSP48E2 does
    // the same multiply in its hard block: ~4.4 ns including routing.
    let pe_path: f64 = if cfg.dsp_packing { 4.4 } else { 9.0 };
    // Scratchpad read: an N-stage pipeline divides the SRAM+routing delay.
    // 4 stages leave ~10 ns on a big array's fan-out; 8 stages ~5.2 ns.
    let fanout_penalty = (cfg.dim as f64 / 16.0).sqrt();
    let sp_path = 36.0 * fanout_penalty / cfg.scratchpad_read_delay as f64;
    // fp32 scaling pipeline is long unless narrowed to fp16.
    let scale_path = match cfg.scale_dtype {
        ScaleDtype::F32 => 9.5,
        ScaleDtype::F16 => 5.5,
    };
    pe_path.max(sp_path).max(scale_path)
}

/// Achievable clock in MHz, quantized to the PLL steps the boards use.
pub fn achievable_frequency(cfg: &GemminiConfig, board: Board) -> f64 {
    // ZCU111 (RFSoC, -2 speed grade) is ~11% faster than ZCU102 (-2).
    let grade = match board {
        Board::Zcu102 => 1.0,
        Board::Zcu111 => 1.11,
    };
    let f = 1000.0 / critical_path_ns(cfg) * grade;
    // Snap down to the nearest step the paper's designs used.
    let steps = [50.0, 75.0, 100.0, 125.0, 150.0, 167.0, 200.0, 242.0];
    let mut best = steps[0];
    for &s in &steps {
        if s <= f + 1e-9 {
            best = s;
        }
    }
    best
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn original_closes_at_100() {
        let f = achievable_frequency(&GemminiConfig::original_zcu102(), Board::Zcu102);
        assert_eq!(f, 100.0);
    }

    #[test]
    fn ours_closes_at_150_on_zcu102() {
        let f = achievable_frequency(&GemminiConfig::ours_zcu102(), Board::Zcu102);
        assert_eq!(f, 150.0);
    }

    #[test]
    fn ours_closes_at_167_on_zcu111() {
        let f = achievable_frequency(&GemminiConfig::ours_zcu111(), Board::Zcu111);
        assert_eq!(f, 167.0);
    }

    #[test]
    fn shallow_pipeline_blocks_high_clock_on_big_array() {
        // A 32×32 array with the default 4-deep read pipeline can't reach
        // 150 MHz — the paper's read-delay increase is what unlocks it.
        let mut cfg = GemminiConfig::ours_zcu102();
        cfg.scratchpad_read_delay = 4;
        let f = achievable_frequency(&cfg, Board::Zcu102);
        assert!(f < 150.0, "got {f}");
    }

    #[test]
    fn fp32_scaler_limits_clock() {
        let mut cfg = GemminiConfig::ours_zcu102();
        cfg.scale_dtype = ScaleDtype::F32;
        let f = achievable_frequency(&cfg, Board::Zcu102);
        assert!(f < 150.0, "got {f}");
    }

    #[test]
    fn config_frequencies_consistent_with_table2() {
        // The frequencies baked into the configs match the timing model.
        let c102 = GemminiConfig::ours_zcu102();
        assert_eq!(achievable_frequency(&c102, Board::Zcu102), c102.clock_mhz);
        let c111 = GemminiConfig::ours_zcu111();
        assert_eq!(achievable_frequency(&c111, Board::Zcu111), c111.clock_mhz);
        let orig = GemminiConfig::original_zcu102();
        assert_eq!(achievable_frequency(&orig, Board::Zcu102), orig.clock_mhz);
    }
}
