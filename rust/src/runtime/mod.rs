//! PJRT runtime: load and execute the AOT-compiled JAX/Pallas artifacts.
//!
//! The deployed system is self-contained after `make artifacts`: this
//! module loads `artifacts/model.hlo.txt` (HLO *text* — the interchange
//! format the image's xla_extension 0.5.1 accepts, see
//! /opt/xla-example/README.md), compiles it once on the PJRT CPU client,
//! and executes it from the request path. Python never runs at inference
//! time — exactly the paper's deployment contract (the TVM-generated C
//! code on the RISC-V side).

use anyhow::{Context, Result};

use crate::ir::interp::Value;
use crate::util::json::Json;

/// Metadata emitted next to each artifact by `aot.py`.
#[derive(Debug, Clone)]
pub struct ArtifactMeta {
    pub input_shape: Vec<usize>,
    pub output_shape: Vec<usize>,
    pub num_anchors: usize,
    pub num_classes: usize,
    /// Shapes of the weight parameters the executable takes after the
    /// image (quantized values carried as f32 — see `aot.py`).
    pub param_shapes: Vec<Vec<usize>>,
}

impl ArtifactMeta {
    pub fn load(path: &str) -> Result<Self> {
        let text = std::fs::read_to_string(path).with_context(|| format!("reading {path}"))?;
        let j = Json::parse(&text).map_err(|e| anyhow::anyhow!("parsing {path}: {e}"))?;
        let shape = |key: &str| -> Result<Vec<usize>> {
            Ok(j.get(key)
                .and_then(|v| v.as_arr())
                .ok_or_else(|| anyhow::anyhow!("missing {key}"))?
                .iter()
                .map(|v| v.as_f64().unwrap_or(0.0) as usize)
                .collect())
        };
        let param_shapes = j
            .get("param_shapes")
            .and_then(|v| v.as_arr())
            .map(|arr| {
                arr.iter()
                    .map(|s| {
                        s.as_arr()
                            .unwrap_or(&[])
                            .iter()
                            .map(|d| d.as_f64().unwrap_or(0.0) as usize)
                            .collect()
                    })
                    .collect()
            })
            .unwrap_or_default();
        Ok(Self {
            input_shape: shape("input")?,
            output_shape: shape("output")?,
            num_anchors: j.get("num_anchors").and_then(|v| v.as_f64()).unwrap_or(2.0) as usize,
            num_classes: j.get("num_classes").and_then(|v| v.as_f64()).unwrap_or(4.0) as usize,
            param_shapes,
        })
    }
}

/// A compiled model on the PJRT CPU client.
pub struct Executor {
    exe: xla::PjRtLoadedExecutable,
    pub meta: ArtifactMeta,
    /// Weight literals loaded once (fed after the image each execute).
    params: Vec<xla::Literal>,
}

impl Executor {
    /// Load + compile `artifacts/<name>.hlo.txt` (+ `.meta.json`).
    pub fn load(hlo_path: &str) -> Result<Self> {
        let client = xla::PjRtClient::cpu().context("creating PJRT CPU client")?;
        let proto = xla::HloModuleProto::from_text_file(hlo_path)
            .with_context(|| format!("parsing HLO text {hlo_path}"))?;
        let comp = xla::XlaComputation::from_proto(&proto);
        let exe = client.compile(&comp).context("PJRT compile")?;
        let meta_path = hlo_path.replace(".hlo.txt", ".meta.json");
        let meta = ArtifactMeta::load(&meta_path)?;
        // Weight parameters (optional: absent for weightless artifacts).
        let mut params = Vec::new();
        if !meta.param_shapes.is_empty() {
            let ppath = hlo_path.replace(".hlo.txt", ".params.json");
            let text =
                std::fs::read_to_string(&ppath).with_context(|| format!("reading {ppath}"))?;
            let j = Json::parse(&text).map_err(|e| anyhow::anyhow!("parsing {ppath}: {e}"))?;
            let arrays = j
                .get("params")
                .and_then(|v| v.as_arr())
                .ok_or_else(|| anyhow::anyhow!("missing params"))?;
            anyhow::ensure!(arrays.len() == meta.param_shapes.len(), "param count mismatch");
            for (vals, shape) in arrays.iter().zip(&meta.param_shapes) {
                let v: Vec<f32> = vals
                    .as_arr()
                    .unwrap_or(&[])
                    .iter()
                    .map(|x| x.as_f64().unwrap_or(0.0) as f32)
                    .collect();
                anyhow::ensure!(v.len() == shape.iter().product::<usize>(), "param size mismatch");
                let dims: Vec<i64> = shape.iter().map(|&d| d as i64).collect();
                params.push(xla::Literal::vec1(&v).reshape(&dims)?);
            }
        }
        Ok(Self { exe, meta, params })
    }

    /// Execute the main part on one image (`Value` NHWC f32 matching the
    /// artifact's input shape). Returns the dequantized head map.
    pub fn run(&self, image: &Value) -> Result<Value> {
        anyhow::ensure!(
            image.shape == self.meta.input_shape,
            "input shape {:?} != artifact {:?}",
            image.shape,
            self.meta.input_shape
        );
        let dims: Vec<i64> = image.shape.iter().map(|&d| d as i64).collect();
        let lit = xla::Literal::vec1(&image.f).reshape(&dims)?;
        let mut args = vec![lit];
        for p in &self.params {
            args.push(p.clone());
        }
        let result = self.exe.execute::<xla::Literal>(&args)?[0][0].to_literal_sync()?;
        // aot.py lowers with return_tuple=True: unwrap the 1-tuple.
        let out = result.to_tuple1()?;
        let values = out.to_vec::<f32>()?;
        anyhow::ensure!(
            values.len() == self.meta.output_shape.iter().product::<usize>(),
            "output size mismatch"
        );
        Ok(Value::new(self.meta.output_shape.clone(), values))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    /// Integration tests that need artifacts live in rust/tests/
    /// (they require `make artifacts`); here only the meta parser.
    #[test]
    fn meta_parses() {
        let dir = std::env::temp_dir().join("ge_meta_test.json");
        std::fs::write(
            &dir,
            r#"{"input":[1,96,96,3],"output":[1,12,12,18],"num_anchors":2,"num_classes":4}"#,
        )
        .unwrap();
        let m = ArtifactMeta::load(dir.to_str().unwrap()).unwrap();
        assert_eq!(m.input_shape, vec![1, 96, 96, 3]);
        assert_eq!(m.output_shape, vec![1, 12, 12, 18]);
        assert_eq!(m.num_classes, 4);
    }

    #[test]
    fn meta_missing_file_errors() {
        assert!(ArtifactMeta::load("/nonexistent/meta.json").is_err());
    }
}
