//! PJRT runtime: load and execute the AOT-compiled JAX/Pallas artifacts.
//!
//! The deployed system is self-contained after `make artifacts`: this
//! module loads `artifacts/model.hlo.txt` (HLO *text* — the interchange
//! format the image's xla_extension 0.5.1 accepts, see
//! /opt/xla-example/README.md), compiles it once on the PJRT CPU client,
//! and executes it from the request path. Python never runs at inference
//! time — exactly the paper's deployment contract (the TVM-generated C
//! code on the RISC-V side).
//!
//! The PJRT executor depends on the deployment image's vendored `xla`
//! crate, which is not available on a plain offline checkout. It is gated
//! behind the `pjrt` cargo feature: without it, [`ArtifactMeta`] still
//! parses artifact metadata (pure Rust) and [`Executor`] is a stub whose
//! `load` returns an error, so every caller that already handles missing
//! artifacts degrades gracefully and `cargo test -q` passes without
//! `make artifacts`.

use std::fmt;

use crate::ir::interp::Value;
use crate::util::json::Json;

/// Runtime error (replaces `anyhow` so the default build has no external
/// dependencies).
#[derive(Debug)]
pub struct RuntimeError(pub String);

impl fmt::Display for RuntimeError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "{}", self.0)
    }
}

impl std::error::Error for RuntimeError {}

pub type Result<T> = std::result::Result<T, RuntimeError>;

fn err<T>(msg: impl Into<String>) -> Result<T> {
    Err(RuntimeError(msg.into()))
}

/// Metadata emitted next to each artifact by `aot.py`.
#[derive(Debug, Clone)]
pub struct ArtifactMeta {
    pub input_shape: Vec<usize>,
    pub output_shape: Vec<usize>,
    pub num_anchors: usize,
    pub num_classes: usize,
    /// Shapes of the weight parameters the executable takes after the
    /// image (quantized values carried as f32 — see `aot.py`).
    pub param_shapes: Vec<Vec<usize>>,
}

impl ArtifactMeta {
    pub fn load(path: &str) -> Result<Self> {
        let text = std::fs::read_to_string(path)
            .map_err(|e| RuntimeError(format!("reading {path}: {e}")))?;
        let j = Json::parse(&text).map_err(|e| RuntimeError(format!("parsing {path}: {e}")))?;
        let shape = |key: &str| -> Result<Vec<usize>> {
            match j.get(key).and_then(|v| v.as_arr()) {
                Some(arr) => {
                    Ok(arr.iter().map(|v| v.as_f64().unwrap_or(0.0) as usize).collect())
                }
                None => err(format!("missing {key}")),
            }
        };
        let param_shapes = j
            .get("param_shapes")
            .and_then(|v| v.as_arr())
            .map(|arr| {
                arr.iter()
                    .map(|s| {
                        s.as_arr()
                            .unwrap_or(&[])
                            .iter()
                            .map(|d| d.as_f64().unwrap_or(0.0) as usize)
                            .collect()
                    })
                    .collect()
            })
            .unwrap_or_default();
        Ok(Self {
            input_shape: shape("input")?,
            output_shape: shape("output")?,
            num_anchors: j.get("num_anchors").and_then(|v| v.as_f64()).unwrap_or(2.0) as usize,
            num_classes: j.get("num_classes").and_then(|v| v.as_f64()).unwrap_or(4.0) as usize,
            param_shapes,
        })
    }
}

/// A compiled model on the PJRT CPU client.
#[cfg(feature = "pjrt")]
pub struct Executor {
    exe: xla::PjRtLoadedExecutable,
    pub meta: ArtifactMeta,
    /// Weight literals loaded once (fed after the image each execute).
    params: Vec<xla::Literal>,
}

#[cfg(feature = "pjrt")]
impl Executor {
    /// Load + compile `artifacts/<name>.hlo.txt` (+ `.meta.json`).
    pub fn load(hlo_path: &str) -> Result<Self> {
        let wrap = |what: &str| move |e: xla::Error| RuntimeError(format!("{what}: {e}"));
        let client = xla::PjRtClient::cpu().map_err(wrap("creating PJRT CPU client"))?;
        let proto = xla::HloModuleProto::from_text_file(hlo_path)
            .map_err(|e| RuntimeError(format!("parsing HLO text {hlo_path}: {e}")))?;
        let comp = xla::XlaComputation::from_proto(&proto);
        let exe = client.compile(&comp).map_err(wrap("PJRT compile"))?;
        let meta_path = hlo_path.replace(".hlo.txt", ".meta.json");
        let meta = ArtifactMeta::load(&meta_path)?;
        // Weight parameters (optional: absent for weightless artifacts).
        let mut params = Vec::new();
        if !meta.param_shapes.is_empty() {
            let ppath = hlo_path.replace(".hlo.txt", ".params.json");
            let text = std::fs::read_to_string(&ppath)
                .map_err(|e| RuntimeError(format!("reading {ppath}: {e}")))?;
            let j = Json::parse(&text).map_err(|e| RuntimeError(format!("parsing {ppath}: {e}")))?;
            let arrays = match j.get("params").and_then(|v| v.as_arr()) {
                Some(a) => a,
                None => return err("missing params"),
            };
            if arrays.len() != meta.param_shapes.len() {
                return err("param count mismatch");
            }
            for (vals, shape) in arrays.iter().zip(&meta.param_shapes) {
                let v: Vec<f32> = vals
                    .as_arr()
                    .unwrap_or(&[])
                    .iter()
                    .map(|x| x.as_f64().unwrap_or(0.0) as f32)
                    .collect();
                if v.len() != shape.iter().product::<usize>() {
                    return err("param size mismatch");
                }
                let dims: Vec<i64> = shape.iter().map(|&d| d as i64).collect();
                params.push(
                    xla::Literal::vec1(&v).reshape(&dims).map_err(wrap("reshaping param"))?,
                );
            }
        }
        Ok(Self { exe, meta, params })
    }

    /// Execute the main part on one image (`Value` NHWC f32 matching the
    /// artifact's input shape). Returns the dequantized head map.
    pub fn run(&self, image: &Value) -> Result<Value> {
        if image.shape != self.meta.input_shape {
            return err(format!(
                "input shape {:?} != artifact {:?}",
                image.shape, self.meta.input_shape
            ));
        }
        let wrap = |what: &str| move |e: xla::Error| RuntimeError(format!("{what}: {e}"));
        let dims: Vec<i64> = image.shape.iter().map(|&d| d as i64).collect();
        let lit = xla::Literal::vec1(&image.f).reshape(&dims).map_err(wrap("reshaping input"))?;
        let mut args = vec![lit];
        for p in &self.params {
            args.push(p.clone());
        }
        let result = self.exe.execute::<xla::Literal>(&args).map_err(wrap("PJRT execute"))?[0][0]
            .to_literal_sync()
            .map_err(wrap("fetching result"))?;
        // aot.py lowers with return_tuple=True: unwrap the 1-tuple.
        let out = result.to_tuple1().map_err(wrap("unwrapping tuple"))?;
        let values = out.to_vec::<f32>().map_err(wrap("reading result"))?;
        if values.len() != self.meta.output_shape.iter().product::<usize>() {
            return err("output size mismatch");
        }
        Ok(Value::new(self.meta.output_shape.clone(), values))
    }
}

/// Stub executor for builds without the `pjrt` feature: `load` always
/// fails with a descriptive error, which every call site already treats
/// as "artifacts unavailable" (the same path taken before `make
/// artifacts` has run).
#[cfg(not(feature = "pjrt"))]
pub struct Executor {
    pub meta: ArtifactMeta,
}

#[cfg(not(feature = "pjrt"))]
impl Executor {
    pub fn load(hlo_path: &str) -> Result<Self> {
        err(format!(
            "cannot load {hlo_path}: built without the `pjrt` feature (the PJRT \
             executor needs the deployment image's vendored `xla` crate)"
        ))
    }

    pub fn run(&self, _image: &Value) -> Result<Value> {
        err("built without the `pjrt` feature")
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    /// Integration tests that need artifacts live in rust/tests/
    /// (they require `make artifacts`); here only the meta parser.
    #[test]
    fn meta_parses() {
        let dir = std::env::temp_dir().join("ge_meta_test.json");
        std::fs::write(
            &dir,
            r#"{"input":[1,96,96,3],"output":[1,12,12,18],"num_anchors":2,"num_classes":4}"#,
        )
        .unwrap();
        let m = ArtifactMeta::load(dir.to_str().unwrap()).unwrap();
        assert_eq!(m.input_shape, vec![1, 96, 96, 3]);
        assert_eq!(m.output_shape, vec![1, 12, 12, 18]);
        assert_eq!(m.num_classes, 4);
    }

    #[test]
    fn meta_missing_file_errors() {
        assert!(ArtifactMeta::load("/nonexistent/meta.json").is_err());
    }

    #[cfg(not(feature = "pjrt"))]
    #[test]
    fn stub_executor_reports_missing_feature() {
        let e = Executor::load("artifacts/model.hlo.txt").unwrap_err();
        assert!(e.to_string().contains("pjrt"), "{e}");
    }
}
