//! The operator graph.
//!
//! A flat arena of nodes; each node consumes tensors produced by earlier
//! nodes (SSA-ish, one output per node). Weight payloads are stored
//! out-of-band so passes can rewrite structure cheaply.

use std::collections::HashMap;


use super::op::Op;
use super::tensor::TensorMeta;

/// Node index in the graph arena.
pub type NodeId = usize;
/// A tensor is identified by the node that produces it.
pub type TensorId = usize;

/// Weight payload for a `Const` node.
#[derive(Debug, Clone, PartialEq)]
pub enum WeightData {
    F32(Vec<f32>),
    I8(Vec<i8>),
    I32(Vec<i32>),
}

impl WeightData {
    pub fn len(&self) -> usize {
        match self {
            WeightData::F32(v) => v.len(),
            WeightData::I8(v) => v.len(),
            WeightData::I32(v) => v.len(),
        }
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    pub fn as_f32(&self) -> Option<&[f32]> {
        match self {
            WeightData::F32(v) => Some(v),
            _ => None,
        }
    }

    pub fn as_i8(&self) -> Option<&[i8]> {
        match self {
            WeightData::I8(v) => Some(v),
            _ => None,
        }
    }
}

/// One operator instance.
#[derive(Debug, Clone, PartialEq)]
pub struct Node {
    pub id: NodeId,
    pub op: Op,
    /// Producer nodes of each input tensor, in positional order.
    pub inputs: Vec<TensorId>,
    /// Metadata of the single output tensor.
    pub output: TensorMeta,
}

/// An operator graph plus out-of-band weights.
#[derive(Debug, Clone, Default)]
pub struct Graph {
    pub name: String,
    pub nodes: Vec<Node>,
    /// Graph input node ids, in signature order.
    pub inputs: Vec<NodeId>,
    /// Graph output node ids, in signature order.
    pub outputs: Vec<NodeId>,
    /// Weight payloads keyed by Const node id.
    pub weights: HashMap<NodeId, WeightData>,
    /// Requantization arithmetic: `false` = float multiplier (TFLite
    /// reference / Gemmini fp scaling), `true` = TVM-style fixed-point
    /// (int32 multiplier + rounding shift). The framework-conversion pass
    /// flips this at the TVM import step (Table I's last column).
    pub requant_fixed_point: bool,
}

impl Graph {
    pub fn new(name: impl Into<String>) -> Self {
        Self { name: name.into(), ..Default::default() }
    }

    pub fn node(&self, id: NodeId) -> &Node {
        &self.nodes[id]
    }

    pub fn node_mut(&mut self, id: NodeId) -> &mut Node {
        &mut self.nodes[id]
    }

    /// Append a node; returns its id.
    pub fn push(&mut self, op: Op, inputs: Vec<TensorId>, output: TensorMeta) -> NodeId {
        let id = self.nodes.len();
        self.nodes.push(Node { id, op, inputs, output });
        id
    }

    /// Consumers of each node's output tensor.
    pub fn consumers(&self) -> Vec<Vec<NodeId>> {
        let mut out = vec![Vec::new(); self.nodes.len()];
        for n in &self.nodes {
            for &i in &n.inputs {
                out[i].push(n.id);
            }
        }
        out
    }

    /// Count nodes matching a predicate.
    pub fn count<F: Fn(&Node) -> bool>(&self, f: F) -> usize {
        self.nodes.iter().filter(|n| f(n)).count()
    }

    /// Total parameter count (elements across all Const weights).
    pub fn param_count(&self) -> usize {
        self.weights.values().map(|w| w.len()).sum()
    }

    /// Giga-operations per inference (MACs*2 for conv/dense), the paper's
    /// GOP unit for efficiency numbers.
    pub fn gops(&self) -> f64 {
        let mut macs = 0u64;
        for n in &self.nodes {
            match &n.op {
                Op::Conv2d { kernel, .. } => {
                    // output: NHWC. in_c from weight input shape [oc,kh,kw,ic].
                    let w = self.node(n.inputs[1]);
                    let ic = *w.output.shape.last().unwrap_or(&0);
                    let out_spatial: usize = n.output.shape[1] * n.output.shape[2];
                    let oc = n.output.shape[3];
                    macs += (out_spatial * oc * kernel * kernel * ic) as u64;
                }
                Op::Dense { out_features, .. } => {
                    let w = self.node(n.inputs[1]);
                    let inf = *w.output.shape.last().unwrap_or(&0);
                    macs += (*out_features * inf) as u64;
                }
                _ => {}
            }
        }
        (macs * 2) as f64 / 1e9
    }

    /// Validate structural invariants: input indices in range and acyclic
    /// (inputs reference strictly earlier nodes — the arena is topological
    /// by construction).
    pub fn validate(&self) -> Result<(), String> {
        for n in &self.nodes {
            for &i in &n.inputs {
                if i >= self.nodes.len() {
                    return Err(format!("node {} references missing tensor {}", n.id, i));
                }
                if i >= n.id {
                    return Err(format!("node {} references non-earlier tensor {}", n.id, i));
                }
            }
            match &n.op {
                Op::Const => {
                    if !self.weights.contains_key(&n.id) {
                        return Err(format!("const node {} has no weight payload", n.id));
                    }
                    let w = &self.weights[&n.id];
                    if w.len() != n.output.numel() {
                        return Err(format!(
                            "const node {} payload len {} != shape numel {}",
                            n.id,
                            w.len(),
                            n.output.numel()
                        ));
                    }
                }
                Op::Conv2d { .. } | Op::Dense { .. } => {
                    if n.inputs.len() < 2 {
                        return Err(format!("node {} ({}) missing weight input", n.id, n.op.mnemonic()));
                    }
                }
                Op::Concat => {
                    if n.inputs.len() < 2 {
                        return Err(format!("concat node {} has <2 inputs", n.id));
                    }
                }
                _ => {}
            }
        }
        for &o in &self.outputs {
            if o >= self.nodes.len() {
                return Err(format!("graph output {} out of range", o));
            }
        }
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::ir::{DType, Layout};

    fn meta(name: &str, shape: Vec<usize>) -> TensorMeta {
        TensorMeta::new(name, shape, DType::Float32, Layout::NHWC)
    }

    #[test]
    fn push_and_validate() {
        let mut g = Graph::new("t");
        let a = g.push(Op::Input, vec![], meta("a", vec![1, 4, 4, 3]));
        g.inputs.push(a);
        let w = g.push(Op::Const, vec![], meta("w", vec![8, 3, 3, 3]));
        g.weights.insert(w, WeightData::F32(vec![0.0; 8 * 3 * 3 * 3]));
        let c = g.push(
            Op::Conv2d {
                out_channels: 8,
                kernel: 3,
                stride: 1,
                padding: crate::ir::PaddingMode::Same,
                activation: crate::ir::ActivationKind::Relu,
                bias: false,
            },
            vec![a, w],
            meta("c", vec![1, 4, 4, 8]),
        );
        g.outputs.push(c);
        assert!(g.validate().is_ok());
        assert_eq!(g.param_count(), 8 * 27);
    }

    #[test]
    fn validate_catches_bad_const() {
        let mut g = Graph::new("t");
        let w = g.push(Op::Const, vec![], meta("w", vec![4]));
        g.weights.insert(w, WeightData::F32(vec![0.0; 3])); // wrong len
        assert!(g.validate().is_err());
    }

    #[test]
    fn validate_catches_forward_reference() {
        let mut g = Graph::new("t");
        // Manually construct a node referencing a later tensor.
        g.nodes.push(Node {
            id: 0,
            op: Op::Reshape,
            inputs: vec![1],
            output: meta("x", vec![1]),
        });
        g.nodes.push(Node { id: 1, op: Op::Input, inputs: vec![], output: meta("y", vec![1]) });
        assert!(g.validate().is_err());
    }

    #[test]
    fn gops_counts_conv_macs() {
        let mut g = Graph::new("t");
        let a = g.push(Op::Input, vec![], meta("a", vec![1, 10, 10, 16]));
        let w = g.push(Op::Const, vec![], meta("w", vec![32, 3, 3, 16]));
        g.weights.insert(w, WeightData::F32(vec![0.0; 32 * 9 * 16]));
        let _c = g.push(
            Op::Conv2d {
                out_channels: 32,
                kernel: 3,
                stride: 1,
                padding: crate::ir::PaddingMode::Same,
                activation: crate::ir::ActivationKind::None,
                bias: false,
            },
            vec![a, w],
            meta("c", vec![1, 10, 10, 32]),
        );
        // 10*10 spatial * 32 oc * 3*3*16 * 2
        let expect = (100 * 32 * 9 * 16 * 2) as f64 / 1e9;
        assert!((g.gops() - expect).abs() < 1e-12);
    }

    #[test]
    fn consumers_tracks_fanout() {
        let mut g = Graph::new("t");
        let a = g.push(Op::Input, vec![], meta("a", vec![1, 4, 4, 8]));
        let p1 = g.push(
            Op::MaxPool2d { kernel: 2, stride: 2, padding: crate::ir::PaddingMode::Valid },
            vec![a],
            meta("p1", vec![1, 2, 2, 8]),
        );
        let p2 = g.push(Op::Upsample { factor: 2, mode: Default::default() }, vec![a], meta("p2", vec![1, 8, 8, 8]));
        let cons = g.consumers();
        assert_eq!(cons[a], vec![p1, p2]);
    }
}
