//! Ergonomic graph construction with shape inference.
//!
//! Builds NHWC graphs (the layout Gemmini consumes). The YOLOv7-tiny
//! workload definition in [`crate::workload`] and the synthetic detector in
//! [`crate::dataset`] are both constructed through this builder.

use super::dtype::DType;
use super::graph::{Graph, NodeId, WeightData};
use super::layout::Layout;
use super::op::{ActivationKind, BinaryKind, Op, PaddingMode};
use super::tensor::TensorMeta;

/// Builder over a [`Graph`] that infers output shapes.
pub struct GraphBuilder {
    pub graph: Graph,
    counter: usize,
}

impl GraphBuilder {
    pub fn new(name: impl Into<String>) -> Self {
        Self { graph: Graph::new(name), counter: 0 }
    }

    fn fresh(&mut self, prefix: &str) -> String {
        self.counter += 1;
        format!("{prefix}_{}", self.counter)
    }

    /// Shape of a node's output (panics if id invalid).
    pub fn shape(&self, id: NodeId) -> &[usize] {
        &self.graph.node(id).output.shape
    }

    /// Declare an NHWC float input.
    pub fn input(&mut self, name: &str, shape: Vec<usize>) -> NodeId {
        let layout = if shape.len() == 4 { Layout::NHWC } else { Layout::Flat };
        let id =
            self.graph.push(Op::Input, vec![], TensorMeta::new(name, shape, DType::Float32, layout));
        self.graph.inputs.push(id);
        id
    }

    /// Add a float constant with explicit data.
    pub fn constant(&mut self, shape: Vec<usize>, data: Vec<f32>) -> NodeId {
        assert_eq!(shape.iter().product::<usize>(), data.len(), "const shape/data mismatch");
        let name = self.fresh("const");
        let layout = if shape.len() == 4 { Layout::NHWC } else { Layout::Flat };
        let id =
            self.graph.push(Op::Const, vec![], TensorMeta::new(name, shape, DType::Float32, layout));
        self.graph.weights.insert(id, WeightData::F32(data));
        id
    }

    /// Conv2d with weights `[oc, kh, kw, ic]`; infers NHWC output shape.
    /// Weight data must be supplied (use zeros for workload-only graphs).
    #[allow(clippy::too_many_arguments)]
    pub fn conv2d(
        &mut self,
        input: NodeId,
        out_channels: usize,
        kernel: usize,
        stride: usize,
        padding: PaddingMode,
        activation: ActivationKind,
        weights: Option<Vec<f32>>,
        bias: Option<Vec<f32>>,
    ) -> NodeId {
        let in_shape = self.shape(input).to_vec();
        assert_eq!(in_shape.len(), 4, "conv2d input must be 4-D NHWC");
        let (n, h, w, ic) = (in_shape[0], in_shape[1], in_shape[2], in_shape[3]);
        let pad_total = padding.total(kernel);
        let oh = (h + pad_total - kernel) / stride + 1;
        let ow = (w + pad_total - kernel) / stride + 1;

        let wnumel = out_channels * kernel * kernel * ic;
        let wdata = weights.unwrap_or_else(|| vec![0.0; wnumel]);
        assert_eq!(wdata.len(), wnumel, "conv weight size mismatch");
        let wid = self.constant(vec![out_channels, kernel, kernel, ic], wdata);

        let mut inputs = vec![input, wid];
        let has_bias = bias.is_some();
        if let Some(b) = bias {
            assert_eq!(b.len(), out_channels, "bias size mismatch");
            let bid = self.constant(vec![out_channels], b);
            inputs.push(bid);
        }
        let name = self.fresh("conv");
        self.graph.push(
            Op::Conv2d { out_channels, kernel, stride, padding, activation, bias: has_bias },
            inputs,
            TensorMeta::new(name, vec![n, oh, ow, out_channels], DType::Float32, Layout::NHWC),
        )
    }

    /// Max pooling; infers output shape.
    pub fn maxpool(&mut self, input: NodeId, kernel: usize, stride: usize) -> NodeId {
        let s = self.shape(input).to_vec();
        let (n, h, w, c) = (s[0], s[1], s[2], s[3]);
        let oh = (h - kernel) / stride + 1;
        let ow = (w - kernel) / stride + 1;
        let name = self.fresh("pool");
        self.graph.push(
            Op::MaxPool2d { kernel, stride, padding: PaddingMode::Valid },
            vec![input],
            TensorMeta::new(name, vec![n, oh, ow, c], DType::Float32, Layout::NHWC),
        )
    }

    /// Nearest-neighbour upsample.
    pub fn upsample(&mut self, input: NodeId, factor: usize) -> NodeId {
        let s = self.shape(input).to_vec();
        let name = self.fresh("up");
        self.graph.push(
            Op::Upsample { factor, mode: Default::default() },
            vec![input],
            TensorMeta::new(
                name,
                vec![s[0], s[1] * factor, s[2] * factor, s[3]],
                DType::Float32,
                Layout::NHWC,
            ),
        )
    }

    /// Channel concat (NHWC axis 3).
    pub fn concat(&mut self, inputs: &[NodeId]) -> NodeId {
        assert!(inputs.len() >= 2);
        let first = self.shape(inputs[0]).to_vec();
        let mut c = 0usize;
        for &i in inputs {
            let s = self.shape(i);
            assert_eq!(&s[..3], &first[..3], "concat spatial mismatch");
            c += s[3];
        }
        let name = self.fresh("cat");
        self.graph.push(
            Op::Concat,
            inputs.to_vec(),
            TensorMeta::new(name, vec![first[0], first[1], first[2], c], DType::Float32, Layout::NHWC),
        )
    }

    /// Dense layer over a flattened input.
    pub fn dense(
        &mut self,
        input: NodeId,
        out_features: usize,
        activation: ActivationKind,
        weights: Option<Vec<f32>>,
    ) -> NodeId {
        let in_features: usize = self.shape(input).iter().product::<usize>()
            / self.shape(input)[0].max(1);
        let n = self.shape(input)[0];
        let wnumel = out_features * in_features;
        let wdata = weights.unwrap_or_else(|| vec![0.0; wnumel]);
        assert_eq!(wdata.len(), wnumel);
        let wid = self.constant(vec![out_features, in_features], wdata);
        let name = self.fresh("dense");
        self.graph.push(
            Op::Dense { out_features, activation, bias: false },
            vec![input, wid],
            TensorMeta::new(name, vec![n, out_features], DType::Float32, Layout::Flat),
        )
    }

    /// Standalone activation node.
    pub fn activation(&mut self, input: NodeId, kind: ActivationKind) -> NodeId {
        let meta = self.graph.node(input).output.clone();
        let name = self.fresh("act");
        self.graph.push(
            Op::Activation { kind },
            vec![input],
            TensorMeta::new(name, meta.shape, meta.dtype, meta.layout),
        )
    }

    /// Elementwise binary op (shapes must match).
    pub fn binary(&mut self, kind: BinaryKind, a: NodeId, b: NodeId) -> NodeId {
        assert_eq!(self.shape(a), self.shape(b), "binary shape mismatch");
        let meta = self.graph.node(a).output.clone();
        let name = self.fresh("bin");
        self.graph.push(
            Op::Binary { kind },
            vec![a, b],
            TensorMeta::new(name, meta.shape, meta.dtype, meta.layout),
        )
    }

    /// Decode head output into box candidates (float tail).
    pub fn box_decode(&mut self, input: NodeId, num_anchors: usize, num_classes: usize) -> NodeId {
        let s = self.shape(input).to_vec();
        let cells = s[1] * s[2];
        let name = self.fresh("decode");
        self.graph.push(
            Op::BoxDecode { num_anchors, num_classes },
            vec![input],
            TensorMeta::new(
                name,
                vec![s[0], cells * num_anchors, 5 + num_classes],
                DType::Float32,
                Layout::Flat,
            ),
        )
    }

    /// Mark graph outputs and return the finished graph.
    pub fn finish(mut self, outputs: &[NodeId]) -> Graph {
        self.graph.outputs = outputs.to_vec();
        self.graph.validate().expect("builder produced invalid graph");
        self.graph
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn conv_shape_inference_same_padding() {
        let mut b = GraphBuilder::new("t");
        let x = b.input("x", vec![1, 480, 480, 3]);
        let c = b.conv2d(x, 32, 3, 2, PaddingMode::Same, ActivationKind::Relu6, None, None);
        assert_eq!(b.shape(c), &[1, 240, 240, 32]);
    }

    #[test]
    fn conv_shape_inference_1x1() {
        let mut b = GraphBuilder::new("t");
        let x = b.input("x", vec![1, 60, 60, 128]);
        let c = b.conv2d(x, 64, 1, 1, PaddingMode::Valid, ActivationKind::None, None, None);
        assert_eq!(b.shape(c), &[1, 60, 60, 64]);
    }

    #[test]
    fn pool_and_upsample_roundtrip() {
        let mut b = GraphBuilder::new("t");
        let x = b.input("x", vec![1, 64, 64, 16]);
        let p = b.maxpool(x, 2, 2);
        assert_eq!(b.shape(p), &[1, 32, 32, 16]);
        let u = b.upsample(p, 2);
        assert_eq!(b.shape(u), &[1, 64, 64, 16]);
    }

    #[test]
    fn concat_channels() {
        let mut b = GraphBuilder::new("t");
        let x = b.input("x", vec![1, 8, 8, 16]);
        let y = b.conv2d(x, 32, 1, 1, PaddingMode::Valid, ActivationKind::None, None, None);
        let z = b.concat(&[x, y]);
        assert_eq!(b.shape(z), &[1, 8, 8, 48]);
    }

    #[test]
    #[should_panic(expected = "concat spatial mismatch")]
    fn concat_rejects_spatial_mismatch() {
        let mut b = GraphBuilder::new("t");
        let x = b.input("x", vec![1, 8, 8, 16]);
        let p = b.maxpool(x, 2, 2);
        b.concat(&[x, p]);
    }

    #[test]
    fn finish_validates() {
        let mut b = GraphBuilder::new("t");
        let x = b.input("x", vec![1, 16, 16, 3]);
        let c = b.conv2d(x, 8, 3, 1, PaddingMode::Same, ActivationKind::Relu, None, None);
        let g = b.finish(&[c]);
        assert_eq!(g.outputs.len(), 1);
        assert!(g.validate().is_ok());
        assert!(g.gops() > 0.0);
    }

    #[test]
    fn box_decode_shape() {
        let mut b = GraphBuilder::new("t");
        let x = b.input("x", vec![1, 15, 15, 39]);
        let d = b.box_decode(x, 3, 8);
        assert_eq!(b.shape(d), &[1, 15 * 15 * 3, 13]);
    }
}
