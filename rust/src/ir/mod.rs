//! Neural-network graph IR.
//!
//! This is the substrate the deployment workflow (Section IV of the paper)
//! operates on: an operator graph with typed tensors, explicit layouts and
//! quantization parameters. It plays the role TVM's Relay graph plays in the
//! paper: the pass pipeline in [`crate::passes`] rewrites it, the partitioner
//! in [`crate::partition`] splits it by dtype, and the scheduler in
//! [`crate::scheduler`] lowers its conv/pool/resize/concat nodes to Gemmini
//! instruction streams.

pub mod builder;
pub mod dtype;
pub mod graph;
pub mod interp;
pub mod layout;
pub mod op;
pub mod tensor;
pub mod topo;

pub use builder::GraphBuilder;
pub use dtype::DType;
pub use graph::{Graph, Node, NodeId, TensorId};
pub use interp::{Interpreter, Value};
pub use layout::Layout;
pub use op::{ActivationKind, Op, PaddingMode, UpsampleMode};
pub use tensor::{QuantParams, TensorMeta};
