//! Reference graph interpreter (float and quantized-int8 execution).
//!
//! Plays the role of the TVM runtime in the paper's workflow: executes IR
//! graphs directly so the pass pipeline (quantization calibration, pruning
//! evaluation, framework-conversion validation — Table I, Figures 3/4) can
//! measure real accuracy. The int8 path mirrors Gemmini's arithmetic
//! exactly: int8 × int8 → int32 accumulate, single f32 (or f16-rounded)
//! requantization multiplier, ReLU clamped in the quantized domain.

use std::collections::HashMap;

use super::dtype::DType;
use super::graph::{Graph, NodeId, WeightData};
use super::op::{ActivationKind, BinaryKind, Op};
use super::tensor::QuantParams;

/// A runtime tensor: f32 storage with NHWC/flat shapes. Quantized tensors
/// keep their int8 payload alongside the dequantized view so int8 chains
/// stay bit-exact.
#[derive(Debug, Clone)]
pub struct Value {
    pub shape: Vec<usize>,
    pub f: Vec<f32>,
    /// Present when this value is a quantized tensor.
    pub q: Option<(Vec<i8>, QuantParams)>,
}

impl Value {
    pub fn new(shape: Vec<usize>, f: Vec<f32>) -> Self {
        assert_eq!(shape.iter().product::<usize>(), f.len());
        Self { shape, f, q: None }
    }

    pub fn numel(&self) -> usize {
        self.f.len()
    }
}

/// Interpreter over a graph. Holds no state between calls except the graph
/// and pre-quantized weights cache.
pub struct Interpreter<'g> {
    pub graph: &'g Graph,
}

impl<'g> Interpreter<'g> {
    pub fn new(graph: &'g Graph) -> Self {
        Self { graph }
    }

    /// Run the graph on the given inputs (one per graph input, NHWC f32).
    /// Returns the output values in graph-output order.
    pub fn run(&self, inputs: &[Value]) -> Vec<Value> {
        assert_eq!(inputs.len(), self.graph.inputs.len(), "input arity mismatch");
        let mut env: HashMap<NodeId, Value> = HashMap::new();
        for (i, &id) in self.graph.inputs.iter().enumerate() {
            env.insert(id, inputs[i].clone());
        }
        for n in &self.graph.nodes {
            if env.contains_key(&n.id) {
                continue; // graph input
            }
            let v = self.quantize_if_int8(n.id, self.eval(n.id, &env));
            env.insert(n.id, v);
        }
        self.graph.outputs.iter().map(|o| env[o].clone()).collect()
    }

    /// Run and also record every intermediate activation's (min, max) —
    /// the calibration pass for post-training quantization.
    pub fn run_calibrated(&self, inputs: &[Value]) -> (Vec<Value>, HashMap<NodeId, (f32, f32)>) {
        let mut env: HashMap<NodeId, Value> = HashMap::new();
        let mut ranges = HashMap::new();
        for (i, &id) in self.graph.inputs.iter().enumerate() {
            env.insert(id, inputs[i].clone());
        }
        for n in &self.graph.nodes {
            if !env.contains_key(&n.id) {
                let v = self.quantize_if_int8(n.id, self.eval(n.id, &env));
                env.insert(n.id, v);
            }
            let v = &env[&n.id];
            if !v.f.is_empty() {
                let mn = v.f.iter().copied().fold(f32::INFINITY, f32::min);
                let mx = v.f.iter().copied().fold(f32::NEG_INFINITY, f32::max);
                ranges.insert(n.id, (mn, mx));
            }
        }
        (self.graph.outputs.iter().map(|o| env[o].clone()).collect(), ranges)
    }

    /// Int8-region shuffle ops (pool/upsample/concat/reshape) produce exact
    /// int8-grid values; attach the quantized payload so downstream int8
    /// convs stay bit-exact. Concat with differing input scales requantizes
    /// to the node's own scale — exactly what the deployed graph does.
    fn quantize_if_int8(&self, id: NodeId, mut v: Value) -> Value {
        let n = self.graph.node(id);
        if v.q.is_none() && n.output.dtype == DType::Int8 {
            if let Some(qp) = n.output.quant {
                let q: Vec<i8> = v.f.iter().map(|&x| qp.quantize(x)).collect();
                v.f = q.iter().map(|&x| qp.dequantize(x)).collect();
                v.q = Some((q, qp));
            }
        }
        v
    }

    fn weights_f32(&self, id: NodeId) -> Vec<f32> {
        match &self.graph.weights[&id] {
            WeightData::F32(v) => v.clone(),
            WeightData::I8(v) => {
                let q = self.graph.node(id).output.quant.expect("int8 weight without quant");
                v.iter().map(|&x| q.dequantize(x)).collect()
            }
            WeightData::I32(v) => v.iter().map(|&x| x as f32).collect(),
        }
    }

    fn eval(&self, id: NodeId, env: &HashMap<NodeId, Value>) -> Value {
        let n = self.graph.node(id);
        let out_shape = n.output.shape.clone();
        match &n.op {
            Op::Input => panic!("unbound input {id}"),
            Op::Const => {
                let f = self.weights_f32(id);
                let mut v = Value::new(out_shape, f);
                if let (WeightData::I8(q), Some(qp)) =
                    (&self.graph.weights[&id], n.output.quant)
                {
                    v.q = Some((q.clone(), qp));
                }
                v
            }
            Op::Conv2d { kernel, stride, padding, activation, bias, .. } => {
                let x = &env[&n.inputs[0]];
                let w = &env[&n.inputs[1]];
                let b = if *bias { Some(&env[&n.inputs[2]]) } else { None };
                let quantized = n.output.dtype == DType::Int8;
                if quantized {
                    self.conv_int8(n.id, x, w, b, *kernel, *stride, padding.begin(*kernel), *activation, &out_shape)
                } else {
                    conv_f32(x, w, b, *kernel, *stride, padding.begin(*kernel), *activation, &out_shape)
                }
            }
            Op::Dense { activation, bias, .. } => {
                let x = &env[&n.inputs[0]];
                let w = &env[&n.inputs[1]];
                let b = if *bias { Some(&env[&n.inputs[2]]) } else { None };
                dense_f32(x, w, b, *activation, &out_shape)
            }
            Op::MaxPool2d { kernel, stride, .. } => {
                let x = &env[&n.inputs[0]];
                maxpool_f32(x, *kernel, *stride, &out_shape)
            }
            Op::Upsample { factor, mode } => upsample_f32(&env[&n.inputs[0]], *factor, *mode, &out_shape),
            Op::Concat => {
                let vals: Vec<&Value> = n.inputs.iter().map(|i| &env[i]).collect();
                concat_channels(&vals, &out_shape)
            }
            Op::Activation { kind } => {
                let x = &env[&n.inputs[0]];
                Value::new(out_shape, x.f.iter().map(|&v| kind.apply(v)).collect())
            }
            Op::Quantize => {
                let x = &env[&n.inputs[0]];
                let qp = n.output.quant.expect("quantize without params");
                let q: Vec<i8> = x.f.iter().map(|&v| qp.quantize(v)).collect();
                let f: Vec<f32> = q.iter().map(|&v| qp.dequantize(v)).collect();
                Value { shape: out_shape, f, q: Some((q, qp)) }
            }
            Op::Dequantize => {
                let x = &env[&n.inputs[0]];
                Value::new(out_shape, x.f.clone())
            }
            Op::Binary { kind } => {
                let a = &env[&n.inputs[0]];
                let b = &env[&n.inputs[1]];
                let f = a
                    .f
                    .iter()
                    .zip(&b.f)
                    .map(|(&x, &y)| match kind {
                        BinaryKind::Add => x + y,
                        BinaryKind::Mul => x * y,
                        BinaryKind::Sub => x - y,
                    })
                    .collect();
                Value::new(out_shape, f)
            }
            Op::Reshape => {
                let x = &env[&n.inputs[0]];
                Value::new(out_shape, x.f.clone())
            }
            Op::Transpose { perm } => transpose(&env[&n.inputs[0]], perm, &out_shape),
            Op::BoxDecode { num_anchors, num_classes } => {
                box_decode(&env[&n.inputs[0]], *num_anchors, *num_classes, &out_shape)
            }
        }
    }

    /// Quantized conv: int8 inputs/weights, int32 accumulate, requantize
    /// with the layer's output scale (Gemmini mvout semantics).
    #[allow(clippy::too_many_arguments)]
    fn conv_int8(
        &self,
        id: NodeId,
        x: &Value,
        w: &Value,
        b: Option<&Value>,
        kernel: usize,
        stride: usize,
        pad: usize,
        act: ActivationKind,
        out_shape: &[usize],
    ) -> Value {
        let (xq, xqp) = x.q.as_ref().expect("int8 conv needs quantized input");
        let (wq, wqp) = w.q.as_ref().expect("int8 conv needs quantized weights");
        let oqp = self.graph.node(id).output.quant.expect("int8 conv needs output quant");
        let (h, wi, ic) = (x.shape[1], x.shape[2], x.shape[3]);
        let (oh, ow, oc) = (out_shape[1], out_shape[2], out_shape[3]);
        // bias is stored as f32; fold to int32 in the conv's accumulator
        // scale (x_scale * w_scale), as TFLite/Gemmini do.
        let acc_scale = xqp.effective_scale() * wqp.effective_scale();
        let bias_i32: Vec<i32> = match b {
            Some(bv) => bv.f.iter().map(|&v| (v / acc_scale).round() as i32).collect(),
            None => vec![0; oc],
        };
        let requant = acc_scale / oqp.effective_scale();
        // TVM lowers requantize to a fixed-point multiply: q31 multiplier +
        // rounding right-shift. Bit-exact differences vs the float path are
        // what the paper's TFLite→TVM column measures.
        let fixed_point = self.graph.requant_fixed_point;
        let (q31_mult, q31_shift) = to_q31(requant);
        let q6 = (6.0 / oqp.effective_scale()).round().clamp(0.0, 127.0) as i32;
        let mut qout = vec![0i8; oh * ow * oc];
        let mut fout = vec![0f32; oh * ow * oc];
        let xzp = xqp.zero_point;
        for oy in 0..oh {
            for ox in 0..ow {
                for n_ in 0..oc {
                    let mut acc: i32 = bias_i32[n_];
                    for kh in 0..kernel {
                        let iy = (oy * stride + kh) as isize - pad as isize;
                        if iy < 0 || iy >= h as isize {
                            continue;
                        }
                        for kw in 0..kernel {
                            let ix = (ox * stride + kw) as isize - pad as isize;
                            if ix < 0 || ix >= wi as isize {
                                continue;
                            }
                            let xbase = ((iy as usize) * wi + ix as usize) * ic;
                            let wbase = ((n_ * kernel + kh) * kernel + kw) * ic;
                            for c in 0..ic {
                                let xv = xq[xbase + c] as i32 - xzp;
                                let wv = wq[wbase + c] as i32;
                                acc += xv * wv;
                            }
                        }
                    }
                    let scaled = if fixed_point {
                        fixed_point_mul(acc, q31_mult, q31_shift)
                    } else {
                        (acc as f32 * requant).round() as i32
                    };
                    let qv = match act {
                        ActivationKind::Relu6 => scaled.clamp(0, q6),
                        ActivationKind::Relu => scaled.clamp(0, 127),
                        _ => scaled.clamp(-128, 127),
                    } as i8;
                    let idx = (oy * ow + ox) * oc + n_;
                    qout[idx] = qv;
                    fout[idx] = oqp.dequantize(qv);
                }
            }
        }
        Value { shape: out_shape.to_vec(), f: fout, q: Some((qout, oqp)) }
    }
}

/// Decompose a positive real multiplier into (q31 mantissa, right shift):
/// `x ≈ m · 2^-31 · 2^shift` with `m` in `[2^30, 2^31)`.
fn to_q31(x: f32) -> (i64, i32) {
    if x <= 0.0 {
        return (0, 0);
    }
    let mut shift = 0i32;
    let mut v = x as f64;
    while v < 0.5 {
        v *= 2.0;
        shift -= 1;
    }
    while v >= 1.0 {
        v /= 2.0;
        shift += 1;
    }
    ((v * (1i64 << 31) as f64).round() as i64, shift)
}

/// TVM-style saturating rounding doubling-free fixed-point multiply.
fn fixed_point_mul(acc: i32, m: i64, shift: i32) -> i32 {
    let prod = acc as i64 * m; // fits in i64 for |acc| < 2^31
    let total_shift = 31 - shift;
    if total_shift <= 0 {
        return (prod << (-total_shift)).clamp(i32::MIN as i64, i32::MAX as i64) as i32;
    }
    let round = 1i64 << (total_shift - 1);
    ((prod + round) >> total_shift) as i32
}

// ---- float reference kernels ----

#[allow(clippy::too_many_arguments)]
fn conv_f32(
    x: &Value,
    w: &Value,
    b: Option<&Value>,
    kernel: usize,
    stride: usize,
    pad: usize,
    act: ActivationKind,
    out_shape: &[usize],
) -> Value {
    let (h, wi, ic) = (x.shape[1], x.shape[2], x.shape[3]);
    let (oh, ow, oc) = (out_shape[1], out_shape[2], out_shape[3]);
    let mut out = vec![0f32; oh * ow * oc];
    for oy in 0..oh {
        for ox in 0..ow {
            for n in 0..oc {
                let mut acc = b.map(|bv| bv.f[n]).unwrap_or(0.0);
                for kh in 0..kernel {
                    let iy = (oy * stride + kh) as isize - pad as isize;
                    if iy < 0 || iy >= h as isize {
                        continue;
                    }
                    for kw in 0..kernel {
                        let ix = (ox * stride + kw) as isize - pad as isize;
                        if ix < 0 || ix >= wi as isize {
                            continue;
                        }
                        let xbase = ((iy as usize) * wi + ix as usize) * ic;
                        let wbase = ((n * kernel + kh) * kernel + kw) * ic;
                        for c in 0..ic {
                            acc += x.f[xbase + c] * w.f[wbase + c];
                        }
                    }
                }
                out[(oy * ow + ox) * oc + n] = act.apply(acc);
            }
        }
    }
    Value::new(out_shape.to_vec(), out)
}

fn dense_f32(
    x: &Value,
    w: &Value,
    b: Option<&Value>,
    act: ActivationKind,
    out_shape: &[usize],
) -> Value {
    let batch = x.shape[0];
    let inf = x.numel() / batch;
    let outf = out_shape[1];
    let mut out = vec![0f32; batch * outf];
    for bi in 0..batch {
        for o in 0..outf {
            let mut acc = b.map(|bv| bv.f[o]).unwrap_or(0.0);
            for i in 0..inf {
                acc += x.f[bi * inf + i] * w.f[o * inf + i];
            }
            out[bi * outf + o] = act.apply(acc);
        }
    }
    Value::new(out_shape.to_vec(), out)
}

fn maxpool_f32(x: &Value, kernel: usize, stride: usize, out_shape: &[usize]) -> Value {
    let (h, w, c) = (x.shape[1], x.shape[2], x.shape[3]);
    let (oh, ow) = (out_shape[1], out_shape[2]);
    let mut out = vec![f32::NEG_INFINITY; oh * ow * c];
    for oy in 0..oh {
        for ox in 0..ow {
            for kh in 0..kernel {
                for kw in 0..kernel {
                    let iy = oy * stride + kh;
                    let ix = ox * stride + kw;
                    if iy >= h || ix >= w {
                        continue;
                    }
                    for ch in 0..c {
                        let v = x.f[(iy * w + ix) * c + ch];
                        let o = &mut out[(oy * ow + ox) * c + ch];
                        if v > *o {
                            *o = v;
                        }
                    }
                }
            }
        }
    }
    Value::new(out_shape.to_vec(), out)
}

fn upsample_f32(x: &Value, factor: usize, mode: crate::ir::op::UpsampleMode, out_shape: &[usize]) -> Value {
    let (h, w, c) = (x.shape[1], x.shape[2], x.shape[3]);
    let (oh, ow) = (out_shape[1], out_shape[2]);
    let mut out = vec![0f32; oh * ow * c];
    // ONNX Resize half-pixel nearest: src = round_half_even((d+0.5)/f - 0.5).
    let half_pixel = |d: usize| -> usize {
        let s = (d as f32 + 0.5) / factor as f32 - 0.5;
        let r = s.round_ties_even();
        (r.max(0.0)) as usize
    };
    for oy in 0..oh {
        for ox in 0..ow {
            let (iy, ix) = match mode {
                crate::ir::op::UpsampleMode::Replicate => (oy / factor, ox / factor),
                crate::ir::op::UpsampleMode::OnnxHalfPixel => (half_pixel(oy), half_pixel(ox)),
            };
            let iy = iy.min(h - 1);
            let ix = ix.min(w - 1);
            for ch in 0..c {
                out[(oy * ow + ox) * c + ch] = x.f[(iy * w + ix) * c + ch];
            }
        }
    }
    Value::new(out_shape.to_vec(), out)
}

fn concat_channels(vals: &[&Value], out_shape: &[usize]) -> Value {
    let (h, w) = (out_shape[1], out_shape[2]);
    let oc = out_shape[3];
    let mut out = vec![0f32; h * w * oc];
    for y in 0..h {
        for x in 0..w {
            let mut co = 0usize;
            for v in vals {
                let c = v.shape[3];
                let src = (y * w + x) * c;
                let dst = (y * w + x) * oc + co;
                out[dst..dst + c].copy_from_slice(&v.f[src..src + c]);
                co += c;
            }
        }
    }
    Value::new(out_shape.to_vec(), out)
}

fn transpose(x: &Value, perm: &[usize], out_shape: &[usize]) -> Value {
    assert_eq!(x.shape.len(), perm.len());
    let in_shape = &x.shape;
    let rank = perm.len();
    let mut in_strides = vec![1usize; rank];
    for i in (0..rank - 1).rev() {
        in_strides[i] = in_strides[i + 1] * in_shape[i + 1];
    }
    let mut out = vec![0f32; x.numel()];
    let mut idx = vec![0usize; rank];
    for (o, slot) in out.iter_mut().enumerate() {
        // decompose o into out coords
        let mut rem = o;
        for i in 0..rank {
            let stride: usize = out_shape[i + 1..].iter().product();
            idx[i] = rem / stride;
            rem %= stride;
        }
        let mut src = 0usize;
        for i in 0..rank {
            src += idx[i] * in_strides[perm[i]];
        }
        *slot = x.f[src];
    }
    Value::new(out_shape.to_vec(), out)
}

/// Decode raw YOLO-style head output into candidate boxes:
/// out[cell·anchor] = [cx, cy, w, h, obj, class scores…], all after
/// sigmoid/exp transforms. Anchor sizes are a fixed ladder per head.
fn box_decode(x: &Value, num_anchors: usize, num_classes: usize, out_shape: &[usize]) -> Value {
    let (gh, gw, c) = (x.shape[1], x.shape[2], x.shape[3]);
    let per = 5 + num_classes;
    assert!(c >= num_anchors * per, "head channels {c} < {num_anchors}×{per}");
    let sigmoid = |v: f32| 1.0 / (1.0 + (-v).exp());
    let mut out = vec![0f32; out_shape.iter().product()];
    let mut o = 0usize;
    for gy in 0..gh {
        for gx in 0..gw {
            for a in 0..num_anchors {
                let base = (gy * gw + gx) * c + a * per;
                let anchor = 2.5 * (a + 1) as f32; // anchor ladder in grid units
                let tx = x.f[base];
                let ty = x.f[base + 1];
                let tw = x.f[base + 2];
                let th = x.f[base + 3];
                let tobj = x.f[base + 4];
                out[o] = (gx as f32 + sigmoid(tx)) / gw as f32; // cx in [0,1]
                out[o + 1] = (gy as f32 + sigmoid(ty)) / gh as f32;
                out[o + 2] = anchor * (0.25 + sigmoid(tw)) / gw as f32;
                out[o + 3] = anchor * (0.25 + sigmoid(th)) / gh as f32;
                out[o + 4] = sigmoid(tobj);
                for cl in 0..num_classes {
                    out[o + 5 + cl] = sigmoid(x.f[base + 5 + cl]);
                }
                o += per;
            }
        }
    }
    Value::new(out_shape.to_vec(), out)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::ir::{GraphBuilder, PaddingMode};

    #[test]
    fn conv_identity_kernel() {
        let mut b = GraphBuilder::new("t");
        let x = b.input("x", vec![1, 3, 3, 1]);
        // 1×1 conv with weight 2.0: output = 2x.
        let c = b.conv2d(x, 1, 1, 1, PaddingMode::Valid, ActivationKind::None, Some(vec![2.0]), None);
        let g = b.finish(&[c]);
        let out = Interpreter::new(&g)
            .run(&[Value::new(vec![1, 3, 3, 1], (1..=9).map(|v| v as f32).collect())]);
        assert_eq!(out[0].f, (1..=9).map(|v| 2.0 * v as f32).collect::<Vec<_>>());
    }

    #[test]
    fn conv_3x3_sum_kernel_with_padding() {
        let mut b = GraphBuilder::new("t");
        let x = b.input("x", vec![1, 3, 3, 1]);
        let c = b.conv2d(x, 1, 3, 1, PaddingMode::Same, ActivationKind::None, Some(vec![1.0; 9]), None);
        let g = b.finish(&[c]);
        let out =
            Interpreter::new(&g).run(&[Value::new(vec![1, 3, 3, 1], vec![1.0; 9])]);
        // Center pixel sees all 9 ones; corner sees 4.
        assert_eq!(out[0].f[4], 9.0);
        assert_eq!(out[0].f[0], 4.0);
    }

    #[test]
    fn conv_bias_and_relu6() {
        let mut b = GraphBuilder::new("t");
        let x = b.input("x", vec![1, 1, 1, 1]);
        let c = b.conv2d(
            x,
            2,
            1,
            1,
            PaddingMode::Valid,
            ActivationKind::Relu6,
            Some(vec![1.0, -1.0]),
            Some(vec![10.0, 0.5]),
        );
        let g = b.finish(&[c]);
        let out = Interpreter::new(&g).run(&[Value::new(vec![1, 1, 1, 1], vec![3.0])]);
        assert_eq!(out[0].f, vec![6.0, 0.0]); // 13→6 clamp, -2.5→0
    }

    #[test]
    fn maxpool_picks_max() {
        let mut b = GraphBuilder::new("t");
        let x = b.input("x", vec![1, 2, 2, 1]);
        let p = b.maxpool(x, 2, 2);
        let g = b.finish(&[p]);
        let out = Interpreter::new(&g)
            .run(&[Value::new(vec![1, 2, 2, 1], vec![1.0, 5.0, 3.0, 2.0])]);
        assert_eq!(out[0].f, vec![5.0]);
    }

    #[test]
    fn upsample_replicates() {
        let mut b = GraphBuilder::new("t");
        let x = b.input("x", vec![1, 1, 2, 1]);
        let u = b.upsample(x, 2);
        let g = b.finish(&[u]);
        let out = Interpreter::new(&g).run(&[Value::new(vec![1, 1, 2, 1], vec![1.0, 2.0])]);
        assert_eq!(out[0].f, vec![1.0, 1.0, 2.0, 2.0, 1.0, 1.0, 2.0, 2.0]);
    }

    #[test]
    fn concat_interleaves_channels() {
        let mut b = GraphBuilder::new("t");
        let x = b.input("x", vec![1, 1, 2, 1]);
        let y = b.input("y", vec![1, 1, 2, 1]);
        let c = b.concat(&[x, y]);
        let g = b.finish(&[c]);
        let out = Interpreter::new(&g).run(&[
            Value::new(vec![1, 1, 2, 1], vec![1.0, 2.0]),
            Value::new(vec![1, 1, 2, 1], vec![10.0, 20.0]),
        ]);
        assert_eq!(out[0].f, vec![1.0, 10.0, 2.0, 20.0]);
    }

    #[test]
    fn calibration_collects_ranges() {
        let mut b = GraphBuilder::new("t");
        let x = b.input("x", vec![1, 2, 2, 1]);
        let c = b.conv2d(x, 1, 1, 1, PaddingMode::Valid, ActivationKind::Relu, Some(vec![-1.0]), None);
        let g = b.finish(&[c]);
        let (_, ranges) = Interpreter::new(&g)
            .run_calibrated(&[Value::new(vec![1, 2, 2, 1], vec![1.0, -2.0, 3.0, 0.0])]);
        let (mn, mx) = ranges[&g.inputs[0]];
        assert_eq!((mn, mx), (-2.0, 3.0));
        let (omn, omx) = ranges[&g.outputs[0]];
        assert_eq!((omn, omx), (0.0, 2.0)); // relu(-x)
    }

    #[test]
    fn box_decode_outputs_normalized() {
        let mut b = GraphBuilder::new("t");
        let x = b.input("x", vec![1, 2, 2, 2 * 9]);
        let d = b.box_decode(x, 2, 4);
        let g = b.finish(&[d]);
        let out = Interpreter::new(&g)
            .run(&[Value::new(vec![1, 2, 2, 18], vec![0.0; 2 * 2 * 18])]);
        // All sigmoid(0) = 0.5; cx of cell (0,0) = 0.5/2 = 0.25.
        assert_eq!(out[0].shape, vec![1, 8, 9]);
        assert!((out[0].f[0] - 0.25).abs() < 1e-6);
        assert!((out[0].f[4] - 0.5).abs() < 1e-6);
    }

    #[test]
    fn transpose_nhwc_to_nchw() {
        let mut b = GraphBuilder::new("t");
        let x = b.input("x", vec![1, 1, 2, 3]);
        let shape = vec![1, 3, 1, 2];
        let name = "tr".to_string();
        let t = b.graph.push(
            Op::Transpose { perm: vec![0, 3, 1, 2] },
            vec![x],
            crate::ir::TensorMeta::new(name, shape, crate::ir::DType::Float32, crate::ir::Layout::NCHW),
        );
        let g = b.finish(&[t]);
        let out = Interpreter::new(&g)
            .run(&[Value::new(vec![1, 1, 2, 3], vec![1., 2., 3., 4., 5., 6.])]);
        // NHWC [[1,2,3],[4,5,6]] -> NCHW channels [[1,4],[2,5],[3,6]]
        assert_eq!(out[0].f, vec![1., 4., 2., 5., 3., 6.]);
    }
}
