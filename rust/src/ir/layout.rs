//! Tensor data layouts.
//!
//! The paper's conversion chain (Section IV-B4) exists partly to move the
//! model from NCHW (PyTorch/ONNX) to NHWC (TFLite / Gemmini's expected
//! activation layout). We model layouts explicitly so the
//! [`crate::passes::layout_convert`] pass has something real to do.


/// Activation tensor layout for 4-D tensors.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum Layout {
    /// Batch, channels, height, width — PyTorch / ONNX convention.
    NCHW,
    /// Batch, height, width, channels — TFLite / Gemmini convention.
    NHWC,
    /// Non-spatial tensors (weights of dense layers, 1-D/2-D tensors).
    Flat,
}

impl Layout {
    /// Permutation mapping logical NCHW axes to this layout's axis order.
    /// Returns indices such that `shape_in_layout[i] = nchw_shape[perm[i]]`.
    pub fn perm_from_nchw(self) -> [usize; 4] {
        match self {
            Layout::NCHW => [0, 1, 2, 3],
            Layout::NHWC => [0, 2, 3, 1],
            Layout::Flat => [0, 1, 2, 3],
        }
    }

    /// Reorder a shape given in NCHW into this layout.
    pub fn shape_from_nchw(self, nchw: [usize; 4]) -> [usize; 4] {
        let p = self.perm_from_nchw();
        [nchw[p[0]], nchw[p[1]], nchw[p[2]], nchw[p[3]]]
    }

    /// Recover an NCHW shape from a shape given in this layout.
    pub fn shape_to_nchw(self, shape: [usize; 4]) -> [usize; 4] {
        let p = self.perm_from_nchw();
        let mut out = [0usize; 4];
        for (i, &axis) in p.iter().enumerate() {
            out[axis] = shape[i];
        }
        out
    }
}

impl std::fmt::Display for Layout {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        let s = match self {
            Layout::NCHW => "NCHW",
            Layout::NHWC => "NHWC",
            Layout::Flat => "flat",
        };
        f.write_str(s)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn nhwc_shape_roundtrip() {
        let nchw = [1, 32, 480, 640];
        let nhwc = Layout::NHWC.shape_from_nchw(nchw);
        assert_eq!(nhwc, [1, 480, 640, 32]);
        assert_eq!(Layout::NHWC.shape_to_nchw(nhwc), nchw);
    }

    #[test]
    fn nchw_identity() {
        let s = [2, 3, 4, 5];
        assert_eq!(Layout::NCHW.shape_from_nchw(s), s);
        assert_eq!(Layout::NCHW.shape_to_nchw(s), s);
    }
}
