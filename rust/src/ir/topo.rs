//! Graph traversal utilities.
//!
//! The arena in [`super::graph::Graph`] is topological by construction, but
//! passes that delete or bypass nodes need reachability and re-compaction.

use std::collections::HashMap;

use super::graph::{Graph, NodeId};
use super::op::Op;

/// Nodes reachable (backwards) from the graph outputs.
pub fn live_set(g: &Graph) -> Vec<bool> {
    let mut live = vec![false; g.nodes.len()];
    let mut stack: Vec<NodeId> = g.outputs.clone();
    while let Some(id) = stack.pop() {
        if live[id] {
            continue;
        }
        live[id] = true;
        stack.extend(g.nodes[id].inputs.iter().copied());
    }
    live
}

/// Remove dead nodes (unreachable from outputs), re-indexing the arena.
/// Returns the old->new id mapping.
pub fn dce(g: &mut Graph) -> HashMap<NodeId, NodeId> {
    let live = live_set(g);
    let mut remap: HashMap<NodeId, NodeId> = HashMap::new();
    let mut new_nodes = Vec::with_capacity(g.nodes.len());
    for n in g.nodes.drain(..) {
        if live[n.id] {
            let new_id = new_nodes.len();
            remap.insert(n.id, new_id);
            let mut n = n;
            n.id = new_id;
            n.inputs = n.inputs.iter().map(|i| remap[i]).collect();
            new_nodes.push(n);
        }
    }
    g.nodes = new_nodes;
    g.inputs.retain(|i| remap.contains_key(i));
    for i in g.inputs.iter_mut() {
        *i = remap[i];
    }
    for o in g.outputs.iter_mut() {
        *o = remap[o];
    }
    g.weights = g
        .weights
        .drain()
        .filter_map(|(k, v)| remap.get(&k).map(|&nk| (nk, v)))
        .collect();
    remap
}

/// Execution order of the compute nodes (skipping Input/Const), i.e. the
/// order the coordinator dispatches layers.
pub fn schedule_order(g: &Graph) -> Vec<NodeId> {
    g.nodes
        .iter()
        .filter(|n| !matches!(n.op, Op::Input | Op::Const))
        .map(|n| n.id)
        .collect()
}

/// Depth (longest path from any graph input) per node — used by reports to
/// show the critical path of the partitioned model.
pub fn depths(g: &Graph) -> Vec<usize> {
    let mut d = vec![0usize; g.nodes.len()];
    for n in &g.nodes {
        let max_in = n.inputs.iter().map(|&i| d[i] + 1).max().unwrap_or(0);
        d[n.id] = max_in;
    }
    d
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::ir::{DType, Layout, TensorMeta};

    fn meta(name: &str) -> TensorMeta {
        TensorMeta::new(name, vec![1], DType::Float32, Layout::Flat)
    }

    fn chain(n: usize) -> Graph {
        let mut g = Graph::new("chain");
        let mut prev = g.push(Op::Input, vec![], meta("in"));
        g.inputs.push(prev);
        for i in 0..n {
            prev = g.push(Op::Reshape, vec![prev], meta(&format!("r{i}")));
        }
        g.outputs.push(prev);
        g
    }

    #[test]
    fn live_set_marks_chain() {
        let g = chain(3);
        assert!(live_set(&g).iter().all(|&b| b));
    }

    #[test]
    fn dce_removes_dangling() {
        let mut g = chain(2);
        // Add a dead branch.
        let dead = g.push(Op::Reshape, vec![g.inputs[0]], meta("dead"));
        let _dead2 = g.push(Op::Reshape, vec![dead], meta("dead2"));
        assert_eq!(g.nodes.len(), 5);
        dce(&mut g);
        assert_eq!(g.nodes.len(), 3);
        assert!(g.validate().is_ok());
    }

    #[test]
    fn dce_preserves_weights() {
        let mut g = Graph::new("t");
        let a = g.push(Op::Input, vec![], meta("a"));
        g.inputs.push(a);
        let w = g.push(Op::Const, vec![], meta("w"));
        g.weights.insert(w, crate::ir::graph::WeightData::F32(vec![1.0]));
        let d = g.push(
            Op::Dense { out_features: 1, activation: crate::ir::ActivationKind::None, bias: false },
            vec![a, w],
            meta("d"),
        );
        g.outputs.push(d);
        // dead const
        let dw = g.push(Op::Const, vec![], meta("dw"));
        g.weights.insert(dw, crate::ir::graph::WeightData::F32(vec![2.0]));
        dce(&mut g);
        assert_eq!(g.nodes.len(), 3);
        assert_eq!(g.weights.len(), 1);
        assert!(g.validate().is_ok());
    }

    #[test]
    fn depths_longest_path() {
        let g = chain(4);
        let d = depths(&g);
        assert_eq!(d[g.outputs[0]], 4);
    }

    #[test]
    fn schedule_order_skips_inputs_consts() {
        let g = chain(3);
        let order = schedule_order(&g);
        assert_eq!(order.len(), 3);
    }
}
