//! Operator set.
//!
//! Covers everything YOLOv7-tiny needs (Section IV-A): conv, maxpool,
//! resize/upsample, concat and dense layers — the set the paper's expanded
//! TVM integration offloads via RISC-type instructions (Section IV-C) —
//! plus the float ops of the NMS-preparation tail and the explicit
//! quantize/dequantize boundary ops the partitioner keys on.


/// Activation functions. Gemmini can only fuse ReLU-family activations
/// (Section IV-B2: LeakyReLU is *not* supported and would fall back to the
/// scalar CPU, hence the paper's ReLU6 replacement).
#[derive(Debug, Clone, Copy, PartialEq)]
pub enum ActivationKind {
    None,
    Relu,
    Relu6,
    /// LeakyReLU(alpha) — unsupported by the accelerator; the activation
    /// pass replaces it.
    LeakyRelu(f32),
    /// SiLU/Swish — present in full YOLOv7; unsupported by the accelerator.
    Silu,
    Sigmoid,
}

impl ActivationKind {
    /// Whether Gemmini can apply this activation inside the accumulator
    /// read-out path (i.e. for free, fused with the layer).
    pub fn accelerator_fusable(self) -> bool {
        matches!(self, ActivationKind::None | ActivationKind::Relu | ActivationKind::Relu6)
    }

    /// Apply the activation to a real value (reference semantics used by
    /// the interpreter and tests).
    pub fn apply(self, x: f32) -> f32 {
        match self {
            ActivationKind::None => x,
            ActivationKind::Relu => x.max(0.0),
            ActivationKind::Relu6 => x.clamp(0.0, 6.0),
            ActivationKind::LeakyRelu(a) => {
                if x >= 0.0 {
                    x
                } else {
                    a * x
                }
            }
            ActivationKind::Silu => x / (1.0 + (-x).exp()),
            ActivationKind::Sigmoid => 1.0 / (1.0 + (-x).exp()),
        }
    }
}

/// Spatial padding specification for conv/pool.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum PaddingMode {
    /// Explicit symmetric padding (pixels on each side).
    Explicit(usize),
    /// SAME padding, split symmetrically (PyTorch convention).
    Same,
    /// SAME padding with the asymmetric begin/end split some exporters
    /// emit for strided convs (all `kernel-1` pixels on the end side).
    /// Output shape matches `Same`; the sampling grid shifts — the
    /// operator-reimplementation difference behind the paper's
    /// PyTorch→ONNX mAP drop (Table I).
    SameAsym,
    /// No padding.
    Valid,
}

impl PaddingMode {
    /// Total padding across both sides of one spatial axis.
    pub fn total(self, kernel: usize) -> usize {
        match self {
            PaddingMode::Explicit(p) => 2 * p,
            PaddingMode::Same | PaddingMode::SameAsym => kernel - 1,
            PaddingMode::Valid => 0,
        }
    }

    /// Padding before the first pixel (the sampling offset).
    pub fn begin(self, kernel: usize) -> usize {
        match self {
            PaddingMode::Explicit(p) => p,
            PaddingMode::Same => kernel / 2,
            PaddingMode::SameAsym => 0,
            PaddingMode::Valid => 0,
        }
    }

    /// Resolve to pad-per-side for a given kernel size (odd kernels).
    /// Kept for symmetric callers (the Gemmini conv FSM).
    pub fn resolve(self, kernel: usize) -> usize {
        self.begin(kernel)
    }
}

/// Nearest-neighbour sampling convention for `Upsample`.
///
/// PyTorch's `nn.Upsample(scale_factor=2)` replicates source pixels
/// (`src = dst / 2`); ONNX `Resize` with the default half-pixel coordinate
/// transform samples `src = round((dst + 0.5) / f - 0.5)`, which shifts the
/// grid by half a pixel. The paper observes a small mAP drop at the
/// PyTorch→ONNX step (Table I) caused by exactly this kind of operator
/// re-implementation difference; the conversion pass flips this mode.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Default)]
pub enum UpsampleMode {
    /// Pixel replication (PyTorch nearest).
    #[default]
    Replicate,
    /// ONNX Resize half-pixel nearest (round-half-to-even).
    OnnxHalfPixel,
}

/// Elementwise binary ops (float tail of the model).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum BinaryKind {
    Add,
    Mul,
    Sub,
}

/// Graph operators.
#[derive(Debug, Clone, PartialEq)]
pub enum Op {
    /// Graph input placeholder.
    Input,
    /// Constant/weight tensor (payload lives out-of-band in `Graph::weights`).
    Const,
    /// 2-D convolution. Weights layout: `[out_c, kh, kw, in_c]` (HWIO-ish,
    /// matching the NHWC activation layout Gemmini consumes).
    Conv2d {
        out_channels: usize,
        kernel: usize,
        stride: usize,
        padding: PaddingMode,
        /// Fused activation (post-bias).
        activation: ActivationKind,
        /// Whether a bias vector is present.
        bias: bool,
    },
    /// Fully connected layer: `[out_features, in_features]` weights.
    Dense { out_features: usize, activation: ActivationKind, bias: bool },
    /// Max pooling.
    MaxPool2d { kernel: usize, stride: usize, padding: PaddingMode },
    /// Nearest-neighbour upsample by an integer factor (YOLO FPN path;
    /// the "resize" layer the paper adds RISC-type support for).
    Upsample { factor: usize, mode: UpsampleMode },
    /// Channel-axis concatenation (the op that makes YOLOv7 pruning hard,
    /// Section IV-B3).
    Concat,
    /// Standalone activation node (used before activation-fusion pass).
    Activation { kind: ActivationKind },
    /// float -> int8 quantize boundary.
    Quantize,
    /// int8 -> float dequantize boundary.
    Dequantize,
    /// Elementwise binary op (float tail).
    Binary { kind: BinaryKind },
    /// Reshape to the node's output shape.
    Reshape,
    /// Generic transpose (layout conversion materialization).
    Transpose { perm: Vec<usize> },
    /// Decode raw head outputs into box candidates (float tail; feeds NMS).
    BoxDecode { num_anchors: usize, num_classes: usize },
}

impl Op {
    /// Short mnemonic for reports and traces.
    pub fn mnemonic(&self) -> &'static str {
        match self {
            Op::Input => "input",
            Op::Const => "const",
            Op::Conv2d { .. } => "conv2d",
            Op::Dense { .. } => "dense",
            Op::MaxPool2d { .. } => "maxpool2d",
            Op::Upsample { .. } => "upsample",
            Op::Concat => "concat",
            Op::Activation { .. } => "activation",
            Op::Quantize => "quantize",
            Op::Dequantize => "dequantize",
            Op::Binary { .. } => "binary",
            Op::Reshape => "reshape",
            Op::Transpose { .. } => "transpose",
            Op::BoxDecode { .. } => "box_decode",
        }
    }

    /// Whether the paper's expanded TVM integration can offload this op to
    /// Gemmini (Section IV-C: convolutions, max pooling, resize, concat and
    /// dense layers via RISC-type instructions).
    pub fn accelerator_offloadable(&self) -> bool {
        match self {
            Op::Conv2d { activation, .. } | Op::Dense { activation, .. } => {
                activation.accelerator_fusable()
            }
            Op::MaxPool2d { .. } | Op::Upsample { .. } | Op::Concat => true,
            _ => false,
        }
    }

    /// Whether this op is a compute-heavy tensor op (vs. a cheap shuffle).
    pub fn is_heavy(&self) -> bool {
        matches!(self, Op::Conv2d { .. } | Op::Dense { .. })
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn leaky_relu_not_fusable_relu6_is() {
        assert!(!ActivationKind::LeakyRelu(0.1).accelerator_fusable());
        assert!(!ActivationKind::Silu.accelerator_fusable());
        assert!(ActivationKind::Relu6.accelerator_fusable());
        assert!(ActivationKind::Relu.accelerator_fusable());
    }

    #[test]
    fn activation_semantics() {
        assert_eq!(ActivationKind::Relu.apply(-1.0), 0.0);
        assert_eq!(ActivationKind::Relu6.apply(10.0), 6.0);
        assert_eq!(ActivationKind::Relu6.apply(3.0), 3.0);
        assert!((ActivationKind::LeakyRelu(0.1).apply(-2.0) + 0.2).abs() < 1e-6);
        assert!((ActivationKind::Sigmoid.apply(0.0) - 0.5).abs() < 1e-6);
        let s = ActivationKind::Silu.apply(1.0);
        assert!((s - 1.0 / (1.0 + (-1.0f32).exp())).abs() < 1e-6);
    }

    #[test]
    fn padding_resolution() {
        assert_eq!(PaddingMode::Same.resolve(3), 1);
        assert_eq!(PaddingMode::Same.resolve(5), 2);
        assert_eq!(PaddingMode::Valid.resolve(3), 0);
        assert_eq!(PaddingMode::Explicit(2).resolve(3), 2);
        // Asym keeps the output size (same total) but shifts sampling.
        assert_eq!(PaddingMode::SameAsym.total(3), PaddingMode::Same.total(3));
        assert_eq!(PaddingMode::SameAsym.begin(3), 0);
        assert_eq!(PaddingMode::Same.begin(3), 1);
    }

    #[test]
    fn conv_with_leaky_not_offloadable() {
        let conv = Op::Conv2d {
            out_channels: 32,
            kernel: 3,
            stride: 1,
            padding: PaddingMode::Same,
            activation: ActivationKind::LeakyRelu(0.1),
            bias: true,
        };
        assert!(!conv.accelerator_offloadable());
        let conv6 = Op::Conv2d {
            out_channels: 32,
            kernel: 3,
            stride: 1,
            padding: PaddingMode::Same,
            activation: ActivationKind::Relu6,
            bias: true,
        };
        assert!(conv6.accelerator_offloadable());
    }

    #[test]
    fn offloadable_set_matches_paper() {
        assert!(Op::MaxPool2d { kernel: 2, stride: 2, padding: PaddingMode::Valid }
            .accelerator_offloadable());
        assert!(Op::Upsample { factor: 2, mode: UpsampleMode::Replicate }.accelerator_offloadable());
        assert!(Op::Concat.accelerator_offloadable());
        assert!(!Op::Quantize.accelerator_offloadable());
        assert!(!Op::BoxDecode { num_anchors: 3, num_classes: 8 }.accelerator_offloadable());
    }
}
