//! Tensor metadata: shape, dtype, layout, quantization parameters.


use super::dtype::DType;
use super::layout::Layout;

/// Per-tensor quantization parameters (TFLite-style affine quantization,
/// Section IV-B4: the paper deliberately chooses *per-tensor* over
/// per-channel for ease of deployment on Gemmini).
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct QuantParams {
    /// Real value = scale * (quantized - zero_point).
    pub scale: f32,
    pub zero_point: i32,
    /// Whether the scale is stored as fp16 in hardware (Section III-A:
    /// we narrowed Gemmini's output-scaling module from fp32 to fp16).
    pub fp16_scale: bool,
}

impl QuantParams {
    pub fn new(scale: f32, zero_point: i32) -> Self {
        Self { scale, zero_point, fp16_scale: false }
    }

    /// The scale as the hardware would apply it: optionally rounded through
    /// fp16 (Section III-A optimization).
    pub fn effective_scale(&self) -> f32 {
        if self.fp16_scale {
            f16_round(self.scale)
        } else {
            self.scale
        }
    }

    /// Quantize a real value to int8 with this tensor's parameters.
    pub fn quantize(&self, x: f32) -> i8 {
        let q = (x / self.effective_scale()).round() as i32 + self.zero_point;
        q.clamp(-128, 127) as i8
    }

    /// Dequantize an int8 value back to real.
    pub fn dequantize(&self, q: i8) -> f32 {
        self.effective_scale() * (q as i32 - self.zero_point) as f32
    }
}

/// Round an f32 through IEEE binary16 and back (round-to-nearest-even).
/// Used to model the fp16 output-scaling module.
pub fn f16_round(x: f32) -> f32 {
    // Convert f32 -> f16 bits -> f32 without external crates.
    let bits = x.to_bits();
    let sign = (bits >> 16) & 0x8000;
    let mut exp = ((bits >> 23) & 0xff) as i32;
    let mut frac = bits & 0x007f_ffff;
    if exp == 0xff {
        // Inf / NaN
        let h = sign | 0x7c00 | if frac != 0 { 0x200 } else { 0 };
        return f16_bits_to_f32(h as u16);
    }
    exp -= 127;
    if exp > 15 {
        return f16_bits_to_f32((sign | 0x7c00) as u16); // overflow -> inf
    }
    if exp >= -14 {
        // Normal half. Round mantissa from 23 to 10 bits (RNE).
        let shift = 13;
        let round_bit = 1u32 << (shift - 1);
        let sticky = frac & (round_bit - 1);
        let mut h_frac = frac >> shift;
        if (frac & round_bit) != 0 && (sticky != 0 || (h_frac & 1) != 0) {
            h_frac += 1;
        }
        let mut h_exp = (exp + 15) as u32;
        if h_frac == 0x400 {
            h_frac = 0;
            h_exp += 1;
            if h_exp >= 0x1f {
                return f16_bits_to_f32((sign | 0x7c00) as u16);
            }
        }
        return f16_bits_to_f32((sign | (h_exp << 10) | h_frac) as u16);
    }
    // Subnormal half.
    if exp < -24 {
        return f16_bits_to_f32(sign as u16); // underflow -> signed zero
    }
    frac |= 0x0080_0000; // implicit leading 1
    // m = frac24 * 2^(exp+1): drop (-1 - exp) bits (subnormal halves hold
    // value m * 2^-24 with frac24 the 24-bit mantissa incl. implicit 1).
    let shift = ((-1 - exp) as u32).min(31);
    let round_bit = 1u32 << (shift - 1);
    let sticky = frac & (round_bit - 1);
    let mut h_frac = frac >> shift;
    if (frac & round_bit) != 0 && (sticky != 0 || (h_frac & 1) != 0) {
        h_frac += 1;
    }
    f16_bits_to_f32((sign | h_frac) as u16)
}

fn f16_bits_to_f32(h: u16) -> f32 {
    let sign = ((h as u32) & 0x8000) << 16;
    let exp = ((h >> 10) & 0x1f) as u32;
    let frac = (h & 0x3ff) as u32;
    let bits = if exp == 0 {
        if frac == 0 {
            sign
        } else {
            // subnormal: normalize
            let mut e = -1i32;
            let mut f = frac;
            while f & 0x400 == 0 {
                f <<= 1;
                e -= 1;
            }
            f &= 0x3ff;
            sign | (((127 - 15 + e + 2) as u32) << 23) | (f << 13)
        }
    } else if exp == 0x1f {
        sign | 0x7f80_0000 | (frac << 13)
    } else {
        sign | ((exp + 127 - 15) << 23) | (frac << 13)
    };
    f32::from_bits(bits)
}

/// Static metadata for one tensor in the graph.
#[derive(Debug, Clone, PartialEq)]
pub struct TensorMeta {
    pub name: String,
    /// Shape in the tensor's own layout.
    pub shape: Vec<usize>,
    pub dtype: DType,
    pub layout: Layout,
    /// Present iff dtype is an integer type produced by quantization.
    pub quant: Option<QuantParams>,
}

impl TensorMeta {
    pub fn new(name: impl Into<String>, shape: Vec<usize>, dtype: DType, layout: Layout) -> Self {
        Self { name: name.into(), shape, dtype, layout, quant: None }
    }

    pub fn with_quant(mut self, q: QuantParams) -> Self {
        self.quant = Some(q);
        self
    }

    /// Total element count.
    pub fn numel(&self) -> usize {
        self.shape.iter().product()
    }

    /// Total size in bytes.
    pub fn size_bytes(&self) -> usize {
        self.numel() * self.dtype.size_bytes()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn quant_roundtrip_exact_grid() {
        let q = QuantParams::new(0.5, 0);
        assert_eq!(q.quantize(1.0), 2);
        assert_eq!(q.dequantize(2), 1.0);
        assert_eq!(q.quantize(100.0), 127); // saturates
        assert_eq!(q.quantize(-100.0), -128);
    }

    #[test]
    fn quant_zero_point_shift() {
        let q = QuantParams::new(0.1, 10);
        assert_eq!(q.quantize(0.0), 10);
        assert!((q.dequantize(10)).abs() < 1e-9);
    }

    #[test]
    fn f16_round_exact_values() {
        // Values exactly representable in fp16 are unchanged.
        for v in [0.0f32, 1.0, -2.5, 0.125, 65504.0] {
            assert_eq!(f16_round(v), v, "{v}");
        }
    }

    #[test]
    fn f16_round_loses_precision() {
        // 1/3 is not representable; fp16 has ~3 decimal digits.
        let r = f16_round(1.0 / 3.0);
        assert!(r != 1.0 / 3.0);
        assert!((r - 1.0 / 3.0).abs() < 1e-3);
    }

    #[test]
    fn f16_round_overflow_underflow() {
        assert!(f16_round(1e6).is_infinite());
        assert_eq!(f16_round(1e-10), 0.0);
        assert_eq!(f16_round(-1e-10), -0.0);
    }

    #[test]
    fn f16_subnormal() {
        // Smallest positive fp16 subnormal is 2^-24 ≈ 5.96e-8.
        let tiny = 2.0f32.powi(-24);
        assert_eq!(f16_round(tiny), tiny);
    }

    #[test]
    fn fp16_scale_changes_effective_scale() {
        let mut q = QuantParams::new(1.0 / 3.0, 0);
        let full = q.effective_scale();
        q.fp16_scale = true;
        let half = q.effective_scale();
        assert_ne!(full, half);
        assert!((full - half).abs() / full < 1e-3); // small relative error
    }

    #[test]
    fn tensor_meta_sizes() {
        let t = TensorMeta::new("x", vec![1, 480, 480, 3], DType::Int8, Layout::NHWC);
        assert_eq!(t.numel(), 480 * 480 * 3);
        assert_eq!(t.size_bytes(), 480 * 480 * 3);
    }
}
