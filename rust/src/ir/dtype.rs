//! Element datatypes.
//!
//! The paper's partitioning criterion (Section IV-D) is *datatype*: the int8
//! main part runs on the accelerator (PL), the float32 NMS-prep part on the
//! ARM cores (PS). `DType` therefore carries everything the partitioner and
//! the quantizer need.


/// Element type of a tensor in the IR.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum DType {
    /// 8-bit signed integer — Gemmini's native input type.
    Int8,
    /// 32-bit signed integer — Gemmini's accumulator type.
    Int32,
    /// IEEE half precision — used by our reduced output-scaling module
    /// (Section III-A: scale factor narrowed from float32 to float16).
    Float16,
    /// IEEE single precision — the NMS post-processing part.
    Float32,
}

impl DType {
    /// Size of one element in bytes.
    pub fn size_bytes(self) -> usize {
        match self {
            DType::Int8 => 1,
            DType::Float16 => 2,
            DType::Int32 | DType::Float32 => 4,
        }
    }

    /// True for integer types (accelerator-eligible in the paper's flow).
    pub fn is_integer(self) -> bool {
        matches!(self, DType::Int8 | DType::Int32)
    }

    /// True for floating-point types (PS-only in the paper's flow).
    pub fn is_float(self) -> bool {
        !self.is_integer()
    }

    /// Representable range for integer types, as (min, max).
    pub fn int_range(self) -> Option<(i64, i64)> {
        match self {
            DType::Int8 => Some((-128, 127)),
            DType::Int32 => Some((i32::MIN as i64, i32::MAX as i64)),
            _ => None,
        }
    }
}

impl std::fmt::Display for DType {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        let s = match self {
            DType::Int8 => "int8",
            DType::Int32 => "int32",
            DType::Float16 => "float16",
            DType::Float32 => "float32",
        };
        f.write_str(s)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn sizes() {
        assert_eq!(DType::Int8.size_bytes(), 1);
        assert_eq!(DType::Float16.size_bytes(), 2);
        assert_eq!(DType::Int32.size_bytes(), 4);
        assert_eq!(DType::Float32.size_bytes(), 4);
    }

    #[test]
    fn integer_classification_partitions_types() {
        for d in [DType::Int8, DType::Int32, DType::Float16, DType::Float32] {
            assert_ne!(d.is_integer(), d.is_float());
        }
    }

    #[test]
    fn int8_range() {
        assert_eq!(DType::Int8.int_range(), Some((-128, 127)));
        assert_eq!(DType::Float32.int_range(), None);
    }

    #[test]
    fn display_names() {
        assert_eq!(DType::Int8.to_string(), "int8");
        assert_eq!(DType::Float16.to_string(), "float16");
    }
}
