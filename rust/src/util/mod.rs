//! Small utilities the offline environment forces us to hand-roll:
//! a deterministic PRNG (no `rand`), a minimal JSON writer (no `serde`),
//! and a lightweight property-test driver (no `proptest`).

pub mod json;
pub mod prop;
pub mod rng;

pub use rng::Rng;
