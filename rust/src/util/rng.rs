//! Deterministic PRNG: xoshiro256** seeded via splitmix64.
//!
//! All stochastic components (dataset generation, tuner search, property
//! tests) take an explicit seed so every experiment in EXPERIMENTS.md is
//! exactly reproducible.

/// xoshiro256** generator.
#[derive(Debug, Clone)]
pub struct Rng {
    s: [u64; 4],
}

impl Rng {
    pub fn new(seed: u64) -> Self {
        // splitmix64 expansion of the seed into the state.
        let mut x = seed.wrapping_add(0x9E3779B97F4A7C15);
        let mut next = || {
            x = x.wrapping_add(0x9E3779B97F4A7C15);
            let mut z = x;
            z = (z ^ (z >> 30)).wrapping_mul(0xBF58476D1CE4E5B9);
            z = (z ^ (z >> 27)).wrapping_mul(0x94D049BB133111EB);
            z ^ (z >> 31)
        };
        Self { s: [next(), next(), next(), next()] }
    }

    pub fn next_u64(&mut self) -> u64 {
        let s = &mut self.s;
        let result = s[1].wrapping_mul(5).rotate_left(7).wrapping_mul(9);
        let t = s[1] << 17;
        s[2] ^= s[0];
        s[3] ^= s[1];
        s[1] ^= s[2];
        s[0] ^= s[3];
        s[2] ^= t;
        s[3] = s[3].rotate_left(45);
        result
    }

    /// Uniform in `[0, 1)`.
    pub fn f64(&mut self) -> f64 {
        (self.next_u64() >> 11) as f64 * (1.0 / (1u64 << 53) as f64)
    }

    /// Uniform in `[lo, hi)`.
    pub fn range_f64(&mut self, lo: f64, hi: f64) -> f64 {
        lo + self.f64() * (hi - lo)
    }

    /// Uniform integer in `[0, n)`. Panics if `n == 0`.
    pub fn below(&mut self, n: usize) -> usize {
        assert!(n > 0);
        (self.next_u64() % n as u64) as usize
    }

    /// Uniform integer in `[lo, hi)`.
    pub fn range(&mut self, lo: usize, hi: usize) -> usize {
        lo + self.below(hi - lo)
    }

    /// Standard normal via Box–Muller.
    pub fn normal(&mut self) -> f64 {
        let u1 = self.f64().max(1e-12);
        let u2 = self.f64();
        (-2.0 * u1.ln()).sqrt() * (2.0 * std::f64::consts::PI * u2).cos()
    }

    /// Bernoulli.
    pub fn chance(&mut self, p: f64) -> bool {
        self.f64() < p
    }

    /// Random int8 in `[-128, 127]`.
    pub fn i8(&mut self) -> i8 {
        (self.next_u64() & 0xff) as u8 as i8
    }

    /// Fisher–Yates shuffle.
    pub fn shuffle<T>(&mut self, v: &mut [T]) {
        for i in (1..v.len()).rev() {
            let j = self.below(i + 1);
            v.swap(i, j);
        }
    }

    /// Pick a random element.
    pub fn choose<'a, T>(&mut self, v: &'a [T]) -> &'a T {
        &v[self.below(v.len())]
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic_per_seed() {
        let mut a = Rng::new(42);
        let mut b = Rng::new(42);
        for _ in 0..100 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
        let mut c = Rng::new(43);
        assert_ne!(Rng::new(42).next_u64(), c.next_u64());
    }

    #[test]
    fn f64_in_unit_interval() {
        let mut r = Rng::new(1);
        for _ in 0..10_000 {
            let x = r.f64();
            assert!((0.0..1.0).contains(&x));
        }
    }

    #[test]
    fn below_bounds() {
        let mut r = Rng::new(2);
        for _ in 0..10_000 {
            assert!(r.below(7) < 7);
        }
    }

    #[test]
    fn normal_mean_and_var_sane() {
        let mut r = Rng::new(3);
        let n = 50_000;
        let xs: Vec<f64> = (0..n).map(|_| r.normal()).collect();
        let mean = xs.iter().sum::<f64>() / n as f64;
        let var = xs.iter().map(|x| (x - mean) * (x - mean)).sum::<f64>() / n as f64;
        assert!(mean.abs() < 0.02, "mean {mean}");
        assert!((var - 1.0).abs() < 0.05, "var {var}");
    }

    #[test]
    fn shuffle_is_permutation() {
        let mut r = Rng::new(4);
        let mut v: Vec<usize> = (0..100).collect();
        r.shuffle(&mut v);
        let mut sorted = v.clone();
        sorted.sort();
        assert_eq!(sorted, (0..100).collect::<Vec<_>>());
    }
}
