//! Minimal JSON value + writer/parser (offline stand-in for serde_json).
//! Used for tuner records, experiment logs and the CLI's report output.

use std::collections::BTreeMap;
use std::fmt::Write as _;

/// A JSON value.
#[derive(Debug, Clone, PartialEq)]
pub enum Json {
    Null,
    Bool(bool),
    Num(f64),
    Str(String),
    Arr(Vec<Json>),
    Obj(BTreeMap<String, Json>),
}

impl Json {
    pub fn obj(pairs: Vec<(&str, Json)>) -> Json {
        Json::Obj(pairs.into_iter().map(|(k, v)| (k.to_string(), v)).collect())
    }

    pub fn get(&self, key: &str) -> Option<&Json> {
        match self {
            Json::Obj(m) => m.get(key),
            _ => None,
        }
    }

    pub fn as_f64(&self) -> Option<f64> {
        match self {
            Json::Num(n) => Some(*n),
            _ => None,
        }
    }

    pub fn as_bool(&self) -> Option<bool> {
        match self {
            Json::Bool(b) => Some(*b),
            _ => None,
        }
    }

    pub fn as_str(&self) -> Option<&str> {
        match self {
            Json::Str(s) => Some(s),
            _ => None,
        }
    }

    pub fn as_arr(&self) -> Option<&[Json]> {
        match self {
            Json::Arr(a) => Some(a),
            _ => None,
        }
    }

    /// Serialize to a compact string.
    pub fn dump(&self) -> String {
        let mut s = String::new();
        self.write(&mut s);
        s
    }

    fn write(&self, out: &mut String) {
        match self {
            Json::Null => out.push_str("null"),
            Json::Bool(b) => out.push_str(if *b { "true" } else { "false" }),
            Json::Num(n) => {
                if n.fract() == 0.0 && n.abs() < 1e15 {
                    let _ = write!(out, "{}", *n as i64);
                } else {
                    let _ = write!(out, "{n}");
                }
            }
            Json::Str(s) => {
                out.push('"');
                for c in s.chars() {
                    match c {
                        '"' => out.push_str("\\\""),
                        '\\' => out.push_str("\\\\"),
                        '\n' => out.push_str("\\n"),
                        '\t' => out.push_str("\\t"),
                        c if (c as u32) < 0x20 => {
                            let _ = write!(out, "\\u{:04x}", c as u32);
                        }
                        c => out.push(c),
                    }
                }
                out.push('"');
            }
            Json::Arr(a) => {
                out.push('[');
                for (i, v) in a.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    v.write(out);
                }
                out.push(']');
            }
            Json::Obj(m) => {
                out.push('{');
                for (i, (k, v)) in m.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    Json::Str(k.clone()).write(out);
                    out.push(':');
                    v.write(out);
                }
                out.push('}');
            }
        }
    }

    /// Parse from a string (strict enough for our own output and simple
    /// hand-written configs).
    pub fn parse(s: &str) -> Result<Json, String> {
        let b = s.as_bytes();
        let mut pos = 0usize;
        let v = parse_value(b, &mut pos)?;
        skip_ws(b, &mut pos);
        if pos != b.len() {
            return Err(format!("trailing data at byte {pos}"));
        }
        Ok(v)
    }
}

fn skip_ws(b: &[u8], pos: &mut usize) {
    while *pos < b.len() && (b[*pos] as char).is_whitespace() {
        *pos += 1;
    }
}

fn parse_value(b: &[u8], pos: &mut usize) -> Result<Json, String> {
    skip_ws(b, pos);
    if *pos >= b.len() {
        return Err("unexpected end".into());
    }
    match b[*pos] {
        b'n' => expect(b, pos, "null").map(|_| Json::Null),
        b't' => expect(b, pos, "true").map(|_| Json::Bool(true)),
        b'f' => expect(b, pos, "false").map(|_| Json::Bool(false)),
        b'"' => parse_string(b, pos).map(Json::Str),
        b'[' => {
            *pos += 1;
            let mut arr = Vec::new();
            skip_ws(b, pos);
            if *pos < b.len() && b[*pos] == b']' {
                *pos += 1;
                return Ok(Json::Arr(arr));
            }
            loop {
                arr.push(parse_value(b, pos)?);
                skip_ws(b, pos);
                match b.get(*pos) {
                    Some(b',') => *pos += 1,
                    Some(b']') => {
                        *pos += 1;
                        return Ok(Json::Arr(arr));
                    }
                    _ => return Err(format!("bad array at {pos}")),
                }
            }
        }
        b'{' => {
            *pos += 1;
            let mut map = BTreeMap::new();
            skip_ws(b, pos);
            if *pos < b.len() && b[*pos] == b'}' {
                *pos += 1;
                return Ok(Json::Obj(map));
            }
            loop {
                skip_ws(b, pos);
                let key = parse_string(b, pos)?;
                skip_ws(b, pos);
                if b.get(*pos) != Some(&b':') {
                    return Err(format!("expected ':' at {pos}"));
                }
                *pos += 1;
                let val = parse_value(b, pos)?;
                map.insert(key, val);
                skip_ws(b, pos);
                match b.get(*pos) {
                    Some(b',') => *pos += 1,
                    Some(b'}') => {
                        *pos += 1;
                        return Ok(Json::Obj(map));
                    }
                    _ => return Err(format!("bad object at {pos}")),
                }
            }
        }
        _ => parse_number(b, pos),
    }
}

fn expect(b: &[u8], pos: &mut usize, word: &str) -> Result<(), String> {
    if b[*pos..].starts_with(word.as_bytes()) {
        *pos += word.len();
        Ok(())
    } else {
        Err(format!("expected {word} at {pos}"))
    }
}

fn parse_string(b: &[u8], pos: &mut usize) -> Result<String, String> {
    if b.get(*pos) != Some(&b'"') {
        return Err(format!("expected string at {pos}"));
    }
    *pos += 1;
    let mut out = String::new();
    while *pos < b.len() {
        match b[*pos] {
            b'"' => {
                *pos += 1;
                return Ok(out);
            }
            b'\\' => {
                *pos += 1;
                match b.get(*pos) {
                    Some(b'n') => out.push('\n'),
                    Some(b't') => out.push('\t'),
                    Some(b'"') => out.push('"'),
                    Some(b'\\') => out.push('\\'),
                    Some(b'u') => {
                        let hex = std::str::from_utf8(&b[*pos + 1..*pos + 5])
                            .map_err(|e| e.to_string())?;
                        let code = u32::from_str_radix(hex, 16).map_err(|e| e.to_string())?;
                        out.push(char::from_u32(code).ok_or("bad codepoint")?);
                        *pos += 4;
                    }
                    _ => return Err(format!("bad escape at {pos}")),
                }
                *pos += 1;
            }
            _ => {
                // advance one UTF-8 scalar
                let s = std::str::from_utf8(&b[*pos..]).map_err(|e| e.to_string())?;
                let c = s.chars().next().unwrap();
                out.push(c);
                *pos += c.len_utf8();
            }
        }
    }
    Err("unterminated string".into())
}

fn parse_number(b: &[u8], pos: &mut usize) -> Result<Json, String> {
    let start = *pos;
    while *pos < b.len()
        && matches!(b[*pos], b'0'..=b'9' | b'-' | b'+' | b'.' | b'e' | b'E')
    {
        *pos += 1;
    }
    let s = std::str::from_utf8(&b[start..*pos]).map_err(|e| e.to_string())?;
    s.parse::<f64>().map(Json::Num).map_err(|e| format!("bad number '{s}': {e}"))
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn roundtrip_object() {
        let j = Json::obj(vec![
            ("name", Json::Str("conv_3".into())),
            ("cycles", Json::Num(12345.0)),
            ("tuned", Json::Bool(true)),
            ("factors", Json::Arr(vec![Json::Num(4.0), Json::Num(8.0)])),
        ]);
        let s = j.dump();
        let back = Json::parse(&s).unwrap();
        assert_eq!(j, back);
    }

    #[test]
    fn parses_whitespace_and_nesting() {
        let s = r#" { "a" : [ 1 , 2.5 , { "b" : null } ] , "c" : "x\ny" } "#;
        let j = Json::parse(s).unwrap();
        assert_eq!(j.get("c").unwrap().as_str().unwrap(), "x\ny");
        assert_eq!(j.get("a").unwrap().as_arr().unwrap()[1].as_f64().unwrap(), 2.5);
    }

    #[test]
    fn escapes_in_output() {
        let j = Json::Str("a\"b\\c\nd".into());
        assert_eq!(Json::parse(&j.dump()).unwrap(), j);
    }

    #[test]
    fn rejects_garbage() {
        assert!(Json::parse("{").is_err());
        assert!(Json::parse("[1,]").is_err());
        assert!(Json::parse("nope").is_err());
        assert!(Json::parse("{} extra").is_err());
    }

    #[test]
    fn integers_print_clean() {
        assert_eq!(Json::Num(42.0).dump(), "42");
        assert_eq!(Json::Num(1.5).dump(), "1.5");
    }
}
