//! Lightweight property-test driver (offline stand-in for proptest).
//!
//! `check(seed, cases, gen, prop)` runs `prop` on `cases` generated inputs;
//! on failure it reports the failing case index and debug representation.
//! No shrinking — failures print the full input, which our inputs are small
//! enough to read directly.

use super::rng::Rng;

/// Run a property over generated cases; panics (with context) on failure.
pub fn check<T: std::fmt::Debug>(
    seed: u64,
    cases: usize,
    mut gen: impl FnMut(&mut Rng) -> T,
    mut prop: impl FnMut(&T) -> Result<(), String>,
) {
    let mut rng = Rng::new(seed);
    for i in 0..cases {
        let input = gen(&mut rng);
        if let Err(msg) = prop(&input) {
            panic!("property failed on case {i} (seed {seed}): {msg}\ninput: {input:#?}");
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn passes_valid_property() {
        check(
            1,
            200,
            |r| (r.below(100), r.below(100)),
            |&(a, b)| {
                if a + b >= a {
                    Ok(())
                } else {
                    Err("overflow".into())
                }
            },
        );
    }

    #[test]
    #[should_panic(expected = "property failed")]
    fn fails_invalid_property() {
        check(2, 100, |r| r.below(10), |&x| if x < 5 { Ok(()) } else { Err(format!("{x} >= 5")) });
    }
}
