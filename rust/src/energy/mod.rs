//! Platform power / energy models (Table IV, Figures 7 & 8).
//!
//! The paper measures wall power with on-board meters; we model each
//! platform as `P_static + P_dynamic(activity)` and compute
//! `energy = power × latency`, with latency coming from the Gemmini
//! simulator (our platforms) or the calibrated baseline models
//! ([`crate::baselines`]). Efficiency is reported exactly as the paper
//! does: `GOP / energy` (numerically equal to GOP/s/W).

use crate::fpga::resources::Board;
use crate::gemmini::config::{GemminiConfig, ScaleDtype};

/// FPGA board + design power model.
///
/// `P = board_static + rocket + array_dynamic + memory_dynamic`, with the
/// array term scaling with PEs × clock (CMOS dynamic power) and a small
/// discount for DSP-packed PEs (hard blocks switch less capacitance than
/// LUT fabric for the same multiply).
#[derive(Debug, Clone)]
pub struct FpgaPowerModel {
    /// Board static + PS idle power, W.
    pub board_static_w: f64,
    /// RocketCore + uncore dynamic, W.
    pub rocket_w: f64,
    /// Per-PE dynamic power at 100 MHz, mW (LUT-fabric PE).
    pub pe_mw_per_100mhz: f64,
    /// Relative switching of a DSP-packed PE vs a fabric PE.
    pub packed_factor: f64,
    /// Scratchpad/accumulator dynamic per KiB at 100 MHz, mW.
    pub mem_mw_per_kib: f64,
}

impl FpgaPowerModel {
    pub fn for_board(board: Board) -> Self {
        match board {
            Board::Zcu102 => Self {
                board_static_w: 4.1,
                rocket_w: 0.9,
                pe_mw_per_100mhz: 3.2,
                packed_factor: 0.62,
                mem_mw_per_kib: 0.25,
            },
            // The RFSoC board idles hotter (RF converters, bigger part).
            Board::Zcu111 => Self {
                board_static_w: 6.8,
                rocket_w: 0.9,
                pe_mw_per_100mhz: 3.2,
                packed_factor: 0.62,
                mem_mw_per_kib: 0.25,
            },
        }
    }

    /// Average board power while running the accelerator, W.
    /// `utilization` in [0,1] scales the array's dynamic component.
    pub fn power_w(&self, cfg: &GemminiConfig, utilization: f64) -> f64 {
        let pes = (cfg.dim * cfg.dim) as f64;
        let f_scale = cfg.clock_mhz / 100.0;
        let pe_factor = if cfg.dsp_packing { self.packed_factor } else { 1.0 };
        // Clock tree + idle array switching keeps a floor even at low util.
        let activity = 0.35 + 0.65 * utilization.clamp(0.0, 1.0);
        let array_w = pes * self.pe_mw_per_100mhz * pe_factor * f_scale * activity / 1000.0;
        let mem_kib = (cfg.scratchpad_kib + 4 * cfg.accumulator_kib) as f64;
        let mem_w = mem_kib * self.mem_mw_per_kib * f_scale * activity / 1000.0;
        let scale_w = match cfg.scale_dtype {
            ScaleDtype::F32 => 0.35,
            ScaleDtype::F16 => 0.12,
        };
        self.board_static_w + self.rocket_w + array_w + mem_w + scale_w
    }
}

/// The paper's Figure 8 operating point for a Gemmini build: peak
/// accelerator-phase efficiency in GOP/s/W — the array fully active
/// (`utilization = 1`), throughput at the configuration's peak. For the
/// "ours" ZCU102 build this lands on the paper's headline 36.5 GOP/s/W
/// (the fleet energy ledger's golden test pins the band); an end-to-end
/// serving fleet always sits below it, because dispatch overhead, idle
/// time and imperfect schedules all burn watts without contributing
/// GOP.
pub fn accelerator_phase_efficiency(cfg: &GemminiConfig, board: Board) -> f64 {
    let power = FpgaPowerModel::for_board(board).power_w(cfg, 1.0);
    cfg.peak_gops() / power
}

/// One energy measurement row (a cell of Table IV).
#[derive(Debug, Clone)]
pub struct EnergyReport {
    pub platform: String,
    pub model: String,
    pub latency_s: f64,
    pub power_w: f64,
    pub energy_j: f64,
    pub gop: f64,
}

impl EnergyReport {
    pub fn new(platform: &str, model: &str, latency_s: f64, power_w: f64, gop: f64) -> Self {
        Self {
            platform: platform.into(),
            model: model.into(),
            latency_s,
            power_w,
            energy_j: latency_s * power_w,
            gop,
        }
    }

    /// The paper's efficiency metric: GOP per Joule (= GOP/s/W).
    pub fn efficiency(&self) -> f64 {
        self.gop / self.energy_j
    }

    /// Throughput in GOP/s.
    pub fn gops(&self) -> f64 {
        self.gop / self.latency_s
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn ours_draws_plausible_board_power() {
        let m = FpgaPowerModel::for_board(Board::Zcu102);
        let p = m.power_w(&GemminiConfig::ours_zcu102(), 0.5);
        assert!((7.0..11.0).contains(&p), "got {p} W");
    }

    #[test]
    fn original_draws_less_than_ours() {
        let m = FpgaPowerModel::for_board(Board::Zcu102);
        let orig = m.power_w(&GemminiConfig::original_zcu102(), 0.5);
        let ours = m.power_w(&GemminiConfig::ours_zcu102(), 0.5);
        assert!(orig < ours, "{orig} !< {ours}");
        // …but not 6× less: static power dominates the gap.
        assert!(ours / orig < 2.0);
    }

    #[test]
    fn packing_reduces_array_power() {
        let m = FpgaPowerModel::for_board(Board::Zcu102);
        let mut unpacked = GemminiConfig::ours_zcu102();
        unpacked.dsp_packing = false;
        let p_packed = m.power_w(&GemminiConfig::ours_zcu102(), 1.0);
        let p_unpacked = m.power_w(&unpacked, 1.0);
        assert!(p_packed < p_unpacked);
    }

    #[test]
    fn zcu111_board_hotter() {
        let p102 = FpgaPowerModel::for_board(Board::Zcu102)
            .power_w(&GemminiConfig::ours_zcu102(), 0.5);
        let p111 = FpgaPowerModel::for_board(Board::Zcu111)
            .power_w(&GemminiConfig::ours_zcu111(), 0.5);
        assert!(p111 > p102);
    }

    #[test]
    fn efficiency_is_gop_per_joule() {
        let r = EnergyReport::new("test", "m", 0.1, 10.0, 7.7);
        assert!((r.energy_j - 1.0).abs() < 1e-12);
        assert!((r.efficiency() - 7.7).abs() < 1e-12);
        assert!((r.gops() - 77.0).abs() < 1e-12);
    }

    #[test]
    fn accelerator_phase_efficiency_matches_fig8_ordering() {
        // ZCU102-ours is the paper's efficiency champion among our
        // builds; the original config pays the same static floor for a
        // quarter of the PEs.
        let ours = accelerator_phase_efficiency(&GemminiConfig::ours_zcu102(), Board::Zcu102);
        let orig =
            accelerator_phase_efficiency(&GemminiConfig::original_zcu102(), Board::Zcu102);
        let z111 = accelerator_phase_efficiency(&GemminiConfig::ours_zcu111(), Board::Zcu111);
        assert!(ours > orig, "{ours} !> {orig}");
        assert!(ours > z111, "{ours} !> {z111} (hotter board)");
        assert!(ours > 20.0 && ours < 60.0, "{ours} GOP/s/W out of range");
    }

    #[test]
    fn utilization_scales_power_mildly() {
        let m = FpgaPowerModel::for_board(Board::Zcu102);
        let cfg = GemminiConfig::ours_zcu102();
        let idle = m.power_w(&cfg, 0.0);
        let busy = m.power_w(&cfg, 1.0);
        assert!(busy > idle);
        assert!(busy / idle < 2.0); // static + clock tree floor
    }
}
