//! Scratchpad and accumulator memories.
//!
//! The scratchpad holds int8 rows of `dim` elements; the accumulator holds
//! int32 rows of `dim` elements. Both are row-addressed, matching Gemmini's
//! local address space.

use super::config::GemminiConfig;

/// The int8 scratchpad.
#[derive(Debug, Clone)]
pub struct Scratchpad {
    pub dim: usize,
    rows: Vec<i8>,
    num_rows: usize,
}

impl Scratchpad {
    pub fn new(cfg: &GemminiConfig) -> Self {
        let num_rows = cfg.scratchpad_rows();
        Self { dim: cfg.dim, rows: vec![0; num_rows * cfg.dim], num_rows }
    }

    pub fn num_rows(&self) -> usize {
        self.num_rows
    }

    pub fn write_row(&mut self, row: usize, data: &[i8]) {
        assert!(row < self.num_rows, "scratchpad row {row} out of range");
        assert!(data.len() <= self.dim);
        let base = row * self.dim;
        self.rows[base..base + data.len()].copy_from_slice(data);
        // zero-fill the remainder (hardware mvin pads partial rows)
        for i in data.len()..self.dim {
            self.rows[base + i] = 0;
        }
    }

    pub fn read_row(&self, row: usize) -> &[i8] {
        assert!(row < self.num_rows, "scratchpad row {row} out of range");
        &self.rows[row * self.dim..(row + 1) * self.dim]
    }
}

/// The int32 accumulator.
#[derive(Debug, Clone)]
pub struct Accumulator {
    pub dim: usize,
    rows: Vec<i32>,
    num_rows: usize,
}

impl Accumulator {
    pub fn new(cfg: &GemminiConfig) -> Self {
        let num_rows = cfg.accumulator_rows();
        Self { dim: cfg.dim, rows: vec![0; num_rows * cfg.dim], num_rows }
    }

    pub fn num_rows(&self) -> usize {
        self.num_rows
    }

    /// Overwrite a row.
    pub fn set_row(&mut self, row: usize, data: &[i32]) {
        assert!(row < self.num_rows, "accumulator row {row} out of range");
        let base = row * self.dim;
        for (i, &v) in data.iter().enumerate() {
            self.rows[base + i] = v;
        }
        for i in data.len()..self.dim {
            self.rows[base + i] = 0;
        }
    }

    /// Add into a row (the accumulate path).
    pub fn add_row(&mut self, row: usize, data: &[i32]) {
        assert!(row < self.num_rows, "accumulator row {row} out of range");
        let base = row * self.dim;
        for (i, &v) in data.iter().enumerate() {
            self.rows[base + i] = self.rows[base + i].wrapping_add(v);
        }
    }

    pub fn read_row(&self, row: usize) -> &[i32] {
        assert!(row < self.num_rows, "accumulator row {row} out of range");
        &self.rows[row * self.dim..(row + 1) * self.dim]
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::gemmini::config::GemminiConfig;

    #[test]
    fn scratchpad_partial_row_zero_fills() {
        let cfg = GemminiConfig::original_zcu102();
        let mut sp = Scratchpad::new(&cfg);
        sp.write_row(3, &[1, 2, 3]);
        let r = sp.read_row(3);
        assert_eq!(&r[..3], &[1, 2, 3]);
        assert!(r[3..].iter().all(|&v| v == 0));
    }

    #[test]
    fn accumulator_accumulate_vs_set() {
        let cfg = GemminiConfig::original_zcu102();
        let mut acc = Accumulator::new(&cfg);
        acc.set_row(0, &[10; 16]);
        acc.add_row(0, &[5; 16]);
        assert!(acc.read_row(0).iter().all(|&v| v == 15));
        acc.set_row(0, &[1; 16]);
        assert!(acc.read_row(0).iter().all(|&v| v == 1));
    }

    #[test]
    #[should_panic(expected = "out of range")]
    fn scratchpad_bounds_checked() {
        let cfg = GemminiConfig::original_zcu102();
        let mut sp = Scratchpad::new(&cfg);
        let n = sp.num_rows();
        sp.write_row(n, &[0]);
    }

    #[test]
    fn capacities_match_config() {
        let cfg = GemminiConfig::ours_zcu102();
        let sp = Scratchpad::new(&cfg);
        let acc = Accumulator::new(&cfg);
        assert_eq!(sp.num_rows(), cfg.scratchpad_rows());
        assert_eq!(acc.num_rows(), cfg.accumulator_rows());
    }
}
