//! Gemmini configuration parameters (Table III of the paper).


/// Systolic-array dataflow.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Dataflow {
    /// Weight stationary only (the paper's choice — Table III "Ours").
    WeightStationary,
    /// Output stationary only.
    OutputStationary,
    /// Hardware supports both (default Gemmini; costs extra resources).
    Both,
}

/// Datatype of the output-scaling factor applied on accumulator read-out.
/// Section III-A: the paper narrows this from float32 to float16 to save
/// FPGA resources "without appreciating any degradation".
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum ScaleDtype {
    F32,
    F16,
}

/// One level of the Gemmini memory hierarchy as the analytical
/// pre-filter ([`crate::scheduler::prefilter`]) sees it: a bandwidth
/// ceiling, a per-access latency, an in-flight window, and (for on-chip
/// memories) a row capacity the schedule must respect. FactorFlow-style:
/// the per-level parameters are all derived from the configuration, so a
/// config edit re-parameterizes the whole cost model.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct MemLevel {
    pub name: &'static str,
    /// Sustained transfer bandwidth across this level, bytes/cycle.
    pub bytes_per_cycle: f64,
    /// Latency of one access (DRAM round-trip, read-pipeline depth).
    pub access_latency: f64,
    /// Accesses that may overlap (ROB window, port count).
    pub in_flight: f64,
    /// Capacity in rows of `dim` elements (`None` = off-chip, unbounded).
    pub capacity_rows: Option<usize>,
}

/// Full accelerator configuration. Defaults mirror Gemmini's defaults;
/// [`GemminiConfig::ours`] mirrors the paper's Table III column "Ours".
#[derive(Debug, Clone, PartialEq)]
pub struct GemminiConfig {
    /// PE array is `dim × dim` (Table III "PEs": 16×16 default, 32×32 ours).
    pub dim: usize,
    pub dataflow: Dataflow,
    /// Scratchpad capacity in KiB (256 default, 512 ours).
    pub scratchpad_kib: usize,
    /// Accumulator capacity in KiB (64 default, 128 ours).
    pub accumulator_kib: usize,
    /// Scratchpad ports (1 default, 2 ours — enables simultaneous
    /// Load-controller writes and Execute-controller reads).
    pub scratchpad_ports: usize,
    /// Scratchpad read pipeline delay in cycles (4 default, 8 ours — the
    /// deeper pipeline is what lets the FPGA design close timing at a
    /// higher clock).
    pub scratchpad_read_delay: usize,
    /// Bits retained at the spatial-array output (20 default, 18 ours).
    pub spatial_output_bits: usize,
    /// Maximum in-flight memory requests (16 default, 32 ours).
    pub max_in_flight: usize,
    /// Input element width in bits (8 throughout the paper).
    pub input_bits: usize,
    /// Accumulator element width in bits.
    pub acc_bits: usize,
    /// Output-scaling factor datatype (Section III-A).
    pub scale_dtype: ScaleDtype,
    /// Optional Gemmini modules the paper disables for YOLO-type networks
    /// (Section III-A): normalization (transformers), transposer, virtual
    /// address translation, kernel dilation.
    pub has_normalization: bool,
    pub has_transposer: bool,
    pub has_virtual_addr: bool,
    pub has_dilation: bool,
    /// DSP-packing applied (two int8 weight multiplies per DSP48E2,
    /// Section III-A / Figure 1).
    pub dsp_packing: bool,
    /// Clock frequency the configuration closes timing at, MHz
    /// (Table II: 100 MHz original on ZCU102, 150 ours, 167 on ZCU111).
    pub clock_mhz: f64,
    /// Effective DDR bandwidth to the PS memory, GB/s. This is a property
    /// of the PS-side DDR controller, *not* of the PL clock — a faster
    /// accelerator clock does not buy more memory bandwidth (why the
    /// paper's 6× peak uplift yields only a 1.6× default-schedule speedup:
    /// both designs share the same DDR).
    pub ddr_gbs: f64,
    /// DRAM round-trip latency in cycles at this clock.
    pub dram_latency: usize,
}

impl GemminiConfig {
    /// The original, unmodified Gemmini configuration as deployed on the
    /// ZCU102 baseline (Tables II & III, rows "Default"/"Original").
    pub fn original_zcu102() -> Self {
        Self {
            dim: 16,
            dataflow: Dataflow::Both,
            scratchpad_kib: 256,
            accumulator_kib: 64,
            scratchpad_ports: 1,
            scratchpad_read_delay: 4,
            spatial_output_bits: 20,
            max_in_flight: 16,
            input_bits: 8,
            acc_bits: 32,
            scale_dtype: ScaleDtype::F32,
            has_normalization: true,
            has_transposer: true,
            has_virtual_addr: true,
            has_dilation: true,
            dsp_packing: false,
            clock_mhz: 100.0,
            ddr_gbs: 2.4,
            dram_latency: 40,
        }
    }

    /// The paper's optimized configuration on the ZCU102 (Table III "Ours").
    pub fn ours_zcu102() -> Self {
        Self {
            dim: 32,
            dataflow: Dataflow::WeightStationary,
            scratchpad_kib: 512,
            accumulator_kib: 128,
            scratchpad_ports: 2,
            scratchpad_read_delay: 8,
            spatial_output_bits: 18,
            max_in_flight: 32,
            input_bits: 8,
            acc_bits: 32,
            scale_dtype: ScaleDtype::F16,
            has_normalization: false,
            has_transposer: false,
            has_virtual_addr: false,
            has_dilation: false,
            dsp_packing: true,
            clock_mhz: 150.0,
            ddr_gbs: 2.4,
            dram_latency: 40,
        }
    }

    /// The paper's configuration on the ZCU111 (Table II row 3: same
    /// architecture, URAM-backed scratchpad, 167 MHz).
    pub fn ours_zcu111() -> Self {
        Self { clock_mhz: 167.0, ..Self::ours_zcu102() }
    }

    /// Scratchpad geometry: rows of `dim` int8 elements.
    pub fn scratchpad_rows(&self) -> usize {
        self.scratchpad_kib * 1024 / (self.dim * self.input_bits / 8)
    }

    /// Accumulator geometry: rows of `dim` int32 elements.
    pub fn accumulator_rows(&self) -> usize {
        self.accumulator_kib * 1024 / (self.dim * self.acc_bits / 8)
    }

    /// DMA bus bytes per accelerator cycle (DDR bandwidth ÷ clock).
    pub fn bus_bytes_per_cycle(&self) -> usize {
        ((self.ddr_gbs * 1e3 / self.clock_mhz).round() as usize).max(1)
    }

    /// Peak MACs per cycle (the whole PE array active).
    pub fn peak_macs_per_cycle(&self) -> usize {
        self.dim * self.dim
    }

    /// The DRAM ↔ on-chip level of the memory hierarchy (shared by the
    /// DRAM→scratchpad and DRAM→accumulator paths — both ride the same
    /// DMA engine and DDR controller). Capacity is unbounded from the
    /// accelerator's point of view.
    pub fn dram_level(&self) -> MemLevel {
        MemLevel {
            name: "dram",
            bytes_per_cycle: self.bus_bytes_per_cycle() as f64,
            access_latency: self.dram_latency as f64,
            in_flight: self.max_in_flight as f64,
            capacity_rows: None,
        }
    }

    /// The scratchpad → PE-array level: one `dim`-element int8 row per
    /// port per cycle, `scratchpad_read_delay` pipeline latency, and the
    /// capacity the schedule's A/B blocks must fit in.
    pub fn scratchpad_level(&self) -> MemLevel {
        MemLevel {
            name: "scratchpad",
            bytes_per_cycle: (self.scratchpad_ports * self.dim * self.input_bits / 8) as f64,
            access_latency: self.scratchpad_read_delay as f64,
            in_flight: self.scratchpad_ports as f64,
            capacity_rows: Some(self.scratchpad_rows()),
        }
    }

    /// The accumulator level (PE results in, mvout drains out): one
    /// `dim`-element int32 row per cycle, drained through the same read
    /// pipeline as the scratchpad, with the capacity live output tiles
    /// must fit in.
    pub fn accumulator_level(&self) -> MemLevel {
        MemLevel {
            name: "accumulator",
            bytes_per_cycle: (self.dim * self.acc_bits / 8) as f64,
            access_latency: self.scratchpad_read_delay as f64,
            in_flight: 1.0,
            capacity_rows: Some(self.accumulator_rows()),
        }
    }

    /// Spatial fanout of one weight preload: the PE array feeds `dim`
    /// compute rows per preloaded tile (FactorFlow's fanout level).
    pub fn pe_fanout(&self) -> usize {
        self.dim
    }

    /// Peak throughput in GOP/s (2 ops per MAC).
    pub fn peak_gops(&self) -> f64 {
        (2 * self.peak_macs_per_cycle()) as f64 * self.clock_mhz * 1e6 / 1e9
    }

    /// Stable 64-bit fingerprint over every parameter that can influence
    /// simulated timing. The schedule-tuning cache
    /// ([`crate::scheduler::TuningCache`]) keys entries by this value, so
    /// changing *any* field — array size, memory geometry, clock, DDR
    /// bandwidth, feature toggles — invalidates cached tunings for the old
    /// configuration without touching entries of other configurations.
    /// FNV-1a over a fixed field encoding (not `DefaultHasher`, whose seed
    /// is randomized per process and would break cross-run persistence).
    /// [`super::sim::TIMING_MODEL_VERSION`] is mixed in too, so cached
    /// cycles are also invalidated when the simulator or search space
    /// changes, not just the configuration.
    pub fn fingerprint(&self) -> u64 {
        fn mix(mut h: u64, v: u64) -> u64 {
            for b in v.to_le_bytes() {
                h = (h ^ b as u64).wrapping_mul(0x100_0000_01b3);
            }
            h
        }
        let mut h = 0xcbf2_9ce4_8422_2325u64;
        h = mix(h, super::sim::TIMING_MODEL_VERSION);
        h = mix(h, self.dim as u64);
        h = mix(
            h,
            match self.dataflow {
                Dataflow::WeightStationary => 0,
                Dataflow::OutputStationary => 1,
                Dataflow::Both => 2,
            },
        );
        h = mix(h, self.scratchpad_kib as u64);
        h = mix(h, self.accumulator_kib as u64);
        h = mix(h, self.scratchpad_ports as u64);
        h = mix(h, self.scratchpad_read_delay as u64);
        h = mix(h, self.spatial_output_bits as u64);
        h = mix(h, self.max_in_flight as u64);
        h = mix(h, self.input_bits as u64);
        h = mix(h, self.acc_bits as u64);
        h = mix(h, matches!(self.scale_dtype, ScaleDtype::F16) as u64);
        let flags = (self.has_normalization as u64)
            | (self.has_transposer as u64) << 1
            | (self.has_virtual_addr as u64) << 2
            | (self.has_dilation as u64) << 3
            | (self.dsp_packing as u64) << 4;
        h = mix(h, flags);
        h = mix(h, self.clock_mhz.to_bits());
        h = mix(h, self.ddr_gbs.to_bits());
        h = mix(h, self.dram_latency as u64);
        h
    }

    /// Validate internal consistency.
    pub fn validate(&self) -> Result<(), String> {
        if !self.dim.is_power_of_two() {
            return Err(format!("dim {} must be a power of two", self.dim));
        }
        if self.scratchpad_rows() < 8 * self.dim {
            return Err("scratchpad too small for double buffering".into());
        }
        if self.accumulator_rows() < self.dim {
            return Err("accumulator smaller than one tile".into());
        }
        if self.max_in_flight == 0 || self.scratchpad_ports == 0 {
            return Err("degenerate resource counts".into());
        }
        Ok(())
    }
}

impl Default for GemminiConfig {
    fn default() -> Self {
        Self::original_zcu102()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn table3_default_column() {
        let c = GemminiConfig::original_zcu102();
        assert_eq!(c.dim, 16);
        assert_eq!(c.dataflow, Dataflow::Both);
        assert_eq!(c.scratchpad_kib, 256);
        assert_eq!(c.accumulator_kib, 64);
        assert_eq!(c.scratchpad_ports, 1);
        assert_eq!(c.scratchpad_read_delay, 4);
        assert_eq!(c.spatial_output_bits, 20);
        assert_eq!(c.max_in_flight, 16);
        assert!(c.validate().is_ok());
    }

    #[test]
    fn table3_ours_column() {
        let c = GemminiConfig::ours_zcu102();
        assert_eq!(c.dim, 32);
        assert_eq!(c.dataflow, Dataflow::WeightStationary);
        assert_eq!(c.scratchpad_kib, 512);
        assert_eq!(c.accumulator_kib, 128);
        assert_eq!(c.scratchpad_ports, 2);
        assert_eq!(c.scratchpad_read_delay, 8);
        assert_eq!(c.spatial_output_bits, 18);
        assert_eq!(c.max_in_flight, 32);
        assert!(c.dsp_packing);
        assert!(c.validate().is_ok());
    }

    #[test]
    fn ours_has_4x_pes_of_original() {
        let orig = GemminiConfig::original_zcu102();
        let ours = GemminiConfig::ours_zcu102();
        assert_eq!(ours.peak_macs_per_cycle(), 4 * orig.peak_macs_per_cycle());
    }

    #[test]
    fn scratchpad_geometry() {
        let c = GemminiConfig::original_zcu102();
        // 256 KiB / 16 B per row = 16384 rows.
        assert_eq!(c.scratchpad_rows(), 16384);
        // 64 KiB / 64 B per acc row = 1024 rows.
        assert_eq!(c.accumulator_rows(), 1024);
    }

    #[test]
    fn peak_gops_scales_with_clock_and_dim() {
        let orig = GemminiConfig::original_zcu102();
        let ours = GemminiConfig::ours_zcu102();
        // 4× PEs × 1.5× clock = 6× peak.
        let ratio = ours.peak_gops() / orig.peak_gops();
        assert!((ratio - 6.0).abs() < 1e-9, "ratio {ratio}");
    }

    #[test]
    fn fingerprint_distinguishes_configs_and_is_stable() {
        let a = GemminiConfig::ours_zcu102();
        let b = GemminiConfig::original_zcu102();
        assert_ne!(a.fingerprint(), b.fingerprint());
        // Same parameters → same fingerprint (pure function of fields).
        assert_eq!(a.fingerprint(), GemminiConfig::ours_zcu102().fingerprint());
        // Any single timing-relevant field flips it.
        let clocked = GemminiConfig { clock_mhz: 151.0, ..a.clone() };
        assert_ne!(a.fingerprint(), clocked.fingerprint());
        let ported = GemminiConfig { scratchpad_ports: 1, ..a.clone() };
        assert_ne!(a.fingerprint(), ported.fingerprint());
    }

    #[test]
    fn memory_levels_derive_from_config() {
        let c = GemminiConfig::original_zcu102();
        let dram = c.dram_level();
        assert_eq!(dram.bytes_per_cycle, c.bus_bytes_per_cycle() as f64);
        assert_eq!(dram.access_latency, c.dram_latency as f64);
        assert_eq!(dram.in_flight, c.max_in_flight as f64);
        assert!(dram.capacity_rows.is_none());
        let sp = c.scratchpad_level();
        // 1 port × 16 int8 elements per row.
        assert_eq!(sp.bytes_per_cycle, 16.0);
        assert_eq!(sp.capacity_rows, Some(c.scratchpad_rows()));
        let acc = c.accumulator_level();
        // 16 int32 elements per row.
        assert_eq!(acc.bytes_per_cycle, 64.0);
        assert_eq!(acc.capacity_rows, Some(c.accumulator_rows()));
        assert_eq!(c.pe_fanout(), c.dim);
        // The wider config widens every level.
        let ours = GemminiConfig::ours_zcu102();
        assert!(ours.scratchpad_level().bytes_per_cycle > sp.bytes_per_cycle);
        assert!(ours.accumulator_level().bytes_per_cycle > acc.bytes_per_cycle);
    }

    #[test]
    fn invalid_configs_rejected() {
        let mut c = GemminiConfig::original_zcu102();
        c.dim = 17;
        assert!(c.validate().is_err());
        let mut c = GemminiConfig::original_zcu102();
        c.scratchpad_kib = 1;
        assert!(c.validate().is_err());
    }
}
