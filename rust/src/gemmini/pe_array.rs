//! The weight-stationary systolic PE array.
//!
//! Functional model: a `dim × dim` tile of int8 weights is preloaded; int8
//! activation rows stream through, producing int32 partial sums per row.
//! Timing model: preload costs `dim` cycles (the weight column shift-in);
//! a compute of `r` rows costs `r` issue cycles plus a pipeline drain of
//! `dim + scratchpad_read_delay` cycles (amortized away when computes are
//! back-to-back — the simulator accounts drain only at dependency
//! boundaries).

use super::config::GemminiConfig;

/// Systolic array state: the currently-loaded weight tile.
#[derive(Debug, Clone)]
pub struct PeArray {
    pub dim: usize,
    /// Weight tile, row-major `dim × dim`. B[k][n].
    weights: Vec<i8>,
    /// Saturation bound from `spatial_output_bits` (Table III: the paper
    /// narrows the spatial-array output from 20 to 18 bits; partial sums
    /// wider than that clip).
    out_max: i32,
    out_min: i32,
}

impl PeArray {
    pub fn new(cfg: &GemminiConfig) -> Self {
        let bits = cfg.spatial_output_bits.min(31);
        let out_max = (1i64 << (bits - 1)) as i32 - 1;
        Self { dim: cfg.dim, weights: vec![0; cfg.dim * cfg.dim], out_max, out_min: -out_max - 1 }
    }

    /// Preload a weight tile (rows = K direction, cols = N direction).
    pub fn preload(&mut self, tile: &[i8]) {
        assert_eq!(tile.len(), self.dim * self.dim);
        self.weights.copy_from_slice(tile);
    }

    /// Stream one activation row (length `k_eff` ≤ dim) through the array:
    /// out[n] = Σ_k a[k] · B[k][n], saturated to the spatial output width.
    pub fn compute_row(&self, a: &[i8], k_eff: usize) -> Vec<i32> {
        let mut out = vec![0i32; self.dim];
        for k in 0..k_eff.min(self.dim).min(a.len()) {
            let av = a[k] as i32;
            if av == 0 {
                continue;
            }
            let wrow = &self.weights[k * self.dim..(k + 1) * self.dim];
            for (n, &w) in wrow.iter().enumerate() {
                out[n] = out[n].saturating_add(av * w as i32);
            }
        }
        for v in out.iter_mut() {
            *v = (*v).clamp(self.out_min, self.out_max);
        }
        out
    }

    /// Cycles for a preload.
    pub fn preload_cycles(&self) -> usize {
        self.dim
    }

    /// Issue cycles for an `r`-row compute (drain handled by the simulator).
    pub fn compute_issue_cycles(&self, rows: usize) -> usize {
        rows.max(1)
    }

    /// Pipeline depth (drain cost at dependency boundaries).
    pub fn drain_cycles(&self, cfg: &GemminiConfig) -> usize {
        self.dim + cfg.scratchpad_read_delay
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn cfg() -> GemminiConfig {
        GemminiConfig::original_zcu102()
    }

    #[test]
    fn identity_weight_passthrough() {
        let c = cfg();
        let mut pe = PeArray::new(&c);
        let mut id = vec![0i8; c.dim * c.dim];
        for i in 0..c.dim {
            id[i * c.dim + i] = 1;
        }
        pe.preload(&id);
        let a: Vec<i8> = (0..c.dim as i8).collect();
        let out = pe.compute_row(&a, c.dim);
        for i in 0..c.dim {
            assert_eq!(out[i], i as i32);
        }
    }

    #[test]
    fn matmul_row_matches_reference() {
        let c = cfg();
        let mut pe = PeArray::new(&c);
        let dim = c.dim;
        let tile: Vec<i8> = (0..dim * dim).map(|i| ((i * 7 + 3) % 17) as i8 - 8).collect();
        pe.preload(&tile);
        let a: Vec<i8> = (0..dim).map(|i| ((i * 5) % 11) as i8 - 5).collect();
        let out = pe.compute_row(&a, dim);
        for n in 0..dim {
            let expect: i32 =
                (0..dim).map(|k| a[k] as i32 * tile[k * dim + n] as i32).sum();
            assert_eq!(out[n], expect);
        }
    }

    #[test]
    fn partial_k_ignores_tail() {
        let c = cfg();
        let mut pe = PeArray::new(&c);
        pe.preload(&vec![1i8; c.dim * c.dim]);
        let a = vec![1i8; c.dim];
        let out = pe.compute_row(&a, 4); // only first 4 of K
        assert!(out.iter().all(|&v| v == 4));
    }

    #[test]
    fn output_saturates_at_spatial_bits() {
        let mut c = cfg();
        c.spatial_output_bits = 10; // tiny range: ±511
        let mut pe = PeArray::new(&c);
        pe.preload(&vec![127i8; c.dim * c.dim]);
        let a = vec![127i8; c.dim];
        let out = pe.compute_row(&a, c.dim);
        assert!(out.iter().all(|&v| v == 511), "{:?}", &out[..4]);
    }

    #[test]
    fn timing_model_shape() {
        let c = cfg();
        let pe = PeArray::new(&c);
        assert_eq!(pe.preload_cycles(), 16);
        assert_eq!(pe.compute_issue_cycles(16), 16);
        assert_eq!(pe.drain_cycles(&c), 16 + 4);
    }
}
