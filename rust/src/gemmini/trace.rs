//! Instruction-stream statistics for reports and debugging.

use std::collections::BTreeMap;

use super::isa::Instr;

/// Histogram of mnemonics plus aggregate byte counts for a stream.
#[derive(Debug, Clone, Default)]
pub struct StreamStats {
    pub counts: BTreeMap<&'static str, usize>,
    pub mvin_bytes: usize,
    pub mvout_bytes: usize,
    pub compute_rows: usize,
}

impl StreamStats {
    pub fn of(stream: &[Instr]) -> Self {
        let mut s = Self::default();
        for ins in stream {
            *s.counts.entry(ins.mnemonic()).or_insert(0) += 1;
            match ins {
                Instr::Mvin { rows, cols, dst, .. } => {
                    let elem = match dst {
                        super::isa::MvinDst::Scratchpad { .. } => 1,
                        super::isa::MvinDst::Accumulator { .. } => 4,
                    };
                    s.mvin_bytes += rows * cols * elem;
                }
                Instr::Mvout { rows, cols, .. } => s.mvout_bytes += rows * cols,
                Instr::Compute { rows, .. } => s.compute_rows += rows,
                _ => {}
            }
        }
        s
    }

    pub fn total(&self) -> usize {
        self.counts.values().sum()
    }

    /// Arithmetic intensity proxy: compute rows per mvin byte.
    pub fn reuse(&self) -> f64 {
        if self.mvin_bytes == 0 {
            return 0.0;
        }
        self.compute_rows as f64 / self.mvin_bytes as f64
    }
}

impl std::fmt::Display for StreamStats {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(f, "{} instrs [", self.total())?;
        for (i, (k, v)) in self.counts.iter().enumerate() {
            if i > 0 {
                write!(f, " ")?;
            }
            write!(f, "{k}:{v}")?;
        }
        write!(f, "] in={}B out={}B", self.mvin_bytes, self.mvout_bytes)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::gemmini::isa::{Activation, MvinDst};

    #[test]
    fn stats_count_stream() {
        let stream = vec![
            Instr::ConfigSt { scale: 1.0, activation: Activation::None },
            Instr::Mvin { dram_addr: 0, dst: MvinDst::Scratchpad { row: 0 }, rows: 4, cols: 4, stride_bytes: 4 },
            Instr::Compute { a_row: 0, rows: 4, cols: 4 },
            Instr::Mvout { acc_row: 0, dram_addr: 0, rows: 4, cols: 4, stride_bytes: 4 },
        ];
        let s = StreamStats::of(&stream);
        assert_eq!(s.total(), 4);
        assert_eq!(s.mvin_bytes, 16);
        assert_eq!(s.mvout_bytes, 16);
        assert_eq!(s.compute_rows, 4);
        assert!(s.reuse() > 0.0);
        let disp = s.to_string();
        assert!(disp.contains("mvin:1"));
    }
}
