//! Simulated external memory (the Zynq PS DDR as seen by the accelerator).

/// Byte-addressable DRAM with separate typed views for int8 tensors and
/// int32 accumulator/bias data. A real Gemmini sees one address space; we
/// keep one byte array and read/write typed values little-endian.
#[derive(Debug, Clone)]
pub struct Dram {
    bytes: Vec<u8>,
}

impl Dram {
    pub fn new(size: usize) -> Self {
        Self { bytes: vec![0; size] }
    }

    pub fn size(&self) -> usize {
        self.bytes.len()
    }

    pub fn read_i8(&self, addr: usize) -> i8 {
        self.bytes[addr] as i8
    }

    pub fn write_i8(&mut self, addr: usize, v: i8) {
        self.bytes[addr] = v as u8;
    }

    pub fn read_i32(&self, addr: usize) -> i32 {
        i32::from_le_bytes(self.bytes[addr..addr + 4].try_into().unwrap())
    }

    pub fn write_i32(&mut self, addr: usize, v: i32) {
        self.bytes[addr..addr + 4].copy_from_slice(&v.to_le_bytes());
    }

    /// Bulk-write an int8 matrix row-major with a row stride in bytes.
    pub fn write_i8_matrix(&mut self, addr: usize, data: &[i8], rows: usize, cols: usize, stride: usize) {
        assert_eq!(data.len(), rows * cols);
        for r in 0..rows {
            for c in 0..cols {
                self.write_i8(addr + r * stride + c, data[r * cols + c]);
            }
        }
    }

    /// Bulk-read an int8 matrix.
    pub fn read_i8_matrix(&self, addr: usize, rows: usize, cols: usize, stride: usize) -> Vec<i8> {
        let mut out = Vec::with_capacity(rows * cols);
        for r in 0..rows {
            for c in 0..cols {
                out.push(self.read_i8(addr + r * stride + c));
            }
        }
        out
    }

    /// Bulk-write an int32 matrix (bias / accumulator data).
    pub fn write_i32_matrix(&mut self, addr: usize, data: &[i32], rows: usize, cols: usize, stride: usize) {
        assert_eq!(data.len(), rows * cols);
        for r in 0..rows {
            for c in 0..cols {
                self.write_i32(addr + r * stride + c * 4, data[r * cols + c]);
            }
        }
    }
}

/// Bump allocator over a [`Dram`] — the coordinator uses it to lay out
/// tensors before generating instruction streams.
#[derive(Debug, Clone)]
pub struct DramAllocator {
    next: usize,
    size: usize,
}

impl DramAllocator {
    pub fn new(size: usize) -> Self {
        Self { next: 64, size } // keep address 0 unused
    }

    /// Allocate `bytes`, 64-byte aligned. Panics on exhaustion (simulation
    /// configuration error, not a runtime condition).
    pub fn alloc(&mut self, bytes: usize) -> usize {
        let addr = (self.next + 63) & !63;
        assert!(addr + bytes <= self.size, "simulated DRAM exhausted");
        self.next = addr + bytes;
        addr
    }

    pub fn used(&self) -> usize {
        self.next
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn i8_roundtrip() {
        let mut d = Dram::new(1024);
        d.write_i8(10, -5);
        assert_eq!(d.read_i8(10), -5);
    }

    #[test]
    fn i32_roundtrip() {
        let mut d = Dram::new(1024);
        d.write_i32(100, -123456);
        assert_eq!(d.read_i32(100), -123456);
    }

    #[test]
    fn matrix_stride_respected() {
        let mut d = Dram::new(1024);
        let m = vec![1i8, 2, 3, 4, 5, 6];
        d.write_i8_matrix(0, &m, 2, 3, 10);
        assert_eq!(d.read_i8(0), 1);
        assert_eq!(d.read_i8(2), 3);
        assert_eq!(d.read_i8(10), 4);
        assert_eq!(d.read_i8_matrix(0, 2, 3, 10), m);
    }

    #[test]
    fn allocator_aligns() {
        let mut a = DramAllocator::new(4096);
        let x = a.alloc(10);
        let y = a.alloc(10);
        assert_eq!(x % 64, 0);
        assert_eq!(y % 64, 0);
        assert!(y >= x + 10);
    }

    #[test]
    #[should_panic(expected = "exhausted")]
    fn allocator_exhaustion_panics() {
        let mut a = DramAllocator::new(128);
        a.alloc(200);
    }
}
