//! Cycle-approximate, functionally-correct simulator of the Gemmini
//! accelerator (Genc et al., DAC 2021) — the substrate the paper deploys on
//! FPGA and that we cannot synthesize here (DESIGN.md §2).
//!
//! Modelled structure (Section III of the paper):
//!
//! - three **decoupled controllers** — *Load* (DRAM→scratchpad mvin),
//!   *Execute* (scratchpad→systolic array→accumulator) and *Store*
//!   (accumulator→DRAM mvout with output scaling) — each with its own
//!   in-order queue, overlapping through ROB-style dependency tracking on
//!   scratchpad/accumulator/DRAM regions;
//! - a banked **scratchpad** and a separate **accumulator** memory;
//! - a **weight-stationary** `dim × dim` PE array (Table III: the paper
//!   fixes WS dataflow);
//! - a DMA engine with a bounded number of in-flight requests;
//! - **CISC-type instructions** (hardcoded tiled-matmul/conv state machines
//!   with a fixed, conservative schedule) and **RISC-type instructions**
//!   (mvin/preload/compute/mvout) that the schedule tuner re-orders
//!   (Sections II, IV-C).
//!
//! The simulator is *functional* as well as timed: RISC programs actually
//! move bytes and multiply int8 matrices, so the codegen in
//! [`crate::scheduler::codegen`] is property-tested against a pure software
//! reference.

pub mod cisc;
pub mod config;
pub mod isa;
pub mod memory;
pub mod pe_array;
pub mod scratchpad;
pub mod sim;
pub mod trace;

pub use config::{Dataflow, GemminiConfig};
pub use isa::{Activation, Instr, MvinDst};
pub use memory::Dram;
pub use sim::{SimResult, Simulator};
