//! The Gemmini instruction set as modelled by the simulator.
//!
//! Two instruction families (Section III of the paper):
//!
//! - **RISC-type**: fine-grained `mvin` / `preload` / `compute` / `mvout`
//!   intrinsics giving full control over data movement and the systolic
//!   array — the instructions the schedule tuner re-orders;
//! - **CISC-type**: `LOOP_WS` (tiled matmul) and `LOOP_CONV` state machines
//!   that expand to a fixed internal schedule (see [`super::cisc`]).
//!
//! Addresses: DRAM addresses are plain byte addresses into the simulated
//! [`super::memory::Dram`]. Scratchpad/accumulator addresses are *row*
//! indices (a row holds `dim` elements), mirroring Gemmini's local address
//! space where the accumulator is distinguished by a high bit — here by
//! [`MvinDst`] / explicit fields instead.


/// Sentinel `b_row` for [`Instr::Preload`]: keep the currently-loaded
/// weight tile (no systolic refill).
pub const REUSE_WEIGHTS: usize = usize::MAX;

/// Activation applied on accumulator read-out (mvout path). Gemmini
/// supports only ReLU-family activations here (Section IV-B2).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Activation {
    None,
    Relu,
    /// Clamped ReLU with a quantized upper bound: after the output scale is
    /// applied, values clamp to `[0, qmax]` where `qmax = round(6.0 /
    /// output_scale)` (ReLU6 in the quantized domain).
    Relu6 { qmax: i8 },
}

/// Destination memory of an `mvin`.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum MvinDst {
    /// Scratchpad row address (int8 rows).
    Scratchpad { row: usize },
    /// Accumulator row address (int32 rows) — used to preload bias.
    Accumulator { row: usize },
}

/// One Gemmini instruction.
#[derive(Debug, Clone, PartialEq)]
pub enum Instr {
    /// Configure the Execute pipeline: systolic-array mode + output shift.
    ConfigEx {
        /// Right-shift applied inside the PE chain (we fold into scale).
        acc_shift: u32,
    },
    /// Configure the Store pipeline: output scale factor + activation.
    ConfigSt { scale: f32, activation: Activation },
    /// Load `rows × cols` int8 elements from DRAM into scratchpad or
    /// int32 elements into the accumulator (Load controller).
    Mvin { dram_addr: usize, dst: MvinDst, rows: usize, cols: usize, stride_bytes: usize },
    /// Preload a `dim × dim` weight tile from scratchpad into the PE array
    /// (Execute controller; WS dataflow). `acc_row` selects the output
    /// accumulator tile of subsequent `Compute`s; `accumulate` keeps the
    /// existing partial sums. `b_row == REUSE_WEIGHTS` re-targets the
    /// accumulator without refilling the array (Gemmini's
    /// `compute.accumulated` path — weights stay resident).
    Preload { b_row: usize, acc_row: usize, accumulate: bool },
    /// Stream `rows` scratchpad rows (the A operand) through the loaded
    /// weight tile, adding into the preloaded accumulator tile
    /// (Execute controller). `cols` ≤ dim is the effective K width.
    Compute { a_row: usize, rows: usize, cols: usize },
    /// Store `rows × cols` elements from accumulator to DRAM, applying the
    /// configured scale + activation and narrowing to int8
    /// (Store controller).
    Mvout { acc_row: usize, dram_addr: usize, rows: usize, cols: usize, stride_bytes: usize },
    /// Drain all pipelines (fence).
    Flush,
    /// CISC: hardware tiled-matmul FSM over DRAM operands
    /// (`C[m×n] = A[m×k] · B[k×n] + bias`), fixed internal schedule.
    LoopWs {
        m: usize,
        n: usize,
        k: usize,
        a_addr: usize,
        b_addr: usize,
        bias_addr: Option<usize>,
        c_addr: usize,
        scale: f32,
        activation: Activation,
    },
    /// CISC: hardware conv FSM. The real FSM gathers im2col patches from
    /// the feature map on the fly; the simulator stages the im2col matrix
    /// at `im2col_addr` (functional mode) and charges the gather cost as
    /// fragmented DMA requests (one per kernel row per tile).
    LoopConv {
        batch: usize,
        in_h: usize,
        in_w: usize,
        in_c: usize,
        out_c: usize,
        kernel: usize,
        stride: usize,
        padding: usize,
        in_addr: usize,
        w_addr: usize,
        bias_addr: Option<usize>,
        out_addr: usize,
        im2col_addr: usize,
        scale: f32,
        activation: Activation,
    },
}

impl Instr {
    /// Which controller queue the instruction is dispatched to.
    pub fn controller(&self) -> Controller {
        match self {
            Instr::Mvin { .. } => Controller::Load,
            Instr::Preload { .. } | Instr::Compute { .. } | Instr::ConfigEx { .. } => {
                Controller::Execute
            }
            Instr::Mvout { .. } | Instr::ConfigSt { .. } => Controller::Store,
            Instr::Flush | Instr::LoopWs { .. } | Instr::LoopConv { .. } => Controller::Front,
        }
    }

    /// Short mnemonic for traces.
    pub fn mnemonic(&self) -> &'static str {
        match self {
            Instr::ConfigEx { .. } => "config_ex",
            Instr::ConfigSt { .. } => "config_st",
            Instr::Mvin { .. } => "mvin",
            Instr::Preload { .. } => "preload",
            Instr::Compute { .. } => "compute",
            Instr::Mvout { .. } => "mvout",
            Instr::Flush => "flush",
            Instr::LoopWs { .. } => "loop_ws",
            Instr::LoopConv { .. } => "loop_conv",
        }
    }

    /// True for CISC-type instructions (Section III: hardcoded FSMs).
    pub fn is_cisc(&self) -> bool {
        matches!(self, Instr::LoopWs { .. } | Instr::LoopConv { .. })
    }
}

/// The three decoupled controllers plus the front-end (CISC FSMs expand at
/// the front-end before dispatch).
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum Controller {
    Load,
    Execute,
    Store,
    Front,
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn controller_dispatch() {
        let mvin = Instr::Mvin {
            dram_addr: 0,
            dst: MvinDst::Scratchpad { row: 0 },
            rows: 16,
            cols: 16,
            stride_bytes: 16,
        };
        assert_eq!(mvin.controller(), Controller::Load);
        assert_eq!(Instr::Preload { b_row: 0, acc_row: 0, accumulate: false }.controller(), Controller::Execute);
        assert_eq!(
            Instr::Mvout { acc_row: 0, dram_addr: 0, rows: 16, cols: 16, stride_bytes: 16 }
                .controller(),
            Controller::Store
        );
        assert_eq!(Instr::Flush.controller(), Controller::Front);
    }

    #[test]
    fn cisc_detection() {
        assert!(Instr::LoopWs {
            m: 1,
            n: 1,
            k: 1,
            a_addr: 0,
            b_addr: 0,
            bias_addr: None,
            c_addr: 0,
            scale: 1.0,
            activation: Activation::None
        }
        .is_cisc());
        assert!(!Instr::Flush.is_cisc());
    }
}
