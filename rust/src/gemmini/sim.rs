//! The decoupled-access-execute timing + functional simulator.
//!
//! Timing model: each of the three controllers (Load / Execute / Store)
//! executes its queue in order; instructions from different controllers
//! overlap freely unless they conflict on a resource. Conflicts are tracked
//! at scratchpad-row / accumulator-row / DRAM-block granularity with
//! last-writer and last-reader completion times — exactly the hazard
//! information Gemmini's ROB tracks between its queues. All hazard tables
//! are dense `Vec`s indexed directly by row / block (the scratchpad and
//! accumulator tables are sized from the config, the DRAM-block table from
//! the simulated DRAM size and grown on demand): the per-instruction
//! lookups sit on the tuner's hottest path, where hashing a `HashMap` key
//! per touched block dominated the old profile.
//!
//! A `Simulator` is reusable across streams: `run` measures cycles relative
//! to the stream's own start, and because every recorded hazard time is
//! bounded by the previous stream's horizon, a reused simulator is
//! cycle-identical to a fresh one (what lets the tuner keep one simulator
//! per worker instead of reallocating DRAM per candidate).
//!
//! Shared resources beyond memory rows:
//! - the **DMA engine** (one AXI port to PS DDR) serializes mvin/mvout
//!   transfers; `max_in_flight` bounds how much DRAM latency pipelines;
//! - with a single **scratchpad port** (Table III default), Load writes and
//!   Execute reads contend; the paper's 2-port configuration removes this.
//!
//! Functional model (enabled with [`Simulator::new_functional`]): bytes
//! actually move and the PE array actually multiplies, so instruction
//! streams can be verified against a software reference.

use super::config::GemminiConfig;
use super::isa::{Activation, Instr, MvinDst};
use super::memory::Dram;
use super::pe_array::PeArray;
use super::scratchpad::{Accumulator, Scratchpad};
use crate::ir::tensor::f16_round;

/// Version of the cycle/timing model (and, by contract, the schedule
/// search space that measures against it). Mixed into
/// [`GemminiConfig::fingerprint`], so bumping it invalidates every
/// persistent tuning-cache entry measured under the old model — cached
/// cycles must never outlive the simulator that produced them. Bump on
/// any change to this file's timing semantics, `pe_array` cycle
/// formulas, CISC expansion, or `scheduler::space::enumerate`.
///
/// v2: `scheduler::space::enumerate` caps `mb` at the layer's m-tile
/// count (small-M layers gained previously-rejected schedules) and the
/// ranking stage moved to the hierarchical `scheduler::prefilter` model
/// with the corrected A-request batching term — measured candidate sets
/// changed, so v1 cached cycles must not be reused.
pub const TIMING_MODEL_VERSION: u64 = 2;

const DRAM_BLOCK: usize = 4096;
const IDX_LOAD: usize = 0;
const IDX_EXEC: usize = 1;
const IDX_STORE: usize = 2;

/// Aggregate result of simulating one instruction stream.
#[derive(Debug, Clone, Default, PartialEq)]
pub struct SimResult {
    /// Total cycles from first issue to last completion (incl. drains).
    pub cycles: u64,
    /// Busy cycles per controller.
    pub load_busy: u64,
    pub execute_busy: u64,
    pub store_busy: u64,
    /// Bytes moved over the DMA engine.
    pub dma_bytes_in: u64,
    pub dma_bytes_out: u64,
    /// MACs issued to the PE array (`rows × dim × dim` per compute).
    pub macs: u64,
    /// Instructions simulated (after CISC expansion).
    pub instrs: u64,
}

impl SimResult {
    /// PE-array utilization in [0, 1].
    pub fn utilization(&self, cfg: &GemminiConfig) -> f64 {
        if self.cycles == 0 {
            return 0.0;
        }
        self.macs as f64 / (self.cycles as f64 * cfg.peak_macs_per_cycle() as f64)
    }

    /// Wall-clock seconds at the configuration's clock.
    pub fn seconds(&self, cfg: &GemminiConfig) -> f64 {
        self.cycles as f64 / (cfg.clock_mhz * 1e6)
    }

    /// Merge another result measured on the same timeline segment
    /// (sequential composition: cycles add).
    pub fn chain(&mut self, other: &SimResult) {
        self.cycles += other.cycles;
        self.load_busy += other.load_busy;
        self.execute_busy += other.execute_busy;
        self.store_busy += other.store_busy;
        self.dma_bytes_in += other.dma_bytes_in;
        self.dma_bytes_out += other.dma_bytes_out;
        self.macs += other.macs;
        self.instrs += other.instrs;
    }
}

/// The simulator. Create one per accelerator instance; `run` simulates an
/// instruction stream starting from the current state.
pub struct Simulator {
    pub cfg: GemminiConfig,
    pub dram: Dram,
    functional: bool,
    sp: Scratchpad,
    acc: Accumulator,
    pe: PeArray,
    // --- timing state ---
    /// Controller free-at times, indexed by [Load, Execute, Store]
    /// (array instead of a map — this is the simulator's hottest state).
    free: [u64; 3],
    dma_free: u64,
    /// Per-bank port timelines (single-ported scratchpad banks; the
    /// 2-port configuration removes the contention entirely).
    sp_port_free: [u64; 4],
    sp_write: Vec<u64>,
    sp_read: Vec<u64>,
    acc_write: Vec<u64>,
    acc_read: Vec<u64>,
    /// Dense per-DRAM-block last-write / last-read completion times,
    /// indexed by `addr / DRAM_BLOCK` (grown on demand past the initial
    /// DRAM size; an untouched block reads as 0, like a map miss did).
    dram_write: Vec<u64>,
    dram_read: Vec<u64>,
    horizon: u64,
    t0: u64,
    // --- execute-pipeline architectural state ---
    cur_acc_row: usize,
    cur_accumulate: bool,
    st_scale: f32,
    st_act: Activation,
    // --- stats ---
    stats: SimResult,
}

impl Simulator {
    /// Timing-only simulator (fast; used by the tuner and benches).
    pub fn new(cfg: GemminiConfig, dram_size: usize) -> Self {
        Self::build(cfg, dram_size, false)
    }

    /// Timing + functional simulator (used by correctness tests).
    pub fn new_functional(cfg: GemminiConfig, dram_size: usize) -> Self {
        Self::build(cfg, dram_size, true)
    }

    fn build(cfg: GemminiConfig, dram_size: usize, functional: bool) -> Self {
        cfg.validate().expect("invalid Gemmini config");
        let sp = Scratchpad::new(&cfg);
        let acc = Accumulator::new(&cfg);
        let pe = PeArray::new(&cfg);
        let sp_rows = sp.num_rows();
        let acc_rows = acc.num_rows();
        let dram_blocks = dram_size.div_ceil(DRAM_BLOCK).max(1);
        Self {
            dram: Dram::new(dram_size),
            functional,
            sp,
            acc,
            pe,
            free: [0; 3],
            dma_free: 0,
            sp_port_free: [0; 4],
            sp_write: vec![0; sp_rows],
            sp_read: vec![0; sp_rows],
            acc_write: vec![0; acc_rows],
            acc_read: vec![0; acc_rows],
            dram_write: vec![0; dram_blocks],
            dram_read: vec![0; dram_blocks],
            horizon: 0,
            t0: 0,
            cur_acc_row: 0,
            cur_accumulate: false,
            st_scale: 1.0,
            st_act: Activation::None,
            stats: SimResult::default(),
            cfg,
        }
    }

    pub fn is_functional(&self) -> bool {
        self.functional
    }

    /// Simulate a stream; returns the result for *this stream only*
    /// (cycles measured from the stream's start).
    pub fn run(&mut self, stream: &[Instr]) -> SimResult {
        self.t0 = self.horizon;
        self.stats = SimResult::default();
        // Start all controllers no earlier than t0 (previous streams done).
        for v in self.free.iter_mut() {
            *v = (*v).max(self.t0);
        }
        self.dma_free = self.dma_free.max(self.t0);
        for b in self.sp_port_free.iter_mut() {
            *b = (*b).max(self.t0);
        }

        // Step instructions in place; CISC FSMs expand into a scratch
        // buffer (no per-instruction clone of the caller's stream — this
        // loop is the tuner's hot path, see EXPERIMENTS.md §Perf).
        let mut n_instrs = 0u64;
        let mut scratch: Vec<Instr> = Vec::new();
        for ins in stream {
            if ins.is_cisc() {
                // The conv FSM gathers im2col on the fly; functionally we
                // stage the gathered matrix before expansion (DESIGN.md §2).
                if self.functional && matches!(ins, Instr::LoopConv { .. }) {
                    super::cisc::stage_im2col(&mut self.dram, ins);
                }
                scratch.clear();
                super::cisc::expand(&self.cfg, ins, &mut scratch);
                for e in &scratch {
                    self.step(e);
                }
                n_instrs += scratch.len() as u64;
            } else {
                self.step(ins);
                n_instrs += 1;
            }
        }
        self.stats.instrs = n_instrs;
        self.stats.cycles = self.horizon - self.t0;
        self.stats.clone()
    }

    // ---- timing helpers ----

    fn dram_dep(&self, addr: usize, bytes: usize, is_write: bool) -> u64 {
        let mut t = 0;
        let b0 = addr / DRAM_BLOCK;
        let b1 = (addr + bytes.max(1) - 1) / DRAM_BLOCK;
        // Blocks past the table were never touched → contribute 0.
        let hi = b1.min(self.dram_write.len() - 1);
        for b in b0..=hi {
            t = t.max(self.dram_write[b]); // RAW / WAW
            if is_write {
                t = t.max(self.dram_read[b]); // WAR
            }
        }
        t
    }

    fn dram_touch(&mut self, addr: usize, bytes: usize, is_write: bool, fin: u64) {
        let b0 = addr / DRAM_BLOCK;
        let b1 = (addr + bytes.max(1) - 1) / DRAM_BLOCK;
        if b1 >= self.dram_write.len() {
            self.dram_write.resize(b1 + 1, 0);
            self.dram_read.resize(b1 + 1, 0);
        }
        let table = if is_write { &mut self.dram_write } else { &mut self.dram_read };
        for slot in &mut table[b0..=b1] {
            *slot = (*slot).max(fin);
        }
    }

    /// Bus occupancy of a DMA transfer (the serialized part): the latency
    /// component pipelines across outstanding requests (Gemmini's ROB
    /// keeps up to `max_in_flight` requests in flight), so it delays the
    /// *completion* of a transfer but does not hold the bus.
    fn dma_occupancy(&self, rows: usize, bytes: usize) -> u64 {
        let transfer = bytes.div_ceil(self.cfg.bus_bytes_per_cycle()) as u64;
        // Row-request issue cost (address generation, one beat per row).
        transfer + rows as u64
    }

    /// Completion latency beyond the bus occupancy.
    fn dma_latency(&self, rows: usize) -> u64 {
        // One DRAM round-trip, plus extra serialized round-trips when the
        // request count exceeds the in-flight window.
        let batches = rows.div_ceil(self.cfg.max_in_flight) as u64;
        batches * self.cfg.dram_latency as u64
    }

    fn bump(&mut self, fin: u64) {
        self.horizon = self.horizon.max(fin);
    }

    /// Scratchpad bank of a row (dim-row interleaving — buffers allocated
    /// on dim-row boundaries land in different banks).
    fn bank(&self, row: usize) -> usize {
        (row / self.cfg.dim) % 4
    }

    // ---- per-instruction semantics ----

    fn step(&mut self, ins: &Instr) {
        match *ins {
            Instr::ConfigEx { .. } => {
                let f = self.free[IDX_EXEC] + 1;
                self.free[IDX_EXEC] = f;
                self.bump(f);
            }
            Instr::ConfigSt { scale, activation } => {
                let f = self.free[IDX_STORE] + 1;
                self.free[IDX_STORE] = f;
                self.st_scale = scale;
                self.st_act = activation;
                self.bump(f);
            }
            Instr::Mvin { dram_addr, dst, rows, cols, stride_bytes } => {
                self.mvin(dram_addr, dst, rows, cols, stride_bytes)
            }
            Instr::Preload { b_row, acc_row, accumulate } => {
                self.preload(b_row, acc_row, accumulate)
            }
            Instr::Compute { a_row, rows, cols } => self.compute(a_row, rows, cols),
            Instr::Mvout { acc_row, dram_addr, rows, cols, stride_bytes } => {
                self.mvout(acc_row, dram_addr, rows, cols, stride_bytes)
            }
            Instr::Flush => {
                let t = self.free.iter().copied().max().unwrap();
                let t = t.max(self.dma_free).max(self.horizon);
                self.free = [t; 3];
                self.bump(t);
            }
            Instr::LoopWs { .. } | Instr::LoopConv { .. } => {
                unreachable!("CISC instructions expand before step()")
            }
        }
    }

    fn mvin(&mut self, dram_addr: usize, dst: MvinDst, rows: usize, cols: usize, stride: usize) {
        let elem = match dst {
            MvinDst::Scratchpad { .. } => 1,
            MvinDst::Accumulator { .. } => 4,
        };
        let bytes = rows * cols * elem;
        let occ = self.dma_occupancy(rows, bytes);
        let dur = occ + self.dma_latency(rows);

        // Dependencies: DRAM source written? destination rows still read?
        let mut ready = self.free[IDX_LOAD];
        ready = ready.max(self.dram_dep(dram_addr, rows * stride, false));
        match dst {
            MvinDst::Scratchpad { row } => {
                for r in row..row + rows {
                    ready = ready.max(self.sp_read[r]).max(self.sp_write[r]);
                }
            }
            MvinDst::Accumulator { row } => {
                for r in row..row + rows {
                    ready = ready.max(self.acc_read[r]).max(self.acc_write[r]);
                }
            }
        }
        let mut start = ready.max(self.dma_free);
        if self.cfg.scratchpad_ports == 1 {
            if let MvinDst::Scratchpad { row } = dst {
                start = start.max(self.sp_port_free[self.bank(row)]);
            }
        }
        let fin = start + dur;
        self.dma_free = start + occ; // latency pipelines across requests
        if self.cfg.scratchpad_ports == 1 {
            if let MvinDst::Scratchpad { row } = dst {
                // The bank port is held for the write burst only — DRAM
                // latency overlaps with other banks' traffic.
                let b = self.bank(row);
                self.sp_port_free[b] = start + occ;
            }
        }
        self.free[IDX_LOAD] = start + occ;
        match dst {
            MvinDst::Scratchpad { row } => {
                for r in row..row + rows {
                    self.sp_write[r] = fin;
                }
            }
            MvinDst::Accumulator { row } => {
                for r in row..row + rows {
                    self.acc_write[r] = fin;
                }
            }
        }
        self.dram_touch(dram_addr, rows * stride, false, fin);
        self.stats.load_busy += occ;
        self.stats.dma_bytes_in += bytes as u64;
        self.bump(fin);

        if self.functional {
            match dst {
                MvinDst::Scratchpad { row } => {
                    for r in 0..rows {
                        let data = self.dram.read_i8_matrix(dram_addr + r * stride, 1, cols, stride);
                        self.sp.write_row(row + r, &data);
                    }
                }
                MvinDst::Accumulator { row } => {
                    for r in 0..rows {
                        let mut vals = Vec::with_capacity(cols);
                        for c in 0..cols {
                            vals.push(self.dram.read_i32(dram_addr + r * stride + c * 4));
                        }
                        self.acc.set_row(row + r, &vals);
                    }
                }
            }
        }
    }

    fn preload(&mut self, b_row: usize, acc_row: usize, accumulate: bool) {
        let dim = self.cfg.dim;
        // Weight-reuse preload: 1-cycle accumulator retarget, no refill.
        if b_row == super::isa::REUSE_WEIGHTS {
            let f = self.free[IDX_EXEC] + 1;
            self.free[IDX_EXEC] = f;
            self.cur_acc_row = acc_row;
            self.cur_accumulate = accumulate;
            self.stats.execute_busy += 1;
            self.bump(f);
            return;
        }
        let mut ready = self.free[IDX_EXEC];
        for r in b_row..b_row + dim {
            ready = ready.max(self.sp_write[r]);
        }
        let mut start = ready;
        if self.cfg.scratchpad_ports == 1 {
            start = start.max(self.sp_port_free[self.bank(b_row)]);
        }
        let dur = self.pe.preload_cycles() as u64 + self.cfg.scratchpad_read_delay as u64;
        let fin = start + dur;
        if self.cfg.scratchpad_ports == 1 {
            let b = self.bank(b_row);
            self.sp_port_free[b] = fin;
        }
        self.free[IDX_EXEC] = fin;
        for r in b_row..b_row + dim {
            self.sp_read[r] = self.sp_read[r].max(fin);
        }
        self.cur_acc_row = acc_row;
        self.cur_accumulate = accumulate;
        self.stats.execute_busy += dur;
        self.bump(fin);

        if self.functional {
            let mut tile = Vec::with_capacity(dim * dim);
            for r in b_row..b_row + dim {
                tile.extend_from_slice(self.sp.read_row(r));
            }
            self.pe.preload(&tile);
        }
    }

    fn compute(&mut self, a_row: usize, rows: usize, cols: usize) {
        let dim = self.cfg.dim;
        let acc_row = self.cur_acc_row;
        let mut ready = self.free[IDX_EXEC];
        for r in a_row..a_row + rows {
            ready = ready.max(self.sp_write[r]);
        }
        // RAW on the accumulator tile if accumulating over prior results
        // that a store might still be reading (WAR).
        for r in acc_row..(acc_row + rows).min(self.acc_write.len()) {
            ready = ready.max(self.acc_read[r]);
            if !self.cur_accumulate {
                ready = ready.max(self.acc_write[r]);
            }
        }
        let mut start = ready;
        if self.cfg.scratchpad_ports == 1 {
            start = start.max(self.sp_port_free[self.bank(a_row)]);
        }
        let issue = self.pe.compute_issue_cycles(rows) as u64;
        let fin_issue = start + issue;
        // Results land after the pipeline drain; back-to-back computes keep
        // issuing (the queue frees at fin_issue), only consumers wait.
        let fin_results = fin_issue + self.pe.drain_cycles(&self.cfg) as u64;
        if self.cfg.scratchpad_ports == 1 {
            let b = self.bank(a_row);
            self.sp_port_free[b] = fin_issue;
        }
        self.free[IDX_EXEC] = fin_issue;
        for r in a_row..a_row + rows {
            self.sp_read[r] = self.sp_read[r].max(fin_issue);
        }
        for r in acc_row..(acc_row + rows).min(self.acc_write.len()) {
            self.acc_write[r] = self.acc_write[r].max(fin_results);
        }
        self.stats.execute_busy += issue;
        self.stats.macs += (rows * dim * dim) as u64;
        self.bump(fin_results);

        if self.functional {
            for r in 0..rows {
                let a = self.sp.read_row(a_row + r).to_vec();
                let out = self.pe.compute_row(&a, cols);
                if self.cur_accumulate {
                    self.acc.add_row(acc_row + r, &out);
                } else {
                    self.acc.set_row(acc_row + r, &out);
                }
            }
            // After the first compute of a tile, subsequent computes to the
            // same tile accumulate (Gemmini semantics: preload arms the
            // overwrite once).
            self.cur_accumulate = true;
        } else {
            self.cur_accumulate = true;
        }
    }

    fn mvout(&mut self, acc_row: usize, dram_addr: usize, rows: usize, cols: usize, stride: usize) {
        let bytes = rows * cols; // int8 out
        let occ = self.dma_occupancy(rows, bytes);
        let dur = occ + self.dma_latency(rows);
        let mut ready = self.free[IDX_STORE];
        for r in acc_row..acc_row + rows {
            ready = ready.max(self.acc_write[r]);
        }
        ready = ready.max(self.dram_dep(dram_addr, rows * stride, true));
        let start = ready.max(self.dma_free);
        let fin = start + dur;
        self.dma_free = start + occ;
        self.free[IDX_STORE] = start + occ;
        for r in acc_row..acc_row + rows {
            self.acc_read[r] = self.acc_read[r].max(fin);
        }
        self.dram_touch(dram_addr, rows * stride, true, fin);
        self.stats.store_busy += occ;
        self.stats.dma_bytes_out += bytes as u64;
        self.bump(fin);

        if self.functional {
            let scale = match self.cfg.scale_dtype {
                super::config::ScaleDtype::F32 => self.st_scale,
                super::config::ScaleDtype::F16 => f16_round(self.st_scale),
            };
            for r in 0..rows {
                let row = self.acc.read_row(acc_row + r).to_vec();
                for (c, &v) in row.iter().take(cols).enumerate() {
                    let scaled = (v as f32 * scale).round() as i32;
                    let q = match self.st_act {
                        Activation::None => scaled.clamp(-128, 127),
                        Activation::Relu => scaled.max(0).clamp(0, 127),
                        Activation::Relu6 { qmax } => scaled.clamp(0, qmax as i32),
                    };
                    self.dram.write_i8(dram_addr + r * stride + c, q as i8);
                }
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn small_cfg() -> GemminiConfig {
        GemminiConfig { dim: 4, scratchpad_kib: 8, accumulator_kib: 4, ..GemminiConfig::original_zcu102() }
    }

    /// Hand-written RISC stream computing a 4×4 · 4×4 int8 matmul.
    fn matmul_stream(a_addr: usize, b_addr: usize, c_addr: usize) -> Vec<Instr> {
        vec![
            Instr::ConfigEx { acc_shift: 0 },
            Instr::ConfigSt { scale: 1.0, activation: Activation::None },
            Instr::Mvin {
                dram_addr: a_addr,
                dst: MvinDst::Scratchpad { row: 0 },
                rows: 4,
                cols: 4,
                stride_bytes: 4,
            },
            Instr::Mvin {
                dram_addr: b_addr,
                dst: MvinDst::Scratchpad { row: 4 },
                rows: 4,
                cols: 4,
                stride_bytes: 4,
            },
            Instr::Preload { b_row: 4, acc_row: 0, accumulate: false },
            Instr::Compute { a_row: 0, rows: 4, cols: 4 },
            Instr::Mvout { acc_row: 0, dram_addr: c_addr, rows: 4, cols: 4, stride_bytes: 4 },
            Instr::Flush,
        ]
    }

    #[test]
    fn functional_matmul_matches_reference() {
        let cfg = small_cfg();
        let mut sim = Simulator::new_functional(cfg, 1 << 16);
        let a: Vec<i8> = vec![1, 2, 3, 4, 5, 6, 7, 8, 9, 10, 11, 12, 13, 14, 15, 16];
        let b: Vec<i8> = vec![1, 0, 0, 0, 0, 1, 0, 0, 0, 0, 1, 0, 0, 0, 0, 1];
        sim.dram.write_i8_matrix(0, &a, 4, 4, 4);
        sim.dram.write_i8_matrix(64, &b, 4, 4, 4);
        let res = sim.run(&matmul_stream(0, 64, 128));
        assert!(res.cycles > 0);
        // Identity B: C == A.
        let c = sim.dram.read_i8_matrix(128, 4, 4, 4);
        assert_eq!(c, a);
    }

    #[test]
    fn functional_matmul_nontrivial_b() {
        let cfg = small_cfg();
        let mut sim = Simulator::new_functional(cfg, 1 << 16);
        let a: Vec<i8> = (0..16).map(|i| (i % 5) as i8 - 2).collect();
        let b: Vec<i8> = (0..16).map(|i| (i % 7) as i8 - 3).collect();
        sim.dram.write_i8_matrix(0, &a, 4, 4, 4);
        sim.dram.write_i8_matrix(64, &b, 4, 4, 4);
        sim.run(&matmul_stream(0, 64, 128));
        let c = sim.dram.read_i8_matrix(128, 4, 4, 4);
        for m in 0..4 {
            for n in 0..4 {
                let expect: i32 =
                    (0..4).map(|k| a[m * 4 + k] as i32 * b[k * 4 + n] as i32).sum();
                assert_eq!(c[m * 4 + n] as i32, expect.clamp(-128, 127), "m={m} n={n}");
            }
        }
    }

    #[test]
    fn relu_applied_on_mvout() {
        let cfg = small_cfg();
        let mut sim = Simulator::new_functional(cfg, 1 << 16);
        let a: Vec<i8> = vec![-1; 16];
        let b: Vec<i8> = vec![1, 0, 0, 0, 0, 1, 0, 0, 0, 0, 1, 0, 0, 0, 0, 1];
        sim.dram.write_i8_matrix(0, &a, 4, 4, 4);
        sim.dram.write_i8_matrix(64, &b, 4, 4, 4);
        let mut stream = matmul_stream(0, 64, 128);
        stream[1] = Instr::ConfigSt { scale: 1.0, activation: Activation::Relu };
        sim.run(&stream);
        let c = sim.dram.read_i8_matrix(128, 4, 4, 4);
        assert!(c.iter().all(|&v| v == 0));
    }

    #[test]
    fn relu6_clamps_at_qmax() {
        let cfg = small_cfg();
        let mut sim = Simulator::new_functional(cfg, 1 << 16);
        let a: Vec<i8> = vec![10; 16];
        let b: Vec<i8> = vec![1; 16];
        sim.dram.write_i8_matrix(0, &a, 4, 4, 4);
        sim.dram.write_i8_matrix(64, &b, 4, 4, 4);
        let mut stream = matmul_stream(0, 64, 128);
        stream[1] =
            Instr::ConfigSt { scale: 1.0, activation: Activation::Relu6 { qmax: 24 } };
        sim.run(&stream);
        let c = sim.dram.read_i8_matrix(128, 4, 4, 4);
        assert!(c.iter().all(|&v| v == 24), "{c:?}"); // 4*10 = 40 clamps to 24
    }

    #[test]
    fn output_scale_requantizes() {
        let cfg = small_cfg();
        let mut sim = Simulator::new_functional(cfg, 1 << 16);
        let a: Vec<i8> = vec![10; 16];
        let b: Vec<i8> = vec![1; 16];
        sim.dram.write_i8_matrix(0, &a, 4, 4, 4);
        sim.dram.write_i8_matrix(64, &b, 4, 4, 4);
        let mut stream = matmul_stream(0, 64, 128);
        stream[1] = Instr::ConfigSt { scale: 0.25, activation: Activation::None };
        sim.run(&stream);
        let c = sim.dram.read_i8_matrix(128, 4, 4, 4);
        assert!(c.iter().all(|&v| v == 10)); // 40 * 0.25
    }

    #[test]
    fn accumulate_chains_partial_sums() {
        let cfg = small_cfg();
        let mut sim = Simulator::new_functional(cfg, 1 << 16);
        let a: Vec<i8> = vec![1; 16];
        let b: Vec<i8> = vec![1, 0, 0, 0, 0, 1, 0, 0, 0, 0, 1, 0, 0, 0, 0, 1];
        sim.dram.write_i8_matrix(0, &a, 4, 4, 4);
        sim.dram.write_i8_matrix(64, &b, 4, 4, 4);
        let stream = vec![
            Instr::ConfigSt { scale: 1.0, activation: Activation::None },
            Instr::Mvin { dram_addr: 0, dst: MvinDst::Scratchpad { row: 0 }, rows: 4, cols: 4, stride_bytes: 4 },
            Instr::Mvin { dram_addr: 64, dst: MvinDst::Scratchpad { row: 4 }, rows: 4, cols: 4, stride_bytes: 4 },
            Instr::Preload { b_row: 4, acc_row: 0, accumulate: false },
            Instr::Compute { a_row: 0, rows: 4, cols: 4 },
            // Second compute into the same tile accumulates.
            Instr::Compute { a_row: 0, rows: 4, cols: 4 },
            Instr::Mvout { acc_row: 0, dram_addr: 128, rows: 4, cols: 4, stride_bytes: 4 },
            Instr::Flush,
        ];
        sim.run(&stream);
        let c = sim.dram.read_i8_matrix(128, 4, 4, 4);
        assert!(c.iter().all(|&v| v == 2), "{c:?}");
    }

    #[test]
    fn controllers_overlap_independent_work() {
        // A long mvin to fresh rows is independent of computes on rows
        // already resident (sp_write = 0): decoupled controllers overlap
        // them, a flush between them forces serialization.
        let mut cfg = small_cfg();
        cfg.scratchpad_ports = 2; // isolate the controller-overlap effect
        let mk = |sim: &mut Simulator, serial: bool| -> u64 {
            let mut stream = vec![
                Instr::ConfigSt { scale: 1.0, activation: Activation::None },
                // Big load to rows 64.. (not used by the computes below).
                Instr::Mvin { dram_addr: 0, dst: MvinDst::Scratchpad { row: 64 }, rows: 64, cols: 4, stride_bytes: 4 },
            ];
            if serial {
                stream.push(Instr::Flush);
            }
            for i in 0..8 {
                stream.push(Instr::Preload { b_row: 4, acc_row: i * 4, accumulate: false });
                stream.push(Instr::Compute { a_row: 0, rows: 4, cols: 4 });
            }
            stream.push(Instr::Flush);
            sim.run(&stream).cycles
        };
        let mut s1 = Simulator::new(cfg.clone(), 1 << 16);
        let overlapped = mk(&mut s1, false);
        let mut s2 = Simulator::new(cfg, 1 << 16);
        let serialized = mk(&mut s2, true);
        assert!(
            overlapped < serialized,
            "overlap {overlapped} !< serial {serialized}"
        );
    }

    #[test]
    fn two_ports_not_slower() {
        let run = |ports: usize| {
            let mut cfg = small_cfg();
            cfg.scratchpad_ports = ports;
            let mut sim = Simulator::new(cfg, 1 << 16);
            let mut stream = vec![Instr::ConfigSt { scale: 1.0, activation: Activation::None }];
            // Interleave loads (to fresh rows) with computes on loaded rows.
            for i in 0..8usize {
                stream.push(Instr::Mvin {
                    dram_addr: i * 64,
                    dst: MvinDst::Scratchpad { row: i * 8 },
                    rows: 8,
                    cols: 4,
                    stride_bytes: 4,
                });
                if i >= 1 {
                    stream.push(Instr::Preload { b_row: (i - 1) * 8 + 4, acc_row: 0, accumulate: false });
                    stream.push(Instr::Compute { a_row: (i - 1) * 8, rows: 4, cols: 4 });
                }
            }
            stream.push(Instr::Flush);
            sim.run(&stream).cycles
        };
        assert!(run(2) <= run(1));
    }

    #[test]
    fn raw_hazard_enforced_mvout_waits_for_compute() {
        let cfg = small_cfg();
        let mut sim = Simulator::new(cfg.clone(), 1 << 16);
        let stream = vec![
            Instr::ConfigSt { scale: 1.0, activation: Activation::None },
            Instr::Mvin { dram_addr: 0, dst: MvinDst::Scratchpad { row: 0 }, rows: 8, cols: 4, stride_bytes: 4 },
            Instr::Preload { b_row: 4, acc_row: 0, accumulate: false },
            Instr::Compute { a_row: 0, rows: 4, cols: 4 },
            Instr::Mvout { acc_row: 0, dram_addr: 1024, rows: 4, cols: 4, stride_bytes: 4 },
            Instr::Flush,
        ];
        let res = sim.run(&stream);
        // The mvout must start after compute results (incl. drain): total
        // must exceed the pure DMA cost of the two transfers.
        let dma_only = sim.dma_occupancy(8, 32)
            + sim.dma_latency(8)
            + sim.dma_occupancy(4, 16)
            + sim.dma_latency(4);
        assert!(res.cycles > dma_only);
    }

    #[test]
    fn utilization_bounded() {
        let cfg = small_cfg();
        let mut sim = Simulator::new_functional(cfg.clone(), 1 << 16);
        let res = sim.run(&matmul_stream(0, 64, 128));
        let u = res.utilization(&cfg);
        assert!(u > 0.0 && u <= 1.0, "{u}");
    }

    #[test]
    fn streams_chain_on_one_timeline() {
        let cfg = small_cfg();
        let mut sim = Simulator::new(cfg, 1 << 16);
        let r1 = sim.run(&matmul_stream(0, 64, 128));
        let r2 = sim.run(&matmul_stream(0, 64, 256));
        assert!(r1.cycles > 0 && r2.cycles > 0);
    }
}
