//! CISC-type instruction expansion.
//!
//! Gemmini's `LOOP_WS` / `LOOP_CONV` instructions run hardcoded state
//! machines that internally issue the same mvin/preload/compute/mvout
//! micro-ops a programmer could issue manually (Section III of the paper).
//! The FSM's schedule is *fixed*: single-buffered tile loops with a
//! conservative m→n→k order and one accumulator tile. That fixed schedule
//! is exactly what the paper's AutoTVM pass beats by ~50 % on most layers
//! (Section V-A) — the tuned RISC streams in
//! [`crate::scheduler::codegen`] double-buffer and reorder loops instead.

use super::config::GemminiConfig;
use super::isa::{Activation, Instr, MvinDst};
use super::memory::Dram;

/// Geometry of a GEMM in DRAM: `C[m×n] = A[m×k] · B[k×n] (+ bias[n])`.
/// `A` row-major with stride `k`, `B` row-major with stride `n` (int8),
/// bias int32 with `n` entries, `C` row-major int8 with stride `n`.
#[derive(Debug, Clone)]
pub struct GemmGeometry {
    pub m: usize,
    pub n: usize,
    pub k: usize,
    pub a_addr: usize,
    pub b_addr: usize,
    pub bias_addr: Option<usize>,
    pub c_addr: usize,
    pub scale: f32,
    pub activation: Activation,
    /// DMA requests per A-tile load (1 for contiguous matmul operands;
    /// `kernel` for conv, modelling the FSM's per-kernel-row gather).
    pub a_frag: usize,
}

/// Expand one CISC instruction into RISC micro-ops.
pub fn expand(cfg: &GemminiConfig, ins: &Instr, out: &mut Vec<Instr>) {
    match ins {
        Instr::LoopWs { m, n, k, a_addr, b_addr, bias_addr, c_addr, scale, activation } => {
            expand_gemm(
                cfg,
                &GemmGeometry {
                    m: *m,
                    n: *n,
                    k: *k,
                    a_addr: *a_addr,
                    b_addr: *b_addr,
                    bias_addr: *bias_addr,
                    c_addr: *c_addr,
                    scale: *scale,
                    activation: *activation,
                    a_frag: 1,
                },
                out,
            );
        }
        Instr::LoopConv {
            in_h,
            in_w,
            in_c,
            out_c,
            kernel,
            stride,
            padding,
            w_addr,
            bias_addr,
            out_addr,
            im2col_addr,
            scale,
            activation,
            ..
        } => {
            let (oh, ow) = conv_out_dims(*in_h, *in_w, *kernel, *stride, *padding);
            expand_gemm(
                cfg,
                &GemmGeometry {
                    m: oh * ow,
                    n: *out_c,
                    k: kernel * kernel * in_c,
                    a_addr: *im2col_addr,
                    b_addr: *w_addr,
                    bias_addr: *bias_addr,
                    c_addr: *out_addr,
                    scale: *scale,
                    activation: *activation,
                    a_frag: *kernel,
                },
                out,
            );
        }
        _ => out.push(ins.clone()),
    }
}

/// Output spatial dims of a convolution.
pub fn conv_out_dims(in_h: usize, in_w: usize, kernel: usize, stride: usize, padding: usize) -> (usize, usize) {
    (
        (in_h + 2 * padding - kernel) / stride + 1,
        (in_w + 2 * padding - kernel) / stride + 1,
    )
}

/// The fixed CISC schedule: m→n→k tile loop with **no cross-tile reuse**
/// (A reloaded per n-tile, B reloaded per (m,n,k) tile) — but with the
/// double-buffered overlap the hardware FSM provides (its Load and
/// Execute controllers run decoupled over two scratchpad banks and two
/// accumulator tiles). What the tuner later adds is *reuse*, not overlap.
fn expand_gemm(cfg: &GemminiConfig, g: &GemmGeometry, out: &mut Vec<Instr>) {
    let dim = cfg.dim;
    let mt = g.m.div_ceil(dim);
    let nt = g.n.div_ceil(dim);
    let kt = g.k.div_ceil(dim);

    out.push(Instr::ConfigEx { acc_shift: 0 });
    out.push(Instr::ConfigSt { scale: g.scale, activation: g.activation });

    let mut iter = 0usize; // rotates the A/B scratchpad banks
    for mi in 0..mt {
        let m_eff = dim.min(g.m - mi * dim);
        for ni in 0..nt {
            let n_eff = dim.min(g.n - ni * dim);
            let with_bias = g.bias_addr.is_some();
            let acc_tile = (mi * nt + ni) % 2; // two acc tiles in flight
            let acc_row = acc_tile * dim;
            if let Some(bias) = g.bias_addr {
                // Broadcast the bias row over all m_eff accumulator rows
                // (stride 0: the same n-segment re-read per row).
                out.push(Instr::Mvin {
                    dram_addr: bias + ni * dim * 4,
                    dst: MvinDst::Accumulator { row: acc_row },
                    rows: m_eff,
                    cols: n_eff,
                    stride_bytes: 0,
                });
            }
            for ki in 0..kt {
                let k_eff = dim.min(g.k - ki * dim);
                let a_buf = (iter % 2) * 2 * dim;
                let b_buf = a_buf + dim;
                iter += 1;
                // A tile: split into `a_frag` chunks to model the conv
                // FSM's per-kernel-row gather.
                let frag = g.a_frag.clamp(1, m_eff);
                let chunk = m_eff.div_ceil(frag);
                let mut r0 = 0usize;
                while r0 < m_eff {
                    let rows = chunk.min(m_eff - r0);
                    out.push(Instr::Mvin {
                        dram_addr: g.a_addr + (mi * dim + r0) * g.k + ki * dim,
                        dst: MvinDst::Scratchpad { row: a_buf + r0 },
                        rows,
                        cols: k_eff,
                        stride_bytes: g.k,
                    });
                    r0 += rows;
                }
                // B tile (k_eff × n_eff).
                out.push(Instr::Mvin {
                    dram_addr: g.b_addr + (ki * dim) * g.n + ni * dim,
                    dst: MvinDst::Scratchpad { row: b_buf },
                    rows: k_eff,
                    cols: n_eff,
                    stride_bytes: g.n,
                });
                out.push(Instr::Preload {
                    b_row: b_buf,
                    acc_row,
                    accumulate: ki > 0 || with_bias,
                });
                out.push(Instr::Compute { a_row: a_buf, rows: m_eff, cols: k_eff });
            }
            out.push(Instr::Mvout {
                acc_row,
                dram_addr: g.c_addr + (mi * dim) * g.n + ni * dim,
                rows: m_eff,
                cols: n_eff,
                stride_bytes: g.n,
            });
        }
    }
    out.push(Instr::Flush);
}

/// Stage the im2col matrix for a `LoopConv` into DRAM (functional mode).
/// Layout: `M×K` row-major at `im2col_addr` with `M = oh·ow`,
/// `K = kernel²·in_c`; padding pixels are zero.
pub fn stage_im2col(dram: &mut Dram, ins: &Instr) {
    let Instr::LoopConv {
        in_h, in_w, in_c, kernel, stride, padding, in_addr, im2col_addr, ..
    } = *ins
    else {
        panic!("stage_im2col expects LoopConv");
    };
    let (oh, ow) = conv_out_dims(in_h, in_w, kernel, stride, padding);
    let kk = kernel * kernel * in_c;
    for oy in 0..oh {
        for ox in 0..ow {
            let patch = oy * ow + ox;
            for kh in 0..kernel {
                for kw in 0..kernel {
                    let iy = (oy * stride + kh) as isize - padding as isize;
                    let ix = (ox * stride + kw) as isize - padding as isize;
                    let dst = im2col_addr + patch * kk + (kh * kernel + kw) * in_c;
                    if iy < 0 || ix < 0 || iy >= in_h as isize || ix >= in_w as isize {
                        for c in 0..in_c {
                            dram.write_i8(dst + c, 0);
                        }
                    } else {
                        let src = in_addr + ((iy as usize) * in_w + ix as usize) * in_c;
                        for c in 0..in_c {
                            let v = dram.read_i8(src + c);
                            dram.write_i8(dst + c, v);
                        }
                    }
                }
            }
        }
    }
}

/// Bytes needed for a conv's staged im2col buffer.
pub fn im2col_bytes(in_h: usize, in_w: usize, in_c: usize, kernel: usize, stride: usize, padding: usize) -> usize {
    let (oh, ow) = conv_out_dims(in_h, in_w, kernel, stride, padding);
    oh * ow * kernel * kernel * in_c
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::gemmini::sim::Simulator;

    fn cfg4() -> GemminiConfig {
        GemminiConfig { dim: 4, scratchpad_kib: 8, accumulator_kib: 4, ..GemminiConfig::original_zcu102() }
    }

    /// Software int8 GEMM reference with requantization.
    fn ref_gemm(
        a: &[i8],
        b: &[i8],
        bias: Option<&[i32]>,
        m: usize,
        n: usize,
        k: usize,
        scale: f32,
        act: Activation,
    ) -> Vec<i8> {
        let mut c = vec![0i8; m * n];
        for i in 0..m {
            for j in 0..n {
                let mut accv: i32 = bias.map(|b| b[j]).unwrap_or(0);
                for x in 0..k {
                    accv += a[i * k + x] as i32 * b[x * n + j] as i32;
                }
                let scaled = (accv as f32 * scale).round() as i32;
                c[i * n + j] = match act {
                    Activation::None => scaled.clamp(-128, 127) as i8,
                    Activation::Relu => scaled.clamp(0, 127) as i8,
                    Activation::Relu6 { qmax } => scaled.clamp(0, qmax as i32) as i8,
                };
            }
        }
        c
    }

    fn run_cisc_gemm(m: usize, n: usize, k: usize, bias: bool, scale: f32, act: Activation) {
        let cfg = cfg4();
        let mut sim = Simulator::new_functional(cfg, 1 << 20);
        let a: Vec<i8> = (0..m * k).map(|i| ((i * 13 + 7) % 11) as i8 - 5).collect();
        let b: Vec<i8> = (0..k * n).map(|i| ((i * 5 + 1) % 9) as i8 - 4).collect();
        let bias_v: Vec<i32> = (0..n).map(|i| (i as i32 % 7) - 3).collect();
        let (a_addr, b_addr, c_addr, bias_addr) = (0usize, 4096usize, 8192usize, 12288usize);
        sim.dram.write_i8_matrix(a_addr, &a, m, k, k);
        sim.dram.write_i8_matrix(b_addr, &b, k, n, n);
        if bias {
            sim.dram.write_i32_matrix(bias_addr, &bias_v, 1, n, 0);
        }
        let stream = vec![Instr::LoopWs {
            m,
            n,
            k,
            a_addr,
            b_addr,
            bias_addr: bias.then_some(bias_addr),
            c_addr,
            scale,
            activation: act,
        }];
        let res = sim.run(&stream);
        assert!(res.cycles > 0);
        let got = sim.dram.read_i8_matrix(c_addr, m, n, n);
        let want = ref_gemm(&a, &b, bias.then_some(&bias_v[..]), m, n, k, scale, act);
        assert_eq!(got, want, "m={m} n={n} k={k} bias={bias}");
    }

    #[test]
    fn cisc_gemm_square_tiles() {
        run_cisc_gemm(8, 8, 8, false, 1.0, Activation::None);
    }

    #[test]
    fn cisc_gemm_ragged_edges() {
        run_cisc_gemm(7, 5, 9, false, 1.0, Activation::None);
        run_cisc_gemm(3, 3, 3, false, 1.0, Activation::None);
        run_cisc_gemm(13, 6, 10, false, 1.0, Activation::None);
    }

    #[test]
    fn cisc_gemm_with_bias_and_scale() {
        run_cisc_gemm(8, 8, 8, true, 0.5, Activation::None);
        run_cisc_gemm(6, 7, 5, true, 0.25, Activation::Relu);
    }

    #[test]
    fn cisc_gemm_relu6() {
        run_cisc_gemm(8, 4, 12, true, 0.125, Activation::Relu6 { qmax: 20 });
    }

    #[test]
    fn cisc_conv_matches_direct_reference() {
        // 6×6×3 input, 2 output channels, 3×3 kernel, stride 1, pad 1.
        let (ih, iw, ic, oc, k, s, p) = (6usize, 6usize, 3usize, 2usize, 3usize, 1usize, 1usize);
        let (oh, ow) = conv_out_dims(ih, iw, k, s, p);
        let cfg = cfg4();
        let mut sim = Simulator::new_functional(cfg, 1 << 20);
        let input: Vec<i8> = (0..ih * iw * ic).map(|i| ((i * 7 + 3) % 13) as i8 - 6).collect();
        // Weights in GEMM layout: K×N where K = k*k*ic, N = oc.
        let kk = k * k * ic;
        let w: Vec<i8> = (0..kk * oc).map(|i| ((i * 11 + 5) % 7) as i8 - 3).collect();
        let (in_addr, w_addr, out_addr, im_addr) = (0usize, 8192usize, 16384usize, 32768usize);
        sim.dram.write_i8_matrix(in_addr, &input, ih * iw, ic, ic);
        sim.dram.write_i8_matrix(w_addr, &w, kk, oc, oc);
        let conv = Instr::LoopConv {
            batch: 1,
            in_h: ih,
            in_w: iw,
            in_c: ic,
            out_c: oc,
            kernel: k,
            stride: s,
            padding: p,
            in_addr,
            w_addr,
            bias_addr: None,
            out_addr,
            im2col_addr: im_addr,
            scale: 1.0,
            activation: Activation::None,
        };
        sim.run(&[conv]);
        let got = sim.dram.read_i8_matrix(out_addr, oh * ow, oc, oc);
        // Direct conv reference.
        let mut want = vec![0i8; oh * ow * oc];
        for oy in 0..oh {
            for ox in 0..ow {
                for n in 0..oc {
                    let mut acc = 0i32;
                    for kh in 0..k {
                        for kw in 0..k {
                            let iy = (oy * s + kh) as isize - p as isize;
                            let ix = (ox * s + kw) as isize - p as isize;
                            if iy < 0 || ix < 0 || iy >= ih as isize || ix >= iw as isize {
                                continue;
                            }
                            for c in 0..ic {
                                let xv = input[((iy as usize) * iw + ix as usize) * ic + c] as i32;
                                let wv = w[((kh * k + kw) * ic + c) * oc + n] as i32;
                                acc += xv * wv;
                            }
                        }
                    }
                    want[(oy * ow + ox) * oc + n] = acc.clamp(-128, 127) as i8;
                }
            }
        }
        assert_eq!(got, want);
    }

    #[test]
    fn expansion_instruction_count_scales_with_tiles() {
        let cfg = cfg4();
        let mut small = Vec::new();
        expand(
            &cfg,
            &Instr::LoopWs { m: 4, n: 4, k: 4, a_addr: 0, b_addr: 0, bias_addr: None, c_addr: 0, scale: 1.0, activation: Activation::None },
            &mut small,
        );
        let mut big = Vec::new();
        expand(
            &cfg,
            &Instr::LoopWs { m: 16, n: 16, k: 16, a_addr: 0, b_addr: 0, bias_addr: None, c_addr: 0, scale: 1.0, activation: Activation::None },
            &mut big,
        );
        assert!(big.len() > 10 * small.len() / 2, "{} vs {}", big.len(), small.len());
    }

    #[test]
    fn im2col_bytes_geometry() {
        // 4×4, k3 s1 p1 -> 16 patches × 9·c
        assert_eq!(im2col_bytes(4, 4, 2, 3, 1, 1), 16 * 18);
    }
}
