//! Baseline hardware models for the cross-platform comparison
//! (Figures 7 & 8, Table IV).
//!
//! The paper measures a server GPU (GTX1080), an embedded GPU (Jetson AGX
//! Xavier), ARM CPUs (Raspberry Pi 4 and the Zynq PS quad-A53) and the VTA
//! accelerator on a ZCU111 — all running the same TVM-compiled, autotuned
//! int8 model. We model each as `latency = overhead + GOP / sustained
//! throughput` with a measured average power, calibrated against the
//! paper's own Table IV energies (DESIGN.md §2: Table IV compares *ratios
//! across platforms*, which the calibration preserves; the shape content
//! is in how latency/energy scale across the three pruned variants).

use crate::energy::EnergyReport;

/// A fixed-function platform model: enough to produce Figure 7 latencies
/// and Table IV energies for any workload size.
#[derive(Debug, Clone)]
pub struct Platform {
    pub name: &'static str,
    /// Per-inference overhead independent of model size (kernel launches,
    /// framework dispatch, data movement), seconds.
    pub overhead_s: f64,
    /// Sustained int8 throughput on tuned CNN layers, GOP/s.
    pub sustained_gops: f64,
    /// Average board/device power while running, W.
    pub power_w: f64,
}

impl Platform {
    /// End-to-end latency for a workload of `gop` giga-operations.
    pub fn latency_s(&self, gop: f64) -> f64 {
        self.overhead_s + gop / self.sustained_gops
    }

    /// Energy report for a workload.
    pub fn energy(&self, model: &str, gop: f64) -> EnergyReport {
        EnergyReport::new(self.name, model, self.latency_s(gop), self.power_w, gop)
    }
}

/// NVIDIA GTX1080 (server GPU reference). TVM-tuned int8 conv throughput
/// is far below the card's theoretical peak (no dp4a tensor cores used by
/// the paper's TVM stack); large per-launch overheads.
pub fn gtx1080() -> Platform {
    Platform { name: "NVIDIA GTX1080", overhead_s: 0.0075, sustained_gops: 430.0, power_w: 180.0 }
}

/// NVIDIA Jetson AGX Xavier (embedded GPU, 30 W mode).
pub fn xavier() -> Platform {
    Platform {
        name: "NVIDIA Jetson AGX Xavier",
        overhead_s: 0.018,
        sustained_gops: 171.0,
        power_w: 30.0,
    }
}

/// Raspberry Pi 4 (Cortex-A72 quad, NEON int8 via TVM).
pub fn rpi4() -> Platform {
    Platform { name: "Raspberry Pi 4", overhead_s: 0.010, sustained_gops: 9.0, power_w: 6.5 }
}

/// The Zynq PS side alone (Cortex-A53 quad) — the "main part on PS"
/// scenario of Figure 6.
pub fn zynq_ps() -> Platform {
    Platform { name: "UltraScale+ PS (A53 quad)", overhead_s: 0.006, sustained_gops: 7.0, power_w: 5.2 }
}

/// VTA on the ZCU111 at 100 MHz (Table II row 4): a 16×16 GEMM core
/// without DSPs; modest sustained throughput and high per-layer overhead
/// through its JIT runtime.
pub fn vta_zcu111() -> Platform {
    Platform { name: "ZCU111-VTA", overhead_s: 0.102, sustained_gops: 68.0, power_w: 8.8 }
}

/// All Figure 7 baseline platforms (our Gemmini rows come from the
/// simulator, not from this list).
pub fn all_baselines() -> Vec<Platform> {
    vec![gtx1080(), xavier(), rpi4(), zynq_ps(), vta_zcu111()]
}

#[cfg(test)]
mod tests {
    use super::*;

    /// YOLOv7-tiny GOP at 480², per variant (from the workload module).
    fn gops3() -> [f64; 3] {
        use crate::workload::{yolov7_tiny, ModelVariant};
        [
            yolov7_tiny(480, ModelVariant::Base, 80).gops(),
            yolov7_tiny(480, ModelVariant::Pruned40, 80).gops(),
            yolov7_tiny(480, ModelVariant::Pruned88, 80).gops(),
        ]
    }

    #[test]
    fn gtx1080_energy_close_to_table4() {
        let [base, p40, p88] = gops3();
        let g = gtx1080();
        // Paper: 4.58 J / 3.28 J / 1.78 J.
        let e = [g.energy("base", base), g.energy("p40", p40), g.energy("p88", p88)];
        assert!((e[0].energy_j - 4.58).abs() / 4.58 < 0.25, "{}", e[0].energy_j);
        assert!((e[1].energy_j - 3.28).abs() / 3.28 < 0.30, "{}", e[1].energy_j);
        assert!((e[2].energy_j - 1.78).abs() / 1.78 < 0.35, "{}", e[2].energy_j);
    }

    #[test]
    fn xavier_energy_close_to_table4() {
        let [base, p40, p88] = gops3();
        let x = xavier();
        // Paper: 1.89 J / 1.31 J / 0.72 J.
        assert!((x.energy("b", base).energy_j - 1.89).abs() / 1.89 < 0.25);
        assert!((x.energy("p40", p40).energy_j - 1.31).abs() / 1.31 < 0.30);
        assert!((x.energy("p88", p88).energy_j - 0.72).abs() / 0.72 < 0.35);
    }

    #[test]
    fn vta_energy_close_to_table4() {
        let [base, p40, p88] = gops3();
        let v = vta_zcu111();
        // Paper: 1.89 J / 1.57 J / 1.03 J.
        assert!((v.energy("b", base).energy_j - 1.89).abs() / 1.89 < 0.25);
        assert!((v.energy("p40", p40).energy_j - 1.57).abs() / 1.57 < 0.30);
        assert!((v.energy("p88", p88).energy_j - 1.03).abs() / 1.03 < 0.35);
    }

    #[test]
    fn pruning_degrades_baseline_efficiency() {
        // Table IV shape: on every platform, the 88 %-pruned model is LESS
        // energy-efficient (fixed overheads amortize worse).
        let [base, _, p88] = gops3();
        for p in all_baselines() {
            let e_base = p.energy("b", base).efficiency();
            let e_p88 = p.energy("p", p88).efficiency();
            assert!(e_p88 < e_base, "{}: {e_p88} !< {e_base}", p.name);
        }
    }

    #[test]
    fn latency_ordering_matches_fig7() {
        // GTX1080 < Xavier < VTA < RPi4 < PS for the base model.
        let [base, ..] = gops3();
        let l: Vec<f64> =
            [gtx1080(), xavier(), vta_zcu111(), rpi4(), zynq_ps()].iter().map(|p| p.latency_s(base)).collect();
        for w in l.windows(2) {
            assert!(w[0] < w[1], "{l:?}");
        }
    }
}
