//! The accuracy pipeline: replay fleet outcomes against scenario ground
//! truth and score what the shed rate cost.
//!
//! Detection runs the synthetic detector head
//! ([`crate::dataset::detector::SyntheticDetector`] — head-format rows
//! through [`crate::postproc::nms::decode_and_nms`], byte-deterministic
//! per `(seed, camera, frame)`); completed frames contribute their
//! detections, shed frames contribute none (but keep their ground truth,
//! so every shed frame directly costs recall). Tracking projects
//! detection centers through the camera [`Homography`] into world meters
//! and updates a per-camera [`GmPhd`] filter in frame order — a shed
//! frame is a missed-measurement step, which is exactly how the GM-PHD
//! recursion models sensor dropout.
//!
//! The whole report is a pure function of `(workload, shed bitmap)`:
//! zero shedding reproduces the offline detector baseline bit-exactly,
//! and any two drivers that shed the same frames report identically —
//! the property `tests/scenario_accuracy.rs` pins down.

use crate::dataset::detector::{SyntheticDetector, NUM_CLASSES};
use crate::postproc::bbox::Detection;
use crate::postproc::map::{mean_average_precision, GroundTruth};
use crate::serving::autoscale::Autoscaler;
use crate::serving::device::Backend;
use crate::serving::ladder::VariantLadder;
use crate::serving::live::{serve_live_logged, LiveConfig};
use crate::serving::metrics::{FleetReport, RegimeReport, ScenarioReport};
use crate::serving::shard::ShardPool;
use crate::serving::sim::{simulate_autoscaled_logged, simulate_logged, SimConfig};
use crate::serving::RequestOutcome;
use crate::tracking::{GmPhd, GmPhdConfig};

use super::catalog::{camera_homography, ScenarioWorkload};

/// World-distance gate (meters) within which a track covers a
/// ground-truth object. Objects are ~1–2 m across and the measurement
/// noise is ~0.2 m, so 2 m separates "tracked" from "lost" cleanly.
const GATE_M: f64 = 2.0;

/// Score one run's outcomes against the workload's ground truth.
/// `outcomes` must cover the whole trace in id order — what the logged
/// drivers return. Every served frame is scored with the full model's
/// detector head; runs under
/// [`AdmissionPolicy::Degrade`](crate::serving::AdmissionPolicy::Degrade)
/// should use [`evaluate_scenario_with`] so degraded frames are scored
/// with their rung's own head.
pub fn evaluate_scenario(w: &ScenarioWorkload, outcomes: &[RequestOutcome]) -> ScenarioReport {
    evaluate_scenario_with(w, outcomes, None)
}

/// As [`evaluate_scenario`], scoring each served frame with the detector
/// head of the [`VariantLadder`] rung it was served at — the measured
/// mAP reflects what was *actually served*, not the full model's
/// ceiling. Rung 0 is the default head, so with `None` (or a log where
/// every rung is 0) this is bit-identical to [`evaluate_scenario`]; the
/// offline ceiling always uses the full model's head.
pub fn evaluate_scenario_with(
    w: &ScenarioWorkload,
    outcomes: &[RequestOutcome],
    ladder: Option<&VariantLadder>,
) -> ScenarioReport {
    assert_eq!(
        outcomes.len(),
        w.trace.len(),
        "outcome log must cover the trace (conservation)"
    );
    assert!(outcomes.iter().enumerate().all(|(i, o)| o.id == i as u64), "outcomes in id order");

    let detector = SyntheticDetector::new(w.seed);
    // One calibrated head per rung (rung 0 shares the offline head's
    // default config; deeper rungs miss more and localize worse).
    let rung_detectors: Vec<SyntheticDetector> = ladder
        .map(|l| {
            l.rungs
                .iter()
                .map(|r| SyntheticDetector { seed: w.seed, cfg: r.detector.clone() })
                .collect()
        })
        .unwrap_or_default();
    let n = w.frames.len();
    let mut gts: Vec<Vec<GroundTruth>> = Vec::with_capacity(n);
    let mut offline: Vec<Vec<Detection>> = Vec::with_capacity(n);
    let mut served: Vec<Vec<Detection>> = Vec::with_capacity(n);
    for (f, o) in w.frames.iter().zip(outcomes) {
        let dets = detector.detect(f.camera, f.frame_idx, &f.truths);
        served.push(if o.shed {
            Vec::new()
        } else if o.rung > 0 && !rung_detectors.is_empty() {
            let k = (o.rung as usize).min(rung_detectors.len() - 1);
            rung_detectors[k].detect(f.camera, f.frame_idx, &f.truths)
        } else {
            dets.clone()
        });
        offline.push(dets);
        gts.push(f.truths.clone());
    }
    let map = mean_average_precision(&served, &gts, NUM_CLASSES, 0.5);
    let offline_map = mean_average_precision(&offline, &gts, NUM_CLASSES, 0.5);

    // ---- per-camera tracking over frames in emission order ----
    let phd_cfg = GmPhdConfig { dt: 1.0 / w.scenario.fps, ..Default::default() };
    let mut covered = 0u64;
    let mut object_frames = 0u64;
    let mut switches = 0u64;
    let mut cardinality_err = 0.0f64;
    // Last matched track id per (camera, pool-object) identity.
    let pool = w.scenario.segments.iter().map(|s| s.density).max().unwrap_or(0);
    let mut last_track: Vec<Option<usize>> = vec![None; w.scenario.cameras * pool];
    let mut seen_object: Vec<bool> = vec![false; w.scenario.cameras * pool];
    for cam in 0..w.scenario.cameras {
        let h = camera_homography(cam);
        let mut filter = GmPhd::new(phd_cfg.clone());
        // Frames are time-sorted globally; filtering preserves the
        // camera's emission order.
        for (i, f) in w.frames.iter().enumerate().filter(|(_, f)| f.camera == cam) {
            let meas: Vec<(f64, f64)> = served[i]
                .iter()
                .map(|d| h.project(d.bbox.cx as f64, d.bbox.cy as f64))
                .collect();
            filter.step(&meas);
            cardinality_err += (filter.cardinality() - f.truths.len() as f64).abs();
            let tracks = filter.tracks();
            for (j, t) in f.truths.iter().enumerate() {
                object_frames += 1;
                let key = cam * pool + j;
                seen_object[key] = true;
                let (gx, gy) = h.project(t.bbox.cx as f64, t.bbox.cy as f64);
                let nearest = tracks
                    .iter()
                    .map(|tr| {
                        let d2 = (tr.x - gx).powi(2) + (tr.y - gy).powi(2);
                        (d2, tr.id)
                    })
                    .min_by(|a, b| a.0.partial_cmp(&b.0).unwrap());
                match nearest {
                    Some((d2, id)) if d2 < GATE_M * GATE_M => {
                        covered += 1;
                        if let Some(prev) = last_track[key] {
                            if prev != id {
                                switches += 1;
                            }
                        }
                        last_track[key] = Some(id);
                    }
                    _ => {}
                }
            }
        }
    }
    let objects = seen_object.iter().filter(|&&s| s).count() as u64;
    let frames_shed = outcomes.iter().filter(|o| o.shed).count() as u64;

    // ---- per-regime breakdown ----
    let regimes = w
        .scenario
        .segments
        .iter()
        .enumerate()
        .map(|(si, s)| {
            let idx: Vec<usize> =
                (0..n).filter(|&i| w.frames[i].segment == si).collect();
            let seg_dets: Vec<Vec<Detection>> =
                idx.iter().map(|&i| served[i].clone()).collect();
            let seg_gts: Vec<Vec<GroundTruth>> = idx.iter().map(|&i| gts[i].clone()).collect();
            let shed = idx.iter().filter(|&&i| outcomes[i].shed).count() as u64;
            RegimeReport {
                name: s.name.to_string(),
                offered: idx.len() as u64,
                completed: idx.len() as u64 - shed,
                shed,
                map: mean_average_precision(&seg_dets, &seg_gts, NUM_CLASSES, 0.5),
            }
        })
        .collect();

    ScenarioReport {
        name: w.scenario.name.to_string(),
        cameras: w.scenario.cameras,
        frames_offered: n as u64,
        frames_completed: n as u64 - frames_shed,
        frames_shed,
        map,
        offline_map,
        continuity: if object_frames == 0 { 1.0 } else { covered as f64 / object_frames as f64 },
        fragmentation: if objects == 0 { 0.0 } else { switches as f64 / objects as f64 },
        cardinality_mae: if n == 0 { 0.0 } else { cardinality_err / n as f64 },
        regimes,
    }
}

/// Run the workload through the DES on a fixed pool and attach the
/// accuracy report.
pub fn run_scenario_des(
    w: &ScenarioWorkload,
    pool: &mut ShardPool,
    cfg: &SimConfig,
) -> FleetReport {
    let (mut report, outcomes) = simulate_logged(pool, &w.trace, cfg);
    report.scenario = Some(evaluate_scenario_with(w, &outcomes, cfg.admission.ladder()));
    report
}

/// Run the workload through the DES with an autoscaled pool.
pub fn run_scenario_autoscaled(
    w: &ScenarioWorkload,
    pool: &mut ShardPool,
    cfg: &SimConfig,
    auto: &mut Autoscaler,
    factory: &mut dyn FnMut(usize) -> Box<dyn Backend>,
) -> FleetReport {
    let (mut report, outcomes) = simulate_autoscaled_logged(pool, &w.trace, cfg, auto, factory);
    report.scenario = Some(evaluate_scenario_with(w, &outcomes, cfg.admission.ladder()));
    report
}

/// Run the workload through the live threaded runtime (consumes the
/// pool, like [`crate::serving::serve_live`]).
pub fn run_scenario_live(
    w: &ScenarioWorkload,
    pool: ShardPool,
    cfg: &SimConfig,
    live: &LiveConfig,
) -> FleetReport {
    let (mut report, outcomes) = serve_live_logged(pool, &w.trace, cfg, live);
    report.scenario = Some(evaluate_scenario_with(w, &outcomes, cfg.admission.ladder()));
    report
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::baselines::Platform;
    use crate::scenario::catalog::ScenarioCatalog;
    use crate::serving::device::BaselineDevice;
    use crate::serving::{BatchPolicy, ShedPolicy};

    fn test_pool(n: usize) -> ShardPool {
        let mut pool = ShardPool::new();
        for _ in 0..n {
            let p = Platform {
                name: "test-dev",
                overhead_s: 5e-3,
                sustained_gops: 100.0,
                power_w: 10.0,
            };
            pool.register(Box::new(BaselineDevice::new(p, 0.5, 16)));
        }
        pool
    }

    fn diff_cfg() -> SimConfig {
        SimConfig {
            batch: BatchPolicy::new(4, 0.010),
            queue_depth: 16,
            shed: ShedPolicy::DropOldest,
            slo_s: 0.050,
            work_stealing: false,
            ..Default::default()
        }
    }

    #[test]
    fn zero_shed_run_reproduces_offline_map_exactly() {
        let cat = ScenarioCatalog::standard();
        let w = ScenarioWorkload::generate(cat.get("steady-day").unwrap(), 42);
        let r = run_scenario_des(&w, &mut test_pool(2), &diff_cfg());
        assert_eq!(r.shed, 0, "steady-day at 1× must not shed on 2 devices");
        let s = r.scenario.expect("scenario report attached");
        assert_eq!(s.frames_offered, w.trace.len() as u64);
        assert_eq!(s.frames_shed, 0);
        assert_eq!(s.map.to_bits(), s.offline_map.to_bits(), "zero shed ⇒ bit-exact mAP");
        assert!(s.map > 0.3, "synthetic detector should score well, got {}", s.map);
        assert!(s.continuity > 0.5, "objects should mostly be tracked, got {}", s.continuity);
        assert!(s.cardinality_mae < 2.0);
        assert_eq!(s.regimes.len(), 1);
        assert_eq!(s.regimes[0].offered, s.frames_offered);
    }

    #[test]
    fn evaluation_is_a_pure_function_of_the_shed_bitmap() {
        let cat = ScenarioCatalog::standard();
        let w = ScenarioWorkload::generate(cat.get("day-night").unwrap(), 9);
        // Hand-build two outcome logs with the same shed pattern but
        // different completion times: reports must be identical.
        let mk = |dt: f64| -> Vec<RequestOutcome> {
            w.trace
                .iter()
                .map(|r| RequestOutcome {
                    id: r.id,
                    camera: r.camera,
                    t_s: r.arrival_s + dt,
                    shed: r.id % 7 == 0,
                    rung: 0,
                })
                .collect()
        };
        let a = evaluate_scenario(&w, &mk(0.01));
        let b = evaluate_scenario(&w, &mk(0.5));
        assert_eq!(format!("{a:?}"), format!("{b:?}"));
        assert!(a.frames_shed > 0);
        assert!(a.map < a.offline_map, "shedding must cost mAP");
    }

    #[test]
    fn degraded_rungs_score_between_full_and_shed() {
        let cat = ScenarioCatalog::standard();
        let w = ScenarioWorkload::generate(cat.get("day-night").unwrap(), 9);
        let ladder = VariantLadder::standard();
        let mk = |rung: u8, shed: bool| -> Vec<RequestOutcome> {
            w.trace
                .iter()
                .map(|r| RequestOutcome {
                    id: r.id,
                    camera: r.camera,
                    t_s: r.arrival_s + 0.01,
                    shed,
                    rung,
                })
                .collect()
        };
        // All-rung-0 with a ladder is bit-identical to the plain path.
        let full = evaluate_scenario_with(&w, &mk(0, false), Some(&ladder));
        let base = evaluate_scenario(&w, &mk(0, false));
        assert_eq!(format!("{full:?}"), format!("{base:?}"));
        // A fully degraded run loses accuracy — but far less than
        // losing the frames outright.
        let deep = evaluate_scenario_with(&w, &mk(2, false), Some(&ladder));
        let all_shed = evaluate_scenario_with(&w, &mk(2, true), Some(&ladder));
        assert!(deep.map < full.map, "deep rung {} !< full {}", deep.map, full.map);
        assert!(deep.map > all_shed.map, "served-degraded {} !> shed {}", deep.map, all_shed.map);
        // The offline ceiling is always the full model's head.
        assert_eq!(deep.offline_map.to_bits(), full.offline_map.to_bits());
    }
}
