//! The scenario catalog and workload generator: named traffic regimes
//! over a deterministic world of moving objects.
//!
//! Each camera owns a pool of constant-velocity objects bouncing inside
//! its frame (triangle-wave reflection, so positions are a closed-form
//! function of time — no per-step integration state). A [`Segment`]
//! timeline modulates how many pool objects are visible (density) and how
//! fast the camera emits frames (arrival multiplier); [`Dropout`] windows
//! silence a camera entirely while the world keeps moving, so rejoin
//! frames see objects far from where they vanished. Ground truth is exact
//! by construction, and every draw goes through [`crate::util::Rng`], so
//! a `(scenario, seed)` pair reproduces byte-identically.

use crate::dataset::scenes::{render_objects, Scene, SceneConfig, SceneObject, CLASS_NAMES};
use crate::postproc::bbox::BBox;
use crate::postproc::map::GroundTruth;
use crate::serving::{Request, SloClass};
use crate::tracking::Homography;
use crate::util::Rng;

/// One stretch of a scenario's timeline with fixed traffic character.
#[derive(Debug, Clone)]
pub struct Segment {
    pub name: &'static str,
    pub start_s: f64,
    pub end_s: f64,
    /// Objects visible per camera during this segment (a prefix of the
    /// camera's object pool, so identities persist across segments).
    pub density: usize,
    /// Frame-rate multiplier on the scenario's nominal fps (rush hours
    /// re-capture faster; quiet nights throttle down).
    pub arrival_mult: f64,
}

/// A camera offline window: no frames are emitted (and no ground truth
/// scored), but the world keeps moving underneath.
#[derive(Debug, Clone)]
pub struct Dropout {
    pub camera: usize,
    pub from_s: f64,
    pub to_s: f64,
}

/// A named traffic regime: cameras, nominal frame rate, a segment
/// timeline tiling `[0, horizon_s)`, and optional dropout windows.
#[derive(Debug, Clone)]
pub struct Scenario {
    pub name: &'static str,
    pub cameras: usize,
    /// Nominal frames per second per camera (scaled per segment).
    pub fps: f64,
    pub horizon_s: f64,
    pub segments: Vec<Segment>,
    pub dropouts: Vec<Dropout>,
}

impl Scenario {
    /// The segment covering time `t` (the last one covers the tail, so a
    /// jittered emission landing exactly on the horizon still resolves).
    pub fn segment_at(&self, t: f64) -> (usize, &Segment) {
        let i = self
            .segments
            .iter()
            .position(|s| t >= s.start_s && t < s.end_s)
            .unwrap_or(self.segments.len() - 1);
        (i, &self.segments[i])
    }

    /// Is `camera` inside a dropout window at time `t`?
    pub fn dropped(&self, camera: usize, t: f64) -> bool {
        self.dropouts.iter().any(|d| d.camera == camera && t >= d.from_s && t < d.to_s)
    }

    /// The scenario with every segment's arrival rate multiplied by
    /// `factor` — how the benches induce 2× overload without touching
    /// the world (ground truth per frame is unchanged; there are just
    /// more frames).
    pub fn scaled(&self, factor: f64) -> Scenario {
        let mut s = self.clone();
        for seg in &mut s.segments {
            seg.arrival_mult *= factor;
        }
        s
    }

    /// Peak objects any segment shows — the camera pool size.
    fn pool_size(&self) -> usize {
        self.segments.iter().map(|s| s.density).max().unwrap_or(0)
    }

    fn check(&self) {
        assert!(self.cameras > 0 && self.fps > 0.0 && self.horizon_s > 0.0);
        assert!(!self.segments.is_empty(), "scenario needs at least one segment");
        assert_eq!(self.segments[0].start_s, 0.0, "segments must start at t=0");
        for w in self.segments.windows(2) {
            assert_eq!(w[0].end_s, w[1].start_s, "segments must tile the timeline");
        }
        assert!(
            self.segments.last().unwrap().end_s >= self.horizon_s,
            "segments must cover the horizon"
        );
        for s in &self.segments {
            assert!(s.end_s > s.start_s && s.arrival_mult > 0.0);
        }
    }
}

fn seg(name: &'static str, start_s: f64, end_s: f64, density: usize, arrival_mult: f64) -> Segment {
    Segment { name, start_s, end_s, density, arrival_mult }
}

/// The named traffic regimes the CLI, benches and tests draw from.
#[derive(Debug, Clone)]
pub struct ScenarioCatalog {
    scenarios: Vec<Scenario>,
}

impl ScenarioCatalog {
    /// The standard five regimes.
    pub fn standard() -> Self {
        let scenarios = vec![
            Scenario {
                name: "steady-day",
                cameras: 4,
                fps: 10.0,
                horizon_s: 8.0,
                segments: vec![seg("day", 0.0, 8.0, 3, 1.0)],
                dropouts: vec![],
            },
            Scenario {
                name: "day-night",
                cameras: 4,
                fps: 10.0,
                horizon_s: 12.0,
                segments: vec![seg("day", 0.0, 6.0, 4, 1.0), seg("night", 6.0, 12.0, 1, 0.6)],
                dropouts: vec![],
            },
            Scenario {
                name: "rush-hour",
                cameras: 4,
                fps: 10.0,
                horizon_s: 12.0,
                segments: vec![
                    seg("calm", 0.0, 4.0, 2, 0.8),
                    seg("ramp", 4.0, 8.0, 4, 1.6),
                    seg("peak", 8.0, 12.0, 5, 2.2),
                ],
                dropouts: vec![],
            },
            Scenario {
                name: "incident",
                cameras: 4,
                fps: 10.0,
                horizon_s: 12.0,
                segments: vec![
                    seg("normal", 0.0, 5.0, 2, 1.0),
                    seg("incident", 5.0, 8.0, 6, 2.5),
                    seg("recovery", 8.0, 12.0, 3, 1.2),
                ],
                dropouts: vec![],
            },
            Scenario {
                name: "dropout",
                cameras: 4,
                fps: 10.0,
                horizon_s: 10.0,
                segments: vec![seg("steady", 0.0, 10.0, 3, 1.0)],
                dropouts: vec![
                    Dropout { camera: 1, from_s: 3.0, to_s: 5.0 },
                    Dropout { camera: 2, from_s: 6.0, to_s: 8.0 },
                ],
            },
        ];
        Self { scenarios }
    }

    pub fn names(&self) -> Vec<&'static str> {
        self.scenarios.iter().map(|s| s.name).collect()
    }

    pub fn get(&self, name: &str) -> Option<&Scenario> {
        self.scenarios.iter().find(|s| s.name == name)
    }

    pub fn all(&self) -> &[Scenario] {
        &self.scenarios
    }
}

/// The calibrated overhead camera for `cam`: the [0,1]² image maps to a
/// 16 m × 16 m ground patch, cameras 20 m apart along the road — so
/// world coordinates are unambiguous per camera and the GM-PHD gate
/// (meters) is physically meaningful.
pub fn camera_homography(cam: usize) -> Homography {
    Homography::scale_offset(16.0, 16.0, cam as f64 * 20.0, 0.0)
}

/// One object of a camera's pool: constant velocity, bouncing inside
/// the frame.
#[derive(Debug, Clone, Copy)]
struct WorldObject {
    class: usize,
    /// Radius, fraction of canvas.
    r: f64,
    intensity: f64,
    x0: f64,
    y0: f64,
    /// Canvas fractions per second.
    vx: f64,
    vy: f64,
}

/// Triangle-wave reflection of `p` into `[lo, hi]` — the closed-form
/// "bounce off the walls" so positions need no per-step state.
fn reflect(p: f64, lo: f64, hi: f64) -> f64 {
    let w = hi - lo;
    if w <= 0.0 {
        return lo;
    }
    let m = (p - lo).rem_euclid(2.0 * w);
    if m < w {
        lo + m
    } else {
        lo + 2.0 * w - m
    }
}

impl WorldObject {
    fn at(&self, t: f64) -> SceneObject {
        // Keep whole objects in frame (the margin render_scene uses).
        let lo = self.r + 0.02;
        let hi = 1.0 - self.r - 0.02;
        SceneObject {
            class: self.class,
            cx: reflect(self.x0 + self.vx * t, lo, hi),
            cy: reflect(self.y0 + self.vy * t, lo, hi),
            r: self.r,
            intensity: self.intensity,
        }
    }
}

/// Exact ground truth of one emitted frame. `frames[i]` describes
/// `trace[i]` (request ids are the post-sort positions, so outcome `id`
/// indexes both).
#[derive(Debug, Clone)]
pub struct FrameTruth {
    pub camera: usize,
    pub t_s: f64,
    /// Per-camera frame counter (the synthetic detector's RNG stream id).
    pub frame_idx: usize,
    /// Index into the scenario's segment list.
    pub segment: usize,
    pub truths: Vec<GroundTruth>,
}

/// A generated scenario workload: the request trace (sorted by arrival,
/// ids = positions — the shape every serving driver expects) plus the
/// parallel per-frame ground truth.
#[derive(Debug, Clone)]
pub struct ScenarioWorkload {
    pub scenario: Scenario,
    pub seed: u64,
    pub trace: Vec<Request>,
    pub frames: Vec<FrameTruth>,
    /// Per-camera object pools (for on-demand frame rendering).
    worlds: Vec<Vec<WorldObject>>,
}

fn cam_seed(seed: u64, cam: usize) -> u64 {
    seed ^ (cam as u64 + 1).wrapping_mul(0x9E37_79B9_7F4A_7C15)
}

impl ScenarioWorkload {
    /// Generate the workload for `(scenario, seed)`. Each camera draws
    /// its object pool and emission jitter from its own RNG stream, so
    /// adding a camera never perturbs the others.
    pub fn generate(scenario: &Scenario, seed: u64) -> ScenarioWorkload {
        scenario.check();
        let pool_size = scenario.pool_size();
        let period = 1.0 / scenario.fps;
        let mut trace: Vec<Request> = Vec::new();
        let mut frames: Vec<FrameTruth> = Vec::new();
        let mut worlds: Vec<Vec<WorldObject>> = Vec::new();
        for cam in 0..scenario.cameras {
            let mut rng = Rng::new(cam_seed(seed, cam));
            let world: Vec<WorldObject> = (0..pool_size)
                .map(|_| WorldObject {
                    class: rng.below(CLASS_NAMES.len()),
                    r: rng.range_f64(0.05, 0.11),
                    intensity: rng.range_f64(0.6, 0.9),
                    x0: rng.f64(),
                    y0: rng.f64(),
                    vx: rng.range_f64(-0.08, 0.08),
                    vy: rng.range_f64(-0.08, 0.08),
                })
                .collect();
            let mut t = rng.f64() * period; // phase offset
            let mut frame_idx = 0usize;
            while t < scenario.horizon_s {
                let (seg_i, segment) = scenario.segment_at(t);
                // The jitter draw happens every step — dropped frames
                // included — so a dropout changes *which* frames exist,
                // never the timing of later ones.
                let jitter = rng.range_f64(0.95, 1.05);
                if !scenario.dropped(cam, t) {
                    let truths: Vec<GroundTruth> = world[..segment.density]
                        .iter()
                        .map(|o| {
                            let s = o.at(t);
                            GroundTruth {
                                bbox: BBox::new(
                                    s.cx as f32,
                                    s.cy as f32,
                                    (2.0 * s.r) as f32,
                                    (2.0 * s.r) as f32,
                                ),
                                class: s.class,
                            }
                        })
                        .collect();
                    trace.push(Request {
                        id: 0,
                        camera: cam,
                        arrival_s: t,
                        objects: truths.len(),
                        class: SloClass::Standard,
                        rung: 0,
                        retries: 0,
                    });
                    frames.push(FrameTruth { camera: cam, t_s: t, frame_idx, segment: seg_i, truths });
                    frame_idx += 1;
                }
                t += period / segment.arrival_mult * jitter;
            }
            worlds.push(world);
        }
        // Sort trace and frames together by (arrival, camera) and stamp
        // ids as positions — the multi_camera_trace contract.
        let mut order: Vec<usize> = (0..trace.len()).collect();
        order.sort_by(|&a, &b| {
            trace[a]
                .arrival_s
                .partial_cmp(&trace[b].arrival_s)
                .unwrap()
                .then(trace[a].camera.cmp(&trace[b].camera))
        });
        let mut sorted_trace = Vec::with_capacity(trace.len());
        let mut sorted_frames = Vec::with_capacity(frames.len());
        for (id, &i) in order.iter().enumerate() {
            let mut r = trace[i].clone();
            r.id = id as u64;
            sorted_trace.push(r);
            sorted_frames.push(frames[i].clone());
        }
        ScenarioWorkload {
            scenario: scenario.clone(),
            seed,
            trace: sorted_trace,
            frames: sorted_frames,
            worlds,
        }
    }

    /// The scene objects camera `cam` sees at time `t` (world positions,
    /// segment-gated density).
    pub fn objects_at(&self, cam: usize, t: f64) -> Vec<SceneObject> {
        let (_, segment) = self.scenario.segment_at(t);
        self.worlds[cam][..segment.density].iter().map(|o| o.at(t)).collect()
    }

    /// Render frame `i` as an actual image (deterministic per-frame
    /// background noise) — what `examples/traffic_scenario.rs` feeds the
    /// real CNN. The fleet drivers never render; they only need the
    /// ground truth.
    pub fn render_frame(&self, i: usize, cfg: &SceneConfig) -> Scene {
        let f = &self.frames[i];
        let objs = self.objects_at(f.camera, f.t_s);
        let mut rng = Rng::new(
            self.seed
                ^ 0xD1B5_4A32_D192_ED03
                ^ (f.camera as u64).wrapping_mul(0x9E37_79B9_7F4A_7C15)
                ^ (f.frame_idx as u64).wrapping_mul(0x94D0_49BB_1331_11EB),
        );
        render_objects(cfg, &objs, &mut rng)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn reflect_stays_in_bounds_and_bounces() {
        for i in 0..200 {
            let p = -3.0 + i as f64 * 0.05;
            let r = reflect(p, 0.1, 0.9);
            assert!((0.1..=0.9).contains(&r), "reflect({p}) = {r}");
        }
        // Inside the band it is the identity.
        assert!((reflect(0.5, 0.1, 0.9) - 0.5).abs() < 1e-12);
        // Just past the wall it comes back by the overshoot.
        assert!((reflect(0.95, 0.1, 0.9) - 0.85).abs() < 1e-12);
    }

    #[test]
    fn catalog_scenarios_are_well_formed() {
        let cat = ScenarioCatalog::standard();
        assert_eq!(cat.names().len(), 5);
        for s in cat.all() {
            s.check();
            assert!(cat.get(s.name).is_some());
        }
        assert!(cat.get("no-such-scenario").is_none());
    }

    #[test]
    fn workload_is_deterministic_and_sorted() {
        let cat = ScenarioCatalog::standard();
        let s = cat.get("rush-hour").unwrap();
        let a = ScenarioWorkload::generate(s, 7);
        let b = ScenarioWorkload::generate(s, 7);
        assert_eq!(a.trace, b.trace);
        assert_eq!(a.trace.len(), a.frames.len());
        assert!(a.trace.windows(2).all(|w| w[0].arrival_s <= w[1].arrival_s));
        assert!(a.trace.iter().enumerate().all(|(i, r)| r.id == i as u64));
        // Frames stay parallel to the trace after the sort.
        for (r, f) in a.trace.iter().zip(&a.frames) {
            assert_eq!(r.camera, f.camera);
            assert_eq!(r.arrival_s, f.t_s);
            assert_eq!(r.objects, f.truths.len());
        }
        let c = ScenarioWorkload::generate(s, 8);
        assert_ne!(a.trace, c.trace, "seed must matter");
    }

    #[test]
    fn densities_follow_segments_and_scaling_multiplies_rate() {
        let cat = ScenarioCatalog::standard();
        let s = cat.get("day-night").unwrap();
        let w = ScenarioWorkload::generate(s, 3);
        for f in &w.frames {
            let expected = s.segments[f.segment].density;
            assert_eq!(f.truths.len(), expected, "frame at t={}", f.t_s);
        }
        // Night frames exist and are sparser.
        assert!(w.frames.iter().any(|f| f.segment == 1));
        let doubled = ScenarioWorkload::generate(&s.scaled(2.0), 3);
        let ratio = doubled.trace.len() as f64 / w.trace.len() as f64;
        assert!((1.7..=2.3).contains(&ratio), "2× scaling gave ratio {ratio}");
    }

    #[test]
    fn dropout_silences_camera_but_world_keeps_moving() {
        let cat = ScenarioCatalog::standard();
        let s = cat.get("dropout").unwrap();
        let w = ScenarioWorkload::generate(s, 5);
        assert!(!w
            .frames
            .iter()
            .any(|f| f.camera == 1 && (3.0..5.0).contains(&f.t_s)), "camera 1 must be silent");
        assert!(w.frames.iter().any(|f| f.camera == 1 && f.t_s >= 5.0), "and must rejoin");
        // Positions differ across the gap: the world moved while the
        // camera was dark (objects move up to 0.16 canvas in 2 s).
        let before = w.frames.iter().filter(|f| f.camera == 1 && f.t_s < 3.0).last().unwrap();
        let after = w.frames.iter().find(|f| f.camera == 1 && f.t_s >= 5.0).unwrap();
        let moved = before
            .truths
            .iter()
            .zip(&after.truths)
            .any(|(a, b)| (a.bbox.cx - b.bbox.cx).abs() + (a.bbox.cy - b.bbox.cy).abs() > 0.02);
        assert!(moved, "objects should have moved across the dropout");
    }

    #[test]
    fn rendered_frame_matches_its_ground_truth() {
        let cat = ScenarioCatalog::standard();
        let s = cat.get("steady-day").unwrap();
        let w = ScenarioWorkload::generate(s, 11);
        let cfg = SceneConfig { noise: 0.0, ..Default::default() };
        let scene = w.render_frame(0, &cfg);
        assert_eq!(scene.truths.len(), w.frames[0].truths.len());
        for (a, b) in scene.truths.iter().zip(&w.frames[0].truths) {
            assert_eq!(a.class, b.class);
            // Rendered truth is quantized through pixel space; stays
            // within a pixel of the analytic truth.
            assert!((a.bbox.cx - b.bbox.cx).abs() < 0.01);
        }
    }
}
