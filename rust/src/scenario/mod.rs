//! Traffic-monitoring scenarios: scene-driven workloads with exact
//! ground truth, per-camera GM-PHD tracking on fleet completions, and
//! accuracy-in-the-loop reporting.
//!
//! This subsystem closes the loop the paper's Section VI system sketches:
//! simulated cameras observe a deterministic world of moving objects
//! ([`catalog`]), every frame becomes a detection [`Request`] into the
//! serving fleet (DES or live threads), and what the fleet *served* is
//! scored against what the world *contained* ([`pipeline`]) — so load
//! shedding stops being an abstract counter and becomes measurable
//! tracking-accuracy loss:
//!
//! - [`catalog`] — named, seedable traffic regimes ([`ScenarioCatalog`]):
//!   day/night density shifts, rush-hour arrival ramps, incident bursts,
//!   camera dropout/rejoin. [`ScenarioWorkload::generate`] turns a
//!   [`Scenario`] into a sorted request trace plus per-frame exact ground
//!   truth; frames render on demand through
//!   [`crate::dataset::scenes::render_objects`].
//! - [`pipeline`] — replays fleet [`RequestOutcome`]s against the ground
//!   truth: completed frames run the synthetic detector head +
//!   [`crate::postproc::nms`], project through
//!   [`crate::tracking::Homography`] into world coordinates and update a
//!   per-camera [`crate::tracking::GmPhd`] filter; shed frames are missed
//!   measurements (the filter steps with no detections). The result is a
//!   [`ScenarioReport`](crate::serving::metrics::ScenarioReport) —
//!   COCO-style mAP vs the offline ceiling, track continuity /
//!   fragmentation, per-regime breakdowns — attached to the run's
//!   [`FleetReport`](crate::serving::FleetReport).
//!
//! Everything is a pure function of `(scenario, seed)` and the fleet's
//! shed decisions: with zero shedding the served mAP equals the offline
//! detector baseline *bit-exactly*, and the DES and live drivers produce
//! identical reports in virtual-clock mode (`tests/scenario_accuracy.rs`).
//!
//! [`Request`]: crate::serving::Request
//! [`RequestOutcome`]: crate::serving::RequestOutcome

pub mod catalog;
pub mod pipeline;

pub use catalog::{
    camera_homography, Dropout, FrameTruth, Scenario, ScenarioCatalog, ScenarioWorkload, Segment,
};
pub use pipeline::{
    evaluate_scenario, evaluate_scenario_with, run_scenario_autoscaled, run_scenario_des,
    run_scenario_live,
};
