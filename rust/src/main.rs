//! `repro` — CLI for the gemmini-edge reproduction.
//!
//! Subcommands (hand-rolled arg parsing; clap is unavailable offline):
//!
//! ```text
//! repro report table2|table3          print paper tables from the models
//! repro deploy [--size N] [--trials K]  run the full workflow on the detector
//! repro infer [--hlo PATH]            run the AOT artifact on a scene (PJRT)
//! repro tune [--size N] [--variant base|p40|p88] [--trials K]
//!            [--tuning-cache PATH] [--threads N]
//!            [--transfer] [--transfer-audit]
//! repro fleet [--cameras N] [--fps F] [--batch B] [--wait MS] [--seconds S]
//!             [--autoscale] [--policy util|slo] [--max-devices N]
//!             [--epoch S] [--delay S] [--closed K] [--tuning-cache PATH]
//!             [--hetero] [--classes] [--quota FPS] [--ladder]
//!             [--live] [--live-threads N] [--time-scale F] [--virtual-clock]
//!             [--faults demo|SPEC] [--parallel N] [--threads N]
//!             [--transfer] [--transfer-audit]
//! repro scenario [--list] [--name NAME] [--seed S] [--load F]
//!                [--autoscale] [--max-devices N] [--tuning-cache PATH] [--ladder]
//!                [--live] [--live-threads N] [--time-scale F] [--virtual-clock]
//!                [--faults demo|SPEC] [--transfer] [--transfer-audit]
//! ```
//!
//! `repro fleet --autoscale` runs the same fleet behind the closed-loop
//! autoscaler (`serving::autoscale`): the pool starts at the two paper
//! boards and grows/shrinks ZCU102 replicas between DES epochs; when
//! `--batch B` is ≥ 2 the replicas use batch-aware schedule tuning
//! (`scheduler::tune_graph_batch`). `--closed K` switches the cameras to
//! the closed-loop client model with a window of K outstanding frames.
//!
//! `--hetero` (with `--autoscale`) provisions from a heterogeneous
//! device catalog instead of identical replicas: tuned ZCU102/ZCU111
//! builds, the original 16×16 Gemmini config, and an embedded-GPU
//! baseline, each stamped with capacity, power and J/frame. Every grow
//! picks the lowest-power device predicted to restore the SLO
//! (`serving::DeviceCatalog`), and scale-in drains the most expensive
//! device first. `--classes` assigns each camera an SLO class
//! (interactive / standard / batchable, cycling by camera index): class
//! travels through admission (class-aware shedding), batching (scaled
//! wait deadlines) and the report (per-class p50/p95/p99, violations).
//! The fleet table always ends with the energy ledger — joules per
//! epoch per device state and fleet-wide GOP/s/W.
//!
//! `--live` serves the trace on the *real threaded runtime*
//! (`serving::live`) instead of the DES: one worker thread per board
//! consuming a bounded `pipeline` topic, wall-clock batching, and a
//! drain-to-retire shutdown — the same `FleetReport`/table comes out
//! the other end. `--time-scale F` maps modeled seconds to wall seconds
//! (0.25 runs a 10 s trace in ~2.5 s), `--live-threads N` multiplexes
//! the shards onto N OS threads, and `--virtual-clock` swaps the wall
//! clock for the deterministic turn-based clock the differential tests
//! use (reports become byte-reproducible). `--quota FPS` puts per-class
//! admission token buckets (FPS tokens/s per class) in front of the
//! queues on either path.
//!
//! `--ladder` (on `fleet` and `scenario`) arms the graceful-degradation
//! ladder (`serving::ladder`): each device carries full / pruned-40 /
//! pruned-88-reduced-input variants of the detector, each tuned through
//! the shared cache-backed engine, and admission steps new requests
//! down the ladder as queue pressure rises *before* any shed decision.
//! The fleet table gains per-variant serve counts and a fleet-level
//! effective accuracy (sheds score zero); on `repro scenario` each
//! degraded frame is scored by that rung's own calibrated detector
//! head, so the scenario mAP reflects what was actually served.
//! `--ladder` and `--quota` are mutually exclusive (the ladder wins).
//!
//! `repro scenario` runs a named traffic regime from the scenario
//! catalog (`scenario::ScenarioCatalog`, `--list` prints them) through
//! the fleet with accuracy in the loop: every completed frame runs the
//! synthetic detector head + NMS, projects into world coordinates and
//! updates that camera's GM-PHD tracker; every shed frame is a missed
//! measurement. The fleet table gains a scenario section — COCO-style
//! mAP vs the zero-shed offline ceiling, track continuity/fragmentation,
//! cardinality error, and a per-regime breakdown. `--load F` multiplies
//! every segment's arrival rate (2.0 = double pressure, same world), and
//! the `--autoscale` / `--live` / `--virtual-clock` switches mean what
//! they mean on `repro fleet`.
//!
//! `--faults` (on `fleet` and `scenario`) arms the chaos plan
//! (`serving::faults`): `--faults demo` injects the canned demo schedule
//! (one crash, one slowdown window, mild spikes and link drops, recovery
//! on); `--faults SPEC` builds a custom [`FaultPlan`] from comma-separated
//! tokens — `crash=DEV@T`, `slow=DEV@FROM..TO*F`, `spikes=P*F`,
//! `drops=P`, `seed=N`, `recover=on|off`, `timeout=S`, `budget=N`,
//! `backoff=S`, `deadline=S`, `reboot=S|off`. The DES and the live
//! runtime inject the same plan identically; the fleet table gains the
//! fault/recovery accounting rows (crashes, detections, re-dispatches,
//! suppressed duplicates, expirations, MTTR, availability).
//!
//! `repro fleet --parallel N` runs the open-loop DES epoch-sharded
//! across N independent sub-fleets (`serving::sim::simulate_parallel`):
//! cameras and devices are dealt round-robin, each shard runs on its own
//! worker (`--threads` caps the OS threads), and the merged report is
//! byte-deterministic — independent of the thread count. Incompatible
//! with `--faults`/`--quota` (global front-door state couples shards).
//!
//! `repro tune --threads N` pins the engine's worker-thread count (the
//! tuned result is byte-identical at any N); the JSON report carries the
//! engine's work accounting under `"engine_stats"`.
//!
//! `--tuning-cache PATH` (on `tune` and `fleet`) loads/saves the
//! persistent schedule-tuning cache (`scheduler::cache`): the first run
//! writes an AutoTVM-log-style JSON file, repeated runs warm-start from
//! it and skip the cycle-simulator measurements entirely. Entries are
//! keyed by the accelerator-config fingerprint, so editing the config
//! invalidates stale entries automatically.
//!
//! `--transfer` (on `tune`, `fleet` and `scenario`) arms transfer
//! tuning (`scheduler::prefilter` + `TuningEngine::with_transfer`):
//! cold layers whose cache lookup misses but that have a tuned
//! m-neighbor or sibling-config donor measure a two-candidate
//! shortlist — the donor's winner plus the analytical pre-filter's top
//! pick — instead of the full top-k search. `--transfer-audit` (implies
//! `--transfer`) additionally re-runs the reference full search per
//! seeded layer to score the ranker hit-rate in the engine table.

use gemmini_edge::coordinator::{deploy, DeployOptions};
use gemmini_edge::dataset::detector::{build_detector, default_weights};
use gemmini_edge::dataset::scenes::{validation_set, SceneConfig};
use gemmini_edge::gemmini::config::GemminiConfig;
use gemmini_edge::ir::interp::Value;
use gemmini_edge::postproc::nms::{decode_and_nms, NmsConfig};
use gemmini_edge::report;
use gemmini_edge::runtime::Executor;
use gemmini_edge::scheduler::{TuningCache, TuningEngine};
use gemmini_edge::workload::{yolov7_tiny, ModelVariant};

fn arg_val(args: &[String], key: &str) -> Option<String> {
    args.iter().position(|a| a == key).and_then(|i| args.get(i + 1).cloned())
}

/// Build a tuning engine, warm-started from `--tuning-cache` when given,
/// with transfer tuning / auditing armed by `--transfer` /
/// `--transfer-audit` (see `scheduler::prefilter` and
/// `TuningEngine::with_transfer`).
fn engine_with_cache(cfg: GemminiConfig, args: &[String]) -> TuningEngine {
    let cache_path = arg_val(args, "--tuning-cache");
    let audit = args.iter().any(|a| a == "--transfer-audit");
    let transfer = audit || args.iter().any(|a| a == "--transfer");
    let mut engine =
        TuningEngine::new(cfg).with_transfer(transfer).with_transfer_audit(audit);
    if let Some(path) = cache_path.as_ref() {
        let cache = TuningCache::load(path);
        if !cache.is_empty() {
            eprintln!(
                "tuning cache: {} layer + {} move entries from {path}",
                cache.layer_entries(),
                cache.move_entries()
            );
        }
        engine = engine.with_cache(cache);
    }
    engine
}

/// Persist the cache (if file-backed) and print the engine's work
/// accounting for *every* tuning call of the run (replica tunings
/// included), via the shared renderer so the format lives in one place.
fn finish_engine(engine: &TuningEngine) {
    if let Err(e) = engine.save_cache() {
        eprintln!("warning: could not write tuning cache: {e}");
    }
    eprintln!("tuning engine:");
    eprint!("{}", report::tuning_engine_table(&engine.total_stats()));
}

fn main() -> Result<(), Box<dyn std::error::Error>> {
    let args: Vec<String> = std::env::args().skip(1).collect();
    match args.first().map(String::as_str) {
        Some("report") => match args.get(1).map(String::as_str) {
            Some("table2") => {
                print!("{}", report::table2(&gemmini_edge::fpga::resources::table2_rows()));
            }
            Some("table3") => {
                print!(
                    "{}",
                    report::table3(
                        &GemminiConfig::original_zcu102(),
                        &GemminiConfig::ours_zcu102()
                    )
                );
            }
            _ => eprintln!("usage: repro report table2|table3"),
        },
        Some("deploy") => {
            let size: usize =
                arg_val(&args, "--size").and_then(|v| v.parse().ok()).unwrap_or(96);
            let trials: usize =
                arg_val(&args, "--trials").and_then(|v| v.parse().ok()).unwrap_or(4);
            let w = default_weights();
            let g = build_detector(size, &w);
            let scenes = validation_set(&SceneConfig { size, ..Default::default() }, 24, 7);
            let calib: Vec<Vec<Value>> =
                scenes.iter().take(4).map(|s| vec![s.image.clone()]).collect();
            let opts = DeployOptions { measure_k: trials, ..Default::default() };
            let r = deploy(&g, &calib, &scenes, &opts);
            println!("deployed detector @{size}px");
            println!("  mAP@0.5           : {:.3}", r.map.unwrap_or(0.0));
            println!("  latency (tuned)   : {:.3} ms ({:.1} FPS)", r.latency_s * 1e3, r.fps());
            println!("  latency (default) : {:.3} ms", r.default_latency_s * 1e3);
            println!("  energy            : {:.4} J ({:.1} GOP/s/W)", r.energy.energy_j, r.energy.efficiency());
            for p in &r.placements {
                println!("  placement {:<18}: {:.3} ms", p.label(), p.total_s() * 1e3);
            }
        }
        Some("infer") => {
            let hlo = arg_val(&args, "--hlo").unwrap_or_else(|| "artifacts/model.hlo.txt".into());
            let exe = Executor::load(&hlo)?;
            let size = exe.meta.input_shape[1];
            let scenes = validation_set(&SceneConfig { size, ..Default::default() }, 1, 99);
            let t0 = std::time::Instant::now();
            let head = exe.run(&scenes[0].image)?;
            let dt = t0.elapsed();
            // Decode via the IR op semantics (single-scale head).
            let g = {
                let mut b = gemmini_edge::ir::GraphBuilder::new("decode");
                let x = b.input("head", head.shape.clone());
                let d = b.box_decode(x, exe.meta.num_anchors, exe.meta.num_classes);
                b.finish(&[d])
            };
            let boxes = gemmini_edge::ir::Interpreter::new(&g).run(&[head]);
            let dets = decode_and_nms(&boxes[0].f, exe.meta.num_classes, &NmsConfig::default());
            println!("PJRT inference: {:.2} ms, {} detections", dt.as_secs_f64() * 1e3, dets.len());
            for d in dets.iter().take(8) {
                println!("  class {} score {:.2} at ({:.2},{:.2})", d.class, d.score, d.bbox.cx, d.bbox.cy);
            }
            println!("ground truth: {} objects", scenes[0].truths.len());
        }
        Some("tune") => {
            let size: usize =
                arg_val(&args, "--size").and_then(|v| v.parse().ok()).unwrap_or(160);
            let trials: usize =
                arg_val(&args, "--trials").and_then(|v| v.parse().ok()).unwrap_or(4);
            let variant = match arg_val(&args, "--variant").as_deref() {
                Some("p40") => ModelVariant::Pruned40,
                Some("p88") => ModelVariant::Pruned88,
                _ => ModelVariant::Base,
            };
            let mut g = yolov7_tiny(size, variant, 80);
            gemmini_edge::passes::replace_activations(&mut g);
            let cfg = GemminiConfig::ours_zcu102();
            let mut engine = engine_with_cache(cfg.clone(), &args);
            if let Some(n) = arg_val(&args, "--threads").and_then(|v| v.parse::<usize>().ok()) {
                engine = engine.with_threads(n);
            }
            let t = engine.tune_graph(&g, trials);
            let stats = engine.last_stats();
            finish_engine(&engine);
            let report_json = gemmini_edge::util::json::Json::obj(vec![
                ("tuning", t.to_json()),
                ("engine_stats", stats.to_json()),
            ]);
            println!("{}", report_json.dump());
            println!(
                "# conv improvement {:.1}% | layers improved {:.0}% | latency {:.1} ms",
                t.conv_improvement() * 100.0,
                t.fraction_improved() * 100.0,
                t.latency_s(&cfg, true) * 1e3
            );
        }
        Some("fleet") => {
            use gemmini_edge::baselines::xavier;
            use gemmini_edge::fpga::resources::Board;
            use gemmini_edge::report::{catalog_table, fleet_table};
            use gemmini_edge::serving::device::DEFAULT_DISPATCH_S;
            use gemmini_edge::serving::{
                assign_slo_classes, multi_camera_trace, serve_live, simulate, simulate_autoscaled,
                simulate_autoscaled_hetero, simulate_closed_loop, simulate_closed_loop_autoscaled,
                simulate_closed_loop_autoscaled_hetero, simulate_parallel, AdmissionPolicy,
                AutoscaleConfig,
                Autoscaler, Backend, BaselineDevice, BatchPolicy, ClassQuota, ClockMode,
                ClosedLoopConfig, DeviceCatalog, DrainOrder, FaultPlan, GemminiDevice, LiveConfig,
                ShardPool, ShedPolicy, SimConfig, SloTracking, TargetUtilization, VariantLadder,
            };
            let cameras: usize =
                arg_val(&args, "--cameras").and_then(|v| v.parse().ok()).unwrap_or(24);
            let fps: f64 = arg_val(&args, "--fps").and_then(|v| v.parse().ok()).unwrap_or(30.0);
            let batch: usize =
                arg_val(&args, "--batch").and_then(|v| v.parse().ok()).unwrap_or(8);
            let wait_ms: f64 =
                arg_val(&args, "--wait").and_then(|v| v.parse().ok()).unwrap_or(15.0);
            let seconds: f64 =
                arg_val(&args, "--seconds").and_then(|v| v.parse().ok()).unwrap_or(10.0);
            let autoscale = args.iter().any(|a| a == "--autoscale");
            let policy = arg_val(&args, "--policy").unwrap_or_else(|| "util".into());
            let max_devices: usize =
                arg_val(&args, "--max-devices").and_then(|v| v.parse().ok()).unwrap_or(8);
            let epoch_s: f64 = arg_val(&args, "--epoch")
                .and_then(|v| v.parse().ok())
                .unwrap_or(0.5)
                .max(0.05);
            let delay_s: f64 = arg_val(&args, "--delay")
                .and_then(|v| v.parse().ok())
                .unwrap_or(1.0)
                .max(0.0);
            let closed: Option<usize> = arg_val(&args, "--closed").and_then(|v| v.parse().ok());
            let parallel: usize =
                arg_val(&args, "--parallel").and_then(|v| v.parse().ok()).unwrap_or(1);
            let par_threads: usize =
                arg_val(&args, "--threads").and_then(|v| v.parse().ok()).unwrap_or(0);
            let hetero = args.iter().any(|a| a == "--hetero");
            if hetero && !autoscale {
                eprintln!("warning: --hetero only affects scale-out; pass --autoscale too (ignoring --hetero)");
            }
            let hetero = hetero && autoscale;
            let classes = args.iter().any(|a| a == "--classes");
            let live = args.iter().any(|a| a == "--live");
            if live && (autoscale || closed.is_some()) {
                eprintln!(
                    "warning: --live serves open-loop traces on a fixed pool; \
                     ignoring --autoscale/--closed"
                );
            }
            let autoscale = autoscale && !live;
            let hetero = hetero && !live;
            let closed = if live { None } else { closed };
            let live_threads: usize =
                arg_val(&args, "--live-threads").and_then(|v| v.parse().ok()).unwrap_or(0);
            let time_scale: f64 = arg_val(&args, "--time-scale")
                .and_then(|v| v.parse().ok())
                .unwrap_or(1.0)
                .max(1e-3);
            let virtual_clock = args.iter().any(|a| a == "--virtual-clock");
            let faults = arg_val(&args, "--faults").and_then(|spec| {
                let plan = if spec == "demo" {
                    Ok(FaultPlan::demo(20240710, seconds))
                } else {
                    FaultPlan::parse(&spec, 20240710)
                };
                match plan {
                    Ok(p) => Some(p),
                    Err(err) => {
                        eprintln!("warning: bad --faults spec ({err}); running fault-free");
                        None
                    }
                }
            });
            let quota: Option<f64> = arg_val(&args, "--quota").and_then(|v| v.parse().ok());
            if let Some(r) = quota {
                if !r.is_finite() || r <= 0.0 {
                    eprintln!("warning: --quota wants a positive FPS value (ignoring {r})");
                }
            }
            let quota = quota.filter(|r| r.is_finite() && *r > 0.0);
            let ladder = args.iter().any(|a| a == "--ladder");
            if ladder && quota.is_some() {
                eprintln!("warning: --ladder and --quota are mutually exclusive (using the ladder)");
            }
            let quota = if ladder { None } else { quota };

            // Tune the detector through the shared engine: repeated
            // geometries, autoscaled replicas and (with --tuning-cache)
            // repeated `repro fleet` invocations all reuse one search.
            let mut g = build_detector(96, &default_weights());
            gemmini_edge::passes::replace_activations(&mut g);
            // A heterogeneous catalog needs the original config tuned
            // too. That runs through its own cache-backed engine (one
            // cache file serves both fingerprints) and saves *before*
            // the main engine loads, so `--tuning-cache` warm-starts
            // both configs on the next run.
            let t_orig = hetero.then(|| {
                let mut e = engine_with_cache(GemminiConfig::original_zcu102(), &args);
                let t = e.tune_graph(&g, 2);
                if let Err(err) = e.save_cache() {
                    eprintln!("warning: could not write tuning cache: {err}");
                }
                t
            });
            let mut engine = engine_with_cache(GemminiConfig::ours_zcu102(), &args);
            let tuning = engine.tune_graph(&g, 2);
            // The degradation ladder tunes the pruned variants through
            // the same engine, so replicas (and repeated runs with
            // `--tuning-cache`) are warm hits.
            let rungs = ladder.then(|| VariantLadder::paper_ladder(&mut engine, 96, 2));

            let mut pool = ShardPool::paper_boards(&tuning, DEFAULT_DISPATCH_S);
            pool.register(Box::new(BaselineDevice::new(xavier(), g.gops(), 8)));

            let cfg = SimConfig {
                batch: BatchPolicy::new(batch, wait_ms * 1e-3),
                queue_depth: 64usize.max(batch),
                shed: if classes { ShedPolicy::ClassAware } else { ShedPolicy::DropOldest },
                // The live runtime's workers own their queues (no
                // cross-shard stealing); the DES keeps its default.
                work_stealing: !live,
                admission: match (rungs, quota) {
                    (Some(l), _) => AdmissionPolicy::Degrade(l),
                    (None, Some(r)) => {
                        AdmissionPolicy::ClassQuota(ClassQuota::uniform(r, (r * 0.5).max(8.0)))
                    }
                    (None, None) => AdmissionPolicy::Open,
                },
                faults,
                ..Default::default()
            };
            if let Some(p) = &cfg.faults {
                println!(
                    "fault plan armed: {} crash(es) | {} slowdown window(s) | spikes p={:.2} | link drops p={:.2} | recovery {}",
                    p.crashes.len(),
                    p.slowdowns.len(),
                    p.spike_prob,
                    p.link_drop_prob,
                    if p.recovery.is_some() { "on" } else { "off" }
                );
            }
            let mode = if let Some(k) = closed {
                format!("closed-loop (window {k})")
            } else {
                "open-loop".into()
            };
            println!(
                "fleet: {} devices | {cameras} cameras × {fps:.0} FPS × {seconds:.0} s ({mode}) | batch≤{batch}, wait≤{wait_ms:.0} ms | autoscale: {}{}{}{}",
                pool.len(),
                if autoscale { policy.as_str() } else { "off" },
                if hetero { " (hetero catalog)" } else { "" },
                if classes { " | SLO classes on" } else { "" },
                if live { " | LIVE threaded runtime" } else { "" }
            );
            if ladder {
                println!("degradation ladder armed: full / pruned-40 / pruned-88-small");
            }

            // The open-loop trace is only needed when not closed-loop.
            let trace = if closed.is_none() {
                let scene = SceneConfig { size: 96, ..Default::default() };
                let mut t = multi_camera_trace(&scene, cameras, fps, seconds, 20240710);
                if classes {
                    assign_slo_classes(&mut t);
                }
                t
            } else {
                Vec::new()
            };
            let clients = ClosedLoopConfig {
                cameras,
                max_outstanding: closed.unwrap_or(2).max(1),
                period_s: 1.0 / fps,
                think_s: 0.005,
                horizon_s: seconds,
                seed: 20240710,
                classed: classes,
            };

            let r = if live {
                let lcfg = LiveConfig {
                    threads: live_threads,
                    clock: if virtual_clock { ClockMode::Virtual } else { ClockMode::Wall },
                    time_scale,
                    ..LiveConfig::default()
                };
                println!(
                    "live runtime: {} worker thread(s) | {} clock{}",
                    if live_threads == 0 { pool.len() } else { live_threads.min(pool.len()) },
                    if virtual_clock { "virtual (deterministic)" } else { "wall" },
                    if virtual_clock {
                        String::new()
                    } else {
                        format!(" | time scale {time_scale:.2} wall s per modeled s")
                    }
                );
                serve_live(pool, &trace, &cfg, &lcfg)
            } else if autoscale {
                let acfg = AutoscaleConfig {
                    epoch_s,
                    provision_delay_s: delay_s,
                    min_devices: pool.len(),
                    max_devices: max_devices.max(pool.len()),
                    cooldown_epochs: 1,
                    drain_order: if hetero {
                        DrainOrder::MostExpensiveFirst
                    } else {
                        DrainOrder::NewestFirst
                    },
                };
                let mut auto = if policy == "slo" {
                    Autoscaler::new(acfg, Box::new(SloTracking::new(cfg.slo_s)))
                } else {
                    Autoscaler::new(acfg, Box::new(TargetUtilization::default()))
                };
                if hetero {
                    // The heterogeneous catalog: the tuned paper boards,
                    // the original 16×16 config (slower, cooler), and an
                    // embedded-GPU baseline. Tunings are computed once
                    // (the original's through its own cache-backed
                    // engine, above); replica construction re-labels.
                    let tb = (batch >= 2).then(|| engine.tune_graph_batch(&g, 2, batch));
                    let t_orig = t_orig.expect("tuned before the main engine loaded");
                    let catalog = DeviceCatalog::paper_catalog(
                        batch,
                        &tuning,
                        tb.as_ref(),
                        true,
                        &t_orig,
                        Some(g.gops()),
                        DEFAULT_DISPATCH_S,
                    );
                    print!("{}", catalog_table(&catalog));
                    if closed.is_some() {
                        simulate_closed_loop_autoscaled_hetero(
                            &mut pool, &clients, &cfg, &mut auto, &catalog,
                        )
                    } else {
                        simulate_autoscaled_hetero(&mut pool, &trace, &cfg, &mut auto, &catalog)
                    }
                } else {
                    // Each replica tunes through the shared engine:
                    // replica 0 pays for the batched search once
                    // (batch >= 2), later replicas are pure cache hits.
                    let mut factory = |i: usize| -> Box<dyn Backend> {
                        let label = format!("ZCU102-Gemmini (replica {i})");
                        Box::new(GemminiDevice::from_engine(
                            &label,
                            Board::Zcu102,
                            &mut engine,
                            &g,
                            2,
                            batch,
                            DEFAULT_DISPATCH_S,
                        ))
                    };
                    if closed.is_some() {
                        simulate_closed_loop_autoscaled(
                            &mut pool,
                            &clients,
                            &cfg,
                            &mut auto,
                            &mut factory,
                        )
                    } else {
                        simulate_autoscaled(&mut pool, &trace, &cfg, &mut auto, &mut factory)
                    }
                }
            } else if closed.is_some() {
                simulate_closed_loop(&mut pool, &clients, &cfg)
            } else if parallel > 1 {
                // Epoch-sharded parallel DES: cameras and devices are
                // dealt across independent sub-fleets. Sharding needs a
                // front door without global state (fault schedules and
                // class quotas couple shards).
                let shards = parallel.min(pool.len());
                if shards < parallel {
                    eprintln!(
                        "warning: --parallel {parallel} clamped to {shards} (one device per shard minimum)"
                    );
                }
                if cfg.faults.is_some() || quota.is_some() {
                    eprintln!(
                        "warning: --parallel is incompatible with --faults/--quota; running serially"
                    );
                    simulate(&mut pool, &trace, &cfg)
                } else {
                    let threads = if par_threads == 0 { shards } else { par_threads };
                    println!(
                        "parallel DES: {shards} shard(s) on {} worker thread(s)",
                        threads.clamp(1, shards)
                    );
                    simulate_parallel(pool, &trace, &cfg, shards, threads)
                }
            } else {
                simulate(&mut pool, &trace, &cfg)
            };
            finish_engine(&engine);
            println!("offered {} frames", r.offered);
            print!("{}", fleet_table(&r));
        }
        Some("scenario") => {
            use gemmini_edge::fpga::resources::Board;
            use gemmini_edge::report::fleet_table;
            use gemmini_edge::scenario::{
                run_scenario_autoscaled, run_scenario_des, run_scenario_live, ScenarioCatalog,
                ScenarioWorkload,
            };
            use gemmini_edge::serving::device::DEFAULT_DISPATCH_S;
            use gemmini_edge::serving::{
                AdmissionPolicy, AutoscaleConfig, Autoscaler, Backend, BatchPolicy, ClockMode,
                DrainOrder, FaultPlan, GemminiDevice, LiveConfig, ShardPool, ShedPolicy,
                SimConfig, TargetUtilization, VariantLadder,
            };
            let cat = ScenarioCatalog::standard();
            if args.iter().any(|a| a == "--list") {
                for s in cat.all() {
                    println!(
                        "{:<12} {} cameras × {:.0} FPS × {:.0} s | segments: {}{}",
                        s.name,
                        s.cameras,
                        s.fps,
                        s.horizon_s,
                        s.segments
                            .iter()
                            .map(|g| format!("{} (d{} ×{:.1})", g.name, g.density, g.arrival_mult))
                            .collect::<Vec<_>>()
                            .join(", "),
                        if s.dropouts.is_empty() {
                            String::new()
                        } else {
                            format!(" | {} dropout window(s)", s.dropouts.len())
                        }
                    );
                }
                return Ok(());
            }
            let name = arg_val(&args, "--name").unwrap_or_else(|| "rush-hour".into());
            let Some(sc) = cat.get(&name) else {
                eprintln!("unknown scenario '{name}'; --list shows: {:?}", cat.names());
                return Ok(());
            };
            let seed: u64 =
                arg_val(&args, "--seed").and_then(|v| v.parse().ok()).unwrap_or(20240710);
            let load: f64 = arg_val(&args, "--load")
                .and_then(|v| v.parse().ok())
                .unwrap_or(1.0)
                .max(0.01);
            let autoscale = args.iter().any(|a| a == "--autoscale");
            let live = args.iter().any(|a| a == "--live");
            if live && autoscale {
                eprintln!("warning: --live serves on a fixed pool; ignoring --autoscale");
            }
            let autoscale = autoscale && !live;
            let max_devices: usize =
                arg_val(&args, "--max-devices").and_then(|v| v.parse().ok()).unwrap_or(6);
            let virtual_clock = args.iter().any(|a| a == "--virtual-clock");
            let live_threads: usize =
                arg_val(&args, "--live-threads").and_then(|v| v.parse().ok()).unwrap_or(0);
            let time_scale: f64 = arg_val(&args, "--time-scale")
                .and_then(|v| v.parse().ok())
                .unwrap_or(1.0)
                .max(1e-3);
            let ladder = args.iter().any(|a| a == "--ladder");
            let faults = arg_val(&args, "--faults").and_then(|spec| {
                let plan = if spec == "demo" {
                    Ok(FaultPlan::demo(seed, sc.horizon_s))
                } else {
                    FaultPlan::parse(&spec, seed)
                };
                match plan {
                    Ok(p) => Some(p),
                    Err(err) => {
                        eprintln!("warning: bad --faults spec ({err}); running fault-free");
                        None
                    }
                }
            });

            let w = ScenarioWorkload::generate(&sc.scaled(load), seed);
            println!(
                "scenario '{}' (load ×{load:.1}, seed {seed}): {} cameras | {} frames over {:.0} s{}{}",
                w.scenario.name,
                w.scenario.cameras,
                w.trace.len(),
                w.scenario.horizon_s,
                if ladder { " | degradation ladder armed" } else { "" },
                if live { " | LIVE threaded runtime" } else { "" }
            );

            // Same paper boards as `repro fleet`, through the shared
            // cache-backed tuning engine.
            let mut g = build_detector(96, &default_weights());
            gemmini_edge::passes::replace_activations(&mut g);
            let mut engine = engine_with_cache(GemminiConfig::ours_zcu102(), &args);
            let tuning = engine.tune_graph(&g, 2);
            let rungs = ladder.then(|| VariantLadder::paper_ladder(&mut engine, 96, 2));
            let mut pool = ShardPool::paper_boards(&tuning, DEFAULT_DISPATCH_S);

            let cfg = SimConfig {
                batch: BatchPolicy::new(4, 0.020),
                queue_depth: 16,
                shed: ShedPolicy::DropOldest,
                slo_s: 0.200,
                work_stealing: !live,
                admission: match rungs {
                    Some(l) => AdmissionPolicy::Degrade(l),
                    None => AdmissionPolicy::Open,
                },
                faults,
                ..Default::default()
            };
            if let Some(p) = &cfg.faults {
                println!(
                    "fault plan armed: {} crash(es) | {} slowdown window(s) | spikes p={:.2} | link drops p={:.2} | recovery {}",
                    p.crashes.len(),
                    p.slowdowns.len(),
                    p.spike_prob,
                    p.link_drop_prob,
                    if p.recovery.is_some() { "on" } else { "off" }
                );
            }
            let r = if live {
                let lcfg = LiveConfig {
                    threads: live_threads,
                    clock: if virtual_clock { ClockMode::Virtual } else { ClockMode::Wall },
                    time_scale,
                    ..LiveConfig::default()
                };
                run_scenario_live(&w, pool, &cfg, &lcfg)
            } else if autoscale {
                let acfg = AutoscaleConfig {
                    epoch_s: 0.5,
                    provision_delay_s: 1.0,
                    min_devices: pool.len(),
                    max_devices: max_devices.max(pool.len()),
                    cooldown_epochs: 1,
                    drain_order: DrainOrder::NewestFirst,
                };
                let mut auto = Autoscaler::new(acfg, Box::new(TargetUtilization::default()));
                let mut factory = |i: usize| -> Box<dyn Backend> {
                    let label = format!("ZCU102-Gemmini (replica {i})");
                    Box::new(GemminiDevice::from_engine(
                        &label,
                        Board::Zcu102,
                        &mut engine,
                        &g,
                        2,
                        4,
                        DEFAULT_DISPATCH_S,
                    ))
                };
                run_scenario_autoscaled(&w, &mut pool, &cfg, &mut auto, &mut factory)
            } else {
                run_scenario_des(&w, &mut pool, &cfg)
            };
            finish_engine(&engine);
            print!("{}", fleet_table(&r));
        }
        _ => {
            eprintln!("usage: repro <report|deploy|infer|tune|fleet|scenario> [options]");
        }
    }
    Ok(())
}
