//! `repro` — CLI for the gemmini-edge reproduction.
//!
//! Subcommands (hand-rolled arg parsing; clap is unavailable offline):
//!
//! ```text
//! repro report table2|table3          print paper tables from the models
//! repro deploy [--size N] [--trials K]  run the full workflow on the detector
//! repro infer [--hlo PATH]            run the AOT artifact on a scene (PJRT)
//! repro tune [--size N] [--variant base|p40|p88] [--trials K]
//! repro fleet [--cameras N] [--fps F] [--batch B] [--wait MS] [--seconds S]
//! ```

use gemmini_edge::coordinator::{deploy, DeployOptions};
use gemmini_edge::dataset::detector::{build_detector, default_weights};
use gemmini_edge::dataset::scenes::{validation_set, SceneConfig};
use gemmini_edge::gemmini::config::GemminiConfig;
use gemmini_edge::ir::interp::Value;
use gemmini_edge::postproc::nms::{decode_and_nms, NmsConfig};
use gemmini_edge::report;
use gemmini_edge::runtime::Executor;
use gemmini_edge::scheduler::tune_graph;
use gemmini_edge::workload::{yolov7_tiny, ModelVariant};

fn arg_val(args: &[String], key: &str) -> Option<String> {
    args.iter().position(|a| a == key).and_then(|i| args.get(i + 1).cloned())
}

fn main() -> Result<(), Box<dyn std::error::Error>> {
    let args: Vec<String> = std::env::args().skip(1).collect();
    match args.first().map(String::as_str) {
        Some("report") => match args.get(1).map(String::as_str) {
            Some("table2") => {
                print!("{}", report::table2(&gemmini_edge::fpga::resources::table2_rows()));
            }
            Some("table3") => {
                print!(
                    "{}",
                    report::table3(
                        &GemminiConfig::original_zcu102(),
                        &GemminiConfig::ours_zcu102()
                    )
                );
            }
            _ => eprintln!("usage: repro report table2|table3"),
        },
        Some("deploy") => {
            let size: usize =
                arg_val(&args, "--size").and_then(|v| v.parse().ok()).unwrap_or(96);
            let trials: usize =
                arg_val(&args, "--trials").and_then(|v| v.parse().ok()).unwrap_or(4);
            let w = default_weights();
            let g = build_detector(size, &w);
            let scenes = validation_set(&SceneConfig { size, ..Default::default() }, 24, 7);
            let calib: Vec<Vec<Value>> =
                scenes.iter().take(4).map(|s| vec![s.image.clone()]).collect();
            let opts = DeployOptions { measure_k: trials, ..Default::default() };
            let r = deploy(&g, &calib, &scenes, &opts);
            println!("deployed detector @{size}px");
            println!("  mAP@0.5           : {:.3}", r.map.unwrap_or(0.0));
            println!("  latency (tuned)   : {:.3} ms ({:.1} FPS)", r.latency_s * 1e3, r.fps());
            println!("  latency (default) : {:.3} ms", r.default_latency_s * 1e3);
            println!("  energy            : {:.4} J ({:.1} GOP/s/W)", r.energy.energy_j, r.energy.efficiency());
            for p in &r.placements {
                println!("  placement {:<18}: {:.3} ms", p.label(), p.total_s() * 1e3);
            }
        }
        Some("infer") => {
            let hlo = arg_val(&args, "--hlo").unwrap_or_else(|| "artifacts/model.hlo.txt".into());
            let exe = Executor::load(&hlo)?;
            let size = exe.meta.input_shape[1];
            let scenes = validation_set(&SceneConfig { size, ..Default::default() }, 1, 99);
            let t0 = std::time::Instant::now();
            let head = exe.run(&scenes[0].image)?;
            let dt = t0.elapsed();
            // Decode via the IR op semantics (single-scale head).
            let g = {
                let mut b = gemmini_edge::ir::GraphBuilder::new("decode");
                let x = b.input("head", head.shape.clone());
                let d = b.box_decode(x, exe.meta.num_anchors, exe.meta.num_classes);
                b.finish(&[d])
            };
            let boxes = gemmini_edge::ir::Interpreter::new(&g).run(&[head]);
            let dets = decode_and_nms(&boxes[0].f, exe.meta.num_classes, &NmsConfig::default());
            println!("PJRT inference: {:.2} ms, {} detections", dt.as_secs_f64() * 1e3, dets.len());
            for d in dets.iter().take(8) {
                println!("  class {} score {:.2} at ({:.2},{:.2})", d.class, d.score, d.bbox.cx, d.bbox.cy);
            }
            println!("ground truth: {} objects", scenes[0].truths.len());
        }
        Some("tune") => {
            let size: usize =
                arg_val(&args, "--size").and_then(|v| v.parse().ok()).unwrap_or(160);
            let trials: usize =
                arg_val(&args, "--trials").and_then(|v| v.parse().ok()).unwrap_or(4);
            let variant = match arg_val(&args, "--variant").as_deref() {
                Some("p40") => ModelVariant::Pruned40,
                Some("p88") => ModelVariant::Pruned88,
                _ => ModelVariant::Base,
            };
            let mut g = yolov7_tiny(size, variant, 80);
            gemmini_edge::passes::replace_activations(&mut g);
            let cfg = GemminiConfig::ours_zcu102();
            let t = tune_graph(&cfg, &g, trials);
            println!("{}", t.to_json().dump());
            println!(
                "# conv improvement {:.1}% | layers improved {:.0}% | latency {:.1} ms",
                t.conv_improvement() * 100.0,
                t.fraction_improved() * 100.0,
                t.latency_s(&cfg, true) * 1e3
            );
        }
        Some("fleet") => {
            use gemmini_edge::baselines::xavier;
            use gemmini_edge::report::fleet_table;
            use gemmini_edge::serving::device::DEFAULT_DISPATCH_S;
            use gemmini_edge::serving::{
                multi_camera_trace, simulate, BaselineDevice, BatchPolicy, ShardPool, SimConfig,
            };
            let cameras: usize =
                arg_val(&args, "--cameras").and_then(|v| v.parse().ok()).unwrap_or(24);
            let fps: f64 = arg_val(&args, "--fps").and_then(|v| v.parse().ok()).unwrap_or(30.0);
            let batch: usize =
                arg_val(&args, "--batch").and_then(|v| v.parse().ok()).unwrap_or(8);
            let wait_ms: f64 =
                arg_val(&args, "--wait").and_then(|v| v.parse().ok()).unwrap_or(15.0);
            let seconds: f64 =
                arg_val(&args, "--seconds").and_then(|v| v.parse().ok()).unwrap_or(10.0);

            // Tune the detector once per distinct architecture.
            let mut g = build_detector(96, &default_weights());
            gemmini_edge::passes::replace_activations(&mut g);
            let cfg102 = GemminiConfig::ours_zcu102();
            let tuning = tune_graph(&cfg102, &g, 2);

            let mut pool = ShardPool::paper_boards(&tuning, DEFAULT_DISPATCH_S);
            pool.register(Box::new(BaselineDevice::new(xavier(), g.gops(), 8)));

            let scene = SceneConfig { size: 96, ..Default::default() };
            let trace = multi_camera_trace(&scene, cameras, fps, seconds, 20240710);
            let cfg = SimConfig {
                batch: BatchPolicy::new(batch, wait_ms * 1e-3),
                ..Default::default()
            };
            println!(
                "fleet: {} devices | {cameras} cameras × {fps:.0} FPS × {seconds:.0} s = {} frames | batch≤{batch}, wait≤{wait_ms:.0} ms",
                pool.len(),
                trace.len()
            );
            let r = simulate(&mut pool, &trace, &cfg);
            print!("{}", fleet_table(&r));
        }
        _ => {
            eprintln!("usage: repro <report|deploy|infer|tune|fleet> [options]");
        }
    }
    Ok(())
}
